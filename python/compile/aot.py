"""AOT pipeline: lower every Layer-2 entry point to HLO **text** and
emit `artifacts/manifest.json` + initial-parameter binaries.

HLO text (not `.serialize()`d protos) is the interchange format: the
image's xla_extension 0.5.1 rejects jax≥0.5's 64-bit instruction ids,
while the text parser reassigns ids (see /opt/xla-example/README.md).

Run once via `make artifacts`; Python never runs on the request path.

Artifacts
---------
- ``train_<cfg>.hlo.txt`` / ``eval_<cfg>.hlo.txt`` — training/eval steps
  for each model variant (fc / trl / trl_cts / trl_mts sweep).
- ``params_<cfg>.bin`` — raw little-endian f32 initial parameters
  (concatenated in schema order).
- ``op_mts_sketch.hlo.txt`` / ``op_cs_sketch.hlo.txt`` /
  ``op_kron_combine.hlo.txt`` — the coordinator's service ops (Layer-1
  Pallas kernels lowered standalone), hashes baked in and exported to
  the manifest so the Rust side can decompress.
- ``manifest.json`` — entry-point index: shapes, dtypes, parameter
  schemas, hash tables.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .hashes import mts_hashes
from .kernels.cs_kernel import cs_batch
from .kernels.fft_combine import kron_combine
from .kernels.mts_kernel import mts_matrix

# service-op shapes (the coordinator's size classes)
OP_MAT_N = (64, 64)
OP_MAT_M = (16, 16)
OP_CS = (64, 256, 32)  # batch, n, c
OP_KRON_M = (16, 16)
OP_SEED = 4242

# model variants lowered for the Fig 10 / Fig 12 experiments
HEAD_CONFIGS = [
    M.HeadConfig(head="fc"),
    M.HeadConfig(head="trl"),
    M.HeadConfig(head="trl_cts", cts_c=8),
    M.HeadConfig(head="trl_mts", sketch=(8, 8, 16)),
    M.HeadConfig(head="trl_mts", sketch=(4, 4, 8)),
    M.HeadConfig(head="trl_mts", sketch=(3, 3, 6)),
    M.HeadConfig(head="trl_mts", sketch=(2, 2, 4)),
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # CRITICAL: the default printer elides multi-dimensional constants as
    # `constant({...})`, which the consuming parser reads back as zeros —
    # silently zeroing the baked hash matrices. print_large_constants
    # forces full literals; print_metadata off keeps the text lean and
    # parser-friendly for xla_extension 0.5.1.
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    opts.print_metadata = False
    return comp.get_hlo_module().to_string(opts)


def lower_fn(fn, example_args) -> str:
    return to_hlo_text(jax.jit(fn).lower(*example_args))


def hash_to_json(h: np.ndarray, s: np.ndarray) -> dict:
    """Export a one-hot/sign pair as (bucket indices, signs)."""
    return {
        "buckets": np.argmax(h, axis=1).astype(int).tolist(),
        "signs": s.astype(float).tolist(),
    }


def emit_model_artifacts(outdir: str, manifest: dict) -> None:
    for cfg in HEAD_CONFIGS:
        name = cfg.name
        # --- train step ---
        train_path = f"train_{name}.hlo.txt"
        text = lower_fn(M.make_train_step(cfg), M.example_args_train(cfg))
        with open(os.path.join(outdir, train_path), "w") as f:
            f.write(text)
        # --- eval step ---
        eval_path = f"eval_{name}.hlo.txt"
        text = lower_fn(M.make_eval_step(cfg), M.example_args_eval(cfg))
        with open(os.path.join(outdir, eval_path), "w") as f:
            f.write(text)
        # --- predict step (serving) ---
        predict_path = f"predict_{name}.hlo.txt"
        text = lower_fn(M.make_predict_step(cfg), M.example_args_predict(cfg))
        with open(os.path.join(outdir, predict_path), "w") as f:
            f.write(text)
        # --- init params ---
        params = M.init_params(cfg, seed=0)
        params_path = f"params_{name}.bin"
        with open(os.path.join(outdir, params_path), "wb") as f:
            for p in params:
                f.write(np.ascontiguousarray(p, dtype="<f4").tobytes())
        manifest["models"][name] = {
            "head": cfg.head,
            "train": train_path,
            "eval": eval_path,
            "predict": predict_path,
            "init_params": params_path,
            "batch": cfg.batch,
            "img": list(M.IMG),
            "num_classes": M.NUM_CLASSES,
            "param_schema": [
                {"name": n, "shape": list(s)} for n, s in M.schema(cfg)
            ],
            "head_param_count": M.param_count(cfg),
            "total_param_count": M.param_count(cfg, head_only=False),
            # compression ratio w.r.t. the exact trl head
            "sketch": list(cfg.sketch) if cfg.head == "trl_mts" else None,
            "cts_c": cfg.cts_c if cfg.head == "trl_cts" else None,
        }
        print(f"  model {name}: train+eval+params "
              f"({M.param_count(cfg)} head params)")


def emit_op_artifacts(outdir: str, manifest: dict) -> None:
    # --- MTS of a matrix (sketch-service op) ---
    (n1, n2), (m1, m2) = OP_MAT_N, OP_MAT_M
    (h1, s1), (h2, s2) = mts_hashes([n1, n2], [m1, m2], OP_SEED)

    def op_mts(x):
        return mts_matrix(x, h1, s1, h2, s2, m1=m1, m2=m2)

    text = lower_fn(op_mts, [jax.ShapeDtypeStruct((n1, n2), jnp.float32)])
    with open(os.path.join(outdir, "op_mts_sketch.hlo.txt"), "w") as f:
        f.write(text)
    manifest["ops"]["mts_sketch"] = {
        "path": "op_mts_sketch.hlo.txt",
        "input_dims": [n1, n2],
        "sketch_dims": [m1, m2],
        "hashes": [hash_to_json(h1, s1), hash_to_json(h2, s2)],
    }

    # --- batched CS (sketch-service op) ---
    b, n, c = OP_CS
    ((hc, sc),) = mts_hashes([n], [c], OP_SEED + 1)

    def op_cs(x):
        return cs_batch(x, hc, sc, c=c)

    text = lower_fn(op_cs, [jax.ShapeDtypeStruct((b, n), jnp.float32)])
    with open(os.path.join(outdir, "op_cs_sketch.hlo.txt"), "w") as f:
        f.write(text)
    manifest["ops"]["cs_sketch"] = {
        "path": "op_cs_sketch.hlo.txt",
        "batch": b,
        "input_dims": [n],
        "sketch_dims": [c],
        "hashes": [hash_to_json(hc, sc)],
    }

    # --- sketched-Kronecker combine ---
    km1, km2 = OP_KRON_M
    text = lower_fn(
        kron_combine,
        [
            jax.ShapeDtypeStruct((km1, km2), jnp.float32),
            jax.ShapeDtypeStruct((km1, km2), jnp.float32),
        ],
    )
    with open(os.path.join(outdir, "op_kron_combine.hlo.txt"), "w") as f:
        f.write(text)
    manifest["ops"]["kron_combine"] = {
        "path": "op_kron_combine.hlo.txt",
        "sketch_dims": [km1, km2],
    }
    print(f"  ops: mts_sketch {OP_MAT_N}->{OP_MAT_M}, cs_sketch {OP_CS}, "
          f"kron_combine {OP_KRON_M}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--ops-only", action="store_true",
                    help="emit only the service ops (fast)")
    args = ap.parse_args()
    outdir = args.out
    os.makedirs(outdir, exist_ok=True)

    manifest: dict = {"version": 1, "models": {}, "ops": {}}
    print("emitting service ops …")
    emit_op_artifacts(outdir, manifest)
    if not args.ops_only:
        print("emitting model train/eval steps …")
        emit_model_artifacts(outdir, manifest)

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {outdir}/manifest.json")


if __name__ == "__main__":
    main()
