"""Host-side hash generation for the sketch layers.

The MTS/CS hash functions used inside the AOT-compiled model are drawn
once at build time (seeded, reproducible) and baked into the HLO as
constants — the runtime never needs to evaluate a hash function, which
is what keeps Python off the request path.

Represented as:
  - one-hot matrices  H_k ∈ {0,1}^{n_k × m_k}   (H[a, h(a)] = 1)
  - sign vectors      s_k ∈ {±1}^{n_k}

A one-hot matmul is the TPU-friendly formulation of the scatter (see
DESIGN.md §Hardware-Adaptation): contracting with H_k on the MXU replaces
the serialized scatter the GPU formulation would use.
"""

from __future__ import annotations

import numpy as np


def mode_hash(n: int, m: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Return (one_hot H [n, m] float32, signs s [n] float32) for one mode."""
    rng = np.random.default_rng(seed)
    buckets = rng.integers(0, m, size=n)
    onehot = np.zeros((n, m), dtype=np.float32)
    onehot[np.arange(n), buckets] = 1.0
    signs = rng.choice(np.array([-1.0, 1.0], dtype=np.float32), size=n)
    return onehot, signs


def mts_hashes(dims: list[int], sketch_dims: list[int], seed: int):
    """Per-mode (H, s) pairs for an MTS of shape dims -> sketch_dims."""
    assert len(dims) == len(sketch_dims)
    out = []
    for k, (n, m) in enumerate(zip(dims, sketch_dims)):
        out.append(mode_hash(n, m, seed * 1_000_003 + 17 * k + 1))
    return out
