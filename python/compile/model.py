"""Layer-2 JAX model: the paper's §4.3 experiment — a small conv
feature extractor whose flatten+FC head is replaced by a (sketched)
tensor-regression layer.

Heads
-----
- ``fc``       flatten → dense (the non-tensorized baseline)
- ``trl``      exact Tucker tensor-regression layer (Kossaifi et al.):
               logits_o = ⟨G(U1,U2,U3)[..,o], A⟩
- ``trl_mts``  the paper's contribution: the regression weight is
               *learned directly in MTS sketch space*. The activation
               tensor is sketched with fixed random hashes (the Layer-1
               Pallas kernel ``mts_batch3``) and inner-producted with the
               learned sketch weights: because decompression is linear,
               ⟨decompress(Ws), A⟩ = ⟨Ws, MTS_scatter(A)⟩.
- ``trl_cts``  the CTS baseline: count-sketch only the channel fibres
               (Layer-1 kernel ``cs_batch``), learn weights in that space.

Everything is pure-functional over an explicit ordered parameter list so
the AOT boundary (aot.py → Rust runtime) is a flat list of f32 buffers.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .hashes import mts_hashes
from .kernels.cs_kernel import make_cs_layer
from .kernels.mts_kernel import make_mts_layer

# ---------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------

NUM_CLASSES = 10
IMG = (32, 32, 3)
# activation tensor after the two conv/pool stages
ACT = (8, 8, 32)


@dataclasses.dataclass(frozen=True)
class HeadConfig:
    """Static configuration of one model variant."""

    head: str  # fc | trl | trl_mts | trl_cts
    batch: int = 64
    # trl ranks (r1, r2, r3)
    ranks: tuple[int, int, int] = (8, 8, 16)
    # trl_mts sketch dims (m1, m2, m3)
    sketch: tuple[int, int, int] = (4, 4, 8)
    # trl_cts channel sketch size c
    cts_c: int = 8
    hash_seed: int = 20190711
    lr_momentum: float = 0.9

    @property
    def name(self) -> str:
        if self.head == "trl_mts":
            return f"trl_mts_{self.sketch[0]}x{self.sketch[1]}x{self.sketch[2]}"
        if self.head == "trl_cts":
            return f"trl_cts_{self.cts_c}"
        return self.head


# ---------------------------------------------------------------------
# parameters: explicit ordered (name, shape) schema per head
# ---------------------------------------------------------------------

FEATURE_SCHEMA = [
    ("conv1_w", (3, 3, 3, 16)),
    ("conv1_b", (16,)),
    ("conv2_w", (3, 3, 16, 32)),
    ("conv2_b", (32,)),
]


def head_schema(cfg: HeadConfig) -> list[tuple[str, tuple[int, ...]]]:
    h, w, c = ACT
    if cfg.head == "fc":
        return [("fc_w", (h * w * c, NUM_CLASSES)), ("fc_b", (NUM_CLASSES,))]
    if cfg.head == "trl":
        r1, r2, r3 = cfg.ranks
        return [
            ("trl_u1", (h, r1)),
            ("trl_u2", (w, r2)),
            ("trl_u3", (c, r3)),
            ("trl_core", (r1, r2, r3, NUM_CLASSES)),
            ("trl_b", (NUM_CLASSES,)),
        ]
    if cfg.head == "trl_mts":
        m1, m2, m3 = cfg.sketch
        return [("mts_w", (m1, m2, m3, NUM_CLASSES)), ("mts_b", (NUM_CLASSES,))]
    if cfg.head == "trl_cts":
        return [("cts_w", (h, w, cfg.cts_c, NUM_CLASSES)), ("cts_b", (NUM_CLASSES,))]
    raise ValueError(f"unknown head {cfg.head!r}")


def schema(cfg: HeadConfig) -> list[tuple[str, tuple[int, ...]]]:
    return FEATURE_SCHEMA + head_schema(cfg)


def init_params(cfg: HeadConfig, seed: int = 0) -> list[np.ndarray]:
    """He-style init, numpy (build-time only)."""
    rng = np.random.default_rng(seed)
    out = []
    for name, shape in schema(cfg):
        if name.endswith("_b"):
            out.append(np.zeros(shape, dtype=np.float32))
        else:
            fan_in = int(np.prod(shape[:-1])) if len(shape) > 1 else shape[0]
            std = np.sqrt(2.0 / max(fan_in, 1))
            out.append(rng.standard_normal(shape).astype(np.float32) * std)
    return out


def fixed_hashes(cfg: HeadConfig):
    """Build-time hash constants for the sketched heads (baked into HLO)."""
    h, w, c = ACT
    if cfg.head == "trl_mts":
        return mts_hashes([h, w, c], list(cfg.sketch), cfg.hash_seed)
    if cfg.head == "trl_cts":
        return mts_hashes([c], [cfg.cts_c], cfg.hash_seed)
    return []


# ---------------------------------------------------------------------
# forward pass
# ---------------------------------------------------------------------


def _conv(x, w, b):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + b[None, None, None, :]


def _avgpool2(x):
    return jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    ) / 4.0


def features(p: dict, x):
    """Conv feature extractor: [B,32,32,3] -> activation [B,8,8,32]."""
    y = jax.nn.relu(_conv(x, p["conv1_w"], p["conv1_b"]))
    y = _avgpool2(y)
    y = jax.nn.relu(_conv(y, p["conv2_w"], p["conv2_b"]))
    y = _avgpool2(y)  # 32→16→8
    return y


def logits_fn(cfg: HeadConfig, p: dict, x, hashes):
    a = features(p, x)  # [B, 8, 8, 32]
    if cfg.head == "fc":
        flat = a.reshape(a.shape[0], -1)
        return flat @ p["fc_w"] + p["fc_b"]
    if cfg.head == "trl":
        core_act = jnp.einsum(
            "nijk,ip,jq,kr->npqr", a, p["trl_u1"], p["trl_u2"], p["trl_u3"]
        )
        return jnp.einsum("npqr,pqro->no", core_act, p["trl_core"]) + p["trl_b"]
    if cfg.head == "trl_mts":
        (h1, s1), (h2, s2), (h3, s3) = hashes
        layer = make_mts_layer(h1, s1, h2, s2, h3, s3)
        sa = layer(a)
        return jnp.einsum("npqr,pqro->no", sa, p["mts_w"]) + p["mts_b"]
    if cfg.head == "trl_cts":
        ((h, s),) = hashes
        layer = make_cs_layer(h, s)
        b, hh, ww, cc = a.shape
        flat = a.reshape(b * hh * ww, cc)
        sk = layer(flat).reshape(b, hh, ww, cfg.cts_c)
        return jnp.einsum("nijc,ijco->no", sk, p["cts_w"]) + p["cts_b"]
    raise ValueError(cfg.head)


# ---------------------------------------------------------------------
# loss / steps
# ---------------------------------------------------------------------


def loss_and_acc(cfg: HeadConfig, p: dict, x, y, hashes):
    logits = logits_fn(cfg, p, x, hashes)
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
    acc = jnp.mean((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))
    return loss, acc


def _to_dict(cfg: HeadConfig, flat):
    names = [n for n, _ in schema(cfg)]
    return dict(zip(names, flat))


def make_train_step(cfg: HeadConfig) -> Callable:
    """Returns train_step(*params, *momenta, x, y, lr) ->
    (*params', *momenta', loss, acc) with SGD + momentum."""
    hashes = fixed_hashes(cfg)
    n_params = len(schema(cfg))
    mu = cfg.lr_momentum

    def step(*args):
        flat_p = args[:n_params]
        flat_m = args[n_params : 2 * n_params]
        x, y, lr = args[2 * n_params :]
        p = _to_dict(cfg, flat_p)

        def loss_fn(pd):
            return loss_and_acc(cfg, pd, x, y, hashes)

        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(p)
        names = [n for n, _ in schema(cfg)]
        new_p = []
        new_m = []
        for name, pv, mv in zip(names, flat_p, flat_m):
            g = grads[name]
            m2 = mu * mv + g
            new_m.append(m2)
            new_p.append(pv - lr * m2)
        return (*new_p, *new_m, loss, acc)

    return step


def make_predict_step(cfg: HeadConfig) -> Callable:
    """Returns predict(*params, x) -> (logits,) — the serving entry
    point the coordinator batches requests into."""
    hashes = fixed_hashes(cfg)
    n_params = len(schema(cfg))

    def step(*args):
        flat_p = args[:n_params]
        (x,) = args[n_params:]
        p = _to_dict(cfg, flat_p)
        return (logits_fn(cfg, p, x, hashes),)

    return step


def example_args_predict(cfg: HeadConfig):
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in schema(cfg)]
    specs.append(jax.ShapeDtypeStruct((cfg.batch, *IMG), jnp.float32))
    return specs


def make_eval_step(cfg: HeadConfig) -> Callable:
    """Returns eval_step(*params, x, y) -> (loss, acc)."""
    hashes = fixed_hashes(cfg)
    n_params = len(schema(cfg))

    def step(*args):
        flat_p = args[:n_params]
        x, y = args[n_params :]
        p = _to_dict(cfg, flat_p)
        loss, acc = loss_and_acc(cfg, p, x, y, hashes)
        return (loss, acc)

    return step


def example_args_train(cfg: HeadConfig):
    """ShapeDtypeStructs for lowering train_step."""
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in schema(cfg)]
    specs = specs + specs  # params + momenta
    specs.append(jax.ShapeDtypeStruct((cfg.batch, *IMG), jnp.float32))
    specs.append(jax.ShapeDtypeStruct((cfg.batch,), jnp.int32))
    specs.append(jax.ShapeDtypeStruct((), jnp.float32))
    return specs


def example_args_eval(cfg: HeadConfig):
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in schema(cfg)]
    specs.append(jax.ShapeDtypeStruct((cfg.batch, *IMG), jnp.float32))
    specs.append(jax.ShapeDtypeStruct((cfg.batch,), jnp.int32))
    return specs


def param_count(cfg: HeadConfig, head_only: bool = True) -> int:
    sch = head_schema(cfg) if head_only else schema(cfg)
    return sum(int(np.prod(s)) for _, s in sch)
