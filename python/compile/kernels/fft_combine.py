"""Layer-1 Pallas kernel: the element-wise complex product at the heart
of the sketched-Kronecker combine (Lemma B.1:
`MTS(A⊗B) = IFFT2(FFT2(A') ∘ FFT2(B'))`).

The FFTs themselves are left to XLA (`jnp.fft`) — they lower to the
optimized backend FFT op — while the complex Hadamard product between
the two spectra is the Pallas kernel (on TPU this is the VPU-bound step
that benefits from fusing the four real multiplies in VMEM).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _complex_mul_kernel(ar_ref, ai_ref, br_ref, bi_ref, or_ref, oi_ref):
    ar, ai = ar_ref[...], ai_ref[...]
    br, bi = br_ref[...], bi_ref[...]
    or_ref[...] = ar * br - ai * bi
    oi_ref[...] = ar * bi + ai * br


@jax.jit
def complex_mul(ar, ai, br, bi):
    """Element-wise complex multiply on split re/im planes (any 2-D shape)."""
    assert ar.shape == ai.shape == br.shape == bi.shape
    shape = ar.shape
    out = pl.pallas_call(
        _complex_mul_kernel,
        out_shape=(
            jax.ShapeDtypeStruct(shape, jnp.float32),
            jax.ShapeDtypeStruct(shape, jnp.float32),
        ),
        interpret=True,
    )(ar, ai, br, bi)
    return out


@jax.jit
def kron_combine(sa, sb):
    """Full sketched-Kronecker combine: FFT2 both sketches (XLA), complex
    Hadamard (Pallas), IFFT2 (XLA), real part.

    sa, sb: [m1, m2] float32 -> [m1, m2] float32
    """
    fa = jnp.fft.fft2(sa)
    fb = jnp.fft.fft2(sb)
    pr, pi = complex_mul(
        jnp.real(fa).astype(jnp.float32),
        jnp.imag(fa).astype(jnp.float32),
        jnp.real(fb).astype(jnp.float32),
        jnp.imag(fb).astype(jnp.float32),
    )
    prod = pr.astype(jnp.complex64) + 1j * pi.astype(jnp.complex64)
    return jnp.real(jnp.fft.ifft2(prod)).astype(jnp.float32)
