"""Pure-jnp oracles for every Pallas kernel (the correctness ground
truth the pytest suite asserts against).

Each `*_ref` takes exactly the same arguments as the kernel entry point
in its sibling module and computes the answer with straightforward
jnp einsums / FFTs.
"""

from __future__ import annotations

import jax.numpy as jnp


def cs_batch_ref(x, onehot, signs):
    """Count sketch of a batch of vectors.

    x: [B, n]; onehot: [n, c]; signs: [n] -> [B, c]
    y[b, h(i)] += s(i) x[b, i]  ==  (x * s) @ H
    """
    return (x * signs[None, :]) @ onehot


def mts_matrix_ref(x, h1, s1, h2, s2):
    """MTS of a matrix: H1ᵀ (s1 s2ᵀ ∘ X) H2.

    x: [n1, n2]; h1: [n1, m1]; h2: [n2, m2] -> [m1, m2]
    """
    signed = x * s1[:, None] * s2[None, :]
    return h1.T @ signed @ h2


def mts_batch3_ref(x, h1, s1, h2, s2, h3, s3):
    """MTS of a batch of third-order tensors (the TRL activation path).

    x: [B, n1, n2, n3] -> [B, m1, m2, m3]
    """
    signed = (
        x
        * s1[None, :, None, None]
        * s2[None, None, :, None]
        * s3[None, None, None, :]
    )
    return jnp.einsum("nijk,ip,jq,kr->npqr", signed, h1, h2, h3)


def complex_mul_ref(ar, ai, br, bi):
    """Element-wise complex multiply on split re/im planes."""
    return ar * br - ai * bi, ar * bi + ai * br


def kron_combine_ref(sa, sb):
    """Sketched-Kronecker combine (Lemma B.1):
    IFFT2(FFT2(sa) ∘ FFT2(sb)), real part.

    sa, sb: [m1, m2] real sketches of A and B.
    """
    fa = jnp.fft.fft2(sa)
    fb = jnp.fft.fft2(sb)
    return jnp.real(jnp.fft.ifft2(fa * fb))
