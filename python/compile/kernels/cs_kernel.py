"""Layer-1 Pallas kernel: count sketch of a batch of vectors as a signed
one-hot matmul (the CTS baseline's request-path op)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_B = 128


def _cs_batch_kernel(x_ref, h_ref, s_ref, o_ref):
    signed = x_ref[...] * s_ref[...][None, :]
    o_ref[...] = jnp.dot(signed, h_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("c",))
def cs_batch(x, onehot, signs, *, c: int):
    """Count sketch each row of `x`: [B, n] @ one-hot [n, c] -> [B, c]."""
    b, n = x.shape
    tb = min(TILE_B, b)
    assert b % tb == 0, (b, tb)
    return pl.pallas_call(
        _cs_batch_kernel,
        grid=(b // tb,),
        in_specs=[
            pl.BlockSpec((tb, n), lambda i: (i, 0)),
            pl.BlockSpec((n, c), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tb, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.float32),
        interpret=True,
    )(x, onehot, signs)


def _cs_batch_t_kernel(g_ref, h_ref, s_ref, o_ref):
    o_ref[...] = jnp.dot(
        g_ref[...], h_ref[...].T, preferred_element_type=jnp.float32
    ) * s_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("n",))
def cs_batch_t(g, onehot, signs, *, n: int):
    """Adjoint of [`cs_batch`]: [B, c] -> [B, n] (signed gather)."""
    b, c = g.shape
    tb = min(TILE_B, b)
    assert b % tb == 0
    return pl.pallas_call(
        _cs_batch_t_kernel,
        grid=(b // tb,),
        in_specs=[
            pl.BlockSpec((tb, c), lambda i: (i, 0)),
            pl.BlockSpec((n, c), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tb, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=True,
    )(g, onehot, signs)


def make_cs_layer(onehot, signs):
    """Differentiable count-sketch layer with a custom VJP."""
    onehot = jnp.asarray(onehot)
    signs = jnp.asarray(signs)
    n, c = onehot.shape

    @jax.custom_vjp
    def layer(x):
        return cs_batch(x, onehot, signs, c=c)

    def fwd(x):
        return layer(x), None

    def bwd(_, g):
        return (cs_batch_t(g, onehot, signs, n=n),)

    layer.defvjp(fwd, bwd)
    return layer
