"""Layer-1 Pallas kernels for the MTS scatter, formulated as signed
one-hot matmuls (the TPU adaptation of the paper's scatter — see
DESIGN.md §Hardware-Adaptation: a scatter serializes on TPU, a one-hot
contraction is a dense MXU pass).

All kernels run with ``interpret=True`` — the CPU PJRT plugin cannot
execute Mosaic custom-calls, so interpret mode is the correctness path
and real-TPU performance is estimated from the BlockSpec structure.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM-motivated tile sizes (f32): a 128×128 input tile (64 KiB) plus two
# one-hot tiles and the m1×m2 accumulator stay well under 16 MiB VMEM.
TILE_N1 = 128
TILE_N2 = 128


def _mts_matrix_kernel(x_ref, h1_ref, s1_ref, h2_ref, s2_ref, o_ref):
    """One grid step: accumulate H1_tileᵀ (S ∘ X_tile) H2_tile into o.

    Grid is (n1 // t1, n2 // t2); the output block is the whole m1×m2
    accumulator (index_map -> (0, 0)), so accumulation across grid steps
    is an in-place add — the standard Pallas reduction pattern.
    """
    i, j = pl.program_id(0), pl.program_id(1)
    signed = x_ref[...] * s1_ref[...][:, None] * s2_ref[...][None, :]
    # (t1×t2)ᵀ·(t1×m1) → wrong order; compute H1ᵀ·X first: (m1×t1)·(t1×t2)
    left = jnp.dot(h1_ref[...].T, signed, preferred_element_type=jnp.float32)
    tile = jnp.dot(left, h2_ref[...], preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_and(i == 0, j == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += tile


@functools.partial(jax.jit, static_argnames=("m1", "m2"))
def mts_matrix(x, h1, s1, h2, s2, *, m1: int, m2: int):
    """MTS of a matrix via the tiled Pallas kernel.

    x: [n1, n2], h1: [n1, m1] one-hot, s1: [n1], h2: [n2, m2], s2: [n2]
    -> [m1, m2]
    """
    n1, n2 = x.shape
    t1 = min(TILE_N1, n1)
    t2 = min(TILE_N2, n2)
    # shapes must tile exactly; callers pad if needed (aot.py always
    # lowers power-of-two-friendly shapes)
    assert n1 % t1 == 0 and n2 % t2 == 0, (n1, n2, t1, t2)
    grid = (n1 // t1, n2 // t2)
    return pl.pallas_call(
        _mts_matrix_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t1, t2), lambda i, j: (i, j)),
            pl.BlockSpec((t1, m1), lambda i, j: (i, 0)),
            pl.BlockSpec((t1,), lambda i, j: (i,)),
            pl.BlockSpec((t2, m2), lambda i, j: (j, 0)),
            pl.BlockSpec((t2,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((m1, m2), lambda i, j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m1, m2), jnp.float32),
        interpret=True,
    )(x, h1, s1, h2, s2)


def _mts_batch3_kernel(x_ref, h1_ref, s1_ref, h2_ref, s2_ref, h3_ref, s3_ref, o_ref):
    """Per-batch-element MTS of a third-order activation tensor.

    Block = one batch element [n1, n2, n3]; three one-hot contractions
    run back-to-back in VMEM (n1,n2,n3 are activation-map sized — 8×8×32
    for the TRL — so the whole element fits trivially).
    """
    x = x_ref[0]  # block is [1, n1, n2, n3]; view the element
    signed = (
        x
        * s1_ref[...][:, None, None]
        * s2_ref[...][None, :, None]
        * s3_ref[...][None, None, :]
    )
    # contract mode 2 (n3→m3), then 1, then 0 — smallest output first
    t = jnp.einsum("ijk,kc->ijc", signed, h3_ref[...])
    t = jnp.einsum("ijc,jb->ibc", t, h2_ref[...])
    t = jnp.einsum("ibc,ia->abc", t, h1_ref[...])
    o_ref[0] = t


@functools.partial(jax.jit, static_argnames=("m1", "m2", "m3"))
def mts_batch3(x, h1, s1, h2, s2, h3, s3, *, m1: int, m2: int, m3: int):
    """Batched MTS of order-3 tensors: [B, n1, n2, n3] -> [B, m1, m2, m3].

    This is the request-path kernel of the sketched tensor-regression
    layer (§4.3): the activation tensor is sketched with fixed hashes and
    inner-producted with the learned sketch weights.
    """
    b, n1, n2, n3 = x.shape
    return pl.pallas_call(
        _mts_batch3_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, n1, n2, n3), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((n1, m1), lambda i: (0, 0)),
            pl.BlockSpec((n1,), lambda i: (0,)),
            pl.BlockSpec((n2, m2), lambda i: (0, 0)),
            pl.BlockSpec((n2,), lambda i: (0,)),
            pl.BlockSpec((n3, m3), lambda i: (0, 0)),
            pl.BlockSpec((n3,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, m1, m2, m3), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, m1, m2, m3), jnp.float32),
        interpret=True,
    )(x, h1, s1, h2, s2, h3, s3)


def _mts_batch3_t_kernel(g_ref, h1_ref, s1_ref, h2_ref, s2_ref, h3_ref, s3_ref, o_ref):
    """Adjoint of the MTS scatter: the signed gather
    dX[n,i,j,k] = s1[i]s2[j]s3[k] · g[n, h1(i), h2(j), h3(k)]
    expressed as one-hot contractions from sketch space back up.
    """
    g = g_ref[0]  # [m1, m2, m3]
    t = jnp.einsum("pqr,kr->pqk", g, h3_ref[...])
    t = jnp.einsum("pqk,jq->pjk", t, h2_ref[...])
    t = jnp.einsum("pjk,ip->ijk", t, h1_ref[...])
    t = (
        t
        * s1_ref[...][:, None, None]
        * s2_ref[...][None, :, None]
        * s3_ref[...][None, None, :]
    )
    o_ref[0] = t


@functools.partial(jax.jit, static_argnames=("n1", "n2", "n3"))
def mts_batch3_t(g, h1, s1, h2, s2, h3, s3, *, n1: int, n2: int, n3: int):
    """Transpose (adjoint) of [`mts_batch3`]: [B, m1, m2, m3] -> [B, n1, n2, n3]."""
    b, m1, m2, m3 = g.shape
    return pl.pallas_call(
        _mts_batch3_t_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, m1, m2, m3), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((n1, m1), lambda i: (0, 0)),
            pl.BlockSpec((n1,), lambda i: (0,)),
            pl.BlockSpec((n2, m2), lambda i: (0, 0)),
            pl.BlockSpec((n2,), lambda i: (0,)),
            pl.BlockSpec((n3, m3), lambda i: (0, 0)),
            pl.BlockSpec((n3,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, n1, n2, n3), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n1, n2, n3), jnp.float32),
        interpret=True,
    )(g, h1, s1, h2, s2, h3, s3)


def make_mts_layer(h1, s1, h2, s2, h3, s3):
    """Differentiable MTS-scatter layer with a custom VJP (Pallas has no
    reverse-mode autodiff in interpret mode; the adjoint of a linear
    sketch is the signed gather, itself a Pallas kernel)."""
    h1 = jnp.asarray(h1); s1 = jnp.asarray(s1)
    h2 = jnp.asarray(h2); s2 = jnp.asarray(s2)
    h3 = jnp.asarray(h3); s3 = jnp.asarray(s3)
    n1, m1 = h1.shape
    n2, m2 = h2.shape
    n3, m3 = h3.shape

    @jax.custom_vjp
    def layer(x):
        return mts_batch3(x, h1, s1, h2, s2, h3, s3, m1=m1, m2=m2, m3=m3)

    def fwd(x):
        return layer(x), None

    def bwd(_, g):
        return (mts_batch3_t(g, h1, s1, h2, s2, h3, s3, n1=n1, n2=n2, n3=n3),)

    layer.defvjp(fwd, bwd)
    return layer
