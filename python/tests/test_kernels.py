"""Layer-1 correctness: every Pallas kernel vs its pure-jnp oracle,
swept over shapes (hypothesis) — the CORE correctness signal for the
compute layer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.hashes import mode_hash, mts_hashes
from compile.kernels import ref
from compile.kernels.cs_kernel import cs_batch, cs_batch_t, make_cs_layer
from compile.kernels.fft_combine import complex_mul, kron_combine
from compile.kernels.mts_kernel import (
    make_mts_layer,
    mts_batch3,
    mts_batch3_t,
    mts_matrix,
)

SETTINGS = settings(max_examples=12, deadline=None)


def rand(shape, seed=0):
    return np.random.default_rng(seed).standard_normal(shape).astype(np.float32)


# ---------------------------------------------------------------------
# mts_matrix
# ---------------------------------------------------------------------


@SETTINGS
@given(
    n1=st.sampled_from([8, 16, 32, 128, 256]),
    n2=st.sampled_from([8, 16, 64, 128]),
    m1=st.integers(2, 12),
    m2=st.integers(2, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_mts_matrix_matches_ref(n1, n2, m1, m2, seed):
    (h1, s1), (h2, s2) = mts_hashes([n1, n2], [m1, m2], seed % 99991)
    x = rand((n1, n2), seed)
    got = mts_matrix(x, h1, s1, h2, s2, m1=m1, m2=m2)
    want = ref.mts_matrix_ref(jnp.asarray(x), h1, s1, h2, s2)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_mts_matrix_tiled_path():
    # shapes larger than one tile exercise the grid accumulation
    (h1, s1), (h2, s2) = mts_hashes([256, 256], [16, 16], 7)
    x = rand((256, 256), 3)
    got = mts_matrix(x, h1, s1, h2, s2, m1=16, m2=16)
    want = ref.mts_matrix_ref(jnp.asarray(x), h1, s1, h2, s2)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_mts_matrix_linearity():
    (h1, s1), (h2, s2) = mts_hashes([16, 16], [4, 4], 5)
    x = rand((16, 16), 1)
    y = rand((16, 16), 2)
    lhs = mts_matrix(2.0 * x - y, h1, s1, h2, s2, m1=4, m2=4)
    rhs = 2.0 * mts_matrix(x, h1, s1, h2, s2, m1=4, m2=4) - mts_matrix(
        y, h1, s1, h2, s2, m1=4, m2=4
    )
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------
# mts_batch3 (+ adjoint)
# ---------------------------------------------------------------------


@SETTINGS
@given(
    b=st.sampled_from([1, 2, 4]),
    dims=st.sampled_from([(4, 4, 8), (8, 8, 32), (3, 5, 7)]),
    ms=st.sampled_from([(2, 2, 4), (4, 4, 8), (3, 3, 3)]),
    seed=st.integers(0, 2**31 - 1),
)
def test_mts_batch3_matches_ref(b, dims, ms, seed):
    hs = mts_hashes(list(dims), list(ms), seed % 99991)
    x = rand((b, *dims), seed)
    args = [v for pair in hs for v in pair]
    got = mts_batch3(x, *args, m1=ms[0], m2=ms[1], m3=ms[2])
    want = ref.mts_batch3_ref(jnp.asarray(x), *args)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_mts_batch3_adjoint_is_true_adjoint():
    # ⟨Sx, y⟩ == ⟨x, Sᵀy⟩ for the scatter/gather pair
    dims, ms = (4, 4, 8), (2, 2, 4)
    hs = mts_hashes(list(dims), list(ms), 11)
    args = [v for pair in hs for v in pair]
    x = rand((2, *dims), 1)
    y = rand((2, *ms), 2)
    sx = np.asarray(mts_batch3(x, *args, m1=ms[0], m2=ms[1], m3=ms[2]))
    sty = np.asarray(mts_batch3_t(y, *args, n1=dims[0], n2=dims[1], n3=dims[2]))
    lhs = float(np.sum(sx * y))
    rhs = float(np.sum(x * sty))
    assert abs(lhs - rhs) < 1e-3 * max(1.0, abs(lhs))


def test_mts_layer_grad_matches_jnp_reference():
    dims, ms = (4, 4, 8), (2, 2, 4)
    hs = mts_hashes(list(dims), list(ms), 13)
    args = [v for pair in hs for v in pair]
    layer = make_mts_layer(*args)
    x = rand((2, *dims), 3)
    w = rand((*ms,), 4)

    def f_kernel(x_):
        return jnp.sum(layer(x_) * w[None])

    def f_ref(x_):
        return jnp.sum(ref.mts_batch3_ref(x_, *args) * w[None])

    gk = jax.grad(f_kernel)(jnp.asarray(x))
    gr = jax.grad(f_ref)(jnp.asarray(x))
    np.testing.assert_allclose(gk, gr, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------
# cs_batch (+ adjoint)
# ---------------------------------------------------------------------


@SETTINGS
@given(
    b=st.sampled_from([4, 16, 128, 256]),
    n=st.sampled_from([8, 32, 256]),
    c=st.integers(2, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_cs_batch_matches_ref(b, n, c, seed):
    h, s = mode_hash(n, c, seed % 99991)
    x = rand((b, n), seed)
    got = cs_batch(x, h, s, c=c)
    want = ref.cs_batch_ref(jnp.asarray(x), h, s)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_cs_layer_grad_matches_matmul():
    h, s = mode_hash(32, 8, 5)
    layer = make_cs_layer(h, s)
    x = rand((16, 32), 1)
    w = rand((8,), 2)

    def f_kernel(x_):
        return jnp.sum(layer(x_) * w[None, :])

    def f_ref(x_):
        return jnp.sum(ref.cs_batch_ref(x_, h, s) * w[None, :])

    gk = jax.grad(f_kernel)(jnp.asarray(x))
    gr = jax.grad(f_ref)(jnp.asarray(x))
    np.testing.assert_allclose(gk, gr, rtol=1e-4, atol=1e-4)


def test_cs_adjoint_identity():
    h, s = mode_hash(16, 4, 9)
    x = rand((8, 16), 1)
    y = rand((8, 4), 2)
    sx = np.asarray(cs_batch(x, h, s, c=4))
    sty = np.asarray(cs_batch_t(y, h, s, n=16))
    assert abs(float(np.sum(sx * y)) - float(np.sum(x * sty))) < 1e-3


# ---------------------------------------------------------------------
# fft combine
# ---------------------------------------------------------------------


@SETTINGS
@given(
    m1=st.integers(2, 24),
    m2=st.integers(2, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_complex_mul_matches_ref(m1, m2, seed):
    a = rand((4, m1, m2), seed)
    pr, pi = complex_mul(a[0], a[1], a[2], a[3])
    wr, wi = ref.complex_mul_ref(*(jnp.asarray(a[i]) for i in range(4)))
    np.testing.assert_allclose(pr, wr, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(pi, wi, rtol=1e-4, atol=1e-5)


@SETTINGS
@given(
    m1=st.sampled_from([4, 8, 15, 16]),
    m2=st.sampled_from([4, 6, 16, 17]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kron_combine_matches_ref(m1, m2, seed):
    sa = rand((m1, m2), seed)
    sb = rand((m1, m2), seed + 1)
    got = kron_combine(sa, sb)
    want = ref.kron_combine_ref(jnp.asarray(sa), jnp.asarray(sb))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_kron_combine_is_circular_convolution():
    # brute-force 2-D circular convolution comparison
    m1, m2 = 4, 5
    sa = rand((m1, m2), 1)
    sb = rand((m1, m2), 2)
    got = np.asarray(kron_combine(sa, sb))
    want = np.zeros((m1, m2), dtype=np.float64)
    for k1 in range(m1):
        for k2 in range(m2):
            acc = 0.0
            for i in range(m1):
                for j in range(m2):
                    acc += sa[i, j] * sb[(k1 - i) % m1, (k2 - j) % m2]
            want[k1, k2] = acc
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------
# hash generation sanity
# ---------------------------------------------------------------------


def test_mode_hash_is_one_hot_and_deterministic():
    h, s = mode_hash(64, 8, 3)
    assert h.shape == (64, 8)
    np.testing.assert_array_equal(h.sum(axis=1), np.ones(64))
    assert set(np.unique(s)) <= {-1.0, 1.0}
    h2, s2 = mode_hash(64, 8, 3)
    np.testing.assert_array_equal(h, h2)
    np.testing.assert_array_equal(s, s2)


def test_mode_hash_seed_sensitivity():
    h1, _ = mode_hash(64, 8, 1)
    h2, _ = mode_hash(64, 8, 2)
    assert not np.array_equal(h1, h2)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
