"""AOT boundary tests: HLO text is emitted, parseable by the xla_client
this image ships (the same parser family the Rust runtime uses), and the
manifest is consistent with the model schemas."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _manifest():
    path = os.path.join(ARTIFACTS, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_manifest_lists_all_files():
    man = _manifest()
    for name, entry in man["models"].items():
        for key in ("train", "eval", "init_params"):
            p = os.path.join(ARTIFACTS, entry[key])
            assert os.path.exists(p), f"{name}.{key} missing: {p}"
    for name, entry in man["ops"].items():
        assert os.path.exists(os.path.join(ARTIFACTS, entry["path"])), name


def test_param_bins_match_schema_sizes():
    man = _manifest()
    for name, entry in man["models"].items():
        total = sum(int(np.prod(p["shape"])) for p in entry["param_schema"])
        size = os.path.getsize(os.path.join(ARTIFACTS, entry["init_params"]))
        assert size == 4 * total, f"{name}: {size} bytes vs {4 * total}"


def test_hlo_text_is_parseable_hlo():
    man = _manifest()
    path = os.path.join(ARTIFACTS, man["ops"]["mts_sketch"]["path"])
    text = open(path).read()
    assert text.startswith("HloModule"), text[:60]
    assert "ENTRY" in text
    # 64-bit-id protos are the known failure mode; text must not contain
    # serialized proto bytes
    assert "\x00" not in text


def test_op_hash_tables_complete():
    man = _manifest()
    op = man["ops"]["mts_sketch"]
    n1, n2 = op["input_dims"]
    m1, m2 = op["sketch_dims"]
    h1, h2 = op["hashes"]
    assert len(h1["buckets"]) == n1 and len(h1["signs"]) == n1
    assert len(h2["buckets"]) == n2 and len(h2["signs"]) == n2
    assert all(0 <= b < m1 for b in h1["buckets"])
    assert all(0 <= b < m2 for b in h2["buckets"])
    assert all(s in (-1.0, 1.0) for s in h1["signs"] + h2["signs"])


def test_op_mts_executes_and_matches_hashes():
    """Execute the lowered op via jax and check it against a numpy
    scatter driven by the *manifest* hash tables — this is exactly the
    contract the Rust decompressor relies on."""
    man = _manifest()
    op = man["ops"]["mts_sketch"]
    n1, n2 = op["input_dims"]
    m1, m2 = op["sketch_dims"]

    from compile.hashes import mts_hashes
    from compile.kernels.mts_kernel import mts_matrix
    from compile.aot import OP_SEED

    (h1, s1), (h2, s2) = mts_hashes([n1, n2], [m1, m2], OP_SEED)
    rng = np.random.default_rng(0)
    x = rng.standard_normal((n1, n2)).astype(np.float32)
    got = np.asarray(mts_matrix(x, h1, s1, h2, s2, m1=m1, m2=m2))

    b1 = op["hashes"][0]["buckets"]
    sg1 = op["hashes"][0]["signs"]
    b2 = op["hashes"][1]["buckets"]
    sg2 = op["hashes"][1]["signs"]
    want = np.zeros((m1, m2), dtype=np.float64)
    for i in range(n1):
        for j in range(n2):
            want[b1[i], b2[j]] += sg1[i] * sg2[j] * x[i, j]
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_aot_ops_only_runs_quickly(tmp_path):
    """`python -m compile.aot --ops-only` into a temp dir works end to end."""
    env = dict(os.environ)
    out = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(tmp_path), "--ops-only"],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        capture_output=True,
        text=True,
        env=env,
        timeout=300,
    )
    assert out.returncode == 0, out.stderr
    assert (tmp_path / "manifest.json").exists()
    man = json.loads((tmp_path / "manifest.json").read_text())
    assert set(man["ops"]) == {"mts_sketch", "cs_sketch", "kron_combine"}
