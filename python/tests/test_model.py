"""Layer-2 model tests: shapes, loss behaviour, and a few training
steps per head (the model must actually learn on separable data)."""

import jax
import numpy as np
import pytest

from compile import model as M


def synthetic_batch(cfg, seed=0):
    """Linearly separable-ish batch: class k brightens channel k%3 in a
    class-specific quadrant."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((cfg.batch, *M.IMG)).astype(np.float32) * 0.3
    y = rng.integers(0, M.NUM_CLASSES, cfg.batch).astype(np.int32)
    for i, lbl in enumerate(y):
        qi, qj = (lbl // 4) % 2, (lbl // 2) % 2
        x[i, qi * 16 : qi * 16 + 16, qj * 16 : qj * 16 + 16, lbl % 3] += 2.0
    return x, y


HEADS = [
    M.HeadConfig(head="fc", batch=16),
    M.HeadConfig(head="trl", batch=16),
    M.HeadConfig(head="trl_mts", batch=16),
    M.HeadConfig(head="trl_cts", batch=16),
]


@pytest.mark.parametrize("cfg", HEADS, ids=lambda c: c.name)
def test_shapes_and_initial_loss(cfg):
    params = M.init_params(cfg)
    assert len(params) == len(M.schema(cfg))
    x, y = synthetic_batch(cfg)
    ev = jax.jit(M.make_eval_step(cfg))(*params, x, y)
    loss, acc = float(ev[0]), float(ev[1])
    # near-uniform predictions at init → loss ≈ ln(10)
    assert 0.5 < loss < 12.0
    assert 0.0 <= acc <= 1.0


@pytest.mark.parametrize("cfg", HEADS, ids=lambda c: c.name)
def test_loss_decreases_over_steps(cfg):
    params = M.init_params(cfg)
    moms = [np.zeros_like(p) for p in params]
    step = jax.jit(M.make_train_step(cfg))
    x, y = synthetic_batch(cfg)
    n = len(params)
    first_loss = None
    loss = None
    for it in range(30):
        out = step(*params, *moms, x, y, np.float32(0.03))
        params = list(out[:n])
        moms = list(out[n : 2 * n])
        loss = float(out[2 * n])
        if first_loss is None:
            first_loss = loss
    assert loss < first_loss * 0.7, f"{cfg.name}: {first_loss} -> {loss}"


def test_param_counts_tell_compression_story():
    trl = M.param_count(M.HeadConfig(head="trl"))
    mts = M.param_count(M.HeadConfig(head="trl_mts", sketch=(4, 4, 8)))
    # the paper's headline: ~8× fewer parameters for the sketched TRL
    assert trl / mts > 6.0, (trl, mts)


def test_schema_order_stable():
    cfg = M.HeadConfig(head="trl_mts")
    names = [n for n, _ in M.schema(cfg)]
    assert names[:4] == ["conv1_w", "conv1_b", "conv2_w", "conv2_b"]
    assert names[-1] == "mts_b"


@pytest.mark.parametrize("sketch", [(8, 8, 16), (4, 4, 8), (3, 3, 6), (2, 2, 4)])
def test_mts_sweep_configs_all_trace(sketch):
    """Every Fig-12 sweep variant must build, step once, and shrink the
    head parameter count monotonically with the sketch volume."""
    cfg = M.HeadConfig(head="trl_mts", batch=8, sketch=sketch)
    params = M.init_params(cfg)
    moms = [np.zeros_like(p) for p in params]
    x, y = synthetic_batch(cfg)
    out = jax.jit(M.make_train_step(cfg))(*params, *moms, x, y, np.float32(0.02))
    assert np.isfinite(float(out[2 * len(params)]))
    expect = int(np.prod(sketch)) * M.NUM_CLASSES + M.NUM_CLASSES
    assert M.param_count(cfg) == expect


def test_eval_matches_train_loss_at_zero_lr():
    """train_step with lr=0 must leave params unchanged and report the
    same loss eval_step computes."""
    cfg = M.HeadConfig(head="trl_cts", batch=8)
    params = M.init_params(cfg)
    moms = [np.zeros_like(p) for p in params]
    x, y = synthetic_batch(cfg)
    out = jax.jit(M.make_train_step(cfg))(*params, *moms, x, y, np.float32(0.0))
    n = len(params)
    for before, after in zip(params, out[:n]):
        np.testing.assert_allclose(np.asarray(after), before, rtol=1e-6)
    ev = jax.jit(M.make_eval_step(cfg))(*params, x, y)
    assert abs(float(out[2 * n]) - float(ev[0])) < 1e-5


def test_hashes_are_stable_across_processes():
    """The baked hashes are derived from the config seed only — the
    manifest contract depends on this."""
    cfg = M.HeadConfig(head="trl_mts")
    a = M.fixed_hashes(cfg)
    b = M.fixed_hashes(cfg)
    for (h1, s1), (h2, s2) in zip(a, b):
        np.testing.assert_array_equal(h1, h2)
        np.testing.assert_array_equal(s1, s2)


def test_train_step_is_deterministic():
    cfg = M.HeadConfig(head="trl_mts", batch=8)
    params = M.init_params(cfg)
    moms = [np.zeros_like(p) for p in params]
    x, y = synthetic_batch(cfg)
    step = jax.jit(M.make_train_step(cfg))
    a = step(*params, *moms, x, y, np.float32(0.05))
    b = step(*params, *moms, x, y, np.float32(0.05))
    n = len(params)
    np.testing.assert_array_equal(np.asarray(a[2 * n]), np.asarray(b[2 * n]))
