//! Sketched Kronecker-product compression (the paper's §4.1 workload):
//! sweep compression ratios and compare CTS vs MTS on error and time —
//! a runnable miniature of Figure 8.
//!
//! ```bash
//! cargo run --release --example kron_compress -- [n] [ratios...]
//! ```

use hocs::experiments::{run_fig8, ExpConfig};

fn main() {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(10);
    let cfg = ExpConfig { quick: false, seed: 20190711 };
    let (table, rows) = run_fig8(&cfg, n);
    table.print();
    // the paper's headline claim, checked live:
    let all_faster = rows.iter().all(|r| r.mts_time <= r.cts_time);
    let mean_speedup: f64 = rows
        .iter()
        .map(|r| r.cts_time.as_secs_f64() / r.mts_time.as_secs_f64())
        .sum::<f64>()
        / rows.len() as f64;
    println!(
        "\nMTS faster at every ratio: {all_faster}; mean compression speedup {mean_speedup:.1}x"
    );
}
