//! Quickstart: sketch a tensor with MTS/HCS, recover it, and do a
//! Kronecker product entirely in sketch space.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use hocs::rng::Pcg64;
use hocs::sketch::estimate::median_decompress;
use hocs::sketch::kron::MtsKron;
use hocs::sketch::mts::MtsSketcher;
use hocs::tensor::{kron, rel_error, Tensor};

fn main() {
    let mut rng = Pcg64::new(0);

    // --- 1. sketch and recover a third-order tensor -------------------
    let t = Tensor::randn(&[16, 16, 16], &mut rng);
    let sk = MtsSketcher::new(&[16, 16, 16], &[8, 8, 8], 42);
    let sketch = sk.sketch(&t);
    println!(
        "MTS: {:?} -> {:?} (compression ratio {:.0}x)",
        t.dims(),
        sketch.dims(),
        sk.compression_ratio()
    );
    // single sketch
    let rec1 = sk.decompress(&sketch);
    // median of 9 independent sketches (the paper's robust estimator)
    let rec9 = median_decompress(9, |rep| {
        let s = MtsSketcher::with_repeat(&[16, 16, 16], &[8, 8, 8], 42, rep);
        s.decompress(&s.sketch(&t))
    });
    println!(
        "recovery rel. error: single {:.3}, median-of-9 {:.3}",
        rel_error(&t, &rec1),
        rel_error(&t, &rec9)
    );

    // --- 2. Kronecker product in sketch space (Lemma B.1) -------------
    let a = Tensor::randn(&[10, 10], &mut rng);
    let b = Tensor::randn(&[10, 10], &mut rng);
    let mk = MtsKron::new(&[10, 10], &[10, 10], 40, 40, 7);
    let p = mk.compress(&a, &b); // never materializes the 100×100 product
    let truth = kron(&a, &b);
    let est = mk.estimate(&p, 3, 4, 5, 6);
    println!(
        "sketched Kron: entry (3,4)x(5,6): estimated {est:.4}, true {:.4}",
        a.at2(3, 4) * b.at2(5, 6)
    );
    println!(
        "full recovery rel. error at ratio {:.1}: {:.3}",
        mk.compression_ratio(),
        rel_error(&truth, &mk.decompress(&p))
    );
}
