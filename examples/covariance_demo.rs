//! Covariance estimation through sketched Kronecker products (§4.2,
//! Figure 9): reconstruct AAᵀ for the paper's correlated-rows matrix
//! and print the reconstructions side by side as ASCII heatmaps.
//!
//! ```bash
//! cargo run --release --example covariance_demo
//! ```

use hocs::rng::Pcg64;
use hocs::sketch::covariance::{
    covariance_median_mts, covariance_median_pagh, figure9_matrix,
};
use hocs::tensor::{rel_error, Tensor};

fn heat(t: &Tensor, title: &str) {
    let (n, m) = (t.dims()[0], t.dims()[1]);
    let max = t.max_abs().max(1e-12);
    const SHADES: [char; 7] = [' ', '.', ':', '+', '*', '#', '@'];
    println!("{title}:");
    for i in 0..n {
        let row: String = (0..m)
            .map(|j| {
                let v = (t.at2(i, j).abs() / max * (SHADES.len() - 1) as f64).round() as usize;
                SHADES[v.min(SHADES.len() - 1)]
            })
            .collect();
        println!("  {row}");
    }
}

fn main() {
    let mut rng = Pcg64::new(20190711);
    let a = figure9_matrix(&mut rng);
    let truth = a.matmul(&a.transpose());
    let d = 301;

    let pagh = covariance_median_pagh(&a, 40, d, 1); // ratio 2.5
    let mts = covariance_median_mts(&a, 40, 40, d, 1); // ratio 6.25

    heat(&truth, "true AAᵀ (rows 2 & 9 correlated)");
    heat(&pagh, "Pagh CS estimate (ratio 2.5)");
    heat(&mts, "MTS (A⊗Aᵀ) estimate (ratio 6.25)");
    println!(
        "\nrel. error: Pagh {:.3}, MTS {:.3} (median of {d} sketches)",
        rel_error(&truth, &pagh),
        rel_error(&truth, &mts)
    );
    println!(
        "correlated-pair signal: true {:.2}, Pagh {:.2}, MTS {:.2}",
        truth.at2(1, 8),
        pagh.at2(1, 8),
        mts.at2(1, 8)
    );
}
