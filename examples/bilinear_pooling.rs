//! Multimodal bilinear pooling (the paper intro's VQA motivation, à la
//! MCB): combine an image-feature matrix and a text-feature matrix by
//! their Kronecker product — except the product is never materialized;
//! both are MTS-sketched and combined in the frequency domain. The
//! pooled sketch itself is the fused feature the downstream classifier
//! consumes, and inner products between pooled sketches estimate inner
//! products between the true bilinear features.
//!
//! ```bash
//! cargo run --release --example bilinear_pooling
//! ```

use hocs::rng::Pcg64;
use hocs::sketch::inner::inner_product_estimate;
use hocs::sketch::kron::MtsKron;
use hocs::tensor::{kron, Tensor};

fn main() {
    let mut rng = Pcg64::new(1);
    // "image" features: 16 spatial positions × 24 channels;
    // "text" features: 8 tokens × 12 dims
    let (ih, iw) = (16usize, 24usize);
    let (th, tw) = (8usize, 12usize);
    let m = 64usize;
    let mk = MtsKron::new(&[ih, iw], &[th, tw], m, m, 42);
    println!(
        "bilinear feature space: {}×{} = {} dims; pooled sketch: {}×{} = {} dims ({}x compression)",
        ih * th,
        iw * tw,
        ih * th * iw * tw,
        m,
        m,
        m * m,
        (ih * th * iw * tw) / (m * m)
    );

    // two scenes: (img_a, txt_a) and a paraphrase pair (img_a, txt_a')
    // where txt_a' ≈ txt_a, plus an unrelated pair (img_b, txt_b)
    let img_a = Tensor::randn(&[ih, iw], &mut rng);
    let txt_a = Tensor::randn(&[th, tw], &mut rng);
    let txt_a2 = txt_a.add(&Tensor::randn(&[th, tw], &mut rng).scale(0.2));
    let img_b = Tensor::randn(&[ih, iw], &mut rng);
    let txt_b = Tensor::randn(&[th, tw], &mut rng);

    let pool_a = mk.compress(&img_a, &txt_a);
    let pool_a2 = mk.compress(&img_a, &txt_a2);
    let pool_b = mk.compress(&img_b, &txt_b);

    // ground-truth bilinear features (materialized only to validate)
    let full_a = kron(&img_a, &txt_a);
    let full_a2 = kron(&img_a, &txt_a2);
    let full_b = kron(&img_b, &txt_b);
    let dot = |x: &Tensor, y: &Tensor| -> f64 {
        x.data().iter().zip(y.data().iter()).map(|(a, b)| a * b).sum()
    };
    let cos = |num: f64, x: &Tensor, y: &Tensor| num / (x.fro_norm() * y.fro_norm());

    println!("\nsimilarity of pooled features (cosine), sketch vs exact:");
    for (name, (pa, pb), (fa, fb)) in [
        ("same image, paraphrased text", (&pool_a, &pool_a2), (&full_a, &full_a2)),
        ("unrelated pair             ", (&pool_a, &pool_b), (&full_a, &full_b)),
    ] {
        let est = inner_product_estimate(pa, pb);
        let exact = dot(fa, fb);
        println!(
            "  {name}: sketch {:+.3}  exact {:+.3}",
            cos(est, fa, fb),
            cos(exact, fa, fb)
        );
    }
    println!("\nthe sketched pooling preserves the similarity structure the");
    println!("VQA head needs, at {}x less feature memory.", (ih * th * iw * tw) / (m * m));
}
