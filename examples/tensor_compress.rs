//! Compressing decomposed tensors (§3): take a dense tensor, fit
//! Tucker / CP / TT forms with the in-crate decomposition substrate,
//! sketch each form with CTS and MTS, and report parameters vs recovery.
//!
//! ```bash
//! cargo run --release --example tensor_compress
//! ```

use hocs::decomp::{cp_als, hosvd, tt_svd};
use hocs::rng::Pcg64;
use hocs::sketch::cp::MtsCp;
use hocs::sketch::estimate::median_decompress;
use hocs::sketch::tt::MtsTt;
use hocs::sketch::tucker::MtsTucker;
use hocs::tensor::{rel_error, Tensor};

fn main() {
    let mut rng = Pcg64::new(1);
    let (n, r) = (16usize, 4usize);
    // ground truth: an exactly low-rank tensor + small noise
    let clean = hocs::decomp::TuckerTensor::random(&[n, n, n], &[r, r, r], &mut rng);
    let noise = Tensor::randn(&[n, n, n], &mut rng).scale(0.01);
    let dense = clean.reconstruct().add(&noise);
    println!("dense tensor: {}³ = {} floats", n, dense.len());

    // --- decompose (substrates built for this repo) --------------------
    let tucker = hosvd(&dense, &[r, r, r]);
    let cp = cp_als(&dense, r, 40, 1e-9, &mut rng);
    let tt = tt_svd(&dense, &[r, r]);
    println!(
        "decomposition error: tucker {:.4}, cp {:.4}, tt {:.4}",
        rel_error(&dense, &tucker.reconstruct()),
        rel_error(&dense, &cp.reconstruct()),
        rel_error(&dense, &tt.reconstruct()),
    );
    println!(
        "params: dense {}, tucker {}, cp {}, tt {}",
        dense.len(),
        tucker.param_count(),
        cp.param_count(),
        tt.param_count()
    );

    // --- sketch the decomposed forms (never re-densify) ----------------
    let d = 9;
    let (m1, m2) = (512, 8);
    let mts_tucker = median_decompress(d, |rep| {
        let s = MtsTucker::with_repeat(&[n, n, n], &[r, r, r], m1, m2, 5, rep);
        s.decompress(&s.sketch(&tucker))
    });
    let mts_cp = median_decompress(d, |rep| {
        let s = MtsCp::with_repeat(&[n, n, n], r, m1, m2, 5, rep);
        s.decompress(&s.sketch(&cp))
    });
    let mts_tt = median_decompress(d, |rep| {
        let s = MtsTt::with_repeat(&[n, n, n], &[r, r], 64, 16, 16, 5, rep);
        s.decompress(&s.sketch(&tt))
    });
    println!("\nsketched recovery (median of {d}):");
    println!(
        "  MTS(Tucker)  sketch {} floats -> rel err {:.3}",
        m1,
        rel_error(&dense, &mts_tucker)
    );
    println!(
        "  MTS(CP)      sketch {} floats -> rel err {:.3}",
        m1,
        rel_error(&dense, &mts_cp)
    );
    println!(
        "  MTS(TT)      sketch {} floats -> rel err {:.3}",
        64 * 16,
        rel_error(&dense, &mts_tt)
    );
}
