//! Streaming frequency estimation — the paper intro's motivating use
//! case (Demaine et al.: internet packet streams with limited space),
//! done with a 2-D MTS over (src, dst) pairs: one pass over 200k
//! packets, 1.3% of exact-table space, then point queries and
//! heavy-hitter extraction.
//!
//! ```bash
//! cargo run --release --example traffic_stream
//! ```

use hocs::rng::Pcg64;
use hocs::sketch::stream::StreamSketch;

fn main() {
    let (hosts_src, hosts_dst) = (512usize, 512usize);
    let mut sketch = StreamSketch::new(hosts_src, hosts_dst, 48, 48, 5, 42);
    println!(
        "universe {}x{} flows, sketch space {} counters ({:.2}% of exact)",
        hosts_src,
        hosts_dst,
        sketch.space(),
        100.0 * sketch.space() as f64 / (hosts_src * hosts_dst) as f64
    );

    // synthetic traffic: heavy flows + elephant-mice background
    let heavy = [(17usize, 400usize, 9.0f64), (300, 8, 6.0), (100, 101, 4.0)];
    let mut rng = Pcg64::new(7);
    let mut exact = std::collections::HashMap::new();
    let packets = 200_000;
    for _ in 0..packets {
        let (s, d, w) = if rng.uniform() < 0.3 {
            let &(s, d, scale) = &heavy[rng.gen_range(heavy.len() as u64) as usize];
            (s, d, scale * (0.5 + rng.uniform()))
        } else {
            (
                rng.gen_range(hosts_src as u64) as usize,
                rng.gen_range(hosts_dst as u64) as usize,
                rng.uniform() + 0.1,
            )
        };
        sketch.update(s, d, w);
        *exact.entry((s, d)).or_insert(0.0) += w;
    }
    println!("processed {packets} packets in one pass\n");

    println!("point queries (true vs estimated bytes):");
    for &(s, d, _) in &heavy {
        println!(
            "  flow {s:>3}->{d:<3}: true {:>9.0}  est {:>9.0}",
            exact[&(s, d)],
            sketch.query(s, d)
        );
    }

    let total: f64 = exact.values().sum();
    let threshold = 0.005 * total;
    let hh = sketch.heavy_hitters(threshold);
    println!("\nflows above 0.5% of total traffic ({threshold:.0} bytes):");
    for (s, d, w) in hh.iter().take(6) {
        println!("  {s:>3}->{d:<3}  est {w:>9.0}");
    }
    let found: std::collections::HashSet<_> =
        hh.iter().map(|&(s, d, _)| (s, d)).collect();
    let all_heavy_found = heavy.iter().all(|&(s, d, _)| found.contains(&(s, d)));
    println!("\nall {} planted heavy flows recovered: {all_heavy_found}", heavy.len());
}
