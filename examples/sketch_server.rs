//! Sketch-service demo: start the coordinator with the XLA (AOT) backend,
//! drive a mixed workload (MTS sketches, CS sketches, Kron combines)
//! from several client threads, and print the service metrics.
//!
//! ```bash
//! make artifacts && cargo run --release --example sketch_server
//! ```

use hocs::coordinator::{BackendKind, Coordinator, CoordinatorConfig, Job};
use hocs::rng::Pcg64;
use hocs::runtime::Manifest;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let dir = hocs::runtime::DEFAULT_ARTIFACTS_DIR;
    let man = Manifest::load(dir)?;
    let mts = man.ops["mts_sketch"].clone();
    let cs = man.ops["cs_sketch"].clone();
    let kron = man.ops["kron_combine"].clone();

    let co = Arc::new(Coordinator::start(CoordinatorConfig {
        backend: BackendKind::Xla,
        artifacts_dir: dir.to_string(),
        serve_model: Some("trl_mts_4x4x8".to_string()),
        ..Default::default()
    })?);
    println!("coordinator up (xla-pjrt backend, serving trl_mts_4x4x8)");

    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for client in 0..4u64 {
        let co = co.clone();
        let (mts, cs, kron) = (mts.clone(), cs.clone(), kron.clone());
        handles.push(std::thread::spawn(move || {
            let mut rng = Pcg64::new(client + 1);
            for i in 0..250usize {
                let job = match i % 4 {
                    0 => Job::MtsSketch(
                        (0..mts.input_dims[0] * mts.input_dims[1])
                            .map(|_| rng.normal() as f32)
                            .collect(),
                    ),
                    1 => Job::CsSketch(
                        (0..cs.input_dims[0]).map(|_| rng.normal() as f32).collect(),
                    ),
                    2 => {
                        let n = kron.sketch_dims[0] * kron.sketch_dims[1];
                        Job::KronCombine(
                            (0..n).map(|_| rng.normal() as f32).collect(),
                            (0..n).map(|_| rng.normal() as f32).collect(),
                        )
                    }
                    _ => Job::Classify(
                        (0..32 * 32 * 3).map(|_| rng.normal() as f32).collect(),
                    ),
                };
                loop {
                    match co.try_submit(job_clone(&job)) {
                        Ok(rx) => {
                            rx.recv().unwrap().unwrap();
                            break;
                        }
                        Err(_) => std::thread::yield_now(), // backpressure
                    }
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "1000 mixed requests in {wall:.2}s ({:.0} req/s)\nmetrics: {}",
        1000.0 / wall,
        co.metrics().summary()
    );
    Ok(())
}

/// Job isn't Clone (payloads move); duplicate manually for the retry loop.
fn job_clone(j: &Job) -> Job {
    match j {
        Job::MtsSketch(x) => Job::MtsSketch(x.clone()),
        Job::CsSketch(x) => Job::CsSketch(x.clone()),
        Job::KronCombine(a, b) => Job::KronCombine(a.clone(), b.clone()),
        Job::Classify(x) => Job::Classify(x.clone()),
    }
}
