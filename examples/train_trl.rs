//! END-TO-END DRIVER (the repo's full-stack validation): train the
//! paper's §4.3 model family — conv features + {FC, exact TRL, sketched
//! TRL} heads — on the synthetic image corpus, for a few hundred steps,
//! entirely from Rust through the AOT artifacts (L1 Pallas kernel → L2
//! JAX train step → L3 Rust loop). Logs the loss curves and writes
//! histories to `results/`. The run is recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example train_trl [-- steps]
//! ```

use hocs::experiments::fig10::{train_model, TrainSettings};
use hocs::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let rt = Runtime::new(hocs::runtime::DEFAULT_ARTIFACTS_DIR)?;
    println!("PJRT platform: {}", rt.platform());
    let settings = TrainSettings { steps, lr: 0.02, eval_every: (steps / 8).max(1) };

    let mut rows = Vec::new();
    for model in ["fc", "trl", "trl_cts_8", "trl_mts_4x4x8"] {
        println!("\n=== training {model} ({steps} steps) ===");
        let hist = train_model(&rt, model, &settings, 42, false)?;
        let _ = std::fs::create_dir_all("results");
        std::fs::write(
            format!("results/train_{model}.json"),
            hist.to_json().to_string_pretty(),
        )?;
        rows.push((model, hist));
    }

    println!("\n=== summary (synthetic corpus, batch 64) ===");
    println!(
        "{:<16} {:>12} {:>12} {:>10} {:>9}",
        "model", "head params", "train loss", "test acc", "wall (s)"
    );
    for (model, h) in &rows {
        println!(
            "{:<16} {:>12} {:>12.4} {:>10.3} {:>9.1}",
            model,
            h.head_param_count,
            h.train_loss.last().copied().unwrap_or(f64::NAN),
            h.final_test_acc(),
            h.wall_secs
        );
    }
    let trl = rows.iter().find(|(m, _)| *m == "trl").unwrap();
    let mts = rows.iter().find(|(m, _)| *m == "trl_mts_4x4x8").unwrap();
    println!(
        "\nsketched TRL: {:.1}x fewer head parameters, {:+.1}% accuracy delta vs exact TRL",
        trl.1.head_param_count as f64 / mts.1.head_param_count as f64,
        (mts.1.final_test_acc() - trl.1.final_test_acc()) * 100.0
    );
    Ok(())
}
