//! Cross-module integration tests: sketch algebra end to end, the
//! decomposition → sketch pipelines, and (when artifacts are built) the
//! Python-AOT ↔ Rust-runtime contract through the coordinator.

use hocs::coordinator::{BackendKind, Coordinator, CoordinatorConfig, Job};
use hocs::decomp::{cp_als, hosvd, tt_svd, TuckerTensor};
use hocs::rng::Pcg64;
use hocs::sketch::estimate::median_decompress;
use hocs::sketch::kron::{CtsKron, MtsKron};
use hocs::sketch::mts::MtsSketcher;
use hocs::sketch::tucker::MtsTucker;
use hocs::tensor::{kron, rel_error, Tensor};

fn artifacts_ready() -> bool {
    hocs::runtime::artifacts_available(hocs::runtime::DEFAULT_ARTIFACTS_DIR)
}

// ---------------------------------------------------------------------
// pure-algorithm pipelines
// ---------------------------------------------------------------------

#[test]
fn decompose_then_sketch_then_recover() {
    // dense → HOSVD → MTS-sketch the Tucker form → decompress → compare:
    // the sketched pipeline should track the unsketched decomposition.
    let mut rng = Pcg64::new(1);
    let src = TuckerTensor::random(&[10, 10, 10], &[3, 3, 3], &mut rng);
    let dense = src.reconstruct();
    let dec = hosvd(&dense, &[3, 3, 3]);
    let decomp_err = rel_error(&dense, &dec.reconstruct());
    assert!(decomp_err < 1e-8);

    let rec = median_decompress(9, |rep| {
        let sk = MtsTucker::with_repeat(&[10, 10, 10], &[3, 3, 3], 512, 16, 7, rep);
        sk.decompress(&sk.sketch(&dec))
    });
    let sk_err = rel_error(&dense, &rec);
    assert!(sk_err < 1.0, "sketched recovery err {sk_err}");
}

#[test]
fn cp_and_tt_pipelines_compose() {
    let mut rng = Pcg64::new(2);
    let dense = {
        let t = TuckerTensor::random(&[8, 8, 8], &[2, 2, 2], &mut rng);
        t.reconstruct()
    };
    let cp = cp_als(&dense, 2, 60, 1e-10, &mut rng);
    assert!(rel_error(&dense, &cp.reconstruct()) < 1e-4);
    let tt = tt_svd(&dense, &[2, 2]);
    assert!(rel_error(&dense, &tt.reconstruct()) < 1e-8);
}

#[test]
fn sketch_space_kron_beats_materializing_for_entry_queries() {
    // the operational win: estimate entries of A⊗B without building it
    let mut rng = Pcg64::new(3);
    let a = Tensor::randn(&[12, 12], &mut rng);
    let b = Tensor::randn(&[12, 12], &mut rng);
    // per-entry std ≈ ‖A⊗B‖_F/(m1·m2)^½ ≈ 144/96 = 1.5 at m = 96
    let mk = MtsKron::new(&[12, 12], &[12, 12], 96, 96, 5);
    let p = mk.compress(&a, &b);
    let truth = kron(&a, &b);
    // median absolute estimation error over a probe set, vs entry scale
    let mut errs = Vec::new();
    for i in (0..12).step_by(3) {
        for j in (0..12).step_by(3) {
            for h in (0..12).step_by(4) {
                for g in (0..12).step_by(4) {
                    let est = mk.estimate(&p, i, j, h, g);
                    errs.push((est - truth.at2(i * 12 + h, j * 12 + g)).abs());
                }
            }
        }
    }
    let med = hocs::util::stats::median(&errs);
    let scale = truth.fro_norm() / 144.0; // rms entry magnitude
    assert!(med < 2.0 * scale, "median point error {med} vs scale {scale}");
}

#[test]
fn property_sketch_linearity_and_composition() {
    use hocs::util::prop::{forall, prop_close};
    forall("MTS respects scaling through the full pipeline", 25, |g| {
        let n = g.usize_in(4, 10);
        let m = g.usize_in(2, 6);
        let alpha = g.f64_in(-3.0, 3.0);
        let data = g.normal_vec(n * n);
        let t = Tensor::from_vec(data, &[n, n]);
        let sk = MtsSketcher::new(&[n, n], &[m, m], 99);
        let a = sk.sketch(&t.scale(alpha));
        let b = sk.sketch(&t).scale(alpha);
        for (x, y) in a.data().iter().zip(b.data().iter()) {
            prop_close(*x, *y, 1e-9, "scaled sketch")?;
        }
        Ok(())
    });
}

#[test]
fn property_kron_sketch_estimates_products() {
    use hocs::util::prop::{forall, prop_assert};
    forall("CTS-Kron estimate is exact when c is huge", 10, |g| {
        let n = g.usize_in(2, 5);
        let a = Tensor::from_vec(g.normal_vec(n * n), &[n, n]);
        let b = Tensor::from_vec(g.normal_vec(n * n), &[n, n]);
        // c big enough that column-pair hashes rarely collide; retry seeds
        // until injective
        for seed in 0..40 {
            let ck = CtsKron::new(&[n, n], &[n, n], 128, seed);
            let mut seen = std::collections::HashSet::new();
            let mut injective = true;
            for q in 0..n {
                for gcol in 0..n {
                    if !seen.insert((ck.su.h(q) + ck.sv.h(gcol)) % 128) {
                        injective = false;
                    }
                }
            }
            if !injective {
                continue;
            }
            let sk = ck.compress(&a, &b);
            let est = ck.estimate(&sk, 1, 1, 0, 0);
            let truth = a.at2(1, 1) * b.at2(0, 0);
            return prop_assert((est - truth).abs() < 1e-9, "exact under injective hash");
        }
        Ok(()) // no injective seed found (unlikely); skip case
    });
}

// ---------------------------------------------------------------------
// artifacts + coordinator (skipped when not built)
// ---------------------------------------------------------------------

#[test]
fn coordinator_xla_and_rust_backends_agree() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mk = |kind| {
        Coordinator::start(CoordinatorConfig { backend: kind, ..Default::default() }).unwrap()
    };
    let xla = mk(BackendKind::Xla);
    let rust = mk(BackendKind::PureRust);
    let man = hocs::runtime::Manifest::load("artifacts").unwrap();
    let op = &man.ops["mts_sketch"];
    let mut rng = Pcg64::new(9);
    for _ in 0..5 {
        let x: Vec<f32> = (0..op.input_dims[0] * op.input_dims[1])
            .map(|_| rng.normal() as f32)
            .collect();
        let a = xla.call(Job::MtsSketch(x.clone())).unwrap();
        let b = rust.call(Job::MtsSketch(x)).unwrap();
        for (u, v) in a.iter().zip(b.iter()) {
            assert!((u - v).abs() < 1e-3, "{u} vs {v}");
        }
    }
    xla.shutdown();
    rust.shutdown();
}

#[test]
fn trained_sketch_head_beats_chance() {
    // quick e2e: 40 steps of the sketched-TRL model must clearly beat
    // the 10% chance level on held-out data (full curves: train_trl)
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = hocs::runtime::Runtime::new("artifacts").unwrap();
    let mut tr = hocs::train::Trainer::new(&rt, "trl_mts_4x4x8").unwrap();
    let hist = tr.train(100, 0.02, 100, 7, true).unwrap();
    assert!(
        hist.final_test_acc() > 0.3,
        "test acc {} after 100 steps",
        hist.final_test_acc()
    );
}

#[test]
fn coordinator_survives_nan_inputs_and_shutdown_with_pending() {
    // failure injection: NaN payloads must not wedge the executor, and
    // dropping the coordinator with replies still pending must not hang
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let co = Coordinator::start(CoordinatorConfig {
        backend: BackendKind::PureRust,
        ..Default::default()
    })
    .unwrap();
    let man = hocs::runtime::Manifest::load("artifacts").unwrap();
    let n = man.ops["cs_sketch"].input_dims[0];
    // NaN propagates linearly through the sketch; service stays up
    let out = co.call(Job::CsSketch(vec![f32::NAN; n])).unwrap();
    assert!(out.iter().any(|v| v.is_nan()));
    assert!(co.call(Job::CsSketch(vec![1.0; n])).is_ok(), "still serving");
    // leave requests in flight and drop — must terminate promptly
    let mut pending = Vec::new();
    for _ in 0..64 {
        if let Ok(rx) = co.try_submit(Job::CsSketch(vec![0.5; n])) {
            pending.push(rx);
        }
    }
    drop(co); // Drop impl joins the executor after draining
    for rx in pending {
        // each pending request either completed or the channel closed;
        // neither case may hang
        let _ = rx.recv_timeout(std::time::Duration::from_secs(5));
    }
}

#[test]
fn serve_trained_classifier_through_coordinator() {
    // the full serving loop: train briefly → save params → start the
    // coordinator with a serve model → classify labeled images through
    // Job::Classify → beat chance comfortably
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let model = "trl_mts_4x4x8";
    {
        let rt = hocs::runtime::Runtime::new("artifacts").unwrap();
        let mut tr = hocs::train::Trainer::new(&rt, model).unwrap();
        tr.train(120, 0.02, 120, 5, true).unwrap();
        tr.save_params("results").unwrap();
    }
    let co = Coordinator::start(CoordinatorConfig {
        backend: BackendKind::Xla,
        serve_model: Some(model.to_string()),
        ..Default::default()
    })
    .unwrap();
    // held-out stream (same templates, fresh samples)
    let mut ds = hocs::train::SyntheticImages::new(5, 1, 1.6);
    let (xs, ys) = ds.batch(64);
    let img_len = 32 * 32 * 3;
    let mut correct = 0;
    for (i, &label) in ys.iter().enumerate() {
        let img = xs[i * img_len..(i + 1) * img_len].to_vec();
        let logits = co.call(Job::Classify(img)).unwrap();
        assert_eq!(logits.len(), 10);
        let pred = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if pred == label as usize {
            correct += 1;
        }
    }
    let acc = correct as f64 / ys.len() as f64;
    assert!(acc > 0.4, "served accuracy {acc} (chance = 0.1)");
    co.shutdown();
}

#[test]
fn coordinator_restart_cycles() {
    // repeated start/stop must not leak the executor or poison state
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    for i in 0..3 {
        let co = Coordinator::start(CoordinatorConfig {
            backend: BackendKind::PureRust,
            ..Default::default()
        })
        .unwrap();
        let man = hocs::runtime::Manifest::load("artifacts").unwrap();
        let n = man.ops["cs_sketch"].input_dims[0];
        let out = co.call(Job::CsSketch(vec![i as f32; n])).unwrap();
        assert_eq!(out.len(), man.ops["cs_sketch"].sketch_dims[0]);
        co.shutdown();
    }
}

#[test]
fn manifest_hash_contract_roundtrip() {
    // The exported hash tables must decompress what the artifact
    // sketches: sketch a 1-sparse matrix through the coordinator and
    // recover the nonzero exactly.
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let co = Coordinator::start(CoordinatorConfig {
        backend: BackendKind::Xla,
        ..Default::default()
    })
    .unwrap();
    let man = hocs::runtime::Manifest::load("artifacts").unwrap();
    let op = man.ops["mts_sketch"].clone();
    let (n1, n2) = (op.input_dims[0], op.input_dims[1]);
    let (i, j, val) = (5usize, 11usize, 2.5f32);
    let mut x = vec![0.0f32; n1 * n2];
    x[i * n2 + j] = val;
    let sk = co.call(Job::MtsSketch(x)).unwrap();
    let m2 = op.sketch_dims[1];
    let bucket = op.hashes[0].buckets[i] * m2 + op.hashes[1].buckets[j];
    let sign = (op.hashes[0].signs[i] * op.hashes[1].signs[j]) as f32;
    let recovered = sign * sk[bucket];
    assert!((recovered - val).abs() < 1e-4, "{recovered} vs {val}");
    co.shutdown();
}
