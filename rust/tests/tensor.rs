//! End-to-end tests for the HCS tensor plane.
//!
//! The unit tests in `store::tensor`, `store::wal`, and `store::server`
//! pin down each layer in isolation; this file exercises the stack the
//! way a deployment does:
//!
//! - **wire + durability** — every tensor RPC round-trips through a
//!   real TCP server backed by snapshot+WAL, and a server restart
//!   recovers the sketch bit-identically (full key-space sweep against
//!   an in-process oracle fed the same stream);
//! - **replication** — a 2-node replica pair fed interleaved turnstile
//!   writes converges, bit-identically, to the union-stream oracle via
//!   the idempotent tensor full-ship frames;
//! - **marginals** — the sketch-side MARGINAL contraction equals the
//!   explicitly-summed dense oracle: per repeat, the sum of the
//!   slice's single-repeat point estimates (integer weights keep every
//!   intermediate exact in f64, so the comparison is bit-for-bit).

use hocs::rng::Pcg64;
use hocs::store::{
    ContractOutput, HcsStream, ShardedStore, StoreClient, StoreConfig, StoreServer,
    StoreServerConfig, TensorContraction, TensorFamily,
};
use hocs::util::prop::{forall, prop_assert, Gen};
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// 2-D store geometry backing the servers (the tensor plane rides the
/// same store; the 2-D plane stays idle in these tests).
fn base_cfg() -> StoreConfig {
    StoreConfig { n1: 24, n2: 20, m1: 8, m2: 7, d: 3, seed: 99, shards: 2, window: 3 }
}

/// The order-3 family used across the tensor test suite.
fn tfam() -> TensorFamily {
    TensorFamily { dims: vec![20, 16, 12], sketch_dims: vec![6, 5, 4], d: 3, seed: 42 }
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("hocs_tensor_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).expect("creating test dir");
    d
}

fn random_key(rng: &mut Pcg64, dims: &[usize]) -> Vec<usize> {
    dims.iter().map(|&n| rng.gen_range(n as u64) as usize).collect()
}

/// Integer weights, ~20% negative (turnstile deletions) — counter sums
/// stay exact in f64, so recovered/replicated state compares bit-exact.
fn int_weight(rng: &mut Pcg64) -> f64 {
    let w = (1 + rng.gen_range(9)) as f64;
    if rng.gen_range(5) == 0 {
        -w
    } else {
        w
    }
}

/// Reserve distinct loopback addresses by binding port 0 and releasing
/// — replica peers must be named before the servers boot.
fn reserve_addrs(n: usize) -> Option<Vec<String>> {
    let mut listeners = Vec::new();
    for _ in 0..n {
        match std::net::TcpListener::bind("127.0.0.1:0") {
            Ok(l) => listeners.push(l),
            Err(e) => {
                eprintln!("skipping: cannot bind loopback ({e})");
                return None;
            }
        }
    }
    Some(listeners.iter().map(|l| l.local_addr().unwrap().to_string()).collect())
}

#[test]
fn tensor_plane_survives_a_server_restart_bit_identically() {
    let dir = tmpdir("srv_restart");
    let dirs = dir.to_string_lossy().to_string();
    let fam = tfam();
    // oracle: an in-process store fed the identical stream
    let oracle = ShardedStore::new(base_cfg());
    oracle.tensor_create("act", &fam).unwrap();
    oracle.tensor_create("wts", &fam).unwrap();
    let mut rng = Pcg64::new(0x7E5707);
    {
        let server = match StoreServer::start(StoreServerConfig {
            addr: "127.0.0.1:0".to_string(),
            store: base_cfg(),
            data_dir: Some(dirs.clone()),
            ..Default::default()
        }) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("skipping: cannot bind loopback ({e})");
                return;
            }
        };
        let mut client = StoreClient::connect(server.local_addr()).unwrap();
        assert!(client.tensor_create("act", &fam).unwrap());
        assert!(!client.tensor_create("act", &fam).unwrap(), "re-create must be a no-op");
        assert!(client.tensor_create("wts", &fam).unwrap());
        for _ in 0..80 {
            let key = random_key(&mut rng, &fam.dims);
            let w = int_weight(&mut rng);
            client.tensor_update("act", &key, w).unwrap();
            oracle.tensor_update("act", &key, w).unwrap();
        }
        for _ in 0..40 {
            let key = random_key(&mut rng, &fam.dims);
            let w = int_weight(&mut rng);
            client.tensor_update("wts", &key, w).unwrap();
            oracle.tensor_update("wts", &key, w).unwrap();
        }
        client.snapshot().unwrap();
        // a post-snapshot batch: lives only in one TensorUpdateBatch
        // WAL frame, plus one point update in its own frame
        let mut keys = Vec::new();
        let mut ws = Vec::new();
        for _ in 0..30 {
            keys.extend(random_key(&mut rng, &fam.dims));
            ws.push(int_weight(&mut rng));
        }
        client.tensor_update_batch("act", &keys, &ws).unwrap();
        oracle.tensor_update_batch("act", &keys, &ws).unwrap();
        client.tensor_update("act", &[5, 6, 7], 9.0).unwrap();
        oracle.tensor_update("act", &[5, 6, 7], 9.0).unwrap();
        server.shutdown();
    }
    let server = match StoreServer::start(StoreServerConfig {
        addr: "127.0.0.1:0".to_string(),
        store: base_cfg(),
        data_dir: Some(dirs),
        ..Default::default()
    }) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("skipping: cannot bind loopback ({e})");
            return;
        }
    };
    let mut client = StoreClient::connect(server.local_addr()).unwrap();
    // the recovered sketch answers every point query bit-identically —
    // the full 20×16×12 key space is cheap to sweep over loopback
    for i in 0..fam.dims[0] {
        for j in 0..fam.dims[1] {
            for k in 0..fam.dims[2] {
                let key = [i, j, k];
                let got = client.tensor_query("act", &key).unwrap();
                let want = oracle.tensor_query("act", &key).unwrap();
                assert_eq!(got.to_bits(), want.to_bits(), "({i},{j},{k}): {got} vs {want}");
            }
        }
    }
    // marginals, slice scans, and contractions serve off the recovered
    // state too
    let spec = [Some(3), None, None];
    assert_eq!(
        client.tensor_marginal("act", &spec).unwrap().to_bits(),
        oracle.tensor_marginal("act", &spec).unwrap().to_bits(),
        "recovered marginal diverges"
    );
    assert_eq!(
        client.tensor_slice_topk("act", 0, 3, 5).unwrap(),
        oracle.tensor_slice_top_k("act", 0, 3, 5).unwrap(),
        "recovered slice top-k diverges"
    );
    let got = client.tensor_contract("act", "wts", &[0, 1, 2], false).unwrap();
    let want = oracle.tensor_contract("act", "wts", &[0, 1, 2]).unwrap();
    match (got, want) {
        (TensorContraction::Scalar(g), ContractOutput::Scalar(w)) => {
            assert_eq!(g.to_bits(), w.to_bits(), "recovered contraction diverges: {g} vs {w}");
        }
        other => panic!("full contraction must be scalar on both sides: {other:?}"),
    }
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn tensor_writes_converge_across_a_two_node_replica_pair() {
    // Two replica servers peering at each other; interleaved turnstile
    // writes split across the nodes. The oracle is one store fed the
    // union stream — anti-entropy must deliver every node's origin
    // mass to its peer exactly once (idempotent full ships, per-tensor
    // sequence dedup), and integer weights make the counter sums exact
    // under any arrival order.
    let cfg = base_cfg();
    let fam = tfam();
    let Some(addrs) = reserve_addrs(2) else { return };
    let mut servers = Vec::new();
    for (n, addr) in addrs.iter().enumerate() {
        let server = match StoreServer::start(StoreServerConfig {
            addr: addr.clone(),
            store: cfg.clone(),
            peers: vec![addrs[1 - n].clone()],
            sync_interval_ms: 15,
            // node 0 self-heals with periodic 2-D full ships, which
            // also reset its tensor acks — the re-ship must dedup
            full_ship_every: if n == 0 { 4 } else { 0 },
            replica_timeout_ms: 2_000,
            ..Default::default()
        }) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("skipping: cannot boot replica server ({e})");
                return;
            }
        };
        servers.push(server);
    }
    let mut clients: Vec<StoreClient> =
        servers.iter().map(|s| StoreClient::connect(s.local_addr()).unwrap()).collect();
    for c in clients.iter_mut() {
        c.tensor_create("act", &fam).unwrap();
    }
    let oracle = ShardedStore::new(cfg.clone());
    oracle.tensor_create("act", &fam).unwrap();

    let mut rng = Pcg64::new(0xFACADE);
    for step in 0..200 {
        let key = random_key(&mut rng, &fam.dims);
        let w = int_weight(&mut rng);
        let node = step % clients.len();
        if step % 9 == 0 {
            // single-item batch: the TUPDATE_BATCH path replicates too
            clients[node].tensor_update_batch("act", &key, &[w]).unwrap();
        } else {
            clients[node].tensor_update("act", &key, w).unwrap();
        }
        oracle.tensor_update("act", &key, w).unwrap();
    }

    // a node's update counter reaches the union total exactly when the
    // peer's mass has arrived exactly once — tensor frames carry their
    // update counts, and the per-tensor sequence dedup forbids doubles
    let want = oracle.stats().updates;
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let counts: Vec<u64> = clients.iter_mut().map(|c| c.stats().unwrap().updates).collect();
        if counts.iter().all(|&u| u == want) {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "tensor anti-entropy did not quiesce: node counts {counts:?}, want {want}"
        );
        std::thread::sleep(Duration::from_millis(20));
    }

    // both replicas answer bit-identically to the union-stream oracle
    // over the whole multi-mode key space, and the derived reads
    // (marginal, turnstile-routed slice top-k) agree too
    for (n, client) in clients.iter_mut().enumerate() {
        for i in 0..fam.dims[0] {
            for j in 0..fam.dims[1] {
                for k in 0..fam.dims[2] {
                    let key = [i, j, k];
                    let got = client.tensor_query("act", &key).unwrap();
                    let exp = oracle.tensor_query("act", &key).unwrap();
                    assert_eq!(
                        got.to_bits(),
                        exp.to_bits(),
                        "node {n} diverges at {key:?}: {got} vs {exp}"
                    );
                }
            }
        }
        let spec = [None, Some(2), None];
        assert_eq!(
            client.tensor_marginal("act", &spec).unwrap().to_bits(),
            oracle.tensor_marginal("act", &spec).unwrap().to_bits(),
            "node {n} marginal diverges"
        );
        assert_eq!(
            client.tensor_slice_topk("act", 1, 2, 4).unwrap(),
            oracle.tensor_slice_top_k("act", 1, 2, 4).unwrap(),
            "node {n} slice top-k diverges"
        );
        let (_, repl) = client.stats_full().unwrap();
        let repl = repl.expect("replication stats");
        assert!(repl.ships > 0, "node {n} never shipped");
        assert!(repl.merges_applied > 0, "node {n} never applied a peer frame");
    }
}

#[test]
fn marginal_matches_the_explicitly_summed_dense_oracle() {
    // MARGINAL is an exact contraction of the estimator: per repeat it
    // must equal the sum, over every key in the slice, of that key's
    // single-repeat point estimate — then the median over repeats. The
    // oracle recomputes that sum the explicit dense way: enumerate the
    // slice's keys, recover each key's (bucket, sign) by probing a
    // fresh same-family sketch with one unit update (the single
    // nonzero table entry is the sign at the bucket), and dot against
    // the live sketch's tables. Integer weights keep every
    // intermediate an exact small integer, so the two summation orders
    // agree bit-for-bit.
    forall("marginal vs summed dense oracle", 6, |g: &mut Gen| {
        let d = 3usize;
        let seed = g.rng().next_u64();
        let dims = vec![g.usize_in(3, 6), g.usize_in(3, 5), g.usize_in(2, 4)];
        let sketch_dims = vec![g.usize_in(2, 4), g.usize_in(2, 3), g.usize_in(2, 3)];
        let mut s = HcsStream::new(&dims, &sketch_dims, d, seed);
        for _ in 0..(30 + g.usize_in(0, 40)) {
            let key: Vec<usize> = dims.iter().map(|&n| g.usize_in(0, n - 1)).collect();
            let mag = (1 + g.usize_in(0, 8)) as f64;
            s.update(&key, if g.usize_in(0, 4) == 0 { -mag } else { mag });
        }
        // random spec; force at least one summed-out mode so the test
        // never degenerates to a pure point query
        let mut spec: Vec<Option<usize>> = dims
            .iter()
            .map(|&n| if g.usize_in(0, 1) == 0 { None } else { Some(g.usize_in(0, n - 1)) })
            .collect();
        let wild = g.usize_in(0, dims.len() - 1);
        spec[wild] = None;

        let mut per_repeat = vec![0.0f64; d];
        let mut key = vec![0usize; dims.len()];
        loop {
            let in_slice =
                spec.iter().zip(key.iter()).all(|(sp, &i)| sp.map_or(true, |f| f == i));
            if in_slice {
                let mut probe = HcsStream::new(&dims, &sketch_dims, d, seed);
                probe.update(&key, 1.0);
                for (r, acc) in per_repeat.iter_mut().enumerate() {
                    let t = probe.table(r);
                    let b = t.iter().position(|&v| v != 0.0).expect("probe bucket");
                    *acc += t[b] * s.table(r)[b];
                }
            }
            let mut done = true;
            for k in (0..key.len()).rev() {
                key[k] += 1;
                if key[k] < dims[k] {
                    done = false;
                    break;
                }
                key[k] = 0;
            }
            if done {
                break;
            }
        }
        per_repeat.sort_by(f64::total_cmp);
        let want = per_repeat[d / 2]; // d = 3: the middle element
        let got = s.marginal(&spec);
        prop_assert(
            got.to_bits() == want.to_bits(),
            &format!("marginal {spec:?}: {got} vs dense oracle {want}"),
        )?;

        // all-Some degenerates to the point query, bit-for-bit
        let pkey: Vec<usize> = dims.iter().map(|&n| g.usize_in(0, n - 1)).collect();
        let full: Vec<Option<usize>> = pkey.iter().map(|&i| Some(i)).collect();
        prop_assert(
            s.marginal(&full).to_bits() == s.query(&pkey).to_bits(),
            "all-Some marginal must equal the point query",
        )?;
        Ok(())
    });
}
