//! Cross-path kernel bit-identity at the public API layer.
//!
//! The dispatch path is fixed per process by `HOCS_KERNEL` (resolved
//! once); CI's `kernel-smoke` job runs this binary three times — vector
//! path forced off (`scalar`), portable lanes forced (`portable`), and
//! auto dispatch (AVX2 where the runner has it) — so every reachable
//! path is compared against the scalar oracle on the same inputs.

use hocs::rng::Pcg64;
use hocs::sketch::kernel;
use hocs::sketch::stream::StreamSketch;
use hocs::store::tensor::HcsStream;

fn items_2d(seed: u64, n1: usize, n2: usize, n: usize) -> Vec<(usize, usize, f64)> {
    let mut rng = Pcg64::new(seed);
    (0..n)
        .map(|_| {
            let mag = (1 + rng.gen_range(9)) as f64 * 0.5;
            let w = if rng.uniform() < 0.25 { -mag } else { mag };
            (rng.gen_range(n1 as u64) as usize, rng.gen_range(n2 as u64) as usize, w)
        })
        .collect()
}

fn bits_2d(sk: &StreamSketch) -> Vec<u64> {
    (0..sk.d).flat_map(|r| sk.table(r).iter().map(|v| v.to_bits())).collect()
}

fn bits_nd(sk: &HcsStream) -> Vec<u64> {
    (0..sk.d).flat_map(|r| sk.table(r).iter().map(|v| v.to_bits())).collect()
}

#[test]
fn dispatch_resolves_and_respects_env() {
    let path = kernel::configured();
    match std::env::var("HOCS_KERNEL").as_deref() {
        Ok("scalar") => assert_eq!(path, kernel::KernelPath::Scalar),
        Ok("portable") => assert_eq!(path, kernel::KernelPath::Portable),
        _ => assert_ne!(path, kernel::KernelPath::Scalar, "auto must pick a vector path"),
    }
}

#[test]
fn batch_2d_bit_identical_to_scalar_oracle() {
    let (n1, n2, m1, m2, d) = (512usize, 512, 64, 64, 5);
    for n in [0usize, 1, 7, 8, 9, 4095, 4096, 4097, 10_000] {
        let items = items_2d(n as u64 + 3, n1, n2, n);
        let mut kern = StreamSketch::new(n1, n2, m1, m2, d, 11);
        kern.update_batch(&items);
        let mut scal = StreamSketch::new(n1, n2, m1, m2, d, 11);
        scal.update_batch_scalar(&items);
        assert_eq!(bits_2d(&kern), bits_2d(&scal), "n={n}");
        assert_eq!(kern.updates, scal.updates);
        assert_eq!(kern.has_deletions, scal.has_deletions);
    }
}

#[test]
fn batch_2d_non_pow2_geometry_bit_identical() {
    // odd table dims keep the general reducer (and, under auto dispatch
    // on x86, force the AVX2 tile's pow2-only gate to fall back)
    let (n1, n2, m1, m2, d) = (300usize, 290, 37, 12, 3);
    let items = items_2d(5, n1, n2, 3000);
    let mut kern = StreamSketch::new(n1, n2, m1, m2, d, 21);
    kern.update_batch(&items);
    let mut scal = StreamSketch::new(n1, n2, m1, m2, d, 21);
    scal.update_batch_scalar(&items);
    assert_eq!(bits_2d(&kern), bits_2d(&scal));
}

#[test]
fn fanout_2d_bit_identical_for_widths_1_to_4() {
    let (n1, n2, m1, m2, d) = (512usize, 512, 64, 64, 5);
    let items = items_2d(17, n1, n2, 2000);
    let mut oracle = StreamSketch::new(n1, n2, m1, m2, d, 11);
    oracle.update_batch_scalar(&items);
    for width in 1usize..=4 {
        let mut fans: Vec<StreamSketch> =
            (0..width).map(|_| StreamSketch::new(n1, n2, m1, m2, d, 11)).collect();
        {
            let mut targets: Vec<&mut StreamSketch> = fans.iter_mut().collect();
            StreamSketch::update_batch_fanout(&mut targets, &items);
        }
        for f in &fans {
            assert_eq!(bits_2d(f), bits_2d(&oracle), "width={width}");
        }
    }
}

#[test]
fn batch_nd_bit_identical_across_memo_modes() {
    let dims = [40usize, 24, 10];
    let mdims = [8usize, 6, 4];
    // n = 5 keeps every mode direct; 24 and 64 mix memoized and direct;
    // 9000 memoizes all modes and crosses the kernel tile boundary
    for n in [0usize, 5, 24, 64, 9000] {
        let mut rng = Pcg64::new(n as u64 + 9);
        let mut keys = Vec::with_capacity(n * dims.len());
        let mut ws = Vec::with_capacity(n);
        for _ in 0..n {
            for &dim in &dims {
                keys.push(rng.gen_range(dim as u64) as usize);
            }
            let mag = (1 + rng.gen_range(5)) as f64 * 0.25;
            ws.push(if rng.uniform() < 0.3 { -mag } else { mag });
        }
        let mut kern = HcsStream::new(&dims, &mdims, 3, 13);
        kern.update_batch(&keys, &ws);
        let mut scal = HcsStream::new(&dims, &mdims, 3, 13);
        scal.update_batch_scalar(&keys, &ws);
        assert_eq!(bits_nd(&kern), bits_nd(&scal), "n={n}");
        assert_eq!(kern.updates, scal.updates);
        assert_eq!(kern.has_deletions, scal.has_deletions);
    }
}

#[test]
fn fanout_nd_bit_identical_for_widths_1_to_4() {
    let dims = [40usize, 24, 10];
    let mdims = [8usize, 6, 4];
    let mut rng = Pcg64::new(31);
    let n = 1500usize;
    let mut keys = Vec::with_capacity(n * dims.len());
    let mut ws = Vec::with_capacity(n);
    for _ in 0..n {
        for &dim in &dims {
            keys.push(rng.gen_range(dim as u64) as usize);
        }
        ws.push(1.0 + rng.gen_range(4) as f64);
    }
    let mut oracle = HcsStream::new(&dims, &mdims, 3, 13);
    oracle.update_batch_scalar(&keys, &ws);
    for width in 1usize..=4 {
        let mut fans: Vec<HcsStream> =
            (0..width).map(|_| HcsStream::new(&dims, &mdims, 3, 13)).collect();
        {
            let mut targets: Vec<&mut HcsStream> = fans.iter_mut().collect();
            HcsStream::update_batch_fanout(&mut targets, &keys, &ws);
        }
        for f in &fans {
            assert_eq!(bits_nd(f), bits_nd(&oracle), "width={width}");
        }
    }
}

#[test]
fn queries_match_after_kernel_ingest() {
    // the scratch-routed query path returns the same medians as a
    // freshly allocated accumulator would: repeated queries from one
    // thread must not contaminate each other
    let (n1, n2, m1, m2, d) = (256usize, 256, 32, 32, 5);
    let items = items_2d(41, n1, n2, 4000);
    let mut sk = StreamSketch::new(n1, n2, m1, m2, d, 11);
    sk.update_batch(&items);
    let mut rng = Pcg64::new(43);
    for _ in 0..200 {
        let (i, j) = (rng.gen_range(n1 as u64) as usize, rng.gen_range(n2 as u64) as usize);
        let a = sk.query(i, j);
        let b = sk.query(i, j);
        assert_eq!(a.to_bits(), b.to_bits());
    }
}
