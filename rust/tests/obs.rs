//! Observability plane at the public API layer: exposition golden
//! names + parse round-trip, tracing-ring wraparound, and multi-writer
//! counter exactness.
//!
//! Registry-shape tests run on a **local** `Registry::new()` so the
//! process-global registry (shared by every test in this binary) can't
//! pollute the asserted values; only the `render_text` golden touches
//! the global, and it asserts presence, not counts.

use hocs::obs::registry::Registry;
use hocs::obs::{expo, trace};
use std::sync::Arc;

/// Every metric family the exposition contract pins (scraped by the CI
/// `obs-smoke` job and consumed by `hocs top`). Renaming any of these
/// is a breaking change to the scrape schema.
const GOLDEN_FAMILIES: &[&str] = &[
    "hocs_rpc_requests_total",
    "hocs_rpc_errors_total",
    "hocs_rpc_latency_us",
    "hocs_wal_appends_total",
    "hocs_wal_bytes_total",
    "hocs_wal_fsync_us",
    "hocs_wal_group_frames",
    "hocs_wal_rotations_total",
    "hocs_wal_fail_stops_total",
    "hocs_scan_cache_hits_total",
    "hocs_scan_cache_folds_total",
    "hocs_scan_cache_rebuilds_total",
    "hocs_scan_cache_hit_ratio",
    "hocs_kernel_dispatch_total",
    "hocs_fault_injections_total",
    "hocs_repl_ticks_total",
    "hocs_repl_settled_ticks_total",
    "hocs_repl_peer_synced",
    "hocs_repl_peer_lag_ms",
    "hocs_repl_peer_bytes_total",
    "hocs_repl_peer_ships_total",
    "hocs_contracts_total",
    "hocs_contract_residual",
    "hocs_contract_bound",
    "hocs_contract_ratio",
];

/// Drive one of everything through a local registry so every family
/// renders (histograms and peer/contract slots only render once they
/// have data).
fn populated_registry() -> Registry {
    let r = Registry::new();
    r.rpc_observe(2, 150, true);
    r.rpc_observe(2, 90, false);
    r.rpc_observe(9, 4_000, true);
    r.wal_appends.inc();
    r.wal_bytes.add(512);
    r.wal_fsync_us.record(800);
    r.wal_group_frames.record(3);
    r.wal_rotations.inc();
    r.wal_fail_stops.inc();
    r.scan_hits.add(9);
    r.scan_folds.inc();
    r.scan_rebuilds.inc();
    r.kernel_scalar.inc();
    r.kernel_portable.add(2);
    r.kernel_avx2.add(3);
    r.fault_injections.inc();
    r.repl_ticks.add(10);
    r.repl_settled_ticks.add(7);
    let peer = r.register_peer("127.0.0.1:7100");
    peer.note_ship(2048, false);
    peer.note_settled(hocs::obs::now_ms());
    r.note_contract("a", "b", 0.5, 2.0);
    r
}

#[test]
fn exposition_covers_every_golden_family() {
    let r = populated_registry();
    let mut text = String::new();
    r.render_into(&mut text);
    for family in GOLDEN_FAMILIES {
        assert!(text.contains(family), "family {family} missing from exposition:\n{text}");
    }
}

#[test]
fn exposition_parses_back_to_the_recorded_values() {
    let r = populated_registry();
    let mut text = String::new();
    r.render_into(&mut text);
    let samples = expo::parse(&text);

    let get = |name: &str, label: Option<(&str, &str)>| -> f64 {
        samples
            .iter()
            .find(|s| {
                s.name == name
                    && label.map(|(k, v)| s.label(k) == Some(v)).unwrap_or(true)
            })
            .unwrap_or_else(|| panic!("sample {name} {label:?} not found"))
            .value
    };

    // per-opcode counters carry the op label (opcode 2 = UPDATE)
    assert_eq!(get("hocs_rpc_requests_total", Some(("op", "UPDATE"))), 2.0);
    assert_eq!(get("hocs_rpc_errors_total", Some(("op", "UPDATE"))), 1.0);
    assert_eq!(get("hocs_rpc_latency_us_count", Some(("op", "UPDATE"))), 2.0);
    assert_eq!(get("hocs_rpc_latency_us_sum", Some(("op", "UPDATE"))), 240.0);
    assert_eq!(get("hocs_wal_bytes_total", None), 512.0);
    assert_eq!(get("hocs_wal_group_frames_count", None), 1.0);
    assert_eq!(get("hocs_scan_cache_hits_total", None), 9.0);
    assert!((get("hocs_scan_cache_hit_ratio", None) - 9.0 / 11.0).abs() < 1e-9);
    assert_eq!(get("hocs_kernel_dispatch_total", Some(("path", "avx2"))), 3.0);
    assert_eq!(get("hocs_repl_peer_synced", Some(("peer", "127.0.0.1:7100"))), 1.0);
    assert_eq!(get("hocs_contract_ratio", Some(("pair", "a/b"))), 0.25);

    // histogram buckets reconstruct a percentile consistent with the
    // registry's own estimate
    let buckets: Vec<(f64, f64)> = samples
        .iter()
        .filter(|s| {
            s.name == "hocs_rpc_latency_us_bucket" && s.label("op") == Some("UPDATE")
        })
        .filter_map(|s| s.label("le").and_then(|le| le.parse::<f64>().ok().map(|l| (l, s.value))))
        .collect();
    assert!(!buckets.is_empty());
    let p50 = expo::percentile_from_buckets(&buckets, 0.5);
    let direct = r.rpc(2).map(|st| st.latency_us.percentile(0.5)).unwrap_or(0);
    assert_eq!(p50 as u64, direct, "parsed p50 {p50} vs direct {direct}");
}

#[test]
fn trace_ring_wraps_and_counts_drops() {
    // dedicated thread: rings are thread-local, so this is immune to
    // the other tests' spans even though ENABLED is process-global
    let handle = std::thread::spawn(|| {
        trace::set_enabled(true);
        trace::drain_current(); // discard anything from a prior state
        let n = trace::RING_CAP + 50;
        for _ in 0..n {
            let _s = trace::span("test.wrap");
        }
        let out = trace::drain_current();
        trace::set_enabled(false);
        out
    });
    let (recs, dropped) = handle.join().expect("trace thread");
    assert_eq!(recs.len(), trace::RING_CAP, "ring must cap at RING_CAP");
    assert!(dropped >= 50, "expected >=50 overwrites, got {dropped}");
    assert!(recs.iter().all(|r| r.name == "test.wrap"));
}

#[test]
fn slow_log_evicts_oldest_past_cap() {
    for i in 0..(trace::SLOW_LOG_CAP + 5) {
        trace::note_slow(format!("slow-{i}"));
    }
    let lines = trace::drain_slow();
    assert_eq!(lines.len(), trace::SLOW_LOG_CAP);
    assert_eq!(lines.first().map(String::as_str), Some("slow-5"));
}

#[test]
fn eight_writer_threads_lose_no_counts() {
    const THREADS: usize = 8;
    const PER_THREAD: u64 = 50_000;
    let r = Arc::new(Registry::new());
    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let r = Arc::clone(&r);
            std::thread::spawn(move || {
                for i in 0..PER_THREAD {
                    r.wal_appends.inc();
                    r.wal_bytes.add(3);
                    r.wal_fsync_us.record((t as u64) * 100 + (i % 7));
                    r.rpc_observe(2, i % 1000, i % 10 != 0);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("writer thread");
    }
    let total = THREADS as u64 * PER_THREAD;
    assert_eq!(r.wal_appends.get(), total);
    assert_eq!(r.wal_bytes.get(), 3 * total);
    assert_eq!(r.wal_fsync_us.count(), total);
    let st = r.rpc(2).expect("opcode 2 slot");
    assert_eq!(st.requests.get(), total);
    assert_eq!(st.errors.get(), total / 10);
    assert_eq!(st.latency_us.count(), total);
    let hist_total: u64 = st.latency_us.bucket_counts().iter().sum();
    assert_eq!(hist_total, total);
}
