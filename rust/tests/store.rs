//! Store-level acceptance properties (ISSUE 2, extended by ISSUE 3):
//!
//! 1. **Merge fidelity** — for random update streams split across
//!    K ∈ {2, 4, 8} shards, merged-shard point estimates are
//!    bit-identical (f64) to a single un-sharded `StreamSketch` fed the
//!    same stream.
//! 2. **Crash recovery** — snapshot → WAL-replay → recovered store
//!    answers identically to the pre-crash store.
//! 3. **Group commit** — batched durable updates (one WAL frame per
//!    batch, shard-grouped apply) are bit-identical to per-item
//!    updates, live and after crash recovery.
//! 4. **Scan-cache fidelity (ISSUE 4)** — the version-stamped scan
//!    plane (cached merged sketch + memoized TOPK/HEAVY) answers
//!    bit-identically to a fresh full K-way re-merge
//!    (`merged_uncached`) across interleaved updates, batches, remote
//!    merges (including deletion-carrying ones, which flip the scans
//!    onto their dense routes), and epoch rotations.
//! 5. **Rotation-storm fallback** — concurrent `advance_epoch` drives
//!    `point_query`/`stats` past their `EPOCH_RETRY_LIMIT` optimistic
//!    retries into the counted lock-all path, which must still answer
//!    consistently.
//! 6. **Multi-node convergence (ISSUE 5)** — three in-process replica
//!    servers in a full mesh, writes (including deletion-carrying
//!    updates, an edge-node MERGE, and an epoch rotation mid-stream)
//!    split across nodes: after anti-entropy quiesces, every replica's
//!    point queries are bit-identical to a single store fed the union
//!    stream.
//!
//! Streams use integer weights: every bucket partial sum is then exact
//! in f64, so accumulation *order* (per-shard vs interleaved) provably
//! cannot change a counter and bit-identity is the right assertion.
//! The ±1 sign products are exact for any weight; only bucket-sum
//! reassociation needs the integrality argument.

use hocs::rng::Pcg64;
use hocs::sketch::stream::StreamSketch;
use hocs::store::{
    DurableStore, ShardedStore, StoreClient, StoreConfig, StoreServer, StoreServerConfig,
};
use hocs::util::prop::{forall, prop_assert, Gen};
use std::path::PathBuf;

fn reference_sketch(cfg: &StoreConfig) -> StreamSketch {
    StreamSketch::new(cfg.n1, cfg.n2, cfg.m1, cfg.m2, cfg.d, cfg.seed)
}

fn store_cfg(shards: usize, window: usize, seed: u64) -> StoreConfig {
    StoreConfig { n1: 48, n2: 40, m1: 12, m2: 10, d: 5, seed, shards, window }
}

fn int_weight(rng: &mut Pcg64) -> f64 {
    let mag = (1 + rng.gen_range(16)) as f64;
    if rng.uniform() < 0.2 {
        -mag // turnstile deletions keep the linearity honest
    } else {
        mag
    }
}

fn random_key(rng: &mut Pcg64, cfg: &StoreConfig) -> (usize, usize) {
    (rng.gen_range(cfg.n1 as u64) as usize, rng.gen_range(cfg.n2 as u64) as usize)
}

#[test]
fn merged_shards_bit_identical_to_unsharded_sketch() {
    for k in [2usize, 4, 8] {
        forall(&format!("merge fidelity K={k}"), 6, |g: &mut Gen| {
            let seed = g.rng().next_u64();
            let cfg = store_cfg(k, 2, seed);
            let store = ShardedStore::new(cfg.clone());
            let mut reference = reference_sketch(&cfg);
            let n_updates = 500 + g.usize_in(0, 300);
            for _ in 0..n_updates {
                let (i, j) = random_key(g.rng(), &cfg);
                let w = int_weight(g.rng());
                store.update(i, j, w);
                reference.update(i, j, w);
            }
            prop_assert(store.updates() == reference.updates, "update counts differ")?;
            // every key of the universe, not a sample: bit-identical means
            // bit-identical everywhere
            for i in 0..cfg.n1 {
                for j in 0..cfg.n2 {
                    let a = store.point_query(i, j);
                    let b = reference.query(i, j);
                    prop_assert(
                        a.to_bits() == b.to_bits(),
                        &format!("estimate differs at ({i}, {j}): {a} vs {b}"),
                    )?;
                }
            }
            // the merged sketch (the TOPK/HEAVY path) agrees too
            let merged = store.merged();
            for _ in 0..50 {
                let (i, j) = random_key(g.rng(), &cfg);
                prop_assert(
                    merged.query(i, j).to_bits() == reference.query(i, j).to_bits(),
                    "merged sketch diverges from reference",
                )?;
            }
            Ok(())
        });
    }
}

#[test]
fn window_expiry_is_exact_subtraction() {
    forall("epoch expiry", 8, |g: &mut Gen| {
        let seed = g.rng().next_u64();
        let cfg = store_cfg(4, 2, seed);
        let store = ShardedStore::new(cfg.clone());
        let phase = |store: &ShardedStore, n: usize, record: bool, g: &mut Gen| {
            let mut items = Vec::new();
            for _ in 0..n {
                let (i, j) = random_key(g.rng(), &cfg);
                let w = int_weight(g.rng());
                store.update(i, j, w);
                if record {
                    items.push((i, j, w));
                }
            }
            items
        };
        phase(&store, 300, false, g); // epoch 0 (will expire)
        store.advance_epoch();
        let live_items = phase(&store, 250, true, g); // epoch 1 (stays)
        store.advance_epoch(); // window=2: epoch 0 expires exactly
        let mut reference = reference_sketch(&cfg);
        for &(i, j, w) in &live_items {
            reference.update(i, j, w);
        }
        prop_assert(store.updates() == reference.updates, "live update counts differ")?;
        for i in 0..cfg.n1 {
            for j in 0..cfg.n2 {
                prop_assert(
                    store.point_query(i, j).to_bits() == reference.query(i, j).to_bits(),
                    &format!("expired mass leaked at ({i}, {j})"),
                )?;
            }
        }
        Ok(())
    });
}

fn entry_bits(v: &[(usize, usize, f64)]) -> Vec<(usize, usize, u64)> {
    v.iter().map(|&(i, j, w)| (i, j, w.to_bits())).collect()
}

#[test]
fn cached_scans_bit_identical_to_fresh_re_merge() {
    forall("scan cache vs full re-merge", 6, |g: &mut Gen| {
        let seed = g.rng().next_u64();
        let cfg = store_cfg(4, 3, seed);
        let store = ShardedStore::new(cfg.clone());
        for _step in 0..10 {
            // one random mutation kind per step, then prove the cached
            // plane is indistinguishable from a fresh K-way re-merge
            match g.usize_in(0, 3) {
                0 => {
                    for _ in 0..60 {
                        let (i, j) = random_key(g.rng(), &cfg);
                        store.update(i, j, int_weight(g.rng()));
                    }
                }
                1 => {
                    let items: Vec<(usize, usize, f64)> = (0..40)
                        .map(|_| {
                            let (i, j) = random_key(g.rng(), &cfg);
                            (i, j, int_weight(g.rng()))
                        })
                        .collect();
                    store.update_batch(&items);
                }
                2 => {
                    // a remote merge; int_weight's negatives make some
                    // of these deletion-carrying, exercising the sticky
                    // has_deletions dense-scan routing through the cache
                    let mut remote = StreamSketch::new(
                        cfg.n1, cfg.n2, cfg.m1, cfg.m2, cfg.d, cfg.seed,
                    );
                    for _ in 0..20 {
                        let (i, j) = random_key(g.rng(), &cfg);
                        remote.update(i, j, int_weight(g.rng()));
                    }
                    store.merge_sketch(&remote).unwrap();
                }
                _ => store.advance_epoch(),
            }
            let fresh = store.merged_uncached();
            let cached = store.merged();
            prop_assert(cached.updates == fresh.updates, "merged update counts diverge")?;
            prop_assert(
                cached.has_deletions == fresh.has_deletions,
                "dense-scan routing flag diverges",
            )?;
            for r in 0..cfg.d {
                prop_assert(
                    cached.table(r) == fresh.table(r),
                    &format!("cached table {r} diverges from re-merge"),
                )?;
            }
            let k = 1 + g.usize_in(0, 7);
            let want_top = entry_bits(&fresh.top_k(k));
            prop_assert(entry_bits(&store.top_k(k)) == want_top, "cached top-k diverges")?;
            // second serve at the same k is the memoized path
            prop_assert(
                entry_bits(&store.top_k(k)) == want_top,
                "memoized top-k diverges",
            )?;
            let t = (5 + g.usize_in(0, 40)) as f64;
            let want_heavy = entry_bits(&fresh.heavy_hitters(t));
            prop_assert(
                entry_bits(&store.heavy_hitters(t)) == want_heavy,
                "cached heavy-hitters diverge",
            )?;
            prop_assert(
                entry_bits(&store.heavy_hitters(t)) == want_heavy,
                "memoized heavy-hitters diverge",
            )?;
        }
        Ok(())
    });
}

#[test]
fn rotation_storm_exercises_the_lockall_fallback() {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::time::{Duration, Instant};

    // Tiny tables make rotations fast and 8 shards make the optimistic
    // fan-out long, so epoch validations keep colliding until a reader
    // exhausts EPOCH_RETRY_LIMIT and takes the counted lock-all path.
    let cfg = StoreConfig { n1: 64, n2: 64, m1: 4, m2: 4, d: 3, seed: 5, shards: 8, window: 3 };
    let store = ShardedStore::new(cfg.clone());
    // one weight-1 key per shard: during the storm each key answers its
    // pre-expiry estimate or (once the window slides past the preload)
    // exactly zero — anything else is a torn read
    let mut keys: Vec<Option<(usize, usize)>> = vec![None; cfg.shards];
    for i in 0..cfg.n1 {
        for j in 0..cfg.n2 {
            let s = store.shard_of(i, j);
            if keys[s].is_none() {
                keys[s] = Some((i, j));
                store.update(i, j, 1.0);
            }
        }
    }
    let keys: Vec<(usize, usize)> = keys.into_iter().map(|k| k.unwrap()).collect();
    let pre: Vec<u64> = keys.iter().map(|&(i, j)| store.point_query(i, j).to_bits()).collect();
    let preloaded = cfg.shards as u64;

    let stop = AtomicBool::new(false);
    let deadline = Instant::now() + Duration::from_secs(20);
    std::thread::scope(|scope| {
        let advancer = scope.spawn(|| {
            while !stop.load(Ordering::Relaxed) {
                store.advance_epoch();
            }
        });
        while store.lockall_fallbacks() == 0 && Instant::now() < deadline {
            for (&(i, j), &want) in keys.iter().zip(pre.iter()) {
                let got = store.point_query(i, j);
                // `== 0.0` (not bits): post-expiry estimates may be a
                // signed zero depending on the key's sign product
                assert!(
                    got.to_bits() == want || got == 0.0,
                    "torn point query at ({i}, {j}): {got}"
                );
            }
            let st = store.stats();
            assert!(st.updates == preloaded || st.updates == 0, "torn stats: {st:?}");
        }
        stop.store(true, Ordering::Relaxed);
        advancer.join().unwrap();
    });
    // On any real multi-core box the storm exhausts EPOCH_RETRY_LIMIT
    // within milliseconds. Whether 8 straight rotations interleave one
    // reader's fan-out is ultimately the scheduler's call, though (a
    // starved single-core or noisy-neighbor runner can simply never
    // produce the collision run), so — mirroring the loopback-skip
    // convention — deadline exhaustion skips the counter assertion
    // loudly instead of failing on scheduler behaviour. The torn-read
    // consistency assertions above ran either way, and the counter
    // itself is proven wired by hitting this path in practice.
    if store.lockall_fallbacks() == 0 {
        eprintln!(
            "skipping lock-all fallback assertion: scheduler never produced \
             enough consecutive epoch collisions within the deadline"
        );
    }
}

fn tmpdir(tag: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("hocs_store_prop_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&p);
    std::fs::create_dir_all(&p).unwrap();
    p
}

#[test]
fn recovered_store_answers_identically_to_pre_crash_store() {
    let dir = tmpdir("recover");
    forall("snapshot + WAL replay", 4, |g: &mut Gen| {
        let seed = g.rng().next_u64();
        let cfg = store_cfg(3, 3, seed);
        // fresh directory per case (different seeds are different stores)
        let _ = std::fs::remove_dir_all(&dir);
        let shadow = ShardedStore::new(cfg.clone());
        {
            let live = DurableStore::open(&dir, cfg.clone()).unwrap();
            let drive = |live: &DurableStore, n: usize, g: &mut Gen| {
                for _ in 0..n {
                    let (i, j) = random_key(g.rng(), &cfg);
                    let w = int_weight(g.rng());
                    live.update(i, j, w).unwrap();
                    shadow.update(i, j, w);
                }
            };
            drive(&live, 150, g);
            live.snapshot().unwrap(); // state up to here in the snapshot
            drive(&live, 100, g);
            live.advance_epoch().unwrap();
            shadow.advance_epoch();
            drive(&live, 80, g); // tail lives only in the WAL
            // drop without snapshot = crash
        }
        let recovered = DurableStore::open(&dir, cfg.clone()).unwrap();
        prop_assert(recovered.stats() == shadow.stats(), "stats diverged after recovery")?;
        for i in 0..cfg.n1 {
            for j in 0..cfg.n2 {
                let a = recovered.point_query(i, j);
                let b = shadow.point_query(i, j);
                prop_assert(
                    a.to_bits() == b.to_bits(),
                    &format!("recovered estimate differs at ({i}, {j}): {a} vs {b}"),
                )?;
            }
        }
        Ok(())
    });
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn batched_durable_updates_bit_identical_and_recoverable() {
    let dir = tmpdir("batch");
    forall("group commit vs per-item", 4, |g: &mut Gen| {
        let seed = g.rng().next_u64();
        let cfg = store_cfg(4, 3, seed);
        let _ = std::fs::remove_dir_all(&dir);
        // shadow applies every item singly — the per-item oracle
        let shadow = ShardedStore::new(cfg.clone());
        {
            let live = DurableStore::open(&dir, cfg.clone()).unwrap();
            let drive_batch = |live: &DurableStore, n: usize, g: &mut Gen| {
                let items: Vec<(usize, usize, f64)> = (0..n)
                    .map(|_| {
                        let (i, j) = random_key(g.rng(), &cfg);
                        (i, j, int_weight(g.rng()))
                    })
                    .collect();
                live.update_batch(&items).unwrap();
                for &(i, j, w) in &items {
                    shadow.update(i, j, w);
                }
            };
            drive_batch(&live, 150 + g.usize_in(0, 100), g);
            live.snapshot().unwrap(); // batches before here live in the snapshot
            drive_batch(&live, 120, g);
            live.advance_epoch().unwrap();
            shadow.advance_epoch();
            drive_batch(&live, 90, g); // tail lives only in UpdateBatch frames
            // drop without snapshot = crash
        }
        let recovered = DurableStore::open(&dir, cfg.clone()).unwrap();
        prop_assert(recovered.stats() == shadow.stats(), "stats diverged after recovery")?;
        for i in 0..cfg.n1 {
            for j in 0..cfg.n2 {
                let a = recovered.point_query(i, j);
                let b = shadow.point_query(i, j);
                prop_assert(
                    a.to_bits() == b.to_bits(),
                    &format!("batched estimate differs at ({i}, {j}): {a} vs {b}"),
                )?;
            }
        }
        Ok(())
    });
    let _ = std::fs::remove_dir_all(&dir);
}

/// Reserve a distinct loopback address per node by binding port 0 and
/// immediately releasing it — replica peers must be named *before* the
/// servers boot, and the replicator's reconnect backoff tolerates peers
/// that are still coming up.
fn reserve_addrs(n: usize) -> Option<Vec<String>> {
    let mut listeners = Vec::new();
    for _ in 0..n {
        match std::net::TcpListener::bind("127.0.0.1:0") {
            Ok(l) => listeners.push(l),
            Err(e) => {
                eprintln!("skipping: cannot bind loopback ({e})");
                return None;
            }
        }
    }
    Some(listeners.iter().map(|l| l.local_addr().unwrap().to_string()).collect())
}

#[test]
fn replicated_nodes_converge_to_the_union_stream() {
    use std::time::{Duration, Instant};

    // Three replica servers in a full mesh. Writes — including
    // turnstile deletions, an edge-node MERGE (relayed by its ingest
    // node), and an epoch rotation mid-stream — are split across the
    // nodes; the oracle is one ShardedStore fed the union stream.
    // Convergence must be *bit-identical*: anti-entropy ships every
    // locally-originated update to every peer exactly once (per-origin
    // dedup + delta cursors), and integer weights make the counter sums
    // exact under any arrival order. Window 4 with a single mid-stream
    // rotation keeps all mass live, so slot assignment of late-arriving
    // remote mass cannot skew expiry within the test horizon.
    let cfg = store_cfg(2, 4, 0xAB5EED);
    let Some(addrs) = reserve_addrs(3) else { return };
    let mut servers = Vec::new();
    for (n, addr) in addrs.iter().enumerate() {
        let peers: Vec<String> =
            addrs.iter().enumerate().filter(|&(m, _)| m != n).map(|(_, a)| a.clone()).collect();
        let server = match StoreServer::start(StoreServerConfig {
            addr: addr.clone(),
            store: cfg.clone(),
            peers,
            sync_interval_ms: 15,
            // one node self-heals with periodic full-state ships, so the
            // cumulative-replace path must also preserve exactness
            full_ship_every: if n == 0 { 3 } else { 0 },
            replica_timeout_ms: 2_000,
            ..Default::default()
        }) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("skipping: cannot boot replica server ({e})");
                return;
            }
        };
        servers.push(server);
    }
    let mut clients: Vec<StoreClient> =
        servers.iter().map(|s| StoreClient::connect(s.local_addr()).unwrap()).collect();
    let oracle = ShardedStore::new(cfg.clone());

    let mut rng = Pcg64::new(0xC0DE);
    let drive = |clients: &mut Vec<StoreClient>, oracle: &ShardedStore, n: usize, rng: &mut Pcg64| {
        for step in 0..n {
            let (i, j) = random_key(rng, &cfg);
            let w = int_weight(rng); // ~20% deletions
            let node = step % clients.len();
            if step % 7 == 0 {
                clients[node].update_batch(&[(i as u32, j as u32, w)]).unwrap();
            } else {
                clients[node].update(i, j, w).unwrap();
            }
            oracle.update(i, j, w);
        }
    };
    let quiesce = |clients: &mut Vec<StoreClient>, oracle: &ShardedStore| {
        let want = oracle.updates();
        let deadline = Instant::now() + Duration::from_secs(60);
        loop {
            // a node's update counter reaches the union total exactly
            // when every other node's mass has arrived exactly once —
            // deltas carry their update counts, dedup forbids doubles
            let counts: Vec<u64> = clients.iter_mut().map(|c| c.stats().unwrap().updates).collect();
            if counts.iter().all(|&u| u == want) {
                return;
            }
            assert!(
                Instant::now() < deadline,
                "anti-entropy did not quiesce: node counts {counts:?}, want {want}"
            );
            std::thread::sleep(Duration::from_millis(20));
        }
    };

    drive(&mut clients, &oracle, 240, &mut rng);
    // an edge node ships a summary (legacy MERGE) to node 2; the
    // ingest node must relay it to its peers like local traffic
    let mut edge = reference_sketch(&cfg);
    for _ in 0..30 {
        let (i, j) = random_key(&mut rng, &cfg);
        edge.update(i, j, int_weight(&mut rng));
    }
    clients[2].merge(&edge).unwrap();
    oracle.merge_sketch(&edge).unwrap();
    quiesce(&mut clients, &oracle);

    // epoch rotation mid-stream, applied to every node and the oracle
    // at the same quiesced point of the stream
    for c in clients.iter_mut() {
        c.advance_epoch().unwrap();
    }
    oracle.advance_epoch();
    drive(&mut clients, &oracle, 180, &mut rng);
    quiesce(&mut clients, &oracle);

    // every replica answers bit-identically to the union-stream oracle,
    // over the whole key universe
    for (n, client) in clients.iter_mut().enumerate() {
        let stats = client.stats().unwrap();
        assert_eq!(stats.updates, oracle.updates(), "node {n} update count diverges");
        assert_eq!(stats.epoch, oracle.epoch(), "node {n} epoch diverges");
        for i in 0..cfg.n1 {
            for j in 0..cfg.n2 {
                let got = client.query(i, j).unwrap();
                let want = oracle.point_query(i, j);
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "node {n} diverges at ({i}, {j}): {got} vs {want}"
                );
            }
        }
        // replication counters are live on every node
        let (_, repl) = client.stats_full().unwrap();
        let repl = repl.expect("replication stats");
        assert_eq!(repl.peers, 2, "node {n} peer count");
        assert!(repl.ships > 0, "node {n} never shipped");
        assert!(repl.merges_applied > 0, "node {n} never applied a peer frame");
    }
    // node 0 ran with a full-ship cadence: its counters must show them
    let (_, repl0) = clients[0].stats_full().unwrap();
    assert!(repl0.unwrap().full_ships >= 1, "full-ship cadence never fired");
    for s in servers {
        s.shutdown();
    }
}

#[test]
fn recovery_is_idempotent_across_reopens() {
    // opening heals the WAL into a snapshot; a second open must not
    // double-apply anything
    let dir = tmpdir("idempotent");
    let cfg = store_cfg(2, 2, 424242);
    {
        let live = DurableStore::open(&dir, cfg.clone()).unwrap();
        live.update(1, 2, 3.0).unwrap();
        live.update(4, 5, 6.0).unwrap();
    }
    let first = DurableStore::open(&dir, cfg.clone()).unwrap();
    let q1 = (first.point_query(1, 2), first.point_query(4, 5));
    drop(first);
    let second = DurableStore::open(&dir, cfg).unwrap();
    assert_eq!(second.point_query(1, 2).to_bits(), q1.0.to_bits());
    assert_eq!(second.point_query(4, 5).to_bits(), q1.1.to_bits());
    assert_eq!(q1.0, 3.0);
    assert_eq!(q1.1, 6.0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Drive every WAL record kind and snapshot section with fixed inputs:
/// point + batch updates, an epoch rotation, a plain merge, deduped
/// origin merges (delta then full), sender cursor advances, the tensor
/// plane (DDL, point, batch), a snapshot, and a post-snapshot WAL tail
/// in the next generation. Nothing here touches wall clocks or derived
/// origin ids, so two runs must produce identical durable bytes.
fn golden_format_workload(dir: &std::path::Path) {
    use hocs::store::replica::wire::{MODE_DELTA, MODE_FULL};
    use hocs::store::TensorFamily;
    let cfg = store_cfg(2, 3, 0x601D_F0D5);
    let live = DurableStore::open(dir, cfg.clone()).unwrap();
    let mut rng = Pcg64::new(7);
    for _ in 0..40 {
        let (i, j) = random_key(&mut rng, &cfg);
        live.update(i, j, int_weight(&mut rng)).unwrap();
    }
    live.update_batch(&[(1, 2, 3.0), (4, 5, -2.0), (6, 7, 9.0)]).unwrap();
    live.advance_epoch().unwrap();
    let mut remote = reference_sketch(&cfg);
    remote.update(3, 4, 5.0);
    remote.update(8, 9, -1.0);
    live.merge_sketch(&remote).unwrap();
    let mut delta = reference_sketch(&cfg);
    delta.update(10, 11, 2.0);
    assert!(live.apply_origin_merge(9, 1, MODE_DELTA, true, delta).unwrap());
    let mut full = reference_sketch(&cfg);
    full.update(12, 13, 4.0);
    assert!(live.apply_origin_merge(9, 2, MODE_FULL, true, full).unwrap());
    live.advance_replica_cursor("peer:a", 3, 7).unwrap();
    live.advance_replica_cursor("peer:b", 1, 2).unwrap();
    let family = TensorFamily { dims: vec![6, 5, 4], sketch_dims: vec![4, 3, 2], d: 3, seed: 99 };
    assert!(live.tensor_create("golden", &family).unwrap());
    live.tensor_update("golden", &[1, 2, 3], 2.5).unwrap();
    live.tensor_update_batch("golden", &[0, 1, 2, 5, 4, 3], &[1.0, -2.0]).unwrap();
    live.snapshot().unwrap();
    live.update(2, 2, 2.0).unwrap();
    live.tensor_update("golden", &[2, 2, 2], 1.0).unwrap();
}

/// Golden on-disk-format pin: FNV-64 over `snapshot.bin` + `wal.bin`
/// from the fixed workload above, pinned per `FORMAT_VERSION` in
/// `rust/tests/golden/` (see the README there for the bless ritual).
/// Complements the `version-gate` lint: the lint pins what the source
/// *says* the format is, this pins what the code actually *writes*.
#[test]
fn on_disk_format_bytes_are_pinned_per_format_version() {
    let dir_a = tmpdir("golden_a");
    let dir_b = tmpdir("golden_b");
    golden_format_workload(&dir_a);
    golden_format_workload(&dir_b);
    let mut digest: u64 = 0xcbf2_9ce4_8422_2325;
    for name in ["snapshot.bin", "wal.bin"] {
        let a = std::fs::read(dir_a.join(name)).unwrap();
        let b = std::fs::read(dir_b.join(name)).unwrap();
        // determinism first: identical runs must leave identical bytes
        assert_eq!(a, b, "{name} differs between two identical runs");
        for &byte in &a {
            digest ^= u64::from(byte);
            digest = digest.wrapping_mul(0x100_0000_01b3);
        }
    }
    let got = format!("{digest:016x}\n");
    let pin =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden/format_v5.fnv");
    match std::fs::read_to_string(&pin) {
        Ok(pinned) => assert_eq!(
            got.trim(),
            pinned.trim(),
            "durable bytes drifted from the v5 golden pin; if the format change is \
             deliberate, bump FORMAT_VERSION in store/wal.rs, re-pin the lint manifest, \
             and bless a new rust/tests/golden/format_v<N>.fnv (delete the old pin file \
             and re-run this test)"
        ),
        Err(_) => {
            std::fs::create_dir_all(pin.parent().unwrap()).unwrap();
            std::fs::write(&pin, &got).unwrap();
            eprintln!("blessed new golden format pin {} = {}", pin.display(), got.trim());
        }
    }
    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
}
