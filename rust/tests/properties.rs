//! Property-based test suite: algebraic invariants of the sketching
//! layer swept over random shapes/seeds with the in-crate prop
//! framework (`hocs::util::prop`). These are the Rust-side counterpart
//! of the hypothesis sweeps in `python/tests/`.

use hocs::fft::{circular_convolve, circular_convolve2};
use hocs::rng::Pcg64;
use hocs::sketch::cs::CsSketcher;
use hocs::sketch::kron::MtsKron;
use hocs::sketch::mts::MtsSketcher;
use hocs::tensor::{kron, mode_k_product, outer, rel_error, Tensor};
use hocs::util::prop::{forall, prop_assert, prop_close, Gen};

// ---------------------------------------------------------------------
// sketch algebra
// ---------------------------------------------------------------------

#[test]
fn prop_mts_is_linear() {
    forall("MTS(aX + bY) = a·MTS(X) + b·MTS(Y)", 40, |g: &mut Gen| {
        let order = g.usize_in(1, 3);
        let dims = g.shape(order, 7);
        let sdims: Vec<usize> = dims.iter().map(|&d| 1 + d / 2).collect();
        let n: usize = dims.iter().product();
        let a = g.f64_in(-2.0, 2.0);
        let b = g.f64_in(-2.0, 2.0);
        let x = Tensor::from_vec(g.normal_vec(n), &dims);
        let y = Tensor::from_vec(g.normal_vec(n), &dims);
        let sk = MtsSketcher::new(&dims, &sdims, 42);
        let lhs = sk.sketch(&x.scale(a).add(&y.scale(b)));
        let rhs = sk.sketch(&x).scale(a).add(&sk.sketch(&y).scale(b));
        for (u, v) in lhs.data().iter().zip(rhs.data().iter()) {
            prop_close(*u, *v, 1e-9, "linearity")?;
        }
        Ok(())
    });
}

#[test]
fn prop_mts_of_vector_equals_cs() {
    forall("order-1 MTS is exactly a count sketch", 40, |g: &mut Gen| {
        let n = g.usize_in(2, 40);
        let m = g.usize_in(1, n);
        let x = g.normal_vec(n);
        let t = Tensor::from_vec(x.clone(), &[n]);
        let sk = MtsSketcher::new(&[n], &[m], 7);
        let got = sk.sketch(&t);
        // scatter with the same mode hash
        let mut want = vec![0.0; m];
        for (i, &v) in x.iter().enumerate() {
            want[sk.mode(0).h(i)] += sk.mode(0).s(i) * v;
        }
        for (u, v) in got.data().iter().zip(want.iter()) {
            prop_close(*u, *v, 1e-12, "cs equivalence")?;
        }
        Ok(())
    });
}

#[test]
fn prop_one_sparse_exact_recovery() {
    forall("1-sparse tensors recover exactly at any sketch size", 40, |g| {
        let dims = g.shape(2, 9);
        let sdims = vec![g.usize_in(1, 5), g.usize_in(1, 5)];
        let mut t = Tensor::zeros(&dims);
        let idx = vec![g.usize_in(0, dims[0] - 1), g.usize_in(0, dims[1] - 1)];
        let v = g.f64_in(-5.0, 5.0);
        t.set(&idx, v);
        let sk = MtsSketcher::new(&dims, &sdims, 3);
        let est = sk.estimate(&sk.sketch(&t), &idx);
        prop_close(est, v, 1e-12, "1-sparse recovery")
    });
}

#[test]
fn prop_estimate_matches_decompress() {
    forall("decompress agrees with pointwise estimates", 20, |g| {
        let dims = g.shape(3, 5);
        let sdims: Vec<usize> = dims.iter().map(|&d| 1 + d / 2).collect();
        let n: usize = dims.iter().product();
        let t = Tensor::from_vec(g.normal_vec(n), &dims);
        let sk = MtsSketcher::new(&dims, &sdims, 11);
        let s = sk.sketch(&t);
        let dec = sk.decompress(&s);
        // probe a few random indices
        for _ in 0..5 {
            let idx: Vec<usize> =
                dims.iter().map(|&d| g.usize_in(0, d - 1)).collect();
            prop_close(dec.get(&idx), sk.estimate(&s, &idx), 1e-12, "agreement")?;
        }
        Ok(())
    });
}

#[test]
fn prop_mts_sketch_preserves_total_mass_mod_signs() {
    // Σ MTS(T) = Σ_i s(i)·T_i-style invariant: sketching the all-ones
    // hash-sign pattern reproduces the signed sum exactly
    forall("bucket sums equal signed totals", 30, |g| {
        let dims = g.shape(2, 8);
        let n: usize = dims.iter().product();
        let t = Tensor::from_vec(g.normal_vec(n), &dims);
        let sk = MtsSketcher::new(&dims, &[3, 3], 13);
        let s = sk.sketch(&t);
        let total: f64 = s.data().iter().sum();
        let mut want = 0.0;
        for i in 0..dims[0] {
            for j in 0..dims[1] {
                want += sk.mode(0).s(i) * sk.mode(1).s(j) * t.get(&[i, j]);
            }
        }
        prop_close(total, want, 1e-9, "mass conservation")
    });
}

// ---------------------------------------------------------------------
// convolution / Kronecker identities
// ---------------------------------------------------------------------

#[test]
fn prop_cs_outer_product_identity() {
    // Pagh Eq. 2 over random sizes
    forall("CS(u⊗v) = CS(u) * CS(v)", 30, |g| {
        let nu = g.usize_in(2, 12);
        let nv = g.usize_in(2, 12);
        let c = g.usize_in(2, 16);
        let u = g.normal_vec(nu);
        let v = g.normal_vec(nv);
        let su = CsSketcher::new(nu, c, 5);
        let sv = CsSketcher::new(nv, c, 6);
        let combined = circular_convolve(&su.sketch(&u), &sv.sketch(&v));
        let mut direct = vec![0.0; c];
        for i in 0..nu {
            for j in 0..nv {
                direct[(su.h(i) + sv.h(j)) % c] += su.s(i) * sv.s(j) * u[i] * v[j];
            }
        }
        for (a, b) in combined.iter().zip(direct.iter()) {
            prop_close(*a, *b, 1e-9, "outer identity")?;
        }
        Ok(())
    });
}

#[test]
fn prop_lemma_b1_over_random_shapes() {
    forall("MTS(A⊗B) = MTS(A) * MTS(B) (2-D)", 15, |g| {
        let n1 = g.usize_in(2, 6);
        let n2 = g.usize_in(2, 6);
        let n3 = g.usize_in(2, 6);
        let n4 = g.usize_in(2, 6);
        let m1 = g.usize_in(2, 7);
        let m2 = g.usize_in(2, 7);
        let a = Tensor::from_vec(g.normal_vec(n1 * n2), &[n1, n2]);
        let b = Tensor::from_vec(g.normal_vec(n3 * n4), &[n3, n4]);
        let mk = MtsKron::new(&[n1, n2], &[n3, n4], m1, m2, 17);
        let combined = mk.compress(&a, &b);
        // direct sketch of the materialized product with derived hashes
        let mut direct = Tensor::zeros(&[m1, m2]);
        for p in 0..n1 {
            for q in 0..n2 {
                for h in 0..n3 {
                    for gg in 0..n4 {
                        let r = (mk.ska.mode(0).h(p) + mk.skb.mode(0).h(h)) % m1;
                        let cc = (mk.ska.mode(1).h(q) + mk.skb.mode(1).h(gg)) % m2;
                        let s = mk.ska.mode(0).s(p)
                            * mk.ska.mode(1).s(q)
                            * mk.skb.mode(0).s(h)
                            * mk.skb.mode(1).s(gg);
                        let v = direct.get(&[r, cc]) + s * a.at2(p, q) * b.at2(h, gg);
                        direct.set(&[r, cc], v);
                    }
                }
            }
        }
        prop_assert(rel_error(&direct, &combined) < 1e-8, "lemma B.1")
    });
}

#[test]
fn prop_convolution_theorem_2d() {
    forall("FFT2 convolution = direct circular convolution", 15, |g| {
        let r = g.usize_in(2, 9);
        let c = g.usize_in(2, 9);
        let a = g.normal_vec(r * c);
        let b = g.normal_vec(r * c);
        let got = circular_convolve2(&a, &b, r, c);
        for kr in 0..r {
            for kc in 0..c {
                let mut want = 0.0;
                for i in 0..r {
                    for j in 0..c {
                        want += a[i * c + j]
                            * b[((kr + r - i) % r) * c + ((kc + c - j) % c)];
                    }
                }
                prop_close(got[kr * c + kc], want, 1e-8, "conv2")?;
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// tensor substrate invariants
// ---------------------------------------------------------------------

#[test]
fn prop_kron_rank_one_structure() {
    forall("kron of rank-1 matrices is rank-1", 20, |g| {
        let n = g.usize_in(2, 5);
        let u = g.normal_vec(n);
        let v = g.normal_vec(n);
        let x = g.normal_vec(n);
        let y = g.normal_vec(n);
        let a = outer(&[&u, &v]);
        let b = outer(&[&x, &y]);
        let k = kron(&a, &b);
        // k should equal outer(u⊗x, v⊗y)
        let ux = hocs::tensor::kron_vec(&u, &x);
        let vy = hocs::tensor::kron_vec(&v, &y);
        let want = outer(&[&ux, &vy]);
        prop_assert(rel_error(&want, &k) < 1e-10, "rank-1 kron structure")
    });
}

#[test]
fn prop_mode_product_associativity() {
    forall("mode products along different modes commute", 20, |g| {
        let dims = g.shape(3, 6);
        let n: usize = dims.iter().product();
        let t = Tensor::from_vec(g.normal_vec(n), &dims);
        let m0 = Tensor::from_vec(g.normal_vec(dims[0] * 3), &[dims[0], 3]);
        let m2 = Tensor::from_vec(g.normal_vec(dims[2] * 2), &[dims[2], 2]);
        let ab = mode_k_product(&mode_k_product(&t, &m0, 0), &m2, 2);
        let ba = mode_k_product(&mode_k_product(&t, &m2, 2), &m0, 0);
        prop_assert(rel_error(&ab, &ba) < 1e-10, "commuting contractions")
    });
}

#[test]
fn prop_unfold_fold_roundtrip_random_shapes() {
    forall("unfold∘fold = id for every mode", 25, |g| {
        let order = g.usize_in(2, 4);
        let dims = g.shape(order, 5);
        let n: usize = dims.iter().product();
        let t = Tensor::from_vec(g.normal_vec(n), &dims);
        for mode in 0..order {
            let back = Tensor::fold(&t.unfold(mode), mode, &dims);
            prop_assert(back == t, "roundtrip")?;
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// median estimator robustness
// ---------------------------------------------------------------------

#[test]
fn prop_median_of_d_is_shift_equivariant() {
    forall("median(x + c) = median(x) + c", 30, |g| {
        let d = 1 + 2 * g.usize_in(0, 6); // odd
        let xs = g.normal_vec(d);
        let c = g.f64_in(-10.0, 10.0);
        let m1 = hocs::util::stats::median(&xs);
        let shifted: Vec<f64> = xs.iter().map(|x| x + c).collect();
        let m2 = hocs::util::stats::median(&shifted);
        prop_close(m2, m1 + c, 1e-12, "shift equivariance")
    });
}

#[test]
fn prop_seeded_everything_is_reproducible() {
    forall("identical seeds → identical pipelines", 10, |g| {
        let dims = g.shape(2, 8);
        let n: usize = dims.iter().product();
        let data = g.normal_vec(n);
        let t = Tensor::from_vec(data, &dims);
        let run = || {
            let sk = MtsSketcher::new(&dims, &[3, 3], 1234);
            let s = sk.sketch(&t);
            let mut rng = Pcg64::new(99);
            let probe = vec![
                rng.gen_range(dims[0] as u64) as usize,
                rng.gen_range(dims[1] as u64) as usize,
            ];
            sk.estimate(&s, &probe)
        };
        prop_close(run(), run(), 0.0, "bit-identical reruns")
    });
}
