//! Crash-consistency harness for the durable and replication paths.
//!
//! The matrix tests spawn `hocs fault-crash` child processes with a
//! failpoint armed through `HOCS_FAULTS` (see `store::faults`), let the
//! child die at the injection site, then recover the directory
//! in-process and assert the durability contract:
//!
//! - **no acknowledged write is lost** — every op the child logged to
//!   `acks.log` is in the recovered state;
//! - **no torn state, ever** — the recovered update counter matches an
//!   exact prefix of the scripted workload, and the sketch contents are
//!   bit-identical to an in-memory replay of that prefix (integer
//!   weights make f64 comparisons exact);
//! - **dedup horizons are monotone** — a re-delivered origin sequence
//!   at or below the recovered horizon is dropped, the next one applies;
//! - **recovery heals** — the reopened store accepts writes that
//!   survive a further reopen.
//!
//! Failpoints compile out of release builds, so the child-process tests
//! skip themselves under `--release`; the in-process rotation-fault and
//! torn-tail tests run everywhere they can arm the registry (debug).
//! `HOCS_FAULT_QUICK=1` trims the matrix for the CI smoke job.

use hocs::store::faults::{self, CrashOp, FaultAction};
use hocs::store::{DurableOptions, DurableStore, StoreConfig, StoreServer, StoreServerConfig};
use std::fs;
use std::path::{Path, PathBuf};
use std::process::Command;
use std::sync::{Mutex, MutexGuard, OnceLock};

const TOTAL_OPS: usize = 120;
const SEED: u64 = 77;

/// The failpoint registry is process-global, and several tests here arm
/// it (or must not see it armed); the whole file serializes on this.
fn serial() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("hocs_faults_{tag}_{}", std::process::id()));
    let _ = fs::remove_dir_all(&d);
    fs::create_dir_all(&d).expect("creating test dir");
    d
}

/// One `hocs fault-crash` child invocation (see `cmd_fault_crash`).
#[derive(Default)]
struct Child<'a> {
    fsync: bool,
    ops: usize,
    start: usize,
    snapshot_at: usize,
    seed: u64,
    op_delay_us: u64,
    fault: Option<&'a str>,
    peer: Option<&'a str>,
}

impl Child<'_> {
    fn run(&self, dir: &Path) -> std::process::Output {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_hocs"));
        cmd.arg("fault-crash").arg("--dir").arg(dir);
        cmd.args(["--ops", &self.ops.to_string()]);
        cmd.args(["--start", &self.start.to_string()]);
        cmd.args(["--seed", &self.seed.to_string()]);
        if self.snapshot_at > 0 {
            cmd.args(["--snapshot-at", &self.snapshot_at.to_string()]);
        }
        if self.fsync {
            cmd.arg("--fsync");
        }
        if let Some(p) = self.peer {
            cmd.args(["--peer", p]);
        }
        if self.op_delay_us > 0 {
            cmd.args(["--op-delay-us", &self.op_delay_us.to_string()]);
        }
        cmd.env_remove("HOCS_FAULTS");
        if let Some(f) = self.fault {
            cmd.env("HOCS_FAULTS", f);
        }
        cmd.output().expect("spawning hocs fault-crash child")
    }
}

/// Ops the child acknowledged (durably committed, then logged) before
/// it died.
fn acked_ops(dir: &Path) -> usize {
    match fs::read_to_string(dir.join("acks.log")) {
        Ok(s) => s.lines().filter(|l| !l.trim().is_empty()).count(),
        Err(_) => 0,
    }
}

/// Infer which workload prefix a recovered update counter corresponds
/// to. Every op advances the counter by ≥ 1, so cumulative counts are
/// strictly increasing and the prefix length is unique; `None` means
/// the counter matches no prefix — torn state.
fn recovered_prefix(ops: &[CrashOp], updates: u64) -> Option<usize> {
    if updates == 0 {
        return Some(0);
    }
    let mut cum = 0u64;
    for (k, op) in ops.iter().enumerate() {
        cum += op.updates();
        if cum == updates {
            return Some(k + 1);
        }
        if cum > updates {
            return None;
        }
    }
    None
}

fn replay_shadow(cfg: &StoreConfig, ops: &[CrashOp]) -> DurableStore {
    let s = DurableStore::in_memory(cfg.clone());
    for op in ops {
        faults::apply_crash_op(&s, cfg, op).expect("shadow replay");
    }
    s
}

/// Bit-exact full-universe comparison (the crash geometry is small
/// enough to sweep; integer weights make every estimate exact in f64).
/// Covers both planes: the 2-D sketch and the crash tensor's full
/// multi-mode key space.
fn assert_same_universe(got: &DurableStore, want: &DurableStore, cfg: &StoreConfig, what: &str) {
    assert_eq!(got.stats().updates, want.stats().updates, "{what}: update counters differ");
    for i in 0..cfg.n1 {
        for j in 0..cfg.n2 {
            let (x, y) = (got.point_query(i, j), want.point_query(i, j));
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: ({i}, {j}) differs: {x} vs {y}");
        }
    }
    assert_same_tensor(got, want, what);
}

/// Bit-exact sweep of the crash tensor's key space. A crash can land
/// between a tensor op's create record and its update record, leaving
/// one side with a created-but-empty tensor the prefix replay never
/// made — an empty HCS reads all-zero, so absence and emptiness are
/// deliberately treated as equal here (the op was never acknowledged).
fn assert_same_tensor(got: &DurableStore, want: &DurableStore, what: &str) {
    let fam = faults::crash_tensor_family();
    let query = |s: &DurableStore, key: &[usize]| -> f64 {
        if s.tensor_family(faults::CRASH_TENSOR).is_some() {
            s.tensor_query(faults::CRASH_TENSOR, key)
                .unwrap_or_else(|e| panic!("{what}: tensor query {key:?} failed: {e}"))
        } else {
            0.0
        }
    };
    for i in 0..fam.dims[0] {
        for j in 0..fam.dims[1] {
            for k in 0..fam.dims[2] {
                let key = [i, j, k];
                let (x, y) = (query(got, &key), query(want, &key));
                assert_eq!(x.to_bits(), y.to_bits(), "{what}: tensor {key:?} differs: {x} vs {y}");
            }
        }
    }
}

struct CrashCase {
    name: &'static str,
    fault: &'static str,
    fsync: bool,
    snapshot_at: usize,
}

/// Every registered WAL/snapshot failpoint, killed at a mid-workload
/// hit. `@nth` picks the hit: append/sync sites fire once per op frame;
/// snapshot/rotation sites fire once during `open` (hit 1), so `@2` is
/// the first runtime `snapshot()` — which the `snapshot_at: 60` cases
/// trigger at op 60.
fn crash_cases() -> Vec<CrashCase> {
    vec![
        CrashCase {
            name: "torn WAL append (flush mode)",
            fault: "wal.append=torn:5@40",
            fsync: false,
            snapshot_at: 0,
        },
        CrashCase {
            name: "abort at WAL append (flush mode)",
            fault: "wal.append=abort@25",
            fsync: false,
            snapshot_at: 0,
        },
        CrashCase {
            name: "torn WAL append (fsync mode)",
            fault: "wal.append=torn:9@60",
            fsync: true,
            snapshot_at: 0,
        },
        CrashCase {
            name: "abort before WAL sync (fsync mode)",
            fault: "wal.sync=abort@30",
            fsync: true,
            snapshot_at: 0,
        },
        CrashCase {
            name: "torn snapshot body",
            fault: "snap.write=torn:64@2",
            fsync: false,
            snapshot_at: 60,
        },
        CrashCase {
            name: "abort at snapshot rename",
            fault: "snap.rename=abort@2",
            fsync: false,
            snapshot_at: 60,
        },
        CrashCase {
            name: "abort at WAL rotation rename",
            fault: "wal.create.rename=abort@2",
            fsync: false,
            snapshot_at: 60,
        },
        CrashCase {
            name: "abort at snapshot dir sync (fsync mode)",
            fault: "snap.dirsync=abort@2",
            fsync: true,
            snapshot_at: 60,
        },
        CrashCase {
            name: "abort at WAL rotation tmp (fsync mode)",
            fault: "wal.create.tmp=abort@2",
            fsync: true,
            snapshot_at: 60,
        },
    ]
}

#[test]
fn crash_matrix_loses_no_acked_write_and_leaves_no_torn_state() {
    let _g = serial();
    faults::reset();
    if !cfg!(debug_assertions) {
        eprintln!("skipping: failpoints compile out of release builds");
        return;
    }
    let quick = std::env::var("HOCS_FAULT_QUICK").is_ok_and(|v| v == "1");
    let cfg = faults::crash_config();
    let ops = faults::crash_workload(&cfg, TOTAL_OPS, SEED);
    let cases = crash_cases();
    let cases = if quick { &cases[..4] } else { &cases[..] };
    for case in cases {
        let tag = format!("matrix_{}", case.fault.replace(['=', ':', '@', '.'], "_"));
        let dir = tmpdir(&tag);
        let out = Child {
            fsync: case.fsync,
            ops: TOTAL_OPS,
            seed: SEED,
            snapshot_at: case.snapshot_at,
            fault: Some(case.fault),
            ..Default::default()
        }
        .run(&dir);
        assert!(
            !out.status.success(),
            "{}: child should have crashed\nstdout: {}\nstderr: {}",
            case.name,
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
        let acked = acked_ops(&dir);
        assert!(acked > 0, "{}: fault fired before any op was acknowledged", case.name);

        let opts = DurableOptions { fsync: case.fsync, group_commit: true };
        let rec = DurableStore::open_opts(&dir, cfg.clone(), opts)
            .unwrap_or_else(|e| panic!("{}: recovery failed: {e}", case.name));
        let recovered = rec.stats().updates;
        let m = recovered_prefix(&ops, recovered).unwrap_or_else(|| {
            panic!("{}: recovered {recovered} updates — not any workload prefix", case.name)
        });
        assert!(
            acked <= m,
            "{}: {acked} ops were acknowledged but only {m} survived recovery",
            case.name
        );
        assert!(m <= TOTAL_OPS, "{}: recovered more ops than were executed", case.name);
        let shadow = replay_shadow(&cfg, &ops[..m]);
        assert_same_universe(&rec, &shadow, &cfg, case.name);

        // dedup horizon is monotone across the crash: the recovered
        // channel still drops everything at or below it, and admits the
        // next sequence
        let horizon =
            ops[..m].iter().filter(|o| matches!(o, CrashOp::OriginMerge { .. })).count() as u64;
        let before = rec.stats().updates;
        if horizon > 0 {
            let dup = CrashOp::OriginMerge { seq: horizon, i: 1, j: 1, w: 1.0 };
            faults::apply_crash_op(&rec, &cfg, &dup).expect(case.name);
            assert_eq!(
                rec.stats().updates,
                before,
                "{}: re-delivered merge seq {horizon} was not deduped",
                case.name
            );
        }
        let next = CrashOp::OriginMerge { seq: horizon + 1, i: 1, j: 1, w: 1.0 };
        faults::apply_crash_op(&rec, &cfg, &next).expect(case.name);
        assert_eq!(rec.stats().updates, before + 1, "{}: next merge seq must apply", case.name);

        // heal: the recovered store accepts writes that survive another
        // crash-free reopen
        rec.update(0, 0, 1.0).expect(case.name);
        let want = before + 2;
        drop(rec);
        let re = DurableStore::open_opts(&dir, cfg.clone(), opts)
            .unwrap_or_else(|e| panic!("{}: reopen after heal failed: {e}", case.name));
        assert_eq!(re.stats().updates, want, "{}: post-recovery writes lost on reopen", case.name);
        drop(re);
        let _ = fs::remove_dir_all(&dir);
    }
}

#[test]
fn torn_wal_tail_is_dropped_at_every_byte_offset() {
    let _g = serial();
    faults::reset();
    let cfg = faults::crash_config();
    let dir = tmpdir("torn_tail");
    let wal = dir.join("wal.bin");
    let snap = dir.join("snapshot.bin");
    let mut len5 = 0u64;
    {
        let store = DurableStore::open(&dir, cfg.clone()).expect("open");
        for k in 0..6usize {
            store.update(k, k, (k + 1) as f64).expect("update");
            if k == 4 {
                len5 = fs::metadata(&wal).expect("wal metadata").len();
            }
        }
    }
    let wal_bytes = fs::read(&wal).expect("reading pristine wal");
    let snap_bytes = fs::read(&snap).expect("reading pristine snapshot");
    assert!(len5 > 0 && (len5 as usize) < wal_bytes.len(), "need a final frame to truncate");

    let five: Vec<CrashOp> =
        (0..5usize).map(|k| CrashOp::Update { i: k, j: k, w: (k + 1) as f64 }).collect();
    let six: Vec<CrashOp> =
        (0..6usize).map(|k| CrashOp::Update { i: k, j: k, w: (k + 1) as f64 }).collect();
    let shadow5 = replay_shadow(&cfg, &five);
    let shadow6 = replay_shadow(&cfg, &six);

    // every cut inside the final frame (header, CRC, payload — all of
    // it) must recover exactly the first five updates; the uncut
    // control recovers all six. Reopening heals (fresh snapshot + WAL),
    // so both files are restored from pristine bytes each round.
    for cut in (len5 as usize)..=wal_bytes.len() {
        fs::write(&snap, &snap_bytes).expect("restoring snapshot");
        fs::write(&wal, &wal_bytes[..cut]).expect("truncating wal");
        let store = DurableStore::open(&dir, cfg.clone()).expect("recovery open");
        let want = if cut == wal_bytes.len() { &shadow6 } else { &shadow5 };
        assert_same_universe(&store, want, &cfg, &format!("cut at byte {cut}"));
    }
    let _ = fs::remove_dir_all(&dir);
}

#[cfg(debug_assertions)]
#[test]
fn snapshot_rename_failure_rolls_back_and_the_store_keeps_serving() {
    let _g = serial();
    faults::reset();
    let cfg = faults::crash_config();
    let dir = tmpdir("snap_rename");
    let store = DurableStore::open(&dir, cfg.clone()).expect("open");
    store.update(1, 1, 2.0).expect("update");
    faults::arm("snap.rename", FaultAction::Error, 1);
    let err = store.snapshot().expect_err("snapshot must fail at the rename");
    assert!(format!("{err:#}").contains("injected fault"), "{err:#}");
    faults::reset();
    // nothing was installed: the old snapshot + WAL pair still matches,
    // so the store keeps accepting writes and a later snapshot succeeds
    assert!(store.wal_healthy(), "a rolled-back snapshot must not fail-stop writes");
    store.update(2, 2, 3.0).expect("write after rolled-back snapshot");
    store.snapshot().expect("snapshot after the fault is disarmed");
    drop(store);
    let re = DurableStore::open(&dir, cfg).expect("reopen");
    assert_eq!(re.stats().updates, 2);
    let _ = fs::remove_dir_all(&dir);
}

#[cfg(debug_assertions)]
#[test]
fn wal_rotation_failure_fail_stops_writes_and_heals_on_reopen() {
    let _g = serial();
    faults::reset();
    let cfg = faults::crash_config();
    let dir = tmpdir("wal_rotate");
    let store = DurableStore::open(&dir, cfg.clone()).expect("open");
    store.update(1, 1, 2.0).expect("update");
    faults::arm("wal.create.rename", FaultAction::Error, 1);
    let err = store.snapshot().expect_err("rotation must fail at the WAL rename");
    assert!(format!("{err:#}").contains("fail-stopping"), "{err:#}");
    faults::reset();
    // snapshot g+1 is installed but the live WAL is gone: writes must
    // fail-stop (appending to the stale log would be silently lost),
    // while reads keep working off the in-memory store
    assert!(!store.wal_healthy(), "failed rotation must fail-stop the log");
    assert!(store.update(2, 2, 1.0).is_err(), "writes must be refused after fail-stop");
    assert_eq!(store.point_query(1, 1).to_bits(), 2.0f64.to_bits(), "reads must keep working");
    drop(store);
    let re = DurableStore::open(&dir, cfg).expect("reopen heals");
    assert!(re.wal_healthy());
    assert_eq!(re.stats().updates, 1, "the pre-rotation write lives in the installed snapshot");
    re.update(2, 2, 1.0).expect("writes work again after healing");
    assert_eq!(re.stats().updates, 2);
    let _ = fs::remove_dir_all(&dir);
}

#[cfg(debug_assertions)]
#[test]
fn snapshot_dirsync_failure_fail_stops_in_fsync_mode() {
    let _g = serial();
    faults::reset();
    let cfg = faults::crash_config();
    let dir = tmpdir("snap_dirsync");
    let store = DurableStore::open_with(&dir, cfg.clone(), true).expect("open");
    store.update(1, 1, 4.0).expect("update");
    faults::arm("snap.dirsync", FaultAction::Error, 1);
    let err = store.snapshot().expect_err("snapshot must fail at the dir sync");
    assert!(format!("{err:#}").contains("fail-stopping"), "{err:#}");
    faults::reset();
    // the rename is installed but its durability is in doubt next to a
    // stale-generation WAL — same fail-stop contract as a failed
    // rotation
    assert!(!store.wal_healthy());
    assert!(store.update(2, 2, 1.0).is_err());
    drop(store);
    let re = DurableStore::open_with(&dir, cfg, true).expect("reopen heals");
    assert!(re.wal_healthy());
    assert_eq!(re.stats().updates, 1);
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn sender_crash_mid_stream_resumes_from_durable_cursors_and_converges() {
    let _g = serial();
    faults::reset();
    if !cfg!(debug_assertions) {
        eprintln!("skipping: failpoints compile out of release builds");
        return;
    }
    const STREAM: usize = 300;
    const STREAM_SEED: u64 = 909;
    let cfg = faults::crash_config();
    let receiver = match StoreServer::start(StoreServerConfig {
        addr: "127.0.0.1:0".to_string(),
        store: cfg.clone(),
        ..Default::default()
    }) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("skipping: cannot bind loopback ({e})");
            return;
        }
    };
    let addr = receiver.local_addr().to_string();
    let dir = tmpdir("sender_crash");
    let ops = faults::crash_workload(&cfg, STREAM, STREAM_SEED);

    // run 1: paced writes shipping to the receiver; the replicator's
    // socket send aborts the whole process on its 6th ship — a sender
    // crash mid-stream with acknowledged-but-partially-shipped state
    let out1 = Child {
        ops: STREAM,
        seed: STREAM_SEED,
        op_delay_us: 1_000,
        fault: Some("repl.send=abort@6"),
        peer: Some(addr.as_str()),
        ..Default::default()
    }
    .run(&dir);
    assert!(
        !out1.status.success(),
        "sender should abort at its 6th ship\nstderr: {}",
        String::from_utf8_lossy(&out1.stderr)
    );
    let m1 = {
        let s = DurableStore::open(&dir, cfg.clone()).expect("recovering crashed sender");
        recovered_prefix(&ops, s.stats().updates)
            .expect("crashed sender recovered to a non-prefix state")
    };
    assert!(m1 < STREAM, "fault fired too late — the whole stream already ran (m1 = {m1})");

    // run 2: resume the same workload at the recovered prefix with no
    // fault armed. The child re-derives its durable origin id and
    // per-peer cursor, full-ships the recovered-but-unshipped
    // remainder, streams the rest, and exits only once its durable
    // cursor covers the whole origin stream.
    let out2 = Child {
        ops: STREAM - m1,
        start: m1,
        seed: STREAM_SEED,
        peer: Some(addr.as_str()),
        ..Default::default()
    }
    .run(&dir);
    assert!(
        out2.status.success(),
        "resumed sender failed\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out2.stdout),
        String::from_utf8_lossy(&out2.stderr)
    );

    // the receiver must now hold exactly the 300-op stream: nothing
    // lost across the crash, nothing double-applied across the resume
    let shadow = replay_shadow(&cfg, &ops);
    assert_same_universe(receiver.store(), &shadow, &cfg, "receiver after crash + resume");
    drop(receiver);
    let _ = fs::remove_dir_all(&dir);
}
