//! Seeded pseudo-random number generation.
//!
//! The offline crate set does not include `rand`, so we carry our own
//! small, well-tested generators:
//!
//! - [`SplitMix64`] — the standard 64-bit mixer; used for seeding and for
//!   cheap stateless stream splitting.
//! - [`Pcg64`] — PCG-XSL-RR 128/64, the workhorse generator. Long period
//!   (2^128), passes BigCrush, and is fast enough that it never shows up
//!   in sketch-path profiles.
//!
//! Distributions: uniform `[0,1)`, uniform integer ranges (via Lemire's
//! unbiased multiply-shift rejection), standard normal (Box–Muller with
//! cached spare), and a few convenience fillers for tensors.

/// SplitMix64: tiny, stateless-splittable generator (Steele et al. 2014).
///
/// Primarily used to expand a single `u64` seed into the larger state of
/// [`Pcg64`] and to derive independent per-mode hash seeds.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSL-RR 128/64 (O'Neill 2014). 128-bit LCG state, 64-bit output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// Cached spare normal deviate from Box–Muller.
    spare_normal: Option<f64>,
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    /// Construct from a 64-bit seed, expanding state via SplitMix64.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s0 = sm.next_u64() as u128;
        let s1 = sm.next_u64() as u128;
        let i0 = sm.next_u64() as u128;
        let i1 = sm.next_u64() as u128;
        let mut rng = Self {
            state: (s0 << 64) | s1,
            // stream selector must be odd
            inc: ((i0 << 64) | i1) | 1,
            spare_normal: None,
        };
        // burn a few to decorrelate low-entropy seeds
        for _ in 0..4 {
            rng.next_u64();
        }
        rng
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn split(&mut self) -> Pcg64 {
        Pcg64::new(self.next_u64())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Unbiased uniform integer in `[0, bound)` (Lemire's method).
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "gen_range bound must be positive");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal deviate (Box–Muller, spare cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Random sign in {-1.0, +1.0}.
    #[inline]
    pub fn sign(&mut self) -> f64 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Fill a fresh Vec with iid standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Fill a fresh Vec with iid uniforms in `[lo, hi)`.
    pub fn uniform_vec(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.uniform_in(lo, hi)).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn pcg_deterministic_and_seed_sensitive() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(1);
        let mut c = Pcg64::new(2);
        let xs: Vec<u64> = (0..32).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..32).map(|_| b.next_u64()).collect();
        let zs: Vec<u64> = (0..32).map(|_| c.next_u64()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

    #[test]
    fn gen_range_stays_in_bounds_and_covers() {
        let mut rng = Pcg64::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.gen_range(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets should be hit");
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut rng = Pcg64::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn sign_is_balanced() {
        let mut rng = Pcg64::new(5);
        let sum: f64 = (0..10_000).map(|_| rng.sign()).sum();
        assert!(sum.abs() < 300.0, "sum={sum}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(xs, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn split_streams_differ() {
        let mut root = Pcg64::new(1234);
        let mut a = root.split();
        let mut b = root.split();
        let xs: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
