//! End-to-end training driver for the paper's §4.3 experiment: train the
//! conv + (sketched) tensor-regression-layer models through the AOT
//! `train_step` artifacts — the Rust binary drives every step; Python
//! was only involved at build time.

pub mod data;
pub mod trainer;

pub use data::SyntheticImages;
pub use trainer::{TrainHistory, Trainer};
