//! Synthetic image-classification corpus — the CIFAR-10 stand-in.
//!
//! The paper's Fig. 10/12 experiment measures how much accuracy a
//! *sketched* TRL head loses relative to the exact TRL and FC heads.
//! What that comparison needs from the data is (a) multi-class image
//! structure whose discriminative signal lives in *spatially low-rank*
//! activation patterns (that is what a Tucker-form regression weight
//! models), and (b) enough noise that generalization is non-trivial.
//!
//! Each class k gets a fixed template built from a few outer-product
//! (rank-1) spatial patterns per channel plus a class-colored quadrant;
//! samples are `α·template + σ·noise` with random per-sample contrast α.
//! See DESIGN.md §Substitutions.

use crate::rng::Pcg64;

pub const H: usize = 32;
pub const W: usize = 32;
pub const C: usize = 3;
pub const NUM_CLASSES: usize = 10;

/// Deterministic synthetic dataset; train and test draw from the same
/// class templates but disjoint RNG streams.
pub struct SyntheticImages {
    /// class templates, each H×W×C (row-major, channel-last)
    templates: Vec<Vec<f32>>,
    /// per-sample noise level
    pub noise: f32,
    rng: Pcg64,
}

impl SyntheticImages {
    /// `stream`: 0 = train, 1 = test (disjoint sample streams, shared
    /// templates derived from `seed`).
    pub fn new(seed: u64, stream: u64, noise: f32) -> Self {
        let mut trng = Pcg64::new(seed); // template rng: shared
        let templates = (0..NUM_CLASSES).map(|k| Self::make_template(k, &mut trng)).collect();
        Self {
            templates,
            noise,
            rng: Pcg64::new(seed ^ (0xABCD_EF00 + stream * 0x1234_5678_9ABC)),
        }
    }

    fn make_template(class: usize, rng: &mut Pcg64) -> Vec<f32> {
        let mut t = vec![0.0f32; H * W * C];
        // rank-2 spatial pattern per channel
        for ch in 0..C {
            for _ in 0..2 {
                let u: Vec<f32> = (0..H).map(|_| rng.normal() as f32).collect();
                let v: Vec<f32> = (0..W).map(|_| rng.normal() as f32).collect();
                for i in 0..H {
                    for j in 0..W {
                        t[(i * W + j) * C + ch] += 0.6 * u[i] * v[j];
                    }
                }
            }
        }
        // weak class-colored quadrant cue: kept small so the task is not
        // linearly trivial — most of the class signal lives in the
        // rank-2 spatial patterns above, which is exactly what a
        // (sketched) Tucker regression weight has to capture
        let qi = (class / 4) % 2;
        let qj = (class / 2) % 2;
        let ch = class % C;
        for i in qi * (H / 2)..qi * (H / 2) + H / 2 {
            for j in qj * (W / 2)..qj * (W / 2) + W / 2 {
                t[(i * W + j) * C + ch] += 0.5;
            }
        }
        t
    }

    /// Sample a batch: returns (images `[b, H, W, C]` flat, labels).
    pub fn batch(&mut self, b: usize) -> (Vec<f32>, Vec<i32>) {
        let mut xs = Vec::with_capacity(b * H * W * C);
        let mut ys = Vec::with_capacity(b);
        for _ in 0..b {
            let k = self.rng.gen_range(NUM_CLASSES as u64) as usize;
            ys.push(k as i32);
            let alpha = 0.7 + 0.6 * self.rng.uniform() as f32;
            let tpl = &self.templates[k];
            for &tv in tpl.iter() {
                xs.push(alpha * tv + self.noise * self.rng.normal() as f32);
            }
        }
        (xs, ys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes_and_label_range() {
        let mut ds = SyntheticImages::new(1, 0, 0.5);
        let (xs, ys) = ds.batch(16);
        assert_eq!(xs.len(), 16 * H * W * C);
        assert_eq!(ys.len(), 16);
        assert!(ys.iter().all(|&y| (0..NUM_CLASSES as i32).contains(&y)));
    }

    #[test]
    fn train_and_test_streams_differ_but_share_templates() {
        let mut train = SyntheticImages::new(7, 0, 0.0);
        let mut test = SyntheticImages::new(7, 1, 0.0);
        let (xa, _) = train.batch(4);
        let (xb, _) = test.batch(4);
        assert_ne!(xa, xb, "streams should draw different samples");
        // with zero noise, samples of the same class from either stream
        // are collinear with the shared template: correlation of two
        // same-class samples ≈ 1
        let mut a = SyntheticImages::new(9, 0, 0.0);
        let (xs, ys) = a.batch(64);
        let mut by_class: std::collections::HashMap<i32, Vec<usize>> = Default::default();
        for (i, &y) in ys.iter().enumerate() {
            by_class.entry(y).or_default().push(i);
        }
        for (_, idxs) in by_class {
            if idxs.len() < 2 {
                continue;
            }
            let n = H * W * C;
            let s1 = &xs[idxs[0] * n..(idxs[0] + 1) * n];
            let s2 = &xs[idxs[1] * n..(idxs[1] + 1) * n];
            let v1: Vec<f64> = s1.iter().map(|&v| v as f64).collect();
            let v2: Vec<f64> = s2.iter().map(|&v| v as f64).collect();
            let corr = crate::util::stats::correlation(&v1, &v2);
            assert!(corr > 0.99, "same-class zero-noise corr={corr}");
        }
    }

    #[test]
    fn classes_are_distinguishable() {
        // different-class templates should have low correlation
        let mut ds = SyntheticImages::new(3, 0, 0.0);
        let (xs, ys) = ds.batch(64);
        let n = H * W * C;
        let mut found = 0;
        for i in 0..ys.len() {
            for j in i + 1..ys.len() {
                if ys[i] != ys[j] {
                    let v1: Vec<f64> = xs[i * n..(i + 1) * n].iter().map(|&v| v as f64).collect();
                    let v2: Vec<f64> = xs[j * n..(j + 1) * n].iter().map(|&v| v as f64).collect();
                    let corr = crate::util::stats::correlation(&v1, &v2).abs();
                    assert!(corr < 0.9, "cross-class corr={corr}");
                    found += 1;
                    if found > 10 {
                        return;
                    }
                }
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = SyntheticImages::new(5, 0, 0.3);
        let mut b = SyntheticImages::new(5, 0, 0.3);
        assert_eq!(a.batch(8), b.batch(8));
    }
}
