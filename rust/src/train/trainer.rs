//! The training loop: Rust drives the AOT `train_step`/`eval_step`
//! executables step by step; parameters and momenta live as PJRT
//! literals between steps.

use super::data::SyntheticImages;
use crate::runtime::client::{literal_f32, literal_i32, literal_scalar_value, literal_to_f32};
use crate::runtime::xla_stub as xla;
use crate::runtime::Runtime;
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::rc::Rc;

/// Loss/accuracy history of one run (written to EXPERIMENTS.md / JSON).
#[derive(Clone, Debug, Default)]
pub struct TrainHistory {
    pub model: String,
    pub steps: Vec<usize>,
    pub train_loss: Vec<f64>,
    pub train_acc: Vec<f64>,
    pub test_loss: Vec<f64>,
    pub test_acc: Vec<f64>,
    pub head_param_count: usize,
    pub wall_secs: f64,
}

impl TrainHistory {
    pub fn final_test_acc(&self) -> f64 {
        self.test_acc.last().copied().unwrap_or(0.0)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("steps", Json::arr_usize(&self.steps)),
            ("train_loss", Json::arr_f64(&self.train_loss)),
            ("train_acc", Json::arr_f64(&self.train_acc)),
            ("test_loss", Json::arr_f64(&self.test_loss)),
            ("test_acc", Json::arr_f64(&self.test_acc)),
            ("head_param_count", Json::Num(self.head_param_count as f64)),
            ("wall_secs", Json::Num(self.wall_secs)),
        ])
    }
}

/// Trainer for one model variant.
pub struct Trainer<'rt> {
    rt: &'rt Runtime,
    pub model: String,
    train_exe: Rc<xla::PjRtLoadedExecutable>,
    eval_exe: Rc<xla::PjRtLoadedExecutable>,
    /// current parameters (+ shapes from the schema)
    params: Vec<xla::Literal>,
    momenta: Vec<xla::Literal>,
    batch: usize,
    img: Vec<usize>,
    n_params: usize,
    pub head_param_count: usize,
}

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt Runtime, model: &str) -> Result<Self> {
        let entry = rt
            .manifest()
            .models
            .get(model)
            .ok_or_else(|| anyhow!("unknown model {model:?} (see `hocs info`)"))?
            .clone();
        let train_exe = rt.load(&entry.train)?;
        let eval_exe = rt.load(&entry.eval)?;
        let init = rt.manifest().load_init_params(model)?;
        let mut params = Vec::with_capacity(init.len());
        let mut momenta = Vec::with_capacity(init.len());
        for (buf, spec) in init.iter().zip(entry.param_schema.iter()) {
            params.push(literal_f32(buf, &spec.shape)?);
            momenta.push(literal_f32(&vec![0.0; buf.len()], &spec.shape)?);
        }
        Ok(Self {
            rt,
            model: model.to_string(),
            train_exe,
            eval_exe,
            params,
            momenta,
            batch: entry.batch,
            img: entry.img.clone(),
            n_params: entry.param_schema.len(),
            head_param_count: entry.head_param_count,
        })
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// One SGD step; returns (loss, acc) on the batch.
    pub fn step(&mut self, x: &[f32], y: &[i32], lr: f32) -> Result<(f64, f64)> {
        let mut img_dims = vec![self.batch];
        img_dims.extend_from_slice(&self.img);
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(2 * self.n_params + 3);
        // params and momenta are moved in; train_step returns updates
        inputs.append(&mut self.params);
        inputs.append(&mut self.momenta);
        inputs.push(literal_f32(x, &img_dims)?);
        inputs.push(literal_i32(y, &[self.batch])?);
        inputs.push(xla::Literal::scalar(lr));
        let mut out = self.rt.execute_loaded(&self.train_exe, &inputs)?;
        anyhow::ensure!(
            out.len() == 2 * self.n_params + 2,
            "train_step returned {} outputs",
            out.len()
        );
        let acc = literal_scalar_value(&out.pop().unwrap())? as f64;
        let loss = literal_scalar_value(&out.pop().unwrap())? as f64;
        self.momenta = out.split_off(self.n_params);
        self.params = out;
        Ok((loss, acc))
    }

    /// Evaluate on `n_batches` fresh test batches; returns (loss, acc).
    pub fn evaluate(&self, ds: &mut SyntheticImages, n_batches: usize) -> Result<(f64, f64)> {
        let mut img_dims = vec![self.batch];
        img_dims.extend_from_slice(&self.img);
        let mut loss_sum = 0.0;
        let mut acc_sum = 0.0;
        for _ in 0..n_batches {
            let (x, y) = ds.batch(self.batch);
            let mut inputs: Vec<xla::Literal> = Vec::with_capacity(self.n_params + 2);
            for p in &self.params {
                // Literal has no cheap clone; round-trip through vec
                inputs.push(clone_literal(p)?);
            }
            inputs.push(literal_f32(&x, &img_dims)?);
            inputs.push(literal_i32(&y, &[self.batch])?);
            let out = self.rt.execute_loaded(&self.eval_exe, &inputs)?;
            loss_sum += literal_scalar_value(&out[0])? as f64;
            acc_sum += literal_scalar_value(&out[1])? as f64;
        }
        Ok((loss_sum / n_batches as f64, acc_sum / n_batches as f64))
    }

    /// Full training run with periodic eval; reproduces one curve of
    /// Fig. 10.
    pub fn train(
        &mut self,
        steps: usize,
        lr: f32,
        eval_every: usize,
        seed: u64,
        quiet: bool,
    ) -> Result<TrainHistory> {
        let mut train_ds = SyntheticImages::new(seed, 0, 1.6);
        let mut test_ds = SyntheticImages::new(seed, 1, 1.6);
        let mut hist = TrainHistory {
            model: self.model.clone(),
            head_param_count: self.head_param_count,
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let mut run_loss = 0.0;
        let mut run_acc = 0.0;
        let mut run_n = 0usize;
        for step in 1..=steps {
            let (x, y) = train_ds.batch(self.batch);
            let (loss, acc) = self.step(&x, &y, lr)?;
            run_loss += loss;
            run_acc += acc;
            run_n += 1;
            if step % eval_every == 0 || step == steps {
                let (tl, ta) = self.evaluate(&mut test_ds, 4)?;
                hist.steps.push(step);
                hist.train_loss.push(run_loss / run_n as f64);
                hist.train_acc.push(run_acc / run_n as f64);
                hist.test_loss.push(tl);
                hist.test_acc.push(ta);
                if !quiet {
                    crate::log_info!(
                        "{} step {step:4}: train loss {:.4} acc {:.3} | test loss {tl:.4} acc {ta:.3}",
                        self.model,
                        run_loss / run_n as f64,
                        run_acc / run_n as f64,
                    );
                }
                run_loss = 0.0;
                run_acc = 0.0;
                run_n = 0;
            }
        }
        hist.wall_secs = t0.elapsed().as_secs_f64();
        Ok(hist)
    }
}

impl<'rt> Trainer<'rt> {
    /// Persist the current parameters as raw little-endian f32 (schema
    /// order) — `results/trained_<model>.bin`, which the serving
    /// backend picks up automatically.
    pub fn save_params(&self, dir: &str) -> Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = std::path::Path::new(dir).join(format!("trained_{}.bin", self.model));
        let mut bytes = Vec::new();
        for p in &self.params {
            for v in literal_to_f32(p)? {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
        }
        std::fs::write(&path, bytes)?;
        Ok(path)
    }
}

/// Load a raw f32 parameter file against a model's schema (the format
/// [`Trainer::save_params`] writes and aot.py's init files use).
pub fn load_param_file(
    path: &std::path::Path,
    entry: &crate::runtime::ModelEntry,
) -> Result<Vec<Vec<f32>>> {
    let raw = std::fs::read(path)?;
    anyhow::ensure!(
        raw.len() == entry.param_len() * 4,
        "param file {path:?} has {} bytes, schema wants {}",
        raw.len(),
        entry.param_len() * 4
    );
    let mut out = Vec::with_capacity(entry.param_schema.len());
    let mut off = 0usize;
    for spec in &entry.param_schema {
        let n = spec.len();
        let buf = raw[off * 4..(off + n) * 4]
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        off += n;
        out.push(buf);
    }
    Ok(out)
}

/// Deep-copy a literal (xla::Literal lacks Clone).
fn clone_literal(lit: &xla::Literal) -> Result<xla::Literal> {
    let shape = lit.array_shape().map_err(|e| anyhow!("{e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = literal_to_f32(lit)?;
    literal_f32(&data, &dims)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn short_training_run_reduces_loss() {
        if !crate::runtime::artifacts_available(crate::runtime::DEFAULT_ARTIFACTS_DIR) {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::new(crate::runtime::DEFAULT_ARTIFACTS_DIR).unwrap();
        let mut tr = Trainer::new(&rt, "trl_mts_4x4x8").unwrap();
        let hist = tr.train(12, 0.03, 6, 42, true).unwrap();
        assert_eq!(hist.steps.len(), 2);
        let first = hist.train_loss[0];
        let last = *hist.train_loss.last().unwrap();
        assert!(
            last < first,
            "loss should fall over 12 steps: {first} -> {last}"
        );
    }

    #[test]
    fn unknown_model_is_an_error() {
        if !crate::runtime::artifacts_available(crate::runtime::DEFAULT_ARTIFACTS_DIR) {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::new(crate::runtime::DEFAULT_ARTIFACTS_DIR).unwrap();
        assert!(Trainer::new(&rt, "nope").is_err());
    }
}
