//! Seeded universal hash families for sketching.
//!
//! Count sketch needs, per mode, a pair of functions
//! `h : [n] → [m]` (2-universal bucket hash) and `s : [n] → {±1}`
//! (4-universal sign hash — 4-wise independence is what makes the
//! variance analysis of Charikar et al. go through; 2-wise suffices for
//! unbiasedness).
//!
//! Two interchangeable implementations:
//!
//! - [`MultiplyShiftHash`] — strongly-universal multiply-shift
//!   (Dietzfelbinger). O(1) evaluation, no tables; the default on the
//!   hot path.
//! - [`TabulationHash`] — simple tabulation over 8-bit characters.
//!   3-independent and behaves like full randomness for count-sketch
//!   style applications (Pătraşcu–Thorup); used in tests as a
//!   cross-check family.
//!
//! [`ModeHash`] bundles `(h, s)` for one tensor mode and is the unit the
//! sketch layer consumes; [`HashSeeds`] derives per-mode seeds from a
//! single experiment seed so every sketch is exactly reproducible.

use crate::rng::SplitMix64;

/// A bucket hash `[n] → [m]` plus sign hash `[n] → {±1}` for one mode.
#[derive(Clone, Debug)]
pub struct ModeHash {
    /// input dimension n (indices in `[0, n)`)
    pub n: usize,
    /// output dimension m (buckets in `[0, m)`)
    pub m: usize,
    bucket: MultiplyShiftHash,
    sign: MultiplyShiftHash,
    /// strength-reduced `% m` (precomputed once; the batch kernels
    /// evaluate it millions of times per second)
    red: ModReduce,
}

impl ModeHash {
    /// Build a mode hash for `[n] → [m]` from a seed.
    pub fn new(n: usize, m: usize, seed: u64) -> Self {
        assert!(n > 0 && m > 0, "ModeHash dims must be positive (n={n}, m={m})");
        let mut sm = SplitMix64::new(seed ^ 0xA5A5_5A5A_DEAD_BEEF);
        let bucket = MultiplyShiftHash::new(&mut sm);
        let sign = MultiplyShiftHash::new(&mut sm);
        Self { n, m, bucket, sign, red: ModReduce::new(m as u64) }
    }

    /// Bucket for index `i`.
    ///
    /// Straight-line reference: a hardware divide per call. The fused
    /// batch kernels ([`crate::sketch::kernel`]) use [`ModeHash::h_fast`]
    /// instead; this form is kept verbatim as the scalar oracle the
    /// kernels' bit-identity tests (and the bench baseline) compare
    /// against.
    #[inline]
    pub fn h(&self, i: usize) -> usize {
        debug_assert!(i < self.n);
        (self.bucket.eval(i as u64) % self.m as u64) as usize
    }

    /// Sign for index `i`.
    #[inline]
    pub fn s(&self, i: usize) -> f64 {
        if self.sign.eval(i as u64) & (1 << 62) == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// [`ModeHash::h`] through the precomputed [`ModReduce`] — the same
    /// bucket for every index (property-tested), without the hardware
    /// divide. Hot paths that cannot batch (single-item fan-out) call
    /// this directly; the batch kernels inline the same reduction.
    #[inline]
    pub fn h_fast(&self, i: usize) -> usize {
        debug_assert!(i < self.n);
        self.red.reduce(self.bucket.eval(i as u64)) as usize
    }

    /// Sign *bit* for index `i`: `0` ↦ `+1.0`, `1` ↦ `−1.0`, i.e.
    /// `s(i) == f64::from_bits(f64::to_bits(1.0) | (s_bit(i) << 63))`.
    /// The kernels combine mode signs by XOR-ing bits instead of
    /// branching per index.
    #[inline]
    pub fn s_bit(&self, i: usize) -> u64 {
        (self.sign.eval(i as u64) >> 62) & 1
    }

    /// The precomputed reducer for this mode's `% m`.
    #[inline]
    pub(crate) fn reducer(&self) -> ModReduce {
        self.red
    }

    /// The raw multiply-shift bucket hash (kernel hash phase).
    #[inline]
    pub(crate) fn bucket_hash(&self) -> &MultiplyShiftHash {
        &self.bucket
    }

    /// The raw multiply-shift sign hash (kernel hash phase).
    #[inline]
    pub(crate) fn sign_hash(&self) -> &MultiplyShiftHash {
        &self.sign
    }

    /// Materialize the bucket map as a `Vec` (hot-path friendly).
    pub fn bucket_table(&self) -> Vec<u32> {
        (0..self.n).map(|i| self.h(i) as u32).collect()
    }

    /// Materialize the sign map as a `Vec`.
    pub fn sign_table(&self) -> Vec<f64> {
        (0..self.n).map(|i| self.s(i)).collect()
    }

    /// The hash matrix H ∈ {0,1}^{n×m} with H[a, h(a)] = 1 (row-major).
    pub fn hash_matrix(&self) -> Vec<f64> {
        let mut hm = vec![0.0; self.n * self.m];
        for a in 0..self.n {
            hm[a * self.m + self.h(a)] = 1.0;
        }
        hm
    }
}

/// Strongly-universal multiply-shift hash over u64 keys.
///
/// `eval(x) = hi_bits((a*x + b) mod 2^128)`; `a` odd. Returns a 63-bit
/// value; callers reduce mod m (bucket) or take a high bit (sign).
#[derive(Clone, Debug)]
pub struct MultiplyShiftHash {
    a: u128,
    b: u128,
}

impl MultiplyShiftHash {
    pub fn new(sm: &mut SplitMix64) -> Self {
        let a = ((sm.next_u64() as u128) << 64 | sm.next_u64() as u128) | 1;
        let b = (sm.next_u64() as u128) << 64 | sm.next_u64() as u128;
        Self { a, b }
    }

    #[inline]
    pub fn eval(&self, x: u64) -> u64 {
        let v = self.a.wrapping_mul(x as u128).wrapping_add(self.b);
        (v >> 65) as u64 // top 63 bits
    }

    /// `(a_lo, a_hi, b_lo, b_hi)` — the 64-bit limbs of the hash
    /// constants, for the lane kernels (which track only the high limb
    /// of `a·x + b` plus the low limb's carry).
    #[inline]
    pub(crate) fn limbs(&self) -> (u64, u64, u64, u64) {
        (self.a as u64, (self.a >> 64) as u64, self.b as u64, (self.b >> 64) as u64)
    }
}

/// Exact strength reduction of `x % m` for the 63-bit values
/// [`MultiplyShiftHash::eval`] produces.
///
/// Power-of-two moduli become a mask. Everything else goes through a
/// Granlund–Montgomery style reciprocal `M = ⌊2^127 / m⌋ + 1`:
/// `⌊M·x / 2^127⌋ = ⌊x / m⌋` exactly for all `x < 2^63`, because the
/// reciprocal's rounding error contributes at most `m·x / (m·2^127) =
/// x/2^127 < 2^-64`, strictly below the `1/m` gap to the next integer
/// (any `m < 2^64`). Two 64×64→128 multiplies replace a hardware
/// divide — the single most expensive instruction on the old hash walk.
#[derive(Clone, Copy, Debug)]
pub(crate) enum ModReduce {
    /// `m` is a power of two: reduce with `x & (m − 1)`.
    Mask(u64),
    /// General `m`: `(m, M_hi, M_lo)` with `M = ⌊2^127/m⌋ + 1`.
    Magic { m: u64, m_hi: u64, m_lo: u64 },
}

impl ModReduce {
    pub(crate) fn new(m: u64) -> Self {
        assert!(m > 0, "modulus must be positive");
        if m.is_power_of_two() {
            ModReduce::Mask(m - 1)
        } else {
            // m ≥ 3 here (1 and 2 are powers of two), so M < 2^126
            let recip = (1u128 << 127) / m as u128 + 1;
            ModReduce::Magic { m, m_hi: (recip >> 64) as u64, m_lo: recip as u64 }
        }
    }

    /// `x % m`; exact for `x < 2^63` (debug-asserted).
    #[inline]
    pub(crate) fn reduce(self, x: u64) -> u64 {
        debug_assert!(x < 1 << 63);
        match self {
            ModReduce::Mask(mask) => x & mask,
            ModReduce::Magic { m, m_hi, m_lo } => {
                // q = ⌊M·x / 2^127⌋ via the high limbs of a 128×64 product
                let t = ((m_lo as u128) * (x as u128)) >> 64;
                let q = (((m_hi as u128) * (x as u128) + t) >> 63) as u64;
                x - q * m
            }
        }
    }

    /// The mask when `m` is a power of two (the AVX2 hash phase only
    /// handles mask reducers; magic moduli fall back to the portable
    /// lanes).
    #[inline]
    pub(crate) fn pow2_mask(self) -> Option<u64> {
        match self {
            ModReduce::Mask(mask) => Some(mask),
            ModReduce::Magic { .. } => None,
        }
    }
}

/// Simple tabulation hashing: split the key into 8 bytes, XOR per-byte
/// random tables. 3-independent; excellent distribution in practice.
#[derive(Clone)]
pub struct TabulationHash {
    tables: Box<[[u64; 256]; 8]>,
}

impl TabulationHash {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut tables = Box::new([[0u64; 256]; 8]);
        for t in tables.iter_mut() {
            for e in t.iter_mut() {
                *e = sm.next_u64();
            }
        }
        Self { tables }
    }

    #[inline]
    pub fn eval(&self, x: u64) -> u64 {
        let mut h = 0u64;
        let bytes = x.to_le_bytes();
        for (i, &b) in bytes.iter().enumerate() {
            h ^= self.tables[i][b as usize];
        }
        h
    }
}

impl std::fmt::Debug for TabulationHash {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TabulationHash").finish_non_exhaustive()
    }
}

/// Derives independent per-mode / per-repeat seeds from one root seed.
///
/// Layout: `seed_for(repeat, mode)` must be unique per (repeat, mode)
/// pair and stable across runs — benchmarks and tests rely on exact
/// reproducibility of sketches.
#[derive(Clone, Copy, Debug)]
pub struct HashSeeds {
    root: u64,
}

impl HashSeeds {
    pub fn new(root: u64) -> Self {
        Self { root }
    }

    /// Seed for sketch repeat `d` (median-of-d estimation), mode `k`.
    pub fn seed_for(&self, repeat: usize, mode: usize) -> u64 {
        let mut sm = SplitMix64::new(self.root);
        // mix in coordinates through two rounds so nearby (d, k) decorrelate
        let x = sm
            .next_u64()
            .wrapping_add((repeat as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .wrapping_add((mode as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F));
        let mut sm2 = SplitMix64::new(x);
        sm2.next_u64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_hash_in_range() {
        let mh = ModeHash::new(1000, 37, 42);
        for i in 0..1000 {
            assert!(mh.h(i) < 37);
            let s = mh.s(i);
            assert!(s == 1.0 || s == -1.0);
        }
    }

    #[test]
    fn mode_hash_deterministic() {
        let a = ModeHash::new(100, 10, 7);
        let b = ModeHash::new(100, 10, 7);
        for i in 0..100 {
            assert_eq!(a.h(i), b.h(i));
            assert_eq!(a.s(i), b.s(i));
        }
    }

    #[test]
    fn mode_hash_seed_sensitivity() {
        let a = ModeHash::new(200, 16, 1);
        let b = ModeHash::new(200, 16, 2);
        let same = (0..200).filter(|&i| a.h(i) == b.h(i)).count();
        // collisions by chance ≈ 200/16 ± noise; identical would be 200
        assert!(same < 60, "hashes look identical across seeds: {same}");
    }

    #[test]
    fn buckets_are_roughly_uniform() {
        let m = 16;
        let n = 16_000;
        let mh = ModeHash::new(n, m, 3);
        let mut counts = vec![0usize; m];
        for i in 0..n {
            counts[mh.h(i)] += 1;
        }
        let expect = n / m;
        for (b, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64 - expect as f64).abs() < expect as f64 * 0.25,
                "bucket {b} count {c} far from {expect}"
            );
        }
    }

    #[test]
    fn signs_are_roughly_balanced() {
        let mh = ModeHash::new(10_000, 4, 13);
        let sum: f64 = (0..10_000).map(|i| mh.s(i)).sum();
        assert!(sum.abs() < 300.0, "sign sum={sum}");
    }

    #[test]
    fn pairwise_independence_empirical() {
        // For random pairs (i, j), P[h(i)=h(j)] should be ≈ 1/m.
        let m = 32;
        let mh = ModeHash::new(100_000, m, 99);
        let mut coll = 0usize;
        let trials = 20_000;
        let mut sm = SplitMix64::new(5);
        for _ in 0..trials {
            let i = (sm.next_u64() % 100_000) as usize;
            let j = (sm.next_u64() % 100_000) as usize;
            if i != j && mh.h(i) == mh.h(j) {
                coll += 1;
            }
        }
        let p = coll as f64 / trials as f64;
        assert!((p - 1.0 / m as f64).abs() < 0.01, "collision prob={p}");
    }

    #[test]
    fn hash_matrix_is_one_hot() {
        let mh = ModeHash::new(20, 5, 8);
        let hm = mh.hash_matrix();
        for a in 0..20 {
            let row = &hm[a * 5..(a + 1) * 5];
            assert_eq!(row.iter().filter(|&&x| x == 1.0).count(), 1);
            assert_eq!(row[mh.h(a)], 1.0);
        }
    }

    #[test]
    fn tabulation_matches_itself_and_spreads() {
        let t = TabulationHash::new(77);
        let a = t.eval(12345);
        assert_eq!(a, t.eval(12345));
        let mut buckets = vec![0usize; 16];
        for i in 0..16_000u64 {
            buckets[(t.eval(i) % 16) as usize] += 1;
        }
        for &c in &buckets {
            assert!((c as i64 - 1000).unsigned_abs() < 250);
        }
    }

    #[test]
    fn mod_reduce_matches_hardware_modulo() {
        // every reducer shape: powers of two (mask), tiny, prime, and
        // near-2^63 magic moduli, against 63-bit inputs of every flavor
        let mut sm = SplitMix64::new(0xFEED);
        let mut moduli = vec![1u64, 2, 3, 4, 5, 7, 10, 12, 16, 37, 63, 64, 65, 1000, 4096];
        moduli.extend([4095, 4097, (1 << 32) - 5, (1 << 48) + 1, (1 << 62) + 3, (1 << 63) - 1]);
        for m in moduli {
            let red = ModReduce::new(m);
            for x in [0u64, 1, m - 1, m % (1 << 63), (1 << 63) - 1] {
                assert_eq!(red.reduce(x), x % m, "m={m} x={x}");
            }
            for _ in 0..2000 {
                let x = sm.next_u64() >> 1; // 63-bit
                assert_eq!(red.reduce(x), x % m, "m={m} x={x}");
            }
        }
    }

    #[test]
    fn h_fast_and_s_bit_match_reference() {
        for (n, m, seed) in [(1000, 37, 42u64), (512, 64, 7), (4096, 12, 99), (64, 1, 3)] {
            let mh = ModeHash::new(n, m, seed);
            for i in 0..n {
                assert_eq!(mh.h_fast(i), mh.h(i), "n={n} m={m} i={i}");
                let s = f64::from_bits(f64::to_bits(1.0) | (mh.s_bit(i) << 63));
                assert_eq!(s.to_bits(), mh.s(i).to_bits(), "n={n} m={m} i={i}");
            }
        }
    }

    #[test]
    fn limbs_reassemble_the_constants() {
        let mut sm = SplitMix64::new(5);
        let h = MultiplyShiftHash::new(&mut sm);
        let (a_lo, a_hi, b_lo, b_hi) = h.limbs();
        let a = (a_hi as u128) << 64 | a_lo as u128;
        let b = (b_hi as u128) << 64 | b_lo as u128;
        assert_eq!(a, h.a);
        assert_eq!(b, h.b);
        assert_eq!(a & 1, 1, "multiply-shift a must be odd");
    }

    #[test]
    fn seeds_unique_per_coordinate() {
        let hs = HashSeeds::new(42);
        let mut seen = std::collections::HashSet::new();
        for d in 0..8 {
            for k in 0..8 {
                assert!(seen.insert(hs.seed_for(d, k)), "duplicate seed at ({d},{k})");
            }
        }
    }
}
