//! Layer-3 coordinator: a sketch *service* in the shape of a vLLM-style
//! router — bounded request queue (backpressure), size-class dynamic
//! batching, a configurable **worker pool** (each worker owns its
//! backend instance — the PJRT runtime is not `Send` — plus its
//! thread-local FFT plan caches), and live metrics with p50/p99
//! latency percentiles.
//!
//! The service exposes the paper's three request-path operations:
//!
//! - `MtsSketch`  — MTS of a matrix (the L1 Pallas artifact)
//! - `CsSketch`   — count sketch of a vector batch
//! - `KronCombine`— sketched-Kronecker combine (Lemma B.1)
//!
//! Two interchangeable backends execute batches: [`backend::XlaBackend`]
//! (the AOT artifacts via PJRT — the production path) and
//! [`backend::PureRustBackend`] (the in-crate sketch algorithms, seeded
//! from the same manifest hash tables so the two are bit-compatible —
//! the parity oracle used in tests and the fallback when artifacts are
//! not built).

pub mod backend;
pub mod metrics;
pub mod server;

pub use backend::{BackendKind, PureRustBackend, SketchBackend};
pub use metrics::Metrics;
pub use server::{default_workers, Coordinator, CoordinatorConfig, Job, JobResult};
