//! The coordinator proper: bounded submission queue → size-class
//! batcher → **worker pool** (each worker owns its backend and FFT plan
//! caches) → per-job response channels.
//!
//! Design notes (vllm-router-like):
//! - the submission queue is a `sync_channel` with fixed capacity;
//!   `try_submit` returns `Err` on overflow — callers see backpressure
//!   instead of unbounded memory growth;
//! - a dedicated batcher thread drains greedily: it blocks for the
//!   first job, then `try_recv`s up to `max_batch - 1` more within
//!   `max_wait`, grouping jobs per op kind (size classes are fixed per
//!   op by the manifest);
//! - whole per-op-kind groups are handed round-robin to
//!   [`CoordinatorConfig::workers`] worker threads. Each worker
//!   constructs its *own* backend (the PJRT client is not `Send`, and
//!   per-thread backends mean per-thread executable caches and
//!   thread-local FFT plan caches) and runs the fused batch kernels
//!   over its group;
//! - per-worker group channels are small and bounded, so a stuck
//!   worker backpressures the batcher instead of queueing unboundedly.

use super::backend::{BackendKind, PureRustBackend, SketchBackend, XlaBackend};
use super::metrics::Metrics;
use anyhow::{anyhow, Result};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A sketch request.
#[derive(Debug)]
pub enum Job {
    /// MTS of one matrix (manifest `mts_sketch` geometry).
    MtsSketch(Vec<f32>),
    /// Count sketch of one vector (manifest `cs_sketch` geometry).
    CsSketch(Vec<f32>),
    /// Combine two MTS sketches into a Kronecker sketch.
    KronCombine(Vec<f32>, Vec<f32>),
    /// Classify one flat image through the serve model (logits out).
    Classify(Vec<f32>),
}

const N_CLASSES: usize = 4;

impl Job {
    fn kind_idx(&self) -> usize {
        match self {
            Job::MtsSketch(_) => 0,
            Job::CsSketch(_) => 1,
            Job::KronCombine(_, _) => 2,
            Job::Classify(_) => 3,
        }
    }
}

/// The result sent back on the per-job channel.
pub type JobResult = Result<Vec<f32>, String>;

struct Envelope {
    job: Job,
    submitted: Instant,
    reply: SyncSender<JobResult>,
}

/// Tuning knobs.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub queue_capacity: usize,
    pub max_batch: usize,
    /// how long the batcher waits to fill a batch after the first job
    pub max_wait: Duration,
    /// number of executor workers, each owning a backend instance.
    /// `None` = auto: available parallelism for the pure-Rust backend,
    /// but 1 for XLA (every worker would construct its own PJRT client
    /// and executable cache — opt into that explicitly).
    pub workers: Option<usize>,
    pub backend: BackendKind,
    pub artifacts_dir: String,
    /// manifest model whose `predict` artifact backs `Job::Classify`
    /// (Xla backend only).
    pub serve_model: Option<String>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            queue_capacity: 1024,
            max_batch: 64,
            max_wait: Duration::from_micros(200),
            workers: None,
            backend: BackendKind::PureRust,
            artifacts_dir: crate::runtime::DEFAULT_ARTIFACTS_DIR.to_string(),
            serve_model: None,
        }
    }
}

/// Available parallelism, clamped to at least one worker.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Client handle to a running coordinator.
pub struct Coordinator {
    tx: Option<SyncSender<Envelope>>,
    metrics: Arc<Metrics>,
    batcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start the batcher and worker pool and return the handle. Backend
    /// construction happens on each worker thread; any failure is
    /// surfaced synchronously here.
    pub fn start(cfg: CoordinatorConfig) -> Result<Self> {
        let n_workers = cfg
            .workers
            .unwrap_or_else(|| match cfg.backend {
                BackendKind::PureRust => default_workers(),
                BackendKind::Xla => 1,
            })
            .max(1);
        let (tx, rx) = sync_channel::<Envelope>(cfg.queue_capacity.max(1));
        let metrics = Arc::new(Metrics::default());
        let (ready_tx, ready_rx) = sync_channel::<Result<(), String>>(n_workers);

        let mut group_txs: Vec<SyncSender<Vec<Envelope>>> = Vec::with_capacity(n_workers);
        let mut workers = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let (gtx, grx) = sync_channel::<Vec<Envelope>>(2);
            group_txs.push(gtx);
            let wcfg = cfg.clone();
            let wmetrics = metrics.clone();
            let wready = ready_tx.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("hocs-worker-{w}"))
                    .spawn(move || worker_loop(w, wcfg, grx, wmetrics, wready))?,
            );
        }
        drop(ready_tx);

        // surface backend construction errors synchronously
        let mut init_err: Option<String> = None;
        for _ in 0..n_workers {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => init_err = Some(e),
                Err(_) => init_err = Some("worker thread died during init".to_string()),
            }
        }
        if let Some(e) = init_err {
            drop(group_txs); // workers drain and exit
            for w in workers {
                let _ = w.join();
            }
            return Err(anyhow!("backend init failed: {e}"));
        }

        let bmetrics = metrics.clone();
        let bcfg = cfg.clone();
        let batcher = std::thread::Builder::new()
            .name("hocs-batcher".into())
            .spawn(move || batcher_loop(bcfg, rx, group_txs, bmetrics))?;
        crate::log_info!("coordinator: {} worker(s) ready", n_workers);
        Ok(Self { tx: Some(tx), metrics, batcher: Some(batcher), workers })
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Submit a job; returns the response receiver. `Err` = queue full
    /// (backpressure) or shut down.
    pub fn try_submit(&self, job: Job) -> Result<Receiver<JobResult>> {
        let (reply, rx) = sync_channel(1);
        let env = Envelope { job, submitted: Instant::now(), reply };
        match self.tx.as_ref().expect("coordinator running").try_send(env) {
            Ok(()) => {
                self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
                Ok(rx)
            }
            Err(TrySendError::Full(_)) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(anyhow!("queue full"))
            }
            Err(TrySendError::Disconnected(_)) => Err(anyhow!("coordinator stopped")),
        }
    }

    /// Submit and wait (convenience for examples / tests).
    pub fn call(&self, job: Job) -> Result<Vec<f32>> {
        let rx = self.try_submit(job)?;
        rx.recv()
            .map_err(|_| anyhow!("executor dropped reply"))?
            .map_err(|e| anyhow!("job failed: {e}"))
    }

    /// Graceful shutdown: close the queue, join the batcher, then the
    /// workers (the batcher drops the group channels on exit).
    pub fn shutdown(mut self) {
        self.join_all();
    }

    fn join_all(&mut self) {
        self.tx.take(); // close channel → batcher drains and exits
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.join_all();
    }
}

fn make_backend(cfg: &CoordinatorConfig) -> Result<Box<dyn SketchBackend>> {
    match cfg.backend {
        BackendKind::Xla => Ok(Box::new(XlaBackend::with_serve_model(
            &cfg.artifacts_dir,
            cfg.serve_model.as_deref(),
        )?)),
        BackendKind::PureRust => {
            let man = crate::runtime::Manifest::load(&cfg.artifacts_dir)?;
            Ok(Box::new(PureRustBackend::new(&man)?))
        }
    }
}

/// Collect size-class batches from the submission queue and hand whole
/// groups to the workers round-robin.
fn batcher_loop(
    cfg: CoordinatorConfig,
    rx: Receiver<Envelope>,
    group_txs: Vec<SyncSender<Vec<Envelope>>>,
    metrics: Arc<Metrics>,
) {
    let n_workers = group_txs.len();
    let mut next_worker = 0usize;
    while let Ok(first) = rx.recv() {
        // size-class queues: [mts, cs, kron, classify]
        let mut classes: [Vec<Envelope>; N_CLASSES] = Default::default();
        let mut count = 1usize;
        classes[first.job.kind_idx()].push(first);
        let deadline = Instant::now() + cfg.max_wait;
        while count < cfg.max_batch {
            match rx.try_recv() {
                Ok(env) => {
                    classes[env.job.kind_idx()].push(env);
                    count += 1;
                }
                Err(_) => {
                    if Instant::now() >= deadline {
                        break;
                    }
                    std::thread::yield_now();
                }
            }
        }
        for class in classes {
            if class.is_empty() {
                continue;
            }
            // prefer an idle worker; fall back to blocking on a *live*
            // busy worker (bounded channel = backpressure). A worker
            // whose channel is disconnected (died) is never the
            // fallback target while live workers remain.
            let mut group = Some(class);
            let mut first_busy: Option<usize> = None;
            for probe in 0..n_workers {
                let w = (next_worker + probe) % n_workers;
                match group_txs[w].try_send(group.take().expect("group present")) {
                    Ok(()) => {
                        next_worker = (w + 1) % n_workers;
                        break;
                    }
                    Err(TrySendError::Full(g)) => {
                        first_busy.get_or_insert(w);
                        group = Some(g);
                    }
                    Err(TrySendError::Disconnected(g)) => group = Some(g),
                }
            }
            if let Some(g) = group {
                let failed = match first_busy {
                    Some(w) => {
                        next_worker = (w + 1) % n_workers;
                        group_txs[w].send(g).err().map(|e| e.0)
                    }
                    // no live worker left at all
                    None => Some(g),
                };
                if let Some(envs) = failed {
                    for env in envs {
                        metrics.errors.fetch_add(1, Ordering::Relaxed);
                        let _ = env.reply.send(Err("worker unavailable".to_string()));
                    }
                }
            }
        }
    }
    crate::log_info!("coordinator: batcher exiting; {}", metrics.summary());
}

/// One pool worker: construct the backend, then execute whole size-class
/// groups through the fused batch kernels until shutdown.
fn worker_loop(
    id: usize,
    cfg: CoordinatorConfig,
    grx: Receiver<Vec<Envelope>>,
    metrics: Arc<Metrics>,
    ready: SyncSender<Result<(), String>>,
) {
    let backend = match make_backend(&cfg) {
        Ok(b) => {
            let _ = ready.send(Ok(()));
            b
        }
        Err(e) => {
            let _ = ready.send(Err(e.to_string()));
            return;
        }
    };
    crate::log_debug!("worker {id}: backend={} ready", backend.name());
    while let Ok(group) = grx.recv() {
        dispatch_class(backend.as_ref(), group, &metrics);
    }
}

fn dispatch_class(backend: &dyn SketchBackend, class: Vec<Envelope>, metrics: &Metrics) {
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    metrics.batched_jobs.fetch_add(class.len() as u64, Ordering::Relaxed);
    // split payloads (moved, not cloned — §Perf) from reply handles
    let kind = class[0].job.kind_idx();
    let mut replies = Vec::with_capacity(class.len());
    let mut xs: Vec<Vec<f32>> = Vec::new();
    let mut pairs: Vec<(Vec<f32>, Vec<f32>)> = Vec::new();
    for env in class {
        debug_assert_eq!(env.job.kind_idx(), kind, "size-class mixing");
        replies.push((env.submitted, env.reply));
        match env.job {
            Job::MtsSketch(x) | Job::CsSketch(x) | Job::Classify(x) => xs.push(x),
            Job::KronCombine(a, b) => pairs.push((a, b)),
        }
    }
    let result: Result<Vec<Vec<f32>>> = match kind {
        0 => backend.mts_sketch_batch(&xs),
        1 => backend.cs_sketch_batch(&xs),
        2 => backend.kron_combine_batch(&pairs),
        _ => backend.classify_batch(&xs),
    };
    match result {
        Ok(outs) => {
            debug_assert_eq!(outs.len(), replies.len());
            for ((submitted, reply), out) in replies.into_iter().zip(outs) {
                let us = submitted.elapsed().as_micros() as u64;
                metrics.completed.fetch_add(1, Ordering::Relaxed);
                metrics.record_latency(us);
                let _ = reply.send(Ok(out));
            }
        }
        Err(e) => {
            let msg = e.to_string();
            for (_, reply) in replies {
                metrics.errors.fetch_add(1, Ordering::Relaxed);
                let _ = reply.send(Err(msg.clone()));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn artifacts_ready() -> bool {
        crate::runtime::artifacts_available(crate::runtime::DEFAULT_ARTIFACTS_DIR)
    }

    fn start_pure() -> Option<Coordinator> {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(
            Coordinator::start(CoordinatorConfig {
                backend: BackendKind::PureRust,
                ..Default::default()
            })
            .unwrap(),
        )
    }

    #[test]
    fn roundtrip_all_ops() {
        let Some(co) = start_pure() else { return };
        let man = crate::runtime::Manifest::load("artifacts").unwrap();
        let mts = &man.ops["mts_sketch"];
        let cs = &man.ops["cs_sketch"];
        let kron = &man.ops["kron_combine"];
        let mut rng = Pcg64::new(1);
        let x: Vec<f32> = (0..mts.input_dims[0] * mts.input_dims[1])
            .map(|_| rng.normal() as f32)
            .collect();
        let out = co.call(Job::MtsSketch(x)).unwrap();
        assert_eq!(out.len(), mts.sketch_dims[0] * mts.sketch_dims[1]);

        let v: Vec<f32> = (0..cs.input_dims[0]).map(|_| rng.normal() as f32).collect();
        let out = co.call(Job::CsSketch(v)).unwrap();
        assert_eq!(out.len(), cs.sketch_dims[0]);

        let n = kron.sketch_dims[0] * kron.sketch_dims[1];
        let a: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let out = co.call(Job::KronCombine(a, b)).unwrap();
        assert_eq!(out.len(), n);
        co.shutdown();
    }

    #[test]
    fn concurrent_submitters_get_correct_answers() {
        let Some(co) = start_pure() else { return };
        let co = std::sync::Arc::new(co);
        let man = crate::runtime::Manifest::load("artifacts").unwrap();
        let cs = man.ops["cs_sketch"].clone();
        let n = cs.input_dims[0];
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let co = co.clone();
            let cs = cs.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Pcg64::new(100 + t);
                for _ in 0..50 {
                    let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
                    let got = co.call(Job::CsSketch(x.clone())).unwrap();
                    // oracle: local scatter
                    let mut want = vec![0.0f32; cs.sketch_dims[0]];
                    for (i, &v) in x.iter().enumerate() {
                        want[cs.hashes[0].buckets[i]] += cs.hashes[0].signs[i] as f32 * v;
                    }
                    for (g, w) in got.iter().zip(want.iter()) {
                        assert!((g - w).abs() < 1e-3);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(
            co.metrics().completed.load(std::sync::atomic::Ordering::Relaxed),
            200
        );
        // flooded by 4 threads → batching must have coalesced at least some
        let batches = co.metrics().batches.load(std::sync::atomic::Ordering::Relaxed);
        assert!(batches <= 200, "batches={batches}");
    }

    #[test]
    fn multi_worker_pool_serves_correctly() {
        // same oracle check, but through an explicit 4-worker pool
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let co = std::sync::Arc::new(
            Coordinator::start(CoordinatorConfig {
                backend: BackendKind::PureRust,
                workers: Some(4),
                ..Default::default()
            })
            .unwrap(),
        );
        let man = crate::runtime::Manifest::load("artifacts").unwrap();
        let cs = man.ops["cs_sketch"].clone();
        let n = cs.input_dims[0];
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let co = co.clone();
            let cs = cs.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Pcg64::new(700 + t);
                for _ in 0..40 {
                    let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
                    let got = co.call(Job::CsSketch(x.clone())).unwrap();
                    let mut want = vec![0.0f32; cs.sketch_dims[0]];
                    for (i, &v) in x.iter().enumerate() {
                        want[cs.hashes[0].buckets[i]] += cs.hashes[0].signs[i] as f32 * v;
                    }
                    for (g, w) in got.iter().zip(want.iter()) {
                        assert!((g - w).abs() < 1e-3);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(
            co.metrics().completed.load(std::sync::atomic::Ordering::Relaxed),
            160
        );
    }

    #[test]
    fn single_worker_pool_still_works() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let co = Coordinator::start(CoordinatorConfig {
            backend: BackendKind::PureRust,
            workers: Some(1),
            ..Default::default()
        })
        .unwrap();
        let man = crate::runtime::Manifest::load("artifacts").unwrap();
        let n = man.ops["cs_sketch"].input_dims[0];
        assert!(co.call(Job::CsSketch(vec![1.0; n])).is_ok());
        co.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let co = Coordinator::start(CoordinatorConfig {
            backend: BackendKind::PureRust,
            queue_capacity: 2,
            max_batch: 1,
            max_wait: Duration::from_millis(0),
            workers: Some(1),
            ..Default::default()
        })
        .unwrap();
        let man = crate::runtime::Manifest::load("artifacts").unwrap();
        let n = man.ops["cs_sketch"].input_dims[0];
        // flood without reading replies; some must be rejected
        let mut rejected = 0;
        let mut receivers = Vec::new();
        for _ in 0..2000 {
            match co.try_submit(Job::CsSketch(vec![1.0; n])) {
                Ok(rx) => receivers.push(rx),
                Err(_) => rejected += 1,
            }
        }
        // drain what was accepted
        for rx in receivers {
            let _ = rx.recv();
        }
        // (timing-dependent, but with capacity 2 and 2000 instant
        // submissions at least one rejection is effectively certain)
        assert!(rejected > 0, "expected backpressure rejections");
        co.shutdown();
    }

    #[test]
    fn bad_input_returns_error_not_crash() {
        let Some(co) = start_pure() else { return };
        let err = co.call(Job::MtsSketch(vec![1.0; 3])); // wrong length
        assert!(err.is_err());
        // service still alive afterwards
        let man = crate::runtime::Manifest::load("artifacts").unwrap();
        let n = man.ops["cs_sketch"].input_dims[0];
        assert!(co.call(Job::CsSketch(vec![0.5; n])).is_ok());
        co.shutdown();
    }

    /// Drive `batcher_loop` directly against synthetic worker channels:
    /// worker 0 is dead (its receiver is dropped), worker 1 is a live
    /// echo thread. Every job must be served by worker 1 — the
    /// round-robin probe and the blocking fallback both have to skip
    /// the disconnected channel. The store's fan-out path sits on top
    /// of this behaviour, so it gets its own test.
    #[test]
    fn batcher_skips_dead_worker_when_picking_fallback() {
        let cfg = CoordinatorConfig {
            max_batch: 1, // one envelope per group: every job probes the pool
            max_wait: Duration::from_millis(0),
            ..Default::default()
        };
        let metrics = Arc::new(Metrics::default());
        let (tx, rx) = sync_channel::<Envelope>(64);

        // worker 0: dead on arrival
        let (dead_tx, dead_rx) = sync_channel::<Vec<Envelope>>(2);
        drop(dead_rx);
        // worker 1: alive, echoes Ok to every envelope
        let (live_tx, live_rx) = sync_channel::<Vec<Envelope>>(2);
        let live = std::thread::spawn(move || {
            let mut served = 0usize;
            while let Ok(group) = live_rx.recv() {
                for env in group {
                    served += 1;
                    let _ = env.reply.send(Ok(vec![]));
                }
            }
            served
        });

        let bmetrics = metrics.clone();
        let batcher =
            std::thread::spawn(move || batcher_loop(cfg, rx, vec![dead_tx, live_tx], bmetrics));

        let n_jobs = 20;
        let mut replies = Vec::new();
        for _ in 0..n_jobs {
            let (reply, reply_rx) = sync_channel(1);
            tx.send(Envelope { job: Job::CsSketch(vec![]), submitted: Instant::now(), reply })
                .unwrap();
            replies.push(reply_rx);
        }
        for (k, rx) in replies.into_iter().enumerate() {
            let got = rx.recv().expect("reply channel open");
            assert!(got.is_ok(), "job {k} failed: {got:?}");
        }
        drop(tx); // close the queue: batcher drains and exits
        batcher.join().unwrap();
        assert_eq!(live.join().unwrap(), n_jobs, "live worker must serve every job");
        assert_eq!(metrics.errors.load(std::sync::atomic::Ordering::Relaxed), 0);
    }

    /// With every worker dead the batcher must fail jobs cleanly
    /// ("worker unavailable") instead of wedging or panicking.
    #[test]
    fn batcher_fails_jobs_when_all_workers_dead() {
        let cfg = CoordinatorConfig {
            max_batch: 1,
            max_wait: Duration::from_millis(0),
            ..Default::default()
        };
        let metrics = Arc::new(Metrics::default());
        let (tx, rx) = sync_channel::<Envelope>(8);
        let (w0_tx, w0_rx) = sync_channel::<Vec<Envelope>>(2);
        let (w1_tx, w1_rx) = sync_channel::<Vec<Envelope>>(2);
        drop(w0_rx);
        drop(w1_rx);
        let bmetrics = metrics.clone();
        let batcher =
            std::thread::spawn(move || batcher_loop(cfg, rx, vec![w0_tx, w1_tx], bmetrics));
        let (reply, reply_rx) = sync_channel(1);
        tx.send(Envelope { job: Job::CsSketch(vec![]), submitted: Instant::now(), reply })
            .unwrap();
        let got = reply_rx.recv().expect("reply channel open");
        let err = got.expect_err("job must fail with no live workers");
        assert!(err.contains("worker unavailable"), "unexpected error: {err}");
        drop(tx);
        batcher.join().unwrap();
        assert_eq!(metrics.errors.load(std::sync::atomic::Ordering::Relaxed), 1);
    }

    #[test]
    fn xla_backend_through_coordinator() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let co = match Coordinator::start(CoordinatorConfig {
            backend: BackendKind::Xla,
            ..Default::default()
        }) {
            Ok(co) => co,
            Err(e) => {
                // the stubbed xla build cannot construct a PJRT client
                eprintln!("skipping: xla backend unavailable ({e})");
                return;
            }
        };
        let man = crate::runtime::Manifest::load("artifacts").unwrap();
        let mts = &man.ops["mts_sketch"];
        let mut rng = Pcg64::new(5);
        let x: Vec<f32> = (0..mts.input_dims[0] * mts.input_dims[1])
            .map(|_| rng.normal() as f32)
            .collect();
        let got = co.call(Job::MtsSketch(x.clone())).unwrap();
        // oracle scatter
        let m2 = mts.sketch_dims[1];
        let mut want = vec![0.0f32; mts.sketch_dims[0] * m2];
        let n2 = mts.input_dims[1];
        for i in 0..mts.input_dims[0] {
            for j in 0..n2 {
                want[mts.hashes[0].buckets[i] * m2 + mts.hashes[1].buckets[j]] +=
                    (mts.hashes[0].signs[i] * mts.hashes[1].signs[j]) as f32 * x[i * n2 + j];
            }
        }
        for (g, w) in got.iter().zip(want.iter()) {
            assert!((g - w).abs() < 1e-3, "{g} vs {w}");
        }
        co.shutdown();
    }
}
