//! Live service metrics: lock-free counters shared between the client
//! handle and the worker threads. The latency histogram is the shared
//! [`crate::obs::Histo`] (one log2 histogram implementation across the
//! whole crate — the coordinator was the prototype, `obs` is the home).

use crate::obs::Histo;
use std::sync::atomic::{AtomicU64, Ordering};

/// Coordinator counters. All `Relaxed`: these are statistics, not
/// synchronization.
#[derive(Debug, Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub batches: AtomicU64,
    pub batched_jobs: AtomicU64,
    pub errors: AtomicU64,
    /// end-to-end latency (µs): sum/max/log2 buckets in one histogram
    pub latency: Histo,
}

impl Metrics {
    pub fn record_latency(&self, us: u64) {
        self.latency.record(us);
    }

    pub fn mean_latency_us(&self) -> f64 {
        let n = self.completed.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.latency.sum() as f64 / n as f64
    }

    /// Approximate latency percentile (upper edge of the log2 bucket
    /// containing the p-quantile — accurate to within 2×). `p` in
    /// `[0, 1]`, e.g. 0.5 for p50, 0.99 for p99.
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        self.latency.percentile(p)
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_jobs.load(Ordering::Relaxed) as f64 / b as f64
    }

    pub fn summary(&self) -> String {
        format!(
            "submitted={} completed={} rejected={} errors={} batches={} \
             mean_batch={:.2} mean_latency={:.1}µs p50={}µs p99={}µs max_latency={}µs",
            self.submitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.errors.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.mean_latency_us(),
            self.latency_percentile_us(0.5),
            self.latency_percentile_us(0.99),
            self.latency.max(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;

    #[test]
    fn latency_accounting() {
        let m = Metrics::default();
        m.completed.store(2, Ordering::Relaxed);
        m.record_latency(100);
        m.record_latency(300);
        assert_eq!(m.mean_latency_us(), 200.0);
        assert_eq!(m.latency.max(), 300);
    }

    #[test]
    fn batch_size_accounting() {
        let m = Metrics::default();
        m.batches.store(2, Ordering::Relaxed);
        m.batched_jobs.store(10, Ordering::Relaxed);
        assert_eq!(m.mean_batch_size(), 5.0);
        assert!(m.summary().contains("mean_batch=5.00"));
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::default();
        assert_eq!(m.mean_latency_us(), 0.0);
        assert_eq!(m.mean_batch_size(), 0.0);
        assert_eq!(m.latency_percentile_us(0.5), 0);
        assert_eq!(m.latency_percentile_us(0.99), 0);
    }

    #[test]
    fn percentiles_bracket_recorded_latencies() {
        let m = Metrics::default();
        // 99 fast requests (~100µs) and one slow outlier (~50ms)
        for _ in 0..99 {
            m.record_latency(100);
        }
        m.record_latency(50_000);
        let p50 = m.latency_percentile_us(0.5);
        let p99 = m.latency_percentile_us(0.99);
        let p999 = m.latency_percentile_us(0.999);
        // p50/p99 live in the 100µs bucket ([64, 128) → edge 128);
        // p99.9 must see the outlier
        assert!((64..=128).contains(&p50), "p50={p50}");
        assert!((64..=128).contains(&p99), "p99={p99}");
        assert!(p999 >= 32_768, "p99.9={p999}");
        assert!(p50 <= p99 && p99 <= p999);
    }

    #[test]
    fn percentile_monotone_in_p() {
        let m = Metrics::default();
        for us in [1u64, 10, 100, 1_000, 10_000] {
            m.record_latency(us);
        }
        let mut last = 0;
        for p in [0.1, 0.3, 0.5, 0.7, 0.9, 0.99] {
            let v = m.latency_percentile_us(p);
            assert!(v >= last, "p={p}: {v} < {last}");
            last = v;
        }
    }
}
