//! Live service metrics: lock-free counters shared between the client
//! handle and the executor thread.

use std::sync::atomic::{AtomicU64, Ordering};

/// Coordinator counters. All `Relaxed`: these are statistics, not
/// synchronization.
#[derive(Debug, Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub batches: AtomicU64,
    pub batched_jobs: AtomicU64,
    pub errors: AtomicU64,
    /// end-to-end latency accumulators (µs)
    pub latency_sum_us: AtomicU64,
    pub latency_max_us: AtomicU64,
}

impl Metrics {
    pub fn record_latency(&self, us: u64) {
        self.latency_sum_us.fetch_add(us, Ordering::Relaxed);
        self.latency_max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn mean_latency_us(&self) -> f64 {
        let n = self.completed.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.latency_sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_jobs.load(Ordering::Relaxed) as f64 / b as f64
    }

    pub fn summary(&self) -> String {
        format!(
            "submitted={} completed={} rejected={} errors={} batches={} \
             mean_batch={:.2} mean_latency={:.1}µs max_latency={}µs",
            self.submitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.errors.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.mean_latency_us(),
            self.latency_max_us.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;

    #[test]
    fn latency_accounting() {
        let m = Metrics::default();
        m.completed.store(2, Ordering::Relaxed);
        m.record_latency(100);
        m.record_latency(300);
        assert_eq!(m.mean_latency_us(), 200.0);
        assert_eq!(m.latency_max_us.load(Ordering::Relaxed), 300);
    }

    #[test]
    fn batch_size_accounting() {
        let m = Metrics::default();
        m.batches.store(2, Ordering::Relaxed);
        m.batched_jobs.store(10, Ordering::Relaxed);
        assert_eq!(m.mean_batch_size(), 5.0);
        assert!(m.summary().contains("mean_batch=5.00"));
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::default();
        assert_eq!(m.mean_latency_us(), 0.0);
        assert_eq!(m.mean_batch_size(), 0.0);
    }
}
