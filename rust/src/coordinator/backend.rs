//! Sketch-service backends. Both are driven by the *same* manifest hash
//! tables, so their outputs agree to float tolerance — the parity tests
//! in `server.rs` and `rust/tests/` rely on that.

use crate::runtime::{client as rtc, Manifest, OpEntry, Runtime};
use anyhow::{anyhow, Result};

/// Which backend the coordinator should construct on its executor thread.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// AOT artifacts through PJRT (production path).
    Xla,
    /// In-crate algorithms seeded from the manifest (oracle / fallback).
    PureRust,
}

/// Batched execution interface for the three service ops.
///
/// All methods take and return flat row-major f32 buffers; shapes are
/// fixed by the manifest (`mts_sketch`: input n1×n2 → m1×m2;
/// `cs_sketch`: input n → c; `kron_combine`: two m1×m2 → m1×m2).
pub trait SketchBackend {
    fn name(&self) -> &'static str;

    /// MTS-sketch each input matrix.
    fn mts_sketch_batch(&self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>>;

    /// Count-sketch each input vector.
    fn cs_sketch_batch(&self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>>;

    /// Combine pairs of MTS sketches into Kronecker-product sketches.
    fn kron_combine_batch(&self, pairs: &[(Vec<f32>, Vec<f32>)]) -> Result<Vec<Vec<f32>>>;

    /// Model inference: one flat image per request → logits. Only
    /// available when the backend was configured with a serve model.
    fn classify_batch(&self, _xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        anyhow::bail!("classification not supported by this backend")
    }

    /// Op geometry (from the manifest), for validation.
    fn shapes(&self) -> BackendShapes;
}

/// Fixed op geometry shared by both backends.
#[derive(Clone, Debug)]
pub struct BackendShapes {
    pub mts_in: [usize; 2],
    pub mts_out: [usize; 2],
    pub cs_in: usize,
    pub cs_out: usize,
    pub cs_native_batch: usize,
    pub kron_dims: [usize; 2],
}

fn shapes_from_manifest(man: &Manifest) -> Result<BackendShapes> {
    let mts = man.ops.get("mts_sketch").ok_or_else(|| anyhow!("manifest missing mts_sketch"))?;
    let cs = man.ops.get("cs_sketch").ok_or_else(|| anyhow!("manifest missing cs_sketch"))?;
    let kron =
        man.ops.get("kron_combine").ok_or_else(|| anyhow!("manifest missing kron_combine"))?;
    Ok(BackendShapes {
        mts_in: [mts.input_dims[0], mts.input_dims[1]],
        mts_out: [mts.sketch_dims[0], mts.sketch_dims[1]],
        cs_in: cs.input_dims[0],
        cs_out: cs.sketch_dims[0],
        cs_native_batch: cs.batch.unwrap_or(1),
        kron_dims: [kron.sketch_dims[0], kron.sketch_dims[1]],
    })
}

// ---------------------------------------------------------------------
// Pure-Rust backend
// ---------------------------------------------------------------------

/// Executes the ops with the in-crate algorithms, using the manifest's
/// exported hash tables (bit-compatible with the AOT artifacts).
pub struct PureRustBackend {
    shapes: BackendShapes,
    mts_op: OpEntry,
    cs_op: OpEntry,
}

impl PureRustBackend {
    pub fn new(man: &Manifest) -> Result<Self> {
        Ok(Self {
            shapes: shapes_from_manifest(man)?,
            mts_op: man.ops["mts_sketch"].clone(),
            cs_op: man.ops["cs_sketch"].clone(),
        })
    }
}

/// Input-tile width for the fused batch scatters: small enough that a
/// tile's outputs stay cache-resident, big enough to amortize one pass
/// over the hash tables across several requests.
const SCATTER_TILE: usize = 8;

impl SketchBackend for PureRustBackend {
    fn name(&self) -> &'static str {
        "pure-rust"
    }

    fn mts_sketch_batch(&self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let [n1, n2] = self.shapes.mts_in;
        let [m1, m2] = self.shapes.mts_out;
        let h = &self.mts_op.hashes;
        for (r, x) in xs.iter().enumerate() {
            anyhow::ensure!(x.len() == n1 * n2, "mts input length (batch row {r})");
        }
        // fused batch kernel: the (bucket, sign) arithmetic per input
        // cell is done once per tile and applied to every request in it
        let mut outs = vec![vec![0.0f32; m1 * m2]; xs.len()];
        let mut start = 0;
        while start < xs.len() {
            let end = (start + SCATTER_TILE).min(xs.len());
            for i in 0..n1 {
                let b1 = h[0].buckets[i] * m2;
                let s1 = h[0].signs[i] as f32;
                for j in 0..n2 {
                    let b = b1 + h[1].buckets[j];
                    let s = s1 * h[1].signs[j] as f32;
                    let src = i * n2 + j;
                    for (x, out) in xs[start..end].iter().zip(outs[start..end].iter_mut()) {
                        out[b] += s * x[src];
                    }
                }
            }
            start = end;
        }
        Ok(outs)
    }

    fn cs_sketch_batch(&self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let n = self.shapes.cs_in;
        let c = self.shapes.cs_out;
        let h = &self.cs_op.hashes[0];
        for (r, x) in xs.iter().enumerate() {
            anyhow::ensure!(x.len() == n, "cs input length (batch row {r})");
        }
        // fused batch kernel: one pass over the hash tables per tile
        let mut outs = vec![vec![0.0f32; c]; xs.len()];
        let mut start = 0;
        while start < xs.len() {
            let end = (start + SCATTER_TILE).min(xs.len());
            for i in 0..n {
                let b = h.buckets[i];
                let s = h.signs[i] as f32;
                for (x, out) in xs[start..end].iter().zip(outs[start..end].iter_mut()) {
                    out[b] += s * x[i];
                }
            }
            start = end;
        }
        Ok(outs)
    }

    fn kron_combine_batch(&self, pairs: &[(Vec<f32>, Vec<f32>)]) -> Result<Vec<Vec<f32>>> {
        let [m1, m2] = self.shapes.kron_dims;
        pairs
            .iter()
            .map(|(a, b)| {
                anyhow::ensure!(a.len() == m1 * m2 && b.len() == m1 * m2, "kron input length");
                let af: Vec<f64> = a.iter().map(|&v| v as f64).collect();
                let bf: Vec<f64> = b.iter().map(|&v| v as f64).collect();
                // real-input half-spectrum path; the RFFT plans are
                // cached thread-locally, so the whole batch shares them
                let out = crate::fft::circular_convolve2_real(&af, &bf, m1, m2);
                Ok(out.into_iter().map(|v| v as f32).collect())
            })
            .collect()
    }

    fn shapes(&self) -> BackendShapes {
        self.shapes.clone()
    }
}

// ---------------------------------------------------------------------
// XLA backend
// ---------------------------------------------------------------------

/// Executes the ops through the AOT artifacts on the PJRT CPU client.
/// Not `Send` — constructed on the coordinator's executor thread.
pub struct XlaBackend {
    rt: Runtime,
    shapes: BackendShapes,
    mts_path: String,
    cs_path: String,
    kron_path: String,
    /// optional serving model: (predict path, param literals, batch, img dims)
    serve: Option<ServeModel>,
}

struct ServeModel {
    predict_path: String,
    params: Vec<Vec<f32>>,
    param_shapes: Vec<Vec<usize>>,
    batch: usize,
    img: Vec<usize>,
    num_classes: usize,
}

impl XlaBackend {
    pub fn new(artifacts_dir: &str) -> Result<Self> {
        Self::with_serve_model(artifacts_dir, None)
    }

    /// `serve_model`: manifest model name whose `predict` artifact should
    /// back `classify_batch`. Uses trained params from
    /// `results/trained_<model>.bin` if present, else the init params.
    pub fn with_serve_model(artifacts_dir: &str, serve_model: Option<&str>) -> Result<Self> {
        let rt = Runtime::new(artifacts_dir)?;
        let shapes = shapes_from_manifest(rt.manifest())?;
        let mts_path = rt.manifest().ops["mts_sketch"].path.clone();
        let cs_path = rt.manifest().ops["cs_sketch"].path.clone();
        let kron_path = rt.manifest().ops["kron_combine"].path.clone();
        // warm the executable cache up front so first-request latency is
        // not a compile
        rt.load(&mts_path)?;
        rt.load(&cs_path)?;
        rt.load(&kron_path)?;
        let serve = match serve_model {
            None => None,
            Some(name) => {
                let entry = rt
                    .manifest()
                    .models
                    .get(name)
                    .ok_or_else(|| anyhow!("unknown serve model {name:?}"))?
                    .clone();
                let predict_path = entry
                    .predict
                    .clone()
                    .ok_or_else(|| anyhow!("model {name} has no predict artifact"))?;
                rt.load(&predict_path)?;
                // prefer trained params if a training run saved them
                let trained = std::path::Path::new("results").join(format!("trained_{name}.bin"));
                let params = if trained.exists() {
                    crate::train::trainer::load_param_file(&trained, &entry)?
                } else {
                    rt.manifest().load_init_params(name)?
                };
                Some(ServeModel {
                    predict_path,
                    param_shapes: entry.param_schema.iter().map(|p| p.shape.clone()).collect(),
                    params,
                    batch: entry.batch,
                    img: entry.img.clone(),
                    num_classes: entry.num_classes,
                })
            }
        };
        Ok(Self { rt, shapes, mts_path, cs_path, kron_path, serve })
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }
}

impl SketchBackend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla-pjrt"
    }

    fn mts_sketch_batch(&self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let [n1, n2] = self.shapes.mts_in;
        let exe = self.rt.load(&self.mts_path)?;
        xs.iter()
            .map(|x| {
                let lit = rtc::literal_f32(x, &[n1, n2])?;
                let out = self.rt.execute_loaded(&exe, &[lit])?;
                rtc::literal_to_f32(&out[0])
            })
            .collect()
    }

    fn cs_sketch_batch(&self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        // the artifact is natively batched [B, n] — pack requests into
        // full batches (zero-padding the tail), then split the output
        let n = self.shapes.cs_in;
        let c = self.shapes.cs_out;
        let bsz = self.shapes.cs_native_batch;
        let exe = self.rt.load(&self.cs_path)?;
        let mut out = Vec::with_capacity(xs.len());
        for chunk in xs.chunks(bsz) {
            let mut packed = vec![0.0f32; bsz * n];
            for (r, x) in chunk.iter().enumerate() {
                anyhow::ensure!(x.len() == n, "cs input length");
                packed[r * n..(r + 1) * n].copy_from_slice(x);
            }
            let lit = rtc::literal_f32(&packed, &[bsz, n])?;
            let res = self.rt.execute_loaded(&exe, &[lit])?;
            let flat = rtc::literal_to_f32(&res[0])?;
            for r in 0..chunk.len() {
                out.push(flat[r * c..(r + 1) * c].to_vec());
            }
        }
        Ok(out)
    }

    fn kron_combine_batch(&self, pairs: &[(Vec<f32>, Vec<f32>)]) -> Result<Vec<Vec<f32>>> {
        let [m1, m2] = self.shapes.kron_dims;
        let exe = self.rt.load(&self.kron_path)?;
        pairs
            .iter()
            .map(|(a, b)| {
                let la = rtc::literal_f32(a, &[m1, m2])?;
                let lb = rtc::literal_f32(b, &[m1, m2])?;
                let out = self.rt.execute_loaded(&exe, &[la, lb])?;
                rtc::literal_to_f32(&out[0])
            })
            .collect()
    }

    fn classify_batch(&self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let serve = self
            .serve
            .as_ref()
            .ok_or_else(|| anyhow!("backend started without a serve model"))?;
        let img_len: usize = serve.img.iter().product();
        let exe = self.rt.load(&serve.predict_path)?;
        let mut img_dims = vec![serve.batch];
        img_dims.extend_from_slice(&serve.img);
        let mut out = Vec::with_capacity(xs.len());
        for chunk in xs.chunks(serve.batch) {
            let mut packed = vec![0.0f32; serve.batch * img_len];
            for (r, x) in chunk.iter().enumerate() {
                anyhow::ensure!(x.len() == img_len, "image length {}", x.len());
                packed[r * img_len..(r + 1) * img_len].copy_from_slice(x);
            }
            let mut inputs = Vec::with_capacity(serve.params.len() + 1);
            for (p, shape) in serve.params.iter().zip(serve.param_shapes.iter()) {
                inputs.push(rtc::literal_f32(p, shape)?);
            }
            inputs.push(rtc::literal_f32(&packed, &img_dims)?);
            let res = self.rt.execute_loaded(&exe, &inputs)?;
            let logits = rtc::literal_to_f32(&res[0])?;
            for r in 0..chunk.len() {
                out.push(logits[r * serve.num_classes..(r + 1) * serve.num_classes].to_vec());
            }
        }
        Ok(out)
    }

    fn shapes(&self) -> BackendShapes {
        self.shapes.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::ModeHash;
    use crate::rng::Pcg64;
    use crate::runtime::OpHash;

    /// A manifest built in-process so the fused batch kernels are
    /// testable without the AOT artifacts.
    fn synthetic_manifest() -> Manifest {
        let mk_hash = |n: usize, m: usize, seed: u64| {
            let mh = ModeHash::new(n, m, seed);
            OpHash {
                buckets: (0..n).map(|i| mh.h(i)).collect(),
                signs: (0..n).map(|i| mh.s(i)).collect(),
            }
        };
        let mut ops = std::collections::BTreeMap::new();
        ops.insert(
            "mts_sketch".to_string(),
            OpEntry {
                path: String::new(),
                batch: None,
                input_dims: vec![6, 5],
                sketch_dims: vec![3, 4],
                hashes: vec![mk_hash(6, 3, 1), mk_hash(5, 4, 2)],
            },
        );
        ops.insert(
            "cs_sketch".to_string(),
            OpEntry {
                path: String::new(),
                batch: Some(4),
                input_dims: vec![32],
                sketch_dims: vec![8],
                hashes: vec![mk_hash(32, 8, 3)],
            },
        );
        ops.insert(
            "kron_combine".to_string(),
            OpEntry {
                path: String::new(),
                batch: None,
                input_dims: vec![],
                sketch_dims: vec![4, 6],
                hashes: vec![],
            },
        );
        Manifest { dir: std::path::PathBuf::new(), models: Default::default(), ops }
    }

    #[test]
    fn cs_batch_kernel_matches_scalar_oracle() {
        let be = PureRustBackend::new(&synthetic_manifest()).unwrap();
        let s = be.shapes();
        let mut rng = Pcg64::new(10);
        // an odd batch size exercises the partial tail tile
        let xs: Vec<Vec<f32>> = (0..19)
            .map(|_| (0..s.cs_in).map(|_| rng.normal() as f32).collect())
            .collect();
        let got = be.cs_sketch_batch(&xs).unwrap();
        let man = synthetic_manifest();
        let h = &man.ops["cs_sketch"].hashes[0];
        for (x, out) in xs.iter().zip(got.iter()) {
            let mut want = vec![0.0f32; s.cs_out];
            for (i, &v) in x.iter().enumerate() {
                want[h.buckets[i]] += h.signs[i] as f32 * v;
            }
            assert_eq!(out, &want);
        }
    }

    #[test]
    fn mts_batch_kernel_matches_scalar_oracle() {
        let be = PureRustBackend::new(&synthetic_manifest()).unwrap();
        let s = be.shapes();
        let [n1, n2] = s.mts_in;
        let [m1, m2] = s.mts_out;
        let mut rng = Pcg64::new(11);
        let xs: Vec<Vec<f32>> = (0..9)
            .map(|_| (0..n1 * n2).map(|_| rng.normal() as f32).collect())
            .collect();
        let got = be.mts_sketch_batch(&xs).unwrap();
        let man = synthetic_manifest();
        let h = &man.ops["mts_sketch"].hashes;
        for (x, out) in xs.iter().zip(got.iter()) {
            let mut want = vec![0.0f32; m1 * m2];
            for i in 0..n1 {
                for j in 0..n2 {
                    want[h[0].buckets[i] * m2 + h[1].buckets[j]] +=
                        (h[0].signs[i] * h[1].signs[j]) as f32 * x[i * n2 + j];
                }
            }
            assert_eq!(out, &want);
        }
    }

    #[test]
    fn kron_batch_matches_complex_reference() {
        let be = PureRustBackend::new(&synthetic_manifest()).unwrap();
        let [m1, m2] = be.shapes().kron_dims;
        let mut rng = Pcg64::new(12);
        let pairs: Vec<(Vec<f32>, Vec<f32>)> = (0..3)
            .map(|_| {
                (
                    (0..m1 * m2).map(|_| rng.normal() as f32).collect(),
                    (0..m1 * m2).map(|_| rng.normal() as f32).collect(),
                )
            })
            .collect();
        let got = be.kron_combine_batch(&pairs).unwrap();
        for ((a, b), out) in pairs.iter().zip(got.iter()) {
            let af: Vec<f64> = a.iter().map(|&v| v as f64).collect();
            let bf: Vec<f64> = b.iter().map(|&v| v as f64).collect();
            let want = crate::fft::circular_convolve2(&af, &bf, m1, m2);
            for (g, w) in out.iter().zip(want.iter()) {
                assert!((*g as f64 - w).abs() < 1e-4, "{g} vs {w}");
            }
        }
    }

    #[test]
    fn batch_kernels_reject_bad_lengths() {
        let be = PureRustBackend::new(&synthetic_manifest()).unwrap();
        assert!(be.cs_sketch_batch(&[vec![0.0; 3]]).is_err());
        assert!(be.mts_sketch_batch(&[vec![0.0; 3]]).is_err());
        assert!(be.kron_combine_batch(&[(vec![0.0; 3], vec![0.0; 3])]).is_err());
    }

    fn with_backends() -> Option<(PureRustBackend, XlaBackend)> {
        if !crate::runtime::artifacts_available(crate::runtime::DEFAULT_ARTIFACTS_DIR) {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let xla = XlaBackend::new(crate::runtime::DEFAULT_ARTIFACTS_DIR).unwrap();
        let pure = PureRustBackend::new(xla.runtime().manifest()).unwrap();
        Some((pure, xla))
    }

    fn rand_vec(n: usize, rng: &mut Pcg64) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn backends_agree_on_mts() {
        let Some((pure, xla)) = with_backends() else { return };
        let s = pure.shapes();
        let mut rng = Pcg64::new(1);
        let xs: Vec<Vec<f32>> =
            (0..3).map(|_| rand_vec(s.mts_in[0] * s.mts_in[1], &mut rng)).collect();
        let a = pure.mts_sketch_batch(&xs).unwrap();
        let b = xla.mts_sketch_batch(&xs).unwrap();
        for (x, y) in a.iter().zip(b.iter()) {
            for (u, v) in x.iter().zip(y.iter()) {
                assert!((u - v).abs() < 1e-3, "{u} vs {v}");
            }
        }
    }

    #[test]
    fn backends_agree_on_cs() {
        let Some((pure, xla)) = with_backends() else { return };
        let s = pure.shapes();
        let mut rng = Pcg64::new(2);
        // more requests than one native batch to exercise chunking
        let xs: Vec<Vec<f32>> =
            (0..s.cs_native_batch + 3).map(|_| rand_vec(s.cs_in, &mut rng)).collect();
        let a = pure.cs_sketch_batch(&xs).unwrap();
        let b = xla.cs_sketch_batch(&xs).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b.iter()) {
            for (u, v) in x.iter().zip(y.iter()) {
                assert!((u - v).abs() < 1e-3, "{u} vs {v}");
            }
        }
    }

    #[test]
    fn backends_agree_on_kron() {
        let Some((pure, xla)) = with_backends() else { return };
        let s = pure.shapes();
        let mut rng = Pcg64::new(3);
        let n = s.kron_dims[0] * s.kron_dims[1];
        let pairs: Vec<(Vec<f32>, Vec<f32>)> =
            (0..2).map(|_| (rand_vec(n, &mut rng), rand_vec(n, &mut rng))).collect();
        let a = pure.kron_combine_batch(&pairs).unwrap();
        let b = xla.kron_combine_batch(&pairs).unwrap();
        for (x, y) in a.iter().zip(b.iter()) {
            for (u, v) in x.iter().zip(y.iter()) {
                assert!((u - v).abs() < 1e-2, "{u} vs {v}");
            }
        }
    }
}
