//! Dense linear algebra substrate: QR (Householder), SVD (one-sided
//! Jacobi), and leading-singular-subspace helpers. These back the tensor
//! decompositions in `decomp/` (HOSVD needs leading left singular
//! vectors; CP-ALS needs least squares; TT-SVD needs truncated SVD).
//!
//! Written for correctness and clarity at the modest sizes the paper's
//! experiments use (n ≤ a few hundred); not a BLAS replacement.

use crate::tensor::Tensor;

/// Householder QR: returns (Q, R) with Q ∈ ℝ^{m×n} orthonormal columns
/// (thin QR), R ∈ ℝ^{n×n} upper triangular, for m ≥ n.
pub fn qr(a: &Tensor) -> (Tensor, Tensor) {
    assert_eq!(a.order(), 2);
    let (m, n) = (a.dims()[0], a.dims()[1]);
    assert!(m >= n, "thin QR requires m >= n (got {m}x{n})");
    let mut r = a.clone(); // working copy, will hold R in top block
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n); // householder vectors

    for k in 0..n {
        // build householder vector for column k below diagonal
        let mut norm = 0.0;
        for i in k..m {
            let x = r.at2(i, k);
            norm += x * x;
        }
        let norm = norm.sqrt();
        if norm < 1e-300 {
            vs.push(vec![0.0; m - k]);
            continue;
        }
        let alpha = if r.at2(k, k) >= 0.0 { -norm } else { norm };
        let mut v: Vec<f64> = (k..m).map(|i| r.at2(i, k)).collect();
        v[0] -= alpha;
        let vnorm_sq: f64 = v.iter().map(|x| x * x).sum();
        if vnorm_sq < 1e-300 {
            vs.push(vec![0.0; m - k]);
            continue;
        }
        // apply H = I - 2vvᵀ/|v|² to remaining columns
        for j in k..n {
            let mut dot = 0.0;
            for (ii, vi) in v.iter().enumerate() {
                dot += vi * r.at2(k + ii, j);
            }
            let scale = 2.0 * dot / vnorm_sq;
            for (ii, vi) in v.iter().enumerate() {
                let cur = r.at2(k + ii, j);
                r.set(&[k + ii, j], cur - scale * vi);
            }
        }
        vs.push(v);
    }

    // materialize thin Q by applying H_k in reverse to identity columns
    let mut q = Tensor::zeros(&[m, n]);
    for j in 0..n {
        let mut e = vec![0.0; m];
        e[j] = 1.0;
        for k in (0..n).rev() {
            let v = &vs[k];
            let vnorm_sq: f64 = v.iter().map(|x| x * x).sum();
            if vnorm_sq < 1e-300 {
                continue;
            }
            let mut dot = 0.0;
            for (ii, vi) in v.iter().enumerate() {
                dot += vi * e[k + ii];
            }
            let scale = 2.0 * dot / vnorm_sq;
            for (ii, vi) in v.iter().enumerate() {
                e[k + ii] -= scale * vi;
            }
        }
        for i in 0..m {
            q.set(&[i, j], e[i]);
        }
    }
    // R: top n×n of working copy, zero below diagonal
    let mut rr = Tensor::zeros(&[n, n]);
    for i in 0..n {
        for j in i..n {
            rr.set(&[i, j], r.at2(i, j));
        }
    }
    (q, rr)
}

/// Thin SVD via one-sided Jacobi: `a = U diag(s) Vᵀ`, with
/// U ∈ ℝ^{m×n}, s descending, V ∈ ℝ^{n×n}. Requires m ≥ n (callers
/// transpose if needed).
pub fn svd(a: &Tensor) -> (Tensor, Vec<f64>, Tensor) {
    assert_eq!(a.order(), 2);
    let (m, n) = (a.dims()[0], a.dims()[1]);
    assert!(m >= n, "svd requires m >= n; transpose first (got {m}x{n})");
    // work on columns of U = A (copied), rotate pairs until orthogonal
    let mut u = a.clone();
    let mut v = Tensor::eye(n);
    let eps = 1e-12;
    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // compute [app apq; apq aqq] of AᵀA for columns p,q
                let mut app = 0.0;
                let mut aqq = 0.0;
                let mut apq = 0.0;
                for i in 0..m {
                    let up = u.at2(i, p);
                    let uq = u.at2(i, q);
                    app += up * up;
                    aqq += uq * uq;
                    apq += up * uq;
                }
                off = off.max(apq.abs() / (app * aqq).sqrt().max(1e-300));
                if apq.abs() <= eps * (app * aqq).sqrt() {
                    continue;
                }
                // Jacobi rotation
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let up = u.at2(i, p);
                    let uq = u.at2(i, q);
                    u.set(&[i, p], c * up - s * uq);
                    u.set(&[i, q], s * up + c * uq);
                }
                for i in 0..n {
                    let vp = v.at2(i, p);
                    let vq = v.at2(i, q);
                    v.set(&[i, p], c * vp - s * vq);
                    v.set(&[i, q], s * vp + c * vq);
                }
            }
        }
        if off < eps {
            break;
        }
    }
    // singular values = column norms; normalize U
    let mut sv: Vec<(f64, usize)> = (0..n)
        .map(|j| {
            let norm: f64 = (0..m).map(|i| u.at2(i, j).powi(2)).sum::<f64>().sqrt();
            (norm, j)
        })
        .collect();
    sv.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let mut uu = Tensor::zeros(&[m, n]);
    let mut vv = Tensor::zeros(&[n, n]);
    let mut s = Vec::with_capacity(n);
    for (out_j, &(norm, j)) in sv.iter().enumerate() {
        s.push(norm);
        if norm > 1e-300 {
            for i in 0..m {
                uu.set(&[i, out_j], u.at2(i, j) / norm);
            }
        }
        for i in 0..n {
            vv.set(&[i, out_j], v.at2(i, j));
        }
    }
    (uu, s, vv)
}

/// Leading `k` left singular vectors of `a` (m×n, any aspect ratio).
pub fn leading_left_singular(a: &Tensor, k: usize) -> Tensor {
    let (m, n) = (a.dims()[0], a.dims()[1]);
    let k = k.min(m).min(n);
    let u = if m >= n {
        let (u, _, _) = svd(a);
        u
    } else {
        // A = U S Vᵀ ⇔ Aᵀ = V S Uᵀ; svd(Aᵀ) gives (V, S, U)
        let (_, _, v) = svd(&a.transpose());
        v
    };
    // take first k columns
    let mut out = Tensor::zeros(&[m, k]);
    for i in 0..m {
        for j in 0..k {
            out.set(&[i, j], u.at2(i, j));
        }
    }
    out
}

/// Solve the least-squares problem `min ‖A x - b‖` for each column of B
/// via QR (A: m×n, m ≥ n; B: m×p) → X: n×p.
pub fn lstsq(a: &Tensor, b: &Tensor) -> Tensor {
    let (q, r) = qr(a);
    let qtb = q.transpose().matmul(b); // n×p
    let n = r.dims()[0];
    let p = qtb.dims()[1];
    let mut x = Tensor::zeros(&[n, p]);
    for col in 0..p {
        for i in (0..n).rev() {
            let mut acc = qtb.at2(i, col);
            for j in (i + 1)..n {
                acc -= r.at2(i, j) * x.at2(j, col);
            }
            let d = r.at2(i, i);
            x.set(&[i, col], if d.abs() > 1e-300 { acc / d } else { 0.0 });
        }
    }
    x
}

/// Pseudo-inverse via SVD (used for Moore–Penrose needs in tests).
pub fn pinv(a: &Tensor) -> Tensor {
    let (m, n) = (a.dims()[0], a.dims()[1]);
    if m >= n {
        let (u, s, v) = svd(a);
        // pinv = V S⁺ Uᵀ
        let mut sp = Tensor::zeros(&[n, n]);
        let cutoff = s.first().copied().unwrap_or(0.0) * 1e-12;
        for (i, &sv) in s.iter().enumerate() {
            if sv > cutoff {
                sp.set(&[i, i], 1.0 / sv);
            }
        }
        v.matmul(&sp).matmul(&u.transpose())
    } else {
        pinv(&a.transpose()).transpose()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::tensor::rel_error;

    #[test]
    fn qr_reconstructs_and_q_orthonormal() {
        let mut rng = Pcg64::new(1);
        for &(m, n) in &[(5usize, 3usize), (6, 6), (10, 2)] {
            let a = Tensor::randn(&[m, n], &mut rng);
            let (q, r) = qr(&a);
            let qr_prod = q.matmul(&r);
            assert!(rel_error(&a, &qr_prod) < 1e-10, "{m}x{n}");
            let qtq = q.transpose().matmul(&q);
            assert!(rel_error(&Tensor::eye(n), &qtq) < 1e-10, "QᵀQ≠I {m}x{n}");
            // R upper triangular
            for i in 0..n {
                for j in 0..i {
                    assert!(r.at2(i, j).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn svd_reconstructs() {
        let mut rng = Pcg64::new(2);
        for &(m, n) in &[(6usize, 4usize), (5, 5), (8, 3)] {
            let a = Tensor::randn(&[m, n], &mut rng);
            let (u, s, v) = svd(&a);
            let mut smat = Tensor::zeros(&[n, n]);
            for (i, &sv) in s.iter().enumerate() {
                smat.set(&[i, i], sv);
            }
            let recon = u.matmul(&smat).matmul(&v.transpose());
            assert!(rel_error(&a, &recon) < 1e-9, "{m}x{n}");
            // descending
            for w in s.windows(2) {
                assert!(w[0] >= w[1] - 1e-12);
            }
            // orthonormal
            assert!(rel_error(&Tensor::eye(n), &u.transpose().matmul(&u)) < 1e-9);
            assert!(rel_error(&Tensor::eye(n), &v.transpose().matmul(&v)) < 1e-9);
        }
    }

    #[test]
    fn svd_rank_deficient() {
        // rank-1 matrix: only one nonzero singular value
        let u = [1.0, 2.0, 3.0, 4.0];
        let v = [1.0, -1.0, 2.0];
        let mut a = Tensor::zeros(&[4, 3]);
        for i in 0..4 {
            for j in 0..3 {
                a.set(&[i, j], u[i] * v[j]);
            }
        }
        let (_, s, _) = svd(&a);
        assert!(s[0] > 1.0);
        assert!(s[1] < 1e-9 && s[2] < 1e-9, "s={s:?}");
    }

    #[test]
    fn leading_left_singular_spans_range() {
        let mut rng = Pcg64::new(3);
        // low-rank matrix: A = B C with inner dim 2
        let b = Tensor::randn(&[8, 2], &mut rng);
        let c = Tensor::randn(&[2, 6], &mut rng);
        let a = b.matmul(&c);
        let u = leading_left_singular(&a, 2);
        assert_eq!(u.dims(), &[8, 2]);
        // projector onto span(u) should reproduce A
        let proj = u.matmul(&u.transpose()).matmul(&a);
        assert!(rel_error(&a, &proj) < 1e-9);
    }

    #[test]
    fn leading_left_singular_wide_matrix() {
        let mut rng = Pcg64::new(4);
        let b = Tensor::randn(&[4, 2], &mut rng);
        let c = Tensor::randn(&[2, 12], &mut rng);
        let a = b.matmul(&c); // 4×12, rank 2
        let u = leading_left_singular(&a, 2);
        let proj = u.matmul(&u.transpose()).matmul(&a);
        assert!(rel_error(&a, &proj) < 1e-9);
    }

    #[test]
    fn lstsq_exact_for_consistent_system() {
        let mut rng = Pcg64::new(5);
        let a = Tensor::randn(&[7, 3], &mut rng);
        let x_true = Tensor::randn(&[3, 2], &mut rng);
        let b = a.matmul(&x_true);
        let x = lstsq(&a, &b);
        assert!(rel_error(&x_true, &x) < 1e-9);
    }

    #[test]
    fn lstsq_minimizes_residual() {
        let mut rng = Pcg64::new(6);
        let a = Tensor::randn(&[10, 3], &mut rng);
        let b = Tensor::randn(&[10, 1], &mut rng);
        let x = lstsq(&a, &b);
        // residual must be orthogonal to columns of A
        let resid = b.sub(&a.matmul(&x));
        let ata_resid = a.transpose().matmul(&resid);
        assert!(ata_resid.fro_norm() < 1e-9, "normal equations violated");
    }

    #[test]
    fn pinv_satisfies_moore_penrose() {
        let mut rng = Pcg64::new(7);
        for dims in [[5usize, 3usize], [3, 5]] {
            let a = Tensor::randn(&dims, &mut rng);
            let p = pinv(&a);
            let apa = a.matmul(&p).matmul(&a);
            assert!(rel_error(&a, &apa) < 1e-9, "A P A = A failed for {dims:?}");
            let pap = p.matmul(&a).matmul(&p);
            assert!(rel_error(&p, &pap) < 1e-9, "P A P = P failed for {dims:?}");
        }
    }
}
