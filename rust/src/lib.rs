//! # hocs — Higher-order Count Sketch
//!
//! A production-quality reproduction of *"Higher-order Count Sketch:
//! Dimensionality Reduction That Retains Efficient Tensor Operations"*
//! (Shi & Anandkumar, 2019; earlier arXiv title "Multi-dimensional
//! Tensor Sketch").
//!
//! The library is organized in three layers:
//!
//! - **Core algorithms** (pure Rust, this crate): [`sketch`] implements
//!   count sketch (CS), count-based tensor sketch (CTS, the vector-space
//!   baseline), and the paper's multi-dimensional tensor sketch
//!   (MTS/HCS), plus the sketched Kronecker / Tucker / CP / TT /
//!   covariance operations. Substrates: [`tensor`], [`fft`], [`hash`],
//!   [`decomp`], [`linalg`], [`rng`], [`util`].
//! - **AOT compute artifacts** (build time, `python/`): Pallas kernels +
//!   JAX models lowered to HLO text, loaded at runtime by [`runtime`].
//! - **Coordinator** ([`coordinator`]): a pooled sketch service — a
//!   size-class batcher feeding a configurable worker pool (each worker
//!   owns its backend and FFT plan caches) with backpressure and
//!   p50/p99 latency metrics — plus the [`train`] driver reproducing
//!   the paper's tensor-regression-network experiments end to end.
//! - **Store** ([`store`]): the serving layer over the streaming
//!   application — a K-way sharded, epoch-windowed store of mergeable
//!   sketches with snapshot/WAL durability and a framed TCP front-end
//!   (`hocs serve` / `hocs store-client`). Built entirely on sketch
//!   linearity: shards, sliding windows, and cross-node merges are all
//!   elementwise addition.
//!
//! ## Quickstart
//!
//! ```
//! use hocs::rng::Pcg64;
//! use hocs::sketch::mts::MtsSketcher;
//! use hocs::tensor::Tensor;
//!
//! let mut rng = Pcg64::new(0);
//! let t = Tensor::randn(&[32, 32], &mut rng);
//! // sketch 32×32 → 16×16 (compression ratio 4)
//! let sk = MtsSketcher::new(&[32, 32], &[16, 16], 42);
//! let mts = sk.sketch(&t);
//! let approx = sk.decompress(&mts);
//! assert_eq!(approx.dims(), t.dims());
//! ```

pub mod analysis;
pub mod coordinator;
pub mod decomp;
pub mod experiments;
pub mod fft;
pub mod hash;
pub mod linalg;
pub mod obs;
pub mod rng;
pub mod runtime;
pub mod sketch;
pub mod store;
pub mod tensor;
pub mod train;
pub mod util;
