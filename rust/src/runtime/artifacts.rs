//! Artifact manifest: the typed view of `artifacts/manifest.json`, the
//! contract between the Python AOT pipeline and the Rust runtime.

use crate::util::json::{self, Json};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One mode's hash table exported from the build (bucket indices +
/// signs) — lets Rust decompress sketches produced by the AOT ops.
#[derive(Clone, Debug)]
pub struct OpHash {
    pub buckets: Vec<usize>,
    pub signs: Vec<f64>,
}

impl OpHash {
    fn from_json(j: &Json) -> Result<Self> {
        let buckets = j
            .get("buckets")
            .and_then(|b| b.as_usize_vec())
            .ok_or_else(|| anyhow!("hash missing buckets"))?;
        let signs = j
            .get("signs")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| anyhow!("hash missing signs"))?
            .iter()
            .map(|v| v.as_f64().ok_or_else(|| anyhow!("bad sign")))
            .collect::<Result<Vec<_>>>()?;
        if buckets.len() != signs.len() {
            bail!("hash table length mismatch");
        }
        Ok(Self { buckets, signs })
    }
}

/// A service op (standalone Pallas kernel lowered to HLO).
#[derive(Clone, Debug)]
pub struct OpEntry {
    pub path: String,
    pub batch: Option<usize>,
    pub input_dims: Vec<usize>,
    pub sketch_dims: Vec<usize>,
    pub hashes: Vec<OpHash>,
}

/// One parameter tensor in a model's flat schema.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.shape.contains(&0)
    }
}

/// A trainable model variant (train + eval steps + init params).
#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub head: String,
    pub train: String,
    pub eval: String,
    /// serving entry point: predict(*params, x) -> (logits,)
    pub predict: Option<String>,
    pub init_params: String,
    pub batch: usize,
    pub img: Vec<usize>,
    pub num_classes: usize,
    pub param_schema: Vec<ParamSpec>,
    pub head_param_count: usize,
    pub total_param_count: usize,
    pub sketch: Option<Vec<usize>>,
    pub cts_c: Option<usize>,
}

impl ModelEntry {
    /// Total parameter scalars (sum of schema shapes).
    pub fn param_len(&self) -> usize {
        self.param_schema.iter().map(|p| p.len()).sum()
    }
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelEntry>,
    pub ops: BTreeMap<String, OpEntry>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let j = json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;

        let mut models = BTreeMap::new();
        if let Some(ms) = j.get("models").and_then(|m| m.as_obj()) {
            for (name, entry) in ms {
                models.insert(name.clone(), Self::model_from_json(entry)?);
            }
        }
        let mut ops = BTreeMap::new();
        if let Some(os) = j.get("ops").and_then(|m| m.as_obj()) {
            for (name, entry) in os {
                ops.insert(name.clone(), Self::op_from_json(entry)?);
            }
        }
        Ok(Self { dir, models, ops })
    }

    fn model_from_json(j: &Json) -> Result<ModelEntry> {
        let str_field = |k: &str| -> Result<String> {
            Ok(j.get(k)
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("model missing {k}"))?
                .to_string())
        };
        let usize_field = |k: &str| -> Result<usize> {
            j.get(k).and_then(|v| v.as_usize()).ok_or_else(|| anyhow!("model missing {k}"))
        };
        let param_schema = j
            .get("param_schema")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("model missing param_schema"))?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: p
                        .get("name")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| anyhow!("param missing name"))?
                        .to_string(),
                    shape: p
                        .get("shape")
                        .and_then(|v| v.as_usize_vec())
                        .ok_or_else(|| anyhow!("param missing shape"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(ModelEntry {
            head: str_field("head")?,
            train: str_field("train")?,
            eval: str_field("eval")?,
            predict: j.get("predict").and_then(|v| v.as_str()).map(|s| s.to_string()),
            init_params: str_field("init_params")?,
            batch: usize_field("batch")?,
            img: j
                .get("img")
                .and_then(|v| v.as_usize_vec())
                .ok_or_else(|| anyhow!("model missing img"))?,
            num_classes: usize_field("num_classes")?,
            param_schema,
            head_param_count: usize_field("head_param_count")?,
            total_param_count: usize_field("total_param_count")?,
            sketch: j.get("sketch").and_then(|v| v.as_usize_vec()),
            cts_c: j.get("cts_c").and_then(|v| v.as_usize()),
        })
    }

    fn op_from_json(j: &Json) -> Result<OpEntry> {
        Ok(OpEntry {
            path: j
                .get("path")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("op missing path"))?
                .to_string(),
            batch: j.get("batch").and_then(|v| v.as_usize()),
            input_dims: j.get("input_dims").and_then(|v| v.as_usize_vec()).unwrap_or_default(),
            sketch_dims: j
                .get("sketch_dims")
                .and_then(|v| v.as_usize_vec())
                .ok_or_else(|| anyhow!("op missing sketch_dims"))?,
            hashes: j
                .get("hashes")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().map(OpHash::from_json).collect::<Result<Vec<_>>>())
                .transpose()?
                .unwrap_or_default(),
        })
    }

    /// Load a model's initial parameters as per-tensor f32 buffers.
    pub fn load_init_params(&self, model: &str) -> Result<Vec<Vec<f32>>> {
        let entry = self
            .models
            .get(model)
            .ok_or_else(|| anyhow!("unknown model {model:?}"))?;
        let raw = std::fs::read(self.dir.join(&entry.init_params))?;
        let expect = entry.param_len() * 4;
        if raw.len() != expect {
            bail!(
                "param file {} has {} bytes, schema wants {}",
                entry.init_params,
                raw.len(),
                expect
            );
        }
        let mut out = Vec::with_capacity(entry.param_schema.len());
        let mut off = 0usize;
        for spec in &entry.param_schema {
            let n = spec.len();
            let mut buf = Vec::with_capacity(n);
            for i in 0..n {
                let b = &raw[(off + i) * 4..(off + i) * 4 + 4];
                buf.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
            }
            off += n;
            out.push(buf);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from(crate::runtime::DEFAULT_ARTIFACTS_DIR);
        if d.join("manifest.json").exists() {
            Some(d)
        } else {
            None
        }
    }

    #[test]
    fn manifest_parses_when_built() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.ops.contains_key("mts_sketch"));
        assert!(m.ops.contains_key("kron_combine"));
        assert!(!m.models.is_empty());
        for (name, model) in &m.models {
            assert!(model.batch > 0, "{name}");
            assert!(!model.param_schema.is_empty(), "{name}");
        }
    }

    #[test]
    fn op_hashes_cover_input_dims() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        let op = &m.ops["mts_sketch"];
        assert_eq!(op.hashes.len(), op.input_dims.len());
        for (h, (&n, &mk)) in op
            .hashes
            .iter()
            .zip(op.input_dims.iter().zip(op.sketch_dims.iter()))
        {
            assert_eq!(h.buckets.len(), n);
            assert!(h.buckets.iter().all(|&b| b < mk));
            assert!(h.signs.iter().all(|&s| s == 1.0 || s == -1.0));
        }
    }

    #[test]
    fn init_params_match_schema() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        let name = m.models.keys().next().unwrap().clone();
        let params = m.load_init_params(&name).unwrap();
        let entry = &m.models[&name];
        assert_eq!(params.len(), entry.param_schema.len());
        for (buf, spec) in params.iter().zip(entry.param_schema.iter()) {
            assert_eq!(buf.len(), spec.len(), "{}", spec.name);
        }
    }
}
