//! The PJRT execution wrapper: compile-once / execute-many over the AOT
//! artifacts, with literal marshalling helpers.

use super::artifacts::Manifest;
use super::xla_stub as xla;
use anyhow::{anyhow, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

/// Runtime = PJRT CPU client + executable cache + manifest.
///
/// Not `Send` (the underlying client is a C++ object confined to one
/// thread); the coordinator owns one `Runtime` on its executor thread.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// compile + execute counters for the metrics endpoint
    pub compiles: std::cell::Cell<u64>,
    pub executions: std::cell::Cell<u64>,
}

impl Runtime {
    /// Create a runtime over an artifacts directory.
    pub fn new(artifacts_dir: &str) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            compiles: std::cell::Cell::new(0),
            executions: std::cell::Cell::new(0),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the HLO-text artifact at
    /// `rel_path` (relative to the artifacts dir).
    pub fn load(&self, rel_path: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(rel_path) {
            return Ok(exe.clone());
        }
        let full = self.manifest.dir.join(rel_path);
        let full_str = full
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 path {full:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(full_str)
            .map_err(|e| anyhow!("parsing HLO text {rel_path}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {rel_path}: {e:?}"))?;
        self.compiles.set(self.compiles.get() + 1);
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(rel_path.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact with the given input literals; returns the
    /// flattened output tuple.
    pub fn execute(
        &self,
        rel_path: &str,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.load(rel_path)?;
        self.execute_loaded(&exe, inputs)
    }

    /// Execute an already-loaded executable (the hot path: no cache
    /// lookup, no path hashing).
    pub fn execute_loaded(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        self.executions.set(self.executions.get() + 1);
        let buffer = &result[0][0];
        let lit = buffer
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        // aot.py lowers with return_tuple=True — always a tuple
        lit.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))
    }
}

// ---------------------------------------------------------------------
// literal marshalling
// ---------------------------------------------------------------------

/// Build an f32 literal of the given shape from a slice.
pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "literal_f32: {} vs {:?}", data.len(), dims);
    let lit = xla::Literal::vec1(data);
    if dims.len() == 1 {
        return Ok(lit);
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims_i64).map_err(|e| anyhow!("reshape: {e:?}"))
}

/// Build an i32 literal of the given shape.
pub fn literal_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "literal_i32: {} vs {:?}", data.len(), dims);
    let lit = xla::Literal::vec1(data);
    if dims.len() == 1 {
        return Ok(lit);
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims_i64).map_err(|e| anyhow!("reshape: {e:?}"))
}

/// Scalar f32 literal.
pub fn literal_scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Read an f32 literal back into a Vec.
pub fn literal_to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec<f32>: {e:?}"))
}

/// Read a scalar f32 out of a literal.
pub fn literal_scalar_value(lit: &xla::Literal) -> Result<f32> {
    let v = literal_to_f32(lit)?;
    v.first().copied().context("empty literal")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn runtime() -> Option<Runtime> {
        if !crate::runtime::artifacts_available(crate::runtime::DEFAULT_ARTIFACTS_DIR) {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Runtime::new(crate::runtime::DEFAULT_ARTIFACTS_DIR).unwrap())
    }

    #[test]
    fn mts_op_matches_manifest_hash_scatter() {
        // The decisive integration test: the AOT Pallas kernel's output
        // must equal a plain Rust scatter driven by the manifest hash
        // tables — proving the L1↔L3 contract end to end.
        let Some(rt) = runtime() else { return };
        let op = rt.manifest().ops["mts_sketch"].clone();
        let (n1, n2) = (op.input_dims[0], op.input_dims[1]);
        let (m1, m2) = (op.sketch_dims[0], op.sketch_dims[1]);
        let mut rng = Pcg64::new(7);
        let x: Vec<f32> = (0..n1 * n2).map(|_| rng.normal() as f32).collect();
        let lit = literal_f32(&x, &[n1, n2]).unwrap();
        let out = rt.execute(&op.path, &[lit]).unwrap();
        let got = literal_to_f32(&out[0]).unwrap();
        assert_eq!(got.len(), m1 * m2);
        // rust-side scatter with the exported hashes
        let mut want = vec![0.0f64; m1 * m2];
        for i in 0..n1 {
            for j in 0..n2 {
                let b = op.hashes[0].buckets[i] * m2 + op.hashes[1].buckets[j];
                want[b] += op.hashes[0].signs[i]
                    * op.hashes[1].signs[j]
                    * x[i * n2 + j] as f64;
            }
        }
        for (g, w) in got.iter().zip(want.iter()) {
            assert!((*g as f64 - w).abs() < 1e-3, "{g} vs {w}");
        }
    }

    #[test]
    fn kron_combine_op_matches_rust_fft() {
        let Some(rt) = runtime() else { return };
        let op = rt.manifest().ops["kron_combine"].clone();
        let (m1, m2) = (op.sketch_dims[0], op.sketch_dims[1]);
        let mut rng = Pcg64::new(8);
        let a: Vec<f32> = (0..m1 * m2).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..m1 * m2).map(|_| rng.normal() as f32).collect();
        let la = literal_f32(&a, &[m1, m2]).unwrap();
        let lb = literal_f32(&b, &[m1, m2]).unwrap();
        let out = rt.execute(&op.path, &[la, lb]).unwrap();
        let got = literal_to_f32(&out[0]).unwrap();
        let af: Vec<f64> = a.iter().map(|&v| v as f64).collect();
        let bf: Vec<f64> = b.iter().map(|&v| v as f64).collect();
        let want = crate::fft::circular_convolve2(&af, &bf, m1, m2);
        for (g, w) in got.iter().zip(want.iter()) {
            assert!((*g as f64 - w).abs() < 1e-2, "{g} vs {w}");
        }
    }

    #[test]
    fn executable_cache_compiles_once() {
        let Some(rt) = runtime() else { return };
        let op = rt.manifest().ops["kron_combine"].clone();
        let _ = rt.load(&op.path).unwrap();
        let before = rt.compiles.get();
        let _ = rt.load(&op.path).unwrap();
        assert_eq!(rt.compiles.get(), before, "second load must hit cache");
    }
}
