//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! (HLO text + manifest.json + parameter binaries) and executes them on
//! the PJRT CPU client via the `xla` crate.
//!
//! Python never runs here — the HLO text was lowered once at build time
//! (`make artifacts`); this module is the entire request-path compute
//! story:
//!
//! ```text
//! HloModuleProto::from_text_file → XlaComputation → client.compile →
//! executable cache → execute(literals) → decompose output tuple
//! ```
//!
//! The PJRT client is not `Send`; the coordinator confines it to one
//! executor thread (see [`crate::coordinator`]).

pub mod artifacts;
pub mod client;
pub mod xla_stub;

pub use artifacts::{Manifest, ModelEntry, OpEntry, OpHash};
pub use client::Runtime;

/// Default artifacts directory (relative to the repo root / CWD).
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";

/// True if the artifacts have been built (manifest present).
pub fn artifacts_available(dir: &str) -> bool {
    std::path::Path::new(dir).join("manifest.json").exists()
}
