//! Build-time stub for the `xla` PJRT bindings.
//!
//! The offline crate set does not include the `xla` crate, so the
//! runtime layer compiles against this API-compatible stand-in instead.
//! Constructors that only wrap host data ([`Literal::vec1`],
//! [`Literal::scalar`]) succeed; anything that would need the real PJRT
//! C++ client returns a [`XlaError`] at *runtime*. The coordinator's
//! `BackendKind::PureRust` path never touches these entry points, and
//! every artifact-gated test skips when `artifacts/manifest.json` is
//! absent, so the stub keeps the full tree building and testing without
//! the native toolchain. Swapping the real crate back in is a two-line
//! change in `runtime/client.rs` and `train/trainer.rs` (the `use ...
//! as xla` aliases).

use std::fmt;

/// Error type mirroring the real crate's debug-printable error.
pub struct XlaError(pub String);

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "XlaError({})", self.0)
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

fn unavailable<T>(what: &str) -> Result<T, XlaError> {
    Err(XlaError(format!(
        "{what}: PJRT/XLA support is stubbed out in this build (the `xla` crate is not in \
         the offline crate set); use BackendKind::PureRust"
    )))
}

/// PJRT CPU client stand-in. [`PjRtClient::cpu`] always fails, so no
/// downstream stub method is ever reached through a live client.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self, XlaError> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        unavailable("PjRtClient::compile")
    }
}

/// Parsed HLO module stand-in.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, XlaError> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Computation wrapper stand-in.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Loaded-executable stand-in.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Device-buffer stand-in.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Host-literal stand-in. Construction succeeds (it only wraps host
/// data in the real crate too); data extraction and reshape fail.
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Self {
        Literal
    }

    pub fn scalar(_v: f32) -> Self {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        unavailable("Literal::reshape")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        unavailable("Literal::to_tuple")
    }

    pub fn array_shape(&self) -> Result<ArrayShape, XlaError> {
        unavailable("Literal::array_shape")
    }
}

/// Shape stand-in returned by [`Literal::array_shape`].
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_gracefully() {
        let err = PjRtClient::cpu().err().expect("stub must not pretend to work");
        assert!(format!("{err:?}").contains("stubbed"));
    }

    #[test]
    fn literals_construct_but_do_not_extract() {
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.to_vec::<f32>().is_err());
        assert!(Literal::scalar(1.0).reshape(&[1]).is_err());
    }
}
