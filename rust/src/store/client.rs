//! Blocking TCP client for the store's framed wire protocol — used by
//! the `hocs store-client` CLI, the end-to-end tests, and `bench_store`.
//!
//! One request in flight per connection (the protocol is strictly
//! request/response); open several clients for pipelining.

use super::codec::{self, Reader};
use super::mergeable::MergeableSketch;
use super::server::{op, read_frame, write_frame, STATUS_OK};
use super::sharded::StoreStats;
use crate::sketch::stream::StreamSketch;
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::net::{TcpStream, ToSocketAddrs};

pub struct StoreClient {
    stream: TcpStream,
}

impl StoreClient {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<Self> {
        let stream = TcpStream::connect(addr).context("connecting to store server")?;
        let _ = stream.set_nodelay(true);
        Ok(Self { stream })
    }

    /// Send one raw request payload and return the response body, with
    /// server-side errors surfaced as `Err`. Exposed for protocol tests;
    /// the typed methods below are the real API.
    pub fn raw_call(&mut self, req: &[u8]) -> Result<Vec<u8>> {
        write_frame(&mut self.stream, req)?;
        let resp = read_frame(&mut self.stream)?
            .ok_or_else(|| anyhow!("server closed the connection"))?;
        ensure!(!resp.is_empty(), "empty response frame");
        if resp[0] == STATUS_OK {
            Ok(resp[1..].to_vec())
        } else {
            bail!("store server: {}", String::from_utf8_lossy(&resp[1..]))
        }
    }

    fn key_req(opcode: u8, i: usize, j: usize) -> Result<Vec<u8>> {
        let mut req = vec![opcode];
        codec::put_u32(&mut req, u32::try_from(i).context("row key exceeds u32")?);
        codec::put_u32(&mut req, u32::try_from(j).context("col key exceeds u32")?);
        Ok(req)
    }

    /// Count key `(i, j)` with weight `w`.
    pub fn update(&mut self, i: usize, j: usize, w: f64) -> Result<()> {
        let mut req = Self::key_req(op::UPDATE, i, j)?;
        codec::put_f64(&mut req, w);
        self.raw_call(&req).map(|_| ())
    }

    /// Ship a whole batch of updates in one frame (the write hot path):
    /// the server applies it with one WAL group-commit frame — one
    /// append + flush/fsync for the entire batch — and one shard-lock
    /// acquisition per destination shard, all-or-nothing on validation.
    pub fn update_batch(&mut self, items: &[(u32, u32, f64)]) -> Result<()> {
        let mut req = vec![op::UPDATE_BATCH];
        codec::put_u32(&mut req, u32::try_from(items.len()).context("batch exceeds u32")?);
        for &(i, j, w) in items {
            codec::put_update(&mut req, i, j, w);
        }
        self.raw_call(&req).map(|_| ())
    }

    /// Windowed point estimate for key `(i, j)`.
    pub fn query(&mut self, i: usize, j: usize) -> Result<f64> {
        let req = Self::key_req(op::QUERY, i, j)?;
        let body = self.raw_call(&req)?;
        Reader::new(&body).f64()
    }

    /// The k heaviest keys in the live window.
    pub fn top_k(&mut self, k: usize) -> Result<Vec<(usize, usize, f64)>> {
        let mut req = vec![op::TOPK];
        codec::put_u32(&mut req, u32::try_from(k).context("k exceeds u32")?);
        let body = self.raw_call(&req)?;
        parse_entries(&body)
    }

    /// All keys with windowed weight ≥ `threshold`.
    pub fn heavy_hitters(&mut self, threshold: f64) -> Result<Vec<(usize, usize, f64)>> {
        let mut req = vec![op::HEAVY];
        codec::put_f64(&mut req, threshold);
        let body = self.raw_call(&req)?;
        parse_entries(&body)
    }

    /// Merge a locally-built same-family sketch into the server's store.
    pub fn merge(&mut self, sk: &StreamSketch) -> Result<()> {
        let mut req = vec![op::MERGE];
        sk.encode(&mut req);
        self.raw_call(&req).map(|_| ())
    }

    /// Force a snapshot + WAL truncation on the server.
    pub fn snapshot(&mut self) -> Result<()> {
        self.raw_call(&[op::SNAPSHOT]).map(|_| ())
    }

    /// Slide the server's window one epoch.
    pub fn advance_epoch(&mut self) -> Result<()> {
        self.raw_call(&[op::ADVANCE_EPOCH]).map(|_| ())
    }

    pub fn stats(&mut self) -> Result<StoreStats> {
        let body = self.raw_call(&[op::STATS])?;
        let mut rd = Reader::new(&body);
        Ok(StoreStats {
            shards: rd.u32()? as usize,
            window: rd.u32()? as usize,
            epoch: rd.u64()?,
            updates: rd.u64()?,
        })
    }

    /// Run one count-sketch job through the server's coordinator pool
    /// (requires the server to be started `with_coordinator`).
    pub fn batch_sketch(&mut self, x: &[f32]) -> Result<Vec<f32>> {
        let mut req = vec![op::BATCH_SKETCH];
        codec::put_u32(&mut req, u32::try_from(x.len()).context("input exceeds u32")?);
        for &v in x {
            codec::put_f32(&mut req, v);
        }
        let body = self.raw_call(&req)?;
        let mut rd = Reader::new(&body);
        let n = rd.u32()? as usize;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(rd.f32()?);
        }
        Ok(out)
    }

    /// Ask the server to stop accepting connections and exit.
    pub fn shutdown_server(&mut self) -> Result<()> {
        self.raw_call(&[op::SHUTDOWN]).map(|_| ())
    }
}

fn parse_entries(body: &[u8]) -> Result<Vec<(usize, usize, f64)>> {
    let mut rd = Reader::new(body);
    let n = rd.u32()? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let (i, j) = (rd.u32()? as usize, rd.u32()? as usize);
        out.push((i, j, rd.f64()?));
    }
    Ok(out)
}
