//! Blocking TCP client for the store's framed wire protocol — used by
//! the `hocs store-client` CLI, the end-to-end tests, and `bench_store`.
//!
//! One request in flight per connection (the protocol is strictly
//! request/response); open several clients for pipelining. The request
//! and response buffers live on the client and are reused across calls,
//! so a settled RPC loop performs no per-call heap allocation on the
//! wire path (typed results that return owned lists still allocate
//! their output).

use super::codec::{self, Reader};
use super::mergeable::MergeableSketch;
use super::server::{op, read_frame_into, write_frame, STATUS_OK};
use super::sharded::StoreStats;
use crate::sketch::stream::StreamSketch;
use anyhow::{bail, ensure, Context, Result};
use std::net::{TcpStream, ToSocketAddrs};

pub struct StoreClient {
    stream: TcpStream,
    /// request scratch, reused across calls
    req: Vec<u8>,
    /// response scratch, reused across calls
    resp: Vec<u8>,
}

impl StoreClient {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<Self> {
        let stream = TcpStream::connect(addr).context("connecting to store server")?;
        let _ = stream.set_nodelay(true);
        Ok(Self { stream, req: Vec::new(), resp: Vec::new() })
    }

    /// Start a request in the reused buffer.
    fn begin(&mut self, opcode: u8) -> &mut Vec<u8> {
        self.req.clear();
        self.req.push(opcode);
        &mut self.req
    }

    /// Send the staged request and read the response into the reused
    /// buffer, surfacing server-side errors as `Err`. Returns the
    /// response body (after the status byte), borrowed from the buffer.
    fn call(&mut self) -> Result<&[u8]> {
        write_frame(&mut self.stream, &self.req)?;
        ensure!(
            read_frame_into(&mut self.stream, &mut self.resp)?,
            "server closed the connection"
        );
        ensure!(!self.resp.is_empty(), "empty response frame");
        if self.resp[0] == STATUS_OK {
            Ok(&self.resp[1..])
        } else {
            bail!("store server: {}", String::from_utf8_lossy(&self.resp[1..]))
        }
    }

    /// Send one raw request payload and return the response body, with
    /// server-side errors surfaced as `Err`. Exposed for protocol tests;
    /// the typed methods below are the real API.
    pub fn raw_call(&mut self, req: &[u8]) -> Result<Vec<u8>> {
        self.req.clear();
        self.req.extend_from_slice(req);
        self.call().map(|body| body.to_vec())
    }

    fn put_key(req: &mut Vec<u8>, i: usize, j: usize) -> Result<()> {
        codec::put_u32(req, u32::try_from(i).context("row key exceeds u32")?);
        codec::put_u32(req, u32::try_from(j).context("col key exceeds u32")?);
        Ok(())
    }

    /// Count key `(i, j)` with weight `w`.
    pub fn update(&mut self, i: usize, j: usize, w: f64) -> Result<()> {
        let req = self.begin(op::UPDATE);
        Self::put_key(req, i, j)?;
        codec::put_f64(req, w);
        self.call().map(|_| ())
    }

    /// Ship a whole batch of updates in one frame (the write hot path):
    /// the server applies it with one WAL group-commit frame — one
    /// append + flush/fsync for the entire batch — and one shard-lock
    /// acquisition per destination shard, all-or-nothing on validation.
    pub fn update_batch(&mut self, items: &[(u32, u32, f64)]) -> Result<()> {
        let req = self.begin(op::UPDATE_BATCH);
        codec::put_u32(req, u32::try_from(items.len()).context("batch exceeds u32")?);
        for &(i, j, w) in items {
            codec::put_update(req, i, j, w);
        }
        self.call().map(|_| ())
    }

    /// Windowed point estimate for key `(i, j)`.
    pub fn query(&mut self, i: usize, j: usize) -> Result<f64> {
        let req = self.begin(op::QUERY);
        Self::put_key(req, i, j)?;
        let body = self.call()?;
        Reader::new(body).f64()
    }

    /// The k heaviest keys in the live window.
    pub fn top_k(&mut self, k: usize) -> Result<Vec<(usize, usize, f64)>> {
        let req = self.begin(op::TOPK);
        codec::put_u32(req, u32::try_from(k).context("k exceeds u32")?);
        let body = self.call()?;
        parse_entries(body)
    }

    /// All keys with windowed weight ≥ `threshold`.
    pub fn heavy_hitters(&mut self, threshold: f64) -> Result<Vec<(usize, usize, f64)>> {
        let req = self.begin(op::HEAVY);
        codec::put_f64(req, threshold);
        let body = self.call()?;
        parse_entries(body)
    }

    /// Merge a locally-built same-family sketch into the server's store.
    pub fn merge(&mut self, sk: &StreamSketch) -> Result<()> {
        let req = self.begin(op::MERGE);
        sk.encode(req);
        self.call().map(|_| ())
    }

    /// Force a snapshot + WAL truncation on the server.
    pub fn snapshot(&mut self) -> Result<()> {
        self.begin(op::SNAPSHOT);
        self.call().map(|_| ())
    }

    /// Slide the server's window one epoch.
    pub fn advance_epoch(&mut self) -> Result<()> {
        self.begin(op::ADVANCE_EPOCH);
        self.call().map(|_| ())
    }

    pub fn stats(&mut self) -> Result<StoreStats> {
        self.begin(op::STATS);
        let body = self.call()?;
        let mut rd = Reader::new(body);
        Ok(StoreStats {
            shards: rd.u32()? as usize,
            window: rd.u32()? as usize,
            epoch: rd.u64()?,
            updates: rd.u64()?,
        })
    }

    /// Run one count-sketch job through the server's coordinator pool
    /// (requires the server to be started `with_coordinator`).
    pub fn batch_sketch(&mut self, x: &[f32]) -> Result<Vec<f32>> {
        let req = self.begin(op::BATCH_SKETCH);
        codec::put_u32(req, u32::try_from(x.len()).context("input exceeds u32")?);
        for &v in x {
            codec::put_f32(req, v);
        }
        let body = self.call()?;
        let mut rd = Reader::new(body);
        let n = rd.u32()? as usize;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(rd.f32()?);
        }
        Ok(out)
    }

    /// Ask the server to stop accepting connections and exit.
    pub fn shutdown_server(&mut self) -> Result<()> {
        self.begin(op::SHUTDOWN);
        self.call().map(|_| ())
    }
}

fn parse_entries(body: &[u8]) -> Result<Vec<(usize, usize, f64)>> {
    let mut rd = Reader::new(body);
    let n = rd.u32()? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let (i, j) = (rd.u32()? as usize, rd.u32()? as usize);
        out.push((i, j, rd.f64()?));
    }
    Ok(out)
}
