//! Blocking TCP client for the store's framed wire protocol — used by
//! the `hocs store-client` CLI, the replicator, the end-to-end tests,
//! and `bench_store`.
//!
//! One request in flight per connection (the protocol is strictly
//! request/response); open several clients for pipelining. The request
//! and response buffers live on the client and are reused across calls,
//! so a settled RPC loop performs no per-call heap allocation on the
//! wire path (typed results that return owned lists still allocate
//! their output).
//!
//! [`StoreClient::connect_with`] takes [`ClientOptions`]: a connect
//! timeout and a read/write timeout. Without them a hung or
//! half-partitioned peer blocks the caller forever — fatal for the
//! replicator (one dead peer would stall anti-entropy to every peer)
//! and bad for the CLI; with them every RPC fails within a bound and
//! the caller decides whether to back off and reconnect.
//!
//! **Retry policy.** Read-only RPCs (QUERY / TOPK / HEAVY / STATS and
//! the tensor reads TQUERY / MARGINAL / SLICE_TOPK / CONTRACT) are
//! idempotent, so a transport failure triggers one automatic
//! reconnect-and-retry of the identical request — a server restart or
//! an idle-timeout disconnect costs the caller nothing. Everything
//! else (UPDATE / UPDATE_BATCH / MERGE / SNAPSHOT / ADVANCE_EPOCH /
//! SHUTDOWN and the tensor writes) never retries: after an ambiguous
//! transport failure the request may have been applied, and a blind
//! re-send would
//! double-count (headerless writes carry no origin sequence for the
//! server to dedup). Server-side `STATUS_ERR` rejections are never
//! retried either — the connection is healthy and the answer is final.

use super::codec::{self, Reader};
use super::mergeable::MergeableSketch;
use super::replica::{wire, ReplicationStats};
use super::server::{read_frame_into, write_frame};
use super::wire_ops::{self as op, STATUS_OK};
use super::sharded::StoreStats;
use super::tensor::{ContractedSketch, HcsStream, TensorFamily};
use crate::sketch::stream::StreamSketch;
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::Duration;

/// Prefix every server-side (STATUS_ERR) rejection carries, as opposed
/// to transport failures. One shared const because the replicator
/// classifies failures on it (server rejection = connection healthy,
/// keep the frame staged; transport = reconnect + backoff): a reworded
/// literal would silently break that routing, a reworded const cannot.
pub(crate) const SERVER_ERR_PREFIX: &str = "store server: ";

/// Connection-robustness knobs for [`StoreClient::connect_with`].
/// `None` = block indefinitely (the pre-replication behaviour).
#[derive(Clone, Copy, Debug, Default)]
pub struct ClientOptions {
    pub connect_timeout: Option<Duration>,
    pub io_timeout: Option<Duration>,
}

impl ClientOptions {
    /// Both timeouts set to `ms` milliseconds (`0` = no timeouts).
    pub fn timeout_ms(ms: u64) -> Self {
        if ms == 0 {
            Self::default()
        } else {
            let t = Some(Duration::from_millis(ms));
            Self { connect_timeout: t, io_timeout: t }
        }
    }
}

pub struct StoreClient {
    stream: TcpStream,
    /// resolved server addresses — kept so idempotent RPCs can
    /// reconnect-and-retry after a transient disconnect
    addrs: Vec<SocketAddr>,
    opts: ClientOptions,
    /// request scratch, reused across calls
    req: Vec<u8>,
    /// response scratch, reused across calls
    resp: Vec<u8>,
}

impl StoreClient {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<Self> {
        Self::connect_with(addr, ClientOptions::default())
    }

    /// [`StoreClient::connect`] with bounded connect and per-RPC I/O
    /// timeouts. A timed-out RPC surfaces as an error; the connection
    /// should then be considered dead (a late response would desynchronize
    /// the request/response framing), so reconnect before retrying.
    pub fn connect_with<A: ToSocketAddrs>(addr: A, opts: ClientOptions) -> Result<Self> {
        let addrs: Vec<SocketAddr> =
            addr.to_socket_addrs().context("resolving store server address")?.collect();
        ensure!(!addrs.is_empty(), "store server address resolved to nothing");
        let stream = Self::open_stream(&addrs, opts)?;
        Ok(Self { stream, addrs, opts, req: Vec::new(), resp: Vec::new() })
    }

    /// Dial the first reachable resolved address and apply the I/O
    /// options — shared by first connect and idempotent-retry reconnect.
    fn open_stream(addrs: &[SocketAddr], opts: ClientOptions) -> Result<TcpStream> {
        let mut last_err = None;
        let mut connected = None;
        for a in addrs {
            let attempt = match opts.connect_timeout {
                None => TcpStream::connect(a),
                Some(timeout) => TcpStream::connect_timeout(a, timeout),
            };
            match attempt {
                Ok(s) => {
                    connected = Some(s);
                    break;
                }
                Err(e) => last_err = Some(e),
            }
        }
        let stream = connected.ok_or_else(|| {
            anyhow!(
                "connecting to store server: {}",
                last_err.expect("at least one address attempted")
            )
        })?;
        stream.set_read_timeout(opts.io_timeout).context("setting read timeout")?;
        stream.set_write_timeout(opts.io_timeout).context("setting write timeout")?;
        let _ = stream.set_nodelay(true);
        Ok(stream)
    }

    /// Start a request in the reused buffer.
    fn begin(&mut self, opcode: u8) -> &mut Vec<u8> {
        self.req.clear();
        self.req.push(opcode);
        &mut self.req
    }

    /// Send the staged request and read the raw response frame into the
    /// reused buffer. An `Err` here is a *transport* failure (or a clean
    /// close) — the staged request is intact and can be re-sent on a
    /// fresh connection if (and only if) it is idempotent.
    fn exchange(&mut self) -> Result<()> {
        write_frame(&mut self.stream, &self.req)?;
        ensure!(
            read_frame_into(&mut self.stream, &mut self.resp)?,
            "server closed the connection"
        );
        ensure!(!self.resp.is_empty(), "empty response frame");
        Ok(())
    }

    /// The received response body, surfacing server-side `STATUS_ERR`
    /// rejections as `Err` (never retried: the connection is healthy
    /// and the rejection is the answer).
    fn body(&self) -> Result<&[u8]> {
        if self.resp[0] == STATUS_OK {
            Ok(&self.resp[1..])
        } else {
            bail!("{SERVER_ERR_PREFIX}{}", String::from_utf8_lossy(&self.resp[1..]))
        }
    }

    /// One shot: exactly one delivery attempt — the write path, where a
    /// retried request could double-count.
    fn call(&mut self) -> Result<&[u8]> {
        self.exchange()?;
        self.body()
    }

    /// [`StoreClient::call`] with one automatic reconnect-and-retry on
    /// transport failure — only for idempotent (read-only) RPCs, where
    /// re-delivering the identical request cannot change server state.
    fn call_idempotent(&mut self) -> Result<&[u8]> {
        if let Err(e) = self.exchange() {
            self.stream = Self::open_stream(&self.addrs, self.opts)
                .with_context(|| format!("reconnecting after transport error ({e})"))?;
            self.exchange()?;
        }
        self.body()
    }

    /// Send one raw request payload and return the response body, with
    /// server-side errors surfaced as `Err`. Exposed for protocol tests;
    /// the typed methods below are the real API.
    pub fn raw_call(&mut self, req: &[u8]) -> Result<Vec<u8>> {
        self.req.clear();
        self.req.extend_from_slice(req);
        self.call().map(|body| body.to_vec())
    }

    fn put_key(req: &mut Vec<u8>, i: usize, j: usize) -> Result<()> {
        codec::put_u32(req, u32::try_from(i).context("row key exceeds u32")?);
        codec::put_u32(req, u32::try_from(j).context("col key exceeds u32")?);
        Ok(())
    }

    /// Count key `(i, j)` with weight `w`.
    pub fn update(&mut self, i: usize, j: usize, w: f64) -> Result<()> {
        let req = self.begin(op::UPDATE);
        Self::put_key(req, i, j)?;
        codec::put_f64(req, w);
        self.call().map(|_| ())
    }

    /// Ship a whole batch of updates in one frame (the write hot path):
    /// the server applies it with one WAL group-commit frame — one
    /// append + flush/fsync for the entire batch — and one shard-lock
    /// acquisition per destination shard, all-or-nothing on validation.
    pub fn update_batch(&mut self, items: &[(u32, u32, f64)]) -> Result<()> {
        let req = self.begin(op::UPDATE_BATCH);
        codec::put_u32(req, u32::try_from(items.len()).context("batch exceeds u32")?);
        for &(i, j, w) in items {
            codec::put_update(req, i, j, w);
        }
        self.call().map(|_| ())
    }

    /// Windowed point estimate for key `(i, j)`. Idempotent: retried
    /// once on a fresh connection after a transient disconnect.
    pub fn query(&mut self, i: usize, j: usize) -> Result<f64> {
        let req = self.begin(op::QUERY);
        Self::put_key(req, i, j)?;
        let body = self.call_idempotent()?;
        Reader::new(body).f64()
    }

    /// The k heaviest keys in the live window. Idempotent: retried once
    /// on a fresh connection after a transient disconnect.
    pub fn top_k(&mut self, k: usize) -> Result<Vec<(usize, usize, f64)>> {
        let req = self.begin(op::TOPK);
        codec::put_u32(req, u32::try_from(k).context("k exceeds u32")?);
        let body = self.call_idempotent()?;
        parse_entries(body)
    }

    /// All keys with windowed weight ≥ `threshold`. Idempotent: retried
    /// once on a fresh connection after a transient disconnect.
    pub fn heavy_hitters(&mut self, threshold: f64) -> Result<Vec<(usize, usize, f64)>> {
        let req = self.begin(op::HEAVY);
        codec::put_f64(req, threshold);
        let body = self.call_idempotent()?;
        parse_entries(body)
    }

    /// Merge a locally-built same-family sketch into the server's store
    /// (legacy headerless MERGE: exact, but a retry double-counts — use
    /// [`StoreClient::merge_origin`] when the call may be retried).
    pub fn merge(&mut self, sk: &StreamSketch) -> Result<()> {
        let req = self.begin(op::MERGE);
        sk.encode(req);
        self.call().map(|_| ())
    }

    /// Origin-headered merge: retry-safe via the server's per-origin
    /// dedup window. Returns `true` when the frame was applied, `false`
    /// when it was recognized as an already-applied retry (both are
    /// success — the mass is in). `full` ships the sketch as cumulative
    /// origin state (the server applies only the unseen remainder);
    /// `ingest` marks the mass as this node's own traffic, re-originated
    /// to its replication peers. Sequences must increase by one per
    /// acknowledged frame on an (origin, server) channel; a skipped
    /// delta sequence is rejected with a gap error that a full ship
    /// heals.
    pub fn merge_origin(
        &mut self,
        origin: u64,
        seq: u64,
        full: bool,
        ingest: bool,
        sk: &StreamSketch,
    ) -> Result<bool> {
        let mode = if full { wire::MODE_FULL } else { wire::MODE_DELTA };
        let frame = wire::build_merge_origin(origin, seq, mode, ingest, sk);
        let body = self.raw_call(&frame)?;
        Ok(body.first().copied() == Some(1))
    }

    /// Force a snapshot + WAL truncation on the server.
    pub fn snapshot(&mut self) -> Result<()> {
        self.begin(op::SNAPSHOT);
        self.call().map(|_| ())
    }

    /// Slide the server's window one epoch.
    pub fn advance_epoch(&mut self) -> Result<()> {
        self.begin(op::ADVANCE_EPOCH);
        self.call().map(|_| ())
    }

    pub fn stats(&mut self) -> Result<StoreStats> {
        self.stats_full().map(|(st, _)| st)
    }

    /// [`StoreClient::stats`] plus the replication counters (peer
    /// count, last-sync age, cursor version, ship/byte/dedup totals).
    /// `None` for pre-replication servers whose STATS body ends after
    /// the store fields. Idempotent: retried once on a fresh connection
    /// after a transient disconnect.
    pub fn stats_full(&mut self) -> Result<(StoreStats, Option<ReplicationStats>)> {
        self.begin(op::STATS);
        let body = self.call_idempotent()?;
        let mut rd = Reader::new(body);
        let store = StoreStats {
            shards: rd.u32()? as usize,
            window: rd.u32()? as usize,
            epoch: rd.u64()?,
            updates: rd.u64()?,
        };
        if rd.is_empty() {
            return Ok((store, None));
        }
        let peers = rd.u32()? as u64;
        let has_sync = rd.u8()? == 1;
        let age = rd.u64()?;
        let repl = ReplicationStats {
            peers,
            last_sync_age_ms: has_sync.then_some(age),
            cursor_version: rd.u64()?,
            ships: rd.u64()?,
            full_ships: rd.u64()?,
            bytes_shipped: rd.u64()?,
            merges_applied: rd.u64()?,
            merges_deduped: rd.u64()?,
        };
        Ok((store, Some(repl)))
    }

    /// Run one count-sketch job through the server's coordinator pool
    /// (requires the server to be started `with_coordinator`).
    pub fn batch_sketch(&mut self, x: &[f32]) -> Result<Vec<f32>> {
        let req = self.begin(op::BATCH_SKETCH);
        codec::put_u32(req, u32::try_from(x.len()).context("input exceeds u32")?);
        for &v in x {
            codec::put_f32(req, v);
        }
        let body = self.call()?;
        let mut rd = Reader::new(body);
        let n = rd.u32()? as usize;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(rd.f32()?);
        }
        Ok(out)
    }

    // ---------- tensor plane ----------

    /// Register a named HCS tensor on the server. Returns `true` when
    /// created, `false` when an identical tensor already existed (a
    /// different family for the same name is a server error).
    pub fn tensor_create(&mut self, name: &str, family: &TensorFamily) -> Result<bool> {
        let req = self.begin(op::TCREATE);
        codec::put_name(req, name);
        family.encode(req);
        let body = self.call()?;
        Ok(body.first().copied() == Some(1))
    }

    /// One multi-mode update: key `key` (one index per mode) with
    /// weight `w`. Never retried — not idempotent.
    pub fn tensor_update(&mut self, name: &str, key: &[usize], w: f64) -> Result<()> {
        let req = self.begin(op::TUPDATE);
        codec::put_name(req, name);
        codec::put_mode_key(req, key);
        codec::put_f64(req, w);
        self.call().map(|_| ())
    }

    /// Batched multi-mode updates in one frame: `keys` holds
    /// `ws.len() × order` flat indices. One WAL group-commit frame and
    /// one fused apply server-side, all-or-nothing on validation.
    pub fn tensor_update_batch(&mut self, name: &str, keys: &[usize], ws: &[f64]) -> Result<()> {
        if ws.is_empty() {
            return Ok(());
        }
        ensure!(
            keys.len() % ws.len() == 0,
            "batch of {} weights cannot split {} indices evenly",
            ws.len(),
            keys.len()
        );
        let order = keys.len() / ws.len();
        let req = self.begin(op::TUPDATE_BATCH);
        codec::put_name(req, name);
        codec::put_u32(req, u32::try_from(ws.len()).context("batch exceeds u32")?);
        for (key, &w) in keys.chunks_exact(order).zip(ws.iter()) {
            codec::put_mode_key(req, key);
            codec::put_f64(req, w);
        }
        self.call().map(|_| ())
    }

    /// Median-of-d point estimate for a multi-mode key. Idempotent:
    /// retried once on a fresh connection after a transient disconnect.
    pub fn tensor_query(&mut self, name: &str, key: &[usize]) -> Result<f64> {
        let req = self.begin(op::TQUERY);
        codec::put_name(req, name);
        codec::put_mode_key(req, key);
        let body = self.call_idempotent()?;
        Reader::new(body).f64()
    }

    /// Marginal with `Some(i)` modes pinned to index `i` and `None`
    /// modes summed out on the sketch (one spec entry per mode).
    /// Idempotent.
    pub fn tensor_marginal(&mut self, name: &str, spec: &[Option<usize>]) -> Result<f64> {
        let req = self.begin(op::MARGINAL);
        codec::put_name(req, name);
        for entry in spec {
            match entry {
                None => codec::put_u8(req, 0),
                Some(i) => {
                    codec::put_u8(req, 1);
                    codec::put_u32(req, u32::try_from(*i).context("mode index exceeds u32")?);
                }
            }
        }
        let body = self.call_idempotent()?;
        Reader::new(body).f64()
    }

    /// Top-k keys within the slice `mode = index`, heaviest first.
    /// Idempotent.
    pub fn tensor_slice_topk(
        &mut self,
        name: &str,
        mode: usize,
        index: usize,
        k: usize,
    ) -> Result<Vec<(Vec<usize>, f64)>> {
        let req = self.begin(op::SLICE_TOPK);
        codec::put_name(req, name);
        codec::put_u32(req, u32::try_from(mode).context("mode exceeds u32")?);
        codec::put_u32(req, u32::try_from(index).context("index exceeds u32")?);
        codec::put_u32(req, u32::try_from(k).context("k exceeds u32")?);
        let body = self.call_idempotent()?;
        let mut rd = Reader::new(body);
        let n = rd.u32()? as usize;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let order = rd.u8()? as usize;
            let mut key = Vec::with_capacity(order);
            for _ in 0..order {
                key.push(rd.u32()? as usize);
            }
            out.push((key, rd.f64()?));
        }
        Ok(out)
    }

    /// Server-side sketched contraction of two stored same-family
    /// tensors over `modes`. `want_dense` asks the server to densify a
    /// partial contraction (subject to its dense-output cap); a full
    /// contraction always comes back as a scalar. Idempotent.
    pub fn tensor_contract(
        &mut self,
        a_name: &str,
        b_name: &str,
        modes: &[usize],
        want_dense: bool,
    ) -> Result<TensorContraction> {
        let req = self.begin(op::CONTRACT);
        codec::put_name(req, a_name);
        codec::put_name(req, b_name);
        codec::put_u8(req, u8::try_from(modes.len()).context("mode count exceeds u8")?);
        for &m in modes {
            codec::put_u8(req, u8::try_from(m).context("mode id exceeds u8")?);
        }
        codec::put_u8(req, u8::from(want_dense));
        let body = self.call_idempotent()?;
        let mut rd = Reader::new(body);
        match rd.u8()? {
            0 => Ok(TensorContraction::Scalar(rd.f64()?)),
            1 => Ok(TensorContraction::Sketch(ContractedSketch::decode(&mut rd)?)),
            2 => {
                let order = rd.u8()? as usize;
                let mut dims = Vec::with_capacity(order);
                for _ in 0..order {
                    dims.push(rd.u32()? as usize);
                }
                let len = rd.u32()? as usize;
                let mut values = Vec::with_capacity(len);
                for _ in 0..len {
                    values.push(rd.f64()?);
                }
                Ok(TensorContraction::Dense { dims, values })
            }
            other => bail!("unknown contraction result kind {other}"),
        }
    }

    /// Tensor replication frame: ship `full` as origin `origin`'s
    /// cumulative state for tensor `name` at sequence `seq`. The server
    /// applies only the unseen remainder and dedups retries per
    /// (origin, tensor) channel, so this is safe to re-send. Returns
    /// `true` when mass was applied, `false` on a dedup.
    pub fn tensor_merge_origin(
        &mut self,
        origin: u64,
        seq: u64,
        name: &str,
        full: &HcsStream,
    ) -> Result<bool> {
        let req = self.begin(op::TMERGE_ORIGIN);
        codec::put_u64(req, origin);
        codec::put_u64(req, seq);
        codec::put_name(req, name);
        full.encode(req);
        let body = self.call()?;
        Ok(body.first().copied() == Some(1))
    }

    /// Scrape the server's observability plane: Prometheus-style text
    /// (see [`crate::obs`] for the metric catalog). Idempotent:
    /// retried once on a fresh connection after a transient
    /// disconnect.
    pub fn metrics(&mut self) -> Result<String> {
        self.begin(op::METRICS);
        let body = self.call_idempotent()?;
        Ok(String::from_utf8_lossy(body).into_owned())
    }

    /// Ask the server to stop accepting connections and exit.
    pub fn shutdown_server(&mut self) -> Result<()> {
        self.begin(op::SHUTDOWN);
        self.call().map(|_| ())
    }
}

/// A [`StoreClient::tensor_contract`] result: a scalar for a full
/// contraction, and for partial contractions either the sketched result
/// or its server-densified expansion (`values` laid out `kept keys of a
/// × kept keys of b`, row-major over `dims` twice).
#[derive(Debug)]
pub enum TensorContraction {
    Scalar(f64),
    Sketch(ContractedSketch),
    Dense { dims: Vec<usize>, values: Vec<f64> },
}

fn parse_entries(body: &[u8]) -> Result<Vec<(usize, usize, f64)>> {
    let mut rd = Reader::new(body);
    let n = rd.u32()? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let (i, j) = (rd.u32()? as usize, rd.u32()? as usize);
        out.push((i, j, rd.f64()?));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::server::{StoreServer, StoreServerConfig};
    use crate::store::sharded::StoreConfig;
    use std::net::TcpListener;
    use std::time::Instant;

    #[test]
    fn timeout_ms_zero_means_no_timeouts() {
        let opts = ClientOptions::timeout_ms(0);
        assert!(opts.connect_timeout.is_none() && opts.io_timeout.is_none());
        let opts = ClientOptions::timeout_ms(250);
        assert_eq!(opts.io_timeout, Some(Duration::from_millis(250)));
    }

    #[test]
    fn idempotent_reads_survive_a_disconnect_but_writes_do_not() {
        // a server that reaps idle connections quickly gives us a
        // deterministic transient disconnect to recover from
        let server = match StoreServer::start(StoreServerConfig {
            addr: "127.0.0.1:0".to_string(),
            store: StoreConfig {
                n1: 64,
                n2: 64,
                m1: 16,
                m2: 16,
                d: 5,
                seed: 99,
                shards: 2,
                window: 4,
            },
            read_timeout_ms: 50,
            ..Default::default()
        }) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("skipping: cannot bind loopback ({e})");
                return;
            }
        };
        let mut client = StoreClient::connect(server.local_addr()).unwrap();
        client.update(1, 1, 5.0).unwrap();
        // idle past the server's read timeout: the connection is dead,
        // and the idempotent read recovers through its one retry
        std::thread::sleep(Duration::from_millis(250));
        assert_eq!(client.query(1, 1).unwrap(), 5.0, "idempotent retry did not recover");
        assert_eq!(client.stats().unwrap().updates, 1);
        // writes never retry: the same disconnect surfaces as an error
        std::thread::sleep(Duration::from_millis(250));
        assert!(client.update(1, 1, 1.0).is_err(), "non-idempotent write was retried");
        // ... and the client recovers again on its next idempotent call
        assert_eq!(client.query(1, 1).unwrap(), 5.0);
        assert_eq!(client.stats().unwrap().updates, 1, "failed write landed anyway");
        server.shutdown();
    }

    #[test]
    fn io_timeout_bounds_an_unresponsive_server() {
        // a listener that accepts (kernel backlog) but never serves:
        // without an io timeout the query below would block forever —
        // exactly how a hung peer used to stall the replicator
        let listener = match TcpListener::bind("127.0.0.1:0") {
            Ok(l) => l,
            Err(e) => {
                eprintln!("skipping: cannot bind loopback ({e})");
                return;
            }
        };
        let addr = listener.local_addr().unwrap();
        let mut client =
            StoreClient::connect_with(addr, ClientOptions::timeout_ms(200)).unwrap();
        let t0 = Instant::now();
        assert!(client.query(1, 1).is_err(), "query against a mute server must fail");
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "timeout did not bound the hung RPC"
        );
    }
}
