//! K-way sharded, epoch-windowed sketch store.
//!
//! Layout: every shard owns a ring of `window` [`StreamSketch`] epoch
//! slots plus a running `total` (the elementwise sum of the live ring).
//! Updates hash their key to one shard, land in that shard's current
//! slot *and* its total; [`ShardedStore::advance_epoch`] rotates the
//! ring by **subtracting** the expiring slot from the total (linearity
//! again — no rescan, no accuracy loss) and clearing it for reuse.
//!
//! Queries exploit the same linearity in two directions:
//! - **fan-out** — a point query sums per-repeat *raw* bucket counters
//!   across shard totals, applies the ±1 signs once, and takes one
//!   median at the end: the summed counter equals the merged sketch's
//!   counter, so the estimate is *bit-identical* to querying a single
//!   sketch fed the whole stream (over exactly-representable update
//!   weights, where addition reassociates without rounding);
//! - **merge** — scans (top-k / heavy hitters) first add the shard
//!   totals into one sketch, then run the pruned scan once.
//!
//! Sharding is by key hash, so one shard = one lock domain and writers
//! on different shards never contend. Every shard uses the *same*
//! sketch seed: that is what makes their tables addable.

use super::codec::{self, Reader};
use super::mergeable::MergeableSketch;
use crate::rng::SplitMix64;
use crate::sketch::stream::StreamSketch;
use anyhow::{ensure, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Geometry + topology of a store. Two stores (or a store and a remote
/// sketch) interoperate iff the sketch-identity fields (`n1, n2, m1,
/// m2, d, seed`) agree.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StoreConfig {
    /// key universe: keys are `(i, j) ∈ [n1] × [n2]`
    pub n1: usize,
    pub n2: usize,
    /// sketch geometry per repeat
    pub m1: usize,
    pub m2: usize,
    /// median-of-d repeats
    pub d: usize,
    /// hash-family seed — part of the mergeability contract
    pub seed: u64,
    /// number of shards (lock domains)
    pub shards: usize,
    /// sliding-window length in epochs
    pub window: usize,
}

impl Default for StoreConfig {
    fn default() -> Self {
        Self {
            n1: 1 << 16,
            n2: 1 << 16,
            m1: 64,
            m2: 64,
            d: 5,
            seed: 0x5EED,
            shards: 4,
            window: 8,
        }
    }
}

impl StoreConfig {
    pub(crate) fn fresh_sketch(&self) -> StreamSketch {
        StreamSketch::new(self.n1, self.n2, self.m1, self.m2, self.d, self.seed)
    }

    /// Does `sk` belong to this store's sketch family?
    pub fn matches(&self, sk: &StreamSketch) -> bool {
        sk.n1 == self.n1
            && sk.n2 == self.n2
            && sk.m1 == self.m1
            && sk.m2 == self.m2
            && sk.d == self.d
            && sk.seed == self.seed
    }

    pub(crate) fn encode(&self, out: &mut Vec<u8>) {
        for v in [self.n1, self.n2, self.m1, self.m2, self.d, self.shards, self.window] {
            codec::put_u32(out, u32::try_from(v).expect("store config field too large"));
        }
        codec::put_u64(out, self.seed);
    }

    pub(crate) fn decode(rd: &mut Reader<'_>) -> Result<Self> {
        let n1 = rd.u32()? as usize;
        let n2 = rd.u32()? as usize;
        let m1 = rd.u32()? as usize;
        let m2 = rd.u32()? as usize;
        let d = rd.u32()? as usize;
        let shards = rd.u32()? as usize;
        let window = rd.u32()? as usize;
        let seed = rd.u64()?;
        let cfg = Self { n1, n2, m1, m2, d, seed, shards, window };
        cfg.validate()?;
        Ok(cfg)
    }

    pub(crate) fn validate(&self) -> Result<()> {
        ensure!(
            self.n1 > 0 && self.n2 > 0 && self.m1 > 0 && self.m2 > 0 && self.d >= 1,
            "store config has empty dimensions"
        );
        ensure!(self.shards >= 1, "store needs at least one shard");
        ensure!(self.window >= 1, "store window must be at least one epoch");
        Ok(())
    }
}

/// Point-in-time counters for STATS / monitoring.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StoreStats {
    pub shards: usize,
    pub window: usize,
    pub epoch: u64,
    pub updates: u64,
}

struct Shard {
    /// `window` epoch slots; `ring[cur]` receives updates
    ring: Vec<StreamSketch>,
    cur: usize,
    /// running sum of the live ring slots
    total: StreamSketch,
}

/// The sharded, epoch-windowed store. All methods take `&self`; one
/// mutex per shard is the only synchronization on the write path.
pub struct ShardedStore {
    cfg: StoreConfig,
    shards: Vec<Mutex<Shard>>,
    /// completed window advances
    epoch: AtomicU64,
    router_salt: u64,
    /// empty same-family sketch: evaluates hashes/signs for the fan-out
    /// query without locking any shard
    probe: StreamSketch,
}

impl ShardedStore {
    pub fn new(cfg: StoreConfig) -> Self {
        cfg.validate().expect("invalid store config");
        let shards = (0..cfg.shards)
            .map(|_| {
                Mutex::new(Shard {
                    ring: (0..cfg.window).map(|_| cfg.fresh_sketch()).collect(),
                    cur: 0,
                    total: cfg.fresh_sketch(),
                })
            })
            .collect();
        let router_salt = Self::derive_salt(cfg.seed);
        let probe = cfg.fresh_sketch();
        Self { cfg, shards, epoch: AtomicU64::new(0), router_salt, probe }
    }

    fn derive_salt(seed: u64) -> u64 {
        SplitMix64::new(seed ^ 0x5AAD_ED51_AB5A_17E5).next_u64()
    }

    pub fn config(&self) -> &StoreConfig {
        &self.cfg
    }

    /// Which shard owns key `(i, j)`. Deterministic in the config seed,
    /// independent of the sketch hashes (a sketch-bucket hash would
    /// correlate shard load with bucket collisions).
    pub fn shard_of(&self, i: usize, j: usize) -> usize {
        let key = ((i as u64) << 32) | (j as u64 & 0xFFFF_FFFF);
        (SplitMix64::new(self.router_salt ^ key).next_u64() % self.cfg.shards as u64) as usize
    }

    /// Route one stream item to its shard.
    pub fn update(&self, i: usize, j: usize, w: f64) {
        assert!(
            i < self.cfg.n1 && j < self.cfg.n2,
            "key ({i}, {j}) outside universe {}x{}",
            self.cfg.n1,
            self.cfg.n2
        );
        let s = self.shard_of(i, j);
        let mut guard = self.shards[s].lock().expect("shard lock");
        let sh = &mut *guard;
        sh.ring[sh.cur].update(i, j, w);
        sh.total.update(i, j, w);
    }

    /// Fan-out point query: raw bucket counters summed across shard
    /// totals, signs applied once, one median at the end. Bit-identical
    /// (for exactly-representable weights) to querying the merged
    /// sketch — summing *signed* estimates instead would flip signed
    /// zeros on zero-sum buckets split across shards.
    pub fn point_query(&self, i: usize, j: usize) -> f64 {
        assert!(
            i < self.cfg.n1 && j < self.cfg.n2,
            "key ({i}, {j}) outside universe {}x{}",
            self.cfg.n1,
            self.cfg.n2
        );
        let mut acc = vec![0.0; self.cfg.d];
        for shm in &self.shards {
            shm.lock().expect("shard lock").total.accumulate_raw(i, j, &mut acc);
        }
        self.probe.finalize_estimates(i, j, &mut acc)
    }

    /// Merge every shard's live window into one sketch (scans,
    /// replication hand-off, MERGE-RPC export).
    pub fn merged(&self) -> StreamSketch {
        let mut out = self.cfg.fresh_sketch();
        for shm in &self.shards {
            out.merge_scaled(&shm.lock().expect("shard lock").total, 1.0);
        }
        out
    }

    /// The k heaviest keys in the live window (merged scan).
    ///
    /// Uses the marginal-pruned scan, which assumes a non-negative
    /// workload (the store's traffic use case; window expiry does not
    /// break this — it only removes mass that was added). Turnstile
    /// streams whose *deletions* can cancel a row's marginal while a
    /// heavy cell survives should scan `merged().heavy_hitters_dense`
    /// in-process instead; point queries are exact either way.
    pub fn top_k(&self, k: usize) -> Vec<(usize, usize, f64)> {
        self.merged().top_k(k)
    }

    /// All keys whose windowed weight clears `threshold` (merged scan).
    /// Same non-negative-workload assumption as [`ShardedStore::top_k`].
    pub fn heavy_hitters(&self, threshold: f64) -> Vec<(usize, usize, f64)> {
        self.merged().heavy_hitters(threshold)
    }

    /// Merge a same-family sketch from outside (another node, a batch
    /// job) into the store. It lands in shard 0's current epoch slot so
    /// it ages out with the window like any other traffic.
    pub fn merge_sketch(&self, sk: &StreamSketch) -> Result<()> {
        ensure!(
            self.cfg.matches(sk),
            "sketch geometry/family does not match this store (want {}x{} -> {}x{}, d={}, seed={})",
            self.cfg.n1,
            self.cfg.n2,
            self.cfg.m1,
            self.cfg.m2,
            self.cfg.d,
            self.cfg.seed
        );
        let mut guard = self.shards[0].lock().expect("shard lock");
        let sh = &mut *guard;
        sh.ring[sh.cur].merge_scaled(sk, 1.0);
        sh.total.merge_scaled(sk, 1.0);
        Ok(())
    }

    /// Slide the window one epoch: in every shard the expiring slot is
    /// subtracted out of the running total and cleared for reuse.
    ///
    /// Shards rotate under their own locks, so concurrent updates may
    /// straddle the boundary (land in the old epoch on one shard and
    /// the new on another); per-key ordering is still serialized by the
    /// owning shard's lock.
    pub fn advance_epoch(&self) {
        for shm in &self.shards {
            let mut guard = shm.lock().expect("shard lock");
            let sh = &mut *guard;
            let next = (sh.cur + 1) % self.cfg.window;
            // expiring slot leaves the total by subtraction (linearity)
            let (total, expiring) = (&mut sh.total, &sh.ring[next]);
            total.merge_scaled(expiring, -1.0);
            sh.ring[next].clear();
            sh.cur = next;
        }
        self.epoch.fetch_add(1, Ordering::SeqCst);
    }

    /// Completed `advance_epoch` calls.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::SeqCst)
    }

    /// Updates currently inside the live window (expired epochs are
    /// subtracted out of this count too).
    pub fn updates(&self) -> u64 {
        self.shards
            .iter()
            .map(|shm| shm.lock().expect("shard lock").total.updates)
            .sum()
    }

    pub fn stats(&self) -> StoreStats {
        StoreStats {
            shards: self.cfg.shards,
            window: self.cfg.window,
            epoch: self.epoch(),
            updates: self.updates(),
        }
    }

    /// Serialize config + every shard's ring/cursor/total (snapshots).
    pub(crate) fn encode_into(&self, out: &mut Vec<u8>) {
        self.cfg.encode(out);
        codec::put_u64(out, self.epoch());
        for shm in &self.shards {
            let sh = shm.lock().expect("shard lock");
            codec::put_u32(out, sh.cur as u32);
            for sk in &sh.ring {
                sk.encode(out);
            }
            sh.total.encode(out);
        }
    }

    /// Bit-exact inverse of [`ShardedStore::encode_into`].
    pub(crate) fn decode_from(rd: &mut Reader<'_>) -> Result<Self> {
        let cfg = StoreConfig::decode(rd)?;
        let epoch = rd.u64()?;
        let mut shards = Vec::with_capacity(cfg.shards);
        for _ in 0..cfg.shards {
            let cur = rd.u32()? as usize;
            ensure!(cur < cfg.window, "corrupt snapshot: epoch cursor out of range");
            let mut ring = Vec::with_capacity(cfg.window);
            for _ in 0..cfg.window {
                let sk = StreamSketch::decode(rd)?;
                ensure!(cfg.matches(&sk), "corrupt snapshot: ring sketch family mismatch");
                ring.push(sk);
            }
            let total = StreamSketch::decode(rd)?;
            ensure!(cfg.matches(&total), "corrupt snapshot: total sketch family mismatch");
            shards.push(Mutex::new(Shard { ring, cur, total }));
        }
        let router_salt = Self::derive_salt(cfg.seed);
        let probe = cfg.fresh_sketch();
        Ok(Self { cfg, shards, epoch: AtomicU64::new(epoch), router_salt, probe })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn small_cfg(shards: usize, window: usize) -> StoreConfig {
        StoreConfig { n1: 48, n2: 40, m1: 12, m2: 10, d: 5, seed: 77, shards, window }
    }

    /// Integer weights make every f64 partial sum exact, so accumulation
    /// order (sharded vs interleaved) cannot change results and
    /// bit-identity is a meaningful assertion.
    fn int_weight(rng: &mut Pcg64) -> f64 {
        let mag = (1 + rng.gen_range(16)) as f64;
        if rng.uniform() < 0.25 {
            -mag
        } else {
            mag
        }
    }

    #[test]
    fn routing_is_deterministic_and_covers_shards() {
        let store = ShardedStore::new(small_cfg(4, 2));
        let mut seen = [false; 4];
        for i in 0..48 {
            for j in 0..40 {
                let s = store.shard_of(i, j);
                assert!(s < 4);
                assert_eq!(s, store.shard_of(i, j));
                seen[s] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "some shard got no keys: {seen:?}");
    }

    #[test]
    fn point_queries_bit_identical_to_unsharded_sketch() {
        for shards in [1usize, 2, 4, 8] {
            let cfg = small_cfg(shards, 3);
            let store = ShardedStore::new(cfg.clone());
            let mut reference = cfg.fresh_sketch();
            let mut rng = Pcg64::new(100 + shards as u64);
            for _ in 0..800 {
                let (i, j) = (rng.gen_range(48) as usize, rng.gen_range(40) as usize);
                let w = int_weight(&mut rng);
                store.update(i, j, w);
                reference.update(i, j, w);
            }
            assert_eq!(store.updates(), reference.updates);
            for i in 0..48 {
                for j in 0..40 {
                    assert_eq!(
                        store.point_query(i, j).to_bits(),
                        reference.query(i, j).to_bits(),
                        "shards={shards} key=({i},{j})"
                    );
                }
            }
            // merged sketch answers identically too
            let merged = store.merged();
            for _ in 0..100 {
                let (i, j) = (rng.gen_range(48) as usize, rng.gen_range(40) as usize);
                assert_eq!(merged.query(i, j).to_bits(), reference.query(i, j).to_bits());
            }
        }
    }

    #[test]
    fn window_expiry_leaves_exactly_the_recent_epochs() {
        let cfg = small_cfg(4, 2);
        let store = ShardedStore::new(cfg.clone());
        let mut rng = Pcg64::new(9);
        let phase = |rng: &mut Pcg64| -> Vec<(usize, usize, f64)> {
            (0..300)
                .map(|_| {
                    (rng.gen_range(48) as usize, rng.gen_range(40) as usize, int_weight(rng))
                })
                .collect()
        };
        let a = phase(&mut rng);
        let b = phase(&mut rng);
        for &(i, j, w) in &a {
            store.update(i, j, w);
        }
        store.advance_epoch();
        for &(i, j, w) in &b {
            store.update(i, j, w);
        }
        store.advance_epoch(); // phase A expires (window = 2)
        assert_eq!(store.epoch(), 2);
        let mut only_b = cfg.fresh_sketch();
        for &(i, j, w) in &b {
            only_b.update(i, j, w);
        }
        assert_eq!(store.updates(), only_b.updates);
        for _ in 0..200 {
            let (i, j) = (rng.gen_range(48) as usize, rng.gen_range(40) as usize);
            assert_eq!(
                store.point_query(i, j).to_bits(),
                only_b.query(i, j).to_bits(),
                "key ({i}, {j})"
            );
        }
    }

    #[test]
    fn window_one_keeps_only_current_epoch() {
        let cfg = small_cfg(2, 1);
        let store = ShardedStore::new(cfg);
        store.update(1, 1, 5.0);
        store.advance_epoch();
        assert_eq!(store.updates(), 0);
        assert_eq!(store.point_query(1, 1), 0.0);
        store.update(2, 2, 3.0);
        assert_eq!(store.updates(), 1);
    }

    #[test]
    fn merge_sketch_adds_foreign_traffic() {
        let cfg = small_cfg(3, 2);
        let store = ShardedStore::new(cfg.clone());
        store.update(5, 5, 2.0);
        // a remote node observed more of the same key
        let mut remote = cfg.fresh_sketch();
        remote.update(5, 5, 3.0);
        remote.update(7, 1, 4.0);
        store.merge_sketch(&remote).unwrap();
        assert_eq!(store.point_query(5, 5), 5.0);
        assert_eq!(store.point_query(7, 1), 4.0);
        // merged traffic ages out with the window
        store.advance_epoch();
        store.advance_epoch();
        assert_eq!(store.point_query(5, 5), 0.0);
        // wrong-family sketches are rejected
        let alien = StreamSketch::new(48, 40, 12, 10, 5, 12345);
        assert!(store.merge_sketch(&alien).is_err());
    }

    #[test]
    fn topk_and_heavy_hitters_over_merged_window() {
        let cfg = small_cfg(4, 2);
        let store = ShardedStore::new(cfg);
        let mut rng = Pcg64::new(4);
        for _ in 0..400 {
            store.update(3, 4, 1.0);
        }
        for _ in 0..200 {
            store.update(20, 30, 1.0);
        }
        for _ in 0..300 {
            store.update(rng.gen_range(48) as usize, rng.gen_range(40) as usize, 1.0);
        }
        let top = store.top_k(2);
        assert_eq!(top.len(), 2);
        assert_eq!((top[0].0, top[0].1), (3, 4));
        assert_eq!((top[1].0, top[1].1), (20, 30));
        let hh = store.heavy_hitters(150.0);
        assert!(hh.iter().any(|&(i, j, _)| (i, j) == (3, 4)));
        assert!(hh.iter().any(|&(i, j, _)| (i, j) == (20, 30)));
    }

    #[test]
    fn snapshot_roundtrip_is_bit_exact() {
        let cfg = small_cfg(3, 4);
        let store = ShardedStore::new(cfg);
        let mut rng = Pcg64::new(6);
        for _ in 0..500 {
            store.update(rng.gen_range(48) as usize, rng.gen_range(40) as usize, rng.normal());
        }
        store.advance_epoch();
        for _ in 0..200 {
            store.update(rng.gen_range(48) as usize, rng.gen_range(40) as usize, rng.normal());
        }
        let mut bytes = Vec::new();
        store.encode_into(&mut bytes);
        let got = ShardedStore::decode_from(&mut Reader::new(&bytes)).unwrap();
        assert_eq!(got.config(), store.config());
        assert_eq!(got.epoch(), store.epoch());
        assert_eq!(got.updates(), store.updates());
        for _ in 0..200 {
            let (i, j) = (rng.gen_range(48) as usize, rng.gen_range(40) as usize);
            assert_eq!(got.point_query(i, j).to_bits(), store.point_query(i, j).to_bits());
        }
        // and the recovered store keeps working (same routing)
        got.update(1, 2, 3.0);
        store.update(1, 2, 3.0);
        assert_eq!(got.point_query(1, 2).to_bits(), store.point_query(1, 2).to_bits());
    }

    #[test]
    fn decode_rejects_corrupt_cursor() {
        let store = ShardedStore::new(small_cfg(2, 2));
        let mut bytes = Vec::new();
        store.encode_into(&mut bytes);
        // config is 7 u32 + 1 u64 = 36 bytes, epoch u64 = 8; first
        // shard's cursor starts at byte 44 — point it past the window
        bytes[44] = 9;
        assert!(ShardedStore::decode_from(&mut Reader::new(&bytes)).is_err());
    }
}
