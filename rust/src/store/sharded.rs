//! K-way sharded, epoch-windowed sketch store.
//!
//! Layout: every shard owns a ring of `window` [`StreamSketch`] epoch
//! slots plus a running `total` (the elementwise sum of the live ring).
//! Updates hash their key to one shard, land in that shard's current
//! slot *and* its total; [`ShardedStore::advance_epoch`] rotates the
//! ring by **subtracting** the expiring slot from the total (linearity
//! again — no rescan, no accuracy loss) and clearing it for reuse.
//!
//! Queries exploit the same linearity in two directions:
//! - **fan-out** — a point query sums per-repeat *raw* bucket counters
//!   across shard totals, applies the ±1 signs once, and takes one
//!   median at the end: the summed counter equals the merged sketch's
//!   counter, so the estimate is *bit-identical* to querying a single
//!   sketch fed the whole stream (over exactly-representable update
//!   weights, where addition reassociates without rounding);
//! - **merge** — scans (top-k / heavy hitters) run over one merged
//!   sketch of all shard totals.
//!
//! **Version-cached scan plane.** The merged sketch is not rebuilt per
//! scan: the store keeps one cached merged sketch stamped with a
//! monotonically increasing version (bumped, under the owning shard's
//! lock, by every update / batch / merge — and by epoch rotation under
//! all locks). Each shard additionally accumulates a small *pending*
//! delta sketch of its updates since the cache last saw it; a scan
//! whose stamp is stale folds only those per-shard deltas into the
//! cache (clearing each under its own lock) instead of re-merging all
//! K shards — linearity again: `cache + Σ deltas ≡ re-merge`,
//! bit-identical over exactly-representable weights. Only an epoch
//! rotation (which *subtracts* expiring slots from the totals, a
//! change the deltas do not record) forces the full K-way re-merge,
//! still available directly as [`ShardedStore::merged_uncached`] — the
//! oracle the property tests compare the cache against. On top of the
//! cached sketch the last TOPK / HEAVY answer is memoized per stamp,
//! so a read-heavy serving loop pays zero re-scans between writes.
//!
//! Sharding is by key hash, so one shard = one lock domain and writers
//! on different shards never contend. Every shard uses the *same*
//! sketch seed: that is what makes their tables addable.

use super::codec::{self, Reader};
use super::lockdep;
use super::mergeable::MergeableSketch;
use super::tensor::contract::ContractOutput;
use super::tensor::hcs::HcsStream;
use super::tensor::registry::{TensorFamily, TensorRegistry};
use crate::rng::SplitMix64;
use crate::sketch::stream::StreamSketch;
use anyhow::{ensure, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};

/// Guard over the tensor registry: the registry mutex plus its
/// [`lockdep`] registration (`TENSOR_REGISTRY` is the bottom of the
/// store's lock hierarchy — see [`lockdep`]'s module docs). Derefs to
/// [`TensorRegistry`]; field order keeps the mutex guard dropping
/// before the lockdep token.
pub(crate) struct TensorLock<'a> {
    guard: MutexGuard<'a, TensorRegistry>,
    _held: lockdep::Held,
}

impl std::ops::Deref for TensorLock<'_> {
    type Target = TensorRegistry;
    fn deref(&self) -> &TensorRegistry {
        &self.guard
    }
}

impl std::ops::DerefMut for TensorLock<'_> {
    fn deref_mut(&mut self) -> &mut TensorRegistry {
        &mut self.guard
    }
}

thread_local! {
    /// Per-thread accumulator for the point-query fan-out (and any
    /// other d-length scratch need): the steady-state read path
    /// performs zero heap allocation. The contract is *returned
    /// zeroed* — every user re-zeros after `finalize_estimates`
    /// consumes the accumulated counters, and the debug assertion in
    /// [`with_zeroed_scratch`] catches a caller that leaks a dirty
    /// scratch back.
    static POINT_SCRATCH: RefCell<Vec<f64>> = RefCell::new(Vec::new());

    /// Per-thread counting-sort scratch for the batched write path
    /// ([`ShardedStore::update_batch`] groups items by destination
    /// shard): warm buffers make the grouping allocation-free, matching
    /// the allocation-free kernel walk it feeds.
    static GROUP_SCRATCH: RefCell<GroupScratch> = RefCell::new(GroupScratch::default());
}

/// Buffers for the stable counting sort in
/// [`ShardedStore::update_batch`]; see `GROUP_SCRATCH`.
#[derive(Default)]
struct GroupScratch {
    dests: Vec<usize>,
    counts: Vec<usize>,
    starts: Vec<usize>,
    fill: Vec<usize>,
    grouped: Vec<(usize, usize, f64)>,
}

/// Hand `f` a zeroed `d`-length slice from the thread-local scratch and
/// re-zero it afterwards (so `finalize_estimates` always starts from a
/// fully-zeroed accumulator on the next call).
fn with_zeroed_scratch<R>(d: usize, f: impl FnOnce(&mut [f64]) -> R) -> R {
    POINT_SCRATCH.with(|cell| {
        let mut buf = cell.borrow_mut();
        if buf.len() < d {
            buf.resize(d, 0.0);
        }
        let acc = &mut buf[..d];
        debug_assert!(
            acc.iter().all(|&x| x == 0.0),
            "point-query scratch handed back dirty: finalize_estimates must \
             see a fully-zeroed accumulator on entry"
        );
        let out = f(acc);
        acc.fill(0.0);
        out
    })
}

/// Geometry + topology of a store. Two stores (or a store and a remote
/// sketch) interoperate iff the sketch-identity fields (`n1, n2, m1,
/// m2, d, seed`) agree.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StoreConfig {
    /// key universe: keys are `(i, j) ∈ [n1] × [n2]`
    pub n1: usize,
    pub n2: usize,
    /// sketch geometry per repeat
    pub m1: usize,
    pub m2: usize,
    /// median-of-d repeats
    pub d: usize,
    /// hash-family seed — part of the mergeability contract
    pub seed: u64,
    /// number of shards (lock domains)
    pub shards: usize,
    /// sliding-window length in epochs
    pub window: usize,
}

impl Default for StoreConfig {
    fn default() -> Self {
        Self {
            n1: 1 << 16,
            n2: 1 << 16,
            m1: 64,
            m2: 64,
            d: 5,
            seed: 0x5EED,
            shards: 4,
            window: 8,
        }
    }
}

impl StoreConfig {
    pub(crate) fn fresh_sketch(&self) -> StreamSketch {
        StreamSketch::new(self.n1, self.n2, self.m1, self.m2, self.d, self.seed)
    }

    /// Does `sk` belong to this store's sketch family?
    pub fn matches(&self, sk: &StreamSketch) -> bool {
        sk.n1 == self.n1
            && sk.n2 == self.n2
            && sk.m1 == self.m1
            && sk.m2 == self.m2
            && sk.d == self.d
            && sk.seed == self.seed
    }

    pub(crate) fn encode(&self, out: &mut Vec<u8>) {
        for v in [self.n1, self.n2, self.m1, self.m2, self.d, self.shards, self.window] {
            codec::put_u32(out, u32::try_from(v).expect("store config field too large"));
        }
        codec::put_u64(out, self.seed);
    }

    pub(crate) fn decode(rd: &mut Reader<'_>) -> Result<Self> {
        let n1 = rd.u32()? as usize;
        let n2 = rd.u32()? as usize;
        let m1 = rd.u32()? as usize;
        let m2 = rd.u32()? as usize;
        let d = rd.u32()? as usize;
        let shards = rd.u32()? as usize;
        let window = rd.u32()? as usize;
        let seed = rd.u64()?;
        let cfg = Self { n1, n2, m1, m2, d, seed, shards, window };
        cfg.validate()?;
        Ok(cfg)
    }

    pub(crate) fn validate(&self) -> Result<()> {
        ensure!(
            self.n1 > 0 && self.n2 > 0 && self.m1 > 0 && self.m2 > 0 && self.d >= 1,
            "store config has empty dimensions"
        );
        ensure!(self.shards >= 1, "store needs at least one shard");
        ensure!(self.window >= 1, "store window must be at least one epoch");
        Ok(())
    }
}

/// Optimistic cross-shard reads ([`ShardedStore::point_query`],
/// [`ShardedStore::stats`]) retry this many epoch-validation collisions
/// before falling back to taking every shard lock — bounding reader
/// latency even under a rotation storm.
const EPOCH_RETRY_LIMIT: usize = 8;

/// Point-in-time counters for STATS / monitoring.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StoreStats {
    pub shards: usize,
    pub window: usize,
    pub epoch: u64,
    pub updates: u64,
}

struct Shard {
    /// `window` epoch slots; `ring[cur]` receives updates
    ring: Vec<StreamSketch>,
    cur: usize,
    /// running sum of the live ring slots
    total: StreamSketch,
    /// delta sketch of everything applied since the scan cache last
    /// folded this shard; cleared (under this shard's lock) by the fold
    pending: StreamSketch,
    /// cheap emptiness flag for `pending` — set by every mutation, so
    /// the fold can skip the O(d·m1·m2) merge for untouched shards
    pending_dirty: bool,
    /// cumulative sketch of this shard's **locally-originated** mass
    /// (updates, batches, and ingest merges — never replication-plane
    /// merges), written by the same fused fan-out kernel when
    /// replication is enabled. Never expired by the window: it is what
    /// the replicator ships, and peers expire by their own rotations.
    origin: StreamSketch,
}

/// The incrementally maintained scan plane: one merged sketch stamped
/// with the store version (and build epoch) it reflects, plus the last
/// memoized TOPK / HEAVY answer at that stamp. Guarded by one mutex —
/// concurrent scans serialize here instead of on every shard lock.
struct ScanCache {
    merged: StreamSketch,
    /// store version `merged` is exact at; `u64::MAX` = never built
    version: u64,
    /// epoch `merged` was built at; a rotation invalidates incremental
    /// maintenance (expiry subtracts from the totals, which the pending
    /// deltas do not record) and forces a full K-way re-merge
    epoch: u64,
    /// memoized `merged.top_k(k)` for the last requested k
    top_k: Option<(usize, Vec<(usize, usize, f64)>)>,
    /// memoized `merged.heavy_hitters(t)` for the last threshold (bit
    /// pattern, so the match is exact even for odd thresholds)
    heavy: Option<(u64, Vec<(usize, usize, f64)>)>,
}

impl ScanCache {
    /// Never-built cache: the `u64::MAX` stamps can match no live
    /// version/epoch, so the first scan always takes the full-rebuild
    /// path. Shared by [`ShardedStore::new`] and snapshot decoding.
    fn empty(cfg: &StoreConfig) -> Mutex<ScanCache> {
        Mutex::new(ScanCache {
            merged: cfg.fresh_sketch(),
            version: u64::MAX,
            epoch: u64::MAX,
            top_k: None,
            heavy: None,
        })
    }
}

/// Bounded retries for an exact incremental version stamp while writers
/// race the fold; past this the refresh takes every shard lock, which
/// freezes the version and always yields an exact stamp.
const SCAN_REFRESH_RETRY_LIMIT: usize = 4;

/// The sharded, epoch-windowed store. All methods take `&self`; one
/// mutex per shard is the only synchronization on the write path.
pub struct ShardedStore {
    cfg: StoreConfig,
    shards: Vec<Mutex<Shard>>,
    /// completed window advances
    epoch: AtomicU64,
    /// bumped by every mutation while the owning shard's lock (or, for
    /// rotation, every lock) is held — the scan cache's staleness stamp
    version: AtomicU64,
    /// whether the per-shard origin accumulators are fed (set once by
    /// the server before replication traffic starts; a plain flag so a
    /// standalone store pays one relaxed load per write and nothing
    /// else)
    replicate: AtomicBool,
    /// bumped (under the owning shard's lock) only when locally-
    /// originated mass lands — the replicator's per-peer cursor stamp.
    /// Replica-plane merges and epoch rotations do not move it, so an
    /// unchanged stamp means "nothing new to ship".
    origin_version: AtomicU64,
    scan: Mutex<ScanCache>,
    /// the HCS tensor plane: named multi-mode sketches + their
    /// replication channel table ([`super::tensor::registry`]). One
    /// lock domain — tensor ops never touch the 2-D shard locks, and
    /// the only place both are held is [`ShardedStore::encode_into`]
    /// (shards first, then this — the store-wide lock order).
    tensors: Mutex<TensorRegistry>,
    /// rotation-storm fallbacks taken by the optimistic readers
    /// ([`ShardedStore::point_query`] / [`ShardedStore::stats`]) —
    /// diagnostics, and how the tests prove the lock-all path runs
    lockall_fallbacks: AtomicU64,
    router_salt: u64,
    /// empty same-family sketch: evaluates hashes/signs for the fan-out
    /// query without locking any shard
    probe: StreamSketch,
}

impl ShardedStore {
    pub fn new(cfg: StoreConfig) -> Self {
        cfg.validate().expect("invalid store config");
        let shards = (0..cfg.shards)
            .map(|_| {
                Mutex::new(Shard {
                    ring: (0..cfg.window).map(|_| cfg.fresh_sketch()).collect(),
                    cur: 0,
                    total: cfg.fresh_sketch(),
                    pending: cfg.fresh_sketch(),
                    pending_dirty: false,
                    origin: cfg.fresh_sketch(),
                })
            })
            .collect();
        let router_salt = Self::derive_salt(cfg.seed);
        let probe = cfg.fresh_sketch();
        let scan = ScanCache::empty(&cfg);
        Self {
            cfg,
            shards,
            epoch: AtomicU64::new(0),
            version: AtomicU64::new(0),
            replicate: AtomicBool::new(false),
            origin_version: AtomicU64::new(0),
            scan,
            tensors: Mutex::new(TensorRegistry::new()),
            lockall_fallbacks: AtomicU64::new(0),
            router_salt,
            probe,
        }
    }

    fn derive_salt(seed: u64) -> u64 {
        SplitMix64::new(seed ^ 0x5AAD_ED51_AB5A_17E5).next_u64()
    }

    pub fn config(&self) -> &StoreConfig {
        &self.cfg
    }

    /// Which shard owns key `(i, j)`. Deterministic in the config seed,
    /// independent of the sketch hashes (a sketch-bucket hash would
    /// correlate shard load with bucket collisions).
    pub fn shard_of(&self, i: usize, j: usize) -> usize {
        let key = ((i as u64) << 32) | (j as u64 & 0xFFFF_FFFF);
        (SplitMix64::new(self.router_salt ^ key).next_u64() % self.cfg.shards as u64) as usize
    }

    /// Route one stream item to its shard. The fused fan-out kernel
    /// lands it in the current epoch slot, the running total, and the
    /// scan cache's pending delta with **one** hash walk; the store
    /// version bumps before the shard lock drops, so the scan cache can
    /// tell exactly when it is stale.
    pub fn update(&self, i: usize, j: usize, w: f64) {
        assert!(
            i < self.cfg.n1 && j < self.cfg.n2,
            "key ({i}, {j}) outside universe {}x{}",
            self.cfg.n1,
            self.cfg.n2
        );
        let s = self.shard_of(i, j);
        let _ld = lockdep::acquire(lockdep::SHARD, s as u32);
        let mut guard = self.shards[s].lock().expect("shard lock");
        let sh = &mut *guard;
        let cur = sh.cur;
        if self.replicate.load(Ordering::Relaxed) {
            // replication adds a fourth fan-out target (the shipped
            // origin accumulator) to the same single hash walk
            StreamSketch::update_fanout(
                &mut [&mut sh.ring[cur], &mut sh.total, &mut sh.pending, &mut sh.origin],
                i,
                j,
                w,
            );
            self.origin_version.fetch_add(1, Ordering::SeqCst);
        } else {
            StreamSketch::update_fanout(
                &mut [&mut sh.ring[cur], &mut sh.total, &mut sh.pending],
                i,
                j,
                w,
            );
        }
        sh.pending_dirty = true;
        self.version.fetch_add(1, Ordering::SeqCst);
    }

    /// Apply a whole batch with one lock acquisition per destination
    /// shard instead of one per item: items are grouped by
    /// [`ShardedStore::shard_of`] (stable — per-shard arrival order is
    /// preserved), then each shard's run goes through the fused
    /// [`StreamSketch::update_batch_fanout`] kernel, landing in the
    /// current epoch slot, the running total, and the scan cache's
    /// pending delta with one hash walk per item. Bit-identical to
    /// per-item [`ShardedStore::update`] calls in batch order: grouping
    /// only reorders *across* shards, whose tables are disjoint.
    ///
    /// The batch is not atomic across shards — a concurrent cross-shard
    /// reader can see one shard's run applied and another's not, exactly
    /// as it could between individual updates. Batches no larger than
    /// the shard count skip the grouping and take the per-item path.
    pub fn update_batch(&self, items: &[(usize, usize, f64)]) {
        let k = self.cfg.shards;
        // tiny batches: grouping overhead rivals the saved lock
        // round-trips, so just take the per-item path (bit-identical by
        // definition)
        if items.len() <= k {
            for &(i, j, w) in items {
                self.update(i, j, w);
            }
            return;
        }
        // counting-sort by destination shard: one flat buffer plus
        // exact-sized offset tables, reused across batches via the
        // thread-local scratch — no allocation on the write hot path
        // after warm-up
        GROUP_SCRATCH.with(|cell| {
            let g = &mut *cell.borrow_mut();
            g.dests.clear();
            g.dests.reserve(items.len());
            g.counts.clear();
            g.counts.resize(k, 0);
            for &(i, j, _) in items {
                assert!(
                    i < self.cfg.n1 && j < self.cfg.n2,
                    "key ({i}, {j}) outside universe {}x{}",
                    self.cfg.n1,
                    self.cfg.n2
                );
                let s = self.shard_of(i, j);
                g.dests.push(s);
                g.counts[s] += 1;
            }
            g.starts.clear();
            g.starts.resize(k + 1, 0);
            for s in 0..k {
                g.starts[s + 1] = g.starts[s] + g.counts[s];
            }
            // stable fill: per-shard arrival order is preserved
            g.grouped.clear();
            g.grouped.resize(items.len(), (0, 0, 0.0));
            g.fill.clear();
            g.fill.extend_from_slice(&g.starts[..k]);
            for (&s, &item) in g.dests.iter().zip(items.iter()) {
                let pos = g.fill[s];
                g.grouped[pos] = item;
                g.fill[s] = pos + 1;
            }
            for s in 0..k {
                let group = &g.grouped[g.starts[s]..g.starts[s + 1]];
                if group.is_empty() {
                    continue;
                }
                let _ld = lockdep::acquire(lockdep::SHARD, s as u32);
                let mut guard = self.shards[s].lock().expect("shard lock");
                let sh = &mut *guard;
                let cur = sh.cur;
                if self.replicate.load(Ordering::Relaxed) {
                    StreamSketch::update_batch_fanout(
                        &mut [&mut sh.ring[cur], &mut sh.total, &mut sh.pending, &mut sh.origin],
                        group,
                    );
                    self.origin_version.fetch_add(1, Ordering::SeqCst);
                } else {
                    StreamSketch::update_batch_fanout(
                        &mut [&mut sh.ring[cur], &mut sh.total, &mut sh.pending],
                        group,
                    );
                }
                sh.pending_dirty = true;
                self.version.fetch_add(1, Ordering::SeqCst);
            }
        });
    }

    /// Every shard lock, acquired in index order — the one order every
    /// cross-shard operation (epoch rotation, merged scans, snapshot
    /// encoding) must use, so none of them can deadlock against another
    /// and none can observe shard 0 post-rotation next to shard 1
    /// pre-rotation (the torn multi-shard read). The paired
    /// [`lockdep::Held`] tokens keep the debug-build order checker
    /// informed for the whole guard lifetime (bind them alongside the
    /// guards; drop order between the vectors does not matter).
    fn lock_all(&self) -> (Vec<lockdep::Held>, Vec<MutexGuard<'_, Shard>>) {
        let mut held = Vec::with_capacity(self.shards.len());
        let guards = self
            .shards
            .iter()
            .enumerate()
            .map(|(s, shm)| {
                held.push(lockdep::acquire(lockdep::SHARD, s as u32));
                shm.lock().expect("shard lock")
            })
            .collect();
        (held, guards)
    }

    /// Fan-out point query: raw bucket counters summed across shard
    /// totals, signs applied once, one median at the end. Bit-identical
    /// (for exactly-representable weights) to querying the merged
    /// sketch — summing *signed* estimates instead would flip signed
    /// zeros on zero-sum buckets split across shards.
    ///
    /// The fan-out locks shards one at a time (queries stay concurrent
    /// with writers on other shards), which a concurrent
    /// [`ShardedStore::advance_epoch`] could tear — shard 0 read
    /// pre-rotation, shard 1 post. Rotation bumps the epoch counter
    /// *while holding every shard lock*, so an unchanged epoch across
    /// the fan-out proves no rotation interleaved; on a change the
    /// cheap fan-out retries (single-shard updates commute and need no
    /// guard), and after [`EPOCH_RETRY_LIMIT`] collisions it takes all
    /// shard locks instead so a rotation storm cannot starve readers.
    pub fn point_query(&self, i: usize, j: usize) -> f64 {
        assert!(
            i < self.cfg.n1 && j < self.cfg.n2,
            "key ({i}, {j}) outside universe {}x{}",
            self.cfg.n1,
            self.cfg.n2
        );
        // thread-local accumulator: the steady-state read path performs
        // zero heap allocation per call
        with_zeroed_scratch(self.cfg.d, |acc| {
            for _ in 0..EPOCH_RETRY_LIMIT {
                let e0 = self.epoch();
                acc.fill(0.0);
                for (s, shm) in self.shards.iter().enumerate() {
                    let _ld = lockdep::acquire(lockdep::SHARD, s as u32);
                    shm.lock().expect("shard lock").total.accumulate_raw(i, j, acc);
                }
                if self.epoch() == e0 {
                    return self.probe.finalize_estimates(i, j, acc);
                }
            }
            // rotation storm: fall back to one consistent fully-locked
            // read (counted, so tests can prove this path runs)
            self.lockall_fallbacks.fetch_add(1, Ordering::Relaxed);
            let (_ld, guards) = self.lock_all();
            acc.fill(0.0);
            for sh in &guards {
                sh.total.accumulate_raw(i, j, acc);
            }
            self.probe.finalize_estimates(i, j, acc)
        })
    }

    /// How many times an optimistic reader ([`ShardedStore::point_query`]
    /// / [`ShardedStore::stats`]) exhausted [`EPOCH_RETRY_LIMIT`] epoch
    /// collisions and fell back to the fully-locked read. Diagnostics;
    /// the rotation-storm tests assert it moves.
    pub fn lockall_fallbacks(&self) -> u64 {
        self.lockall_fallbacks.load(Ordering::Relaxed)
    }

    /// Every-shard merge of the live window, served from the
    /// version-stamped scan cache (refreshed incrementally from the
    /// per-shard pending deltas; see the module docs). Bit-identical to
    /// [`ShardedStore::merged_uncached`] over exactly-representable
    /// weights — the store's standing contract.
    pub fn merged(&self) -> StreamSketch {
        let _ld = lockdep::acquire(lockdep::SCAN_CACHE, 0);
        let mut cache = self.scan.lock().expect("scan cache lock");
        self.refresh_scan_cache(&mut cache);
        cache.merged.clone()
    }

    /// The pre-cache behaviour: merge every shard total into a fresh
    /// sketch under every shard lock (index order), one consistent
    /// instant. This is the full K-way re-merge the cache avoids — kept
    /// public as the oracle for the cache-identity property tests and
    /// the uncached side of the scan bench.
    pub fn merged_uncached(&self) -> StreamSketch {
        let (_ld, guards) = self.lock_all();
        let mut out = self.cfg.fresh_sketch();
        for sh in &guards {
            out.merge_scaled(&sh.total, 1.0);
        }
        out
    }

    /// The k heaviest keys in the live window, from the cached scan
    /// plane: the merged sketch refreshes incrementally and the ranked
    /// answer itself is memoized per (version, k) — a read-heavy loop
    /// re-scans only after a write invalidates the stamp.
    ///
    /// Uses the marginal-pruned scan for non-negative workloads (the
    /// store's traffic use case; window expiry does not break this — it
    /// only removes mass that was added). Once any shard has absorbed a
    /// deletion, the merged sketch carries
    /// [`StreamSketch::has_deletions`] and the scan routes itself to the
    /// dense variant, so turnstile streams get correct answers without
    /// caller intervention; point queries are exact either way.
    pub fn top_k(&self, k: usize) -> Vec<(usize, usize, f64)> {
        let _ld = lockdep::acquire(lockdep::SCAN_CACHE, 0);
        let mut cache = self.scan.lock().expect("scan cache lock");
        self.refresh_scan_cache(&mut cache);
        if let Some((ck, hits)) = &cache.top_k {
            if *ck == k {
                return hits.clone();
            }
        }
        let hits = cache.merged.top_k(k);
        cache.top_k = Some((k, hits.clone()));
        hits
    }

    /// All keys whose windowed weight clears `threshold`, memoized like
    /// [`ShardedStore::top_k`] (exact threshold match, by bit pattern).
    /// Same pruned-vs-dense routing as [`ShardedStore::top_k`].
    pub fn heavy_hitters(&self, threshold: f64) -> Vec<(usize, usize, f64)> {
        let _ld = lockdep::acquire(lockdep::SCAN_CACHE, 0);
        let mut cache = self.scan.lock().expect("scan cache lock");
        self.refresh_scan_cache(&mut cache);
        if let Some((ct, hits)) = &cache.heavy {
            if *ct == threshold.to_bits() {
                return hits.clone();
            }
        }
        let hits = cache.merged.heavy_hitters(threshold);
        cache.heavy = Some((threshold.to_bits(), hits.clone()));
        hits
    }

    /// Bring the scan cache up to the current store version.
    ///
    /// Invalidation rules: any version bump clears the memoized scan
    /// results; a version bump *without* an epoch change folds only the
    /// dirty per-shard pending deltas into the cached sketch (each
    /// cleared under its own shard lock); an epoch change means expiry
    /// subtracted mass the deltas never saw, so the cache rebuilds from
    /// a full K-way re-merge under every shard lock. The version stamp
    /// is only written when it is *exact*: either no mutation raced the
    /// incremental fold (checked by re-reading the version — bumps
    /// happen under shard locks after the mutation is visible, so an
    /// unchanged version proves the folds saw everything), or the
    /// rebuild held every lock, freezing the version. Re-folding after
    /// a raced attempt is safe because absorbed deltas were cleared.
    fn refresh_scan_cache(&self, cache: &mut ScanCache) {
        if cache.version == self.version.load(Ordering::SeqCst) && cache.epoch == self.epoch() {
            crate::obs::global().scan_hits.inc();
            return;
        }
        // something changed — whatever refresh path runs, the memoized
        // scan answers are stale
        cache.top_k = None;
        cache.heavy = None;
        if cache.epoch == self.epoch() {
            for _ in 0..SCAN_REFRESH_RETRY_LIMIT {
                let v0 = self.version.load(Ordering::SeqCst);
                for (s, shm) in self.shards.iter().enumerate() {
                    let _ld = lockdep::acquire(lockdep::SHARD, s as u32);
                    let mut guard = shm.lock().expect("shard lock");
                    let sh = &mut *guard;
                    if sh.pending_dirty {
                        cache.merged.merge_scaled(&sh.pending, 1.0);
                        sh.pending.clear();
                        sh.pending_dirty = false;
                    }
                }
                if self.epoch() != cache.epoch {
                    break; // rotation raced the fold: rebuild below
                }
                if self.version.load(Ordering::SeqCst) == v0 {
                    cache.version = v0;
                    crate::obs::global().scan_folds.inc();
                    return;
                }
                // writers raced the fold; retry for an exact stamp
            }
        }
        // full K-way re-merge under every shard lock (version and epoch
        // are frozen while we hold them all, so the stamp is exact):
        // the post-rotation path, and the bounded fallback when writers
        // keep racing the incremental fold
        let (_ld, mut guards) = self.lock_all();
        let mut merged = self.cfg.fresh_sketch();
        for guard in guards.iter_mut() {
            let sh = &mut **guard;
            merged.merge_scaled(&sh.total, 1.0);
            sh.pending.clear();
            sh.pending_dirty = false;
        }
        cache.merged = merged;
        cache.version = self.version.load(Ordering::SeqCst);
        cache.epoch = self.epoch();
        crate::obs::global().scan_rebuilds.inc();
    }

    /// Merge a same-family sketch from outside (another node, a batch
    /// job) into the store. It lands in shard 0's current epoch slot so
    /// it ages out with the window like any other traffic. Counts as
    /// locally-originated (edge-ingest) traffic: with replication on it
    /// enters the origin accumulator and is relayed to peers.
    pub fn merge_sketch(&self, sk: &StreamSketch) -> Result<()> {
        self.merge_sketch_opts(sk, true)
    }

    /// [`ShardedStore::merge_sketch`] with explicit origination.
    /// `originate = false` is the replication plane: mass received from
    /// a peer must never re-enter the origin accumulator, or every mesh
    /// with more than one path would deliver it twice.
    pub(crate) fn merge_sketch_opts(&self, sk: &StreamSketch, originate: bool) -> Result<()> {
        ensure!(
            self.cfg.matches(sk),
            "sketch geometry/family does not match this store (want {}x{} -> {}x{}, d={}, seed={})",
            self.cfg.n1,
            self.cfg.n2,
            self.cfg.m1,
            self.cfg.m2,
            self.cfg.d,
            self.cfg.seed
        );
        let _ld = lockdep::acquire(lockdep::SHARD, 0);
        let mut guard = self.shards[0].lock().expect("shard lock");
        let sh = &mut *guard;
        let cur = sh.cur;
        sh.ring[cur].merge_scaled(sk, 1.0);
        sh.total.merge_scaled(sk, 1.0);
        // the scan cache's delta record, like any other mutation
        sh.pending.merge_scaled(sk, 1.0);
        sh.pending_dirty = true;
        if originate && self.replicate.load(Ordering::Relaxed) {
            sh.origin.merge_scaled(sk, 1.0);
            self.origin_version.fetch_add(1, Ordering::SeqCst);
        }
        self.version.fetch_add(1, Ordering::SeqCst);
        Ok(())
    }

    /// Turn the per-shard origin accumulators on (or off). The server
    /// flips this on **before** serving when peers are configured, so
    /// every locally-originated write is captured. The flag (and the
    /// accumulator it guards) is durable: snapshots carry both, and
    /// [`super::DurableStore`] recovery re-enables it *before* WAL
    /// replay on a node that ever replicated — so recovered-but-
    /// unshipped mass re-enters the accumulator and is re-shipped.
    pub fn set_replication(&self, on: bool) {
        self.replicate.store(on, Ordering::SeqCst);
    }

    pub fn replication_enabled(&self) -> bool {
        self.replicate.load(Ordering::SeqCst)
    }

    /// Current origin-version stamp without taking any lock — the
    /// replicator's cheap "anything new to ship?" probe. May race a
    /// concurrent write; [`ShardedStore::origin_snapshot`] re-reads it
    /// under every shard lock for the exact cursor stamp.
    pub fn origin_version(&self) -> u64 {
        self.origin_version.load(Ordering::SeqCst)
    }

    /// One consistent (origin-version, cumulative local-origin sketch)
    /// pair, merged across every shard under all shard locks — what the
    /// replicator diffs per-peer cursors against. O(K·d·m1·m2) per call,
    /// paid once per sync tick, never on the write path.
    pub fn origin_snapshot(&self) -> (u64, StreamSketch) {
        let (_ld, guards) = self.lock_all();
        let mut out = self.cfg.fresh_sketch();
        for sh in &guards {
            out.merge_scaled(&sh.origin, 1.0);
        }
        (self.origin_version.load(Ordering::SeqCst), out)
    }

    // ---------- tensor plane ----------

    fn tensor_lock(&self) -> TensorLock<'_> {
        let held = lockdep::acquire(lockdep::TENSOR_REGISTRY, 0);
        TensorLock { guard: self.tensors.lock().expect("tensor registry lock"), _held: held }
    }

    /// Register a named tensor. Idempotent on an identical family;
    /// returns `Ok(true)` iff the tensor was newly created.
    pub fn tensor_create(&self, name: &str, family: &TensorFamily) -> Result<bool> {
        self.tensor_lock().create(name, family)
    }

    /// One multi-mode stream item. With replication on it also lands in
    /// the tensor's origin accumulator (same fused fan-out discipline
    /// as the 2-D [`ShardedStore::update`]).
    pub fn tensor_update(&self, name: &str, key: &[usize], w: f64) -> Result<()> {
        let originate = self.replicate.load(Ordering::Relaxed);
        self.tensor_lock().update(name, key, w, originate)
    }

    /// A whole multi-mode batch through the fused multi-key kernel
    /// (`ws.len()` items, item `i`'s key at `keys[i·order ..]`).
    pub fn tensor_update_batch(&self, name: &str, keys: &[usize], ws: &[f64]) -> Result<()> {
        let originate = self.replicate.load(Ordering::Relaxed);
        self.tensor_lock().update_batch(name, keys, ws, originate)
    }

    /// Median-of-d point estimate at a multi-mode key.
    pub fn tensor_query(&self, name: &str, key: &[usize]) -> Result<f64> {
        self.tensor_lock().query(name, key)
    }

    /// Marginal over any mode subset, computed on the sketch.
    pub fn tensor_marginal(&self, name: &str, spec: &[Option<usize>]) -> Result<f64> {
        self.tensor_lock().marginal(name, spec)
    }

    /// Top-k keys within a fixed slice of one mode.
    pub fn tensor_slice_top_k(
        &self,
        name: &str,
        mode: usize,
        index: usize,
        k: usize,
    ) -> Result<Vec<(Vec<usize>, f64)>> {
        self.tensor_lock().slice_top_k(name, mode, index, k)
    }

    /// Sketched contraction between two stored same-family tensors.
    pub fn tensor_contract(
        &self,
        a_name: &str,
        b_name: &str,
        contracted: &[usize],
    ) -> Result<ContractOutput> {
        self.tensor_lock().contract(a_name, b_name, contracted)
    }

    /// Family of a registered tensor (`None` if unknown) — the wire
    /// layer fetches this to decode key payloads with full validation.
    pub fn tensor_family(&self, name: &str) -> Option<TensorFamily> {
        self.tensor_lock().family(name)
    }

    /// Registered tensor names, in catalog order.
    pub fn tensor_names(&self) -> Vec<String> {
        self.tensor_lock().names()
    }

    /// Tensor-plane origin-version stamp — the replicator's cheap
    /// "anything new to ship on the tensor plane?" probe. Only
    /// originating mutations move it (mirrors
    /// [`ShardedStore::origin_version`]).
    pub fn tensor_version(&self) -> u64 {
        self.tensor_lock().version()
    }

    /// Tensors with unshipped locally-originated mass relative to the
    /// caller's per-tensor acked map: `(name, version, cumulative
    /// origin sketch)` triples, each shipped as one full-state frame.
    pub fn tensor_dirty_origins(
        &self,
        acked: &HashMap<String, u64>,
    ) -> Vec<(String, u64, HcsStream)> {
        self.tensor_lock().dirty_origins(acked)
    }

    /// Apply one tensor replication frame (full cumulative state from a
    /// peer). Returns `Ok(true)` if mass was applied, `Ok(false)` on a
    /// dedup. Never re-originates and is never WAL-logged — see the
    /// registry docs.
    pub fn tensor_apply_origin_merge(
        &self,
        origin: u64,
        name: &str,
        seq: u64,
        full: HcsStream,
    ) -> Result<bool> {
        self.tensor_lock().apply_origin_merge(origin, name, seq, full)
    }

    /// Slide the window one epoch: in every shard the expiring slot is
    /// subtracted out of the running total and cleared for reuse.
    ///
    /// All shard locks are held (acquired in index order) while the
    /// rings rotate and the epoch counter bumps, so cross-shard readers
    /// ([`ShardedStore::merged`], [`ShardedStore::encode_into`]) see
    /// every shard pre-rotation or every shard post-rotation — never a
    /// torn mix. Point updates still only contend on their own shard.
    pub fn advance_epoch(&self) {
        let (_ld, mut guards) = self.lock_all();
        for guard in guards.iter_mut() {
            let sh = &mut **guard;
            let next = (sh.cur + 1) % self.cfg.window;
            // expiring slot leaves the total by subtraction (linearity)
            let (total, expiring) = (&mut sh.total, &sh.ring[next]);
            total.merge_scaled(expiring, -1.0);
            sh.ring[next].clear();
            sh.cur = next;
        }
        // both bumped while the locks are still held, so epoch, version
        // and cursors move together for any holder of all the locks.
        // The version bump alone would not tell the scan cache that the
        // totals shrank (pending deltas never record expiry); the epoch
        // bump is what routes its next refresh to the full re-merge.
        self.version.fetch_add(1, Ordering::SeqCst);
        self.epoch.fetch_add(1, Ordering::SeqCst);
    }

    /// Completed `advance_epoch` calls.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::SeqCst)
    }

    /// Updates currently inside the live window (expired epochs are
    /// subtracted out of this count too). Epoch-validated via
    /// [`ShardedStore::stats`], so the sum never mixes pre- and
    /// post-rotation shards.
    pub fn updates(&self) -> u64 {
        self.stats().updates
    }

    /// Epoch-validated like [`ShardedStore::point_query`]: the count is
    /// retried while rotations interleave with the per-shard sums, with
    /// the same bounded (and counted) fall-back to a fully-locked read.
    /// Already allocation-free — the sums are scalar accumulators.
    /// Includes the tensor plane's update count (tensors never expire,
    /// so the total stays monotone for a rotation-free workload — the
    /// crash harness's prefix-inference invariant).
    pub fn stats(&self) -> StoreStats {
        let tensor_updates = self.tensor_lock().updates();
        let mk = |epoch: u64, updates: u64| StoreStats {
            shards: self.cfg.shards,
            window: self.cfg.window,
            epoch,
            updates: updates + tensor_updates,
        };
        for _ in 0..EPOCH_RETRY_LIMIT {
            let e0 = self.epoch();
            let updates = self
                .shards
                .iter()
                .enumerate()
                .map(|(s, shm)| {
                    let _ld = lockdep::acquire(lockdep::SHARD, s as u32);
                    shm.lock().expect("shard lock").total.updates
                })
                .sum();
            if self.epoch() == e0 {
                return mk(e0, updates);
            }
        }
        self.lockall_fallbacks.fetch_add(1, Ordering::Relaxed);
        let (_ld, guards) = self.lock_all();
        mk(self.epoch(), guards.iter().map(|sh| sh.total.updates).sum())
    }

    /// Serialize config + every shard's ring/cursor/total (snapshots).
    /// Takes every shard lock up front (index order), so the encoded
    /// image is one instant of the whole store — a concurrent
    /// [`ShardedStore::advance_epoch`] lands entirely before or entirely
    /// after it, never halfway through the shards.
    pub(crate) fn encode_into(&self, out: &mut Vec<u8>) {
        let (_ld, guards) = self.lock_all();
        self.cfg.encode(out);
        codec::put_u64(out, self.epoch());
        for sh in &guards {
            codec::put_u32(out, sh.cur as u32);
            for sk in &sh.ring {
                sk.encode(out);
            }
            sh.total.encode(out);
        }
        // replication section (snapshot format v4): the cumulative
        // local-origin accumulator and its version stamp, captured under
        // the same all-locks instant as the shard images above — so a
        // recovered sender diffs peers against exactly the mass the
        // snapshot holds, and WAL replay rebuilds only the tail. A node
        // that never replicated writes one zero byte.
        let replicate = self.replicate.load(Ordering::SeqCst);
        codec::put_u8(out, u8::from(replicate));
        if replicate {
            codec::put_u64(out, self.origin_version.load(Ordering::SeqCst));
            let mut origin = self.cfg.fresh_sketch();
            for sh in &guards {
                origin.merge_scaled(&sh.origin, 1.0);
            }
            origin.encode(out);
        }
        // tensor plane (snapshot format v5): the whole catalog + its
        // replication channel table, appended after the 2-D image so
        // every pre-existing byte offset into the encoding stays put.
        // The registry lock is taken while the shard locks are held —
        // the one sanctioned shards→registry order (see the field doc).
        self.tensor_lock().encode_into(out);
    }

    /// Bit-exact inverse of [`ShardedStore::encode_into`].
    pub(crate) fn decode_from(rd: &mut Reader<'_>) -> Result<Self> {
        let cfg = StoreConfig::decode(rd)?;
        let epoch = rd.u64()?;
        let mut shards = Vec::with_capacity(cfg.shards);
        for _ in 0..cfg.shards {
            let cur = rd.u32()? as usize;
            ensure!(cur < cfg.window, "corrupt snapshot: epoch cursor out of range");
            let mut ring = Vec::with_capacity(cfg.window);
            for _ in 0..cfg.window {
                let sk = StreamSketch::decode(rd)?;
                ensure!(cfg.matches(&sk), "corrupt snapshot: ring sketch family mismatch");
                ring.push(sk);
            }
            let total = StreamSketch::decode(rd)?;
            ensure!(cfg.matches(&total), "corrupt snapshot: total sketch family mismatch");
            // pendings are redundant state (already inside the totals),
            // so snapshots do not carry them: a decoded store starts
            // with clean deltas and a never-built scan cache.
            shards.push(Mutex::new(Shard {
                ring,
                cur,
                total,
                pending: cfg.fresh_sketch(),
                pending_dirty: false,
                origin: cfg.fresh_sketch(),
            }));
        }
        // replication section (v4): a replicating node's cumulative
        // origin accumulator is durable — recovery must re-ship exactly
        // the WAL-recovered-but-unshipped remainder, which only works if
        // the accumulator survives bit-exactly. The whole image lands in
        // shard 0 (the per-shard split is an implementation detail; only
        // the all-shards merge is ever shipped, and new local mass keeps
        // landing per-shard on top).
        let replicate = rd.u8()? != 0;
        let mut origin_version = 0u64;
        if replicate {
            origin_version = rd.u64()?;
            let origin = StreamSketch::decode(rd)?;
            ensure!(cfg.matches(&origin), "corrupt snapshot: origin sketch family mismatch");
            shards[0].get_mut().expect("shard lock").origin = origin;
        }
        // tensor plane (v5): bit-exact catalog + channel table
        let tensors = TensorRegistry::decode_from(rd)?;
        let router_salt = Self::derive_salt(cfg.seed);
        let probe = cfg.fresh_sketch();
        let scan = ScanCache::empty(&cfg);
        Ok(Self {
            cfg,
            shards,
            epoch: AtomicU64::new(epoch),
            version: AtomicU64::new(0),
            replicate: AtomicBool::new(replicate),
            origin_version: AtomicU64::new(origin_version),
            scan,
            tensors: Mutex::new(tensors),
            lockall_fallbacks: AtomicU64::new(0),
            router_salt,
            probe,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn small_cfg(shards: usize, window: usize) -> StoreConfig {
        StoreConfig { n1: 48, n2: 40, m1: 12, m2: 10, d: 5, seed: 77, shards, window }
    }

    /// Integer weights make every f64 partial sum exact, so accumulation
    /// order (sharded vs interleaved) cannot change results and
    /// bit-identity is a meaningful assertion.
    fn int_weight(rng: &mut Pcg64) -> f64 {
        let mag = (1 + rng.gen_range(16)) as f64;
        if rng.uniform() < 0.25 {
            -mag
        } else {
            mag
        }
    }

    #[test]
    fn routing_is_deterministic_and_covers_shards() {
        let store = ShardedStore::new(small_cfg(4, 2));
        let mut seen = [false; 4];
        for i in 0..48 {
            for j in 0..40 {
                let s = store.shard_of(i, j);
                assert!(s < 4);
                assert_eq!(s, store.shard_of(i, j));
                seen[s] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "some shard got no keys: {seen:?}");
    }

    #[test]
    fn point_queries_bit_identical_to_unsharded_sketch() {
        for shards in [1usize, 2, 4, 8] {
            let cfg = small_cfg(shards, 3);
            let store = ShardedStore::new(cfg.clone());
            let mut reference = cfg.fresh_sketch();
            let mut rng = Pcg64::new(100 + shards as u64);
            for _ in 0..800 {
                let (i, j) = (rng.gen_range(48) as usize, rng.gen_range(40) as usize);
                let w = int_weight(&mut rng);
                store.update(i, j, w);
                reference.update(i, j, w);
            }
            assert_eq!(store.updates(), reference.updates);
            for i in 0..48 {
                for j in 0..40 {
                    assert_eq!(
                        store.point_query(i, j).to_bits(),
                        reference.query(i, j).to_bits(),
                        "shards={shards} key=({i},{j})"
                    );
                }
            }
            // merged sketch answers identically too
            let merged = store.merged();
            for _ in 0..100 {
                let (i, j) = (rng.gen_range(48) as usize, rng.gen_range(40) as usize);
                assert_eq!(merged.query(i, j).to_bits(), reference.query(i, j).to_bits());
            }
        }
    }

    #[test]
    fn window_expiry_leaves_exactly_the_recent_epochs() {
        let cfg = small_cfg(4, 2);
        let store = ShardedStore::new(cfg.clone());
        let mut rng = Pcg64::new(9);
        let phase = |rng: &mut Pcg64| -> Vec<(usize, usize, f64)> {
            (0..300)
                .map(|_| {
                    (rng.gen_range(48) as usize, rng.gen_range(40) as usize, int_weight(rng))
                })
                .collect()
        };
        let a = phase(&mut rng);
        let b = phase(&mut rng);
        for &(i, j, w) in &a {
            store.update(i, j, w);
        }
        store.advance_epoch();
        for &(i, j, w) in &b {
            store.update(i, j, w);
        }
        store.advance_epoch(); // phase A expires (window = 2)
        assert_eq!(store.epoch(), 2);
        let mut only_b = cfg.fresh_sketch();
        for &(i, j, w) in &b {
            only_b.update(i, j, w);
        }
        assert_eq!(store.updates(), only_b.updates);
        for _ in 0..200 {
            let (i, j) = (rng.gen_range(48) as usize, rng.gen_range(40) as usize);
            assert_eq!(
                store.point_query(i, j).to_bits(),
                only_b.query(i, j).to_bits(),
                "key ({i}, {j})"
            );
        }
    }

    #[test]
    fn window_one_keeps_only_current_epoch() {
        let cfg = small_cfg(2, 1);
        let store = ShardedStore::new(cfg);
        store.update(1, 1, 5.0);
        store.advance_epoch();
        assert_eq!(store.updates(), 0);
        assert_eq!(store.point_query(1, 1), 0.0);
        store.update(2, 2, 3.0);
        assert_eq!(store.updates(), 1);
    }

    #[test]
    fn merge_sketch_adds_foreign_traffic() {
        let cfg = small_cfg(3, 2);
        let store = ShardedStore::new(cfg.clone());
        store.update(5, 5, 2.0);
        // a remote node observed more of the same key
        let mut remote = cfg.fresh_sketch();
        remote.update(5, 5, 3.0);
        remote.update(7, 1, 4.0);
        store.merge_sketch(&remote).unwrap();
        assert_eq!(store.point_query(5, 5), 5.0);
        assert_eq!(store.point_query(7, 1), 4.0);
        // merged traffic ages out with the window
        store.advance_epoch();
        store.advance_epoch();
        assert_eq!(store.point_query(5, 5), 0.0);
        // wrong-family sketches are rejected
        let alien = StreamSketch::new(48, 40, 12, 10, 5, 12345);
        assert!(store.merge_sketch(&alien).is_err());
    }

    #[test]
    fn topk_and_heavy_hitters_over_merged_window() {
        let cfg = small_cfg(4, 2);
        let store = ShardedStore::new(cfg);
        let mut rng = Pcg64::new(4);
        for _ in 0..400 {
            store.update(3, 4, 1.0);
        }
        for _ in 0..200 {
            store.update(20, 30, 1.0);
        }
        for _ in 0..300 {
            store.update(rng.gen_range(48) as usize, rng.gen_range(40) as usize, 1.0);
        }
        let top = store.top_k(2);
        assert_eq!(top.len(), 2);
        assert_eq!((top[0].0, top[0].1), (3, 4));
        assert_eq!((top[1].0, top[1].1), (20, 30));
        let hh = store.heavy_hitters(150.0);
        assert!(hh.iter().any(|&(i, j, _)| (i, j) == (3, 4)));
        assert!(hh.iter().any(|&(i, j, _)| (i, j) == (20, 30)));
    }

    #[test]
    fn cached_scans_match_uncached_re_merge() {
        // the scan cache must be indistinguishable from a full K-way
        // re-merge after every kind of mutation: first build, then an
        // incremental pending-delta fold, a rotation (full-rebuild
        // path), a remote merge carrying a deletion (dense-scan
        // routing), and total expiry
        let cfg = small_cfg(4, 3);
        let store = ShardedStore::new(cfg.clone());
        let mut rng = Pcg64::new(21);
        let step = |store: &ShardedStore, rng: &mut Pcg64, n: usize| {
            for _ in 0..n {
                let (i, j) = (rng.gen_range(48) as usize, rng.gen_range(40) as usize);
                store.update(i, j, (1 + rng.gen_range(9)) as f64);
            }
        };
        let check = |store: &ShardedStore| {
            let fresh = store.merged_uncached();
            let cached = store.merged();
            assert_eq!(cached.updates, fresh.updates);
            assert_eq!(cached.has_deletions, fresh.has_deletions);
            for r in 0..5 {
                assert_eq!(cached.table(r), fresh.table(r), "table {r}");
            }
            for k in [1usize, 3, 8] {
                assert_eq!(store.top_k(k), fresh.top_k(k), "k={k}");
                // second call at the same k takes the memoized path
                assert_eq!(store.top_k(k), fresh.top_k(k), "memoized k={k}");
            }
            for t in [5.0, 40.0] {
                assert_eq!(store.heavy_hitters(t), fresh.heavy_hitters(t), "t={t}");
                assert_eq!(store.heavy_hitters(t), fresh.heavy_hitters(t), "memoized t={t}");
            }
        };
        step(&store, &mut rng, 300);
        check(&store); // first build (never-built cache → full merge)
        step(&store, &mut rng, 200);
        check(&store); // incremental fold of the pending deltas
        store.advance_epoch();
        check(&store); // rotation forces the full-rebuild path
        step(&store, &mut rng, 150);
        let mut remote = cfg.fresh_sketch();
        remote.update(1, 2, -3.0); // a deletion arrives via MERGE
        store.merge_sketch(&remote).unwrap();
        check(&store); // has_deletions routes scans to the dense variants
        assert!(store.merged().has_deletions);
        for _ in 0..3 {
            store.advance_epoch();
        }
        check(&store); // everything expired
        assert_eq!(store.updates(), 0);
    }

    #[test]
    fn scan_cache_invalidates_on_every_mutation_kind() {
        // after every kind of mutation the next scan must reflect it —
        // i.e. match a fresh re-merge, never a stale memoized answer
        let cfg = small_cfg(2, 2);
        let store = ShardedStore::new(cfg.clone());
        let expect_fresh = |store: &ShardedStore| {
            let fresh = store.merged_uncached();
            assert_eq!(store.top_k(3), fresh.top_k(3));
            assert_eq!(store.heavy_hitters(1.0), fresh.heavy_hitters(1.0));
            assert_eq!(store.merged().updates, fresh.updates);
        };
        store.update(1, 1, 10.0);
        expect_fresh(&store);
        store.update(2, 2, 20.0); // single update invalidates
        expect_fresh(&store);
        let mut remote = cfg.fresh_sketch();
        remote.update(3, 3, 40.0);
        store.merge_sketch(&remote).unwrap(); // remote merge invalidates
        expect_fresh(&store);
        store.update_batch(&[(4, 4, 1.0), (5, 5, 2.0), (6, 6, 3.0)]); // batch invalidates
        expect_fresh(&store);
        store.advance_epoch();
        store.advance_epoch(); // window 2: everything expires
        expect_fresh(&store);
        assert_eq!(store.updates(), 0);
        assert_eq!(store.merged().updates, 0, "expired mass still served from cache");
    }

    #[test]
    fn snapshot_roundtrip_is_bit_exact() {
        let cfg = small_cfg(3, 4);
        let store = ShardedStore::new(cfg);
        let mut rng = Pcg64::new(6);
        for _ in 0..500 {
            store.update(rng.gen_range(48) as usize, rng.gen_range(40) as usize, rng.normal());
        }
        store.advance_epoch();
        for _ in 0..200 {
            store.update(rng.gen_range(48) as usize, rng.gen_range(40) as usize, rng.normal());
        }
        let mut bytes = Vec::new();
        store.encode_into(&mut bytes);
        let got = ShardedStore::decode_from(&mut Reader::new(&bytes)).unwrap();
        assert_eq!(got.config(), store.config());
        assert_eq!(got.epoch(), store.epoch());
        assert_eq!(got.updates(), store.updates());
        for _ in 0..200 {
            let (i, j) = (rng.gen_range(48) as usize, rng.gen_range(40) as usize);
            assert_eq!(got.point_query(i, j).to_bits(), store.point_query(i, j).to_bits());
        }
        // and the recovered store keeps working (same routing)
        got.update(1, 2, 3.0);
        store.update(1, 2, 3.0);
        assert_eq!(got.point_query(1, 2).to_bits(), store.point_query(1, 2).to_bits());
    }

    /// Byte offset of the first shard's epoch cursor in an
    /// [`ShardedStore::encode_into`] image, computed from the codec
    /// itself (config encoding + the u64 epoch stamp) so a config-format
    /// change moves the tests with it instead of silently neutering them.
    fn cursor_base(cfg: &StoreConfig) -> usize {
        let mut hdr = Vec::new();
        cfg.encode(&mut hdr);
        codec::put_u64(&mut hdr, 0);
        hdr.len()
    }

    /// Encoded length of one sketch of this family (fixed: the tables
    /// are dense, so empty and full sketches encode identically long).
    fn sketch_encoded_len(cfg: &StoreConfig) -> usize {
        let mut b = Vec::new();
        cfg.fresh_sketch().encode(&mut b);
        b.len()
    }

    #[test]
    fn update_batch_bit_identical_to_per_item_updates() {
        let cfg = small_cfg(4, 2);
        let batched = ShardedStore::new(cfg.clone());
        let single = ShardedStore::new(cfg.clone());
        let mut rng = Pcg64::new(13);
        let items: Vec<(usize, usize, f64)> = (0..700)
            .map(|_| {
                (rng.gen_range(48) as usize, rng.gen_range(40) as usize, int_weight(&mut rng))
            })
            .collect();
        batched.update_batch(&items[..350]);
        batched.update_batch(&[]);
        batched.update_batch(&items[350..]);
        for &(i, j, w) in &items {
            single.update(i, j, w);
        }
        assert_eq!(batched.updates(), single.updates());
        for i in 0..48 {
            for j in 0..40 {
                assert_eq!(
                    batched.point_query(i, j).to_bits(),
                    single.point_query(i, j).to_bits(),
                    "key ({i}, {j})"
                );
            }
        }
        // and the batch respects the current epoch slot: advancing the
        // window past it expires batched mass exactly like single mass
        batched.advance_epoch();
        batched.advance_epoch();
        single.advance_epoch();
        single.advance_epoch();
        assert_eq!(batched.updates(), single.updates());
        assert_eq!(batched.updates(), 0);
    }

    #[test]
    fn concurrent_advance_and_reads_see_consistent_state() {
        // Epoch rotation touches every shard; per-shard locking could
        // let a cross-shard reader capture shard 0 post-rotation and
        // shard 3 pre-rotation (a torn multi-shard read). Invariants
        // hammered here, all of which only hold for reads of one
        // consistent instant:
        // - encode_into: all shards' epoch cursors are identical (they
        //   start at 0 and only advance_epoch moves them, in lockstep);
        // - updates()/stats(): one preloaded update per shard, window 3
        //   → the live count is K before the preload epoch expires and
        //   0 after, never a partial sum in between;
        // - point_query: each preloaded key answers its pre-expiry
        //   estimate or 0.0, bit-exactly, never a mix.
        let cfg = small_cfg(4, 3);
        let store = ShardedStore::new(cfg.clone());
        // one weight-1 key per shard (seed 77 routing covers all four)
        let mut keys: Vec<Option<(usize, usize)>> = vec![None; cfg.shards];
        for i in 0..cfg.n1 {
            for j in 0..cfg.n2 {
                let s = store.shard_of(i, j);
                if keys[s].is_none() {
                    keys[s] = Some((i, j));
                    store.update(i, j, 1.0);
                }
            }
        }
        let keys: Vec<(usize, usize)> = keys.into_iter().map(|k| k.unwrap()).collect();
        let pre: Vec<u64> =
            keys.iter().map(|&(i, j)| store.point_query(i, j).to_bits()).collect();
        let preloaded = cfg.shards as u64;

        let base = cursor_base(&cfg);
        // per shard: u32 cursor + window ring sketches + the total
        let stride = 4 + (cfg.window + 1) * sketch_encoded_len(&cfg);
        std::thread::scope(|scope| {
            let advancer = scope.spawn(|| {
                for _ in 0..150 {
                    store.advance_epoch();
                }
            });
            for _ in 0..150 {
                let mut bytes = Vec::new();
                store.encode_into(&mut bytes);
                let cursors: Vec<u32> = (0..cfg.shards)
                    .map(|s| {
                        let off = base + s * stride;
                        u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap())
                    })
                    .collect();
                assert!(
                    cursors.iter().all(|&c| c == cursors[0]),
                    "torn multi-shard encode: cursors {cursors:?}"
                );
                let u = store.updates();
                assert!(
                    u == preloaded || u == 0,
                    "torn multi-shard count: {u} (want {preloaded} or 0)"
                );
                let st = store.stats();
                assert!(st.updates == preloaded || st.updates == 0, "torn stats: {st:?}");
                for (&(i, j), &want) in keys.iter().zip(pre.iter()) {
                    let got = store.point_query(i, j);
                    // `== 0.0` (not bits): post-expiry estimates may be
                    // a signed zero depending on the key's sign product
                    assert!(
                        got.to_bits() == want || got == 0.0,
                        "torn point query at ({i}, {j}): {got}"
                    );
                }
            }
            advancer.join().unwrap();
        });
        assert_eq!(store.epoch(), 150);
        assert_eq!(store.updates(), 0, "window 3 expired the preload long ago");
    }

    #[test]
    fn origin_accumulator_tracks_exactly_the_local_mass() {
        let cfg = small_cfg(3, 2);
        let store = ShardedStore::new(cfg.clone());
        // mass written before replication is enabled is not captured
        store.update(1, 1, 4.0);
        store.set_replication(true);
        let (v0, empty) = store.origin_snapshot();
        assert_eq!(v0, 0);
        assert_eq!(empty.updates, 0);

        // local traffic of every kind lands in the origin accumulator
        let mut reference = cfg.fresh_sketch();
        let mut rng = Pcg64::new(55);
        for _ in 0..200 {
            let (i, j) = (rng.gen_range(48) as usize, rng.gen_range(40) as usize);
            let w = int_weight(&mut rng);
            store.update(i, j, w);
            reference.update(i, j, w);
        }
        let items: Vec<(usize, usize, f64)> = (0..60)
            .map(|_| {
                (rng.gen_range(48) as usize, rng.gen_range(40) as usize, int_weight(&mut rng))
            })
            .collect();
        store.update_batch(&items);
        reference.update_batch(&items);
        let mut edge = cfg.fresh_sketch();
        edge.update(5, 5, 9.0);
        store.merge_sketch(&edge).unwrap(); // ingest: relayed
        reference.merge_scaled(&edge, 1.0);

        // replication-plane mass must NOT enter the accumulator
        let mut remote = cfg.fresh_sketch();
        remote.update(7, 7, 3.0);
        store.merge_sketch_opts(&remote, false).unwrap();
        // ... but it is in the store itself
        assert_eq!(store.point_query(7, 7), 3.0);

        let (v1, origin) = store.origin_snapshot();
        assert!(v1 > 0);
        assert_eq!(origin.updates, reference.updates);
        for r in 0..cfg.d {
            assert_eq!(origin.table(r), reference.table(r), "origin table {r} diverges");
        }

        // rotations expire the window but never the origin accumulator,
        // and do not move the origin version (nothing new to ship)
        store.advance_epoch();
        store.advance_epoch();
        assert_eq!(store.updates(), 0);
        let (v2, after) = store.origin_snapshot();
        assert_eq!(v2, v1);
        assert_eq!(after.updates, reference.updates);
        for r in 0..cfg.d {
            assert_eq!(after.table(r), reference.table(r));
        }
    }

    #[test]
    fn snapshot_carries_origin_accumulator_when_replicating() {
        let cfg = small_cfg(3, 2);
        let store = ShardedStore::new(cfg.clone());
        store.set_replication(true);
        let mut rng = Pcg64::new(71);
        for _ in 0..300 {
            store.update(
                rng.gen_range(48) as usize,
                rng.gen_range(40) as usize,
                int_weight(&mut rng),
            );
        }
        store.advance_epoch(); // expiry must not touch the accumulator
        let (v, origin) = store.origin_snapshot();
        assert!(v > 0);
        let mut bytes = Vec::new();
        store.encode_into(&mut bytes);
        let got = ShardedStore::decode_from(&mut Reader::new(&bytes)).unwrap();
        assert!(got.replication_enabled(), "replicate flag lost in snapshot");
        let (gv, gorigin) = got.origin_snapshot();
        assert_eq!(gv, v, "origin version stamp lost");
        assert_eq!(gorigin.updates, origin.updates);
        for r in 0..cfg.d {
            assert_eq!(gorigin.table(r), origin.table(r), "origin table {r} diverges");
        }
        // a non-replicating store writes (and reads back) the flag off
        let plain = ShardedStore::new(small_cfg(2, 2));
        plain.update(1, 1, 1.0);
        let mut pb = Vec::new();
        plain.encode_into(&mut pb);
        let pg = ShardedStore::decode_from(&mut Reader::new(&pb)).unwrap();
        assert!(!pg.replication_enabled());
        assert_eq!(pg.origin_snapshot().1.updates, 0);
    }

    #[test]
    fn tensor_plane_rides_in_the_store_snapshot() {
        use super::super::tensor::registry::TensorFamily;
        let cfg = small_cfg(2, 2);
        let store = ShardedStore::new(cfg);
        let fam = TensorFamily {
            dims: vec![20, 16, 12],
            sketch_dims: vec![6, 5, 4],
            d: 3,
            seed: 42,
        };
        assert!(store.tensor_create("t", &fam).unwrap());
        assert!(!store.tensor_create("t", &fam).unwrap(), "re-create must be a no-op");
        store.tensor_update("t", &[1, 2, 3], 5.0).unwrap();
        store
            .tensor_update_batch("t", &[4, 5, 6, 1, 2, 3], &[2.0, 1.0])
            .unwrap();
        store.update(0, 0, 9.0); // 2-D plane still works alongside
        assert_eq!(store.tensor_query("t", &[1, 2, 3]).unwrap(), 6.0);
        // STATS counts both planes
        assert_eq!(store.stats().updates, 4);
        // replication off: nothing accumulates for shipping
        assert!(store.tensor_dirty_origins(&HashMap::new()).is_empty());

        let mut bytes = Vec::new();
        store.encode_into(&mut bytes);
        let got = ShardedStore::decode_from(&mut Reader::new(&bytes)).unwrap();
        assert_eq!(got.tensor_family("t"), Some(fam));
        assert_eq!(
            got.tensor_query("t", &[1, 2, 3]).unwrap().to_bits(),
            store.tensor_query("t", &[1, 2, 3]).unwrap().to_bits()
        );
        assert_eq!(got.stats().updates, store.stats().updates);

        // replication on: tensor writes feed the origin accumulator
        store.set_replication(true);
        store.tensor_update("t", &[7, 8, 9], 4.0).unwrap();
        let dirty = store.tensor_dirty_origins(&HashMap::new());
        assert_eq!(dirty.len(), 1);
        assert_eq!(dirty[0].2.updates, 1, "pre-replication mass must not ship");
        assert_eq!(store.tensor_version(), dirty[0].1);
    }

    #[test]
    fn decode_rejects_corrupt_cursor() {
        let cfg = small_cfg(2, 2);
        let store = ShardedStore::new(cfg.clone());
        let mut bytes = Vec::new();
        store.encode_into(&mut bytes);
        // first shard's cursor sits right after the config + epoch
        // header; point it past the window
        bytes[cursor_base(&cfg)] = 9;
        assert!(ShardedStore::decode_from(&mut Reader::new(&bytes)).is_err());
    }
}
