//! Named-tensor catalog: the server-side registry of [`HcsStream`]
//! sketches, plus the receiver-side replication channel table for the
//! tensor plane.
//!
//! A store holds many named tensors (e.g. `user×feature×time`), each
//! with its own mode dims / sketch family. The registry is the single
//! mutation point: every originating write lands in the tensor's live
//! sketch *and* (when replication is on) its lazily-allocated origin
//! accumulator through the same fused fan-out kernel the 2-D plane
//! uses, and stamps the entry with a registry-global version counter.
//! That stamp doubles as the replication sequence number: it only moves
//! on locally-originated mutations, so an unchanged stamp means
//! "nothing new to ship" — exactly the 2-D `origin_version` contract.
//!
//! **Tensor replication is full-ship only.** The 2-D plane earns its
//! delta cursors from a strict `seq == last + 1` channel; per-tensor
//! deltas would need one durable cursor per (peer, tensor) to keep that
//! invariant across restarts. Instead every tensor frame carries the
//! origin's *entire* cumulative accumulator: [`TensorOriginTable`]
//! applies `full − received` (linearity — exactly the unseen mass), so
//! frames are idempotent at any sequence, a receiver restart heals on
//! the next frame without gap protocol, and `seq ≤ last` is still a
//! full-history dedup horizon. The price is frame size; tensors are
//! sketches (fixed `d · Π m_k` counters), so a full ship is the same
//! O(space) as a delta.
//!
//! Replica-plane merges land in the tensor's live sketch only — never
//! the origin accumulator (no re-origination: a mesh with more than one
//! path must not deliver mass twice) and never the WAL (anti-entropy,
//! not the log, restores replica mass after a crash).

use super::super::codec::{self, Reader};
use super::super::mergeable::MergeableSketch;
use super::contract::{self, ContractOutput};
use super::hcs::{HcsStream, MAX_ORDER};
use anyhow::{bail, ensure, Result};
use std::collections::{BTreeMap, HashMap};

/// Cap on registered tensors: each costs `d · Π m_k` counters (plus an
/// equal-sized origin accumulator on a replicating node), so an
/// unbounded catalog would let any client grow server memory without
/// limit. Creates past the cap are rejected — tensors are never
/// deleted, so unlike the origin tables there is no safe eviction.
pub const MAX_TENSORS: usize = 64;

/// Cap on one tensor's counter space (`d · Π m_k` f64 slots, ≈ 32 MiB).
/// Sketch dims are the *compressed* geometry — a family this large is a
/// misconfiguration (or a hostile TCREATE), not a workload.
pub const MAX_TENSOR_SPACE: usize = 1 << 22;

/// Cap on tracked (origin, tensor) replication channels, mirroring
/// [`super::super::replica::origins::MAX_ORIGINS`]: each retains one
/// sketch-sized cumulative record. At the cap the least-recently-active
/// channel is evicted; because tensor frames are full-ship only, an
/// evicted-but-live channel degrades gracefully — its next frame is
/// admitted as unknown and re-applies mass the table no longer
/// remembers, never a protocol halt.
pub const MAX_TENSOR_CHANNELS: usize = 64;

/// Identity of one tensor's sketch family: key universe, sketch
/// geometry, repeats, and hash-family seed. Two sketches interoperate
/// (merge / contract) iff their families are equal.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorFamily {
    pub dims: Vec<usize>,
    pub sketch_dims: Vec<usize>,
    pub d: usize,
    pub seed: u64,
}

impl TensorFamily {
    /// The family an existing sketch belongs to.
    pub fn of(sk: &HcsStream) -> Self {
        Self {
            dims: sk.dims().to_vec(),
            sketch_dims: sk.sketch_dims().to_vec(),
            d: sk.d,
            seed: sk.seed,
        }
    }

    pub fn order(&self) -> usize {
        self.dims.len()
    }

    /// Counter space of one sketch of this family (`d · Π m_k`).
    pub fn space(&self) -> usize {
        let mut s = self.d;
        for &m in &self.sketch_dims {
            s = s.saturating_mul(m);
        }
        s
    }

    pub fn validate(&self) -> Result<()> {
        let order = self.dims.len();
        ensure!(
            (1..=MAX_ORDER).contains(&order),
            "tensor order {order} outside 1..={MAX_ORDER}"
        );
        ensure!(
            self.sketch_dims.len() == order,
            "tensor family has {} sketch dims for {order} modes",
            self.sketch_dims.len()
        );
        ensure!(
            self.dims.iter().all(|&n| n > 0) && self.sketch_dims.iter().all(|&m| m > 0),
            "tensor family has an empty mode"
        );
        ensure!(
            self.dims.iter().zip(self.sketch_dims.iter()).all(|(&n, &m)| m <= n),
            "tensor sketch dim exceeds its mode dim (sketches compress, never expand)"
        );
        ensure!(self.d >= 1, "tensor family needs at least one repeat");
        ensure!(
            self.space() <= MAX_TENSOR_SPACE,
            "tensor family of {} counters exceeds cap {MAX_TENSOR_SPACE}",
            self.space()
        );
        // every dim must survive the u32 wire/WAL encoding
        ensure!(
            self.dims.iter().chain(self.sketch_dims.iter()).all(|&v| v <= u32::MAX as usize)
                && self.d <= u32::MAX as usize,
            "tensor family field too large to encode"
        );
        Ok(())
    }

    pub fn fresh(&self) -> HcsStream {
        HcsStream::new(&self.dims, &self.sketch_dims, self.d, self.seed)
    }

    /// Does `sk` belong to this family?
    pub fn matches(&self, sk: &HcsStream) -> bool {
        sk.dims() == self.dims.as_slice()
            && sk.sketch_dims() == self.sketch_dims.as_slice()
            && sk.d == self.d
            && sk.seed == self.seed
    }

    /// `u8 order | order×u32 dims | order×u32 sketch dims | u32 d |
    /// u64 seed` — shared by the TCREATE wire body and the WAL's
    /// TensorCreate record.
    pub fn encode(&self, out: &mut Vec<u8>) {
        codec::put_u8(out, self.order() as u8);
        for &n in &self.dims {
            codec::put_u32(out, n as u32);
        }
        for &m in &self.sketch_dims {
            codec::put_u32(out, m as u32);
        }
        codec::put_u32(out, self.d as u32);
        codec::put_u64(out, self.seed);
    }

    /// Inverse of [`TensorFamily::encode`], fully validated — WAL
    /// frames and network payloads are untrusted.
    pub fn decode(rd: &mut Reader<'_>) -> Result<Self> {
        let order = rd.u8()? as usize;
        ensure!((1..=MAX_ORDER).contains(&order), "tensor order {order} outside 1..={MAX_ORDER}");
        let mut dims = Vec::with_capacity(order);
        for _ in 0..order {
            dims.push(rd.u32()? as usize);
        }
        let mut sketch_dims = Vec::with_capacity(order);
        for _ in 0..order {
            sketch_dims.push(rd.u32()? as usize);
        }
        let d = rd.u32()? as usize;
        let seed = rd.u64()?;
        let fam = Self { dims, sketch_dims, d, seed };
        fam.validate()?;
        Ok(fam)
    }
}

/// Reject a multi-mode key against a tensor's dims with an error (never
/// a panic): tensor keys arrive from the wire and the WAL.
pub(crate) fn validate_key(dims: &[usize], key: &[usize]) -> Result<()> {
    ensure!(
        key.len() == dims.len(),
        "tensor key order {} does not match tensor order {}",
        key.len(),
        dims.len()
    );
    for (k, (&i, &n)) in key.iter().zip(dims.iter()).enumerate() {
        ensure!(i < n, "tensor key mode {k} index {i} out of range (dim {n})");
    }
    Ok(())
}

/// One registered tensor.
struct TensorEntry {
    /// the live, queryable sketch (local + replicated mass)
    hcs: HcsStream,
    /// cumulative locally-originated mass — what the replicator ships.
    /// Allocated lazily on the first originating write under
    /// replication, so a standalone store pays nothing.
    origin: Option<HcsStream>,
    /// registry-global version stamp of the last *originating* mutation
    /// (replica-plane merges do not move it) — the replication sequence
    /// number for this tensor's channel.
    version: u64,
}

/// Outcome of admitting one tensor replication frame.
pub enum TensorAdmit {
    /// Merge this (remainder) sketch into the tensor, then commit.
    Apply(HcsStream),
    /// Retry at or below the dedup horizon — acknowledged no-op.
    Dedup,
}

struct TensorChannel {
    last_seq: u64,
    /// eviction clock stamp of the last applied frame
    last_active: u64,
    /// cumulative mass applied on this channel (deliveries, not live
    /// state)
    received: HcsStream,
}

/// Receiver-side per-(origin, tensor) replay protection. Full-ship
/// only: see the module docs for why the tensor plane drops the delta
/// protocol entirely.
pub struct TensorOriginTable {
    channels: HashMap<(u64, String), TensorChannel>,
    cap: usize,
    clock: u64,
}

impl TensorOriginTable {
    pub fn new(cap: usize) -> Self {
        Self { channels: HashMap::new(), cap, clock: 0 }
    }

    pub fn len(&self) -> usize {
        self.channels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.channels.is_empty()
    }

    /// Validate one full-state frame and return the unseen remainder to
    /// merge. Does not mutate — call [`TensorOriginTable::commit`]
    /// after the store merge succeeds, so a failed merge leaves the
    /// channel ready for an exact retry.
    pub fn admit(&self, origin: u64, name: &str, seq: u64, full: HcsStream) -> TensorAdmit {
        match self.channels.get(&(origin, name.to_string())) {
            None => TensorAdmit::Apply(full),
            Some(ch) => {
                if seq <= ch.last_seq {
                    return TensorAdmit::Dedup;
                }
                // apply only the unseen remainder; merge_scaled with -1
                // also subtracts update counts, so the remainder counts
                // exactly the new items
                let mut delta = full;
                delta.merge_scaled(&ch.received, -1.0);
                TensorAdmit::Apply(delta)
            }
        }
    }

    /// Record a successfully-applied frame: advance the dedup horizon
    /// and fold the applied mass into the channel's cumulative record.
    /// A new channel at the cap evicts the least-recently-active one
    /// (safe: full-ship frames re-admit an evicted channel as unknown).
    pub fn commit(&mut self, origin: u64, name: &str, seq: u64, applied: &HcsStream) {
        self.clock += 1;
        let key = (origin, name.to_string());
        if !self.channels.contains_key(&key) && self.channels.len() >= self.cap {
            let stalest = self
                .channels
                .iter()
                .min_by_key(|(_, ch)| ch.last_active)
                .map(|(k, _)| k.clone());
            if let Some(k) = stalest {
                crate::log_warn!(
                    "store: tensor channel table at cap ({}); evicting stalest channel \
                     (origin {:#x}, tensor {:?}) to admit (origin {origin:#x}, tensor {name:?})",
                    self.cap,
                    k.0,
                    k.1
                );
                self.channels.remove(&k);
            }
        }
        let clock = self.clock;
        let ch = self.channels.entry(key).or_insert_with(|| TensorChannel {
            last_seq: 0,
            last_active: 0,
            received: {
                let mut empty = applied.clone();
                empty.clear();
                empty
            },
        });
        ch.received.merge_scaled(applied, 1.0);
        ch.last_seq = seq;
        ch.last_active = clock;
    }

    /// Serialize (snapshot persistence), in sorted (origin, name) order
    /// so identical tables encode identically.
    fn encode_into(&self, out: &mut Vec<u8>) {
        codec::put_u64(out, self.clock);
        codec::put_u32(out, self.channels.len() as u32);
        let mut keys: Vec<&(u64, String)> = self.channels.keys().collect();
        keys.sort();
        for key in keys {
            let ch = &self.channels[key];
            codec::put_u64(out, key.0);
            codec::put_name(out, &key.1);
            codec::put_u64(out, ch.last_seq);
            codec::put_u64(out, ch.last_active);
            ch.received.encode(out);
        }
    }

    /// Bit-exact inverse of `encode_into`; each channel's cumulative
    /// record is validated against its tensor's family via `lookup`.
    fn decode_from(
        rd: &mut Reader<'_>,
        lookup: impl Fn(&str) -> Option<TensorFamily>,
    ) -> Result<Self> {
        let clock = rd.u64()?;
        let count = rd.u32()? as usize;
        ensure!(
            count <= MAX_TENSOR_CHANNELS,
            "snapshot tensor channel table of {count} entries exceeds cap"
        );
        let mut channels = HashMap::with_capacity(count);
        for _ in 0..count {
            let origin = rd.u64()?;
            let name = codec::read_name(rd)?;
            let last_seq = rd.u64()?;
            let last_active = rd.u64()?;
            let received = HcsStream::decode(rd)?;
            let fam = match lookup(&name) {
                Some(f) => f,
                None => bail!("corrupt snapshot: tensor channel for unknown tensor {name:?}"),
            };
            ensure!(
                fam.matches(&received),
                "corrupt snapshot: tensor channel {name:?} family mismatch"
            );
            channels.insert((origin, name), TensorChannel { last_seq, last_active, received });
        }
        Ok(Self { channels, cap: MAX_TENSOR_CHANNELS, clock })
    }
}

/// The named-tensor catalog for one store, plus its receiver-side
/// channel table. Owned by `ShardedStore` behind one mutex — tensor
/// sketches are small and their ops never touch the 2-D shard locks, so
/// a single lock domain suffices (and keeps the snapshot image of the
/// whole catalog trivially consistent).
pub struct TensorRegistry {
    tensors: BTreeMap<String, TensorEntry>,
    /// registry-global mutation counter: bumped by every originating
    /// mutation, stamped onto the mutated entry. Strictly increasing
    /// across the catalog, so per-tensor stamps are strictly increasing
    /// too — a valid replication sequence.
    version: u64,
    channels: TensorOriginTable,
}

impl Default for TensorRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl TensorRegistry {
    pub fn new() -> Self {
        Self {
            tensors: BTreeMap::new(),
            version: 0,
            channels: TensorOriginTable::new(MAX_TENSOR_CHANNELS),
        }
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Registry-global version stamp — the replicator's cheap "anything
    /// new on the tensor plane?" probe. Only originating mutations move
    /// it.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Total updates across every tensor's live sketch (STATS; also the
    /// crash harness's prefix-inference counter, so every tensor op
    /// must advance it).
    pub fn updates(&self) -> u64 {
        self.tensors.values().map(|e| e.hcs.updates).sum()
    }

    pub fn names(&self) -> Vec<String> {
        self.tensors.keys().cloned().collect()
    }

    pub fn family(&self, name: &str) -> Option<TensorFamily> {
        self.tensors.get(name).map(|e| TensorFamily::of(&e.hcs))
    }

    fn entry(&self, name: &str) -> Result<&TensorEntry> {
        match self.tensors.get(name) {
            Some(e) => Ok(e),
            None => bail!("unknown tensor {name:?}"),
        }
    }

    /// Register `name` with `family`. Idempotent on an identical
    /// family (re-running a recovered WAL / a retried TCREATE must not
    /// fail); a *different* family under a live name is a hard error —
    /// silently replacing it would orphan every sketch shipped or
    /// merged under the old family.
    pub fn create(&mut self, name: &str, family: &TensorFamily) -> Result<bool> {
        family.validate()?;
        ensure!(!name.is_empty(), "tensor name must be non-empty");
        ensure!(
            name.len() <= codec::MAX_TENSOR_NAME,
            "tensor name of {} bytes exceeds cap {}",
            name.len(),
            codec::MAX_TENSOR_NAME
        );
        if let Some(e) = self.tensors.get(name) {
            ensure!(
                family.matches(&e.hcs),
                "tensor {name:?} already exists with a different family"
            );
            return Ok(false);
        }
        ensure!(
            self.tensors.len() < MAX_TENSORS,
            "tensor catalog at cap ({MAX_TENSORS}); cannot create {name:?}"
        );
        self.tensors.insert(
            name.to_string(),
            TensorEntry { hcs: family.fresh(), origin: None, version: 0 },
        );
        Ok(true)
    }

    /// One multi-mode stream item. With `originate` (replication on),
    /// the fused fan-out kernel lands it in the live sketch *and* the
    /// origin accumulator with one hash walk, and the entry is stamped
    /// with a fresh global version.
    pub fn update(&mut self, name: &str, key: &[usize], w: f64, originate: bool) -> Result<()> {
        let version = &mut self.version;
        let e = match self.tensors.get_mut(name) {
            Some(e) => e,
            None => bail!("unknown tensor {name:?}"),
        };
        validate_key(e.hcs.dims(), key)?;
        if originate {
            let origin = e.origin.get_or_insert_with(|| {
                let mut empty = e.hcs.clone();
                empty.clear();
                empty
            });
            HcsStream::update_fanout(&mut [&mut e.hcs, origin], key, w);
            *version += 1;
            e.version = *version;
        } else {
            e.hcs.update(key, w);
            *version += 1;
            e.version = *version;
        }
        Ok(())
    }

    /// A whole batch through the fused multi-key kernel: `ws.len()`
    /// items, item `i`'s key at `keys[i·order .. (i+1)·order]`. Every
    /// key is validated before any lands (all-or-nothing, like the 2-D
    /// batch path). Both arms route through the two-phase vectorized
    /// kernel ([`crate::sketch::kernel`]): with `originate` the hash
    /// phase runs once and the staged runs replay into the live sketch
    /// and the origin accumulator.
    pub fn update_batch(
        &mut self,
        name: &str,
        keys: &[usize],
        ws: &[f64],
        originate: bool,
    ) -> Result<()> {
        let version = &mut self.version;
        let e = match self.tensors.get_mut(name) {
            Some(e) => e,
            None => bail!("unknown tensor {name:?}"),
        };
        let order = e.hcs.order();
        ensure!(
            keys.len() == ws.len() * order,
            "tensor batch key buffer of {} indices does not match {} items of order {order}",
            keys.len(),
            ws.len()
        );
        ensure!(
            ws.len() <= super::super::MAX_UPDATE_BATCH,
            "tensor batch of {} items exceeds cap {}",
            ws.len(),
            super::super::MAX_UPDATE_BATCH
        );
        for key in keys.chunks_exact(order) {
            validate_key(e.hcs.dims(), key)?;
        }
        if ws.is_empty() {
            return Ok(());
        }
        if originate {
            let origin = e.origin.get_or_insert_with(|| {
                let mut empty = e.hcs.clone();
                empty.clear();
                empty
            });
            HcsStream::update_batch_fanout(&mut [&mut e.hcs, origin], keys, ws);
        } else {
            e.hcs.update_batch(keys, ws);
        }
        *version += 1;
        e.version = *version;
        Ok(())
    }

    pub fn query(&self, name: &str, key: &[usize]) -> Result<f64> {
        let e = self.entry(name)?;
        validate_key(e.hcs.dims(), key)?;
        Ok(e.hcs.query(key))
    }

    /// Marginal over any mode subset (`None` = sum the mode out,
    /// `Some(i)` = pin it), computed on the sketch.
    pub fn marginal(&self, name: &str, spec: &[Option<usize>]) -> Result<f64> {
        let e = self.entry(name)?;
        let dims = e.hcs.dims();
        ensure!(
            spec.len() == dims.len(),
            "marginal spec order {} does not match tensor order {}",
            spec.len(),
            dims.len()
        );
        for (k, (s, &n)) in spec.iter().zip(dims.iter()).enumerate() {
            if let Some(i) = s {
                ensure!(*i < n, "marginal spec mode {k} index {i} out of range (dim {n})");
            }
        }
        Ok(e.hcs.marginal(spec))
    }

    pub fn slice_top_k(
        &self,
        name: &str,
        mode: usize,
        index: usize,
        k: usize,
    ) -> Result<Vec<(Vec<usize>, f64)>> {
        let e = self.entry(name)?;
        let dims = e.hcs.dims();
        ensure!(mode < dims.len(), "slice mode {mode} out of range (order {})", dims.len());
        ensure!(
            index < dims[mode],
            "slice index {index} out of range (mode {mode} dim {})",
            dims[mode]
        );
        Ok(e.hcs.slice_top_k(mode, index, k))
    }

    /// Sketched contraction between two stored tensors (FCS-style:
    /// computed directly on the sketch tables, see [`contract`]).
    pub fn contract(
        &self,
        a_name: &str,
        b_name: &str,
        contracted: &[usize],
    ) -> Result<ContractOutput> {
        let a = self.entry(a_name)?;
        let b = self.entry(b_name)?;
        ensure!(
            a.hcs.same_family(&b.hcs),
            "tensors {a_name:?} and {b_name:?} are not the same sketch family"
        );
        let out = contract::contract(&a.hcs, &b.hcs, contracted)?;
        if matches!(out, ContractOutput::Scalar(_)) {
            // live accuracy gauge: observed per-repeat spread vs the
            // paper's 8·‖A‖‖B‖/√Πm deviation scale (see obs catalog)
            let (residual, bound) = contract::contract_accuracy(&a.hcs, &b.hcs);
            crate::obs::global().note_contract(a_name, b_name, residual, bound);
        }
        Ok(out)
    }

    /// Tensors with unshipped locally-originated mass: every entry
    /// whose origin accumulator exists and whose version stamp is ahead
    /// of the caller's per-tensor acknowledgement map. Returns
    /// `(name, version, cumulative origin sketch)` triples — the
    /// replicator ships each as one full-state frame with `version` as
    /// the channel sequence.
    pub fn dirty_origins(
        &self,
        acked: &HashMap<String, u64>,
    ) -> Vec<(String, u64, HcsStream)> {
        self.tensors
            .iter()
            .filter_map(|(name, e)| {
                let origin = e.origin.as_ref()?;
                if acked.get(name).copied().unwrap_or(0) >= e.version {
                    return None;
                }
                Some((name.clone(), e.version, origin.clone()))
            })
            .collect()
    }

    /// Apply one tensor replication frame: full cumulative state from
    /// `origin` for tensor `name` at channel sequence `seq`. An unknown
    /// tensor is auto-created from the frame's family (the catalog is
    /// replicated implicitly — peers learn tensors from their mass).
    /// Returns `Ok(true)` if mass was applied, `Ok(false)` on a dedup.
    /// The merge lands in the live sketch only — never the origin
    /// accumulator, never the WAL (see the module docs).
    pub fn apply_origin_merge(
        &mut self,
        origin: u64,
        name: &str,
        seq: u64,
        full: HcsStream,
    ) -> Result<bool> {
        let fam = TensorFamily::of(&full);
        fam.validate()?;
        match self.tensors.get(name) {
            Some(e) => ensure!(
                fam.matches(&e.hcs),
                "tensor replication frame for {name:?} does not match the stored family"
            ),
            None => {
                self.create(name, &fam)?;
            }
        }
        match self.channels.admit(origin, name, seq, full) {
            TensorAdmit::Dedup => Ok(false),
            TensorAdmit::Apply(delta) => {
                let e = self.tensors.get_mut(name).expect("tensor created above");
                e.hcs.merge_scaled(&delta, 1.0);
                self.channels.commit(origin, name, seq, &delta);
                Ok(true)
            }
        }
    }

    /// Serialize the whole catalog + channel table (appended at the end
    /// of the `ShardedStore` snapshot image). Deterministic: tensors in
    /// `BTreeMap` order, channels in sorted key order.
    pub(crate) fn encode_into(&self, out: &mut Vec<u8>) {
        codec::put_u32(out, self.tensors.len() as u32);
        for (name, e) in &self.tensors {
            codec::put_name(out, name);
            codec::put_u64(out, e.version);
            codec::put_u8(out, u8::from(e.origin.is_some()));
            e.hcs.encode(out);
            if let Some(origin) = &e.origin {
                origin.encode(out);
            }
        }
        codec::put_u64(out, self.version);
        self.channels.encode_into(out);
    }

    /// Bit-exact inverse of [`TensorRegistry::encode_into`].
    pub(crate) fn decode_from(rd: &mut Reader<'_>) -> Result<Self> {
        let count = rd.u32()? as usize;
        ensure!(count <= MAX_TENSORS, "snapshot tensor catalog of {count} entries exceeds cap");
        let mut tensors = BTreeMap::new();
        for _ in 0..count {
            let name = codec::read_name(rd)?;
            let entry_version = rd.u64()?;
            let has_origin = rd.u8()?;
            ensure!(has_origin <= 1, "corrupt snapshot: tensor origin flag {has_origin}");
            let hcs = HcsStream::decode(rd)?;
            TensorFamily::of(&hcs).validate()?;
            let origin = if has_origin == 1 {
                let o = HcsStream::decode(rd)?;
                ensure!(
                    hcs.same_family(&o),
                    "corrupt snapshot: tensor {name:?} origin family mismatch"
                );
                Some(o)
            } else {
                None
            };
            ensure!(
                !tensors.contains_key(&name),
                "corrupt snapshot: duplicate tensor {name:?}"
            );
            tensors.insert(name, TensorEntry { hcs, origin, version: entry_version });
        }
        let version = rd.u64()?;
        let channels = TensorOriginTable::decode_from(rd, |name| {
            tensors.get(name).map(|e: &TensorEntry| TensorFamily::of(&e.hcs))
        })?;
        Ok(Self { tensors, version, channels })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fam() -> TensorFamily {
        TensorFamily { dims: vec![20, 16, 12], sketch_dims: vec![6, 5, 4], d: 3, seed: 42 }
    }

    #[test]
    fn create_is_idempotent_and_rejects_family_changes() {
        let mut reg = TensorRegistry::new();
        assert!(reg.create("t", &fam()).unwrap());
        assert!(!reg.create("t", &fam()).unwrap(), "identical re-create must be a no-op");
        let mut other = fam();
        other.seed = 7;
        assert!(reg.create("t", &other).is_err(), "family change under a live name");
        assert!(reg.create("", &fam()).is_err(), "empty name");
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn family_validation_rejects_bad_geometries() {
        let mut f = fam();
        f.sketch_dims = vec![6, 5]; // order mismatch
        assert!(f.validate().is_err());
        let mut f = fam();
        f.sketch_dims[0] = 0;
        assert!(f.validate().is_err());
        let mut f = fam();
        f.sketch_dims[0] = f.dims[0] + 1; // sketch larger than the mode
        assert!(f.validate().is_err());
        let mut f = fam();
        f.d = 0;
        assert!(f.validate().is_err());
        let f = TensorFamily {
            dims: vec![1 << 20; 4],
            sketch_dims: vec![1 << 10; 4],
            d: 8,
            seed: 1,
        };
        assert!(f.validate().is_err(), "space cap must hold");
        // encode/decode roundtrip of a good family
        let good = fam();
        let mut bytes = Vec::new();
        good.encode(&mut bytes);
        assert_eq!(TensorFamily::decode(&mut Reader::new(&bytes)).unwrap(), good);
    }

    #[test]
    fn updates_land_in_live_and_origin_planes() {
        let mut reg = TensorRegistry::new();
        reg.create("t", &fam()).unwrap();
        assert_eq!(reg.version(), 0);
        reg.update("t", &[1, 2, 3], 5.0, true).unwrap();
        reg.update("t", &[4, 5, 6], 3.0, false).unwrap(); // non-originating
        let keys = [7usize, 8, 9, 1, 2, 3];
        reg.update_batch("t", &keys, &[2.0, 1.0], true).unwrap();
        assert_eq!(reg.query("t", &[1, 2, 3]).unwrap(), 6.0);
        assert_eq!(reg.updates(), 4);
        // the origin accumulator holds exactly the originating mass
        let dirty = reg.dirty_origins(&HashMap::new());
        assert_eq!(dirty.len(), 1);
        let (name, version, origin) = &dirty[0];
        assert_eq!(name, "t");
        assert_eq!(*version, reg.version());
        assert_eq!(origin.updates, 3);
        assert_eq!(origin.query(&[1, 2, 3]), 6.0);
        assert_eq!(origin.query(&[4, 5, 6]), 0.0, "non-originating mass shipped");
        // acked at the current version: nothing left to ship
        let mut acked = HashMap::new();
        acked.insert("t".to_string(), *version);
        assert!(reg.dirty_origins(&acked).is_empty());
        // bad keys error, never panic
        assert!(reg.update("t", &[1, 2], 1.0, true).is_err());
        assert!(reg.update("t", &[1, 2, 99], 1.0, true).is_err());
        assert!(reg.update("missing", &[1, 2, 3], 1.0, true).is_err());
        assert!(reg.update_batch("t", &keys[..5], &[1.0, 1.0], true).is_err());
    }

    #[test]
    fn replication_frames_are_idempotent_and_auto_create() {
        let mut sender = TensorRegistry::new();
        sender.create("t", &fam()).unwrap();
        sender.update("t", &[1, 2, 3], 5.0, true).unwrap();
        sender.update("t", &[4, 0, 1], 2.0, true).unwrap();

        let mut receiver = TensorRegistry::new();
        let ship = |reg: &TensorRegistry| {
            let mut d = reg.dirty_origins(&HashMap::new());
            assert_eq!(d.len(), 1);
            d.pop().unwrap()
        };
        let (name, seq1, full1) = ship(&sender);
        // unknown tensor: auto-created from the frame's family
        assert!(receiver.apply_origin_merge(9, &name, seq1, full1.clone()).unwrap());
        assert_eq!(receiver.query("t", &[1, 2, 3]).unwrap(), 5.0);
        // exact retry: dedup, bit-identical state
        assert!(!receiver.apply_origin_merge(9, &name, seq1, full1).unwrap());
        assert_eq!(receiver.query("t", &[1, 2, 3]).unwrap(), 5.0);
        assert_eq!(receiver.updates(), 2);
        // grown cumulative state: only the remainder lands
        sender.update("t", &[1, 2, 3], 4.0, true).unwrap();
        let (_, seq2, full2) = ship(&sender);
        assert!(seq2 > seq1);
        assert!(receiver.apply_origin_merge(9, "t", seq2, full2).unwrap());
        assert_eq!(receiver.query("t", &[1, 2, 3]).unwrap(), 9.0);
        assert_eq!(receiver.updates(), 3);
        // replica-plane mass is not re-originated
        assert!(receiver.dirty_origins(&HashMap::new()).is_empty());
        // family-mismatched frame for a live name is rejected
        let mut other = fam();
        other.seed = 1;
        let alien = other.fresh();
        assert!(receiver.apply_origin_merge(9, "t", seq2 + 1, alien).is_err());
    }

    #[test]
    fn registry_roundtrips_bit_exact() {
        let mut reg = TensorRegistry::new();
        reg.create("a", &fam()).unwrap();
        let mut f2 = fam();
        f2.dims = vec![10, 10];
        f2.sketch_dims = vec![4, 4];
        reg.create("b", &f2).unwrap();
        reg.update("a", &[1, 2, 3], 5.0, true).unwrap();
        reg.update("b", &[0, 9], -2.0, false).unwrap();
        // a replication channel with history
        let mut remote = fam().fresh();
        remote.update(&[3, 3, 3], 7.0);
        reg.apply_origin_merge(0xAB, "a", 4, remote).unwrap();

        let mut bytes = Vec::new();
        reg.encode_into(&mut bytes);
        let got = TensorRegistry::decode_from(&mut Reader::new(&bytes)).unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got.version(), reg.version());
        assert_eq!(got.updates(), reg.updates());
        assert_eq!(
            got.query("a", &[1, 2, 3]).unwrap().to_bits(),
            reg.query("a", &[1, 2, 3]).unwrap().to_bits()
        );
        assert_eq!(
            got.query("b", &[0, 9]).unwrap().to_bits(),
            reg.query("b", &[0, 9]).unwrap().to_bits()
        );
        // identical registries encode identically (deterministic order)
        let mut bytes2 = Vec::new();
        got.encode_into(&mut bytes2);
        assert_eq!(bytes, bytes2);
        // the recovered channel still dedups: a stale retry is a no-op
        let mut re = got;
        let mut stale = fam().fresh();
        stale.update(&[3, 3, 3], 7.0);
        assert!(!re.apply_origin_merge(0xAB, "a", 4, stale).unwrap());
        // and the recovered origin accumulator still ships
        let dirty = re.dirty_origins(&HashMap::new());
        assert_eq!(dirty.len(), 1);
        assert_eq!(dirty[0].0, "a");
        // truncated snapshot bytes error cleanly
        assert!(TensorRegistry::decode_from(&mut Reader::new(&bytes[..bytes.len() - 3]))
            .is_err());
    }

    #[test]
    fn channel_table_evicts_stalest_at_cap() {
        let mut reg = TensorRegistry::new();
        reg.create("t", &fam()).unwrap();
        let mut table = TensorOriginTable::new(2);
        let mut sk = fam().fresh();
        sk.update(&[1, 1, 1], 1.0);
        for (origin, seq) in [(1u64, 1u64), (2, 1)] {
            match table.admit(origin, "t", seq, sk.clone()) {
                TensorAdmit::Apply(d) => table.commit(origin, "t", seq, &d),
                TensorAdmit::Dedup => panic!("fresh channel deduped"),
            }
        }
        // touch channel 1 so channel 2 is stalest
        sk.update(&[2, 2, 2], 1.0);
        match table.admit(1, "t", 2, sk.clone()) {
            TensorAdmit::Apply(d) => table.commit(1, "t", 2, &d),
            TensorAdmit::Dedup => panic!("grown frame deduped"),
        }
        // a third channel evicts channel 2
        match table.admit(3, "t", 1, sk.clone()) {
            TensorAdmit::Apply(d) => table.commit(3, "t", 1, &d),
            TensorAdmit::Dedup => panic!("new channel deduped"),
        }
        assert_eq!(table.len(), 2);
        // channel 1's horizon is intact
        assert!(matches!(table.admit(1, "t", 2, sk.clone()), TensorAdmit::Dedup));
        // channel 2 was forgotten: its next full frame re-applies as
        // unknown (full-ship idempotence, not a protocol halt)
        assert!(matches!(table.admit(2, "t", 2, sk.clone()), TensorAdmit::Apply(_)));
    }
}
