//! [`HcsStream`] — a streaming, mergeable Higher-order Count Sketch of
//! arbitrary order, the per-tensor engine behind the store's named
//! tensor registry ([`super::registry`]).
//!
//! This is [`crate::sketch::stream::StreamSketch`] generalized from the
//! fixed 2-D `(i, j)` key space to N modes. Each repeat keeps **one
//! small hash pair per mode** (`h_k : [n_k] → [m_k]`, `s_k : [n_k] →
//! {±1}`, the paper's tensor-product family via [`ModeHash`], seeded
//! exactly like [`crate::sketch::mts::MtsSketcher`] with
//! `HashSeeds::seed_for(repeat, mode)`), so the hash state is
//! `Σ_k n_k` entries instead of the `Π_k n_k` a flat count sketch over
//! the linearized key space would need — the paper's exponential-saving
//! claim, measured by `benches/bench_tensor.rs`.
//!
//! An update at key `(i_1, …, i_N)` lands at bucket `(h_1(i_1), …,
//! h_N(i_N))` of each repeat's `Π_k m_k` table with sign
//! `Π_k s_k(i_k)`; a point query reads the bucket back, re-applies the
//! sign, and takes the median over the `d` repeats. Everything the
//! store's planes rely on carries over unchanged from `StreamSketch`:
//!
//! - the **fused fan-out kernels** ([`HcsStream::update_fanout`] /
//!   [`HcsStream::update_batch_fanout`]) evaluate each repeat's bucket
//!   and signed contribution once and apply it to every same-family
//!   target, so one hash walk can feed a running total *and* an
//!   origin accumulator (the replication plane's input);
//! - the **raw-accumulate / finalize split**
//!   ([`HcsStream::accumulate_raw`] / [`HcsStream::finalize_estimates`])
//!   sums raw counters across sketches of disjoint substreams and
//!   applies the signs once, which keeps sharded fan-out point queries
//!   bit-identical to a single sketch fed the union stream (signed
//!   zeros included);
//! - the sticky [`HcsStream::has_deletions`] flag routes the
//!   marginal-pruned [`HcsStream::slice_top_k`] scan to the dense
//!   variant once any negative-weight update has been absorbed —
//!   deletion-cancelled marginals can hide surviving heavy cells;
//! - [`HcsStream::merge_scaled`] is exact by linearity (merge,
//!   subtraction, delta shipping).
//!
//! Marginals ([`HcsStream::marginal`]) sum out **any mode subset**
//! directly on the sketch: summing mode k with the per-bucket signed
//! count `u_k[t] = Σ_{h_k(i)=t} s_k(i)` contracts the table's k-th axis
//! in O(Π m) per repeat — no decompression, the paper's
//! "tensor operations on sketched data" served online.

use crate::hash::{HashSeeds, ModeHash};
use crate::sketch::kernel;
use crate::store::codec::{self, Reader};
use crate::store::mergeable::{MergeableSketch, MAX_DECODE_ELEMS};
use crate::util::stats::median_inplace;
use anyhow::{ensure, Result};
use std::cell::RefCell;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

thread_local! {
    /// Per-thread median scratch for [`HcsStream::query`]: the serve
    /// path calls it once per key and `d` is tiny and constant, so one
    /// warm buffer removes a heap allocation per query.
    static QUERY_SCRATCH: RefCell<Vec<f64>> = RefCell::new(Vec::new());
}

/// Early-exit slack for the pruned [`HcsStream::slice_top_k`] scan:
/// stop once the current line's marginal estimate, inflated by this
/// factor, cannot reach the k-th best point estimate found so far
/// (same constant discipline as `StreamSketch`).
const TOP_K_SLACK: f64 = 2.0;

/// Hard cap on tensor order. Keys travel with a one-byte order on the
/// wire, and every per-mode loop is O(order); 16 matches the dense
/// [`crate::tensor::Tensor`] decode cap.
pub const MAX_ORDER: usize = 16;

/// d independent `Π m_k`-bucket HCS tables over keys `[n_1]×…×[n_N]`.
#[derive(Clone, Debug)]
pub struct HcsStream {
    /// per-mode key universe `n_k`
    dims: Vec<usize>,
    /// per-mode table extent `m_k`
    sketch_dims: Vec<usize>,
    pub d: usize,
    /// root seed the d·N mode hashes were derived from (part of the
    /// sketch identity: only same-seed sketches are mergeable)
    pub seed: u64,
    /// `modes[r][k]` — repeat r's hash pair for mode k
    modes: Vec<Vec<ModeHash>>,
    /// row-major strides of `sketch_dims` (shared by every repeat)
    strides: Vec<usize>,
    tables: Vec<Vec<f64>>,
    /// total updates processed
    pub updates: u64,
    /// true once any negative-weight update has been absorbed (directly
    /// or via merge). Sticky; see `StreamSketch::has_deletions` — the
    /// marginal-pruned slice scan is only sound for non-negative
    /// streams.
    pub has_deletions: bool,
}

/// Min-heap entry for [`HcsStream::slice_top_k`] (ordered by estimate;
/// key as a deterministic tie-break so `Ord` is total).
struct TopEntry {
    est: f64,
    key: Vec<usize>,
}

impl PartialEq for TopEntry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for TopEntry {}

impl PartialOrd for TopEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for TopEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.est.total_cmp(&other.est).then_with(|| self.key.cmp(&other.key))
    }
}

impl HcsStream {
    /// One sketch dim per mode; `d ≥ 1` repeats; order in `1..=MAX_ORDER`.
    pub fn new(dims: &[usize], sketch_dims: &[usize], d: usize, seed: u64) -> Self {
        assert!(d >= 1, "need at least one repeat");
        assert_eq!(dims.len(), sketch_dims.len(), "one sketch dim per mode");
        assert!(!dims.is_empty() && dims.len() <= MAX_ORDER, "order must be in 1..={MAX_ORDER}");
        assert!(dims.iter().all(|&n| n > 0) && sketch_dims.iter().all(|&m| m > 0));
        let seeds = HashSeeds::new(seed);
        let modes: Vec<Vec<ModeHash>> = (0..d)
            .map(|r| {
                dims.iter()
                    .zip(sketch_dims.iter())
                    .enumerate()
                    .map(|(k, (&n, &m))| ModeHash::new(n, m, seeds.seed_for(r, k)))
                    .collect()
            })
            .collect();
        let strides = row_major_strides(sketch_dims);
        let table_len: usize = sketch_dims.iter().product();
        Self {
            dims: dims.to_vec(),
            sketch_dims: sketch_dims.to_vec(),
            d,
            seed,
            modes,
            strides,
            tables: vec![vec![0.0; table_len]; d],
            updates: 0,
            has_deletions: false,
        }
    }

    pub fn order(&self) -> usize {
        self.dims.len()
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn sketch_dims(&self) -> &[usize] {
        &self.sketch_dims
    }

    /// Space used, in f64 counters (`d · Π m_k`).
    pub fn space(&self) -> usize {
        self.d * self.tables[0].len()
    }

    /// Repeat r's table offset for `key` — `Σ_k h_k(i_k)·stride_k`.
    #[inline]
    fn bucket(&self, r: usize, key: &[usize]) -> usize {
        debug_assert_eq!(key.len(), self.order());
        let hashes = &self.modes[r];
        let mut b = 0;
        for (k, &i) in key.iter().enumerate() {
            debug_assert!(i < self.dims[k], "mode {k} index {i} out of {}", self.dims[k]);
            b += hashes[k].h(i) * self.strides[k];
        }
        b
    }

    /// Repeat r's sign for `key` — `Π_k s_k(i_k)`.
    #[inline]
    fn sign(&self, r: usize, key: &[usize]) -> f64 {
        let hashes = &self.modes[r];
        let mut s = 1.0;
        for (k, &i) in key.iter().enumerate() {
            s *= hashes[k].s(i);
        }
        s
    }

    /// Process one stream item: multi-mode key with weight `w`.
    pub fn update(&mut self, key: &[usize], w: f64) {
        for r in 0..self.d {
            let b = self.bucket(r, key);
            let v = self.sign(r, key) * w;
            self.tables[r][b] += v;
        }
        self.updates += 1;
        if w < 0.0 {
            self.has_deletions = true;
        }
    }

    /// Apply one update to several **same-family** sketches at once,
    /// evaluating each repeat's bucket and signed contribution a single
    /// time. The registry's write path fans one update into the running
    /// tensor *and* its origin accumulator — one hash walk instead of
    /// two. Bit-identical to calling [`HcsStream::update`] per target.
    pub fn update_fanout(targets: &mut [&mut HcsStream], key: &[usize], w: f64) {
        let Some((first, rest)) = targets.split_first_mut() else {
            return;
        };
        debug_assert!(rest.iter().all(|t| first.same_family(t)));
        for r in 0..first.d {
            let b = first.bucket(r, key);
            let v = first.sign(r, key) * w;
            first.tables[r][b] += v;
            for t in rest.iter_mut() {
                t.tables[r][b] += v;
            }
        }
        first.updates += 1;
        for t in rest.iter_mut() {
            t.updates += 1;
        }
        if w < 0.0 {
            first.has_deletions = true;
            for t in rest.iter_mut() {
                t.has_deletions = true;
            }
        }
    }

    /// Fused multi-key update over a flat key buffer (`keys.len() ==
    /// ws.len() · order`, item i's key at `keys[i·order ..]` — the wire
    /// and WAL layout, applied without re-packing), routed through the
    /// two-phase kernel ([`crate::sketch::kernel`]). The hash phase
    /// memoizes per-(repeat, mode) `(h·stride, s)` tables whenever the
    /// batch is at least as long as a mode's key range, so the per-mode
    /// `Σ h_k·stride_k` walk amortizes across repeats; the apply phase
    /// adds the runs in batch order. **Bit-identical** to calling
    /// [`HcsStream::update`] per item and to
    /// [`HcsStream::update_batch_scalar`] on every dispatch path.
    pub fn update_batch(&mut self, keys: &[usize], ws: &[f64]) {
        let order = self.order();
        debug_assert_eq!(keys.len(), ws.len() * order);
        let path = kernel::configured();
        if path == kernel::KernelPath::Scalar || self.tables[0].len() > u32::MAX as usize {
            crate::obs::global().kernel_scalar.inc();
            self.update_batch_scalar(keys, ws);
            return;
        }
        // the N-D hash walk is the portable lane kernel (no AVX2 tile)
        crate::obs::global().kernel_portable.inc();
        kernel::with_scratch(|s| {
            for r in 0..self.d {
                let hash = kernel::HashNd::new(&self.modes[r], &self.strides, ws.len());
                let table = &mut self.tables[r];
                let key_tiles = keys.chunks(kernel::TILE * order);
                for (kt, wt) in key_tiles.zip(ws.chunks(kernel::TILE)) {
                    kernel::hash_tile_nd(&hash, order, kt, wt, &mut s.b, &mut s.v);
                    s.stage(table.len());
                    let (bs, vs) = s.runs();
                    kernel::apply_runs(table, bs, vs);
                }
            }
        });
        self.updates += ws.len() as u64;
        if ws.iter().any(|&w| w < 0.0) {
            self.has_deletions = true;
        }
    }

    /// The pre-kernel fused walk: each repeat's hash pairs and counter
    /// table walked once for the whole batch, hardware `%` and branchy
    /// signs per (item, mode). Kept public as the bit-identity oracle
    /// for the kernel paths and as the bench baseline
    /// (`HOCS_KERNEL=scalar` routes [`HcsStream::update_batch`] here).
    pub fn update_batch_scalar(&mut self, keys: &[usize], ws: &[f64]) {
        let order = self.order();
        debug_assert_eq!(keys.len(), ws.len() * order);
        for r in 0..self.d {
            for (key, &w) in keys.chunks_exact(order).zip(ws.iter()) {
                let b = self.bucket(r, key);
                self.tables[r][b] += self.sign(r, key) * w;
            }
        }
        self.updates += ws.len() as u64;
        if ws.iter().any(|&w| w < 0.0) {
            self.has_deletions = true;
        }
    }

    /// Batched [`HcsStream::update_fanout`]: one kernel hash phase per
    /// repeat and tile, with the staged runs replayed into every
    /// target's table. Bit-identical to calling
    /// [`HcsStream::update_batch`] on each target (and to
    /// [`HcsStream::update_batch_fanout_scalar`]).
    pub fn update_batch_fanout(targets: &mut [&mut HcsStream], keys: &[usize], ws: &[f64]) {
        let Some(first) = targets.first() else {
            return;
        };
        let path = kernel::configured();
        if path == kernel::KernelPath::Scalar || first.tables[0].len() > u32::MAX as usize {
            crate::obs::global().kernel_scalar.inc();
            Self::update_batch_fanout_scalar(targets, keys, ws);
            return;
        }
        crate::obs::global().kernel_portable.inc();
        debug_assert!(targets.windows(2).all(|p| p[0].same_family(&p[1])));
        let order = targets[0].order();
        debug_assert_eq!(keys.len(), ws.len() * order);
        let d = targets[0].d;
        kernel::with_scratch(|s| {
            for r in 0..d {
                let t0 = &targets[0];
                let hash = kernel::HashNd::new(&t0.modes[r], &t0.strides, ws.len());
                let table_len = t0.tables[r].len();
                let key_tiles = keys.chunks(kernel::TILE * order);
                for (kt, wt) in key_tiles.zip(ws.chunks(kernel::TILE)) {
                    kernel::hash_tile_nd(&hash, order, kt, wt, &mut s.b, &mut s.v);
                    s.stage(table_len);
                    for t in targets.iter_mut() {
                        let (bs, vs) = s.runs();
                        kernel::apply_runs(&mut t.tables[r], bs, vs);
                    }
                }
            }
        });
        let n = ws.len() as u64;
        let deletions = ws.iter().any(|&w| w < 0.0);
        for t in targets.iter_mut() {
            t.updates += n;
            if deletions {
                t.has_deletions = true;
            }
        }
    }

    /// The pre-kernel scalar fan-out walk — bit-identity oracle and
    /// bench baseline for [`HcsStream::update_batch_fanout`].
    pub fn update_batch_fanout_scalar(targets: &mut [&mut HcsStream], keys: &[usize], ws: &[f64]) {
        let Some((first, rest)) = targets.split_first_mut() else {
            return;
        };
        debug_assert!(rest.iter().all(|t| first.same_family(t)));
        let order = first.order();
        debug_assert_eq!(keys.len(), ws.len() * order);
        for r in 0..first.d {
            for (key, &w) in keys.chunks_exact(order).zip(ws.iter()) {
                let b = first.bucket(r, key);
                let v = first.sign(r, key) * w;
                first.tables[r][b] += v;
                for t in rest.iter_mut() {
                    t.tables[r][b] += v;
                }
            }
        }
        let n = ws.len() as u64;
        let deletions = ws.iter().any(|&w| w < 0.0);
        first.updates += n;
        if deletions {
            first.has_deletions = true;
        }
        for t in rest.iter_mut() {
            t.updates += n;
            if deletions {
                t.has_deletions = true;
            }
        }
    }

    /// Point query: median-of-d estimate of the total weight at `key`.
    /// Routed through a thread-local scratch buffer so the per-key
    /// serve path is allocation-free after the first call.
    pub fn query(&self, key: &[usize]) -> f64 {
        QUERY_SCRATCH.with(|cell| {
            let mut est = cell.borrow_mut();
            est.clear();
            est.resize(self.d, 0.0);
            self.query_scratch(key, &mut est)
        })
    }

    /// [`HcsStream::query`] into caller-owned scratch (scan paths call
    /// this per cell; one allocation per scan instead of per key).
    fn query_scratch(&self, key: &[usize], est: &mut [f64]) -> f64 {
        debug_assert_eq!(est.len(), self.d);
        for (r, e) in est.iter_mut().enumerate() {
            *e = self.sign(r, key) * self.tables[r][self.bucket(r, key)];
        }
        median_inplace(est)
    }

    /// Add this sketch's raw bucket counters for `key` into `acc[r]` —
    /// no signs yet. Summing raw counters across same-family sketches
    /// of disjoint substreams and applying the signs once
    /// ([`HcsStream::finalize_estimates`]) is bit-identical to querying
    /// the merged sketch, signed zeros included.
    pub fn accumulate_raw(&self, key: &[usize], acc: &mut [f64]) {
        assert_eq!(acc.len(), self.d, "accumulator length {} != d {}", acc.len(), self.d);
        for (r, a) in acc.iter_mut().enumerate() {
            *a += self.tables[r][self.bucket(r, key)];
        }
    }

    /// Turn counters summed by [`HcsStream::accumulate_raw`] into the
    /// median-of-d point estimate for `key`.
    pub fn finalize_estimates(&self, key: &[usize], acc: &mut [f64]) -> f64 {
        assert_eq!(acc.len(), self.d, "accumulator length {} != d {}", acc.len(), self.d);
        for (r, a) in acc.iter_mut().enumerate() {
            *a *= self.sign(r, key);
        }
        median_inplace(acc)
    }

    // ---------- marginals ----------

    /// Estimate of the tensor marginal with the given per-mode spec:
    /// `Some(i_k)` fixes mode k at index `i_k`, `None` sums it out.
    /// All-`Some` degenerates to the point query; all-`None` estimates
    /// the total stream mass.
    ///
    /// Computed directly on the sketch: summing mode k replaces its
    /// table axis with the signed bucket totals `u_k[t] =
    /// Σ_{h_k(i)=t} s_k(i)` — an exact contraction of the estimator,
    /// O(Π m + Σ n_summed) per repeat, never a dense decompression.
    /// Unbiased (every per-key estimate is, and expectation is linear).
    pub fn marginal(&self, spec: &[Option<usize>]) -> f64 {
        assert_eq!(spec.len(), self.order(), "one spec entry per mode");
        for (k, s) in spec.iter().enumerate() {
            if let Some(i) = s {
                assert!(*i < self.dims[k], "mode {k} index {i} out of {}", self.dims[k]);
            }
        }
        let mut est: Vec<f64> = (0..self.d)
            .map(|r| {
                // fixed modes contribute a base offset and a sign; each
                // summed mode contributes a weight vector over its axis
                let hashes = &self.modes[r];
                let mut base = 0usize;
                let mut sign = 1.0;
                let mut summed: Vec<(usize, Vec<f64>)> = Vec::new(); // (mode, u_k)
                for (k, s) in spec.iter().enumerate() {
                    match s {
                        Some(i) => {
                            base += hashes[k].h(*i) * self.strides[k];
                            sign *= hashes[k].s(*i);
                        }
                        None => {
                            let mut u = vec![0.0; self.sketch_dims[k]];
                            for i in 0..self.dims[k] {
                                u[hashes[k].h(i)] += hashes[k].s(i);
                            }
                            summed.push((k, u));
                        }
                    }
                }
                // odometer over the summed modes' buckets: accumulate
                // (Π_k u_k[t_k]) · table[base + Σ t_k·stride_k]
                let t = &self.tables[r];
                let mut acc = 0.0;
                let mut idx = vec![0usize; summed.len()];
                loop {
                    let mut off = base;
                    let mut uw = 1.0;
                    for (slot, &(k, ref u)) in summed.iter().enumerate() {
                        off += idx[slot] * self.strides[k];
                        uw *= u[idx[slot]];
                    }
                    acc += uw * t[off];
                    // advance the odometer (empty summed set: one pass)
                    let mut carry = true;
                    for (slot, &(k, _)) in summed.iter().enumerate().rev() {
                        idx[slot] += 1;
                        if idx[slot] < self.sketch_dims[k] {
                            carry = false;
                            break;
                        }
                        idx[slot] = 0;
                    }
                    if carry {
                        break;
                    }
                }
                sign * acc
            })
            .collect();
        median_inplace(&mut est)
    }

    // ---------- slice top-k ----------

    /// The k keys with the largest estimated weight inside the slice
    /// `mode = index`, sorted descending (full keys returned, fixed
    /// mode included).
    ///
    /// Non-negative streams go through a marginal-pruned scan: the
    /// slice's remaining key grid is walked line by line along its
    /// first free mode, lines visited in decreasing marginal-estimate
    /// order with a size-k min-heap, stopping once a line's marginal
    /// (×[`TOP_K_SLACK`] for estimator noise) cannot beat the k-th best
    /// — for non-negative streams no cell exceeds its line marginal.
    /// Once any deletion has been absorbed
    /// ([`HcsStream::has_deletions`]) that bound is unsound (a
    /// cancelled marginal can hide a surviving heavy cell) and the scan
    /// falls back to [`HcsStream::slice_top_k_dense`].
    pub fn slice_top_k(&self, mode: usize, index: usize, k: usize) -> Vec<(Vec<usize>, f64)> {
        assert!(mode < self.order(), "mode {mode} out of order {}", self.order());
        assert!(index < self.dims[mode], "index {index} out of {}", self.dims[mode]);
        if k == 0 {
            return Vec::new();
        }
        if self.has_deletions {
            return self.slice_top_k_dense(mode, index, k);
        }
        // order-1: the slice is a single cell
        let Some(line_mode) = (0..self.order()).find(|&a| a != mode) else {
            return vec![(vec![index], self.query(&[index]))];
        };
        // per-line marginal bound: fix (mode=index, line_mode=i), sum
        // out everything else
        let mut spec: Vec<Option<usize>> = vec![None; self.order()];
        spec[mode] = Some(index);
        let bounds: Vec<f64> = (0..self.dims[line_mode])
            .map(|i| {
                spec[line_mode] = Some(i);
                self.marginal(&spec)
            })
            .collect();
        let mut order: Vec<usize> = (0..self.dims[line_mode]).collect();
        order.sort_by(|&a, &b| bounds[b].total_cmp(&bounds[a]));
        self.slice_top_k_scan(mode, index, k, line_mode, &order, Some(&bounds))
    }

    /// Unpruned slice top-k: the slice's full key grid through a size-k
    /// min-heap. Correct for arbitrary turnstile streams; same ranking
    /// semantics as [`HcsStream::slice_top_k`] (estimate-descending,
    /// deterministic key tie-break) — both go through the one scan loop.
    pub fn slice_top_k_dense(&self, mode: usize, index: usize, k: usize) -> Vec<(Vec<usize>, f64)> {
        assert!(mode < self.order() && index < self.dims[mode]);
        if k == 0 {
            return Vec::new();
        }
        let Some(line_mode) = (0..self.order()).find(|&a| a != mode) else {
            return vec![(vec![index], self.query(&[index]))];
        };
        let order: Vec<usize> = (0..self.dims[line_mode]).collect();
        self.slice_top_k_scan(mode, index, k, line_mode, &order, None)
    }

    /// The shared min-heap scan: visit the slice line by line along
    /// `line_mode` in the given order, rank every cell; with `bound`
    /// (per-line upper bounds, lines sorted bound-descending) stop at
    /// the first line whose slack-inflated bound cannot beat the k-th
    /// best.
    fn slice_top_k_scan(
        &self,
        mode: usize,
        index: usize,
        k: usize,
        line_mode: usize,
        lines: &[usize],
        bound: Option<&[f64]>,
    ) -> Vec<(Vec<usize>, f64)> {
        let free: Vec<usize> =
            (0..self.order()).filter(|&a| a != mode && a != line_mode).collect();
        let mut heap: BinaryHeap<std::cmp::Reverse<TopEntry>> = BinaryHeap::with_capacity(k + 1);
        let mut est = vec![0.0; self.d];
        let mut key = vec![0usize; self.order()];
        key[mode] = index;
        for &line in lines {
            if let Some(bm) = bound {
                if heap.len() == k {
                    let kth = heap.peek().expect("heap non-empty").0.est;
                    if bm[line] * TOP_K_SLACK < kth {
                        break;
                    }
                }
            }
            key[line_mode] = line;
            // odometer over the remaining free modes
            for f in &free {
                key[*f] = 0;
            }
            loop {
                let e = self.query_scratch(&key, &mut est);
                if heap.len() < k {
                    heap.push(std::cmp::Reverse(TopEntry { est: e, key: key.clone() }));
                } else if e > heap.peek().expect("heap non-empty").0.est {
                    heap.pop();
                    heap.push(std::cmp::Reverse(TopEntry { est: e, key: key.clone() }));
                }
                let mut carry = true;
                for &f in free.iter().rev() {
                    key[f] += 1;
                    if key[f] < self.dims[f] {
                        carry = false;
                        break;
                    }
                    key[f] = 0;
                }
                if carry {
                    break;
                }
            }
        }
        let mut out: Vec<(Vec<usize>, f64)> =
            heap.into_iter().map(|std::cmp::Reverse(e)| (e.key, e.est)).collect();
        out.sort_by(|a, b| b.1.total_cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        out
    }

    // ---------- linearity (merge / scale / clear) ----------

    /// True when `other` was built over the same key universe, sketch
    /// geometry, and hash-family seed — the precondition for
    /// elementwise merging (and for sketched contraction,
    /// [`super::contract`]).
    pub fn same_family(&self, other: &Self) -> bool {
        self.dims == other.dims
            && self.sketch_dims == other.sketch_dims
            && self.d == other.d
            && self.seed == other.seed
    }

    /// `self += a · other`, elementwise over all d tables. Exact by
    /// linearity; `a = -1` deletes a previously-added substream (delta
    /// cursors), which is why a negative `a` does not set
    /// [`HcsStream::has_deletions`] by itself — `other`'s own flag
    /// always propagates.
    pub fn merge_scaled(&mut self, other: &Self, a: f64) {
        assert!(self.same_family(other), "merge of incompatible HCS streams");
        for (t, o) in self.tables.iter_mut().zip(other.tables.iter()) {
            for (x, y) in t.iter_mut().zip(o.iter()) {
                *x += a * y;
            }
        }
        if a >= 0.0 {
            self.updates += other.updates;
        } else {
            self.updates = self.updates.saturating_sub(other.updates);
        }
        self.has_deletions |= other.has_deletions;
    }

    /// `self *= a` (decay weighting). `updates` counts stream items,
    /// not mass — untouched.
    pub fn scale_tables(&mut self, a: f64) {
        for t in &mut self.tables {
            for x in t.iter_mut() {
                *x *= a;
            }
        }
    }

    /// Zero all counters.
    pub fn clear(&mut self) {
        for t in &mut self.tables {
            t.fill(0.0);
        }
        self.updates = 0;
        self.has_deletions = false;
    }

    /// Raw counter table of repeat `r` (serialization / contraction).
    pub fn table(&self, r: usize) -> &[f64] {
        &self.tables[r]
    }

    /// Mutable raw counter table of repeat `r` (deserialization only).
    pub fn table_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.tables[r]
    }

    /// Repeat r's hash pair for mode `k` (contraction layer).
    pub(crate) fn mode_hash(&self, r: usize, k: usize) -> &ModeHash {
        &self.modes[r][k]
    }
}

/// Row-major strides of `dims` (last mode fastest).
pub(crate) fn row_major_strides(dims: &[usize]) -> Vec<usize> {
    let mut strides = vec![1usize; dims.len()];
    for k in (0..dims.len().saturating_sub(1)).rev() {
        strides[k] = strides[k + 1] * dims[k + 1];
    }
    strides
}

impl MergeableSketch for HcsStream {
    fn mergeable_with(&self, other: &Self) -> bool {
        self.same_family(other)
    }

    fn merge_from(&mut self, other: &Self) -> Result<()> {
        ensure!(
            self.mergeable_with(other),
            "cannot merge HCS streams from different geometries/hash families"
        );
        self.merge_scaled(other, 1.0);
        Ok(())
    }

    fn scale_by(&mut self, a: f64) {
        self.scale_tables(a);
    }

    /// Counters and identity only; the hash families are rebuilt from
    /// the seed on decode (pure functions of it). A one-byte flags
    /// field carries [`HcsStream::has_deletions`], mirroring the
    /// `StreamSketch` codec.
    fn encode(&self, out: &mut Vec<u8>) {
        codec::put_u8(out, u8::try_from(self.order()).expect("order fits u8"));
        for &n in &self.dims {
            codec::put_u32(out, u32::try_from(n).expect("dim too large to encode"));
        }
        for &m in &self.sketch_dims {
            codec::put_u32(out, u32::try_from(m).expect("sketch dim too large to encode"));
        }
        codec::put_u32(out, u32::try_from(self.d).expect("d fits u32"));
        codec::put_u64(out, self.seed);
        codec::put_u64(out, self.updates);
        codec::put_u8(out, u8::from(self.has_deletions));
        for r in 0..self.d {
            for &v in self.table(r) {
                codec::put_f64(out, v);
            }
        }
    }

    fn decode(rd: &mut Reader<'_>) -> Result<Self> {
        let order = rd.u8()? as usize;
        ensure!((1..=MAX_ORDER).contains(&order), "HCS order {order} outside 1..={MAX_ORDER}");
        let mut dims = Vec::with_capacity(order);
        for _ in 0..order {
            let n = rd.u32()? as usize;
            ensure!(n > 0, "corrupt HCS header: zero mode dim");
            dims.push(n);
        }
        let mut sketch_dims = Vec::with_capacity(order);
        for _ in 0..order {
            let m = rd.u32()? as usize;
            ensure!(m > 0, "corrupt HCS header: zero sketch dim");
            sketch_dims.push(m);
        }
        let d = rd.u32()? as usize;
        ensure!(d >= 1, "corrupt HCS header: d = 0");
        let mut elems = d;
        for &m in &sketch_dims {
            elems = elems.saturating_mul(m);
        }
        ensure!(elems <= MAX_DECODE_ELEMS, "HCS sketch of {elems} counters exceeds decode cap");
        let seed = rd.u64()?;
        let updates = rd.u64()?;
        let flags = rd.u8()?;
        ensure!(flags <= 1, "corrupt HCS flags byte {flags}");
        let mut sk = HcsStream::new(&dims, &sketch_dims, d, seed);
        for r in 0..d {
            for x in sk.table_mut(r).iter_mut() {
                *x = rd.f64()?;
            }
        }
        sk.updates = updates;
        sk.has_deletions = flags == 1;
        Ok(sk)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn dense_oracle(dims: &[usize]) -> (Vec<f64>, Vec<usize>) {
        (vec![0.0; dims.iter().product()], row_major_strides(dims))
    }

    fn offset(strides: &[usize], key: &[usize]) -> usize {
        key.iter().zip(strides.iter()).map(|(i, s)| i * s).sum()
    }

    fn random_key(rng: &mut Pcg64, dims: &[usize]) -> Vec<usize> {
        dims.iter().map(|&n| rng.gen_range(n as u64) as usize).collect()
    }

    #[test]
    fn point_queries_track_true_counts() {
        let dims = [24, 18, 12];
        let mut sk = HcsStream::new(&dims, &[10, 8, 6], 5, 42);
        let (mut truth, strides) = dense_oracle(&dims);
        let mut rng = Pcg64::new(1);
        // skewed stream: a few heavy keys plus noise
        let heavy: Vec<Vec<usize>> = (0..4).map(|_| random_key(&mut rng, &dims)).collect();
        for _ in 0..300 {
            for key in &heavy {
                sk.update(key, 10.0);
                truth[offset(&strides, key)] += 10.0;
            }
            let key = random_key(&mut rng, &dims);
            sk.update(&key, 1.0);
            truth[offset(&strides, &key)] += 1.0;
        }
        for key in &heavy {
            let est = sk.query(key);
            let t = truth[offset(&strides, key)];
            assert!((est - t).abs() < 0.25 * t, "estimate {est} vs true {t}");
        }
    }

    #[test]
    fn update_batch_and_fanout_bit_identical_to_single_updates() {
        let dims = [16, 12, 10];
        let mdims = [6, 5, 4];
        let mut rng = Pcg64::new(7);
        let mut keys = Vec::new();
        let mut ws = Vec::new();
        let mut items: Vec<(Vec<usize>, f64)> = Vec::new();
        for _ in 0..200 {
            let key = random_key(&mut rng, &dims);
            let w = (1 + rng.gen_range(9)) as f64 * if rng.uniform() < 0.2 { -1.0 } else { 1.0 };
            keys.extend_from_slice(&key);
            ws.push(w);
            items.push((key, w));
        }
        let mut single = HcsStream::new(&dims, &mdims, 3, 9);
        for (key, w) in &items {
            single.update(key, *w);
        }
        let mut batched = HcsStream::new(&dims, &mdims, 3, 9);
        batched.update_batch(&keys, &ws);
        let mut fan_a = HcsStream::new(&dims, &mdims, 3, 9);
        let mut fan_b = HcsStream::new(&dims, &mdims, 3, 9);
        {
            let mut targets = [&mut fan_a, &mut fan_b];
            HcsStream::update_batch_fanout(&mut targets, &keys, &ws);
        }
        let mut fan_c = HcsStream::new(&dims, &mdims, 3, 9);
        let mut fan_d = HcsStream::new(&dims, &mdims, 3, 9);
        for (key, w) in &items {
            let mut targets = [&mut fan_c, &mut fan_d];
            HcsStream::update_fanout(&mut targets, key, *w);
        }
        for got in [&batched, &fan_a, &fan_b, &fan_c, &fan_d] {
            assert_eq!(got.updates, single.updates);
            assert_eq!(got.has_deletions, single.has_deletions);
            for r in 0..single.d {
                for (a, b) in single.table(r).iter().zip(got.table(r).iter()) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
        }
    }

    fn table_bits(sk: &HcsStream) -> Vec<u64> {
        (0..sk.d).flat_map(|r| sk.table(r).iter().map(|v| v.to_bits())).collect()
    }

    fn random_batch(seed: u64, dims: &[usize], n: usize) -> (Vec<usize>, Vec<f64>) {
        let mut rng = Pcg64::new(seed);
        let mut keys = Vec::new();
        let mut ws = Vec::new();
        for _ in 0..n {
            keys.extend(random_key(&mut rng, dims));
            let mag = (1 + rng.gen_range(9)) as f64 * 0.25;
            ws.push(if rng.uniform() < 0.3 { -mag } else { mag });
        }
        (keys, ws)
    }

    #[test]
    fn kernel_batch_bit_identical_across_remainders_and_memo_modes() {
        // n < 4 keeps every mode on the direct (unmemoized) hash path;
        // n ≥ 16 tabulates all three modes; sizes in between mix them.
        // n = 5000 crosses the kernel tile boundary.
        let dims = [16, 12, 10];
        let mdims = [6, 5, 4];
        for n in [0usize, 1, 3, 7, 8, 9, 11, 16, 200, 5000] {
            let (keys, ws) = random_batch(n as u64 + 1, &dims, n);
            let mut kern = HcsStream::new(&dims, &mdims, 3, 9);
            kern.update_batch(&keys, &ws);
            let mut scal = HcsStream::new(&dims, &mdims, 3, 9);
            scal.update_batch_scalar(&keys, &ws);
            assert_eq!(table_bits(&kern), table_bits(&scal), "n={n}");
            assert_eq!(kern.updates, scal.updates);
            assert_eq!(kern.has_deletions, scal.has_deletions);
        }
    }

    #[test]
    fn kernel_fanout_bit_identical_for_widths_1_to_4() {
        let dims = [16, 12, 10];
        let mdims = [6, 5, 4];
        let (keys, ws) = random_batch(77, &dims, 1000);
        for width in 1usize..=4 {
            let mut fans: Vec<HcsStream> =
                (0..width).map(|_| HcsStream::new(&dims, &mdims, 3, 9)).collect();
            {
                let mut targets: Vec<&mut HcsStream> = fans.iter_mut().collect();
                HcsStream::update_batch_fanout(&mut targets, &keys, &ws);
            }
            let mut oracle = HcsStream::new(&dims, &mdims, 3, 9);
            oracle.update_batch_scalar(&keys, &ws);
            for f in &fans {
                assert_eq!(table_bits(f), table_bits(&oracle), "width={width}");
            }
        }
    }

    #[test]
    fn sharded_raw_accumulation_matches_merged_query_bitwise() {
        // K sketches over disjoint substreams: raw-sum + finalize must
        // equal both the merged sketch's query and a single union-fed
        // sketch, bit for bit (integer weights: f64 sums are exact)
        let dims = [20, 14, 8];
        let mdims = [7, 6, 5];
        for shards in [2usize, 4, 8] {
            let mut rng = Pcg64::new(shards as u64);
            let mut parts: Vec<HcsStream> =
                (0..shards).map(|_| HcsStream::new(&dims, &mdims, 5, 33)).collect();
            let mut union = HcsStream::new(&dims, &mdims, 5, 33);
            for n in 0..400 {
                let key = random_key(&mut rng, &dims);
                let w = (1 + rng.gen_range(20)) as f64;
                parts[n % shards].update(&key, w);
                union.update(&key, w);
            }
            let mut merged = HcsStream::new(&dims, &mdims, 5, 33);
            for p in &parts {
                merged.merge_scaled(p, 1.0);
            }
            for _ in 0..60 {
                let key = random_key(&mut rng, &dims);
                let mut acc = vec![0.0; 5];
                for p in &parts {
                    p.accumulate_raw(&key, &mut acc);
                }
                let est = parts[0].finalize_estimates(&key, &mut acc);
                assert_eq!(est.to_bits(), union.query(&key).to_bits());
                assert_eq!(est.to_bits(), merged.query(&key).to_bits());
            }
        }
    }

    #[test]
    fn marginal_tracks_dense_oracle() {
        let dims = [12, 10, 8];
        let mut sk = HcsStream::new(&dims, &[8, 8, 6], 7, 5);
        let (mut truth, strides) = dense_oracle(&dims);
        let mut rng = Pcg64::new(3);
        for _ in 0..500 {
            let key = random_key(&mut rng, &dims);
            let w = (1 + rng.gen_range(5)) as f64;
            sk.update(&key, w);
            truth[offset(&strides, &key)] += w;
        }
        let total: f64 = truth.iter().sum();
        // sum out one mode at a fixed (i, j)
        for (i, j) in [(3usize, 4usize), (0, 0), (11, 9)] {
            let est = sk.marginal(&[Some(i), Some(j), None]);
            let t: f64 = (0..dims[2]).map(|k| truth[offset(&strides, &[i, j, k])]).sum();
            assert!((est - t).abs() < 0.3 * total.max(1.0) / 10.0, "marginal {est} vs {t}");
        }
        // sum out two modes
        let est = sk.marginal(&[Some(5), None, None]);
        let t: f64 = (0..dims[1])
            .flat_map(|j| (0..dims[2]).map(move |k| (j, k)))
            .map(|(j, k)| truth[offset(&strides, &[5, j, k])])
            .sum();
        assert!((est - t).abs() < 0.3 * total / 4.0, "marginal {est} vs {t}");
        // all-fixed spec degenerates to the point query, bit-identically
        let key = [2usize, 3, 4];
        let spec: Vec<Option<usize>> = key.iter().map(|&i| Some(i)).collect();
        assert_eq!(sk.marginal(&spec).to_bits(), sk.query(&key).to_bits());
        // all-None estimates the total mass
        let est_total = sk.marginal(&[None, None, None]);
        assert!((est_total - total).abs() < 0.35 * total, "total {est_total} vs {total}");
    }

    #[test]
    fn slice_top_k_matches_dense_scan_on_nonnegative_streams() {
        let dims = [10, 12, 6];
        let mut sk = HcsStream::new(&dims, &[8, 9, 5], 5, 17);
        let mut rng = Pcg64::new(11);
        let heavy: Vec<Vec<usize>> = (0..5).map(|_| random_key(&mut rng, &dims)).collect();
        for _ in 0..200 {
            for key in &heavy {
                sk.update(key, 8.0);
            }
            sk.update(&random_key(&mut rng, &dims), 1.0);
        }
        assert!(!sk.has_deletions);
        for mode in 0..3 {
            let idx = heavy[0][mode];
            let pruned = sk.slice_top_k(mode, idx, 4);
            let dense = sk.slice_top_k_dense(mode, idx, 4);
            assert_eq!(pruned, dense, "mode {mode}");
            assert!(pruned.iter().all(|(key, _)| key[mode] == idx));
            // the slice's heavy keys surface first
            assert_eq!(pruned[0].0, heavy[0]);
        }
    }

    #[test]
    fn turnstile_updates_route_slice_top_k_to_the_dense_scan() {
        let dims = [8, 8, 8];
        let mut sk = HcsStream::new(&dims, &[6, 6, 6], 5, 23);
        for i in 0..8 {
            sk.update(&[i, i, i], 50.0);
        }
        // cancel most of one slice's mass so its marginal goes to ~0
        // while a heavy cell survives — the pruned bound would skip it
        sk.update(&[3, 3, 3], -45.0);
        sk.update(&[3, 4, 5], 30.0);
        assert!(sk.has_deletions);
        let got = sk.slice_top_k(0, 3, 2);
        let dense = sk.slice_top_k_dense(0, 3, 2);
        assert_eq!(got, dense, "turnstile slice scan must be the dense scan");
        assert_eq!(got[0].0, vec![3, 4, 5], "surviving heavy cell found: {got:?}");
    }

    #[test]
    fn merge_equals_concatenated_stream_and_rejects_other_families() {
        let dims = [14, 9];
        let mut a = HcsStream::new(&dims, &[6, 5], 3, 1);
        let mut b = HcsStream::new(&dims, &[6, 5], 3, 1);
        let mut whole = HcsStream::new(&dims, &[6, 5], 3, 1);
        let mut rng = Pcg64::new(9);
        for n in 0..200 {
            let key = random_key(&mut rng, &dims);
            let w = (1 + rng.gen_range(6)) as f64;
            if n % 2 == 0 {
                a.update(&key, w);
            } else {
                b.update(&key, w);
            }
            whole.update(&key, w);
        }
        a.merge_scaled(&b, 1.0);
        assert_eq!(a.updates, whole.updates);
        for r in 0..3 {
            for (x, y) in a.table(r).iter().zip(whole.table(r).iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        // subtracting b recovers a's own stream exactly
        a.merge_scaled(&b, -1.0);
        let key = random_key(&mut rng, &dims);
        let _ = a.query(&key); // still queryable
        // different seed / dims / order are not mergeable
        let other_seed = HcsStream::new(&dims, &[6, 5], 3, 2);
        assert!(!a.same_family(&other_seed));
        let other_dims = HcsStream::new(&[14, 10], &[6, 5], 3, 1);
        assert!(!a.same_family(&other_dims));
    }

    #[test]
    fn codec_roundtrips_bit_exact_and_rejects_corruption() {
        let dims = [10, 8, 6];
        let mut sk = HcsStream::new(&dims, &[5, 4, 4], 5, 77);
        let mut rng = Pcg64::new(13);
        for _ in 0..150 {
            let key = random_key(&mut rng, &dims);
            sk.update(&key, if rng.uniform() < 0.3 { -2.0 } else { 3.0 });
        }
        let mut out = Vec::new();
        sk.encode(&mut out);
        let got = HcsStream::decode(&mut Reader::new(&out)).unwrap();
        assert!(sk.same_family(&got));
        assert_eq!(sk.updates, got.updates);
        assert!(sk.has_deletions && got.has_deletions);
        for r in 0..sk.d {
            for (a, b) in sk.table(r).iter().zip(got.table(r).iter()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        // truncated payload
        let mut trunc = out.clone();
        trunc.truncate(trunc.len() - 1);
        assert!(HcsStream::decode(&mut Reader::new(&trunc)).is_err());
        // zero order
        let mut bad_order = out.clone();
        bad_order[0] = 0;
        assert!(HcsStream::decode(&mut Reader::new(&bad_order)).is_err());
        // garbage flags byte (one byte before the d·Πm f64 tables)
        let flags_off = out.len() - sk.space() * 8 - 1;
        let mut bad_flags = out;
        bad_flags[flags_off] = 9;
        assert!(HcsStream::decode(&mut Reader::new(&bad_flags)).is_err());
    }

    #[test]
    fn space_is_sum_of_mode_tables_not_product_universe() {
        // the paper's claim in miniature: the hash table is d·Πm_k
        // counters regardless of the Πn_k universe size
        let sk = HcsStream::new(&[1 << 10, 1 << 10, 1 << 10], &[16, 16, 16], 3, 1);
        assert_eq!(sk.space(), 3 * 16 * 16 * 16);
    }
}
