//! HCS-native tensor plane: the store's multi-mode sketch subsystem.
//!
//! The 2-D store serves flat `(i, j)` keys through a `StreamSketch`;
//! this module serves arbitrary-order keys through [`HcsStream`], the
//! paper's Higher-order Count Sketch. One small hash pair per mode
//! (`h_k : [n_k] → [m_k]`, `s_k : [n_k] → {±1}`) replaces the flat
//! sketch's one giant pair over `Π n_k` — hash state shrinks from the
//! product of the mode sizes to their *sum*, the paper's exponential
//! saving, measured in `benches/bench_tensor.rs`.
//!
//! **Key encoding.** A multi-mode key travels as `u8 order` followed by
//! `order` little-endian `u32` indices ([`super::codec::put_mode_key`]);
//! the explicit order byte lets decoders reject an order-mismatched
//! frame instead of misaligning everything after it. Inside a sketch
//! the key maps to table offset `Σ_k h_k(i_k) · stride_k` (row-major
//! strides over the sketch dims) with sign `Π_k s_k(i_k)`.
//!
//! **Estimator.** `d` independent repeats; a point estimate is the
//! median of the d signed counters ([`HcsStream::query`]). Marginals
//! sum table counters against per-mode sign sums *on the sketch*
//! ([`HcsStream::marginal`]) — no densification. Slice top-k prunes by
//! marginal mass for insert-only streams and routes itself to a dense
//! scan once the sticky `has_deletions` flag is set, mirroring the 2-D
//! scan plane.
//!
//! **Contraction protocol.** Two same-family sketches contract directly
//! on their tables ([`contract`]): a full contraction is the per-repeat
//! table dot product (median over d — unbiased, the Ahle–Knudsen-style
//! bound asserted in tests), a partial contraction reshapes each table
//! to kept × contracted matrices and multiplies (FCS-style, returning a
//! [`ContractedSketch`] that can be queried or densified).
//!
//! **Serving.** [`registry`] is the named-tensor catalog inside
//! `ShardedStore`/`DurableStore`: durable behind the v5 snapshot format
//! and the TCREATE/TUPDATE/TUPDATE_BATCH WAL records, replicated by
//! full-ship origin frames (idempotent via the cumulative-remainder
//! rule — see the registry docs), and exposed over the wire as
//! TCREATE / TUPDATE / TUPDATE_BATCH / TQUERY / MARGINAL / SLICE_TOPK /
//! CONTRACT.

pub mod contract;
pub mod hcs;
pub mod registry;

pub use contract::{contract, contract_scalar, ContractOutput, ContractedSketch};
pub use hcs::{HcsStream, MAX_ORDER};
pub use registry::{TensorFamily, TensorRegistry, MAX_TENSORS, MAX_TENSOR_SPACE};
