//! Sketched tensor contraction between two stored same-family
//! [`HcsStream`]s — the CONTRACT RPC's compute kernel, FCS-style (Cao &
//! Liu, "Efficient Tensor Contraction via Fast Count Sketch"): the
//! contraction is evaluated **on the sketches**, never on dense data.
//!
//! Both operands share one hash family (the registry only admits
//! CONTRACT between same-family tensors), so for every contracted mode
//! the two tables are aligned bucket-by-bucket and
//!
//! ```text
//! Σ_{i_S} A[i_keep, i_S] · B[j_keep, i_S]
//!   ≈ Σ_{t_S} HCS(A)[t_keep, t_S] · HCS(B)[u_keep, t_S]
//! ```
//!
//! per repeat: the diagonal terms survive with sign² = 1 and every
//! cross term carries an odd sign product with zero expectation. With
//! **all** modes contracted this is the classic count-sketch inner
//! product estimator — unbiased, with variance `O(‖A‖² ‖B‖² / Π m_k)`
//! per repeat (the Ahle–Knudsen-style bound `benches/bench_tensor.rs`
//! and the acceptance test assert); the median over the d repeats
//! tightens the tail as usual.
//!
//! A **partial** contraction returns a [`ContractedSketch`]: the d
//! contracted tables over `[kept A buckets] × [kept B buckets]`, still
//! a sketch — point estimates re-apply the kept-mode signs and take the
//! median, and a dense materialization is just that estimate at every
//! kept key pair. One honest caveat, documented rather than hidden:
//! because the kept modes of both sides use the *same* hash pair (the
//! price of keeping every stored tensor in one mergeable family), the
//! estimator picks up an `O(1/m_keep)` diagonal bias on entries whose
//! A-side and B-side indices collide under `h` — exact two-sided
//! independence would need a second family per tensor. The scalar path
//! has no such term.

use super::hcs::{row_major_strides, HcsStream, MAX_ORDER};
use crate::hash::{HashSeeds, ModeHash};
use crate::store::codec::{self, Reader};
use crate::store::mergeable::MAX_DECODE_ELEMS;
use crate::util::stats::median_inplace;
use anyhow::{bail, ensure, Result};

/// Largest dense materialization [`ContractedSketch::to_dense`] will
/// produce (f64 elements) — a CONTRACT RPC asking for a dense result
/// beyond it is rejected instead of allocating unboundedly.
pub const CONTRACT_DENSE_CAP: usize = 1 << 20;

/// Result of [`contract`]: a scalar when every mode was contracted, a
/// sketch of the contracted tensor otherwise.
#[derive(Clone, Debug)]
pub enum ContractOutput {
    Scalar(f64),
    Sketch(ContractedSketch),
}

/// Contract `a` and `b` over the mode subset `contracted` (mode ids of
/// the shared family; each used once). Requires `a.same_family(b)`.
pub fn contract(a: &HcsStream, b: &HcsStream, contracted: &[usize]) -> Result<ContractOutput> {
    ensure!(a.same_family(b), "CONTRACT requires same-family sketches");
    ensure!(!contracted.is_empty(), "CONTRACT needs at least one contracted mode");
    let order = a.order();
    let mut seen = vec![false; order];
    for &k in contracted {
        ensure!(k < order, "contracted mode {k} out of order {order}");
        ensure!(!seen[k], "contracted mode {k} repeated");
        seen[k] = true;
    }
    if contracted.len() == order {
        return Ok(ContractOutput::Scalar(contract_scalar(a, b)));
    }
    let kept: Vec<usize> = (0..order).filter(|k| !seen[*k]).collect();
    Ok(ContractOutput::Sketch(contract_partial(a, b, &kept, &seen)))
}

/// Full contraction `⟨A, B⟩ = Σ_i A[i]·B[i]`: per repeat the dot
/// product of the two aligned tables, median over repeats. Unbiased.
pub fn contract_scalar(a: &HcsStream, b: &HcsStream) -> f64 {
    assert!(a.same_family(b), "CONTRACT requires same-family sketches");
    let mut est: Vec<f64> = (0..a.d)
        .map(|r| a.table(r).iter().zip(b.table(r).iter()).map(|(x, y)| x * y).sum())
        .collect();
    median_inplace(&mut est)
}

/// Live accuracy of the scalar estimator, computed **on the sketches**
/// (the true value is long gone in a streaming store): `(residual,
/// bound)` where `residual` is the median absolute deviation of the d
/// per-repeat estimates from their median — an observable proxy for
/// the estimator's spread — and `bound` is the paper's theoretical
/// per-repeat deviation scale `8·‖A‖·‖B‖/√Πm`, with each operand norm
/// estimated as the median per-repeat table L2 norm (`‖HCS(A)‖₂ ≈
/// ‖A‖₂` in expectation by sign cancellation). A healthy sketch keeps
/// `residual / bound` well below 1; drift toward or past 1 means the
/// sketch is too small for the mass it carries. Feeds the
/// `hocs_contract_*` gauges (see [`crate::obs`]).
pub fn contract_accuracy(a: &HcsStream, b: &HcsStream) -> (f64, f64) {
    let per_repeat: Vec<f64> = (0..a.d)
        .map(|r| a.table(r).iter().zip(b.table(r).iter()).map(|(x, y)| x * y).sum())
        .collect();
    let mut center = per_repeat.clone();
    let center = median_inplace(&mut center);
    let mut devs: Vec<f64> = per_repeat.iter().map(|e| (e - center).abs()).collect();
    let residual = median_inplace(&mut devs);
    let norm = |t: &HcsStream| -> f64 {
        let mut norms: Vec<f64> =
            (0..t.d).map(|r| t.table(r).iter().map(|v| v * v).sum::<f64>().sqrt()).collect();
        median_inplace(&mut norms)
    };
    let m: f64 = a.sketch_dims().iter().map(|&m| m as f64).product();
    let bound = 8.0 * norm(a) * norm(b) / m.sqrt();
    (residual, bound)
}

/// Partial contraction: per repeat, reshape both tables to
/// `[kept buckets × contracted buckets]` matrices and multiply
/// `A · Bᵀ`, giving the contracted table over
/// `[kept A buckets] × [kept B buckets]`.
fn contract_partial(
    a: &HcsStream,
    b: &HcsStream,
    kept: &[usize],
    contracted: &[bool],
) -> ContractedSketch {
    let kept_m: Vec<usize> = kept.iter().map(|&k| a.sketch_dims()[k]).collect();
    let ka: usize = kept_m.iter().product();
    let s_total: usize = a
        .sketch_dims()
        .iter()
        .enumerate()
        .filter(|(k, _)| contracted[*k])
        .map(|(_, &m)| m)
        .product();
    // per full-table offset, the (kept combo, contracted combo) split —
    // computed once, shared by both operands and every repeat
    let table_len = a.table(0).len();
    let mut split = Vec::with_capacity(table_len);
    {
        let order = a.order();
        let mut idx = vec![0usize; order];
        let kept_strides = row_major_strides(&kept_m);
        let s_dims: Vec<usize> = (0..order).filter(|&k| contracted[k]).map(|k| a.sketch_dims()[k]).collect();
        let s_strides = row_major_strides(&s_dims);
        loop {
            let mut kk = 0usize;
            for (slot, &k) in kept.iter().enumerate() {
                kk += idx[k] * kept_strides[slot];
            }
            let mut ss = 0usize;
            let mut slot = 0usize;
            for k in 0..order {
                if contracted[k] {
                    ss += idx[k] * s_strides[slot];
                    slot += 1;
                }
            }
            split.push((kk, ss));
            let mut carry = true;
            for k in (0..order).rev() {
                idx[k] += 1;
                if idx[k] < a.sketch_dims()[k] {
                    carry = false;
                    break;
                }
                idx[k] = 0;
            }
            if carry {
                break;
            }
        }
    }
    let mut tables = Vec::with_capacity(a.d);
    for r in 0..a.d {
        // reshape to [kept × contracted] row-major
        let mut amat = vec![0.0; ka * s_total];
        let mut bmat = vec![0.0; ka * s_total];
        for (off, &(kk, ss)) in split.iter().enumerate() {
            amat[kk * s_total + ss] = a.table(r)[off];
            bmat[kk * s_total + ss] = b.table(r)[off];
        }
        // C = A · Bᵀ over the contracted axis
        let mut c = vec![0.0; ka * ka];
        for i in 0..ka {
            let arow = &amat[i * s_total..(i + 1) * s_total];
            for j in 0..ka {
                let brow = &bmat[j * s_total..(j + 1) * s_total];
                c[i * ka + j] = arow.iter().zip(brow.iter()).map(|(x, y)| x * y).sum();
            }
        }
        tables.push(c);
    }
    let kept_n: Vec<usize> = kept.iter().map(|&k| a.dims()[k]).collect();
    let modes = (0..a.d)
        .map(|r| kept.iter().map(|&k| a.mode_hash(r, k).clone()).collect())
        .collect();
    ContractedSketch {
        kept_modes: kept.to_vec(),
        kept_dims: kept_n,
        kept_sketch_dims: kept_m,
        d: a.d,
        seed: a.seed,
        modes,
        tables,
    }
}

/// The sketch of a partially-contracted tensor `C[i_keep, j_keep] =
/// Σ_{i_S} A[i_keep, i_S]·B[j_keep, i_S]`: d tables over
/// `[kept buckets]²`, queryable like any HCS (kept-mode signs on both
/// sides, median over repeats).
#[derive(Clone, Debug)]
pub struct ContractedSketch {
    /// kept mode ids of the operands' shared family
    pub kept_modes: Vec<usize>,
    /// per kept mode: key universe `n_k`
    pub kept_dims: Vec<usize>,
    /// per kept mode: table extent `m_k`
    pub kept_sketch_dims: Vec<usize>,
    pub d: usize,
    pub seed: u64,
    /// `modes[r][slot]` — hash pair of kept mode `kept_modes[slot]`
    modes: Vec<Vec<ModeHash>>,
    /// `[d][Π m_kept · Π m_kept]`, row-major `[a-side, b-side]`
    tables: Vec<Vec<f64>>,
}

impl ContractedSketch {
    /// Kept-bucket combo and sign for one side's key.
    fn side(&self, r: usize, key: &[usize]) -> (usize, f64) {
        let strides = row_major_strides(&self.kept_sketch_dims);
        let mut b = 0usize;
        let mut s = 1.0;
        for (slot, &i) in key.iter().enumerate() {
            b += self.modes[r][slot].h(i) * strides[slot];
            s *= self.modes[r][slot].s(i);
        }
        (b, s)
    }

    /// Median-of-d estimate of `C[key_a, key_b]` (one index per kept
    /// mode, in `kept_modes` order, per side).
    pub fn query(&self, key_a: &[usize], key_b: &[usize]) -> f64 {
        assert_eq!(key_a.len(), self.kept_modes.len());
        assert_eq!(key_b.len(), self.kept_modes.len());
        for (slot, (&i, &j)) in key_a.iter().zip(key_b.iter()).enumerate() {
            assert!(i < self.kept_dims[slot] && j < self.kept_dims[slot]);
        }
        let ka: usize = self.kept_sketch_dims.iter().product();
        let mut est: Vec<f64> = (0..self.d)
            .map(|r| {
                let (ba, sa) = self.side(r, key_a);
                let (bb, sb) = self.side(r, key_b);
                sa * sb * self.tables[r][ba * ka + bb]
            })
            .collect();
        median_inplace(&mut est)
    }

    /// Dense materialization: the estimate at every kept key pair,
    /// dims `[kept A dims…, kept B dims…]` row-major. Rejected above
    /// [`CONTRACT_DENSE_CAP`] elements.
    pub fn to_dense(&self) -> Result<(Vec<usize>, Vec<f64>)> {
        let per_side: usize = self.kept_dims.iter().product();
        let total = per_side.saturating_mul(per_side);
        ensure!(
            total <= CONTRACT_DENSE_CAP,
            "dense contraction of {total} elements exceeds cap {CONTRACT_DENSE_CAP}"
        );
        let mut dims = self.kept_dims.clone();
        dims.extend_from_slice(&self.kept_dims);
        let mut data = Vec::with_capacity(total);
        let mut key_a = vec![0usize; self.kept_dims.len()];
        'outer_a: loop {
            let mut key_b = vec![0usize; self.kept_dims.len()];
            loop {
                data.push(self.query(&key_a, &key_b));
                if !advance(&mut key_b, &self.kept_dims) {
                    break;
                }
            }
            if !advance(&mut key_a, &self.kept_dims) {
                break 'outer_a;
            }
        }
        Ok((dims, data))
    }

    /// Wire form: kept-mode metadata plus the d contracted tables; the
    /// hash pairs are rebuilt from the seed on decode.
    pub fn encode(&self, out: &mut Vec<u8>) {
        codec::put_u8(out, u8::try_from(self.kept_modes.len()).expect("order fits u8"));
        for &k in &self.kept_modes {
            codec::put_u8(out, u8::try_from(k).expect("mode id fits u8"));
        }
        for &n in &self.kept_dims {
            codec::put_u32(out, u32::try_from(n).expect("dim fits u32"));
        }
        for &m in &self.kept_sketch_dims {
            codec::put_u32(out, u32::try_from(m).expect("sketch dim fits u32"));
        }
        codec::put_u32(out, u32::try_from(self.d).expect("d fits u32"));
        codec::put_u64(out, self.seed);
        for t in &self.tables {
            for &v in t {
                codec::put_f64(out, v);
            }
        }
    }

    /// Bit-exact inverse of [`ContractedSketch::encode`].
    pub fn decode(rd: &mut Reader<'_>) -> Result<Self> {
        let n_kept = rd.u8()? as usize;
        ensure!((1..=MAX_ORDER).contains(&n_kept), "kept-mode count {n_kept} out of range");
        let mut kept_modes = Vec::with_capacity(n_kept);
        for _ in 0..n_kept {
            let k = rd.u8()? as usize;
            ensure!(k < MAX_ORDER, "kept mode id {k} out of range");
            if kept_modes.contains(&k) {
                bail!("kept mode id {k} repeated");
            }
            kept_modes.push(k);
        }
        let mut kept_dims = Vec::with_capacity(n_kept);
        for _ in 0..n_kept {
            let n = rd.u32()? as usize;
            ensure!(n > 0, "corrupt contracted sketch: zero kept dim");
            kept_dims.push(n);
        }
        let mut kept_sketch_dims = Vec::with_capacity(n_kept);
        for _ in 0..n_kept {
            let m = rd.u32()? as usize;
            ensure!(m > 0, "corrupt contracted sketch: zero kept sketch dim");
            kept_sketch_dims.push(m);
        }
        let d = rd.u32()? as usize;
        ensure!(d >= 1, "corrupt contracted sketch: d = 0");
        let ka: usize = kept_sketch_dims.iter().product();
        let elems = d.saturating_mul(ka).saturating_mul(ka);
        ensure!(elems <= MAX_DECODE_ELEMS, "contracted sketch of {elems} counters exceeds cap");
        let seed = rd.u64()?;
        let seeds = HashSeeds::new(seed);
        let modes: Vec<Vec<ModeHash>> = (0..d)
            .map(|r| {
                kept_modes
                    .iter()
                    .zip(kept_dims.iter().zip(kept_sketch_dims.iter()))
                    .map(|(&k, (&n, &m))| ModeHash::new(n, m, seeds.seed_for(r, k)))
                    .collect()
            })
            .collect();
        let mut tables = Vec::with_capacity(d);
        for _ in 0..d {
            let mut t = Vec::with_capacity(ka * ka);
            for _ in 0..ka * ka {
                t.push(rd.f64()?);
            }
            tables.push(t);
        }
        Ok(Self { kept_modes, kept_dims, kept_sketch_dims, d, seed, modes, tables })
    }
}

/// Row-major odometer step; false once the key wrapped to all-zero.
fn advance(key: &mut [usize], dims: &[usize]) -> bool {
    for k in (0..key.len()).rev() {
        key[k] += 1;
        if key[k] < dims[k] {
            return true;
        }
        key[k] = 0;
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn offset(strides: &[usize], key: &[usize]) -> usize {
        key.iter().zip(strides.iter()).map(|(i, s)| i * s).sum()
    }

    /// Two dense order-3 tensors + their same-family sketches.
    fn pair(
        dims: &[usize],
        mdims: &[usize],
        d: usize,
        seed: u64,
        n_items: usize,
    ) -> (Vec<f64>, Vec<f64>, HcsStream, HcsStream) {
        let strides = row_major_strides(dims);
        let total: usize = dims.iter().product();
        let (mut da, mut db) = (vec![0.0; total], vec![0.0; total]);
        let mut a = HcsStream::new(dims, mdims, d, seed);
        let mut b = HcsStream::new(dims, mdims, d, seed);
        let mut rng = Pcg64::new(seed ^ 0xABCD);
        for _ in 0..n_items {
            let key: Vec<usize> =
                dims.iter().map(|&n| rng.gen_range(n as u64) as usize).collect();
            let w = (1 + rng.gen_range(9)) as f64;
            if rng.uniform() < 0.5 {
                a.update(&key, w);
                da[offset(&strides, &key)] += w;
            } else {
                b.update(&key, w);
                db[offset(&strides, &key)] += w;
            }
        }
        (da, db, a, b)
    }

    #[test]
    fn scalar_contraction_tracks_the_oracle_inner_product() {
        let dims = [12, 10, 8];
        let (da, db, a, b) = pair(&dims, &[10, 8, 8], 7, 3, 4000);
        let truth: f64 = da.iter().zip(db.iter()).map(|(x, y)| x * y).sum();
        let ContractOutput::Scalar(est) = contract(&a, &b, &[0, 1, 2]).unwrap() else {
            panic!("full contraction must be scalar");
        };
        let norm: f64 = (da.iter().map(|x| x * x).sum::<f64>()
            * db.iter().map(|y| y * y).sum::<f64>())
        .sqrt();
        // Ahle–Knudsen-style: per-repeat std is O(‖A‖‖B‖/√Πm); allow a
        // generous constant over the median of d repeats
        let m: usize = [10usize, 8, 8].iter().product();
        let bound = 8.0 * norm / (m as f64).sqrt();
        assert!(
            (est - truth).abs() <= bound.max(0.05 * truth.abs()),
            "estimate {est} vs truth {truth} (bound {bound})"
        );
    }

    #[test]
    fn contract_accuracy_residual_sits_inside_the_theoretical_bound() {
        let dims = [12, 10, 8];
        let (da, db, a, b) = pair(&dims, &[10, 8, 8], 7, 5, 4000);
        let (residual, bound) = contract_accuracy(&a, &b);
        assert!(residual >= 0.0 && bound > 0.0);
        // the per-repeat spread is what the bound bounds (up to the
        // sketch-side norm proxy), so the observed ratio stays < 1
        assert!(residual <= bound, "residual {residual} vs bound {bound}");
        // the sketch-side norm proxy tracks the dense norms
        let dense_norm: f64 = (da.iter().map(|x| x * x).sum::<f64>()
            * db.iter().map(|y| y * y).sum::<f64>())
        .sqrt();
        let m: usize = [10usize, 8, 8].iter().product();
        let dense_bound = 8.0 * dense_norm / (m as f64).sqrt();
        assert!(
            bound <= 4.0 * dense_bound && bound >= dense_bound / 4.0,
            "sketched bound {bound} vs dense bound {dense_bound}"
        );
    }

    #[test]
    fn partial_contraction_matches_the_dense_oracle() {
        let dims = [6usize, 5, 8];
        let strides = row_major_strides(&dims);
        let (da, db, a, b) = pair(&dims, &[6, 5, 6], 7, 11, 2500);
        // contract mode 2, keep modes 0 and 1 on each side
        let ContractOutput::Sketch(cs) = contract(&a, &b, &[2]).unwrap() else {
            panic!("partial contraction must return a sketch");
        };
        assert_eq!(cs.kept_modes, vec![0, 1]);
        // oracle C[(i0,i1),(j0,j1)] = Σ_k A[i0,i1,k]·B[j0,j1,k]
        let oracle = |ka: &[usize], kb: &[usize]| -> f64 {
            (0..dims[2])
                .map(|k| {
                    da[offset(&strides, &[ka[0], ka[1], k])]
                        * db[offset(&strides, &[kb[0], kb[1], k])]
                })
                .sum()
        };
        let norm: f64 = (da.iter().map(|x| x * x).sum::<f64>()
            * db.iter().map(|y| y * y).sum::<f64>())
        .sqrt();
        let mut worst: f64 = 0.0;
        for ka in [[0usize, 0], [3, 2], [5, 4], [1, 3]] {
            for kb in [[0usize, 1], [2, 2], [4, 0]] {
                let est = cs.query(&ka, &kb);
                worst = worst.max((est - oracle(&ka, &kb)).abs());
            }
        }
        // loose bound: kept modes stay hashed, so per-entry noise is
        // O(‖A‖‖B‖/√m_S) plus the documented O(1/m_keep) bias
        assert!(worst <= norm, "worst partial-contraction error {worst} vs norm {norm}");
        // dense materialization is exactly the per-entry estimates
        let (ddims, data) = cs.to_dense().unwrap();
        assert_eq!(ddims, vec![6, 5, 6, 5]);
        let kstr = row_major_strides(&ddims);
        let est = cs.query(&[3, 2], &[2, 2]);
        assert_eq!(data[offset(&kstr, &[3, 2, 2, 2])].to_bits(), est.to_bits());
    }

    #[test]
    fn contracted_sketch_roundtrips_bit_exact() {
        let dims = [6usize, 5, 8];
        let (_, _, a, b) = pair(&dims, &[6, 5, 6], 5, 21, 800);
        let ContractOutput::Sketch(cs) = contract(&a, &b, &[2]).unwrap() else {
            panic!("expected sketch");
        };
        let mut out = Vec::new();
        cs.encode(&mut out);
        let got = ContractedSketch::decode(&mut Reader::new(&out)).unwrap();
        assert_eq!(got.kept_modes, cs.kept_modes);
        assert_eq!(got.kept_dims, cs.kept_dims);
        for ka in [[0usize, 0], [5, 4], [2, 3]] {
            for kb in [[1usize, 1], [3, 0]] {
                assert_eq!(got.query(&ka, &kb).to_bits(), cs.query(&ka, &kb).to_bits());
            }
        }
        // truncated frames are rejected
        let mut trunc = out.clone();
        trunc.truncate(out.len() - 3);
        assert!(ContractedSketch::decode(&mut Reader::new(&trunc)).is_err());
    }

    #[test]
    fn contract_validates_its_inputs() {
        let a = HcsStream::new(&[8, 8], &[4, 4], 3, 1);
        let b = HcsStream::new(&[8, 8], &[4, 4], 3, 2); // different seed
        assert!(contract(&a, &b, &[0]).is_err());
        let c = HcsStream::new(&[8, 8], &[4, 4], 3, 1);
        assert!(contract(&a, &c, &[]).is_err());
        assert!(contract(&a, &c, &[2]).is_err());
        assert!(contract(&a, &c, &[0, 0]).is_err());
        assert!(matches!(contract(&a, &c, &[0, 1]), Ok(ContractOutput::Scalar(_))));
    }
}
