//! Framed TCP front-end for the store.
//!
//! Wire protocol (all little-endian):
//!
//! ```text
//! request  = u32 len | u8 opcode | body
//! response = u32 len | u8 status (0 ok / 1 err) | body-or-utf8-error
//! ```
//!
//! Plain `std::net` with one thread per connection (accept → spawn, the
//! darkfi-style blocking net layer) — no async runtime: connections are
//! long-lived and the per-request work is either O(d) table arithmetic
//! or a store scan that dwarfs any scheduling overhead. Shard mutexes
//! inside [`DurableStore`] are the only cross-connection coordination,
//! so concurrent clients on different shards proceed in parallel.
//!
//! **Commit scheduling.** Durable single-record UPDATEs from different
//! connections coalesce in the WAL's leader/follower commit queue
//! ([`super::wal`]): the first arrival leads a group write (one flush /
//! `sync_data` for every staged frame) while the rest wait on a condvar
//! for their commit LSN — so un-batched clients get the batched-WAL win
//! without protocol changes. `StoreServerConfig::group_commit = false`
//! (CLI `--no-group-commit`) restores per-record commits.
//!
//! **Steady-state allocation.** The connection loop reuses one request
//! and one response buffer per connection ([`read_frame_into`] fills in
//! place, `dispatch` serializes straight into the response frame), the
//! batch decode scratch is thread-local, and point queries run on the
//! store's thread-local fan-out accumulator — a settled UPDATE / QUERY
//! loop performs no per-request heap allocation. Scan responses
//! (TOPK / HEAVY) come out of the store's version-stamped scan cache
//! ([`super::sharded`]), which re-merges and re-scans only after a
//! write invalidates its stamp.
//!
//! **Tensor plane.** The TCREATE / TUPDATE / TUPDATE_BATCH / TQUERY /
//! MARGINAL / SLICE_TOPK / CONTRACT opcodes serve the named HCS catalog
//! ([`super::tensor`]) over the same framing: the server resolves the
//! target tensor's family first and decodes the multi-mode key payload
//! against its declared dims ([`codec::read_mode_key`]), so a
//! mis-ordered or out-of-range key is a framed error, never a
//! misaligned parse. TMERGE_ORIGIN is the tensor replication frame
//! (full cumulative origin state, per-(origin, tensor) sequence dedup).
//!
//! `BATCH_SKETCH` reuses the PR-1 coordinator worker pool
//! ([`crate::coordinator::Coordinator`]) when the server is started
//! `with_coordinator` and AOT artifacts are present; otherwise the
//! opcode reports an error and everything else keeps working.
//!
//! **Replication.** With `StoreServerConfig::peers` set the node is a
//! cluster member: a replicator thread ([`super::replica`]) ships
//! per-peer origin deltas over this same protocol, and the server
//! accepts `MERGE_ORIGIN` frames — headered merges whose per-origin
//! sequence dedup makes retries (replication *and* edge-node) safe,
//! where a retried legacy MERGE would double-count. Legacy headerless
//! MERGE keeps working unchanged. STATS carries the replication
//! counters (peer count, last-sync age, cursor version, ships, bytes,
//! dedups) after the store fields.

use super::codec::{self, Reader};
use super::mergeable::MergeableSketch;
use super::replica::{wire, ReplicaConfig, ReplicationCounters, Replicator};
use super::sharded::StoreConfig;
use super::tensor::{ContractOutput, HcsStream, TensorFamily};
use super::wal::{DurableOptions, DurableStore};
use crate::coordinator::{BackendKind, Coordinator, CoordinatorConfig, Job};
use crate::sketch::stream::StreamSketch;
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::cell::RefCell;
use std::io::{Read as _, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

thread_local! {
    /// Per-connection-thread scratch for decoded UPDATE_BATCH items —
    /// the batched write path allocates nothing per request once warm.
    static BATCH_SCRATCH: RefCell<Vec<(usize, usize, f64)>> = RefCell::new(Vec::new());
}

// Request opcodes (first payload byte) and response status bytes live
// in `store::wire_ops` — the single source of truth the
// `opcode-symmetry` lint pass cross-checks against this file's
// dispatch match, the typed `StoreClient` methods, and the CLI.
use super::wire_ops::{self as op, STATUS_ERR, STATUS_OK};

/// Hard cap on a single frame — a hostile length prefix must not be
/// able to allocate gigabytes.
const MAX_FRAME: u32 = 64 << 20;
/// Per-request caps on fan-in sizes. The batch cap is the store-wide
/// one so RPC validation, the durable API, and WAL decode stay in
/// lockstep.
const MAX_BATCH_UPDATES: usize = super::MAX_UPDATE_BATCH;
const MAX_TOPK: usize = 4096;
const MAX_SKETCH_INPUT: usize = 1 << 22;

/// Write one `len | payload` frame.
// lint: allow(fault-coverage) socket writes, not durable-path filesystem I/O — the fault plane covers disks, not the network
pub(crate) fn write_frame(stream: &mut TcpStream, payload: &[u8]) -> Result<()> {
    let len = u32::try_from(payload.len()).context("frame too large")?;
    ensure!(len <= MAX_FRAME, "frame of {len} bytes exceeds protocol cap");
    stream.write_all(&len.to_le_bytes())?;
    stream.write_all(payload)?;
    Ok(())
}

/// Read one frame into `buf`, reusing its capacity (the per-connection
/// steady state allocates nothing); `Ok(false)` is a clean EOF at a
/// frame boundary.
pub(crate) fn read_frame_into(stream: &mut TcpStream, buf: &mut Vec<u8>) -> Result<bool> {
    let mut lenb = [0u8; 4];
    match stream.read_exact(&mut lenb) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(false),
        Err(e) => return Err(e.into()),
    }
    let len = u32::from_le_bytes(lenb);
    ensure!(len <= MAX_FRAME, "oversized frame ({len} bytes)");
    // resize without clear: only buffer *growth* pays a zero-fill, and
    // read_exact overwrites every byte (or errors, dropping the
    // connection) — no stale bytes can leak into a served frame
    buf.resize(len as usize, 0);
    stream.read_exact(buf)?;
    Ok(true)
}

/// How to boot a [`StoreServer`].
#[derive(Clone, Debug)]
pub struct StoreServerConfig {
    /// bind address (`host:port`; port 0 picks a free one)
    pub addr: String,
    pub store: StoreConfig,
    /// snapshot/WAL directory; `None` = in-memory only
    pub data_dir: Option<String>,
    /// `sync_data` every WAL commit (power-loss durability; group
    /// commit amortizes the sync over a batch or a leader group).
    /// Ignored without `data_dir`.
    pub fsync: bool,
    /// leader/follower cross-connection group commit (default on);
    /// `false` = one WAL write + flush per record, the measured
    /// baseline. Ignored without `data_dir`.
    pub group_commit: bool,
    /// boot the coordinator worker pool for BATCH_SKETCH
    pub with_coordinator: bool,
    /// AOT artifacts for the coordinator backend
    pub artifacts_dir: String,
    /// replication peers (`host:port` of their store servers); non-empty
    /// turns this node into a cluster member: local writes accumulate in
    /// the origin sketch and a replicator thread ships per-peer deltas
    pub peers: Vec<String>,
    /// anti-entropy tick interval (staleness vs bandwidth knob)
    pub sync_interval_ms: u64,
    /// force a dense full-state ship every Nth sync per peer
    /// (self-healing cadence; `0` = only on first contact / gaps)
    pub full_ship_every: u64,
    /// connect + I/O timeout for the replicator's peer connections
    pub replica_timeout_ms: u64,
    /// per-connection read timeout in ms (`0` = none). A client that
    /// stops mid-frame or goes half-open is disconnected after this
    /// long instead of pinning its thread forever (slowloris
    /// protection). The CLI default is 30 s; the struct default is off
    /// so embedded/test servers keep their patient behaviour.
    pub read_timeout_ms: u64,
    /// accepted-connection bound (`0` = unlimited). Over-limit
    /// connections are rejected gracefully: one framed
    /// "connection limit" error, then close — a fast client-visible
    /// failure instead of an unbounded thread pile-up.
    pub max_connections: u64,
}

impl Default for StoreServerConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:0".to_string(),
            store: StoreConfig::default(),
            data_dir: None,
            fsync: false,
            group_commit: true,
            with_coordinator: false,
            artifacts_dir: crate::runtime::DEFAULT_ARTIFACTS_DIR.to_string(),
            peers: Vec::new(),
            sync_interval_ms: 100,
            full_ship_every: 0,
            replica_timeout_ms: 2_000,
            read_timeout_ms: 0,
            max_connections: 1024,
        }
    }
}

/// State shared by the accept loop and every connection thread.
struct Shared {
    store: Arc<DurableStore>,
    coordinator: Option<Coordinator>,
    /// replication counters (zeros on a standalone node) — written by
    /// the replicator thread and the origin-merge path, read by STATS
    repl: Arc<ReplicationCounters>,
    stop: AtomicBool,
    connections: AtomicU64,
    /// currently-open connections (accept-loop admission gate)
    active: AtomicU64,
    /// connections inside handle-request-and-respond right now — what
    /// the shutdown drain waits on
    busy: AtomicU64,
    read_timeout: Option<std::time::Duration>,
    max_connections: u64,
}

/// Handle to a running server. Dropping it (or calling
/// [`StoreServer::shutdown`]) stops the replicator and the accept loop;
/// in-flight connection threads finish their current request and exit
/// when their client disconnects.
pub struct StoreServer {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<std::thread::JoinHandle<()>>,
    replicator: Option<Replicator>,
}

impl StoreServer {
    pub fn start(cfg: StoreServerConfig) -> Result<Self> {
        let store = match &cfg.data_dir {
            Some(dir) => DurableStore::open_opts(
                Path::new(dir),
                cfg.store.clone(),
                DurableOptions { fsync: cfg.fsync, group_commit: cfg.group_commit },
            )?,
            None => DurableStore::in_memory(cfg.store.clone()),
        };
        let store = Arc::new(store);
        let repl = Arc::new(ReplicationCounters::new(cfg.peers.len() as u64));
        let replicator = if cfg.peers.is_empty() {
            None
        } else {
            // an easy copy-paste misconfig with a silent symptom: a node
            // peered at itself re-ingests its own deltas and every
            // estimate doubles. Catch the literal form of it here (alias
            // addresses can still slip through — documented).
            ensure!(
                !cfg.peers.iter().any(|p| p == &cfg.addr),
                "peer list contains this node's own address {} (self-replication \
                 would double-count every update)",
                cfg.addr
            );
            // flip the origin accumulators on before the listener
            // exists, so every locally-originated write is captured
            store.enable_replication();
            Some(Replicator::start(
                store.clone(),
                ReplicaConfig {
                    peers: cfg.peers.clone(),
                    sync_interval_ms: cfg.sync_interval_ms,
                    full_ship_every: cfg.full_ship_every,
                    connect_timeout_ms: cfg.replica_timeout_ms,
                    io_timeout_ms: cfg.replica_timeout_ms,
                },
                repl.clone(),
            )?)
        };
        let coordinator = if cfg.with_coordinator {
            match Coordinator::start(CoordinatorConfig {
                backend: BackendKind::PureRust,
                artifacts_dir: cfg.artifacts_dir.clone(),
                ..Default::default()
            }) {
                Ok(co) => Some(co),
                Err(e) => {
                    crate::log_warn!("store: coordinator unavailable ({e}); BATCH_SKETCH disabled");
                    None
                }
            }
        } else {
            None
        };
        let listener =
            TcpListener::bind(&cfg.addr).with_context(|| format!("binding {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        let shared = Arc::new(Shared {
            store,
            coordinator,
            repl,
            stop: AtomicBool::new(false),
            connections: AtomicU64::new(0),
            active: AtomicU64::new(0),
            busy: AtomicU64::new(0),
            read_timeout: (cfg.read_timeout_ms > 0)
                .then(|| std::time::Duration::from_millis(cfg.read_timeout_ms)),
            max_connections: cfg.max_connections,
        });
        let ashared = shared.clone();
        let accept = std::thread::Builder::new()
            .name("hocs-store-accept".into())
            .spawn(move || accept_loop(listener, ashared))?;
        crate::log_info!("store: serving on {addr}");
        Ok(Self { addr, shared, accept: Some(accept), replicator })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The served store (tests / embedding).
    pub fn store(&self) -> &DurableStore {
        &self.shared.store
    }

    /// Block until the server stops (SHUTDOWN RPC).
    pub fn wait(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Stop accepting and join the accept loop.
    pub fn shutdown(self) {
        drop(self);
    }
}

impl Drop for StoreServer {
    fn drop(&mut self) {
        // stop shipping before the listener dies (peers see a clean
        // connection drop, not a mid-frame hangup)
        self.replicator.take();
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            // poke the blocking accept() so it observes the stop flag
            let _ = TcpStream::connect(self.addr);
            let _ = h.join();
        }
        // drain: requests already being handled get a bounded window to
        // finish and flush their response before the process moves on
        // (connection threads then observe the stop flag and close)
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while self.shared.busy.load(Ordering::SeqCst) > 0 {
            if std::time::Instant::now() >= deadline {
                crate::log_warn!("store: shutdown drain timed out with requests in flight");
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    for conn in listener.incoming() {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        match conn {
            Ok(mut stream) => {
                // admission gate: past the bound, reject gracefully —
                // one framed error the client can read and report,
                // instead of an unbounded thread pile-up or a silent
                // RST. `active` was incremented by still-open
                // connections and is released as each loop exits.
                if shared.max_connections > 0
                    && shared.active.load(Ordering::SeqCst) >= shared.max_connections
                {
                    let mut err = vec![STATUS_ERR];
                    err.extend_from_slice(b"connection limit reached");
                    let _ = write_frame(&mut stream, &err);
                    crate::log_debug!("store: connection rejected (limit reached)");
                    continue;
                }
                shared.active.fetch_add(1, Ordering::SeqCst);
                let cshared = shared.clone();
                let id = cshared.connections.fetch_add(1, Ordering::Relaxed);
                let spawned = std::thread::Builder::new()
                    .name(format!("hocs-store-conn-{id}"))
                    .spawn(move || connection_loop(stream, cshared));
                if spawned.is_err() {
                    shared.active.fetch_sub(1, Ordering::SeqCst);
                    crate::log_warn!("store: could not spawn connection thread");
                }
            }
            Err(e) => crate::log_debug!("store: accept error: {e}"),
        }
    }
    crate::log_info!("store: accept loop exiting");
}

fn connection_loop(mut stream: TcpStream, shared: Arc<Shared>) {
    let _ = stream.set_nodelay(true);
    // half-open / slowloris protection: a peer that stops mid-frame (or
    // vanishes without a FIN) costs at most the read timeout, not a
    // thread forever
    if let Some(t) = shared.read_timeout {
        let _ = stream.set_read_timeout(Some(t));
    }
    // one request and one response buffer per connection, reused across
    // requests — the settled request loop allocates nothing
    let mut req = Vec::new();
    let mut resp = Vec::new();
    loop {
        match read_frame_into(&mut stream, &mut req) {
            Ok(true) => {}
            Ok(false) => break,
            Err(e) => {
                crate::log_debug!("store: connection read error: {e}");
                break;
            }
        }
        // `busy` spans handle + respond: the shutdown drain in
        // [`StoreServer::drop`] waits for in-flight requests to finish
        // and flush, so an acknowledged write is never cut off mid-frame
        let opcode = req.first().copied().unwrap_or(0);
        let t0 = std::time::Instant::now();
        shared.busy.fetch_add(1, Ordering::SeqCst);
        let shutdown = {
            let _span = crate::obs::trace::span(op::name(opcode).unwrap_or("rpc.unknown"));
            handle_request(&req, &shared, &mut resp)
        };
        let responded = write_frame(&mut stream, &resp).is_ok();
        shared.busy.fetch_sub(1, Ordering::SeqCst);
        let us = t0.elapsed().as_micros() as u64;
        let ok = resp.first().copied() == Some(STATUS_OK);
        crate::obs::global().rpc_observe(opcode, us, ok);
        let slow = crate::obs::trace::slow_threshold_us();
        if slow > 0 && us >= slow {
            crate::obs::trace::note_slow(format!(
                "{} {us}us ok={ok}",
                op::name(opcode).unwrap_or("UNKNOWN")
            ));
        }
        if !responded {
            break;
        }
        if shutdown {
            shared.stop.store(true, Ordering::SeqCst);
            // poke the accept loop from its own listening address
            if let Ok(addr) = stream.local_addr() {
                let _ = TcpStream::connect(addr);
            }
            break;
        }
        if shared.stop.load(Ordering::SeqCst) {
            // drain semantics: the request in flight when SHUTDOWN
            // arrived was answered above; the connection then closes
            // instead of serving a stopped store forever
            break;
        }
    }
    shared.active.fetch_sub(1, Ordering::SeqCst);
}

/// Run [`dispatch`] straight into the reused response buffer as a
/// status-tagged frame; protocol errors become `STATUS_ERR` + message
/// instead of a dropped connection. Returns the shutdown flag.
fn handle_request(req: &[u8], shared: &Shared, resp: &mut Vec<u8>) -> bool {
    resp.clear();
    codec::put_u8(resp, STATUS_OK);
    match dispatch(req, shared, resp) {
        Ok(shutdown) => shutdown,
        Err(e) => {
            resp.clear();
            codec::put_u8(resp, STATUS_ERR);
            resp.extend_from_slice(e.to_string().as_bytes());
            false
        }
    }
}

/// Handle one request, serializing the response body directly into
/// `body` (which already holds the status byte). Returns the shutdown
/// flag; on `Err` the caller discards `body` and frames the error.
fn dispatch(req: &[u8], shared: &Shared, body: &mut Vec<u8>) -> Result<bool> {
    let mut rd = Reader::new(req);
    let opcode = rd.u8()?;
    let cfg = shared.store.config();
    match opcode {
        op::UPDATE => {
            let (i, j, w) = rd.update_triple()?;
            let (i, j) = (i as usize, j as usize);
            ensure!(w.is_finite(), "non-finite update weight");
            shared.store.update(i, j, w)?;
        }
        op::UPDATE_BATCH => {
            let count = rd.u32()? as usize;
            ensure!(count <= MAX_BATCH_UPDATES, "batch of {count} updates exceeds cap");
            // decode + validate the whole batch (into the thread-local
            // scratch — no per-request allocation once warm) before
            // applying any of it: a bad item must not leave a
            // half-applied batch behind
            BATCH_SCRATCH.with(|cell| -> Result<()> {
                let mut items = cell.borrow_mut();
                items.clear();
                items.reserve(count);
                for _ in 0..count {
                    let (i, j, w) = rd.update_triple()?;
                    let (i, j) = (i as usize, j as usize);
                    ensure!(
                        i < cfg.n1 && j < cfg.n2,
                        "batch key ({i}, {j}) outside universe {}x{}",
                        cfg.n1,
                        cfg.n2
                    );
                    ensure!(w.is_finite(), "non-finite update weight in batch");
                    items.push((i, j, w));
                }
                // group commit + shard-grouped apply: one WAL frame and
                // one lock acquisition per destination shard for the
                // whole batch
                shared.store.update_batch(&items)
            })?;
            codec::put_u32(body, count as u32);
        }
        op::QUERY => {
            let (i, j) = (rd.u32()? as usize, rd.u32()? as usize);
            ensure!(
                i < cfg.n1 && j < cfg.n2,
                "key ({i}, {j}) outside universe {}x{}",
                cfg.n1,
                cfg.n2
            );
            codec::put_f64(body, shared.store.point_query(i, j));
        }
        op::TOPK => {
            let k = rd.u32()? as usize;
            ensure!(k <= MAX_TOPK, "top-k of {k} exceeds cap {MAX_TOPK}");
            put_entries(body, &shared.store.top_k(k))?;
        }
        op::HEAVY => {
            let threshold = rd.f64()?;
            ensure!(threshold.is_finite(), "non-finite heavy-hitter threshold");
            put_entries(body, &shared.store.heavy_hitters(threshold))?;
        }
        op::MERGE => {
            let sk = StreamSketch::decode(&mut rd)?;
            for r in 0..sk.d {
                ensure!(
                    sk.table(r).iter().all(|v| v.is_finite()),
                    "merged sketch contains non-finite counters"
                );
            }
            shared.store.merge_sketch(&sk)?;
        }
        op::MERGE_ORIGIN => {
            let hdr = wire::read_header(&mut rd)?;
            let sk = match hdr.enc {
                wire::ENC_SPARSE => wire::decode_sparse(&mut rd)?,
                _ => StreamSketch::decode(&mut rd)?,
            };
            ensure!(cfg.matches(&sk), "origin-merge sketch family does not match this store");
            for r in 0..sk.d {
                ensure!(
                    sk.table(r).iter().all(|v| v.is_finite()),
                    "origin-merge sketch contains non-finite counters"
                );
            }
            // the store runs the whole admit → log(ingest) → apply →
            // commit sequence atomically relative to snapshots; a
            // deduplicated retry is an acknowledged no-op
            let applied =
                shared.store.apply_origin_merge(hdr.origin, hdr.seq, hdr.mode, hdr.ingest, sk)?;
            if applied {
                shared.repl.note_applied();
            } else {
                shared.repl.note_deduped();
            }
            codec::put_u8(body, u8::from(applied));
        }
        op::SNAPSHOT => shared.store.snapshot()?,
        op::ADVANCE_EPOCH => shared.store.advance_epoch()?,
        op::STATS => {
            let st = shared.store.stats();
            codec::put_u32(body, st.shards as u32);
            codec::put_u32(body, st.window as u32);
            codec::put_u64(body, st.epoch);
            codec::put_u64(body, st.updates);
            // replication fields (zeros on a standalone node); old
            // clients simply stop reading after the store fields
            let rs = shared.repl.snapshot();
            codec::put_u32(body, rs.peers as u32);
            codec::put_u8(body, u8::from(rs.last_sync_age_ms.is_some()));
            codec::put_u64(body, rs.last_sync_age_ms.unwrap_or(0));
            codec::put_u64(body, rs.cursor_version);
            codec::put_u64(body, rs.ships);
            codec::put_u64(body, rs.full_ships);
            codec::put_u64(body, rs.bytes_shipped);
            codec::put_u64(body, rs.merges_applied);
            codec::put_u64(body, rs.merges_deduped);
        }
        op::BATCH_SKETCH => {
            let co = shared
                .coordinator
                .as_ref()
                .ok_or_else(|| anyhow!("coordinator not enabled on this server"))?;
            let n = rd.u32()? as usize;
            ensure!(n <= MAX_SKETCH_INPUT, "sketch input of {n} floats exceeds cap");
            let mut x = Vec::with_capacity(n);
            for _ in 0..n {
                x.push(rd.f32()?);
            }
            let out = co.call(Job::CsSketch(x)).map_err(|e| anyhow!("sketch job: {e}"))?;
            codec::put_u32(body, u32::try_from(out.len()).context("sketch output too large")?);
            for v in out {
                codec::put_f32(body, v);
            }
        }
        op::TCREATE => {
            let name = codec::read_name(&mut rd)?;
            let family = TensorFamily::decode(&mut rd)?;
            let created = shared.store.tensor_create(&name, &family)?;
            codec::put_u8(body, u8::from(created));
        }
        op::TUPDATE => {
            let name = codec::read_name(&mut rd)?;
            let family = tensor_family(shared, &name)?;
            let key = codec::read_mode_key(&mut rd, &family.dims)?;
            let w = rd.f64()?;
            ensure!(w.is_finite(), "non-finite update weight");
            shared.store.tensor_update(&name, &key, w)?;
        }
        op::TUPDATE_BATCH => {
            let name = codec::read_name(&mut rd)?;
            let family = tensor_family(shared, &name)?;
            let count = rd.u32()? as usize;
            ensure!(count <= MAX_BATCH_UPDATES, "tensor batch of {count} updates exceeds cap");
            // decode + validate everything before applying anything —
            // the all-or-nothing rule of the 2-D batch path
            let mut keys = Vec::with_capacity(count * family.order());
            let mut ws = Vec::with_capacity(count);
            for _ in 0..count {
                let key = codec::read_mode_key(&mut rd, &family.dims)?;
                keys.extend_from_slice(&key);
                let w = rd.f64()?;
                ensure!(w.is_finite(), "non-finite update weight in batch");
                ws.push(w);
            }
            shared.store.tensor_update_batch(&name, &keys, &ws)?;
            codec::put_u32(body, count as u32);
        }
        op::TQUERY => {
            let name = codec::read_name(&mut rd)?;
            let family = tensor_family(shared, &name)?;
            let key = codec::read_mode_key(&mut rd, &family.dims)?;
            codec::put_f64(body, shared.store.tensor_query(&name, &key)?);
        }
        op::MARGINAL => {
            let name = codec::read_name(&mut rd)?;
            let family = tensor_family(shared, &name)?;
            let mut spec = Vec::with_capacity(family.order());
            for (k, &n) in family.dims.iter().enumerate() {
                match rd.u8()? {
                    0 => spec.push(None),
                    1 => {
                        let i = rd.u32()? as usize;
                        ensure!(i < n, "marginal mode {k} index {i} out of range (dim {n})");
                        spec.push(Some(i));
                    }
                    other => bail!("bad marginal mode flag {other}"),
                }
            }
            codec::put_f64(body, shared.store.tensor_marginal(&name, &spec)?);
        }
        op::SLICE_TOPK => {
            let name = codec::read_name(&mut rd)?;
            let mode = rd.u32()? as usize;
            let index = rd.u32()? as usize;
            let k = rd.u32()? as usize;
            ensure!(k <= MAX_TOPK, "slice top-k of {k} exceeds cap {MAX_TOPK}");
            let entries = shared.store.tensor_slice_top_k(&name, mode, index, k)?;
            codec::put_u32(body, u32::try_from(entries.len()).context("entry count too large")?);
            for (key, w) in &entries {
                codec::put_mode_key(body, key);
                codec::put_f64(body, *w);
            }
        }
        op::CONTRACT => {
            let a_name = codec::read_name(&mut rd)?;
            let b_name = codec::read_name(&mut rd)?;
            let n = rd.u8()? as usize;
            let mut modes = Vec::with_capacity(n);
            for _ in 0..n {
                modes.push(rd.u8()? as usize);
            }
            let want_dense = rd.u8()? != 0;
            match shared.store.tensor_contract(&a_name, &b_name, &modes)? {
                ContractOutput::Scalar(v) => {
                    codec::put_u8(body, 0);
                    codec::put_f64(body, v);
                }
                ContractOutput::Sketch(cs) if want_dense => {
                    let (dims, vals) = cs.to_dense()?;
                    codec::put_u8(body, 2);
                    codec::put_u8(body, u8::try_from(dims.len()).context("contraction order too large")?);
                    for &d in &dims {
                        codec::put_u32(body, u32::try_from(d).context("contraction dim too large")?);
                    }
                    codec::put_u32(body, u32::try_from(vals.len()).context("dense result too large")?);
                    for v in vals {
                        codec::put_f64(body, v);
                    }
                }
                ContractOutput::Sketch(cs) => {
                    codec::put_u8(body, 1);
                    cs.encode(body);
                }
            }
        }
        op::TMERGE_ORIGIN => {
            let origin = rd.u64()?;
            let seq = rd.u64()?;
            let name = codec::read_name(&mut rd)?;
            let full = HcsStream::decode(&mut rd)?;
            for r in 0..full.d {
                ensure!(
                    full.table(r).iter().all(|v| v.is_finite()),
                    "tensor replication frame contains non-finite counters"
                );
            }
            let applied = shared.store.tensor_apply_origin_merge(origin, &name, seq, full)?;
            if applied {
                shared.repl.note_applied();
            } else {
                shared.repl.note_deduped();
            }
            codec::put_u8(body, u8::from(applied));
        }
        op::METRICS => {
            body.extend_from_slice(crate::obs::render_text().as_bytes());
        }
        op::SHUTDOWN => return Ok(true),
        other => bail!("{}", op::unknown(other)),
    }
    Ok(false)
}

fn tensor_family(shared: &Shared, name: &str) -> Result<TensorFamily> {
    shared.store.tensor_family(name).ok_or_else(|| anyhow!("unknown tensor {name:?}"))
}

fn put_entries(out: &mut Vec<u8>, entries: &[(usize, usize, f64)]) -> Result<()> {
    codec::put_u32(out, u32::try_from(entries.len()).context("entry count too large")?);
    for &(i, j, w) in entries {
        codec::put_u32(out, i as u32);
        codec::put_u32(out, j as u32);
        codec::put_f64(out, w);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::store::client::StoreClient;
    use crate::store::sharded::ShardedStore;

    fn test_cfg() -> StoreConfig {
        StoreConfig { n1: 64, n2: 64, m1: 16, m2: 16, d: 5, seed: 1234, shards: 4, window: 4 }
    }

    /// `None` when the sandbox forbids loopback sockets — tests skip,
    /// mirroring the artifacts_ready() convention elsewhere.
    fn start_server(data_dir: Option<String>) -> Option<StoreServer> {
        match StoreServer::start(StoreServerConfig {
            addr: "127.0.0.1:0".to_string(),
            store: test_cfg(),
            data_dir,
            ..Default::default()
        }) {
            Ok(s) => Some(s),
            Err(e) => {
                eprintln!("skipping: cannot bind loopback ({e})");
                None
            }
        }
    }

    #[test]
    fn end_to_end_roundtrip_matches_in_process_store() {
        let Some(server) = start_server(None) else { return };
        let mut client = StoreClient::connect(server.local_addr()).unwrap();
        let oracle = ShardedStore::new(test_cfg());
        let mut rng = Pcg64::new(7);
        let mut batch = Vec::new();
        for _ in 0..300 {
            let (i, j) = (rng.gen_range(64) as usize, rng.gen_range(64) as usize);
            let w = (1 + rng.gen_range(9)) as f64;
            oracle.update(i, j, w);
            batch.push((i as u32, j as u32, w));
        }
        // half singly, half batched
        for &(i, j, w) in &batch[..150] {
            client.update(i as usize, j as usize, w).unwrap();
        }
        client.update_batch(&batch[150..]).unwrap();
        for _ in 0..100 {
            let (i, j) = (rng.gen_range(64) as usize, rng.gen_range(64) as usize);
            let got = client.query(i, j).unwrap();
            assert_eq!(got.to_bits(), oracle.point_query(i, j).to_bits(), "key ({i}, {j})");
        }
        let stats = client.stats().unwrap();
        assert_eq!(stats.updates, 300);
        assert_eq!(stats.shards, 4);
        let top = client.top_k(5).unwrap();
        let want = oracle.top_k(5);
        assert_eq!(top.len(), want.len());
        for (g, w) in top.iter().zip(want.iter()) {
            assert_eq!((g.0, g.1), (w.0, w.1));
            assert_eq!(g.2.to_bits(), w.2.to_bits());
        }
        server.shutdown();
    }

    #[test]
    fn merge_and_epoch_over_the_wire() {
        let Some(server) = start_server(None) else { return };
        let mut client = StoreClient::connect(server.local_addr()).unwrap();
        client.update(3, 7, 2.0).unwrap();
        let mut remote = test_cfg().fresh_sketch();
        remote.update(3, 7, 5.0);
        client.merge(&remote).unwrap();
        assert_eq!(client.query(3, 7).unwrap(), 7.0);
        // wrong-family merges surface as server errors, not hangups
        let alien = StreamSketch::new(64, 64, 16, 16, 5, 4321);
        let err = client.merge(&alien).unwrap_err().to_string();
        assert!(err.contains("family"), "unexpected error: {err}");
        // window = 4: four advances expire everything
        for _ in 0..4 {
            client.advance_epoch().unwrap();
        }
        assert_eq!(client.query(3, 7).unwrap(), 0.0);
        assert_eq!(client.stats().unwrap().epoch, 4);
        server.shutdown();
    }

    #[test]
    fn protocol_errors_keep_connection_alive() {
        let Some(server) = start_server(None) else { return };
        let mut client = StoreClient::connect(server.local_addr()).unwrap();
        // out-of-range key
        assert!(client.update(1 << 20, 0, 1.0).is_err());
        // non-finite weights are rejected before they can poison a scan
        assert!(client.update(1, 1, f64::NAN).is_err());
        assert!(client.update_batch(&[(1, 1, 1.0), (2, 2, f64::INFINITY)]).is_err());
        // all-or-nothing batch: the valid first item must not have landed
        assert_eq!(client.query(1, 1).unwrap(), 0.0);
        // unknown opcode straight through the framing
        let err = client.raw_call(&[250]).unwrap_err().to_string();
        assert!(err.contains("opcode"), "unexpected error: {err}");
        // snapshot without a data dir
        assert!(client.snapshot().is_err());
        // batch sketch without a coordinator
        assert!(client.batch_sketch(&[1.0f32; 4]).is_err());
        // connection still serves after all of those
        client.update(1, 1, 1.0).unwrap();
        assert_eq!(client.query(1, 1).unwrap(), 1.0);
        server.shutdown();
    }

    /// Regression guard for the `no-panic-paths` lint findings: hostile
    /// or truncated frames through every formerly-panicking dispatch
    /// path must come back as framed errors on a connection that keeps
    /// serving — a bad frame must never kill the connection thread.
    #[test]
    fn hostile_frames_never_kill_the_connection_thread() {
        let Some(server) = start_server(None) else { return };
        let mut client = StoreClient::connect(server.local_addr()).unwrap();
        // empty frame: no opcode byte at all
        assert!(client.raw_call(&[]).is_err());
        // truncated bodies across the dispatch surface
        for opc in [
            op::UPDATE,
            op::UPDATE_BATCH,
            op::QUERY,
            op::MERGE,
            op::MERGE_ORIGIN,
            op::TCREATE,
            op::TUPDATE,
            op::MARGINAL,
            op::SLICE_TOPK,
            op::CONTRACT,
            op::TMERGE_ORIGIN,
        ] {
            assert!(client.raw_call(&[opc]).is_err(), "opcode {opc} accepted an empty body");
        }
        // a hostile batch count far past the cap must be rejected before
        // any decode or allocation
        let mut huge = vec![op::UPDATE_BATCH];
        huge.extend_from_slice(&u32::MAX.to_le_bytes());
        let err = client.raw_call(&huge).unwrap_err().to_string();
        assert!(err.contains("exceeds cap"), "unexpected error: {err}");
        // slice top-k past the response cap errors instead of building it
        let mut req = vec![op::SLICE_TOPK];
        req.extend_from_slice(&4u32.to_le_bytes()); // name length
        req.extend_from_slice(b"tttt"); // unknown tensor — also an error path
        assert!(client.raw_call(&req).is_err());
        // the connection thread survived every one of those
        client.update(2, 2, 4.0).unwrap();
        assert_eq!(client.query(2, 2).unwrap(), 4.0);
        server.shutdown();
    }

    #[test]
    fn retried_origin_merge_is_a_no_op() {
        // the MERGE replay-protection regression test: an identical
        // re-delivered origin-headered frame must not double-count,
        // while legacy headerless MERGE keeps its additive semantics
        let Some(server) = start_server(None) else { return };
        let mut client = StoreClient::connect(server.local_addr()).unwrap();
        let mut sk = test_cfg().fresh_sketch();
        sk.update(3, 7, 5.0);
        assert!(client.merge_origin(0xE0, 1, false, true, &sk).unwrap(), "first frame applies");
        // identical retry (same origin, same seq): acknowledged no-op
        assert!(!client.merge_origin(0xE0, 1, false, true, &sk).unwrap(), "retry re-applied");
        assert_eq!(client.query(3, 7).unwrap(), 5.0, "retried frame double-counted");
        // a second connection retrying the same frame is deduped too
        let mut other = StoreClient::connect(server.local_addr()).unwrap();
        assert!(!other.merge_origin(0xE0, 1, false, true, &sk).unwrap());
        assert_eq!(client.query(3, 7).unwrap(), 5.0);
        // the dedup is observable in STATS
        let (_, repl) = client.stats_full().unwrap();
        let repl = repl.expect("replication stats present");
        assert_eq!(repl.merges_applied, 1);
        assert_eq!(repl.merges_deduped, 2);
        // legacy headerless MERGE still round-trips (and still adds)
        client.merge(&sk).unwrap();
        assert_eq!(client.query(3, 7).unwrap(), 10.0);
        server.shutdown();
    }

    #[test]
    fn origin_sequence_gaps_error_and_full_ships_heal() {
        let Some(server) = start_server(None) else { return };
        let mut client = StoreClient::connect(server.local_addr()).unwrap();
        let mut d1 = test_cfg().fresh_sketch();
        d1.update(1, 1, 2.0);
        assert!(client.merge_origin(0xF1, 1, false, false, &d1).unwrap());
        // a skipped delta sequence is rejected with the gap marker
        let err = client.merge_origin(0xF1, 3, false, false, &d1).unwrap_err().to_string();
        assert!(err.contains("origin sequence gap"), "unexpected error: {err}");
        assert_eq!(client.query(1, 1).unwrap(), 2.0, "rejected frame was applied");
        // a full-state ship at any sequence heals the channel: only the
        // unseen remainder lands
        let mut full = test_cfg().fresh_sketch();
        full.update(1, 1, 2.0); // already delivered via d1
        full.update(2, 2, 4.0); // new
        assert!(client.merge_origin(0xF1, 9, true, false, &full).unwrap());
        assert_eq!(client.query(1, 1).unwrap(), 2.0, "full ship double-counted");
        assert_eq!(client.query(2, 2).unwrap(), 4.0);
        // and the channel continues with deltas after the full
        let mut d2 = test_cfg().fresh_sketch();
        d2.update(5, 5, 1.0);
        assert!(client.merge_origin(0xF1, 10, false, false, &d2).unwrap());
        assert_eq!(client.query(5, 5).unwrap(), 1.0);
        server.shutdown();
    }

    #[test]
    fn shutdown_rpc_stops_the_server() {
        let Some(server) = start_server(None) else { return };
        let addr = server.local_addr();
        let mut client = StoreClient::connect(addr).unwrap();
        client.update(1, 2, 3.0).unwrap();
        client.shutdown_server().unwrap();
        // wait() returns because the accept loop observed the stop flag
        server.wait();
        // new connections are no longer served: either refused outright
        // or accepted-then-ignored by the dead loop; a query must fail
        let failed = match StoreClient::connect(addr) {
            Ok(mut c2) => c2.query(1, 2).is_err(),
            Err(_) => true,
        };
        assert!(failed, "server still answering after shutdown");
    }

    #[test]
    fn over_limit_connections_are_rejected_gracefully() {
        let server = match StoreServer::start(StoreServerConfig {
            addr: "127.0.0.1:0".to_string(),
            store: test_cfg(),
            max_connections: 1,
            ..Default::default()
        }) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("skipping: cannot bind loopback ({e})");
                return;
            }
        };
        let mut first = StoreClient::connect(server.local_addr()).unwrap();
        first.update(1, 1, 1.0).unwrap(); // admission observed: RPC served
        // the second connection is over the bound: it must fail fast
        // with a readable reason, not hang or get a silent RST
        let mut second = StoreClient::connect(server.local_addr()).unwrap();
        let err = second.query(1, 1).unwrap_err().to_string();
        assert!(err.contains("connection limit"), "unexpected rejection: {err}");
        // releasing the first slot re-admits new connections
        drop(first);
        drop(second);
        let mut served = false;
        for _ in 0..200 {
            if let Ok(mut c) = StoreClient::connect(server.local_addr()) {
                if let Ok(v) = c.query(1, 1) {
                    assert_eq!(v, 1.0);
                    served = true;
                    break;
                }
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(served, "slot never released after disconnect");
        server.shutdown();
    }

    #[test]
    fn idle_connections_time_out_but_fast_clients_are_served() {
        let server = match StoreServer::start(StoreServerConfig {
            addr: "127.0.0.1:0".to_string(),
            store: test_cfg(),
            read_timeout_ms: 50,
            ..Default::default()
        }) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("skipping: cannot bind loopback ({e})");
                return;
            }
        };
        let mut slow = StoreClient::connect(server.local_addr()).unwrap();
        slow.update(2, 2, 2.0).unwrap();
        // a half-open/slowloris peer: sends nothing past the timeout and
        // finds its connection closed. UPDATE (never retried — not
        // idempotent) observes the dead channel directly, where an
        // idempotent call would mask it behind the client's
        // reconnect-and-retry.
        std::thread::sleep(std::time::Duration::from_millis(250));
        assert!(
            slow.update(2, 2, 1.0).is_err(),
            "idle connection survived the read timeout"
        );
        // prompt clients on fresh connections are unaffected
        let mut fast = StoreClient::connect(server.local_addr()).unwrap();
        assert_eq!(fast.query(2, 2).unwrap(), 2.0);
        server.shutdown();
    }

    #[test]
    fn shutdown_answers_in_flight_then_drains_connections() {
        let Some(server) = start_server(None) else { return };
        let mut ctl = StoreClient::connect(server.local_addr()).unwrap();
        let mut other = StoreClient::connect(server.local_addr()).unwrap();
        other.update(4, 4, 4.0).unwrap();
        ctl.shutdown_server().unwrap();
        // `other` was idle when SHUTDOWN landed; its next request may
        // still be answered (drain finishes work in flight) but the
        // connection must then close instead of serving forever
        let mut closed = false;
        for _ in 0..50 {
            if other.query(4, 4).is_err() {
                closed = true;
                break;
            }
        }
        assert!(closed, "connection kept being served after shutdown");
        server.wait();
    }

    fn test_tfam() -> TensorFamily {
        TensorFamily { dims: vec![20, 16, 12], sketch_dims: vec![6, 5, 4], d: 3, seed: 42 }
    }

    #[test]
    fn tensor_rpcs_roundtrip_against_in_process_oracle() {
        let Some(server) = start_server(None) else { return };
        let mut client = StoreClient::connect(server.local_addr()).unwrap();
        let oracle = ShardedStore::new(test_cfg());
        oracle.tensor_create("act", &test_tfam()).unwrap();
        assert!(client.tensor_create("act", &test_tfam()).unwrap());
        assert!(!client.tensor_create("act", &test_tfam()).unwrap(), "re-create not a no-op");
        let mut other = test_tfam();
        other.d = 5;
        let err = client.tensor_create("act", &other).unwrap_err().to_string();
        assert!(err.contains("family"), "unexpected error: {err}");

        let mut rng = Pcg64::new(11);
        let mut keys = Vec::new();
        let mut ws = Vec::new();
        for _ in 0..120 {
            let key = [
                rng.gen_range(20) as usize,
                rng.gen_range(16) as usize,
                rng.gen_range(12) as usize,
            ];
            let w = (1 + rng.gen_range(9)) as f64;
            keys.extend_from_slice(&key);
            ws.push(w);
        }
        // half singly, half batched
        for (key, &w) in keys.chunks_exact(3).zip(ws.iter()).take(60) {
            client.tensor_update("act", key, w).unwrap();
        }
        client.tensor_update_batch("act", &keys[180..], &ws[60..]).unwrap();
        oracle.tensor_update_batch("act", &keys, &ws).unwrap();

        for _ in 0..60 {
            let key = [
                rng.gen_range(20) as usize,
                rng.gen_range(16) as usize,
                rng.gen_range(12) as usize,
            ];
            assert_eq!(
                client.tensor_query("act", &key).unwrap().to_bits(),
                oracle.tensor_query("act", &key).unwrap().to_bits(),
                "key {key:?}"
            );
        }
        let spec = [Some(3), None, None];
        assert_eq!(
            client.tensor_marginal("act", &spec).unwrap().to_bits(),
            oracle.tensor_marginal("act", &spec).unwrap().to_bits()
        );
        let got = client.tensor_slice_topk("act", 0, 3, 5).unwrap();
        let want = oracle.tensor_slice_top_k("act", 0, 3, 5).unwrap();
        assert_eq!(got.len(), want.len());
        for ((gk, gw), (wk, ww)) in got.iter().zip(want.iter()) {
            assert_eq!(gk, wk);
            assert_eq!(gw.to_bits(), ww.to_bits());
        }
        server.shutdown();
    }

    #[test]
    fn tensor_contract_over_the_wire_matches_local() {
        let Some(server) = start_server(None) else { return };
        let mut client = StoreClient::connect(server.local_addr()).unwrap();
        client.tensor_create("a", &test_tfam()).unwrap();
        client.tensor_create("b", &test_tfam()).unwrap();
        let mut la = test_tfam().fresh();
        let mut lb = test_tfam().fresh();
        let mut rng = Pcg64::new(13);
        for _ in 0..40 {
            let key = [
                rng.gen_range(20) as usize,
                rng.gen_range(16) as usize,
                rng.gen_range(12) as usize,
            ];
            let w = (1 + rng.gen_range(9)) as f64;
            client.tensor_update("a", &key, w).unwrap();
            la.update(&key, w);
            let key2 = [
                rng.gen_range(20) as usize,
                rng.gen_range(16) as usize,
                rng.gen_range(12) as usize,
            ];
            client.tensor_update("b", &key2, w).unwrap();
            lb.update(&key2, w);
        }
        // full contraction: scalar, bit-identical to the local result
        match client.tensor_contract("a", "b", &[0, 1, 2], false).unwrap() {
            crate::store::TensorContraction::Scalar(v) => {
                assert_eq!(
                    v.to_bits(),
                    crate::store::tensor::contract_scalar(&la, &lb).to_bits()
                );
            }
            other => panic!("expected scalar, got {other:?}"),
        }
        // partial contraction: sketch result queryable client-side
        let local = match crate::store::tensor::contract(&la, &lb, &[1, 2]).unwrap() {
            ContractOutput::Sketch(cs) => cs,
            ContractOutput::Scalar(_) => unreachable!(),
        };
        match client.tensor_contract("a", "b", &[1, 2], false).unwrap() {
            crate::store::TensorContraction::Sketch(cs) => {
                assert_eq!(
                    cs.query(&[3], &[7]).to_bits(),
                    local.query(&[3], &[7]).to_bits()
                );
            }
            other => panic!("expected sketch, got {other:?}"),
        }
        // dense expansion matches the local densification
        let (ldims, lvals) = local.to_dense().unwrap();
        match client.tensor_contract("a", "b", &[1, 2], true).unwrap() {
            crate::store::TensorContraction::Dense { dims, values } => {
                assert_eq!(dims, ldims);
                assert_eq!(values.len(), lvals.len());
                for (a, b) in values.iter().zip(lvals.iter()) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("expected dense, got {other:?}"),
        }
        // unknown tensors / bad modes are framed errors
        assert!(client.tensor_contract("a", "ghost", &[0], false).is_err());
        assert!(client.tensor_contract("a", "b", &[9], false).is_err());
        server.shutdown();
    }

    #[test]
    fn retried_tensor_origin_merge_is_a_no_op_and_auto_creates() {
        let Some(server) = start_server(None) else { return };
        let mut client = StoreClient::connect(server.local_addr()).unwrap();
        let mut full = test_tfam().fresh();
        full.update(&[1, 2, 3], 5.0);
        // the receiver has never heard of "act": the frame's family
        // auto-creates it (replicas learn tensors from their peers)
        assert!(client.tensor_merge_origin(0xAB, 1, "act", &full).unwrap());
        assert!(!client.tensor_merge_origin(0xAB, 1, "act", &full).unwrap(), "retry applied");
        assert_eq!(
            client.tensor_query("act", &[1, 2, 3]).unwrap().to_bits(),
            full.query(&[1, 2, 3]).to_bits(),
            "retried frame double-counted"
        );
        // a later full ship lands only the remainder
        full.update(&[4, 5, 6], 2.0);
        assert!(client.tensor_merge_origin(0xAB, 2, "act", &full).unwrap());
        assert_eq!(
            client.tensor_query("act", &[4, 5, 6]).unwrap().to_bits(),
            full.query(&[4, 5, 6]).to_bits()
        );
        assert_eq!(
            client.tensor_query("act", &[1, 2, 3]).unwrap().to_bits(),
            full.query(&[1, 2, 3]).to_bits(),
            "full ship double-counted earlier mass"
        );
        server.shutdown();
    }

    #[test]
    fn durable_server_survives_restart() {
        let dir = std::env::temp_dir()
            .join(format!("hocs_store_srv_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let dirs = dir.to_string_lossy().to_string();
        {
            let Some(server) = start_server(Some(dirs.clone())) else { return };
            let mut client = StoreClient::connect(server.local_addr()).unwrap();
            client.update(10, 20, 6.0).unwrap();
            client.snapshot().unwrap();
            client.update(11, 21, 4.0).unwrap(); // only in the WAL
            // a batch after the snapshot: one group-commit WAL frame
            client.update_batch(&[(12, 22, 2.0), (12, 22, 1.5)]).unwrap();
            server.shutdown();
        }
        {
            let Some(server) = start_server(Some(dirs)) else { return };
            let mut client = StoreClient::connect(server.local_addr()).unwrap();
            assert_eq!(client.query(10, 20).unwrap(), 6.0);
            assert_eq!(client.query(11, 21).unwrap(), 4.0);
            assert_eq!(client.query(12, 22).unwrap(), 3.5, "batched WAL frame lost");
            server.shutdown();
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
