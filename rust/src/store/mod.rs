//! Sharded, mergeable, durable sketch store — the serving layer over
//! the paper's streaming application.
//!
//! Count sketches are *linear* in the update stream, so sketches of
//! disjoint substreams combine by elementwise addition with zero
//! accuracy loss. The whole subsystem is that one identity applied
//! three ways:
//!
//! - **scale-out** — [`ShardedStore`] routes each key to one of K
//!   shards (one lock domain each); point queries fan out and sum
//!   per-repeat estimates, scans merge shard totals into one sketch.
//!   Estimates are bit-identical to an unsharded sketch fed the same
//!   stream (see `rust/tests/store.rs`).
//! - **sliding windows** — every shard keeps a ring of per-epoch
//!   sketches; expiring an epoch *subtracts* its sketch from the
//!   running total. No rescan, no approximation on top of the sketch's
//!   own.
//! - **federation** — the MERGE RPC accepts any serialized same-family
//!   sketch ([`MergeableSketch::encode`]), so edge nodes can sketch
//!   locally and ship summaries instead of raw streams;
//! - **replication** — nodes with configured peers run an anti-entropy
//!   replicator ([`replica`]) that ships each node's locally-originated
//!   mass to its peers: per-peer *delta cursors* (sketch subtraction
//!   against the last acknowledged origin snapshot — exact, linearity
//!   again) keep steady-state traffic to the sparse-encoded new mass
//!   instead of full `merged()` images, and the origin-headered MERGE
//!   with a per-origin sequence dedup window makes re-delivery a no-op
//!   (addition alone is not idempotent). Replicas converge to the
//!   sketch of the union stream without consensus.
//!
//! Durability is a versioned binary snapshot plus an append-only WAL of
//! length-prefixed CRC-32-checked frames ([`DurableStore`]); recovery
//! replays the WAL tail onto the snapshot and tolerates torn tails.
//! Batched writes **group-commit**: the whole batch is one WAL frame
//! (one flush — one `sync_data` with fsync on) and one shard-grouped
//! in-memory apply through the fused multi-key sketch kernel; on top of
//! that, *concurrent un-batched* writers coalesce through a
//! leader/follower commit queue (one group write + flush/sync for every
//! staged frame — see [`wal`]), and no log lock is held across the
//! in-memory apply, so writers on different shards run concurrently.
//! Scans serve from [`sharded`]'s version-stamped cache (incremental
//! pending-delta folds instead of per-call K-way re-merges). The
//! front-end ([`StoreServer`]) speaks a framed TCP protocol (UPDATE /
//! UPDATE_BATCH / QUERY / TOPK / HEAVY / MERGE / SNAPSHOT /
//! ADVANCE_EPOCH / STATS / BATCH_SKETCH / SHUTDOWN) with a thread per
//! connection — its request loop reuses per-connection buffers and
//! thread-local scratch, allocating nothing per request once warm — and
//! can reuse the PR-1 coordinator worker pool for batch sketch jobs.
//!
//! The **tensor plane** ([`tensor`]) lifts all of this to multi-mode
//! keys: a named catalog of Higher-order Count Sketches (one small hash
//! pair per mode — the paper's exponential hash-state saving) served
//! through TCREATE / TUPDATE / TUPDATE_BATCH / TQUERY / MARGINAL /
//! SLICE_TOPK / CONTRACT, durable behind the same snapshot+WAL, and
//! replicated by idempotent full-ship origin frames (HCS is linear too,
//! so the remainder rule `full − received` applies exactly the unseen
//! mass).
//!
//! Module map: [`mergeable`] (the trait + impls), [`sharded`] (shards +
//! epoch rings), [`tensor`] (the HCS tensor plane: sketches, catalog,
//! contraction), [`wal`] (snapshot/WAL), [`server`]/[`client`] (wire),
//! [`wire_ops`] (the opcode table — single source of truth for the
//! protocol surface), [`replica`] (anti-entropy replication: delta
//! cursors, origin dedup, the replicator thread), [`codec`] (bytes +
//! CRC-32), [`faults`] (the deterministic fault-injection plane +
//! scripted crash workload; compiles to no-ops in release builds),
//! [`lockdep`] (debug-build lock-order checker).
//!
//! **Lock ordering.** The store's cross-thread locks form a fixed
//! hierarchy — tensor DDL mutex, then commit gate, then scan cache,
//! then WAL commit queue, then shard mutexes in ascending index order,
//! then the tensor registry. [`lockdep`] is the machine-checked
//! contract: every acquisition of those locks registers with a
//! debug-build checker that panics on any cross-thread ordering cycle
//! or out-of-index-order shard acquisition, so the whole test suite
//! (and the crash matrix, which runs debug children) continuously
//! proves the hierarchy. See the `lockdep` module docs for the full
//! class DAG and the one documented exclusion (the origin-table and
//! replica-cursor mutexes, which are serialized by the commit gate).

pub mod client;
pub mod codec;
pub mod faults;
pub mod lockdep;
pub mod mergeable;
pub mod replica;
pub mod server;
pub mod sharded;
pub mod tensor;
pub mod wal;
pub mod wire_ops;

/// One shared cap on a batch of updates, enforced in lockstep at the
/// RPC boundary ([`server`]), at the durable API
/// ([`DurableStore::update_batch`] — so an acknowledged batch can never
/// exceed it), and at WAL decode (so recovery never refuses a frame the
/// write path accepted; a drift between those two silently drops
/// acknowledged data).
pub(crate) const MAX_UPDATE_BATCH: usize = 1 << 20;

pub use client::{ClientOptions, StoreClient, TensorContraction};
pub use mergeable::MergeableSketch;
pub use replica::{ReplicaConfig, ReplicationStats, Replicator};
pub use server::{StoreServer, StoreServerConfig};
pub use sharded::{ShardedStore, StoreConfig, StoreStats};
pub use tensor::{ContractOutput, ContractedSketch, HcsStream, TensorFamily};
pub use wal::{DurableOptions, DurableStore};
