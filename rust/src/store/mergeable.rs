//! [`MergeableSketch`] — the algebraic contract the store is built on.
//!
//! Count-sketch-style summaries are *linear* maps of the update stream
//! (the same linearity the paper's compositional operations exploit),
//! so summaries of disjoint substreams combine by elementwise addition
//! with **zero** accuracy loss: `Sketch(A ⊎ B) = Sketch(A) + Sketch(B)`
//! whenever both sides share the hash family. That one identity buys
//! the whole store design: shards merge, replicas anti-entropy by
//! addition, sliding windows expire by *subtracting* the sketch of
//! the expired epoch, and the scan plane's cached merged sketch stays
//! fresh by folding in small per-shard *delta* sketches instead of
//! re-merging every shard per query (`cache + Σ deltas ≡ re-merge`,
//! see [`crate::store::sharded`]).
//!
//! Implementations:
//! - `Vec<f64>` — a flat count-sketch table ([`crate::sketch::cs::CsSketcher`]
//!   output);
//! - [`Tensor`] — an MTS/HCS table ([`crate::sketch::mts::MtsSketcher`]
//!   output);
//! - [`StreamSketch`] — the d-repeat streaming sketch the store shards.
//!
//! `encode`/`decode` is the shared binary form used by snapshots, the
//! WAL, and the MERGE RPC; floats travel as bit patterns, so a decode
//! is bit-identical to what was encoded.

use super::codec::{self, Reader};
use crate::sketch::stream::StreamSketch;
use crate::tensor::Tensor;
use anyhow::{ensure, Result};

/// Upper bound on decoded table sizes (elements). A corrupt or hostile
/// frame must not be able to request an arbitrarily large allocation.
/// Shared with the sparse replica codec ([`crate::store::replica::wire`])
/// so the dense and sparse decoders can never drift on what they accept.
pub(crate) const MAX_DECODE_ELEMS: usize = 1 << 28;

/// A linear sketch that merges by addition. See the module docs for why
/// these three operations are exact.
pub trait MergeableSketch: Sized {
    /// True when the two summaries share geometry (and hash family,
    /// where the type carries one) — the precondition for `merge_from`.
    fn mergeable_with(&self, other: &Self) -> bool;

    /// `self += other`: afterwards `self` is exactly the summary of the
    /// two input streams concatenated.
    fn merge_from(&mut self, other: &Self) -> Result<()>;

    /// `self *= a` — decay weighting, or subtraction when composed as
    /// `scale_by(-1)` + `merge_from`.
    fn scale_by(&mut self, a: f64);

    /// Append the binary encoding to `out`.
    fn encode(&self, out: &mut Vec<u8>);

    /// Decode one value from the reader (bit-exact inverse of `encode`).
    fn decode(rd: &mut Reader<'_>) -> Result<Self>;
}

// ---------- flat count-sketch tables ----------

impl MergeableSketch for Vec<f64> {
    fn mergeable_with(&self, other: &Self) -> bool {
        self.len() == other.len()
    }

    fn merge_from(&mut self, other: &Self) -> Result<()> {
        ensure!(
            self.mergeable_with(other),
            "cannot merge sketch tables of lengths {} and {}",
            self.len(),
            other.len()
        );
        for (x, y) in self.iter_mut().zip(other.iter()) {
            *x += *y;
        }
        Ok(())
    }

    fn scale_by(&mut self, a: f64) {
        for x in self.iter_mut() {
            *x *= a;
        }
    }

    fn encode(&self, out: &mut Vec<u8>) {
        codec::put_u32(out, u32::try_from(self.len()).expect("table too large to encode"));
        for &v in self {
            codec::put_f64(out, v);
        }
    }

    fn decode(rd: &mut Reader<'_>) -> Result<Self> {
        let n = rd.u32()? as usize;
        ensure!(n <= MAX_DECODE_ELEMS, "table length {n} exceeds decode cap");
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(rd.f64()?);
        }
        Ok(v)
    }
}

// ---------- MTS/HCS tables ----------

impl MergeableSketch for Tensor {
    fn mergeable_with(&self, other: &Self) -> bool {
        self.dims() == other.dims()
    }

    fn merge_from(&mut self, other: &Self) -> Result<()> {
        ensure!(
            self.mergeable_with(other),
            "cannot merge MTS tables of shapes {:?} and {:?}",
            self.dims(),
            other.dims()
        );
        for (x, y) in self.data_mut().iter_mut().zip(other.data().iter()) {
            *x += *y;
        }
        Ok(())
    }

    fn scale_by(&mut self, a: f64) {
        for x in self.data_mut() {
            *x *= a;
        }
    }

    fn encode(&self, out: &mut Vec<u8>) {
        codec::put_u32(out, u32::try_from(self.order()).expect("order too large"));
        for &d in self.dims() {
            codec::put_u32(out, u32::try_from(d).expect("dim too large to encode"));
        }
        for &v in self.data() {
            codec::put_f64(out, v);
        }
    }

    fn decode(rd: &mut Reader<'_>) -> Result<Self> {
        let order = rd.u32()? as usize;
        ensure!(order <= 16, "tensor order {order} exceeds decode cap");
        let mut dims = Vec::with_capacity(order);
        for _ in 0..order {
            dims.push(rd.u32()? as usize);
        }
        let n: usize = dims.iter().product();
        ensure!(n <= MAX_DECODE_ELEMS, "tensor with {n} elements exceeds decode cap");
        let mut data = Vec::with_capacity(n);
        for _ in 0..n {
            data.push(rd.f64()?);
        }
        Ok(Tensor::from_vec(data, &dims))
    }
}

// ---------- streaming sketches ----------

impl MergeableSketch for StreamSketch {
    fn mergeable_with(&self, other: &Self) -> bool {
        self.same_family(other)
    }

    fn merge_from(&mut self, other: &Self) -> Result<()> {
        ensure!(
            self.mergeable_with(other),
            "cannot merge stream sketches from different geometries/hash families"
        );
        self.merge_scaled(other, 1.0);
        Ok(())
    }

    fn scale_by(&mut self, a: f64) {
        self.scale_tables(a);
    }

    /// Only the counters and identity are written; the hash families are
    /// rebuilt from the seed on decode (they are pure functions of it),
    /// which keeps snapshots ~d·m1·m2 floats instead of shipping tables
    /// of hashes. A one-byte flags field carries
    /// [`StreamSketch::has_deletions`] so remote merges and recovered
    /// snapshots keep routing turnstile scans correctly.
    fn encode(&self, out: &mut Vec<u8>) {
        for v in [self.n1, self.n2, self.m1, self.m2, self.d] {
            codec::put_u32(out, u32::try_from(v).expect("sketch dim too large to encode"));
        }
        codec::put_u64(out, self.seed);
        codec::put_u64(out, self.updates);
        codec::put_u8(out, u8::from(self.has_deletions));
        for r in 0..self.d {
            for &v in self.table(r) {
                codec::put_f64(out, v);
            }
        }
    }

    fn decode(rd: &mut Reader<'_>) -> Result<Self> {
        let n1 = rd.u32()? as usize;
        let n2 = rd.u32()? as usize;
        let m1 = rd.u32()? as usize;
        let m2 = rd.u32()? as usize;
        let d = rd.u32()? as usize;
        ensure!(
            n1 > 0 && n2 > 0 && m1 > 0 && m2 > 0 && d >= 1,
            "corrupt stream-sketch header ({n1}x{n2} -> {m1}x{m2}, d={d})"
        );
        ensure!(
            m1.saturating_mul(m2).saturating_mul(d) <= MAX_DECODE_ELEMS,
            "stream sketch of {d}x{m1}x{m2} counters exceeds decode cap"
        );
        let seed = rd.u64()?;
        let updates = rd.u64()?;
        let flags = rd.u8()?;
        ensure!(flags <= 1, "corrupt stream-sketch flags byte {flags}");
        let mut sk = StreamSketch::new(n1, n2, m1, m2, d, seed);
        for r in 0..d {
            for x in sk.table_mut(r).iter_mut() {
                *x = rd.f64()?;
            }
        }
        sk.updates = updates;
        sk.has_deletions = flags == 1;
        Ok(sk)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::sketch::cs::CsSketcher;
    use crate::sketch::mts::MtsSketcher;

    #[test]
    fn cs_tables_merge_like_concatenated_streams() {
        let cs = CsSketcher::new(64, 16, 3);
        let mut rng = Pcg64::new(1);
        let x = rng.normal_vec(64);
        let y = rng.normal_vec(64);
        let whole: Vec<f64> = x.iter().zip(y.iter()).map(|(a, b)| a + b).collect();
        let mut sx = cs.sketch(&x);
        let sy = cs.sketch(&y);
        sx.merge_from(&sy).unwrap();
        let direct = cs.sketch(&whole);
        for (a, b) in sx.iter().zip(direct.iter()) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn mts_tables_merge_like_concatenated_streams() {
        let sk = MtsSketcher::new(&[12, 10], &[5, 4], 7);
        let mut rng = Pcg64::new(2);
        let x = Tensor::randn(&[12, 10], &mut rng);
        let y = Tensor::randn(&[12, 10], &mut rng);
        let mut sx = sk.sketch(&x);
        sx.merge_from(&sk.sketch(&y)).unwrap();
        let direct = sk.sketch(&x.add(&y));
        for (a, b) in sx.data().iter().zip(direct.data().iter()) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn scale_by_scales_estimates() {
        let cs = CsSketcher::new(32, 8, 5);
        let mut x = vec![0.0; 32];
        x[9] = 2.0;
        let mut y = cs.sketch(&x);
        y.scale_by(3.0);
        assert!((cs.estimate(&y, 9) - 6.0).abs() < 1e-12);
    }

    #[test]
    fn mismatched_merges_error() {
        let mut a = vec![0.0; 4];
        assert!(a.merge_from(&vec![0.0; 5]).is_err());
        let mut t = Tensor::zeros(&[2, 3]);
        assert!(t.merge_from(&Tensor::zeros(&[3, 2])).is_err());
        let mut s = StreamSketch::new(8, 8, 4, 4, 3, 1);
        assert!(s.merge_from(&StreamSketch::new(8, 8, 4, 4, 3, 2)).is_err());
    }

    #[test]
    fn vec_roundtrips_bit_exact() {
        let mut rng = Pcg64::new(3);
        let v = rng.normal_vec(33);
        let mut out = Vec::new();
        v.encode(&mut out);
        let got = Vec::<f64>::decode(&mut Reader::new(&out)).unwrap();
        assert_eq!(v.len(), got.len());
        for (a, b) in v.iter().zip(got.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn tensor_roundtrips_bit_exact() {
        let mut rng = Pcg64::new(4);
        let t = Tensor::randn(&[3, 4, 5], &mut rng);
        let mut out = Vec::new();
        t.encode(&mut out);
        let got = Tensor::decode(&mut Reader::new(&out)).unwrap();
        assert_eq!(t.dims(), got.dims());
        for (a, b) in t.data().iter().zip(got.data().iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn stream_sketch_roundtrips_and_answers_identically() {
        let mut sk = StreamSketch::new(40, 30, 10, 8, 5, 99);
        let mut rng = Pcg64::new(5);
        for _ in 0..500 {
            sk.update(rng.gen_range(40) as usize, rng.gen_range(30) as usize, rng.normal());
        }
        let mut out = Vec::new();
        sk.encode(&mut out);
        let got = StreamSketch::decode(&mut Reader::new(&out)).unwrap();
        assert!(sk.same_family(&got));
        assert_eq!(sk.updates, got.updates);
        // normal() produced negative weights, so the turnstile flag is
        // set and must survive the roundtrip
        assert!(sk.has_deletions);
        assert_eq!(sk.has_deletions, got.has_deletions);
        for _ in 0..50 {
            let (i, j) = (rng.gen_range(40) as usize, rng.gen_range(30) as usize);
            assert_eq!(sk.query(i, j).to_bits(), got.query(i, j).to_bits());
        }
        // a clean non-negative sketch roundtrips flag-off
        let mut clean = StreamSketch::new(8, 8, 4, 4, 3, 7);
        clean.update(1, 1, 2.0);
        let mut out2 = Vec::new();
        clean.encode(&mut out2);
        assert!(!StreamSketch::decode(&mut Reader::new(&out2)).unwrap().has_deletions);
    }

    #[test]
    fn corrupt_stream_sketch_header_rejected() {
        let sk = StreamSketch::new(8, 8, 4, 4, 3, 1);
        let mut out = Vec::new();
        sk.encode(&mut out);
        // zero out d (bytes 16..20 of the header)
        out[16] = 0;
        out[17] = 0;
        out[18] = 0;
        out[19] = 0;
        assert!(StreamSketch::decode(&mut Reader::new(&out)).is_err());
        // truncated payload
        let mut out2 = Vec::new();
        sk.encode(&mut out2);
        out2.truncate(out2.len() - 1);
        assert!(StreamSketch::decode(&mut Reader::new(&out2)).is_err());
        // garbage flags byte — its offset is computed from the encoding
        // (one byte before the d·m1·m2 f64 tables) so a header change
        // moves the test with it
        let mut out3 = Vec::new();
        sk.encode(&mut out3);
        let flags_off = out3.len() - sk.d * sk.m1 * sk.m2 * 8 - 1;
        out3[flags_off] = 7;
        assert!(StreamSketch::decode(&mut Reader::new(&out3)).is_err());
    }
}
