//! Snapshot + write-ahead-log persistence for the sharded store.
//!
//! On-disk layout (one directory per store):
//!
//! ```text
//! snapshot.bin  = "HOCSSNAP" | u32 version | u64 generation | ShardedStore encoding
//! wal.bin       = "HOCSWAL0" | u32 version | u64 generation | frame*
//! frame         = u32 payload_len | u32 crc32(payload) | payload
//! payload       = u8 tag | fields           (see WalRecord)
//! ```
//!
//! Everything is little-endian (see [`super::codec`]). Writes append a
//! frame *before* mutating the in-memory store; recovery loads the
//! snapshot and replays frames until the first torn or CRC-failing one
//! (a crash mid-append leaves exactly such a tail). [`DurableStore::open`]
//! then immediately re-snapshots and truncates the WAL, so the torn
//! tail is healed rather than appended after.
//!
//! [`DurableStore::snapshot`] replaces `snapshot.bin` atomically
//! (tmp-file + rename) and truncates the WAL under the same log lock
//! that writers append under, so no record can fall between the
//! snapshot image and the fresh log.
//!
//! The **generation** stamp makes the rename → truncate pair safe: a
//! new snapshot (which already incorporates every logged record) is
//! written with generation g+1, and only then is the WAL recreated with
//! g+1. If a crash lands between the two, recovery sees a snapshot at
//! g+1 next to a WAL still at g and skips the replay — without the
//! stamp those records would be applied a second time.

use super::codec::{self, Reader};
use super::mergeable::MergeableSketch;
use super::sharded::{ShardedStore, StoreConfig, StoreStats};
use crate::sketch::stream::StreamSketch;
use anyhow::{bail, ensure, Context, Result};
use std::fs::{self, File, OpenOptions};
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

const SNAP_MAGIC: &[u8; 8] = b"HOCSSNAP";
const WAL_MAGIC: &[u8; 8] = b"HOCSWAL0";
const FORMAT_VERSION: u32 = 1;
/// magic + version + generation
const HEADER_LEN: usize = 20;

pub const SNAPSHOT_FILE: &str = "snapshot.bin";
pub const WAL_FILE: &str = "wal.bin";

/// One durable mutation. Queries never hit the log.
#[derive(Debug)]
pub enum WalRecord {
    Update { i: u32, j: u32, w: f64 },
    AdvanceEpoch,
    MergeSketch(StreamSketch),
}

const TAG_UPDATE: u8 = 1;
const TAG_ADVANCE: u8 = 2;
const TAG_MERGE: u8 = 3;

impl WalRecord {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            WalRecord::Update { i, j, w } => {
                codec::put_u8(out, TAG_UPDATE);
                codec::put_u32(out, *i);
                codec::put_u32(out, *j);
                codec::put_f64(out, *w);
            }
            WalRecord::AdvanceEpoch => codec::put_u8(out, TAG_ADVANCE),
            WalRecord::MergeSketch(sk) => {
                codec::put_u8(out, TAG_MERGE);
                sk.encode(out);
            }
        }
    }

    fn decode(rd: &mut Reader<'_>) -> Result<Self> {
        match rd.u8()? {
            TAG_UPDATE => Ok(WalRecord::Update { i: rd.u32()?, j: rd.u32()?, w: rd.f64()? }),
            TAG_ADVANCE => Ok(WalRecord::AdvanceEpoch),
            TAG_MERGE => Ok(WalRecord::MergeSketch(StreamSketch::decode(rd)?)),
            other => bail!("unknown WAL record tag {other}"),
        }
    }
}

/// Append-only frame writer.
struct WalWriter {
    file: File,
}

impl WalWriter {
    /// Create (truncating any previous log) and write the header,
    /// stamped with the generation of the snapshot it extends.
    fn create(path: &Path, generation: u64) -> Result<Self> {
        let mut file = File::create(path).with_context(|| format!("creating WAL {path:?}"))?;
        file.write_all(WAL_MAGIC)?;
        file.write_all(&FORMAT_VERSION.to_le_bytes())?;
        file.write_all(&generation.to_le_bytes())?;
        file.flush()?;
        Ok(Self { file })
    }

    fn append(&mut self, rec: &WalRecord) -> Result<()> {
        let mut payload = Vec::new();
        rec.encode(&mut payload);
        let mut frame = Vec::with_capacity(payload.len() + 8);
        codec::put_u32(&mut frame, u32::try_from(payload.len()).expect("WAL record too large"));
        codec::put_u32(&mut frame, codec::crc32(&payload));
        frame.extend_from_slice(&payload);
        self.file.write_all(&frame)?;
        self.file.flush()?;
        Ok(())
    }
}

/// Read the WAL's generation stamp and every intact record; stop
/// (without error) at the first torn or corrupt frame — that is the
/// crash-recovery contract.
fn read_wal(path: &Path) -> Result<(u64, Vec<WalRecord>)> {
    let bytes = fs::read(path).with_context(|| format!("reading WAL {path:?}"))?;
    ensure!(bytes.len() >= HEADER_LEN, "WAL shorter than its header");
    ensure!(&bytes[..8] == WAL_MAGIC, "bad WAL magic");
    let mut rd = Reader::new(&bytes[8..]);
    let version = rd.u32()?;
    ensure!(version == FORMAT_VERSION, "unsupported WAL version {version}");
    let generation = rd.u64()?;
    let mut out = Vec::new();
    loop {
        if rd.remaining() < 8 {
            break; // torn or absent frame header
        }
        let len = rd.u32()? as usize;
        let crc = rd.u32()?;
        if rd.remaining() < len {
            break; // torn payload
        }
        let payload = rd.take(len)?;
        if codec::crc32(payload) != crc {
            break; // corrupt frame
        }
        let mut prd = Reader::new(payload);
        match WalRecord::decode(&mut prd) {
            Ok(rec) => out.push(rec),
            Err(_) => break, // CRC passed but the record is garbage
        }
    }
    Ok((generation, out))
}

/// A [`ShardedStore`] with optional snapshot/WAL durability. All write
/// paths log first, then mutate; `log == None` is a purely in-memory
/// store with identical semantics and no I/O.
pub struct DurableStore {
    store: ShardedStore,
    log: Option<Mutex<WalWriter>>,
    dir: Option<PathBuf>,
    /// generation of the current snapshot + WAL pair; bumped by every
    /// snapshot (only ever touched under the log lock)
    generation: AtomicU64,
}

impl DurableStore {
    /// Purely in-memory store (no persistence; `snapshot()` errors).
    pub fn in_memory(cfg: StoreConfig) -> Self {
        Self {
            store: ShardedStore::new(cfg),
            log: None,
            dir: None,
            generation: AtomicU64::new(0),
        }
    }

    /// Open or create a durable store under `dir`: load the snapshot if
    /// one exists, replay the WAL tail onto it (only when the WAL's
    /// generation matches the snapshot's — a mismatch means a crash
    /// landed between snapshot rename and WAL truncation, and those
    /// records are already inside the snapshot), then write a fresh
    /// snapshot and truncate the WAL (healing any torn tail). An
    /// existing store must match `cfg` — silently changing sketch
    /// geometry would corrupt every merge invariant.
    pub fn open(dir: &Path, cfg: StoreConfig) -> Result<Self> {
        cfg.validate()?;
        fs::create_dir_all(dir).with_context(|| format!("creating store dir {dir:?}"))?;
        let snap_path = dir.join(SNAPSHOT_FILE);
        let wal_path = dir.join(WAL_FILE);

        let (store, snap_generation) = if snap_path.exists() {
            let bytes = fs::read(&snap_path).with_context(|| format!("reading {snap_path:?}"))?;
            ensure!(bytes.len() >= HEADER_LEN, "snapshot shorter than its header");
            ensure!(&bytes[..8] == SNAP_MAGIC, "bad snapshot magic");
            let mut rd = Reader::new(&bytes[8..]);
            let version = rd.u32()?;
            ensure!(version == FORMAT_VERSION, "unsupported snapshot version {version}");
            let generation = rd.u64()?;
            let store = ShardedStore::decode_from(&mut rd)?;
            ensure!(
                *store.config() == cfg,
                "on-disk store config {:?} does not match requested {cfg:?}",
                store.config()
            );
            (store, generation)
        } else {
            (ShardedStore::new(cfg), 0)
        };

        if wal_path.exists() {
            let (wal_generation, records) = read_wal(&wal_path)?;
            if wal_generation == snap_generation {
                crate::log_debug!("store: replaying {} WAL record(s)", records.len());
                for rec in &records {
                    apply(&store, rec)?;
                }
            } else {
                // crash between snapshot rename and WAL truncation: the
                // snapshot already contains these records
                crate::log_warn!(
                    "store: skipping WAL generation {wal_generation} (snapshot is at \
                     {snap_generation}) — records already applied"
                );
            }
        }

        let next_generation = snap_generation + 1;
        let mut ds = Self {
            store,
            log: None,
            dir: Some(dir.to_path_buf()),
            generation: AtomicU64::new(next_generation),
        };
        // snapshot the replayed state first (at the bumped generation),
        // then start a clean same-generation log: a crash between the
        // two leaves snapshot g+1 + WAL g, which the next open skips
        ds.write_snapshot_file()?;
        ds.log = Some(Mutex::new(WalWriter::create(&wal_path, next_generation)?));
        Ok(ds)
    }

    pub fn config(&self) -> &StoreConfig {
        self.store.config()
    }

    /// The wrapped in-memory store (tests / read-only access).
    pub fn store(&self) -> &ShardedStore {
        &self.store
    }

    /// Log (if durable) then apply one update.
    pub fn update(&self, i: usize, j: usize, w: f64) -> Result<()> {
        let cfg = self.store.config();
        ensure!(
            i < cfg.n1 && j < cfg.n2,
            "key ({i}, {j}) outside universe {}x{}",
            cfg.n1,
            cfg.n2
        );
        match &self.log {
            Some(log) => {
                // holding the log lock across the apply serializes the
                // WAL order with the store order (and with snapshots)
                let mut lw = log.lock().expect("wal lock");
                lw.append(&WalRecord::Update { i: i as u32, j: j as u32, w })?;
                self.store.update(i, j, w);
            }
            None => self.store.update(i, j, w),
        }
        Ok(())
    }

    pub fn advance_epoch(&self) -> Result<()> {
        match &self.log {
            Some(log) => {
                let mut lw = log.lock().expect("wal lock");
                lw.append(&WalRecord::AdvanceEpoch)?;
                self.store.advance_epoch();
            }
            None => self.store.advance_epoch(),
        }
        Ok(())
    }

    pub fn merge_sketch(&self, sk: &StreamSketch) -> Result<()> {
        ensure!(self.store.config().matches(sk), "sketch family does not match this store");
        match &self.log {
            Some(log) => {
                let mut lw = log.lock().expect("wal lock");
                lw.append(&WalRecord::MergeSketch(sk.clone()))?;
                self.store.merge_sketch(sk)
            }
            None => self.store.merge_sketch(sk),
        }
    }

    // -------- queries (never logged) --------

    pub fn point_query(&self, i: usize, j: usize) -> f64 {
        self.store.point_query(i, j)
    }

    pub fn top_k(&self, k: usize) -> Vec<(usize, usize, f64)> {
        self.store.top_k(k)
    }

    pub fn heavy_hitters(&self, threshold: f64) -> Vec<(usize, usize, f64)> {
        self.store.heavy_hitters(threshold)
    }

    pub fn merged(&self) -> StreamSketch {
        self.store.merged()
    }

    pub fn stats(&self) -> StoreStats {
        self.store.stats()
    }

    /// Write a fresh snapshot (bumping the generation) and truncate the
    /// WAL. Errors for in-memory stores.
    pub fn snapshot(&self) -> Result<()> {
        let Some(log) = &self.log else {
            bail!("in-memory store has no snapshot directory (start with a data dir)");
        };
        // the log lock blocks writers, so the snapshot image and the
        // truncated WAL describe the same instant
        let mut lw = log.lock().expect("wal lock");
        self.generation.fetch_add(1, Ordering::SeqCst);
        self.write_snapshot_file()?;
        let dir = self.dir.as_ref().expect("durable store has a dir");
        *lw = WalWriter::create(&dir.join(WAL_FILE), self.generation.load(Ordering::SeqCst))?;
        Ok(())
    }

    fn write_snapshot_file(&self) -> Result<()> {
        let Some(dir) = &self.dir else {
            bail!("in-memory store has no snapshot directory");
        };
        let mut out = Vec::new();
        out.extend_from_slice(SNAP_MAGIC);
        codec::put_u32(&mut out, FORMAT_VERSION);
        codec::put_u64(&mut out, self.generation.load(Ordering::SeqCst));
        self.store.encode_into(&mut out);
        let tmp = dir.join("snapshot.tmp");
        {
            let mut f = OpenOptions::new()
                .write(true)
                .create(true)
                .truncate(true)
                .open(&tmp)
                .with_context(|| format!("creating {tmp:?}"))?;
            f.write_all(&out)?;
            f.flush()?;
        }
        fs::rename(&tmp, dir.join(SNAPSHOT_FILE)).context("atomically replacing snapshot")?;
        Ok(())
    }
}

/// Replay one record onto the store, validating against the config so a
/// corrupt-but-CRC-clean record cannot panic the recovery path.
fn apply(store: &ShardedStore, rec: &WalRecord) -> Result<()> {
    let cfg = store.config();
    match rec {
        WalRecord::Update { i, j, w } => {
            let (i, j) = (*i as usize, *j as usize);
            ensure!(i < cfg.n1 && j < cfg.n2, "WAL update key ({i}, {j}) out of range");
            store.update(i, j, *w);
            Ok(())
        }
        WalRecord::AdvanceEpoch => {
            store.advance_epoch();
            Ok(())
        }
        WalRecord::MergeSketch(sk) => store.merge_sketch(sk),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn cfg() -> StoreConfig {
        StoreConfig { n1: 40, n2: 32, m1: 10, m2: 8, d: 5, seed: 31, shards: 3, window: 3 }
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let p = std::env::temp_dir()
            .join(format!("hocs_store_wal_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&p);
        fs::create_dir_all(&p).unwrap();
        p
    }

    fn int_weight(rng: &mut Pcg64) -> f64 {
        (1 + rng.gen_range(9)) as f64
    }

    #[test]
    fn record_roundtrip() {
        let mut sk = StreamSketch::new(8, 8, 4, 4, 3, 1);
        sk.update(1, 2, 3.0);
        for rec in [
            WalRecord::Update { i: 3, j: 9, w: -2.5 },
            WalRecord::AdvanceEpoch,
            WalRecord::MergeSketch(sk),
        ] {
            let mut out = Vec::new();
            rec.encode(&mut out);
            let got = WalRecord::decode(&mut Reader::new(&out)).unwrap();
            match (&rec, &got) {
                (
                    WalRecord::Update { i, j, w },
                    WalRecord::Update { i: gi, j: gj, w: gw },
                ) => {
                    assert_eq!((i, j), (gi, gj));
                    assert_eq!(w.to_bits(), gw.to_bits());
                }
                (WalRecord::AdvanceEpoch, WalRecord::AdvanceEpoch) => {}
                (WalRecord::MergeSketch(a), WalRecord::MergeSketch(b)) => {
                    assert!(a.same_family(b));
                    assert_eq!(a.table(0), b.table(0));
                }
                other => panic!("variant mismatch: {other:?}"),
            }
        }
    }

    #[test]
    fn crash_recovery_replays_wal_tail() {
        let dir = tmpdir("replay");
        let shadow = ShardedStore::new(cfg());
        let mut rng = Pcg64::new(2);
        {
            let live = DurableStore::open(&dir, cfg()).unwrap();
            for _ in 0..200 {
                let (i, j) = (rng.gen_range(40) as usize, rng.gen_range(32) as usize);
                let w = int_weight(&mut rng);
                live.update(i, j, w).unwrap();
                shadow.update(i, j, w);
            }
            live.snapshot().unwrap();
            live.advance_epoch().unwrap();
            shadow.advance_epoch();
            for _ in 0..150 {
                let (i, j) = (rng.gen_range(40) as usize, rng.gen_range(32) as usize);
                let w = int_weight(&mut rng);
                live.update(i, j, w).unwrap();
                shadow.update(i, j, w);
            }
            // dropped without a final snapshot: the tail lives in the WAL
        }
        let recovered = DurableStore::open(&dir, cfg()).unwrap();
        assert_eq!(recovered.stats(), shadow.stats());
        for i in 0..40 {
            for j in 0..32 {
                assert_eq!(
                    recovered.point_query(i, j).to_bits(),
                    shadow.point_query(i, j).to_bits(),
                    "key ({i}, {j})"
                );
            }
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn recovery_without_any_snapshot_call() {
        // never snapshot explicitly: open() writes the initial snapshot,
        // everything else must come back from the WAL alone
        let dir = tmpdir("wal_only");
        let shadow = ShardedStore::new(cfg());
        {
            let live = DurableStore::open(&dir, cfg()).unwrap();
            live.update(3, 4, 7.0).unwrap();
            live.update(9, 9, 2.0).unwrap();
            let mut remote = cfg().fresh_sketch();
            remote.update(3, 4, 1.0);
            live.merge_sketch(&remote).unwrap();
            shadow.update(3, 4, 7.0);
            shadow.update(9, 9, 2.0);
            shadow.merge_sketch(&remote).unwrap();
        }
        let recovered = DurableStore::open(&dir, cfg()).unwrap();
        assert_eq!(recovered.point_query(3, 4).to_bits(), shadow.point_query(3, 4).to_bits());
        assert_eq!(recovered.point_query(9, 9).to_bits(), shadow.point_query(9, 9).to_bits());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_wal_tail_is_ignored() {
        let dir = tmpdir("torn");
        {
            let live = DurableStore::open(&dir, cfg()).unwrap();
            live.update(1, 1, 5.0).unwrap();
        }
        // simulate a crash mid-append: a frame header promising more
        // payload than was written
        {
            let mut f = OpenOptions::new().append(true).open(dir.join(WAL_FILE)).unwrap();
            f.write_all(&100u32.to_le_bytes()).unwrap();
            f.write_all(&0xDEAD_BEEFu32.to_le_bytes()).unwrap();
            f.write_all(&[1, 2, 3]).unwrap();
        }
        let recovered = DurableStore::open(&dir, cfg()).unwrap();
        assert_eq!(recovered.point_query(1, 1), 5.0);
        // and the healed store keeps accepting writes
        recovered.update(2, 2, 1.0).unwrap();
        assert_eq!(recovered.point_query(2, 2), 1.0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_frame_stops_replay_cleanly() {
        let dir = tmpdir("crc");
        {
            let live = DurableStore::open(&dir, cfg()).unwrap();
            live.update(1, 1, 5.0).unwrap();
            live.update(2, 2, 6.0).unwrap();
        }
        // flip one payload byte of the last frame: CRC must catch it and
        // recovery keeps everything before that frame
        {
            let path = dir.join(WAL_FILE);
            let mut bytes = fs::read(&path).unwrap();
            let last = bytes.len() - 1;
            bytes[last] ^= 0xFF;
            fs::write(&path, &bytes).unwrap();
        }
        let recovered = DurableStore::open(&dir, cfg()).unwrap();
        assert_eq!(recovered.point_query(1, 1), 5.0);
        assert_eq!(recovered.point_query(2, 2), 0.0, "corrupt record must not replay");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_wal_generation_is_not_double_applied() {
        // simulate a crash *between* snapshot rename and WAL truncation:
        // the snapshot already contains the WAL's records, so replaying
        // them would double-count
        let dir = tmpdir("stale_gen");
        {
            let live = DurableStore::open(&dir, cfg()).unwrap();
            live.update(1, 1, 5.0).unwrap();
            // keep a copy of the record-bearing WAL
            fs::copy(dir.join(WAL_FILE), dir.join("wal.old")).unwrap();
            live.snapshot().unwrap(); // snapshot g+1 + fresh WAL g+1
        }
        // crash left the old WAL (generation g) next to snapshot g+1
        fs::copy(dir.join("wal.old"), dir.join(WAL_FILE)).unwrap();
        let recovered = DurableStore::open(&dir, cfg()).unwrap();
        assert_eq!(
            recovered.point_query(1, 1),
            5.0,
            "stale WAL record was double-applied"
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn mismatched_config_is_rejected() {
        let dir = tmpdir("cfg");
        {
            DurableStore::open(&dir, cfg()).unwrap();
        }
        let mut other = cfg();
        other.seed = 999;
        assert!(DurableStore::open(&dir, other).is_err());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn in_memory_store_has_no_snapshot() {
        let ds = DurableStore::in_memory(cfg());
        ds.update(1, 1, 1.0).unwrap();
        assert!(ds.snapshot().is_err());
        assert_eq!(ds.point_query(1, 1), 1.0);
    }
}
