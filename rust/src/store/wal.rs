//! Snapshot + write-ahead-log persistence for the sharded store.
//!
//! On-disk layout (one directory per store):
//!
//! ```text
//! snapshot.bin  = "HOCSSNAP" | u32 version | u64 generation
//!               | ShardedStore encoding | OriginTable encoding
//! wal.bin       = "HOCSWAL0" | u32 version | u64 generation | frame*
//! frame         = u32 payload_len | u32 crc32(payload) | payload
//! payload       = u8 tag | fields           (see WalRecord)
//! batch payload = u8 4 | u32 count | (u32 i | u32 j | f64 w)*   (group commit)
//! ```
//!
//! **Replication state.** The durable store owns the receiver side of
//! the replication plane: the per-origin dedup table
//! ([`super::replica::origins::OriginTable`]) is part of the snapshot
//! image, and *ingest* origin-merges are logged as their own record
//! ([`WalRecord::OriginMerge`]) whose replay re-commits the dedup
//! horizon — so a recovered node still recognizes a re-delivered frame.
//! *Replication-plane* merges (ingest = 0) are deliberately **not**
//! logged: the snapshot's origin records and the store image describe
//! the same instant, so after a crash the peer's next full-state ship
//! re-delivers exactly the since-snapshot remainder — anti-entropy is
//! the redo log for remote mass, and logging it as well would
//! double-count. [`DurableStore::apply_origin_merge`] runs the whole
//! admit → log → apply → commit sequence under the shared commit gate,
//! which keeps it atomic relative to snapshots.
//!
//! Everything is little-endian (see [`super::codec`]). Writes append a
//! frame *before* mutating the in-memory store; recovery loads the
//! snapshot and replays frames until the first torn or CRC-failing one
//! (a crash mid-append leaves exactly such a tail). [`DurableStore::open`]
//! then immediately re-snapshots and truncates the WAL, so the torn
//! tail is healed rather than appended after.
//!
//! **Group commit, two ways.** A whole batch of updates is one
//! [`WalRecord::UpdateBatch`] frame: one encode, one append, one flush
//! (one `sync_data` when fsync is on) for the entire batch, instead of
//! per item. The in-memory apply then goes through the shard-grouped
//! [`ShardedStore::update_batch`], so the WAL cost and the lock cost
//! both amortize over the batch. On top of that, *cross-connection*
//! commits coalesce via a **leader/follower commit queue**
//! ([`GroupCommitLog`]): every appender frames its record outside any
//! lock, stages it under the queue mutex, and is assigned a commit
//! LSN. Leader election is implicit — the first appender to observe no
//! leader in flight takes the file writer and writes *every* staged
//! frame with one coalesced `write_all` + flush (one `sync_data` in
//! fsync mode), with the queue mutex released so later arrivals keep
//! staging the next group. Followers park on a condvar until the
//! durable LSN covers their frame. The result: many independent
//! un-batched connections pay one disk round-trip per *group*, not per
//! record — the batched-WAL win without client changes. A failed group
//! write truncates the chunk back out, **fail-stops** the log, and
//! wakes every waiter with an error (nothing past the failure was
//! acknowledged). `DurableOptions::group_commit = false` restores the
//! per-record path (the bench baseline).
//!
//! **Concurrency.** The queue mutex is held only for staging and
//! hand-off — not across the file write, and never across the
//! in-memory apply — so writers on different shards proceed in
//! parallel after serializing briefly on the queue. What keeps that
//! safe is a commit *gate* (an `RwLock<()>`): every append→apply pair
//! runs under a shared guard, while [`DurableStore::snapshot`] and
//! [`DurableStore::advance_epoch`] take it exclusively. Exclusive
//! acquisition therefore waits until every appended record is durable
//! *and* applied (a commit returns only once its LSN is durable, so
//! the staged queue is empty whenever the gate is held exclusively —
//! a snapshot image always contains exactly the records the truncated
//! WAL held), and epoch rotation — which does not commute with updates
//! — keeps the same relative order in the WAL as in the store.
//! Update/merge records commute with each other (counter addition), so
//! their apply order may differ from WAL order without changing any
//! state reachable from either (bit-exact for exactly-representable
//! weights, the store's standing contract).
//!
//! **Durability levels.** `flush` only moves bytes into the OS page
//! cache: it survives a process crash, **not** a power failure or
//! kernel panic. With `fsync` enabled ([`DurableStore::open_with`], the
//! server's `--fsync` flag) every append also calls `sync_data`, so an
//! acknowledged write survives power loss at the cost of one disk sync
//! per frame — which is exactly why group commit matters: the sync
//! amortizes over the whole batch.
//!
//! **Rotation safety.** [`DurableStore::snapshot`] replaces
//! `snapshot.bin` atomically (tmp-file + rename) and then recreates the
//! WAL, also via tmp-file + rename so a crash mid-header can never
//! leave a truncated `wal.bin` that the next open refuses to parse.
//! The **generation** stamp makes the rename → recreate pair safe: the
//! new snapshot (which already incorporates every logged record) is
//! written with generation g+1, and only then is the WAL recreated with
//! g+1. If a crash lands between the two, recovery sees a snapshot at
//! g+1 next to a WAL still at g and skips the replay — without the
//! stamp those records would be applied a second time. If recreating
//! the WAL *fails*, the store **fail-stops** writes: appending to the
//! stale-generation log would be acknowledged and then silently skipped
//! by that same recovery rule, which is data loss. Reads keep working.
//!
//! # Failure model
//!
//! Every crash-sensitive operation below passes through a named
//! failpoint ([`super::faults`]; a no-op in release builds), and
//! `rust/tests/faults.rs` kills a scripted child process at each site
//! and asserts these guarantees. What each durability level promises:
//!
//! - **flush mode** (default): an acknowledged write survives a
//!   *process* crash (the bytes reached the OS page cache), not a power
//!   failure. Recovery returns a clean **op-prefix** of the history —
//!   never torn state.
//! - **fsync mode**: an acknowledged write also survives power loss
//!   (`sync_data` per commit, amortized by group commit). Same prefix
//!   guarantee.
//!
//! Which faults *heal* on the next open and which *fail-stop* the
//! running process:
//!
//! - Torn or corrupt WAL tail (crash mid-append, at any byte offset):
//!   **heals** — replay stops at the last whole frame, `open()`
//!   re-snapshots, and the store accepts writes again.
//! - Crash between snapshot rename and WAL recreation: **heals** — the
//!   generation stamp makes recovery skip the stale log (no
//!   double-apply), and everything acknowledged is in the snapshot.
//! - Failed group write, failed WAL rotation, or a snapshot installed
//!   without a durable directory sync: **fail-stop** — writes error,
//!   reads keep serving, reopening recovers. Fail-stop exists precisely
//!   because appending past the failure would acknowledge records that
//!   recovery silently drops.
//! - Failed snapshot *before* the rename: **rollback** — nothing was
//!   installed, the old snapshot + WAL pair stays live and writes
//!   continue.
//!
//! **Cursor durability rules.** The snapshot also carries the sender
//! side of replication: this node's stable origin id and the per-peer
//! acknowledged cursor positions ([`WalRecord::CursorAdvance`] /
//! [`WalRecord::ReplicaId`] cover the stretch between snapshots). The
//! replicator logs a cursor advance only *after* the peer acknowledged
//! the frame, and refuses to ship the next sequence until the previous
//! advance is durable — so the durable cursor trails the receiver's
//! dedup horizon by at most one frame, and a restarted sender resuming
//! at `acked + 2` with a full-state ship re-delivers exactly the
//! WAL-recovered-but-unshipped remainder (the receiver applies
//! `full − received` against its cumulative per-origin record).

use super::codec::{self, Reader};
use super::faults;
use super::lockdep;
use super::mergeable::MergeableSketch;
use super::replica::origins::{Admit, OriginTable, MAX_ORIGINS};
use super::sharded::{ShardedStore, StoreConfig, StoreStats};
use super::tensor::contract::ContractOutput;
use super::tensor::hcs::{HcsStream, MAX_ORDER};
use super::tensor::registry::{self, TensorFamily};
use crate::sketch::stream::StreamSketch;
use anyhow::{bail, ensure, Context, Result};
use std::collections::{BTreeMap, HashMap};
use std::fs::{self, File, OpenOptions};
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, RwLock, RwLockReadGuard, RwLockWriteGuard};

const SNAP_MAGIC: &[u8; 8] = b"HOCSSNAP";
const WAL_MAGIC: &[u8; 8] = b"HOCSWAL0";
/// Bumped to 2 when the embedded [`StreamSketch`] encoding grew its
/// turnstile flags byte (group-commit PR), to 3 when snapshots grew
/// the per-origin replication dedup table and the WAL its
/// `OriginMerge` record (replication PR), and to 4 when snapshots grew
/// the durable sender-side replication section (origin id + per-peer
/// cursors + the origin accumulator behind the store's replicate flag)
/// and the WAL its `CursorAdvance` / `ReplicaId` records
/// (fault-injection PR), and to 5 when snapshots grew the tensor-plane
/// section (the named HCS catalog + its replication channel table,
/// appended to the store image) and the WAL its `TensorCreate` /
/// `TensorUpdate` / `TensorUpdateBatch` records (tensor-store PR);
/// older files are rejected with a version error rather than misparsed.
const FORMAT_VERSION: u32 = 5;
/// magic + version + generation
const HEADER_LEN: usize = 20;
/// Cap on a batch frame's item count, shared with the server's
/// per-request cap ([`super::MAX_UPDATE_BATCH`]) so the write path can
/// never acknowledge a frame that decode would refuse; it also keeps a
/// corrupt length from driving a huge allocation.
const MAX_WAL_BATCH: usize = super::MAX_UPDATE_BATCH;

pub const SNAPSHOT_FILE: &str = "snapshot.bin";
pub const WAL_FILE: &str = "wal.bin";

/// One durable mutation. Queries never hit the log.
#[derive(Debug)]
pub enum WalRecord {
    Update { i: u32, j: u32, w: f64 },
    AdvanceEpoch,
    MergeSketch(StreamSketch),
    /// Group commit: a whole client batch in one frame.
    UpdateBatch(Vec<(u32, u32, f64)>),
    /// An applied *ingest* origin-merge: the already-computed remainder
    /// plus the (origin, seq) whose dedup horizon replay must re-commit
    /// — a recovered node keeps recognizing re-delivered frames.
    OriginMerge { origin: u64, seq: u64, sketch: StreamSketch },
    /// Sender-side cursor advance: `peer` acknowledged the frame at
    /// `seq`, which covered the origin snapshot stamped `version`.
    /// Logged *after* the ack, so replaying every record leaves the
    /// durable cursor at most one frame behind the receiver's horizon.
    CursorAdvance { peer: String, seq: u64, version: u64 },
    /// This node's stable replication origin id, logged when first
    /// derived so a restarted sender keeps its channel (and the
    /// receiver's cumulative per-origin record keeps matching).
    ReplicaId(u64),
    /// Tensor-plane DDL: register `name` with `family` in the catalog.
    TensorCreate { name: String, family: TensorFamily },
    /// One multi-mode tensor update.
    TensorUpdate { name: String, key: Vec<usize>, w: f64 },
    /// A whole multi-mode batch in one frame: `ws.len()` items, item
    /// `i`'s key at `keys[i·order .. (i+1)·order]` — the same flat
    /// layout the fused [`HcsStream::update_batch`] kernel consumes.
    TensorUpdateBatch { name: String, keys: Vec<usize>, ws: Vec<f64> },
}

const TAG_UPDATE: u8 = 1;
const TAG_ADVANCE: u8 = 2;
const TAG_MERGE: u8 = 3;
const TAG_UPDATE_BATCH: u8 = 4;
const TAG_ORIGIN_MERGE: u8 = 5;
const TAG_CURSOR_ADVANCE: u8 = 6;
const TAG_REPLICA_ID: u8 = 7;
const TAG_TENSOR_CREATE: u8 = 8;
const TAG_TENSOR_UPDATE: u8 = 9;
const TAG_TENSOR_UPDATE_BATCH: u8 = 10;

/// Context-free multi-mode key decode for WAL replay: the record's own
/// order byte (validated against [`MAX_ORDER`], so a corrupt byte
/// cannot drive a huge allocation) followed by raw `u32` indices.
/// Unlike [`codec::read_mode_key`] — the wire-path reader, which
/// validates against the target tensor's dims up front — WAL decode has
/// no registry in scope; range validation happens when the record is
/// applied through the registry's own `ensure`-based checks.
fn read_mode_key_raw(rd: &mut Reader<'_>) -> Result<Vec<usize>> {
    let order = rd.u8()? as usize;
    ensure!(
        (1..=MAX_ORDER).contains(&order),
        "WAL tensor key order {order} outside 1..={MAX_ORDER}"
    );
    let mut key = Vec::with_capacity(order);
    for _ in 0..order {
        key.push(rd.u32()? as usize);
    }
    Ok(key)
}

/// Decode cap on a peer address embedded in a cursor record or
/// snapshot — keeps a corrupt length from driving a huge allocation.
const MAX_PEER_ADDR: usize = 1024;
/// Decode cap on the number of per-peer cursors in a snapshot (a
/// static mesh is small; this only bounds corrupt counts).
const MAX_PEER_CURSORS: usize = 4096;

impl WalRecord {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            WalRecord::Update { i, j, w } => {
                codec::put_u8(out, TAG_UPDATE);
                codec::put_update(out, *i, *j, *w);
            }
            WalRecord::AdvanceEpoch => codec::put_u8(out, TAG_ADVANCE),
            WalRecord::MergeSketch(sk) => {
                codec::put_u8(out, TAG_MERGE);
                sk.encode(out);
            }
            WalRecord::UpdateBatch(items) => {
                codec::put_u8(out, TAG_UPDATE_BATCH);
                codec::put_u32(
                    out,
                    u32::try_from(items.len()).expect("WAL batch too large"),
                );
                for &(i, j, w) in items {
                    codec::put_update(out, i, j, w);
                }
            }
            WalRecord::OriginMerge { origin, seq, sketch } => {
                codec::put_u8(out, TAG_ORIGIN_MERGE);
                codec::put_u64(out, *origin);
                codec::put_u64(out, *seq);
                sketch.encode(out);
            }
            WalRecord::CursorAdvance { peer, seq, version } => {
                codec::put_u8(out, TAG_CURSOR_ADVANCE);
                codec::put_u32(out, u32::try_from(peer.len()).expect("peer addr fits u32"));
                out.extend_from_slice(peer.as_bytes());
                codec::put_u64(out, *seq);
                codec::put_u64(out, *version);
            }
            WalRecord::ReplicaId(id) => {
                codec::put_u8(out, TAG_REPLICA_ID);
                codec::put_u64(out, *id);
            }
            WalRecord::TensorCreate { name, family } => {
                codec::put_u8(out, TAG_TENSOR_CREATE);
                codec::put_name(out, name);
                family.encode(out);
            }
            WalRecord::TensorUpdate { name, key, w } => {
                codec::put_u8(out, TAG_TENSOR_UPDATE);
                codec::put_name(out, name);
                codec::put_mode_key(out, key);
                codec::put_f64(out, *w);
            }
            WalRecord::TensorUpdateBatch { name, keys, ws } => {
                codec::put_u8(out, TAG_TENSOR_UPDATE_BATCH);
                codec::put_name(out, name);
                let order = if ws.is_empty() { 1 } else { keys.len() / ws.len() };
                codec::put_u8(out, u8::try_from(order).expect("tensor order fits u8"));
                codec::put_u32(out, u32::try_from(ws.len()).expect("WAL tensor batch too large"));
                for &i in keys {
                    codec::put_u32(out, u32::try_from(i).expect("mode index fits u32"));
                }
                for &w in ws {
                    codec::put_f64(out, w);
                }
            }
        }
    }

    /// Encode an [`WalRecord::UpdateBatch`] payload straight from the
    /// caller's slice — the write hot path must not copy the whole
    /// batch into an owned record first. Byte-identical to encoding
    /// `WalRecord::UpdateBatch` of the same (bounds-checked) items.
    fn encode_update_batch(out: &mut Vec<u8>, items: &[(usize, usize, f64)]) {
        codec::put_u8(out, TAG_UPDATE_BATCH);
        codec::put_u32(out, u32::try_from(items.len()).expect("WAL batch too large"));
        for &(i, j, w) in items {
            codec::put_update(out, i as u32, j as u32, w);
        }
    }

    fn decode(rd: &mut Reader<'_>) -> Result<Self> {
        match rd.u8()? {
            TAG_UPDATE => {
                let (i, j, w) = rd.update_triple()?;
                Ok(WalRecord::Update { i, j, w })
            }
            TAG_ADVANCE => Ok(WalRecord::AdvanceEpoch),
            TAG_MERGE => Ok(WalRecord::MergeSketch(StreamSketch::decode(rd)?)),
            TAG_UPDATE_BATCH => {
                let count = rd.u32()? as usize;
                ensure!(count <= MAX_WAL_BATCH, "WAL batch of {count} updates exceeds cap");
                let mut items = Vec::with_capacity(count);
                for _ in 0..count {
                    items.push(rd.update_triple()?);
                }
                Ok(WalRecord::UpdateBatch(items))
            }
            TAG_ORIGIN_MERGE => {
                let origin = rd.u64()?;
                let seq = rd.u64()?;
                let sketch = StreamSketch::decode(rd)?;
                Ok(WalRecord::OriginMerge { origin, seq, sketch })
            }
            TAG_CURSOR_ADVANCE => {
                let len = rd.u32()? as usize;
                ensure!(len <= MAX_PEER_ADDR, "cursor peer address of {len} bytes");
                let peer = String::from_utf8(rd.take(len)?.to_vec())
                    .context("cursor peer address is not UTF-8")?;
                let seq = rd.u64()?;
                let version = rd.u64()?;
                Ok(WalRecord::CursorAdvance { peer, seq, version })
            }
            TAG_REPLICA_ID => Ok(WalRecord::ReplicaId(rd.u64()?)),
            TAG_TENSOR_CREATE => {
                let name = codec::read_name(rd).context("WAL tensor create name")?;
                let family = TensorFamily::decode(rd).context("WAL tensor create family")?;
                Ok(WalRecord::TensorCreate { name, family })
            }
            TAG_TENSOR_UPDATE => {
                let name = codec::read_name(rd).context("WAL tensor update name")?;
                let key = read_mode_key_raw(rd)?;
                let w = rd.f64()?;
                Ok(WalRecord::TensorUpdate { name, key, w })
            }
            TAG_TENSOR_UPDATE_BATCH => {
                let name = codec::read_name(rd).context("WAL tensor batch name")?;
                let order = rd.u8()? as usize;
                ensure!(
                    (1..=MAX_ORDER).contains(&order),
                    "WAL tensor batch order {order} outside 1..={MAX_ORDER}"
                );
                let count = rd.u32()? as usize;
                ensure!(
                    count <= MAX_WAL_BATCH,
                    "WAL tensor batch of {count} updates exceeds cap {MAX_WAL_BATCH}"
                );
                let mut keys = Vec::with_capacity(count * order);
                for _ in 0..count * order {
                    keys.push(rd.u32()? as usize);
                }
                let mut ws = Vec::with_capacity(count);
                for _ in 0..count {
                    ws.push(rd.f64()?);
                }
                Ok(WalRecord::TensorUpdateBatch { name, keys, ws })
            }
            other => bail!("unknown WAL record tag {other}"),
        }
    }
}

/// Durable sender-side replication state: this node's stable origin id
/// plus, per peer address, the last *durably acknowledged* (sequence,
/// origin-version) pair. Snapshots embed it ([`FORMAT_VERSION`] 4) and
/// [`WalRecord::CursorAdvance`] / [`WalRecord::ReplicaId`] replay
/// rebuilds the stretch since — so a restarted sender re-ships exactly
/// the recovered-but-unshipped remainder instead of forgetting its
/// channels (see the module docs' cursor durability rules).
#[derive(Default)]
struct ReplicaCursors {
    /// 0 = never derived (this node has never replicated)
    origin_id: u64,
    /// peer addr → (acked seq, acked origin version); `BTreeMap` so
    /// identical states encode identically
    peers: BTreeMap<String, (u64, u64)>,
}

impl ReplicaCursors {
    /// Monotone advance (replay order is WAL order, but a re-delivered
    /// snapshot + tail must never move a cursor backwards).
    fn advance(&mut self, peer: &str, seq: u64, version: u64) {
        let ent = self.peers.entry(peer.to_string()).or_insert((0, 0));
        ent.0 = ent.0.max(seq);
        ent.1 = ent.1.max(version);
    }

    fn encode_into(&self, out: &mut Vec<u8>) {
        codec::put_u64(out, self.origin_id);
        codec::put_u32(out, u32::try_from(self.peers.len()).expect("peer count fits u32"));
        for (addr, (seq, version)) in &self.peers {
            codec::put_u32(out, u32::try_from(addr.len()).expect("peer addr fits u32"));
            out.extend_from_slice(addr.as_bytes());
            codec::put_u64(out, *seq);
            codec::put_u64(out, *version);
        }
    }

    fn decode_from(rd: &mut Reader<'_>) -> Result<Self> {
        let origin_id = rd.u64()?;
        let count = rd.u32()? as usize;
        ensure!(count <= MAX_PEER_CURSORS, "snapshot claims {count} peer cursors");
        let mut peers = BTreeMap::new();
        for _ in 0..count {
            let len = rd.u32()? as usize;
            ensure!(len <= MAX_PEER_ADDR, "snapshot peer address of {len} bytes");
            let addr = String::from_utf8(rd.take(len)?.to_vec())
                .context("snapshot peer address is not UTF-8")?;
            let seq = rd.u64()?;
            let version = rd.u64()?;
            peers.insert(addr, (seq, version));
        }
        Ok(Self { origin_id, peers })
    }
}

/// Append-only frame writer. `sync` upgrades the per-append flush to a
/// `sync_data`, trading throughput for power-loss durability.
struct WalWriter {
    file: File,
    sync: bool,
    /// bytes known durable-intended: header + every fully-acknowledged
    /// frame. A failed append truncates back to this length (best
    /// effort, followed by a best-effort sync) so a frame that landed
    /// in the page cache but whose flush/sync errored — a NACKed write
    /// — does not replay on recovery. An errored commit is inherently
    /// ambiguous: if the device also refuses the truncation, or power
    /// is lost before it persists, the NACKed frame can still resurface.
    committed_len: u64,
}

impl WalWriter {
    /// Create the new log **atomically** (tmp-file + rename) and write
    /// the header, stamped with the generation of the snapshot it
    /// extends. The rename means a crash mid-header can never leave a
    /// truncated `wal.bin` behind — the old log survives intact until
    /// the new one is fully formed.
    fn create(path: &Path, generation: u64, sync: bool) -> Result<Self> {
        let tmp = path.with_extension("tmp");
        faults::fire("wal.create.tmp").with_context(|| format!("creating WAL tmp {tmp:?}"))?;
        let mut file =
            File::create(&tmp).with_context(|| format!("creating WAL tmp {tmp:?}"))?;
        file.write_all(WAL_MAGIC)?;
        file.write_all(&FORMAT_VERSION.to_le_bytes())?;
        file.write_all(&generation.to_le_bytes())?;
        file.flush()?;
        if sync {
            file.sync_data().context("syncing new WAL header")?;
        }
        faults::fire("wal.create.rename").with_context(|| format!("installing WAL {path:?}"))?;
        fs::rename(&tmp, path).with_context(|| format!("installing WAL {path:?}"))?;
        if sync {
            // the rename itself must survive power loss too; an error
            // here propagates, which the rotation path turns into a
            // fail-stop — acknowledging writes into a WAL whose install
            // may not be durable would re-open the data-loss window
            if let Some(parent) = path.parent() {
                File::open(parent)
                    .and_then(|d| d.sync_all())
                    .with_context(|| format!("syncing {parent:?} after WAL install"))?;
            }
        }
        Ok(Self { file, sync, committed_len: HEADER_LEN as u64 })
    }

    /// Persist pre-framed bytes — one frame, or a whole coalesced
    /// group-commit chunk — with one `write_all` + flush (one
    /// `sync_data` in sync mode).
    fn append_frames(&mut self, framed: &[u8]) -> Result<()> {
        if let Err(e) = self.write_and_sync(framed) {
            // the chunk may sit (partly) complete in the page cache (or
            // on disk, in sync mode) even though the callers get an
            // error — truncate it back out and try to persist the
            // truncation so the NACKed writes do not replay on
            // recovery. Best effort: the log fail-stops either way, and
            // see committed_len for the residual ambiguity of an
            // errored commit.
            if faults::fire("wal.truncate").is_ok()
                && self.file.set_len(self.committed_len).is_ok()
            {
                let _ = self.file.sync_data();
            }
            return Err(e);
        }
        self.committed_len += framed.len() as u64;
        Ok(())
    }

    fn write_and_sync(&mut self, framed: &[u8]) -> Result<()> {
        faults::write_all("wal.append", &mut self.file, framed)?;
        self.file.flush()?;
        if self.sync {
            faults::fire("wal.sync")?;
            let t0 = std::time::Instant::now();
            self.file.sync_data().context("syncing WAL append")?;
            crate::obs::global().wal_fsync_us.record(t0.elapsed().as_micros() as u64);
        }
        crate::obs::global().wal_appends.inc();
        crate::obs::global().wal_bytes.add(framed.len() as u64);
        Ok(())
    }
}

fn failstop_error() -> anyhow::Error {
    anyhow::anyhow!(
        "store is fail-stopped: a WAL write failed and appending to the \
         stale log would lose acknowledged writes on recovery"
    )
}

/// Leader/follower commit queue over one [`WalWriter`] — see the module
/// docs. Concurrent appenders stage framed records and the first to
/// find no leader in flight writes the whole staged group with a single
/// flush/`sync_data`; the rest wait on the condvar for their LSN.
struct GroupCommitLog {
    state: Mutex<CommitQueue>,
    cv: Condvar,
    /// `false` = one write + flush per record under the queue mutex
    /// (the measured baseline; [`DurableOptions::group_commit`])
    group: bool,
}

struct CommitQueue {
    /// `None` while the leader holds the writer during a group write
    /// (`writing == true`), or permanently after fail-stop
    /// (`writing == false`)
    writer: Option<WalWriter>,
    writing: bool,
    /// framed bytes staged for the next leader write
    staged: Vec<u8>,
    /// LSN of the newest staged frame
    staged_lsn: u64,
    /// every LSN ≤ this is durable (written + flushed / synced)
    durable_lsn: u64,
    /// next LSN to assign
    next_lsn: u64,
}

impl GroupCommitLog {
    fn new(writer: WalWriter, group: bool) -> Self {
        Self {
            state: Mutex::new(CommitQueue {
                writer: Some(writer),
                writing: false,
                staged: Vec::new(),
                staged_lsn: 0,
                durable_lsn: 0,
                next_lsn: 1,
            }),
            cv: Condvar::new(),
            group,
        }
    }

    /// Commit one framed record: stage it, then either lead the next
    /// group write or park until a leader makes its LSN durable.
    /// Returns only once the frame is durable at this log's level
    /// (flushed; synced in fsync mode) — or with the fail-stop error if
    /// a write failed before it got there.
    fn commit_frame(&self, frame: &[u8]) -> Result<()> {
        let mut ldq = lockdep::acquire(lockdep::WAL_QUEUE, 0);
        // lint: allow(no-panic-paths) queue poison means a writer thread panicked mid-commit; propagating the panic is the fail-stop
        let mut st = self.state.lock().expect("wal lock");
        if st.writer.is_none() && !st.writing {
            return Err(failstop_error());
        }
        if !self.group {
            // per-record baseline: one write + flush per frame,
            // serialized on the queue mutex (PR-3 behaviour). The
            // writer is present here — checked above, and `writing` is
            // never set in this mode — but fail-stop beats panicking in
            // a commit path if that invariant ever breaks.
            let Some(writer) = st.writer.as_mut() else {
                return Err(failstop_error());
            };
            if let Err(e) = writer.append_frames(frame) {
                st.writer = None;
                crate::obs::global().wal_fail_stops.inc();
                return Err(e.context("WAL append failed; store is now fail-stopped"));
            }
            return Ok(());
        }
        st.staged.extend_from_slice(frame);
        let lsn = st.next_lsn;
        st.next_lsn += 1;
        st.staged_lsn = lsn;
        loop {
            if st.durable_lsn >= lsn {
                return Ok(());
            }
            if st.writer.is_none() && !st.writing {
                // a leader failed with our frame staged or in its
                // chunk: nothing past the failure was acknowledged
                return Err(failstop_error());
            }
            if !st.writing {
                // leader election is implicit: we found no write in
                // flight and our frame is still staged, so we take the
                // writer and commit everything staged so far. Both
                // checks above guarantee the writer is present; treat a
                // broken invariant as fail-stop, not a panic.
                let chunk = std::mem::take(&mut st.staged);
                let group_lsn = st.staged_lsn;
                // group size = LSNs this write makes durable, measured
                // before the lock drops (durable_lsn may move after)
                let group_frames = group_lsn.saturating_sub(st.durable_lsn);
                let Some(mut writer) = st.writer.take() else {
                    return Err(failstop_error());
                };
                st.writing = true;
                // the queue lock (and its lockdep registration) drops
                // across the group write so followers can stage
                drop(st);
                drop(ldq);
                let res = {
                    let _span = crate::obs::trace::span("wal.group_commit");
                    writer.append_frames(&chunk)
                };
                ldq = lockdep::acquire(lockdep::WAL_QUEUE, 0);
                // lint: allow(no-panic-paths) queue poison propagates the fail-stop panic, as above
                st = self.state.lock().expect("wal lock");
                st.writing = false;
                match res {
                    Ok(()) => {
                        st.writer = Some(writer);
                        if group_lsn > st.durable_lsn {
                            st.durable_lsn = group_lsn;
                        }
                        crate::obs::global().wal_group_frames.record(group_frames);
                        self.cv.notify_all();
                        // loop re-checks: durable_lsn now covers us
                    }
                    Err(e) => {
                        // fail-stop (writer stays None); wake everyone
                        // so followers observe it and error out
                        crate::obs::global().wal_fail_stops.inc();
                        self.cv.notify_all();
                        return Err(e.context(
                            "WAL append failed; store is now fail-stopped",
                        ));
                    }
                }
            } else {
                // lint: allow(no-panic-paths) condvar poison mirrors the queue-poison fail-stop above
                st = self.cv.wait(st).expect("wal cv");
            }
        }
    }
}

/// Read the WAL's generation stamp and every intact record; stop
/// (without error) at the first torn or corrupt frame — that is the
/// crash-recovery contract.
fn read_wal(path: &Path) -> Result<(u64, Vec<WalRecord>)> {
    let bytes = fs::read(path).with_context(|| format!("reading WAL {path:?}"))?;
    ensure!(bytes.len() >= HEADER_LEN, "WAL shorter than its header");
    ensure!(&bytes[..8] == WAL_MAGIC, "bad WAL magic");
    let mut rd = Reader::new(&bytes[8..]);
    let version = rd.u32()?;
    ensure!(version == FORMAT_VERSION, "unsupported WAL version {version}");
    let generation = rd.u64()?;
    let mut out = Vec::new();
    loop {
        if rd.remaining() < 8 {
            break; // torn or absent frame header
        }
        let len = rd.u32()? as usize;
        let crc = rd.u32()?;
        if rd.remaining() < len {
            break; // torn payload
        }
        let payload = rd.take(len)?;
        if codec::crc32(payload) != crc {
            break; // corrupt frame
        }
        let mut prd = Reader::new(payload);
        match WalRecord::decode(&mut prd) {
            Ok(rec) => out.push(rec),
            Err(_) => break, // CRC passed but the record is garbage
        }
    }
    Ok((generation, out))
}

/// Durability / commit-scheduling knobs for [`DurableStore::open_opts`].
#[derive(Clone, Copy, Debug)]
pub struct DurableOptions {
    /// `sync_data` every WAL commit (power-loss durability; the group
    /// commit amortizes the sync over the whole group)
    pub fsync: bool,
    /// leader/follower cross-connection group commit (default on);
    /// `false` restores one write + flush per record under the log
    /// mutex — the baseline `bench_store`'s concurrent-writer sweep
    /// compares against
    pub group_commit: bool,
}

impl Default for DurableOptions {
    fn default() -> Self {
        Self { fsync: false, group_commit: true }
    }
}

/// A [`ShardedStore`] with optional snapshot/WAL durability. All write
/// paths log first, then mutate; `log == None` is a purely in-memory
/// store with identical semantics and no I/O.
///
/// The commit queue serializes only staging and the leader hand-off;
/// the `commit` gate (shared for writers, exclusive for snapshot /
/// epoch rotation) is what makes the append→apply pair atomic
/// *relative to those two* without serializing writers against each
/// other — see the module docs.
pub struct DurableStore {
    store: ShardedStore,
    /// receiver side of the replication plane: per-origin dedup
    /// horizons + cumulative records, persisted with every snapshot
    /// and re-committed by `OriginMerge` replay (see the module docs)
    origins: Mutex<OriginTable>,
    /// sender side of the replication plane: the durable origin id and
    /// per-peer acked cursors (snapshot section + `CursorAdvance` /
    /// `ReplicaId` records — see the module docs' cursor rules)
    replica: Mutex<ReplicaCursors>,
    /// leader/follower commit queue; fail-stop lives inside it
    log: Option<GroupCommitLog>,
    /// shared by every append→apply pair, exclusive for snapshot and
    /// epoch rotation. `std`'s futex-based `RwLock` (Linux) blocks new
    /// readers once a writer waits, so sustained update traffic cannot
    /// starve snapshot/rotation; platforms with reader-preferring locks
    /// would need a fairness shim here.
    commit: RwLock<()>,
    dir: Option<PathBuf>,
    /// generation of the current snapshot + WAL pair; bumped by every
    /// snapshot (only ever touched under the exclusive commit gate)
    generation: AtomicU64,
    /// `sync_data` on every WAL append (power-loss durability)
    fsync: bool,
    /// serializes tensor DDL (`tensor_create`): the validate→log→apply
    /// sequence must be atomic against a racing create of the same name
    /// with a different family, or the WAL could record two
    /// contradictory creates and replay would fail where the live path
    /// succeeded. Plain updates never take it.
    ddl: Mutex<()>,
}

impl DurableStore {
    /// Purely in-memory store (no persistence; `snapshot()` errors).
    pub fn in_memory(cfg: StoreConfig) -> Self {
        Self {
            store: ShardedStore::new(cfg),
            origins: Mutex::new(OriginTable::new(MAX_ORIGINS)),
            replica: Mutex::new(ReplicaCursors::default()),
            log: None,
            commit: RwLock::new(()),
            dir: None,
            generation: AtomicU64::new(0),
            fsync: false,
            ddl: Mutex::new(()),
        }
    }

    /// [`DurableStore::open_with`] without fsync: appends are flushed
    /// (process-crash safe) but not synced (not power-loss safe).
    pub fn open(dir: &Path, cfg: StoreConfig) -> Result<Self> {
        Self::open_with(dir, cfg, false)
    }

    /// [`DurableStore::open_opts`] with the default commit scheduling
    /// (leader/follower group commit on) and the given fsync level.
    pub fn open_with(dir: &Path, cfg: StoreConfig, fsync: bool) -> Result<Self> {
        Self::open_opts(dir, cfg, DurableOptions { fsync, ..DurableOptions::default() })
    }

    /// Open or create a durable store under `dir`: load the snapshot if
    /// one exists, replay the WAL tail onto it (only when the WAL's
    /// generation matches the snapshot's — a mismatch means a crash
    /// landed between snapshot rename and WAL truncation, and those
    /// records are already inside the snapshot), then write a fresh
    /// snapshot and truncate the WAL (healing any torn tail). An
    /// existing store must match `cfg` — silently changing sketch
    /// geometry would corrupt every merge invariant.
    ///
    /// `opts.fsync = true` makes every WAL commit `sync_data`, so
    /// acknowledged writes survive power loss, not just process
    /// crashes; both batched updates and the cross-connection group
    /// commit amortize that sync over a whole group of records.
    /// `opts.group_commit = false` restores per-record commits.
    pub fn open_opts(dir: &Path, cfg: StoreConfig, opts: DurableOptions) -> Result<Self> {
        let fsync = opts.fsync;
        cfg.validate()?;
        fs::create_dir_all(dir).with_context(|| format!("creating store dir {dir:?}"))?;
        let snap_path = dir.join(SNAPSHOT_FILE);
        let wal_path = dir.join(WAL_FILE);

        let (store, mut origins, mut cursors, snap_generation) = if snap_path.exists() {
            let bytes = fs::read(&snap_path).with_context(|| format!("reading {snap_path:?}"))?;
            ensure!(bytes.len() >= HEADER_LEN, "snapshot shorter than its header");
            ensure!(&bytes[..8] == SNAP_MAGIC, "bad snapshot magic");
            let mut rd = Reader::new(&bytes[8..]);
            let version = rd.u32()?;
            ensure!(version == FORMAT_VERSION, "unsupported snapshot version {version}");
            let generation = rd.u64()?;
            let store = ShardedStore::decode_from(&mut rd)?;
            ensure!(
                *store.config() == cfg,
                "on-disk store config {:?} does not match requested {cfg:?}",
                store.config()
            );
            // the origin dedup table and the sender cursors are part of
            // the same instant as the store image — decoding all three
            // together is what keeps full-ship remainders exact across
            // restarts on both sides of a channel
            let origins = OriginTable::decode_from(&mut rd, store.config())?;
            let cursors = ReplicaCursors::decode_from(&mut rd)?;
            (store, origins, cursors, generation)
        } else {
            (ShardedStore::new(cfg), OriginTable::new(MAX_ORIGINS), ReplicaCursors::default(), 0)
        };

        if wal_path.exists() {
            let (wal_generation, records) = read_wal(&wal_path)?;
            if wal_generation == snap_generation {
                crate::log_debug!("store: replaying {} WAL record(s)", records.len());
                // a node that was replicating must rebuild its origin
                // accumulator *during* replay: the snapshot's replicate
                // flag covers the snapshot instant, and a `ReplicaId`
                // record covers the first-enable-after-open case (the
                // initial snapshot predates `enable_replication`). The
                // replayed local records are exactly the recovered-but-
                // possibly-unshipped mass the durable cursors exist for.
                if !store.replication_enabled()
                    && (cursors.origin_id != 0
                        || records.iter().any(|r| matches!(r, WalRecord::ReplicaId(_))))
                {
                    store.set_replication(true);
                }
                for rec in &records {
                    apply(&store, &mut origins, &mut cursors, rec)?;
                }
            } else {
                // crash between snapshot rename and WAL truncation: the
                // snapshot already contains these records
                crate::log_warn!(
                    "store: skipping WAL generation {wal_generation} (snapshot is at \
                     {snap_generation}) — records already applied"
                );
            }
        }

        let next_generation = snap_generation + 1;
        let mut ds = Self {
            store,
            origins: Mutex::new(origins),
            replica: Mutex::new(cursors),
            log: None,
            commit: RwLock::new(()),
            dir: Some(dir.to_path_buf()),
            generation: AtomicU64::new(next_generation),
            fsync,
            ddl: Mutex::new(()),
        };
        // snapshot the replayed state first (at the bumped generation),
        // then start a clean same-generation log: a crash between the
        // two leaves snapshot g+1 + WAL g, which the next open skips.
        // No WAL writer exists yet, so either failure side just fails
        // the open — nothing can be acknowledged against a bad pair.
        ds.write_snapshot_file().map_err(|e| match e {
            SnapInstall::NotInstalled(err) | SnapInstall::Installed(err) => err,
        })?;
        ds.log = Some(GroupCommitLog::new(
            WalWriter::create(&wal_path, next_generation, fsync)?,
            opts.group_commit,
        ));
        Ok(ds)
    }

    /// Take the commit gate **shared** (append→apply pairs), with its
    /// [`lockdep`] registration — COMMIT_GATE sits above the WAL queue,
    /// the shard locks, and the registry in the lock hierarchy. The
    /// tuple keeps guard and token alive together; bind it whole.
    fn gate_shared(&self) -> (lockdep::Held, RwLockReadGuard<'_, ()>) {
        let held = lockdep::acquire(lockdep::COMMIT_GATE, 0);
        // lint: allow(no-panic-paths) gate poison means a holder panicked mid-commit; propagating the panic is the fail-stop
        (held, self.commit.read().expect("commit gate"))
    }

    /// Take the commit gate **exclusively** (snapshot / epoch rotation),
    /// with its [`lockdep`] registration.
    fn gate_excl(&self) -> (lockdep::Held, RwLockWriteGuard<'_, ()>) {
        let held = lockdep::acquire(lockdep::COMMIT_GATE, 0);
        // lint: allow(no-panic-paths) gate poison means a holder panicked mid-commit; propagating the panic is the fail-stop
        (held, self.commit.write().expect("commit gate"))
    }

    /// Append one record to the live WAL through the commit queue.
    /// Errors when writes are fail-stopped; a group write that itself
    /// fails (possibly leaving a torn frame mid-log) also fail-stops,
    /// because recovery silently drops everything after the first bad
    /// frame — later appends would be acknowledged and then lost.
    fn append_record(&self, rec: &WalRecord) -> Result<()> {
        let mut payload = Vec::new();
        rec.encode(&mut payload);
        self.append_payload(&payload)
    }

    /// [`DurableStore::append_record`] for pre-encoded payloads (the
    /// batch hot path encodes straight from the caller's slice). The
    /// CRC frame is built outside any lock; the commit queue only ever
    /// sees ready-to-write bytes.
    fn append_payload(&self, payload: &[u8]) -> Result<()> {
        let log = self.log.as_ref().context("append requires a durable store")?;
        let len = u32::try_from(payload.len()).context("WAL record too large for a frame")?;
        let mut frame = Vec::with_capacity(payload.len() + 8);
        codec::put_u32(&mut frame, len);
        codec::put_u32(&mut frame, codec::crc32(payload));
        frame.extend_from_slice(payload);
        log.commit_frame(&frame)
    }

    pub fn config(&self) -> &StoreConfig {
        self.store.config()
    }

    /// The wrapped in-memory store (tests / read-only access).
    pub fn store(&self) -> &ShardedStore {
        &self.store
    }

    /// Log (if durable) then apply one update. The log lock is released
    /// before the apply, so updates bound for different shards only
    /// serialize on the brief append itself; the shared commit guard
    /// keeps the append→apply pair atomic relative to snapshot / epoch
    /// rotation (which take the gate exclusively).
    pub fn update(&self, i: usize, j: usize, w: f64) -> Result<()> {
        let cfg = self.store.config();
        ensure!(
            i < cfg.n1 && j < cfg.n2,
            "key ({i}, {j}) outside universe {}x{}",
            cfg.n1,
            cfg.n2
        );
        if self.log.is_some() {
            let _shared = self.gate_shared();
            self.append_record(&WalRecord::Update { i: i as u32, j: j as u32, w })?;
            self.store.update(i, j, w);
        } else {
            self.store.update(i, j, w);
        }
        Ok(())
    }

    /// Group commit: the whole batch becomes **one** WAL frame (one
    /// append, one flush, one `sync_data` when fsync is on) and one
    /// shard-grouped in-memory apply. Validated up front — a bad key
    /// fails the entire batch before anything is logged or applied.
    /// Bit-identical to per-item [`DurableStore::update`] calls, both
    /// live and after recovery (the frame replays through the same
    /// [`ShardedStore::update_batch`] kernel).
    pub fn update_batch(&self, items: &[(usize, usize, f64)]) -> Result<()> {
        // an oversized batch would encode and acknowledge fine but fail
        // the decode cap on recovery, silently dropping it (and every
        // later frame) — reject it up front instead
        ensure!(
            items.len() <= MAX_WAL_BATCH,
            "batch of {} updates exceeds the {MAX_WAL_BATCH}-item cap (split it)",
            items.len()
        );
        let cfg = self.store.config();
        for &(i, j, _) in items {
            ensure!(
                i < cfg.n1 && j < cfg.n2,
                "batch key ({i}, {j}) outside universe {}x{}",
                cfg.n1,
                cfg.n2
            );
        }
        if items.is_empty() {
            return Ok(());
        }
        if self.log.is_some() {
            // encoded straight from the slice — no owned WalRecord copy
            // of the batch on the hot path
            let mut payload = Vec::with_capacity(5 + items.len() * 16);
            WalRecord::encode_update_batch(&mut payload, items);
            let _shared = self.gate_shared();
            self.append_payload(&payload)?;
            self.store.update_batch(items);
        } else {
            self.store.update_batch(items);
        }
        Ok(())
    }

    /// Epoch rotation takes the commit gate **exclusively**: it does not
    /// commute with updates, so it must land in the same relative order
    /// in the WAL as in the store — otherwise recovery could assign a
    /// straddling update to a different epoch than the live store did.
    pub fn advance_epoch(&self) -> Result<()> {
        if self.log.is_some() {
            let _excl = self.gate_excl();
            self.append_record(&WalRecord::AdvanceEpoch)?;
            self.store.advance_epoch();
        } else {
            self.store.advance_epoch();
        }
        Ok(())
    }

    pub fn merge_sketch(&self, sk: &StreamSketch) -> Result<()> {
        ensure!(self.store.config().matches(sk), "sketch family does not match this store");
        if self.log.is_some() {
            // merges are counter additions — they commute with updates,
            // so a shared guard suffices (same as the update paths)
            let _shared = self.gate_shared();
            self.append_record(&WalRecord::MergeSketch(sk.clone()))?;
            self.store.merge_sketch(sk)
        } else {
            self.store.merge_sketch(sk)
        }
    }

    /// Apply one origin-headered merge frame: admit it against the
    /// per-origin dedup window, log it (ingest only), merge the
    /// admitted remainder, and commit the horizon — all under the
    /// shared commit gate, so a snapshot always captures the dedup
    /// table and the store at the same instant. Returns `true` when
    /// applied, `false` for a deduplicated retry (both are success).
    ///
    /// `ingest = true` counts as this node's own traffic: the applied
    /// remainder is WAL-logged as [`WalRecord::OriginMerge`] (replay
    /// re-commits the horizon) and re-originated to replication peers.
    /// `ingest = false` is the replication plane: deliberately **not**
    /// logged — after a restart the snapshot's origin record matches
    /// the snapshot's store image exactly, so the peer's next
    /// full-state ship re-delivers precisely the since-snapshot
    /// remainder; anti-entropy is the redo log for remote mass, and
    /// logging it too would double-count. Replica-plane merges also
    /// keep working on a fail-stopped log (no append happens).
    pub fn apply_origin_merge(
        &self,
        origin: u64,
        seq: u64,
        mode: u8,
        ingest: bool,
        sk: StreamSketch,
    ) -> Result<bool> {
        ensure!(self.store.config().matches(&sk), "sketch family does not match this store");
        let _shared = self.gate_shared();
        let mut origins = self.origins.lock().expect("origin table lock");
        let to_apply = match origins.admit(origin, seq, mode, sk)? {
            Admit::Dedup => return Ok(false),
            Admit::Apply(d) => d,
        };
        if ingest && self.log.is_some() {
            // logged as the already-computed remainder, so replay needs
            // no origin record from before the snapshot
            self.append_record(&WalRecord::OriginMerge { origin, seq, sketch: to_apply.clone() })?;
        }
        self.store.merge_sketch_opts(&to_apply, ingest)?;
        origins.commit(self.store.config(), origin, seq, &to_apply);
        Ok(true)
    }

    /// Start capturing locally-originated mass for the replicator (see
    /// [`ShardedStore::set_replication`]). Once a node has replicated,
    /// recovery re-enables this *before* WAL replay (the snapshot's
    /// replicate flag, a nonzero durable origin id, or a `ReplicaId`
    /// record in the tail), so replayed local records rebuild the
    /// origin accumulator and the durable cursors ship exactly the
    /// recovered-but-unshipped remainder.
    pub fn enable_replication(&self) {
        self.store.set_replication(true);
    }

    /// This node's stable replication origin id: derived once, logged
    /// as a [`WalRecord::ReplicaId`], and persisted in every snapshot,
    /// so a restarted sender keeps its channel identity and the
    /// receivers' cumulative per-origin records stay exact.
    pub fn replica_id(&self) -> Result<u64> {
        let _shared = self.gate_shared();
        let mut rc = self.replica.lock().expect("replica cursors lock");
        if rc.origin_id == 0 {
            let id = super::replica::derive_origin_id();
            if self.log.is_some() {
                self.append_record(&WalRecord::ReplicaId(id))?;
            }
            rc.origin_id = id;
        }
        Ok(rc.origin_id)
    }

    /// The durably acknowledged (sequence, origin-version) cursor for
    /// `peer`, if this node has ever logged an advance for it.
    pub fn replica_cursor(&self, peer: &str) -> Option<(u64, u64)> {
        self.replica.lock().expect("replica cursors lock").peers.get(peer).copied()
    }

    /// Durably record that `peer` acknowledged the frame at `seq`
    /// covering origin version `version`: logged first (one small WAL
    /// frame), then applied in memory. The replicator calls this after
    /// every ack and refuses to advance the channel until it succeeds —
    /// that discipline is what bounds the durable-cursor lag to one
    /// frame (see the module docs' cursor rules).
    pub fn advance_replica_cursor(&self, peer: &str, seq: u64, version: u64) -> Result<()> {
        let _shared = self.gate_shared();
        if self.log.is_some() {
            self.append_record(&WalRecord::CursorAdvance {
                peer: peer.to_string(),
                seq,
                version,
            })?;
        }
        self.replica.lock().expect("replica cursors lock").advance(peer, seq, version);
        Ok(())
    }

    /// `false` once the WAL has fail-stopped (a failed group write or
    /// rotation). The replicator gates idle heartbeats on this: a
    /// fail-stopped node must not keep advancing receiver horizons it
    /// can no longer durably record.
    pub fn wal_healthy(&self) -> bool {
        match &self.log {
            None => true,
            Some(log) => {
                let _ld = lockdep::acquire(lockdep::WAL_QUEUE, 0);
                let st = log.state.lock().expect("wal lock");
                st.writer.is_some() || st.writing
            }
        }
    }

    /// The (origin-version, cumulative local-origin sketch) pair the
    /// replicator diffs per-peer cursors against.
    pub fn origin_snapshot(&self) -> (u64, StreamSketch) {
        self.store.origin_snapshot()
    }

    /// Lock-free origin-version probe (see
    /// [`ShardedStore::origin_version`]).
    pub fn origin_version(&self) -> u64 {
        self.store.origin_version()
    }

    // -------- tensor plane --------
    //
    // Same log-then-apply discipline as the 2-D paths, with every check
    // that could fail at replay performed *before* the append — once a
    // tensor record is in the WAL it must apply, both live and on
    // recovery. Families are immutable and tensors are never deleted,
    // so a validation that passes pre-log stays true post-log.

    /// Create (or idempotently re-create) a named HCS tensor. The whole
    /// validate→log→apply sequence holds the `ddl` mutex, so two racing
    /// creates of the same name cannot both log — the WAL never records
    /// two contradictory families for one name. Returns `Ok(true)` when
    /// the tensor was created, `Ok(false)` (without logging) when an
    /// identical tensor already exists.
    pub fn tensor_create(&self, name: &str, family: &TensorFamily) -> Result<bool> {
        let _ld = lockdep::acquire(lockdep::DDL, 0);
        let _ddl = self.ddl.lock().expect("tensor ddl lock");
        family.validate()?;
        ensure!(!name.is_empty(), "tensor name is empty");
        ensure!(
            name.len() <= codec::MAX_TENSOR_NAME,
            "tensor name of {} bytes exceeds cap {}",
            name.len(),
            codec::MAX_TENSOR_NAME
        );
        if let Some(existing) = self.store.tensor_family(name) {
            ensure!(
                existing == *family,
                "tensor {name:?} already exists with a different family"
            );
            return Ok(false);
        }
        ensure!(
            self.store.tensor_names().len() < registry::MAX_TENSORS,
            "tensor catalog is full ({} tensors)",
            registry::MAX_TENSORS
        );
        if self.log.is_some() {
            let _shared = self.gate_shared();
            self.append_record(&WalRecord::TensorCreate {
                name: name.to_string(),
                family: family.clone(),
            })?;
            self.store.tensor_create(name, family)
        } else {
            self.store.tensor_create(name, family)
        }
    }

    /// One multi-mode update against a registered tensor: key validated
    /// against the tensor's declared dims, logged, applied.
    pub fn tensor_update(&self, name: &str, key: &[usize], w: f64) -> Result<()> {
        let family = self
            .store
            .tensor_family(name)
            .with_context(|| format!("unknown tensor {name:?}"))?;
        registry::validate_key(&family.dims, key)?;
        if self.log.is_some() {
            let _shared = self.gate_shared();
            self.append_record(&WalRecord::TensorUpdate {
                name: name.to_string(),
                key: key.to_vec(),
                w,
            })?;
            self.store.tensor_update(name, key, w)
        } else {
            self.store.tensor_update(name, key, w)
        }
    }

    /// Batched multi-mode updates: `keys` is `ws.len() × order` flat
    /// indices. One WAL frame, one fused in-memory apply — the tensor
    /// analogue of [`DurableStore::update_batch`], with the same
    /// validate-everything-before-logging rule.
    pub fn tensor_update_batch(&self, name: &str, keys: &[usize], ws: &[f64]) -> Result<()> {
        let family = self
            .store
            .tensor_family(name)
            .with_context(|| format!("unknown tensor {name:?}"))?;
        let order = family.order();
        ensure!(
            keys.len() == ws.len() * order,
            "batch of {} weights needs {} indices, got {}",
            ws.len(),
            ws.len() * order,
            keys.len()
        );
        ensure!(
            ws.len() <= MAX_WAL_BATCH,
            "tensor batch of {} updates exceeds the {MAX_WAL_BATCH}-item cap (split it)",
            ws.len()
        );
        for key in keys.chunks_exact(order) {
            registry::validate_key(&family.dims, key)?;
        }
        if ws.is_empty() {
            return Ok(());
        }
        if self.log.is_some() {
            let rec = WalRecord::TensorUpdateBatch {
                name: name.to_string(),
                keys: keys.to_vec(),
                ws: ws.to_vec(),
            };
            let _shared = self.gate_shared();
            self.append_record(&rec)?;
            self.store.tensor_update_batch(name, keys, ws)
        } else {
            self.store.tensor_update_batch(name, keys, ws)
        }
    }

    /// Apply one tensor replication frame (a peer's full cumulative
    /// origin state). Shared commit gate (so a snapshot captures the
    /// channel table and the sketch at the same instant), deliberately
    /// **not** WAL-logged — exactly like the 2-D replica-plane merges:
    /// the peer's next full-state ship re-delivers whatever a restart
    /// forgot, so anti-entropy is the redo log for remote tensor mass.
    pub fn tensor_apply_origin_merge(
        &self,
        origin: u64,
        name: &str,
        seq: u64,
        full: HcsStream,
    ) -> Result<bool> {
        let _shared = self.gate_shared();
        self.store.tensor_apply_origin_merge(origin, name, seq, full)
    }

    /// Point estimate for a multi-mode key (never logged).
    pub fn tensor_query(&self, name: &str, key: &[usize]) -> Result<f64> {
        self.store.tensor_query(name, key)
    }

    /// Marginal over any mode subset, computed on the sketch.
    pub fn tensor_marginal(&self, name: &str, spec: &[Option<usize>]) -> Result<f64> {
        self.store.tensor_marginal(name, spec)
    }

    /// Top-k keys within a fixed slice of one mode.
    pub fn tensor_slice_top_k(
        &self,
        name: &str,
        mode: usize,
        index: usize,
        k: usize,
    ) -> Result<Vec<(Vec<usize>, f64)>> {
        self.store.tensor_slice_top_k(name, mode, index, k)
    }

    /// Sketched contraction between two stored same-family tensors.
    pub fn tensor_contract(
        &self,
        a_name: &str,
        b_name: &str,
        contracted: &[usize],
    ) -> Result<ContractOutput> {
        self.store.tensor_contract(a_name, b_name, contracted)
    }

    /// Family of a registered tensor (`None` if unknown).
    pub fn tensor_family(&self, name: &str) -> Option<TensorFamily> {
        self.store.tensor_family(name)
    }

    /// Registered tensor names, in catalog order.
    pub fn tensor_names(&self) -> Vec<String> {
        self.store.tensor_names()
    }

    /// Tensor-plane origin-version probe for the replicator.
    pub fn tensor_version(&self) -> u64 {
        self.store.tensor_version()
    }

    /// Tensors with unshipped locally-originated mass (see
    /// [`ShardedStore::tensor_dirty_origins`]).
    pub fn tensor_dirty_origins(
        &self,
        acked: &HashMap<String, u64>,
    ) -> Vec<(String, u64, HcsStream)> {
        self.store.tensor_dirty_origins(acked)
    }

    // -------- queries (never logged) --------

    pub fn point_query(&self, i: usize, j: usize) -> f64 {
        self.store.point_query(i, j)
    }

    pub fn top_k(&self, k: usize) -> Vec<(usize, usize, f64)> {
        self.store.top_k(k)
    }

    pub fn heavy_hitters(&self, threshold: f64) -> Vec<(usize, usize, f64)> {
        self.store.heavy_hitters(threshold)
    }

    pub fn merged(&self) -> StreamSketch {
        self.store.merged()
    }

    pub fn stats(&self) -> StoreStats {
        self.store.stats()
    }

    /// Write a fresh snapshot (bumping the generation) and rotate the
    /// WAL. Errors for in-memory stores.
    ///
    /// The exclusive commit gate waits out every in-flight append→apply
    /// pair and blocks new ones, so the snapshot image contains exactly
    /// the records the rotated-away WAL held. If the snapshot file write
    /// fails, nothing rotated: the old WAL (whose generation still
    /// matches the on-disk snapshot) keeps accepting writes. If the
    /// snapshot succeeded but recreating the WAL fails, writes
    /// **fail-stop**: the disk now holds snapshot g+1 next to WAL g,
    /// and recovery (correctly) skips stale-generation records — so an
    /// append acknowledged into that stale log would be silently lost.
    /// Everything acknowledged before the failed rotation is already in
    /// the g+1 snapshot; reads keep working.
    pub fn snapshot(&self) -> Result<()> {
        let Some(log) = &self.log else {
            bail!("in-memory store has no snapshot directory (start with a data dir)");
        };
        let _excl = self.gate_excl();
        let _ldq = lockdep::acquire(lockdep::WAL_QUEUE, 0);
        let mut st = log.state.lock().expect("wal lock");
        // Every commit returns only after its frame is durable, and the
        // exclusive gate waits out every in-flight append→apply pair —
        // so with a live writer the queue is drained here. After a
        // fail-stop, NACKed frames (whose committers all saw errors)
        // can remain staged; a successful rotation below heals the
        // store onto a fresh generation, and those dead frames must not
        // leak into the new log.
        debug_assert!(
            st.writer.is_none() || (st.staged.is_empty() && !st.writing),
            "commit queue not drained under the exclusive gate"
        );
        st.staged.clear();
        self.generation.fetch_add(1, Ordering::SeqCst);
        match self.write_snapshot_file() {
            Ok(()) => {}
            Err(SnapInstall::NotInstalled(e)) => {
                // nothing was renamed: roll the in-memory generation
                // back so it keeps matching the snapshot + WAL pair on
                // disk, which is still valid and accepting writes
                self.generation.fetch_sub(1, Ordering::SeqCst);
                return Err(e);
            }
            Err(SnapInstall::Installed(e)) => {
                // the g+1 snapshot is installed but its durability is in
                // doubt and the WAL is still at g — appends there would
                // be skipped by recovery, so fail-stop
                st.writer = None;
                crate::obs::global().wal_fail_stops.inc();
                return Err(e.context(
                    "snapshot installed but not durably synced; \
                     fail-stopping writes (reopen the store to recover)",
                ));
            }
        }
        let dir = self.dir.as_ref().expect("durable store has a dir");
        match WalWriter::create(
            &dir.join(WAL_FILE),
            self.generation.load(Ordering::SeqCst),
            self.fsync,
        ) {
            Ok(w) => {
                st.writer = Some(w);
                crate::obs::global().wal_rotations.inc();
                Ok(())
            }
            Err(e) => {
                st.writer = None;
                crate::obs::global().wal_fail_stops.inc();
                Err(e.context(
                    "WAL rotation failed after the snapshot rename; \
                     fail-stopping writes (reopen the store to recover)",
                ))
            }
        }
    }

    fn write_snapshot_file(&self) -> std::result::Result<(), SnapInstall> {
        let Some(dir) = &self.dir else {
            return Err(SnapInstall::NotInstalled(anyhow::anyhow!(
                "in-memory store has no snapshot directory"
            )));
        };
        let pre_install = || -> Result<()> {
            let mut out = Vec::new();
            out.extend_from_slice(SNAP_MAGIC);
            codec::put_u32(&mut out, FORMAT_VERSION);
            codec::put_u64(&mut out, self.generation.load(Ordering::SeqCst));
            self.store.encode_into(&mut out);
            // the origin dedup table and the sender cursors ride in the
            // same image: all three are one instant here (open() is
            // single-threaded; snapshot() holds the commit gate
            // exclusively, and every origin merge / cursor advance runs
            // under a shared guard)
            self.origins.lock().expect("origin table lock").encode_into(&mut out);
            self.replica.lock().expect("replica cursors lock").encode_into(&mut out);
            let tmp = dir.join("snapshot.tmp");
            {
                let mut f = OpenOptions::new()
                    .write(true)
                    .create(true)
                    .truncate(true)
                    .open(&tmp)
                    .with_context(|| format!("creating {tmp:?}"))?;
                faults::write_all("snap.write", &mut f, &out)?;
                f.flush()?;
                // in fsync mode the rotation that follows makes this
                // snapshot the only copy of older records, so its bytes
                // must hit the platter before the rename installs it
                if self.fsync {
                    faults::fire("snap.sync")?;
                    f.sync_data().context("syncing snapshot")?;
                }
            }
            faults::fire("snap.rename").context("atomically replacing snapshot")?;
            fs::rename(&tmp, dir.join(SNAPSHOT_FILE))
                .context("atomically replacing snapshot")?;
            Ok(())
        };
        pre_install().map_err(SnapInstall::NotInstalled)?;
        if self.fsync {
            // rename durability: until the directory entry is synced,
            // power loss can surface the old snapshot next to a newer
            // WAL — callers must treat a failure here as fail-stop
            faults::fire("snap.dirsync")
                .context("syncing store dir after snapshot rename")
                .map_err(SnapInstall::Installed)?;
            File::open(dir)
                .and_then(|d| d.sync_all())
                .context("syncing store dir after snapshot rename")
                .map_err(SnapInstall::Installed)?;
        }
        Ok(())
    }
}

/// Which side of the rename a snapshot write failed on: before it the
/// old snapshot is still installed and the caller may keep writing;
/// after it the on-disk pair no longer matches the live WAL generation,
/// so the caller must fail-stop.
enum SnapInstall {
    NotInstalled(anyhow::Error),
    Installed(anyhow::Error),
}

/// Replay one record onto the store, validating against the config so a
/// corrupt-but-CRC-clean record cannot panic the recovery path. Origin
/// merges also re-commit their dedup horizon into `origins`, and cursor
/// records rebuild the sender state in `cursors`, so a recovered node
/// keeps recognizing re-delivered frames on both sides of a channel.
fn apply(
    store: &ShardedStore,
    origins: &mut OriginTable,
    cursors: &mut ReplicaCursors,
    rec: &WalRecord,
) -> Result<()> {
    let cfg = store.config();
    match rec {
        WalRecord::Update { i, j, w } => {
            let (i, j) = (*i as usize, *j as usize);
            ensure!(i < cfg.n1 && j < cfg.n2, "WAL update key ({i}, {j}) out of range");
            store.update(i, j, *w);
            Ok(())
        }
        WalRecord::AdvanceEpoch => {
            store.advance_epoch();
            Ok(())
        }
        WalRecord::MergeSketch(sk) => store.merge_sketch(sk),
        WalRecord::OriginMerge { origin, seq, sketch } => {
            // the logged sketch is the remainder that was applied live;
            // replay re-applies it and re-commits the horizon (replay
            // order is WAL order, so horizons advance monotonically)
            store.merge_sketch(sketch)?;
            origins.commit(cfg, *origin, *seq, sketch);
            Ok(())
        }
        WalRecord::UpdateBatch(items) => {
            let mut batch = Vec::with_capacity(items.len());
            for &(i, j, w) in items {
                let (i, j) = (i as usize, j as usize);
                ensure!(i < cfg.n1 && j < cfg.n2, "WAL batch key ({i}, {j}) out of range");
                batch.push((i, j, w));
            }
            // same fused kernel the live path used — replay stays
            // bit-identical
            store.update_batch(&batch);
            Ok(())
        }
        WalRecord::CursorAdvance { peer, seq, version } => {
            cursors.advance(peer, *seq, *version);
            Ok(())
        }
        WalRecord::ReplicaId(id) => {
            cursors.origin_id = *id;
            Ok(())
        }
        // Tensor records replay through the same ShardedStore entry
        // points the live path used, so an update re-originates exactly
        // when replication was re-enabled before replay — matching the
        // 2-D records above.
        WalRecord::TensorCreate { name, family } => store.tensor_create(name, family).map(|_| ()),
        WalRecord::TensorUpdate { name, key, w } => store.tensor_update(name, key, *w),
        WalRecord::TensorUpdateBatch { name, keys, ws } => {
            store.tensor_update_batch(name, keys, ws)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn cfg() -> StoreConfig {
        StoreConfig { n1: 40, n2: 32, m1: 10, m2: 8, d: 5, seed: 31, shards: 3, window: 3 }
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let p = std::env::temp_dir()
            .join(format!("hocs_store_wal_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&p);
        fs::create_dir_all(&p).unwrap();
        p
    }

    fn int_weight(rng: &mut Pcg64) -> f64 {
        (1 + rng.gen_range(9)) as f64
    }

    #[test]
    fn record_roundtrip() {
        let mut sk = StreamSketch::new(8, 8, 4, 4, 3, 1);
        sk.update(1, 2, 3.0);
        let osk = sk.clone();
        for rec in [
            WalRecord::Update { i: 3, j: 9, w: -2.5 },
            WalRecord::AdvanceEpoch,
            WalRecord::MergeSketch(sk),
            WalRecord::UpdateBatch(vec![(1, 2, 3.5), (4, 5, -6.0), (0, 0, 0.25)]),
            WalRecord::OriginMerge { origin: 0xBEEF, seq: 42, sketch: osk },
            WalRecord::CursorAdvance { peer: "10.0.0.7:7878".to_string(), seq: 9, version: 17 },
            WalRecord::ReplicaId(0xABCD_EF01),
            WalRecord::TensorCreate { name: "act".to_string(), family: tfam() },
            WalRecord::TensorUpdate { name: "act".to_string(), key: vec![1, 2, 3], w: -2.5 },
            WalRecord::TensorUpdateBatch {
                name: "act".to_string(),
                keys: vec![1, 2, 3, 19, 15, 11],
                ws: vec![4.0, -0.5],
            },
        ] {
            let mut out = Vec::new();
            rec.encode(&mut out);
            let got = WalRecord::decode(&mut Reader::new(&out)).unwrap();
            match (&rec, &got) {
                (
                    WalRecord::Update { i, j, w },
                    WalRecord::Update { i: gi, j: gj, w: gw },
                ) => {
                    assert_eq!((i, j), (gi, gj));
                    assert_eq!(w.to_bits(), gw.to_bits());
                }
                (WalRecord::AdvanceEpoch, WalRecord::AdvanceEpoch) => {}
                (WalRecord::MergeSketch(a), WalRecord::MergeSketch(b)) => {
                    assert!(a.same_family(b));
                    assert_eq!(a.table(0), b.table(0));
                }
                (WalRecord::UpdateBatch(a), WalRecord::UpdateBatch(b)) => {
                    assert_eq!(a.len(), b.len());
                    for ((ai, aj, aw), (bi, bj, bw)) in a.iter().zip(b.iter()) {
                        assert_eq!((ai, aj), (bi, bj));
                        assert_eq!(aw.to_bits(), bw.to_bits());
                    }
                }
                (
                    WalRecord::OriginMerge { origin, seq, sketch },
                    WalRecord::OriginMerge { origin: go, seq: gs, sketch: gsk },
                ) => {
                    assert_eq!((origin, seq), (go, gs));
                    assert!(sketch.same_family(gsk));
                    assert_eq!(sketch.table(0), gsk.table(0));
                }
                (
                    WalRecord::CursorAdvance { peer, seq, version },
                    WalRecord::CursorAdvance { peer: gp, seq: gs, version: gv },
                ) => assert_eq!((peer, seq, version), (gp, gs, gv)),
                (WalRecord::ReplicaId(a), WalRecord::ReplicaId(b)) => assert_eq!(a, b),
                (
                    WalRecord::TensorCreate { name, family },
                    WalRecord::TensorCreate { name: gn, family: gf },
                ) => assert_eq!((name, family), (gn, gf)),
                (
                    WalRecord::TensorUpdate { name, key, w },
                    WalRecord::TensorUpdate { name: gn, key: gk, w: gw },
                ) => {
                    assert_eq!((name, key), (gn, gk));
                    assert_eq!(w.to_bits(), gw.to_bits());
                }
                (
                    WalRecord::TensorUpdateBatch { name, keys, ws },
                    WalRecord::TensorUpdateBatch { name: gn, keys: gk, ws: gw },
                ) => {
                    assert_eq!((name, keys), (gn, gk));
                    assert_eq!(ws.len(), gw.len());
                    for (a, b) in ws.iter().zip(gw.iter()) {
                        assert_eq!(a.to_bits(), b.to_bits());
                    }
                }
                other => panic!("variant mismatch: {other:?}"),
            }
        }
    }

    #[test]
    fn crash_recovery_replays_wal_tail() {
        let dir = tmpdir("replay");
        let shadow = ShardedStore::new(cfg());
        let mut rng = Pcg64::new(2);
        {
            let live = DurableStore::open(&dir, cfg()).unwrap();
            for _ in 0..200 {
                let (i, j) = (rng.gen_range(40) as usize, rng.gen_range(32) as usize);
                let w = int_weight(&mut rng);
                live.update(i, j, w).unwrap();
                shadow.update(i, j, w);
            }
            live.snapshot().unwrap();
            live.advance_epoch().unwrap();
            shadow.advance_epoch();
            for _ in 0..150 {
                let (i, j) = (rng.gen_range(40) as usize, rng.gen_range(32) as usize);
                let w = int_weight(&mut rng);
                live.update(i, j, w).unwrap();
                shadow.update(i, j, w);
            }
            // dropped without a final snapshot: the tail lives in the WAL
        }
        let recovered = DurableStore::open(&dir, cfg()).unwrap();
        assert_eq!(recovered.stats(), shadow.stats());
        for i in 0..40 {
            for j in 0..32 {
                assert_eq!(
                    recovered.point_query(i, j).to_bits(),
                    shadow.point_query(i, j).to_bits(),
                    "key ({i}, {j})"
                );
            }
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn recovery_without_any_snapshot_call() {
        // never snapshot explicitly: open() writes the initial snapshot,
        // everything else must come back from the WAL alone
        let dir = tmpdir("wal_only");
        let shadow = ShardedStore::new(cfg());
        {
            let live = DurableStore::open(&dir, cfg()).unwrap();
            live.update(3, 4, 7.0).unwrap();
            live.update(9, 9, 2.0).unwrap();
            let mut remote = cfg().fresh_sketch();
            remote.update(3, 4, 1.0);
            live.merge_sketch(&remote).unwrap();
            shadow.update(3, 4, 7.0);
            shadow.update(9, 9, 2.0);
            shadow.merge_sketch(&remote).unwrap();
        }
        let recovered = DurableStore::open(&dir, cfg()).unwrap();
        assert_eq!(recovered.point_query(3, 4).to_bits(), shadow.point_query(3, 4).to_bits());
        assert_eq!(recovered.point_query(9, 9).to_bits(), shadow.point_query(9, 9).to_bits());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_wal_tail_is_ignored() {
        let dir = tmpdir("torn");
        {
            let live = DurableStore::open(&dir, cfg()).unwrap();
            live.update(1, 1, 5.0).unwrap();
        }
        // simulate a crash mid-append: a frame header promising more
        // payload than was written
        {
            let mut f = OpenOptions::new().append(true).open(dir.join(WAL_FILE)).unwrap();
            f.write_all(&100u32.to_le_bytes()).unwrap();
            f.write_all(&0xDEAD_BEEFu32.to_le_bytes()).unwrap();
            f.write_all(&[1, 2, 3]).unwrap();
        }
        let recovered = DurableStore::open(&dir, cfg()).unwrap();
        assert_eq!(recovered.point_query(1, 1), 5.0);
        // and the healed store keeps accepting writes
        recovered.update(2, 2, 1.0).unwrap();
        assert_eq!(recovered.point_query(2, 2), 1.0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_frame_stops_replay_cleanly() {
        let dir = tmpdir("crc");
        {
            let live = DurableStore::open(&dir, cfg()).unwrap();
            live.update(1, 1, 5.0).unwrap();
            live.update(2, 2, 6.0).unwrap();
        }
        // flip one payload byte of the last frame: CRC must catch it and
        // recovery keeps everything before that frame
        {
            let path = dir.join(WAL_FILE);
            let mut bytes = fs::read(&path).unwrap();
            let last = bytes.len() - 1;
            bytes[last] ^= 0xFF;
            fs::write(&path, &bytes).unwrap();
        }
        let recovered = DurableStore::open(&dir, cfg()).unwrap();
        assert_eq!(recovered.point_query(1, 1), 5.0);
        assert_eq!(recovered.point_query(2, 2), 0.0, "corrupt record must not replay");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_wal_generation_is_not_double_applied() {
        // simulate a crash *between* snapshot rename and WAL truncation:
        // the snapshot already contains the WAL's records, so replaying
        // them would double-count
        let dir = tmpdir("stale_gen");
        {
            let live = DurableStore::open(&dir, cfg()).unwrap();
            live.update(1, 1, 5.0).unwrap();
            // keep a copy of the record-bearing WAL
            fs::copy(dir.join(WAL_FILE), dir.join("wal.old")).unwrap();
            live.snapshot().unwrap(); // snapshot g+1 + fresh WAL g+1
        }
        // crash left the old WAL (generation g) next to snapshot g+1
        fs::copy(dir.join("wal.old"), dir.join(WAL_FILE)).unwrap();
        let recovered = DurableStore::open(&dir, cfg()).unwrap();
        assert_eq!(
            recovered.point_query(1, 1),
            5.0,
            "stale WAL record was double-applied"
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn update_batch_is_one_wal_frame_and_replays_exactly() {
        let dir = tmpdir("group_commit");
        let shadow = ShardedStore::new(cfg());
        {
            let live = DurableStore::open(&dir, cfg()).unwrap();
            let mut rng = Pcg64::new(3);
            let items: Vec<(usize, usize, f64)> = (0..100)
                .map(|_| {
                    (
                        rng.gen_range(40) as usize,
                        rng.gen_range(32) as usize,
                        int_weight(&mut rng),
                    )
                })
                .collect();
            live.update_batch(&items).unwrap();
            for &(i, j, w) in &items {
                shadow.update(i, j, w);
            }
            // the whole batch must be one group-commit frame
            let (_, records) = read_wal(&dir.join(WAL_FILE)).unwrap();
            assert_eq!(records.len(), 1, "group commit must write one frame per batch");
            assert!(
                matches!(records[0], WalRecord::UpdateBatch(ref v) if v.len() == 100),
                "unexpected record: {:?}",
                records[0]
            );
            // crash without snapshot: the batch replays from its frame
        }
        let recovered = DurableStore::open(&dir, cfg()).unwrap();
        assert_eq!(recovered.stats(), shadow.stats());
        for i in 0..40 {
            for j in 0..32 {
                assert_eq!(
                    recovered.point_query(i, j).to_bits(),
                    shadow.point_query(i, j).to_bits(),
                    "key ({i}, {j})"
                );
            }
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn failed_wal_rotation_fail_stops_writes() {
        let dir = tmpdir("failstop");
        {
            let live = DurableStore::open(&dir, cfg()).unwrap();
            live.update(1, 1, 5.0).unwrap();
            // fault injection: replace wal.bin with a directory, so the
            // rotation's tmp-file rename over it must fail *after* the
            // snapshot rename succeeded
            fs::remove_file(dir.join(WAL_FILE)).unwrap();
            fs::create_dir(dir.join(WAL_FILE)).unwrap();
            assert!(live.snapshot().is_err());
            // writes must fail-stop: an append acknowledged into the
            // stale-generation log would be silently skipped on recovery
            assert!(live.update(2, 2, 1.0).is_err());
            assert!(live.update_batch(&[(3, 3, 1.0)]).is_err());
            assert!(live.advance_epoch().is_err());
            // reads keep working on the in-memory state
            assert_eq!(live.point_query(1, 1), 5.0);
        }
        fs::remove_dir_all(dir.join(WAL_FILE)).unwrap();
        // everything acknowledged before the failed rotation was already
        // inside the g+1 snapshot — no data loss, no double-apply
        let recovered = DurableStore::open(&dir, cfg()).unwrap();
        assert_eq!(recovered.point_query(1, 1), 5.0);
        assert_eq!(recovered.point_query(2, 2), 0.0, "failed write must not resurface");
        assert_eq!(recovered.point_query(3, 3), 0.0, "failed batch must not resurface");
        // and the reopened store accepts writes again
        recovered.update(4, 4, 2.0).unwrap();
        assert_eq!(recovered.point_query(4, 4), 2.0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn concurrent_durable_writers_recover_exactly() {
        // the log lock is no longer held across the in-memory apply;
        // four threads of integer-weight traffic must still recover to
        // exactly the reference state (counter sums commute)
        let dir = tmpdir("mt_writers");
        let shadow = ShardedStore::new(cfg());
        {
            let live = DurableStore::open(&dir, cfg()).unwrap();
            std::thread::scope(|scope| {
                for t in 0..4u64 {
                    let live = &live;
                    scope.spawn(move || {
                        let mut rng = Pcg64::new(90 + t);
                        for step in 0..120 {
                            let (i, j) =
                                (rng.gen_range(40) as usize, rng.gen_range(32) as usize);
                            let w = (1 + rng.gen_range(9)) as f64;
                            if step % 3 == 0 {
                                live.update_batch(&[(i, j, w), (i, j, w)]).unwrap();
                            } else {
                                live.update(i, j, w).unwrap();
                            }
                        }
                    });
                }
            });
            for t in 0..4u64 {
                let mut rng = Pcg64::new(90 + t);
                for step in 0..120 {
                    let (i, j) = (rng.gen_range(40) as usize, rng.gen_range(32) as usize);
                    let w = (1 + rng.gen_range(9)) as f64;
                    let reps = if step % 3 == 0 { 2 } else { 1 };
                    for _ in 0..reps {
                        shadow.update(i, j, w);
                    }
                }
            }
            assert_eq!(live.stats(), shadow.stats());
        }
        let recovered = DurableStore::open(&dir, cfg()).unwrap();
        assert_eq!(recovered.stats(), shadow.stats());
        for i in 0..40 {
            for j in 0..32 {
                assert_eq!(
                    recovered.point_query(i, j).to_bits(),
                    shadow.point_query(i, j).to_bits(),
                    "key ({i}, {j})"
                );
            }
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn concurrent_group_commit_writers_preserve_every_frame() {
        // eight un-batched writers race through the leader/follower
        // queue; every acknowledged update must survive as its own
        // intact frame inside the coalesced leader writes
        let dir = tmpdir("group_cc");
        {
            let live = DurableStore::open_opts(
                &dir,
                cfg(),
                DurableOptions { fsync: false, group_commit: true },
            )
            .unwrap();
            std::thread::scope(|scope| {
                for t in 0..8u64 {
                    let live = &live;
                    scope.spawn(move || {
                        for s in 0..50u64 {
                            let i = ((t * 50 + s) % 40) as usize;
                            live.update(i, (s % 32) as usize, 1.0).unwrap();
                        }
                    });
                }
            });
            assert_eq!(live.stats().updates, 400);
        }
        let (_, records) = read_wal(&dir.join(WAL_FILE)).unwrap();
        assert_eq!(records.len(), 400, "a group write dropped or merged frames");
        assert!(records.iter().all(|r| matches!(r, WalRecord::Update { .. })));
        let recovered = DurableStore::open(&dir, cfg()).unwrap();
        assert_eq!(recovered.stats().updates, 400);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn group_commit_off_path_round_trips() {
        // the per-record baseline stays a first-class path (it is the
        // bench's comparison point): concurrent writers recover exactly
        let dir = tmpdir("no_group");
        let shadow = ShardedStore::new(cfg());
        {
            let live = DurableStore::open_opts(
                &dir,
                cfg(),
                DurableOptions { fsync: false, group_commit: false },
            )
            .unwrap();
            std::thread::scope(|scope| {
                for t in 0..4u64 {
                    let live = &live;
                    scope.spawn(move || {
                        let mut rng = Pcg64::new(500 + t);
                        for _ in 0..80 {
                            let (i, j) =
                                (rng.gen_range(40) as usize, rng.gen_range(32) as usize);
                            live.update(i, j, (1 + rng.gen_range(9)) as f64).unwrap();
                        }
                    });
                }
            });
            for t in 0..4u64 {
                let mut rng = Pcg64::new(500 + t);
                for _ in 0..80 {
                    let (i, j) = (rng.gen_range(40) as usize, rng.gen_range(32) as usize);
                    shadow.update(i, j, (1 + rng.gen_range(9)) as f64);
                }
            }
            assert_eq!(live.stats(), shadow.stats());
        }
        let recovered = DurableStore::open(&dir, cfg()).unwrap();
        assert_eq!(recovered.stats(), shadow.stats());
        for i in 0..40 {
            for j in 0..32 {
                assert_eq!(
                    recovered.point_query(i, j).to_bits(),
                    shadow.point_query(i, j).to_bits(),
                    "key ({i}, {j})"
                );
            }
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn fsync_mode_round_trips() {
        let dir = tmpdir("fsync");
        let shadow = ShardedStore::new(cfg());
        {
            let live = DurableStore::open_with(&dir, cfg(), true).unwrap();
            live.update(1, 2, 3.0).unwrap();
            live.update_batch(&[(4, 5, 6.0), (1, 2, 1.0)]).unwrap();
            live.snapshot().unwrap();
            live.update(7, 7, 2.0).unwrap(); // post-rotation append, synced
        }
        shadow.update(1, 2, 3.0);
        shadow.update_batch(&[(4, 5, 6.0), (1, 2, 1.0)]);
        shadow.update(7, 7, 2.0);
        let recovered = DurableStore::open_with(&dir, cfg(), true).unwrap();
        assert_eq!(recovered.stats(), shadow.stats());
        for &(i, j) in &[(1usize, 2usize), (4, 5), (7, 7), (0, 0)] {
            assert_eq!(
                recovered.point_query(i, j).to_bits(),
                shadow.point_query(i, j).to_bits(),
                "key ({i}, {j})"
            );
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn origin_dedup_horizon_survives_crash_and_snapshot() {
        use crate::store::replica::wire::{MODE_DELTA, MODE_FULL};
        let dir = tmpdir("origin_replay");
        let mut d1 = cfg().fresh_sketch();
        d1.update(1, 1, 5.0);
        {
            let live = DurableStore::open(&dir, cfg()).unwrap();
            // ingest origin-merge: logged as an OriginMerge record
            assert!(live.apply_origin_merge(9, 1, MODE_DELTA, true, d1.clone()).unwrap());
            assert!(!live.apply_origin_merge(9, 1, MODE_DELTA, true, d1.clone()).unwrap());
            assert_eq!(live.point_query(1, 1), 5.0);
            // crash without snapshot: the horizon must replay from the WAL
        }
        {
            let re = DurableStore::open(&dir, cfg()).unwrap();
            assert_eq!(re.point_query(1, 1), 5.0);
            // the re-delivered frame is still recognized after recovery
            assert!(!re.apply_origin_merge(9, 1, MODE_DELTA, true, d1.clone()).unwrap());
            assert_eq!(re.point_query(1, 1), 5.0, "replayed horizon lost: double count");
            // a full ship applies only the remainder: the cumulative
            // record also survived
            let mut full = cfg().fresh_sketch();
            full.update(1, 1, 5.0);
            full.update(2, 2, 3.0);
            assert!(re.apply_origin_merge(9, 7, MODE_FULL, true, full).unwrap());
            assert_eq!(re.point_query(1, 1), 5.0, "full ship double-counted");
            assert_eq!(re.point_query(2, 2), 3.0);
            re.snapshot().unwrap(); // horizon now persisted in the image
        }
        let re2 = DurableStore::open(&dir, cfg()).unwrap();
        // recognized via the snapshot's origin table (the WAL was rotated)
        let mut full2 = cfg().fresh_sketch();
        full2.update(1, 1, 5.0);
        full2.update(2, 2, 3.0);
        assert!(!re2.apply_origin_merge(9, 7, MODE_FULL, true, full2).unwrap());
        assert_eq!(re2.point_query(1, 1), 5.0);
        assert_eq!(re2.point_query(2, 2), 3.0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn replica_plane_mass_is_volatile_and_full_ships_resync_exactly() {
        use crate::store::replica::wire::{MODE_DELTA, MODE_FULL};
        let dir = tmpdir("replica_volatile");
        let mut d1 = cfg().fresh_sketch();
        d1.update(4, 4, 6.0);
        {
            let live = DurableStore::open(&dir, cfg()).unwrap();
            live.update(1, 1, 2.0).unwrap(); // local mass: WAL-logged
            // replication-plane merge (ingest = false): NOT logged
            assert!(live.apply_origin_merge(5, 1, MODE_DELTA, false, d1.clone()).unwrap());
            assert_eq!(live.point_query(4, 4), 6.0);
            // crash: remote mass and its origin record die together
        }
        let re = DurableStore::open(&dir, cfg()).unwrap();
        assert_eq!(re.point_query(1, 1), 2.0, "local mass must recover");
        assert_eq!(re.point_query(4, 4), 0.0, "replica mass is anti-entropy's to restore");
        // the peer's full-state ship re-delivers everything exactly once
        // (this is the sender's gap → full fallback after our restart)
        let mut full = cfg().fresh_sketch();
        full.update(4, 4, 6.0);
        full.update(6, 6, 1.0);
        assert!(re.apply_origin_merge(5, 2, MODE_FULL, false, full).unwrap());
        assert_eq!(re.point_query(4, 4), 6.0, "full ship lost or doubled remote mass");
        assert_eq!(re.point_query(6, 6), 1.0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn mismatched_config_is_rejected() {
        let dir = tmpdir("cfg");
        {
            DurableStore::open(&dir, cfg()).unwrap();
        }
        let mut other = cfg();
        other.seed = 999;
        assert!(DurableStore::open(&dir, other).is_err());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn in_memory_store_has_no_snapshot() {
        let ds = DurableStore::in_memory(cfg());
        ds.update(1, 1, 1.0).unwrap();
        assert!(ds.snapshot().is_err());
        assert_eq!(ds.point_query(1, 1), 1.0);
    }

    #[test]
    fn replica_id_and_cursors_survive_wal_replay_and_snapshot() {
        let dir = tmpdir("cursors");
        let id = {
            let live = DurableStore::open(&dir, cfg()).unwrap();
            live.enable_replication();
            let id = live.replica_id().unwrap();
            assert_ne!(id, 0);
            assert_eq!(live.replica_id().unwrap(), id, "id must be derived once");
            assert_eq!(live.replica_cursor("peer:1"), None);
            live.advance_replica_cursor("peer:1", 3, 7).unwrap();
            live.advance_replica_cursor("peer:1", 4, 9).unwrap();
            live.advance_replica_cursor("peer:2", 1, 2).unwrap();
            // a replayed stale advance must never move a cursor back
            live.advance_replica_cursor("peer:1", 2, 5).unwrap();
            assert_eq!(live.replica_cursor("peer:1"), Some((4, 9)));
            id
            // crash without snapshot: everything must replay from the WAL
        };
        {
            let re = DurableStore::open(&dir, cfg()).unwrap();
            assert_eq!(re.replica_id().unwrap(), id, "durable origin id lost");
            assert_eq!(re.replica_cursor("peer:1"), Some((4, 9)));
            assert_eq!(re.replica_cursor("peer:2"), Some((1, 2)));
            assert!(
                re.store().replication_enabled(),
                "a node that ever replicated must recover replicating"
            );
            re.snapshot().unwrap(); // cursors now persisted in the image
        }
        let re2 = DurableStore::open(&dir, cfg()).unwrap();
        assert_eq!(re2.replica_id().unwrap(), id);
        assert_eq!(re2.replica_cursor("peer:1"), Some((4, 9)));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn origin_accumulator_recovers_recovered_but_unshipped_mass() {
        // the durable-cursor contract: after a sender crash, the origin
        // accumulator rebuilt from snapshot + WAL replay holds exactly
        // the cumulative local mass, so `full − receiver's record` is
        // exactly the unshipped remainder
        let dir = tmpdir("origin_acc");
        let mut expect = cfg().fresh_sketch();
        {
            let live = DurableStore::open(&dir, cfg()).unwrap();
            live.enable_replication();
            live.replica_id().unwrap();
            live.update(1, 2, 3.0).unwrap();
            live.update_batch(&[(4, 5, 6.0), (7, 7, 2.0)]).unwrap();
            expect.update(1, 2, 3.0);
            expect.update(4, 5, 6.0);
            expect.update(7, 7, 2.0);
            live.snapshot().unwrap(); // accumulator rides in the image
            live.update(9, 9, 4.0).unwrap(); // post-snapshot: WAL only
            expect.update(9, 9, 4.0);
        }
        let re = DurableStore::open(&dir, cfg()).unwrap();
        let (version, acc) = re.origin_snapshot();
        assert!(version > 0, "recovered origin version must be stamped");
        assert!(expect.same_family(&acc));
        assert_eq!(acc.updates, expect.updates, "accumulator lost or doubled mass");
        for r in 0..expect.d {
            for (a, b) in acc.table(r).iter().zip(expect.table(r).iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "repeat {r}");
            }
        }
        let _ = fs::remove_dir_all(&dir);
    }

    fn tfam() -> TensorFamily {
        TensorFamily { dims: vec![20, 16, 12], sketch_dims: vec![6, 5, 4], d: 3, seed: 42 }
    }

    #[test]
    fn tensor_plane_survives_crash_and_snapshot() {
        // create + updates + batch before the snapshot, a WAL-only tail
        // after it; recovery must rebuild the catalog bit-identically to
        // a shadow store fed the same stream
        let dir = tmpdir("tensor");
        let shadow = ShardedStore::new(cfg());
        shadow.tensor_create("act", &tfam()).unwrap();
        {
            let live = DurableStore::open(&dir, cfg()).unwrap();
            assert!(live.tensor_create("act", &tfam()).unwrap());
            assert!(!live.tensor_create("act", &tfam()).unwrap(), "re-create must be a no-op");
            let mut other = tfam();
            other.d = 5;
            assert!(live.tensor_create("act", &other).is_err(), "family change must fail");
            assert!(live.tensor_update("ghost", &[0, 0, 0], 1.0).is_err());
            assert!(live.tensor_update("act", &[20, 0, 0], 1.0).is_err(), "index out of range");
            assert!(live.tensor_update("act", &[1, 2], 1.0).is_err(), "order mismatch");

            let mut rng = Pcg64::new(7);
            for _ in 0..60 {
                let key = [
                    rng.gen_range(20) as usize,
                    rng.gen_range(16) as usize,
                    rng.gen_range(12) as usize,
                ];
                let w = int_weight(&mut rng);
                live.tensor_update("act", &key, w).unwrap();
                shadow.tensor_update("act", &key, w).unwrap();
            }
            live.snapshot().unwrap();
            // post-snapshot tail: one batch + one point update, WAL only
            let keys = [1usize, 2, 3, 19, 15, 11, 0, 0, 0];
            let ws = [4.0, -1.0, 2.5];
            live.tensor_update_batch("act", &keys, &ws).unwrap();
            shadow.tensor_update_batch("act", &keys, &ws).unwrap();
            live.tensor_update("act", &[5, 6, 7], 9.0).unwrap();
            shadow.tensor_update("act", &[5, 6, 7], 9.0).unwrap();
        }
        let re = DurableStore::open(&dir, cfg()).unwrap();
        assert_eq!(re.tensor_names(), vec!["act".to_string()]);
        assert_eq!(re.tensor_family("act"), Some(tfam()));
        let mut rng = Pcg64::new(8);
        for _ in 0..200 {
            let key = [
                rng.gen_range(20) as usize,
                rng.gen_range(16) as usize,
                rng.gen_range(12) as usize,
            ];
            assert_eq!(
                re.tensor_query("act", &key).unwrap().to_bits(),
                shadow.tensor_query("act", &key).unwrap().to_bits(),
                "key {key:?}"
            );
        }
        let spec = [Some(1), None, None];
        assert_eq!(
            re.tensor_marginal("act", &spec).unwrap().to_bits(),
            shadow.tensor_marginal("act", &spec).unwrap().to_bits()
        );
        assert_eq!(re.stats(), shadow.stats(), "tensor updates lost from stats");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn tensor_replica_mass_is_volatile_and_full_ships_resync_exactly() {
        // replica-plane tensor merges are never WAL-logged: after a
        // crash the peer's next full-state ship must re-deliver exactly
        // what was forgotten (the channel record rides in snapshots, and
        // here the crash predates any snapshot of it)
        let dir = tmpdir("tensor_replica");
        let mut full = tfam().fresh();
        full.update(&[1, 2, 3], 5.0);
        full.update(&[4, 5, 6], 2.0);
        {
            let live = DurableStore::open(&dir, cfg()).unwrap();
            live.tensor_create("act", &tfam()).unwrap();
            assert!(live.tensor_apply_origin_merge(0xBEEF, "act", 3, full.clone()).unwrap());
            assert!(
                !live.tensor_apply_origin_merge(0xBEEF, "act", 3, full.clone()).unwrap(),
                "same seq must dedup"
            );
            assert_eq!(
                live.tensor_query("act", &[1, 2, 3]).unwrap().to_bits(),
                full.query(&[1, 2, 3]).to_bits()
            );
            // crash without snapshot: the create replays, the merge does not
        }
        let re = DurableStore::open(&dir, cfg()).unwrap();
        assert_eq!(
            re.tensor_query("act", &[1, 2, 3]).unwrap(),
            0.0,
            "unlogged replica mass must not replay"
        );
        // anti-entropy redo: the peer re-ships its cumulative state and
        // the recovered (empty) channel record admits all of it
        assert!(re.tensor_apply_origin_merge(0xBEEF, "act", 3, full.clone()).unwrap());
        assert_eq!(
            re.tensor_query("act", &[1, 2, 3]).unwrap().to_bits(),
            full.query(&[1, 2, 3]).to_bits()
        );
        assert_eq!(
            re.tensor_query("act", &[4, 5, 6]).unwrap().to_bits(),
            full.query(&[4, 5, 6]).to_bits()
        );
        let _ = fs::remove_dir_all(&dir);
    }
}
