//! Wire opcodes — the single source of truth for the store protocol's
//! request surface.
//!
//! Every opcode lives here exactly once, together with the name of the
//! typed [`super::client::StoreClient`] method that speaks it and the
//! `hocs store-client` CLI verb that exposes it (when one does — pure
//! machine-plane opcodes like replication frames deliberately have
//! none). The [`ALL`] table is what the `opcode-symmetry` lint pass
//! ([`crate::analysis`]) cross-checks: an opcode added here without a
//! server dispatch arm, a client method, or its declared CLI verb —
//! or an `op::X` reference in server/client code that this table does
//! not know — fails `hocs lint`.
//!
//! TOPK and HEAVY run the marginal-pruned scans for non-negative
//! workloads; once any deletion has been absorbed the merged sketch
//! carries its turnstile flag and the scans route themselves to the
//! dense variants (see [`crate::sketch::stream`]), so both opcodes are
//! correct under any workload. QUERY is exact either way.
//! UPDATE_BATCH is the write hot path: one WAL group-commit frame and
//! one lock acquisition per destination shard for the whole batch.

pub const UPDATE: u8 = 1;
pub const UPDATE_BATCH: u8 = 2;
pub const QUERY: u8 = 3;
pub const TOPK: u8 = 4;
pub const HEAVY: u8 = 5;
pub const MERGE: u8 = 6;
pub const SNAPSHOT: u8 = 7;
pub const ADVANCE_EPOCH: u8 = 8;
pub const STATS: u8 = 9;
pub const BATCH_SKETCH: u8 = 10;
pub const SHUTDOWN: u8 = 11;
/// Origin-headered merge (replication plane + retry-safe edge
/// ingest): `u64 origin | u64 seq | u8 mode | u8 enc | u8 ingest |
/// sketch`, deduplicated per origin — see [`crate::store::replica`].
pub const MERGE_ORIGIN: u8 = 12;
// ---- tensor plane (multi-mode HCS catalog — see `store::tensor`) ----
/// `name | TensorFamily` → `u8 created` (0 = identical tensor
/// already existed; a different family errors).
pub const TCREATE: u8 = 13;
/// `name | mode_key | f64 w` — one multi-mode update.
pub const TUPDATE: u8 = 14;
/// `name | u32 count | count × (mode_key | f64 w)` — one WAL
/// group-commit frame and one fused apply for the whole batch.
pub const TUPDATE_BATCH: u8 = 15;
/// `name | mode_key` → `f64` median-of-d point estimate.
pub const TQUERY: u8 = 16;
/// `name | per mode (u8 flag | u32 index if flag = 1)` → `f64`:
/// marginal with flagged modes pinned and the rest summed out on
/// the sketch.
pub const MARGINAL: u8 = 17;
/// `name | u32 mode | u32 index | u32 k` → `u32 count | count ×
/// (mode_key | f64)`: top-k keys within one fixed slice.
pub const SLICE_TOPK: u8 = 18;
/// `a_name | b_name | u8 n | n × u8 modes | u8 want_dense` →
/// `u8 kind | payload`: kind 0 = `f64` scalar (all modes
/// contracted), 1 = encoded `ContractedSketch`, 2 = dense result
/// (`u8 n_kept | n_kept × u32 dims | u32 len | len × f64`, laid out
/// `kept keys of a × kept keys of b`, row-major).
pub const CONTRACT: u8 = 19;
/// Tensor replication frame: `u64 origin | u64 seq | name |
/// HcsStream (full cumulative origin state)` → `u8 applied`.
/// Unknown tensors are auto-created from the frame's family;
/// per-(origin, tensor) sequence dedup makes retries no-ops.
pub const TMERGE_ORIGIN: u8 = 20;
/// Empty body → Prometheus-style text: the whole observability plane
/// ([`crate::obs`]) — per-opcode request histograms, WAL group/fsync
/// distributions, scan-cache ratio, per-peer replication lag, kernel
/// dispatch counters, contraction-accuracy gauges.
pub const METRICS: u8 = 21;

/// First response byte: request handled, body follows.
pub const STATUS_OK: u8 = 0;
/// First response byte: error message follows.
pub const STATUS_ERR: u8 = 1;

/// One row of the protocol surface: the opcode, its constant's name,
/// the typed [`super::client::StoreClient`] method that speaks it, and
/// the `hocs store-client` verb exposing it (`None` = machine-plane
/// only, deliberately not a CLI action).
pub struct WireOp {
    pub code: u8,
    pub name: &'static str,
    pub client_method: &'static str,
    pub cli: Option<&'static str>,
}

/// Every opcode the protocol speaks, in opcode order. The
/// `opcode-symmetry` lint pass walks this table; keep it exhaustive.
pub const ALL: &[WireOp] = &[
    WireOp { code: UPDATE, name: "UPDATE", client_method: "update", cli: Some("update") },
    WireOp {
        code: UPDATE_BATCH,
        name: "UPDATE_BATCH",
        client_method: "update_batch",
        cli: Some("update-batch"),
    },
    WireOp { code: QUERY, name: "QUERY", client_method: "query", cli: Some("query") },
    WireOp { code: TOPK, name: "TOPK", client_method: "top_k", cli: Some("topk") },
    WireOp { code: HEAVY, name: "HEAVY", client_method: "heavy_hitters", cli: Some("heavy") },
    // federation-plane ingest: edge nodes ship serialized sketches
    // programmatically; there is no CLI verb that reads a sketch file
    WireOp { code: MERGE, name: "MERGE", client_method: "merge", cli: None },
    WireOp { code: SNAPSHOT, name: "SNAPSHOT", client_method: "snapshot", cli: Some("snapshot") },
    WireOp {
        code: ADVANCE_EPOCH,
        name: "ADVANCE_EPOCH",
        client_method: "advance_epoch",
        cli: Some("advance-epoch"),
    },
    WireOp { code: STATS, name: "STATS", client_method: "stats", cli: Some("stats") },
    // coordinator-pool compute job, not a store action
    WireOp { code: BATCH_SKETCH, name: "BATCH_SKETCH", client_method: "batch_sketch", cli: None },
    WireOp {
        code: SHUTDOWN,
        name: "SHUTDOWN",
        client_method: "shutdown_server",
        cli: Some("shutdown"),
    },
    // replication plane: spoken by the replicator thread, never by hand
    WireOp { code: MERGE_ORIGIN, name: "MERGE_ORIGIN", client_method: "merge_origin", cli: None },
    WireOp {
        code: TCREATE,
        name: "TCREATE",
        client_method: "tensor_create",
        cli: Some("tcreate"),
    },
    WireOp {
        code: TUPDATE,
        name: "TUPDATE",
        client_method: "tensor_update",
        cli: Some("tupdate"),
    },
    // batched tensor writes are a programmatic hot path; the CLI's
    // one-shot tupdate covers the interactive case
    WireOp {
        code: TUPDATE_BATCH,
        name: "TUPDATE_BATCH",
        client_method: "tensor_update_batch",
        cli: None,
    },
    WireOp { code: TQUERY, name: "TQUERY", client_method: "tensor_query", cli: Some("tquery") },
    WireOp {
        code: MARGINAL,
        name: "MARGINAL",
        client_method: "tensor_marginal",
        cli: Some("marginal"),
    },
    WireOp {
        code: SLICE_TOPK,
        name: "SLICE_TOPK",
        client_method: "tensor_slice_topk",
        cli: Some("slice-topk"),
    },
    WireOp {
        code: CONTRACT,
        name: "CONTRACT",
        client_method: "tensor_contract",
        cli: Some("contract"),
    },
    // replication plane (tensor full ships), replicator-only
    WireOp {
        code: TMERGE_ORIGIN,
        name: "TMERGE_ORIGIN",
        client_method: "tensor_merge_origin",
        cli: None,
    },
    WireOp { code: METRICS, name: "METRICS", client_method: "metrics", cli: Some("metrics") },
];

/// The name of an opcode, if the table knows it.
pub fn name(code: u8) -> Option<&'static str> {
    ALL.iter().find(|o| o.code == code).map(|o| o.name)
}

/// The one place the `unknown opcode` error message is spelled — the
/// server's dispatch fallback arm formats through here so the error
/// path stays tied to this table.
pub fn unknown(code: u8) -> String {
    format!("unknown opcode {code}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_is_exhaustive_and_consistent() {
        // codes are dense 1..=21, unique, in table order
        let mut seen = std::collections::HashSet::new();
        for (i, o) in ALL.iter().enumerate() {
            assert_eq!(o.code as usize, i + 1, "opcode {} out of order", o.name);
            assert!(seen.insert(o.code), "duplicate opcode {}", o.code);
            assert!(!o.client_method.is_empty());
        }
        assert_eq!(ALL.len(), 21);
        assert_eq!(name(UPDATE), Some("UPDATE"));
        assert_eq!(name(TMERGE_ORIGIN), Some("TMERGE_ORIGIN"));
        assert_eq!(name(METRICS), Some("METRICS"));
        assert_eq!(name(0), None);
        assert_eq!(name(22), None);
        assert!(unknown(42).contains("42"));
    }
}
