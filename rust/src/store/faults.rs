//! Deterministic fault-injection plane for the durable and replication
//! paths.
//!
//! Every crash-sensitive file or socket operation in [`super::wal`] and
//! [`super::replica`] passes through a **named failpoint** ([`fire`] /
//! [`write_all`]). In debug builds a process-global registry can arm a
//! site with an action:
//!
//! - `Error` — the operation returns an injected `io::Error` (exercises
//!   the error-handling path: fail-stop, rollback, backoff);
//! - `Torn(n)` — write only the first `n` bytes, flush them, then
//!   [`std::process::abort`] (a torn write followed by a crash — the
//!   worst thing a kernel or disk can do short of corruption);
//! - `Abort` — abort before the operation runs (a crash at the site);
//! - `Delay(ms)` — sleep, then proceed (races and slow-I/O windows).
//!
//! Sites are armed programmatically ([`arm`]) or, for child-process
//! crash tests, from the `HOCS_FAULTS` environment variable parsed by
//! [`arm_from_env`]:
//!
//! ```text
//! HOCS_FAULTS="site=action[:arg][@nth];site2=…"
//!   actions: error | torn:BYTES | panic | abort | delay:MS
//!   @nth:    1-based hit at which the site starts firing (default 1;
//!            it keeps firing on every later hit)
//! ```
//!
//! **Release builds compile the whole plane to a no-op**: the registry
//! module only exists under `cfg(debug_assertions)`, and the release
//! stubs below are `#[inline(always)]` identities, so the hot path
//! carries no failpoint branches when disarmed-by-construction. `cargo
//! test` runs in debug, so the same binaries the tests exercise have
//! the plane armed-able.
//!
//! The module also hosts the **scripted crash workload** shared by the
//! `hocs fault-crash` child-process mode and `rust/tests/faults.rs`:
//! a deterministic op sequence ([`crash_workload`]) in which every op
//! advances the store's update counter by a known amount, so a parent
//! process can recover a crashed child's directory and infer exactly
//! which op-prefix survived (see `CrashOp::updates`).

use super::sharded::StoreConfig;
use super::wal::DurableStore;
use crate::rng::Pcg64;
use std::io::{self, Write};

/// What an armed failpoint does when it fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// The shimmed operation returns an injected [`io::Error`].
    Error,
    /// Write only the first `n` bytes of the buffer, flush, then abort
    /// the process. At a non-write site ([`fire`]) this acts as
    /// [`FaultAction::Abort`].
    Torn(usize),
    /// Abort the process before the operation runs.
    Abort,
    /// Sleep this many milliseconds, then run the operation normally.
    Delay(u64),
}

#[cfg(debug_assertions)]
mod armed {
    use super::FaultAction;
    use std::collections::HashMap;
    use std::io::{self, Write};
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::{Mutex, Once, OnceLock};
    use std::time::Duration;

    struct Site {
        action: FaultAction,
        /// 1-based hit number at which the site starts firing.
        nth: u64,
        hits: u64,
    }

    /// Fast path: skip the registry lock entirely while nothing is
    /// armed (the common case even in debug test runs).
    static ANY_ARMED: AtomicBool = AtomicBool::new(false);

    fn registry() -> &'static Mutex<HashMap<String, Site>> {
        static REG: OnceLock<Mutex<HashMap<String, Site>>> = OnceLock::new();
        REG.get_or_init(|| Mutex::new(HashMap::new()))
    }

    fn injected(site: &str) -> io::Error {
        io::Error::other(format!("injected fault at {site}"))
    }

    /// Count a hit at `site`; return the action to take if the site is
    /// armed and its trigger threshold has been reached. The registry
    /// lock is released before the action runs.
    fn triggered(site: &str) -> Option<FaultAction> {
        if !ANY_ARMED.load(Ordering::Relaxed) {
            return None;
        }
        let mut reg = registry().lock().unwrap();
        let st = reg.get_mut(site)?;
        st.hits += 1;
        let action = (st.hits >= st.nth).then_some(st.action);
        if action.is_some() {
            crate::obs::global().fault_injections.inc();
        }
        action
    }

    /// Failpoint at a non-write operation (rename, sync, truncate,
    /// socket call, …).
    pub fn fire(site: &str) -> io::Result<()> {
        match triggered(site) {
            None => Ok(()),
            Some(FaultAction::Error) => Err(injected(site)),
            Some(FaultAction::Abort | FaultAction::Torn(_)) => std::process::abort(),
            Some(FaultAction::Delay(ms)) => {
                std::thread::sleep(Duration::from_millis(ms));
                Ok(())
            }
        }
    }

    /// Failpoint shimming a buffer write: `Torn(n)` leaves exactly the
    /// first `n` bytes behind (flushed, so they reach the file before
    /// the process dies), every other action behaves as at [`fire`].
    pub fn write_all<W: Write>(site: &str, w: &mut W, buf: &[u8]) -> io::Result<()> {
        match triggered(site) {
            None => w.write_all(buf),
            Some(FaultAction::Error) => Err(injected(site)),
            Some(FaultAction::Abort) => std::process::abort(),
            Some(FaultAction::Torn(n)) => {
                let n = n.min(buf.len());
                let _ = w.write_all(&buf[..n]);
                let _ = w.flush();
                std::process::abort();
            }
            Some(FaultAction::Delay(ms)) => {
                std::thread::sleep(Duration::from_millis(ms));
                w.write_all(buf)
            }
        }
    }

    /// Arm `site` with `action`, firing from the `nth` hit on (1-based;
    /// 0 is treated as 1). Resets the site's hit counter.
    pub fn arm(site: &str, action: FaultAction, nth: u64) {
        let mut reg = registry().lock().unwrap();
        reg.insert(site.to_string(), Site { action, nth: nth.max(1), hits: 0 });
        ANY_ARMED.store(true, Ordering::Relaxed);
    }

    pub fn disarm(site: &str) {
        let mut reg = registry().lock().unwrap();
        reg.remove(site);
        if reg.is_empty() {
            ANY_ARMED.store(false, Ordering::Relaxed);
        }
    }

    /// Disarm every site and zero all hit counters.
    pub fn reset() {
        let mut reg = registry().lock().unwrap();
        reg.clear();
        ANY_ARMED.store(false, Ordering::Relaxed);
    }

    /// Hits recorded at `site` since it was armed (0 if never armed).
    pub fn hits(site: &str) -> u64 {
        registry().lock().unwrap().get(site).map_or(0, |s| s.hits)
    }

    /// Arm every site named in the `HOCS_FAULTS` spec (see the module
    /// docs for the grammar). Parses at most once per process; child
    /// crash processes call this before opening the store. Panics on a
    /// malformed spec — this is test-only plumbing and a typo should
    /// fail loudly, not silently disarm the fault.
    pub fn arm_from_env() {
        static ONCE: Once = Once::new();
        ONCE.call_once(|| {
            let Ok(spec) = std::env::var("HOCS_FAULTS") else { return };
            for part in spec.split(';').map(str::trim).filter(|p| !p.is_empty()) {
                let (site, rest) = part
                    .split_once('=')
                    .unwrap_or_else(|| panic!("HOCS_FAULTS entry {part:?} is not site=action"));
                let (action_spec, nth) = match rest.split_once('@') {
                    Some((a, n)) => (
                        a,
                        n.parse::<u64>()
                            .unwrap_or_else(|_| panic!("bad @nth in HOCS_FAULTS entry {part:?}")),
                    ),
                    None => (rest, 1),
                };
                let (name, arg) = match action_spec.split_once(':') {
                    Some((n, a)) => (n, Some(a)),
                    None => (action_spec, None),
                };
                let bad = |what: &str| -> ! {
                    panic!("bad {what} in HOCS_FAULTS entry {part:?}")
                };
                let action = match (name, arg) {
                    ("error", None) => FaultAction::Error,
                    ("panic" | "abort", None) => FaultAction::Abort,
                    ("torn", Some(n)) => {
                        FaultAction::Torn(n.parse().unwrap_or_else(|_| bad("torn byte count")))
                    }
                    ("delay", Some(ms)) => {
                        FaultAction::Delay(ms.parse().unwrap_or_else(|_| bad("delay millis")))
                    }
                    _ => bad("action"),
                };
                arm(site, action, nth);
            }
        });
    }
}

#[cfg(debug_assertions)]
pub use armed::{arm, arm_from_env, disarm, fire, hits, reset, write_all};

#[cfg(not(debug_assertions))]
mod disarmed {
    use super::FaultAction;
    use std::io::{self, Write};

    #[inline(always)]
    pub fn fire(_site: &str) -> io::Result<()> {
        Ok(())
    }

    #[inline(always)]
    pub fn write_all<W: Write>(_site: &str, w: &mut W, buf: &[u8]) -> io::Result<()> {
        w.write_all(buf)
    }

    #[inline(always)]
    pub fn arm(_site: &str, _action: FaultAction, _nth: u64) {}

    #[inline(always)]
    pub fn disarm(_site: &str) {}

    #[inline(always)]
    pub fn reset() {}

    #[inline(always)]
    pub fn hits(_site: &str) -> u64 {
        0
    }

    #[inline(always)]
    pub fn arm_from_env() {}
}

#[cfg(not(debug_assertions))]
pub use disarmed::{arm, arm_from_env, disarm, fire, hits, reset, write_all};

/// Origin id used by the scripted crash workload's ingest merges.
pub const CRASH_ORIGIN: u64 = 0xC0FFEE;

/// Name of the crash workload's tensor-plane sketch. Created lazily —
/// and idempotently — when the first [`CrashOp::TensorUpdate`] is
/// applied, so `--start K` continuation runs find it already durable.
pub const CRASH_TENSOR: &str = "crash";

/// Family of the crash workload's tensor: order 3, small enough that
/// full bit-identity sweeps of the key space are cheap.
pub fn crash_tensor_family() -> super::tensor::TensorFamily {
    super::tensor::TensorFamily {
        dims: vec![12, 10, 8],
        sketch_dims: vec![5, 4, 3],
        d: 3,
        seed: 167,
    }
}

/// Store geometry for the crash-consistency harness: small enough that
/// full-universe bit-identity sweeps are cheap, sharded and windowed
/// enough to exercise the fan-out and rotation paths.
pub fn crash_config() -> StoreConfig {
    StoreConfig { n1: 40, n2: 32, m1: 10, m2: 8, d: 5, seed: 131, shards: 3, window: 4 }
}

/// One scripted operation of the crash workload. Every variant advances
/// the store's `stats().updates` counter by [`CrashOp::updates`] ≥ 1,
/// so the counter recovered from a crashed directory uniquely
/// identifies the surviving op-prefix (cumulative update counts are
/// strictly increasing in the prefix length).
#[derive(Clone, Debug)]
pub enum CrashOp {
    Update { i: usize, j: usize, w: f64 },
    Batch(Vec<(u32, u32, f64)>),
    /// Edge-ingest origin merge (WAL-logged; replay re-commits the
    /// dedup horizon). `seq` is the 1-based index among merge ops, so a
    /// continuation run picks up the channel without a gap.
    OriginMerge { seq: u64, i: usize, j: usize, w: f64 },
    /// One multi-mode update to the [`CRASH_TENSOR`] HCS (tensor-plane
    /// WAL record; the tensor itself is created idempotently on first
    /// application). Counts once in `stats().updates` — the sharded
    /// store folds the tensor registry's update count in.
    TensorUpdate { key: Vec<usize>, w: f64 },
}

impl CrashOp {
    /// How many sketch updates this op contributes to `stats().updates`.
    pub fn updates(&self) -> u64 {
        match self {
            CrashOp::Update { .. } | CrashOp::OriginMerge { .. } | CrashOp::TensorUpdate { .. } => {
                1
            }
            CrashOp::Batch(items) => items.len() as u64,
        }
    }
}

/// Deterministic crash workload: mostly single updates, with a 3-item
/// batch every 10th op, an edge-ingest origin merge every 10th, and a
/// tensor-plane HCS update every 10th — the four durable write paths
/// (per-record append, group frame, origin-merge record, tensor
/// record), integer weights so recovered f64 state is exactly
/// comparable.
pub fn crash_workload(cfg: &StoreConfig, total: usize, seed: u64) -> Vec<CrashOp> {
    let tdims = crash_tensor_family().dims;
    let mut rng = Pcg64::new(seed);
    let mut merges = 0u64;
    let mut ops = Vec::with_capacity(total);
    for k in 0..total {
        let i = rng.gen_range(cfg.n1 as u64) as usize;
        let j = rng.gen_range(cfg.n2 as u64) as usize;
        let w = (1 + rng.gen_range(9)) as f64;
        if k % 10 == 9 {
            merges += 1;
            ops.push(CrashOp::OriginMerge { seq: merges, i, j, w });
        } else if k % 10 == 2 {
            let key = vec![i % tdims[0], j % tdims[1], rng.gen_range(tdims[2] as u64) as usize];
            ops.push(CrashOp::TensorUpdate { key, w });
        } else if k % 10 == 4 {
            let mut items = vec![(i as u32, j as u32, w)];
            for _ in 0..2 {
                items.push((
                    rng.gen_range(cfg.n1 as u64) as u32,
                    rng.gen_range(cfg.n2 as u64) as u32,
                    (1 + rng.gen_range(9)) as f64,
                ));
            }
            ops.push(CrashOp::Batch(items));
        } else {
            ops.push(CrashOp::Update { i, j, w });
        }
    }
    ops
}

/// Execute one workload op against a store (shared by `hocs
/// fault-crash` and the harness's in-memory shadow replays).
pub fn apply_crash_op(store: &DurableStore, cfg: &StoreConfig, op: &CrashOp) -> anyhow::Result<()> {
    match op {
        CrashOp::Update { i, j, w } => store.update(*i, *j, *w),
        CrashOp::Batch(items) => store.update_batch(items),
        CrashOp::OriginMerge { seq, i, j, w } => {
            let mut sk = cfg.fresh_sketch();
            sk.update(*i, *j, *w);
            store
                .apply_origin_merge(CRASH_ORIGIN, *seq, super::replica::wire::MODE_DELTA, true, sk)
                .map(|_| ())
        }
        CrashOp::TensorUpdate { key, w } => {
            store.tensor_create(CRASH_TENSOR, &crash_tensor_family())?;
            store.tensor_update(CRASH_TENSOR, key, *w)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Mutex, MutexGuard, OnceLock};

    /// The registry is process-global; tests that arm it must not
    /// overlap (cargo's test threads share the process).
    fn serial() -> MutexGuard<'static, ()> {
        static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
        LOCK.get_or_init(|| Mutex::new(())).lock().unwrap()
    }

    #[test]
    fn disarmed_sites_pass_through() {
        let _guard = serial();
        reset();
        assert!(fire("nope").is_ok());
        let mut out = Vec::new();
        write_all("nope", &mut out, b"abc").unwrap();
        assert_eq!(out, b"abc");
        assert_eq!(hits("nope"), 0);
    }

    #[test]
    fn error_fires_from_nth_hit_on() {
        let _guard = serial();
        reset();
        arm("x", FaultAction::Error, 3);
        assert!(fire("x").is_ok());
        assert!(fire("x").is_ok());
        let err = fire("x").unwrap_err();
        assert!(err.to_string().contains("injected fault at x"), "{err}");
        // keeps firing on every later hit
        assert!(fire("x").is_err());
        assert_eq!(hits("x"), 4);
        disarm("x");
        assert!(fire("x").is_ok());
    }

    #[test]
    fn torn_write_at_a_plain_error_site_is_an_error_for_write_all() {
        let _guard = serial();
        reset();
        // Error at a write site: nothing written
        arm("w", FaultAction::Error, 1);
        let mut out = Vec::new();
        assert!(write_all("w", &mut out, b"abcdef").is_err());
        assert!(out.is_empty());
        reset();
        // Delay at a write site: full write proceeds
        arm("w", FaultAction::Delay(1), 1);
        let mut out2 = Vec::new();
        write_all("w", &mut out2, b"abcdef").unwrap();
        assert_eq!(out2, b"abcdef");
        reset();
    }

    #[test]
    fn workload_is_deterministic_and_update_counts_are_exact() {
        let cfg = crash_config();
        let a = crash_workload(&cfg, 50, 7);
        let b = crash_workload(&cfg, 50, 7);
        assert_eq!(a.len(), 50);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(format!("{x:?}"), format!("{y:?}"));
        }
        // op mix: batches at k%10==4, merges at k%10==9 with contiguous
        // seqs, tensor updates at k%10==2 with in-range keys
        let tfam = crash_tensor_family();
        let mut merges = 0;
        for (k, op) in a.iter().enumerate() {
            match op {
                CrashOp::Batch(items) => {
                    assert_eq!(k % 10, 4);
                    assert_eq!(items.len(), 3);
                    assert_eq!(op.updates(), 3);
                }
                CrashOp::OriginMerge { seq, .. } => {
                    assert_eq!(k % 10, 9);
                    merges += 1;
                    assert_eq!(*seq, merges);
                }
                CrashOp::TensorUpdate { key, .. } => {
                    assert_eq!(k % 10, 2);
                    assert_eq!(key.len(), tfam.dims.len());
                    for (idx, dim) in key.iter().zip(tfam.dims.iter()) {
                        assert!(idx < dim, "tensor key {key:?} out of range for {:?}", tfam.dims);
                    }
                    assert_eq!(op.updates(), 1);
                }
                CrashOp::Update { .. } => assert_eq!(op.updates(), 1),
            }
        }
        // replaying against an in-memory store advances updates by
        // exactly the per-op counts (the m-inference invariant)
        let store = DurableStore::in_memory(cfg.clone());
        let mut expect = 0u64;
        for op in &a {
            apply_crash_op(&store, &cfg, op).unwrap();
            expect += op.updates();
            assert_eq!(store.stats().updates, expect);
        }
    }
}
