//! Byte-level codec shared by the mergeable-sketch serializers, the
//! snapshot/WAL persistence layer, and the TCP wire protocol.
//!
//! Everything is little-endian; floats travel as IEEE-754 bit patterns
//! (`f64::to_bits`) so encode → decode is bit-exact — the store's merge
//! and recovery fidelity guarantees are stated at the bit level, and the
//! codec must not be the layer that loses them. CRC-32 (IEEE/zlib
//! polynomial) frames the WAL and lets crash recovery tell a torn tail
//! from good data.

use anyhow::{bail, Result};

// ---------- writers ----------

pub fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_f64(out: &mut Vec<u8>, v: f64) {
    put_u64(out, v.to_bits());
}

pub fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

/// One `(i, j, w)` update triple — the shared unit of the UPDATE /
/// UPDATE_BATCH wire bodies and the WAL's update frames, so the client,
/// server, and log can never drift apart on its layout.
pub fn put_update(out: &mut Vec<u8>, i: u32, j: u32, w: f64) {
    put_u32(out, i);
    put_u32(out, j);
    put_f64(out, w);
}

// ---------- reader ----------

/// Bounds-checked cursor over a byte slice. Every take returns a
/// descriptive error instead of panicking — WAL frames and network
/// payloads are untrusted input.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    /// Consume the next `n` bytes.
    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!("truncated input: wanted {n} bytes, {} left", self.remaining());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub fn f32(&mut self) -> Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_bits(u32::from_le_bytes([b[0], b[1], b[2], b[3]])))
    }

    /// Inverse of [`put_update`].
    pub fn update_triple(&mut self) -> Result<(u32, u32, f64)> {
        Ok((self.u32()?, self.u32()?, self.f64()?))
    }
}

// ---------- CRC-32 ----------

const CRC32_TABLE: [u32; 256] = crc32_table();

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut n = 0;
    while n < 256 {
        let mut c = n as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[n] = c;
        n += 1;
    }
    table
}

/// CRC-32 (IEEE 802.3, the zlib/PNG polynomial).
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC32_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitives_roundtrip() {
        let mut out = Vec::new();
        put_u8(&mut out, 7);
        put_u32(&mut out, 0xDEAD_BEEF);
        put_u64(&mut out, u64::MAX - 1);
        put_f64(&mut out, -0.1);
        put_f32(&mut out, 3.5);
        put_update(&mut out, 3, 9, -2.5);
        let mut rd = Reader::new(&out);
        assert_eq!(rd.u8().unwrap(), 7);
        assert_eq!(rd.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(rd.u64().unwrap(), u64::MAX - 1);
        assert_eq!(rd.f64().unwrap().to_bits(), (-0.1f64).to_bits());
        assert_eq!(rd.f32().unwrap(), 3.5);
        let (i, j, w) = rd.update_triple().unwrap();
        assert_eq!((i, j), (3, 9));
        assert_eq!(w.to_bits(), (-2.5f64).to_bits());
        assert!(rd.is_empty());
    }

    #[test]
    fn float_bit_patterns_survive() {
        // NaN payloads and signed zero must roundtrip exactly
        for v in [f64::NAN, -0.0, f64::INFINITY, f64::MIN_POSITIVE] {
            let mut out = Vec::new();
            put_f64(&mut out, v);
            let got = Reader::new(&out).f64().unwrap();
            assert_eq!(got.to_bits(), v.to_bits());
        }
    }

    #[test]
    fn truncated_reads_error() {
        let mut out = Vec::new();
        put_u32(&mut out, 1);
        let mut rd = Reader::new(&out);
        assert!(rd.u64().is_err());
        // failed take consumes nothing
        assert_eq!(rd.remaining(), 4);
        assert_eq!(rd.u32().unwrap(), 1);
        assert!(rd.u8().is_err());
    }

    #[test]
    fn crc32_test_vectors() {
        // the canonical check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_ne!(crc32(b"a"), crc32(b"b"));
    }
}
