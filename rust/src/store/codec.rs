//! Byte-level codec shared by the mergeable-sketch serializers, the
//! snapshot/WAL persistence layer, and the TCP wire protocol.
//!
//! Everything is little-endian; floats travel as IEEE-754 bit patterns
//! (`f64::to_bits`) so encode → decode is bit-exact — the store's merge
//! and recovery fidelity guarantees are stated at the bit level, and the
//! codec must not be the layer that loses them. CRC-32 (IEEE/zlib
//! polynomial) frames the WAL and lets crash recovery tell a torn tail
//! from good data.

use anyhow::{bail, Result};

// ---------- writers ----------

pub fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_f64(out: &mut Vec<u8>, v: f64) {
    put_u64(out, v.to_bits());
}

pub fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

/// One `(i, j, w)` update triple — the shared unit of the UPDATE /
/// UPDATE_BATCH wire bodies and the WAL's update frames, so the client,
/// server, and log can never drift apart on its layout.
pub fn put_update(out: &mut Vec<u8>, i: u32, j: u32, w: f64) {
    put_u32(out, i);
    put_u32(out, j);
    put_f64(out, w);
}

/// Longest tensor name accepted on the wire and in WAL records. Names
/// key the registry's `BTreeMap`; an unbounded length would let one
/// corrupt frame allocate arbitrarily.
pub const MAX_TENSOR_NAME: usize = 128;

/// One multi-mode key: `u8 order` then `order` little-endian `u32`
/// indices — the shared unit of the tensor wire bodies (TUPDATE /
/// TQUERY / …) and the WAL's tensor frames. The explicit order byte is
/// what lets [`read_mode_key`] catch an order-mismatched frame instead
/// of silently misaligning every field after the key.
pub fn put_mode_key(out: &mut Vec<u8>, key: &[usize]) {
    debug_assert!(key.len() <= u8::MAX as usize, "tensor order exceeds wire format");
    put_u8(out, key.len() as u8);
    for &i in key {
        put_u32(out, u32::try_from(i).expect("mode index fits u32"));
    }
}

/// Inverse of [`put_mode_key`], validated against the target tensor's
/// mode dims: rejects an order mismatch, any out-of-range mode index,
/// and a truncated key vector with a decode error — never a panic or a
/// wrapped offset. WAL frames and network payloads are untrusted.
pub fn read_mode_key(rd: &mut Reader<'_>, dims: &[usize]) -> Result<Vec<usize>> {
    let order = rd.u8()? as usize;
    if order != dims.len() {
        bail!("tensor key order {order} does not match tensor order {}", dims.len());
    }
    let mut key = Vec::with_capacity(order);
    for (k, &n) in dims.iter().enumerate() {
        let i = rd.u32()? as usize;
        if i >= n {
            bail!("tensor key mode {k} index {i} out of range (dim {n})");
        }
        key.push(i);
    }
    Ok(key)
}

/// A length-prefixed UTF-8 tensor name (`u32 len | bytes`), capped at
/// [`MAX_TENSOR_NAME`].
pub fn put_name(out: &mut Vec<u8>, name: &str) {
    debug_assert!(name.len() <= MAX_TENSOR_NAME, "tensor name exceeds MAX_TENSOR_NAME");
    put_u32(out, name.len() as u32);
    out.extend_from_slice(name.as_bytes());
}

/// Inverse of [`put_name`]: rejects over-cap lengths *before*
/// allocating, and non-UTF-8 bytes.
pub fn read_name(rd: &mut Reader<'_>) -> Result<String> {
    let len = rd.u32()? as usize;
    if len > MAX_TENSOR_NAME {
        bail!("tensor name of {len} bytes exceeds cap {MAX_TENSOR_NAME}");
    }
    let bytes = rd.take(len)?;
    match std::str::from_utf8(bytes) {
        Ok(s) => Ok(s.to_string()),
        Err(_) => bail!("tensor name is not valid UTF-8"),
    }
}

// ---------- reader ----------

/// Bounds-checked cursor over a byte slice. Every take returns a
/// descriptive error instead of panicking — WAL frames and network
/// payloads are untrusted input.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    /// Consume the next `n` bytes.
    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!("truncated input: wanted {n} bytes, {} left", self.remaining());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub fn f32(&mut self) -> Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_bits(u32::from_le_bytes([b[0], b[1], b[2], b[3]])))
    }

    /// Inverse of [`put_update`].
    pub fn update_triple(&mut self) -> Result<(u32, u32, f64)> {
        Ok((self.u32()?, self.u32()?, self.f64()?))
    }
}

// ---------- CRC-32 ----------

const CRC32_TABLE: [u32; 256] = crc32_table();

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut n = 0;
    while n < 256 {
        let mut c = n as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[n] = c;
        n += 1;
    }
    table
}

/// CRC-32 (IEEE 802.3, the zlib/PNG polynomial).
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC32_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitives_roundtrip() {
        let mut out = Vec::new();
        put_u8(&mut out, 7);
        put_u32(&mut out, 0xDEAD_BEEF);
        put_u64(&mut out, u64::MAX - 1);
        put_f64(&mut out, -0.1);
        put_f32(&mut out, 3.5);
        put_update(&mut out, 3, 9, -2.5);
        let mut rd = Reader::new(&out);
        assert_eq!(rd.u8().unwrap(), 7);
        assert_eq!(rd.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(rd.u64().unwrap(), u64::MAX - 1);
        assert_eq!(rd.f64().unwrap().to_bits(), (-0.1f64).to_bits());
        assert_eq!(rd.f32().unwrap(), 3.5);
        let (i, j, w) = rd.update_triple().unwrap();
        assert_eq!((i, j), (3, 9));
        assert_eq!(w.to_bits(), (-2.5f64).to_bits());
        assert!(rd.is_empty());
    }

    #[test]
    fn float_bit_patterns_survive() {
        // NaN payloads and signed zero must roundtrip exactly
        for v in [f64::NAN, -0.0, f64::INFINITY, f64::MIN_POSITIVE] {
            let mut out = Vec::new();
            put_f64(&mut out, v);
            let got = Reader::new(&out).f64().unwrap();
            assert_eq!(got.to_bits(), v.to_bits());
        }
    }

    #[test]
    fn truncated_reads_error() {
        let mut out = Vec::new();
        put_u32(&mut out, 1);
        let mut rd = Reader::new(&out);
        assert!(rd.u64().is_err());
        // failed take consumes nothing
        assert_eq!(rd.remaining(), 4);
        assert_eq!(rd.u32().unwrap(), 1);
        assert!(rd.u8().is_err());
    }

    #[test]
    fn mode_keys_roundtrip_and_reject_corrupt_frames() {
        let dims = [24usize, 18, 12];
        let key = [23usize, 0, 11];
        let mut out = Vec::new();
        put_mode_key(&mut out, &key);
        assert_eq!(read_mode_key(&mut Reader::new(&out), &dims).unwrap(), key);
        // order mismatch: the frame says order 3, the tensor is order 2
        assert!(read_mode_key(&mut Reader::new(&out), &[24, 18]).is_err());
        // out-of-range index on any mode
        let mut big = Vec::new();
        put_mode_key(&mut big, &[5, 18, 3]);
        assert!(read_mode_key(&mut Reader::new(&big), &dims).is_err());
        // truncated key vector: order promises 3 indices, bytes hold 2
        let trunc = &out[..out.len() - 2];
        assert!(read_mode_key(&mut Reader::new(trunc), &dims).is_err());
        // empty buffer
        assert!(read_mode_key(&mut Reader::new(&[]), &dims).is_err());
    }

    #[test]
    fn names_roundtrip_and_reject_corrupt_frames() {
        let mut out = Vec::new();
        put_name(&mut out, "user×feature×time");
        assert_eq!(read_name(&mut Reader::new(&out)).unwrap(), "user×feature×time");
        // an over-cap length prefix is rejected before allocating
        let mut huge = Vec::new();
        put_u32(&mut huge, u32::MAX);
        assert!(read_name(&mut Reader::new(&huge)).is_err());
        // length prefix promising more bytes than the buffer holds
        let mut short = Vec::new();
        put_u32(&mut short, 10);
        short.extend_from_slice(b"abc");
        assert!(read_name(&mut Reader::new(&short)).is_err());
        // invalid UTF-8
        let mut bad = Vec::new();
        put_u32(&mut bad, 2);
        bad.extend_from_slice(&[0xFF, 0xFE]);
        assert!(read_name(&mut Reader::new(&bad)).is_err());
    }

    #[test]
    fn crc32_test_vectors() {
        // the canonical check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_ne!(crc32(b"a"), crc32(b"b"));
    }
}
