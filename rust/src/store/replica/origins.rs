//! Receiver-side per-origin replay protection and full-ship accounting.
//!
//! Addition-based merging is exact but **not idempotent**, so every
//! origin-headered merge passes through this table before it may touch
//! the store:
//!
//! - **Dedup window.** Per origin the table remembers the last applied
//!   sequence number; any frame at or below it is a retry (the sender
//!   re-sends the identical bytes after an ambiguous error) and is
//!   dropped as an acknowledged no-op. Sequences on one channel are
//!   strictly increasing, so "≤ last" is a full-history dedup horizon.
//! - **Gap detection.** A *delta* frame whose sequence skips ahead
//!   means the receiver lost channel state this delta builds on
//!   (typically a receiver restart: replica-plane mass is deliberately
//!   not WAL-logged — anti-entropy, not the log, restores it). The
//!   frame is rejected with [`wire::SEQ_GAP_MARKER`] and the sender
//!   falls back to a full-state ship. A *full* frame heals any gap: it
//!   carries the origin's entire cumulative state, so it may arrive at
//!   any sequence.
//! - **Full-ship remainder.** The table keeps, per origin, the
//!   cumulative sketch of everything applied from it (`received` —
//!   fixed size, linearity again). A full frame is applied as
//!   `full − received`: exactly the mass this receiver has not seen,
//!   landing in the current epoch like any fresh delivery. Window
//!   expiry cannot corrupt this — `received` tracks *deliveries*, not
//!   live mass.
//!
//! [`OriginTable::admit`] validates and computes the sketch to apply;
//! [`OriginTable::commit`] records it only after the store merge
//! succeeded, so a failed merge (e.g. a fail-stopped WAL on the ingest
//! path) leaves the channel ready for an exact retry.
//!
//! **Crash durability.** The horizons and cumulative records ride in
//! every snapshot, and ingest merges replay from their own WAL record,
//! so a recovered receiver keeps deduping at or below its horizon and
//! full-ship remainders stay exact across restarts. The crash harness
//! (`rust/tests/faults.rs`) kills stores at armed WAL/snapshot
//! failpoints and asserts the horizon is monotone across recovery.

use super::super::codec::{self, Reader};
use super::super::mergeable::MergeableSketch;
use super::super::sharded::StoreConfig;
use super::wire::{self, MODE_DELTA, MODE_FULL};
use crate::sketch::stream::StreamSketch;
use anyhow::{bail, ensure, Result};
use std::collections::HashMap;

/// Cap on tracked origins: each entry retains one geometry-sized
/// cumulative sketch, so an unbounded table would let a peer (or a
/// hostile client) grow memory without limit. At the cap the
/// least-recently-active origin is evicted — origin ids are fresh per
/// sender incarnation, so with live channels touching the table every
/// ship, the stalest entry is almost certainly a dead incarnation whose
/// channel can never resume (a hard cap instead would permanently halt
/// replication once enough restarts had been seen). Evicting a
/// still-live origin degrades rather than corrupts: its next delta hits
/// the unknown-origin gap path, and the recovery full ship re-delivers
/// mass the table no longer remembers receiving — the bounded-memory
/// price, documented here.
pub const MAX_ORIGINS: usize = 64;

struct OriginState {
    last_seq: u64,
    /// eviction clock stamp of the last applied frame
    last_active: u64,
    /// cumulative mass applied from this origin (deliveries, not live
    /// window mass)
    received: StreamSketch,
}

/// Outcome of admitting one origin-headered merge frame.
pub enum Admit {
    /// Merge this sketch into the store, then [`OriginTable::commit`].
    Apply(StreamSketch),
    /// Retry of an already-applied frame — acknowledged no-op.
    Dedup,
}

/// Per-origin channel state for one receiving node.
pub struct OriginTable {
    origins: HashMap<u64, OriginState>,
    cap: usize,
    /// monotonic eviction clock, bumped per committed frame
    clock: u64,
}

impl OriginTable {
    pub fn new(cap: usize) -> Self {
        Self { origins: HashMap::new(), cap, clock: 0 }
    }

    /// Origins currently tracked (diagnostics).
    pub fn len(&self) -> usize {
        self.origins.len()
    }

    pub fn is_empty(&self) -> bool {
        self.origins.is_empty()
    }

    /// Validate one frame against the origin's channel state and return
    /// what (if anything) to merge. Does not mutate — call
    /// [`OriginTable::commit`] after the store merge succeeds.
    pub fn admit(&self, origin: u64, seq: u64, mode: u8, sk: StreamSketch) -> Result<Admit> {
        match self.origins.get(&origin) {
            None => {
                match mode {
                    MODE_FULL => Ok(Admit::Apply(sk)),
                    MODE_DELTA => {
                        ensure!(
                            seq == 1,
                            "{}: first frame from origin {origin:#x} has seq {seq} \
                             (want 1); ship full state",
                            wire::SEQ_GAP_MARKER
                        );
                        Ok(Admit::Apply(sk))
                    }
                    other => bail!("unknown origin-merge mode {other}"),
                }
            }
            Some(st) => {
                if seq <= st.last_seq {
                    return Ok(Admit::Dedup);
                }
                match mode {
                    MODE_FULL => {
                        // apply only the unseen remainder; merge_scaled
                        // with -1 also subtracts the update counts, so
                        // the remainder counts exactly the new items
                        let mut delta = sk;
                        delta.merge_scaled(&st.received, -1.0);
                        Ok(Admit::Apply(delta))
                    }
                    MODE_DELTA => {
                        ensure!(
                            seq == st.last_seq + 1,
                            "{}: got seq {seq} from origin {origin:#x} after {}; \
                             ship full state",
                            wire::SEQ_GAP_MARKER,
                            st.last_seq
                        );
                        Ok(Admit::Apply(sk))
                    }
                    other => bail!("unknown origin-merge mode {other}"),
                }
            }
        }
    }

    /// Record a successfully-applied frame: advance the dedup horizon
    /// and fold the applied mass into the origin's cumulative record.
    /// A new origin arriving at the cap evicts the least-recently-
    /// active entry first (see [`MAX_ORIGINS`] for why that is safe in
    /// practice and what it costs when it is not).
    pub fn commit(&mut self, cfg: &StoreConfig, origin: u64, seq: u64, applied: &StreamSketch) {
        self.clock += 1;
        if !self.origins.contains_key(&origin) && self.origins.len() >= self.cap {
            let stalest =
                self.origins.iter().min_by_key(|(_, st)| st.last_active).map(|(id, _)| *id);
            if let Some(id) = stalest {
                // loud on purpose: if the evicted origin is still live,
                // its recovery full ship will re-deliver mass this
                // table no longer remembers receiving (see MAX_ORIGINS)
                crate::log_warn!(
                    "store: origin table at cap ({}); evicting stalest origin {id:#x} \
                     to admit {origin:#x}",
                    self.cap
                );
                self.origins.remove(&id);
            }
        }
        let clock = self.clock;
        let st = self.origins.entry(origin).or_insert_with(|| OriginState {
            last_seq: 0,
            last_active: 0,
            received: cfg.fresh_sketch(),
        });
        st.received.merge_scaled(applied, 1.0);
        st.last_seq = seq;
        st.last_active = clock;
    }

    /// Serialize the table (snapshot persistence): the dedup horizons
    /// and cumulative records must survive a receiver restart together
    /// with the store image they describe, or a re-delivered frame /
    /// full ship would double-count mass the snapshot already holds.
    /// Origins are written in sorted id order so identical tables
    /// encode identically.
    pub(crate) fn encode_into(&self, out: &mut Vec<u8>) {
        codec::put_u64(out, self.clock);
        codec::put_u32(out, u32::try_from(self.origins.len()).expect("origin count fits u32"));
        let mut ids: Vec<u64> = self.origins.keys().copied().collect();
        ids.sort_unstable();
        for id in ids {
            let st = &self.origins[&id];
            codec::put_u64(out, id);
            codec::put_u64(out, st.last_seq);
            codec::put_u64(out, st.last_active);
            st.received.encode(out);
        }
    }

    /// Bit-exact inverse of [`OriginTable::encode_into`], validated
    /// against the store's sketch family.
    pub(crate) fn decode_from(rd: &mut Reader<'_>, cfg: &StoreConfig) -> Result<Self> {
        let clock = rd.u64()?;
        let count = rd.u32()? as usize;
        ensure!(count <= MAX_ORIGINS, "snapshot origin table of {count} entries exceeds cap");
        let mut origins = HashMap::with_capacity(count);
        for _ in 0..count {
            let id = rd.u64()?;
            let last_seq = rd.u64()?;
            let last_active = rd.u64()?;
            let received = StreamSketch::decode(rd)?;
            ensure!(
                cfg.matches(&received),
                "corrupt snapshot: origin {id:#x} sketch family mismatch"
            );
            origins.insert(id, OriginState { last_seq, last_active, received });
        }
        Ok(Self { origins, cap: MAX_ORIGINS, clock })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> StoreConfig {
        StoreConfig { n1: 32, n2: 32, m1: 8, m2: 8, d: 3, seed: 9, shards: 2, window: 2 }
    }

    fn sketch_of(cfg: &StoreConfig, items: &[(usize, usize, f64)]) -> StreamSketch {
        let mut sk = cfg.fresh_sketch();
        for &(i, j, w) in items {
            sk.update(i, j, w);
        }
        sk
    }

    fn apply(
        table: &mut OriginTable,
        cfg: &StoreConfig,
        origin: u64,
        seq: u64,
        mode: u8,
        sk: StreamSketch,
    ) -> Result<Option<StreamSketch>> {
        match table.admit(origin, seq, mode, sk)? {
            Admit::Apply(d) => {
                table.commit(cfg, origin, seq, &d);
                Ok(Some(d))
            }
            Admit::Dedup => Ok(None),
        }
    }

    #[test]
    fn retried_frames_dedup_and_sequences_advance() {
        let cfg = cfg();
        let mut t = OriginTable::new(4);
        let d1 = sketch_of(&cfg, &[(1, 1, 5.0)]);
        assert!(apply(&mut t, &cfg, 7, 1, MODE_DELTA, d1.clone()).unwrap().is_some());
        // exact retry: acknowledged no-op
        assert!(apply(&mut t, &cfg, 7, 1, MODE_DELTA, d1.clone()).unwrap().is_none());
        // stale (below the horizon) too
        assert!(apply(&mut t, &cfg, 7, 0, MODE_FULL, d1.clone()).unwrap().is_none());
        // next in sequence applies
        let d2 = sketch_of(&cfg, &[(2, 2, 3.0)]);
        assert!(apply(&mut t, &cfg, 7, 2, MODE_DELTA, d2).unwrap().is_some());
        // independent origins have independent horizons
        assert!(apply(&mut t, &cfg, 8, 1, MODE_DELTA, d1).unwrap().is_some());
    }

    #[test]
    fn delta_gaps_error_and_full_heals_them() {
        let cfg = cfg();
        let mut t = OriginTable::new(4);
        let d1 = sketch_of(&cfg, &[(1, 1, 5.0)]);
        apply(&mut t, &cfg, 7, 1, MODE_DELTA, d1.clone()).unwrap();
        // skipped sequence: the receiver is missing seq 2
        let err = t.admit(7, 3, MODE_DELTA, d1.clone()).unwrap_err().to_string();
        assert!(err.contains(wire::SEQ_GAP_MARKER), "unexpected error: {err}");
        // unknown origin starting mid-sequence is a gap too
        let err2 = t.admit(99, 5, MODE_DELTA, d1).unwrap_err().to_string();
        assert!(err2.contains(wire::SEQ_GAP_MARKER), "unexpected error: {err2}");
        // a full frame at any sequence heals the channel
        let full = sketch_of(&cfg, &[(1, 1, 5.0), (2, 2, 3.0), (3, 3, 4.0)]);
        let applied = apply(&mut t, &cfg, 7, 9, MODE_FULL, full).unwrap().unwrap();
        // only the unseen remainder is applied: (2,2,3) and (3,3,4)
        assert_eq!(applied.updates, 2);
        assert_eq!(applied.query(2, 2), 3.0);
        assert_eq!(applied.query(1, 1), 0.0, "already-delivered mass re-applied");
        // and a delta continuing from the full's sequence applies
        let d3 = sketch_of(&cfg, &[(4, 4, 1.0)]);
        assert!(apply(&mut t, &cfg, 7, 10, MODE_DELTA, d3).unwrap().is_some());
    }

    #[test]
    fn full_frames_are_idempotent_via_the_remainder() {
        let cfg = cfg();
        let mut t = OriginTable::new(4);
        let full = sketch_of(&cfg, &[(1, 1, 2.0), (2, 2, 3.0)]);
        let first = apply(&mut t, &cfg, 5, 1, MODE_FULL, full.clone()).unwrap().unwrap();
        assert_eq!(first.query(1, 1), 2.0);
        // the same cumulative state at a later sequence applies nothing
        let again = apply(&mut t, &cfg, 5, 2, MODE_FULL, full.clone()).unwrap().unwrap();
        assert_eq!(again.updates, 0);
        for r in 0..cfg.d {
            assert!(again.table(r).iter().all(|&v| v == 0.0), "re-applied full mass");
        }
        // a grown cumulative state applies exactly the growth
        let mut grown = full;
        grown.update(3, 3, 7.0);
        let third = apply(&mut t, &cfg, 5, 3, MODE_FULL, grown).unwrap().unwrap();
        assert_eq!(third.updates, 1);
        assert_eq!(third.query(3, 3), 7.0);
    }

    #[test]
    fn table_roundtrips_bit_exact() {
        let cfg = cfg();
        let mut t = OriginTable::new(4);
        apply(&mut t, &cfg, 3, 1, MODE_DELTA, sketch_of(&cfg, &[(1, 1, 2.0)])).unwrap();
        apply(&mut t, &cfg, 3, 2, MODE_DELTA, sketch_of(&cfg, &[(2, 2, -3.0)])).unwrap();
        apply(&mut t, &cfg, 8, 1, MODE_FULL, sketch_of(&cfg, &[(4, 4, 7.0)])).unwrap();
        let mut bytes = Vec::new();
        t.encode_into(&mut bytes);
        let got = OriginTable::decode_from(&mut Reader::new(&bytes), &cfg).unwrap();
        assert_eq!(got.len(), 2);
        // identical tables encode identically (sorted id order)
        let mut bytes2 = Vec::new();
        got.encode_into(&mut bytes2);
        assert_eq!(bytes, bytes2);
        // the recovered horizons still dedup and still know the
        // cumulative record: a stale retry is a no-op, a full ship
        // applies only the remainder
        let mut re = got;
        assert!(apply(&mut re, &cfg, 3, 2, MODE_DELTA, sketch_of(&cfg, &[(2, 2, -3.0)]))
            .unwrap()
            .is_none());
        let full = sketch_of(&cfg, &[(1, 1, 2.0), (2, 2, -3.0), (5, 5, 9.0)]);
        let applied = apply(&mut re, &cfg, 3, 3, MODE_FULL, full).unwrap().unwrap();
        assert_eq!(applied.updates, 1);
        assert_eq!(applied.query(5, 5), 9.0);
        // wrong-family snapshot bytes are rejected
        let mut other = cfg.clone();
        other.seed = 999;
        assert!(OriginTable::decode_from(&mut Reader::new(&bytes), &other).is_err());
    }

    #[test]
    fn stalest_origin_is_evicted_at_the_cap() {
        let cfg = cfg();
        let mut t = OriginTable::new(2);
        let sk = sketch_of(&cfg, &[(1, 1, 1.0)]);
        apply(&mut t, &cfg, 1, 1, MODE_FULL, sk.clone()).unwrap();
        apply(&mut t, &cfg, 2, 1, MODE_FULL, sk.clone()).unwrap();
        // touch origin 1 so origin 2 is the stalest
        let mut grown = sk.clone();
        grown.update(9, 9, 1.0);
        apply(&mut t, &cfg, 1, 2, MODE_FULL, grown).unwrap();
        // a third origin at the cap evicts origin 2, not origin 1
        apply(&mut t, &cfg, 3, 1, MODE_FULL, sk.clone()).unwrap();
        assert_eq!(t.len(), 2);
        // origin 1's channel is intact: its dedup horizon still holds
        assert!(apply(&mut t, &cfg, 1, 2, MODE_FULL, sk.clone()).unwrap().is_none());
        // origin 2 was forgotten: a continuing delta hits the
        // unknown-origin gap path (the sender will full-ship to recover)
        let err = t.admit(2, 2, MODE_DELTA, sk.clone()).unwrap_err().to_string();
        assert!(err.contains(wire::SEQ_GAP_MARKER), "unexpected error: {err}");
        // replication never halts: new origins keep being admitted
        apply(&mut t, &cfg, 4, 1, MODE_FULL, sk).unwrap();
        assert_eq!(t.len(), 2);
    }
}
