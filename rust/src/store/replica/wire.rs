//! Wire format of the origin-headered MERGE (replication plane).
//!
//! The legacy MERGE opcode ships a bare [`StreamSketch`] and is applied
//! by pure addition — re-delivering it double-counts, because addition
//! is not idempotent. The replication plane therefore speaks a
//! *headered* merge frame (`op::MERGE_ORIGIN`):
//!
//! ```text
//! body = u64 origin_id | u64 seq | u8 mode | u8 enc | u8 ingest | sketch
//! ```
//!
//! - `origin_id` names one sender incarnation (drawn fresh per process,
//!   so a restarted sender can never collide with its old channel);
//! - `seq` increases by one per acknowledged frame on the
//!   origin→receiver channel; the receiver's per-origin dedup window
//!   ([`super::origins`]) drops any `seq` at or below the last applied
//!   one, which is what makes replication (and edge-node) retries safe;
//! - `mode` is [`MODE_DELTA`] (add the sketch) or [`MODE_FULL`] (the
//!   sender's whole cumulative origin state; the receiver applies only
//!   the part it has not already received — see `origins`);
//! - `enc` is [`ENC_DENSE`] (the standard [`MergeableSketch`] encoding)
//!   or [`ENC_SPARSE`] (below) — deltas from a short sync interval touch
//!   few buckets, and shipping only the non-zero counters is where the
//!   replicator's bandwidth win over full-state ships comes from;
//! - `ingest` distinguishes *edge ingest* (1: the mass counts as this
//!   node's own traffic and is re-originated to its peers) from
//!   *replication traffic* (0: never re-originated — relaying would
//!   double-deliver in any mesh with more than one path).
//!
//! Sparse encoding (per-repeat non-zero counters):
//!
//! ```text
//! sparse = u32 n1,n2,m1,m2,d | u64 seed | u64 updates | u8 flags
//!        | d × ( u32 nnz | nnz × (u32 bucket | f64 value) )
//! ```
//!
//! Skipping exact-zero counters is bit-safe: adding `±0.0` to any
//! counter never changes its bit pattern, so a sparse-shipped delta
//! merges bit-identically to its dense form.

use super::super::codec::{self, Reader};
use super::super::mergeable::{MergeableSketch, MAX_DECODE_ELEMS};
use crate::sketch::stream::StreamSketch;
use anyhow::{ensure, Result};

/// Additive delta frame.
pub const MODE_DELTA: u8 = 0;
/// Cumulative full-state frame (receiver applies the unseen remainder).
pub const MODE_FULL: u8 = 1;

/// Payload is the standard dense [`MergeableSketch`] encoding.
pub const ENC_DENSE: u8 = 0;
/// Payload is the sparse non-zero-counter encoding.
pub const ENC_SPARSE: u8 = 1;

/// Marker substring for receiver-side sequence-gap errors. The sender
/// matches on it to fall back to a full-state ship (the receiver lost
/// this channel's cursor — typically a receiver restart).
pub const SEQ_GAP_MARKER: &str = "origin sequence gap";

/// Parsed origin header of a `MERGE_ORIGIN` body.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OriginHeader {
    pub origin: u64,
    pub seq: u64,
    pub mode: u8,
    pub enc: u8,
    pub ingest: bool,
}

pub fn put_header(out: &mut Vec<u8>, h: &OriginHeader) {
    codec::put_u64(out, h.origin);
    codec::put_u64(out, h.seq);
    codec::put_u8(out, h.mode);
    codec::put_u8(out, h.enc);
    codec::put_u8(out, u8::from(h.ingest));
}

pub fn read_header(rd: &mut Reader<'_>) -> Result<OriginHeader> {
    let origin = rd.u64()?;
    let seq = rd.u64()?;
    let mode = rd.u8()?;
    ensure!(mode <= MODE_FULL, "unknown origin-merge mode {mode}");
    let enc = rd.u8()?;
    ensure!(enc <= ENC_SPARSE, "unknown origin-merge encoding {enc}");
    let ingest = rd.u8()?;
    ensure!(ingest <= 1, "corrupt origin-merge ingest flag {ingest}");
    Ok(OriginHeader { origin, seq, mode, enc, ingest: ingest == 1 })
}

/// Sparse-encode `sk` (only non-zero counters travel). One pass per
/// table instead of a count pass plus an emit pass: the `nnz` slot is
/// reserved up front and backpatched after the scan, and a chunk-of-8
/// prefilter ORs the sign-stripped bit patterns (`bits << 1` maps both
/// `±0.0` — and only them — to 0) to skip all-zero runs, the common
/// case in a short sync interval's delta. The per-counter predicate is
/// the same `v != ±0.0` as before (NaN bits survive the shift), so the
/// emitted bytes are identical to the two-pass form.
pub fn encode_sparse(sk: &StreamSketch, out: &mut Vec<u8>) {
    for v in [sk.n1, sk.n2, sk.m1, sk.m2, sk.d] {
        codec::put_u32(out, u32::try_from(v).expect("sketch dim too large to encode"));
    }
    codec::put_u64(out, sk.seed);
    codec::put_u64(out, sk.updates);
    codec::put_u8(out, u8::from(sk.has_deletions));
    for r in 0..sk.d {
        let table = sk.table(r);
        let nnz_pos = out.len();
        codec::put_u32(out, 0); // reserved; backpatched below
        let mut nnz: u64 = 0;
        let mut base = 0usize;
        let mut chunks = table.chunks_exact(8);
        for chunk in &mut chunks {
            let mut any = 0u64;
            for &v in chunk {
                any |= v.to_bits() << 1;
            }
            if any != 0 {
                for (off, &v) in chunk.iter().enumerate() {
                    if v.to_bits() << 1 != 0 {
                        codec::put_u32(out, (base + off) as u32);
                        codec::put_f64(out, v);
                        nnz += 1;
                    }
                }
            }
            base += 8;
        }
        for (off, &v) in chunks.remainder().iter().enumerate() {
            if v.to_bits() << 1 != 0 {
                codec::put_u32(out, (base + off) as u32);
                codec::put_f64(out, v);
                nnz += 1;
            }
        }
        let nnz = u32::try_from(nnz).expect("nnz fits u32");
        out[nnz_pos..nnz_pos + 4].copy_from_slice(&nnz.to_le_bytes());
    }
}

/// Bit-exact inverse of [`encode_sparse`] (untouched buckets decode to
/// `+0.0`, which merges as a no-op).
pub fn decode_sparse(rd: &mut Reader<'_>) -> Result<StreamSketch> {
    let n1 = rd.u32()? as usize;
    let n2 = rd.u32()? as usize;
    let m1 = rd.u32()? as usize;
    let m2 = rd.u32()? as usize;
    let d = rd.u32()? as usize;
    ensure!(
        n1 > 0 && n2 > 0 && m1 > 0 && m2 > 0 && d >= 1,
        "corrupt sparse-sketch header ({n1}x{n2} -> {m1}x{m2}, d={d})"
    );
    ensure!(
        m1.saturating_mul(m2).saturating_mul(d) <= MAX_DECODE_ELEMS,
        "sparse sketch of {d}x{m1}x{m2} counters exceeds decode cap"
    );
    let seed = rd.u64()?;
    let updates = rd.u64()?;
    let flags = rd.u8()?;
    ensure!(flags <= 1, "corrupt sparse-sketch flags byte {flags}");
    let mut sk = StreamSketch::new(n1, n2, m1, m2, d, seed);
    let buckets = m1 * m2;
    for r in 0..d {
        let nnz = rd.u32()? as usize;
        ensure!(nnz <= buckets, "sparse table {r} claims {nnz} entries in {buckets} buckets");
        let table = sk.table_mut(r);
        for _ in 0..nnz {
            let idx = rd.u32()? as usize;
            ensure!(idx < buckets, "sparse entry bucket {idx} outside table of {buckets}");
            table[idx] = rd.f64()?;
        }
    }
    sk.updates = updates;
    sk.has_deletions = flags == 1;
    Ok(sk)
}

/// Append `sk` in whichever encoding is smaller (deltas from a short
/// sync interval are usually sparse; a saturated cumulative state is
/// not). Returns the [`ENC_DENSE`] / [`ENC_SPARSE`] tag that was used.
pub fn encode_sketch_auto(sk: &StreamSketch, out: &mut Vec<u8>) -> u8 {
    // same sign-stripped-bits nonzero test as the encode_sparse scan
    let nnz: usize =
        (0..sk.d).map(|r| sk.table(r).iter().filter(|&&v| v.to_bits() << 1 != 0).count()).sum();
    // shared header is identical; per repeat sparse pays 4 + 12·nnz
    // bytes against the dense 8·m1·m2
    if 4 * sk.d + 12 * nnz < 8 * sk.space() {
        encode_sparse(sk, out);
        ENC_SPARSE
    } else {
        sk.encode(out);
        ENC_DENSE
    }
}

/// Build a complete `MERGE_ORIGIN` request payload (opcode byte
/// included) — shared by [`StoreClient::merge_origin`] and the
/// replicator, which retains the exact bytes for dedup-safe retries.
/// Full-state ships always travel dense (they are the measured
/// full-ship baseline); deltas pick the smaller encoding.
///
/// [`StoreClient::merge_origin`]: super::super::client::StoreClient::merge_origin
/// Build a complete `TMERGE_ORIGIN` request payload (opcode byte
/// included): the tensor plane's replication frame. Always a dense
/// full-state ship of the sender's cumulative per-tensor origin sketch
/// — the receiver applies only the remainder it has not seen and
/// dedups per `(origin, tensor)` sequence ([`super::origins`]'s rule,
/// per tensor), so re-sending any frame is a no-op.
pub fn build_tensor_merge(
    origin: u64,
    seq: u64,
    name: &str,
    full: &crate::store::tensor::HcsStream,
) -> Vec<u8> {
    let mut out = Vec::new();
    out.push(super::super::wire_ops::TMERGE_ORIGIN);
    codec::put_u64(&mut out, origin);
    codec::put_u64(&mut out, seq);
    codec::put_name(&mut out, name);
    full.encode(&mut out);
    out
}

pub fn build_merge_origin(
    origin: u64,
    seq: u64,
    mode: u8,
    ingest: bool,
    sk: &StreamSketch,
) -> Vec<u8> {
    let mut out = Vec::new();
    out.push(super::super::wire_ops::MERGE_ORIGIN);
    // one serializer for the header layout: the enc byte is a
    // placeholder until the payload encoding is chosen below
    put_header(&mut out, &OriginHeader { origin, seq, mode, enc: ENC_DENSE, ingest });
    let enc_pos = out.len() - 2; // enc byte sits before the ingest byte
    let enc = if mode == MODE_FULL {
        sk.encode(&mut out);
        ENC_DENSE
    } else {
        encode_sketch_auto(sk, &mut out)
    };
    out[enc_pos] = enc;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn sample_sketch(n_updates: usize) -> StreamSketch {
        let mut sk = StreamSketch::new(48, 40, 12, 10, 5, 77);
        let mut rng = Pcg64::new(5);
        for _ in 0..n_updates {
            let (i, j) = (rng.gen_range(48) as usize, rng.gen_range(40) as usize);
            let w = if rng.uniform() < 0.25 { -2.0 } else { 3.0 };
            sk.update(i, j, w);
        }
        sk
    }

    #[test]
    fn header_roundtrips() {
        let h = OriginHeader {
            origin: 0xFEED,
            seq: 42,
            mode: MODE_FULL,
            enc: ENC_SPARSE,
            ingest: true,
        };
        let mut out = Vec::new();
        put_header(&mut out, &h);
        assert_eq!(read_header(&mut Reader::new(&out)).unwrap(), h);
        // corrupt mode / enc / ingest bytes are rejected
        for (pos, bad) in [(16usize, 9u8), (17, 9), (18, 9)] {
            let mut b = out.clone();
            b[pos] = bad;
            assert!(read_header(&mut Reader::new(&b)).is_err(), "byte {pos} accepted {bad}");
        }
    }

    #[test]
    fn sparse_roundtrip_is_bit_exact() {
        for n in [0usize, 1, 30, 400] {
            let sk = sample_sketch(n);
            let mut out = Vec::new();
            encode_sparse(&sk, &mut out);
            let got = decode_sparse(&mut Reader::new(&out)).unwrap();
            assert!(sk.same_family(&got));
            assert_eq!(sk.updates, got.updates);
            assert_eq!(sk.has_deletions, got.has_deletions);
            for r in 0..sk.d {
                for (a, b) in sk.table(r).iter().zip(got.table(r).iter()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "n={n} table {r}");
                }
            }
        }
    }

    #[test]
    fn single_pass_sparse_matches_two_pass_reference_bytes() {
        for n in [0usize, 3, 57, 1000] {
            let mut sk = sample_sketch(n);
            // plant a -0.0: it equals 0.0 and must stay skipped
            sk.table_mut(0)[0] = -0.0;
            let mut got = Vec::new();
            encode_sparse(&sk, &mut got);
            // reference: the pre-backpatch two-pass form
            let mut want = Vec::new();
            for v in [sk.n1, sk.n2, sk.m1, sk.m2, sk.d] {
                codec::put_u32(&mut want, v as u32);
            }
            codec::put_u64(&mut want, sk.seed);
            codec::put_u64(&mut want, sk.updates);
            codec::put_u8(&mut want, u8::from(sk.has_deletions));
            for r in 0..sk.d {
                let table = sk.table(r);
                let nnz = table.iter().filter(|&&v| v != 0.0).count();
                codec::put_u32(&mut want, nnz as u32);
                for (idx, &v) in table.iter().enumerate() {
                    if v != 0.0 {
                        codec::put_u32(&mut want, idx as u32);
                        codec::put_f64(&mut want, v);
                    }
                }
            }
            assert_eq!(got, want, "n={n}");
        }
    }

    #[test]
    fn sparse_beats_dense_for_small_deltas() {
        let sk = sample_sketch(8);
        let mut sparse = Vec::new();
        let mut dense = Vec::new();
        encode_sparse(&sk, &mut sparse);
        sk.encode(&mut dense);
        assert!(
            sparse.len() * 4 < dense.len(),
            "sparse {} vs dense {}",
            sparse.len(),
            dense.len()
        );
        // auto picks sparse for the delta, dense for a saturated sketch
        let mut out = Vec::new();
        assert_eq!(encode_sketch_auto(&sk, &mut out), ENC_SPARSE);
        let saturated = sample_sketch(20_000);
        let mut out2 = Vec::new();
        assert_eq!(encode_sketch_auto(&saturated, &mut out2), ENC_DENSE);
    }

    #[test]
    fn sparse_rejects_corrupt_entries() {
        let sk = sample_sketch(20);
        let mut out = Vec::new();
        encode_sparse(&sk, &mut out);
        // header ends at 5*4 + 8 + 8 + 1 = 37; first table's nnz there
        let nnz_pos = 37;
        let mut oversized = out.clone();
        oversized[nnz_pos..nnz_pos + 4].copy_from_slice(&10_000u32.to_le_bytes());
        assert!(decode_sparse(&mut Reader::new(&oversized)).is_err());
        // out-of-range bucket index in the first entry
        let mut bad_idx = out;
        bad_idx[nnz_pos + 4..nnz_pos + 8].copy_from_slice(&9_999u32.to_le_bytes());
        assert!(decode_sparse(&mut Reader::new(&bad_idx)).is_err());
    }

    #[test]
    fn build_merge_origin_parses_back() {
        let sk = sample_sketch(12);
        let frame = build_merge_origin(7, 3, MODE_DELTA, false, &sk);
        let mut rd = Reader::new(&frame);
        assert_eq!(rd.u8().unwrap(), super::super::super::wire_ops::MERGE_ORIGIN);
        let h = read_header(&mut rd).unwrap();
        assert_eq!((h.origin, h.seq, h.mode, h.ingest), (7, 3, MODE_DELTA, false));
        let got = match h.enc {
            ENC_SPARSE => decode_sparse(&mut rd).unwrap(),
            _ => StreamSketch::decode(&mut rd).unwrap(),
        };
        assert_eq!(got.updates, sk.updates);
        assert!(rd.is_empty());
    }
}
