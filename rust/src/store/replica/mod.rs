//! Anti-entropy replication: turns a [`DurableStore`]/`StoreServer`
//! node into a cluster member that converges with its peers by
//! **addition** — the same linearity (`Sketch(A ⊎ B) = Sketch(A) +
//! Sketch(B)`) the paper's compositional operations exploit, applied
//! across machines. No consensus, no ordering: every node keeps
//! accepting writes, and replicas converge to the sketch of the union
//! stream as soon as every node's locally-originated mass has reached
//! every other node exactly once.
//!
//! **Delta cursor protocol.** Each node accumulates its
//! locally-originated mass (UPDATE / UPDATE_BATCH / edge-ingest MERGE —
//! never replication-plane merges, which would relay and double-deliver)
//! in a per-shard *origin* sketch, fed by the store's fused fan-out
//! kernel and stamped with a monotonic `origin_version`
//! ([`super::sharded::ShardedStore::origin_snapshot`]). Per peer the replicator keeps a
//! cursor: the last **acknowledged** origin snapshot and its version.
//! Each sync tick it ships only the mass accumulated since —
//! `snapshot − cursor`, an exact sketch subtraction — encoded
//! *sparsely* (only non-zero counters travel, [`wire`]), which is where
//! the ≥ 5× bandwidth win over shipping full `merged()` images comes
//! from. An unchanged `origin_version` ships nothing — except a tiny
//! empty-delta heartbeat every [`HEARTBEAT_TICKS`] idle ticks, which is
//! how an idle sender discovers a restarted receiver (the heartbeat
//! draws the sequence-gap error that triggers the healing full ship).
//!
//! **Full-ship fallback rules.** A dense full-state frame (the entire
//! cumulative origin sketch) is shipped instead of a delta when:
//! 1. the channel is new (first contact — the peer may hold nothing);
//! 2. the receiver reports a **sequence gap** ([`wire::SEQ_GAP_MARKER`]
//!    — it lost channel state, typically a restart, since replica-plane
//!    mass is deliberately not WAL-logged and is restored by exactly
//!    this path);
//! 3. the configured cadence forces one every
//!    [`ReplicaConfig::full_ship_every`] syncs (a periodic self-healing
//!    safety net; `0` disables it).
//! Full frames are safe to deliver at any time because the receiver
//! applies only the *remainder* it has not seen ([`origins`]).
//!
//! **Dedup window / retry safety.** Every frame carries an origin id
//! (fresh per process incarnation) and a per-channel sequence number;
//! the receiver drops any sequence at or below its per-origin horizon.
//! After an ambiguous failure the replicator re-sends the *identical
//! bytes* under the same sequence (kept in `Pending`), so a frame that
//! did land is acknowledged as a no-op and the cursor still advances
//! exactly once. Connections use bounded connect/IO timeouts and
//! exponential reconnect backoff — a hung peer can neither stall the
//! replicator nor starve the other peers.
//!
//! # Failure model
//!
//! Both sides of a channel are durable. The *receiver* persists the
//! per-origin dedup table in every snapshot and WAL-logs ingest
//! origin-merges ([`super::wal`]); replication-plane merges are
//! deliberately not logged — the snapshot's origin records and store
//! image describe the same instant, so after a receiver restart the
//! sender's gap-triggered full ship re-delivers exactly the
//! since-snapshot remainder. The *sender* persists its origin id (a
//! WAL record, minted once per store lifetime), its cumulative origin
//! accumulator (in every snapshot, rebuilt by WAL replay — recovery
//! re-enables replication *before* replay on a node that ever
//! replicated), and a per-peer cursor `(acked seq, acked origin
//! version)` logged only **after** the peer acknowledged the frame.
//!
//! The ack/advance ordering is the safety argument. If logging a
//! cursor advance fails, the channel does **not** move forward: the
//! staged frame is kept and re-sent identically (the receiver dedups
//! it into an acknowledged no-op), so the durable cursor trails the
//! receiver's dedup horizon by at most one frame. A restarted sender
//! therefore resumes at `acked seq + 2` — strictly above any horizon
//! the receiver can hold — with `synced_once = false`, so its first
//! frame is a dense full-state ship under the *recovered* origin id:
//! the receiver applies `full − its cumulative per-origin record`,
//! which is exactly the WAL-recovered-but-unshipped remainder. No
//! double-count (the record subtracts what already landed), no loss
//! (the accumulator is rebuilt from snapshot + WAL). A sender whose
//! WAL has fail-stopped ([`DurableStore::wal_healthy`]) stops spending
//! idle heartbeats — it could not durably record the advances they
//! produce — but still delivers already-staged mass; the receiver
//! converges even when the sender can no longer record that it did.
//! Window expiry is local — peers expire by their own rotations, so a
//! replica's slot assignment for remote mass lags by the staleness the
//! bench measures.
//!
//! **Tensor plane.** Named HCS tensors ([`super::tensor`]) ride the
//! same loop with a deliberately simpler protocol: each sync that
//! touches a peer also ships every tensor whose registry version is
//! above that peer's per-tensor ack (`TMERGE_ORIGIN`,
//! [`wire::build_tensor_merge`]) as an idempotent dense full-state
//! frame — the receiver applies only the remainder it has not seen and
//! dedups per `(origin, tensor)` sequence, so there is no staged-retry
//! state to carry and a lost ack just re-ships next tick. Tensors are
//! small (sketch space, not key space), so full ships are cheap enough
//! to skip the delta-cursor machinery. A 2-D full ship (the
//! receiver-restart signal) clears the per-tensor acks too, so a
//! restarted receiver gets its tensor mass re-delivered alongside.

pub mod origins;
pub mod wire;

use super::client::{ClientOptions, StoreClient, SERVER_ERR_PREFIX};
use super::faults;
use super::sharded::StoreConfig;
use super::wal::DurableStore;
use crate::rng::SplitMix64;
use crate::sketch::stream::StreamSketch;
use anyhow::{ensure, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

pub use origins::{Admit, OriginTable, MAX_ORIGINS};

/// How a node replicates to its peers.
#[derive(Clone, Debug)]
pub struct ReplicaConfig {
    /// peer addresses (`host:port` of their store servers)
    pub peers: Vec<String>,
    /// anti-entropy tick interval
    pub sync_interval_ms: u64,
    /// force a dense full-state ship every Nth sync per peer (self-
    /// healing cadence); `0` = only on first contact / sequence gaps
    pub full_ship_every: u64,
    /// connect timeout for peer connections
    pub connect_timeout_ms: u64,
    /// read/write timeout for peer RPCs — a hung peer costs at most
    /// this long per tick, then backs off
    pub io_timeout_ms: u64,
}

impl Default for ReplicaConfig {
    fn default() -> Self {
        Self {
            peers: Vec::new(),
            sync_interval_ms: 100,
            full_ship_every: 0,
            connect_timeout_ms: 1_000,
            io_timeout_ms: 2_000,
        }
    }
}

impl ReplicaConfig {
    /// `0` means no timeout — the same convention as
    /// [`ClientOptions::timeout_ms`] and the store-client CLI (not
    /// recommended for the replicator: a hung peer then blocks its
    /// whole sync tick).
    fn client_options(&self) -> ClientOptions {
        ClientOptions {
            connect_timeout: (self.connect_timeout_ms > 0)
                .then(|| Duration::from_millis(self.connect_timeout_ms)),
            io_timeout: (self.io_timeout_ms > 0).then(|| Duration::from_millis(self.io_timeout_ms)),
        }
    }
}

/// Idle channels send a tiny empty-delta heartbeat every this many sync
/// ticks. The heartbeat is what lets an idle sender discover a receiver
/// restart: the receiver answers it with a sequence gap (its channel
/// state died with its un-snapshotted replica mass) and the sender
/// full-ships the recovery — without it, a cluster that goes quiet
/// right before a receiver crash would never heal.
const HEARTBEAT_TICKS: u64 = 50;

/// Shared replication counters: written by the replicator thread and
/// the server's origin-merge path, read by the STATS RPC.
pub struct ReplicationCounters {
    start: Instant,
    peers: AtomicU64,
    /// millis since `start` of the last *settled* sync tick (every
    /// channel acked, nothing staged); `u64::MAX` = never settled
    last_sync_ms: AtomicU64,
    /// minimum acknowledged origin-version across peers
    cursor_version: AtomicU64,
    ships: AtomicU64,
    full_ships: AtomicU64,
    bytes_shipped: AtomicU64,
    merges_applied: AtomicU64,
    merges_deduped: AtomicU64,
}

/// Point-in-time replication counters (STATS RPC /
/// `hocs store-client stats`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReplicationStats {
    pub peers: u64,
    /// age of the last *settled* sync tick — every channel acked with
    /// nothing staged, so a partitioned peer makes this grow instead
    /// of hiding behind a liveness tick; `None` = never settled
    pub last_sync_age_ms: Option<u64>,
    /// minimum acknowledged origin-version across peers (how far behind
    /// the slowest peer's cursor is)
    pub cursor_version: u64,
    /// acknowledged frames (delta + full)
    pub ships: u64,
    pub full_ships: u64,
    /// payload bytes of acknowledged frames
    pub bytes_shipped: u64,
    /// origin-headered merges applied by this node
    pub merges_applied: u64,
    /// origin-headered merges dropped by the dedup window
    pub merges_deduped: u64,
}

impl ReplicationCounters {
    pub fn new(peers: u64) -> Self {
        Self {
            start: Instant::now(),
            peers: AtomicU64::new(peers),
            last_sync_ms: AtomicU64::new(u64::MAX),
            cursor_version: AtomicU64::new(0),
            ships: AtomicU64::new(0),
            full_ships: AtomicU64::new(0),
            bytes_shipped: AtomicU64::new(0),
            merges_applied: AtomicU64::new(0),
            merges_deduped: AtomicU64::new(0),
        }
    }

    fn now_ms(&self) -> u64 {
        u64::try_from(self.start.elapsed().as_millis()).unwrap_or(u64::MAX - 1)
    }

    pub(crate) fn note_tick(&self, cursor_version: u64, settled: bool) {
        self.cursor_version.store(cursor_version, Ordering::Relaxed);
        if settled {
            self.last_sync_ms.store(self.now_ms(), Ordering::Relaxed);
        }
    }

    pub(crate) fn note_ship(&self, bytes: u64, full: bool) {
        self.ships.fetch_add(1, Ordering::Relaxed);
        self.bytes_shipped.fetch_add(bytes, Ordering::Relaxed);
        if full {
            self.full_ships.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub(crate) fn note_applied(&self) {
        self.merges_applied.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn note_deduped(&self) {
        self.merges_deduped.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> ReplicationStats {
        let last = self.last_sync_ms.load(Ordering::Relaxed);
        ReplicationStats {
            peers: self.peers.load(Ordering::Relaxed),
            last_sync_age_ms: (last != u64::MAX).then(|| self.now_ms().saturating_sub(last)),
            cursor_version: self.cursor_version.load(Ordering::Relaxed),
            ships: self.ships.load(Ordering::Relaxed),
            full_ships: self.full_ships.load(Ordering::Relaxed),
            bytes_shipped: self.bytes_shipped.load(Ordering::Relaxed),
            merges_applied: self.merges_applied.load(Ordering::Relaxed),
            merges_deduped: self.merges_deduped.load(Ordering::Relaxed),
        }
    }
}

/// A staged frame awaiting acknowledgement. Retries after ambiguous
/// failures re-send exactly these bytes under the same sequence — the
/// receiver's dedup window turns an already-applied copy into an
/// acknowledged no-op, so the cursor advances exactly once either way.
struct Pending {
    frame: Vec<u8>,
    /// origin snapshot/version this frame brings the peer up to
    snap: StreamSketch,
    version: u64,
    full: bool,
}

struct Peer {
    addr: String,
    client: Option<StoreClient>,
    /// next channel sequence to assign
    next_seq: u64,
    /// origin snapshot known applied at the peer (the delta cursor)
    acked: StreamSketch,
    acked_version: u64,
    synced_once: bool,
    syncs_since_full: u64,
    /// consecutive ticks with nothing to ship; at [`HEARTBEAT_TICKS`]
    /// an empty delta probes the channel (receiver-restart detection)
    idle_ticks: u64,
    pending: Option<Pending>,
    backoff_ms: u64,
    backoff_until: Instant,
    /// per-tensor registry version known applied at the peer (tensor
    /// frames are idempotent full ships — no staged retry, no cursor
    /// sketch; a lost ack just re-ships next tick). In-memory only:
    /// after a sender restart every tensor re-ships once and dedups.
    tensor_acked: HashMap<String, u64>,
    /// registry version stamp as of the last tick whose dirty-tensor
    /// scan came back empty for this peer (the cheap-probe analogue of
    /// `acked_version` for the tensor plane)
    tensor_synced: u64,
    /// this channel's exported slot in the global metrics registry
    /// (lag gauge, ship/byte counters)
    obs: std::sync::Arc<crate::obs::registry::PeerObs>,
}

impl Peer {
    fn new(addr: String, cfg: &StoreConfig) -> Self {
        let obs = crate::obs::global().register_peer(&addr);
        Self {
            addr,
            client: None,
            next_seq: 1,
            acked: cfg.fresh_sketch(),
            acked_version: 0,
            synced_once: false,
            syncs_since_full: 0,
            idle_ticks: 0,
            pending: None,
            backoff_ms: 0,
            backoff_until: Instant::now(),
            tensor_acked: HashMap::new(),
            tensor_synced: 0,
            obs,
        }
    }

    fn bump_backoff(&mut self) {
        self.backoff_ms = (self.backoff_ms * 2).clamp(50, 5_000);
        self.backoff_until = Instant::now() + Duration::from_millis(self.backoff_ms);
    }
}

struct Stop {
    stopped: Mutex<bool>,
    cv: Condvar,
}

/// The per-node anti-entropy thread: one loop over all configured
/// peers, one origin snapshot per tick shared by every peer's delta.
pub struct Replicator {
    stop: Arc<Stop>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Replicator {
    pub fn start(
        store: Arc<DurableStore>,
        cfg: ReplicaConfig,
        counters: Arc<ReplicationCounters>,
    ) -> Result<Self> {
        ensure!(!cfg.peers.is_empty(), "replicator needs at least one peer");
        ensure!(
            store.store().replication_enabled(),
            "enable_replication() must be called before starting the replicator"
        );
        let stop = Arc::new(Stop { stopped: Mutex::new(false), cv: Condvar::new() });
        let tstop = stop.clone();
        let handle = std::thread::Builder::new()
            .name("hocs-replicator".into())
            .spawn(move || run(store, cfg, counters, tstop))?;
        Ok(Self { stop, handle: Some(handle) })
    }
}

impl Drop for Replicator {
    fn drop(&mut self) {
        // a poisoned stop lock means the replicator thread already
        // panicked out of its loop — nothing left to signal
        if let Ok(mut stopped) = self.stop.stopped.lock() {
            *stopped = true;
        }
        self.stop.cv.notify_all();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Mint a fresh origin id. Normally called once per store *lifetime*
/// (via [`DurableStore::replica_id`], which persists it): keeping the
/// id across restarts is what lets a recovered sender resume its old
/// channels and ship exactly the unshipped remainder instead of
/// double-counting under a new identity.
pub(crate) fn derive_origin_id() -> u64 {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    SplitMix64::new(nanos ^ ((std::process::id() as u64) << 32) ^ 0x5EED_0121_6171).next_u64()
}

fn run(
    store: Arc<DurableStore>,
    cfg: ReplicaConfig,
    counters: Arc<ReplicationCounters>,
    stop: Arc<Stop>,
) {
    let origin_id = match store.replica_id() {
        Ok(id) => id,
        Err(e) => {
            // fail-stopped WAL before the id was ever minted: replicate
            // under a volatile id rather than not at all (peers dedup
            // per id, so a later durable incarnation still converges)
            crate::log_warn!("replicator: cannot persist origin id ({e}); using a volatile one");
            derive_origin_id()
        }
    };
    let family = store.config().clone();
    let mut peers: Vec<Peer> = cfg
        .peers
        .iter()
        .map(|a| {
            let mut p = Peer::new(a.clone(), &family);
            if let Some((seq, version)) = store.replica_cursor(&p.addr) {
                // resume strictly above any dedup horizon the receiver
                // can hold (the durable cursor trails it by ≤ 1 frame);
                // synced_once stays false, so the first frame is a full
                // ship of the recovered accumulator and the receiver
                // applies exactly the unshipped remainder
                p.next_seq = seq + 2;
                crate::log_info!(
                    "replicator: resuming {} from durable cursor (seq {seq}, version {version})",
                    p.addr
                );
            }
            p
        })
        .collect();
    let interval = Duration::from_millis(cfg.sync_interval_ms.max(1));
    crate::log_info!(
        "replicator: origin {origin_id:#x}, {} peer(s), sync every {}ms",
        peers.len(),
        interval.as_millis()
    );
    loop {
        {
            // a poisoned stop lock means the owning thread panicked;
            // winding the replicator down beats panicking a second
            // thread (and taking the whole process's locks with it)
            let Ok(guard) = stop.stopped.lock() else {
                break;
            };
            let Ok((guard, _)) = stop.cv.wait_timeout_while(guard, interval, |stopped| !*stopped)
            else {
                break;
            };
            if *guard {
                break;
            }
        }
        // cheap probe first: an idle cluster must not pay the lock-all
        // K-way origin merge 60+ times a second just to discover there
        // is nothing to ship and no staged retry outstanding. Peers in
        // reconnect backoff are excluded (a dead peer must not force
        // the snapshot either); synced idle channels accrue heartbeat
        // credit here so receiver restarts are probed even with no
        // local writes.
        let stamp = store.origin_version();
        let tstamp = store.tensor_version();
        let now = Instant::now();
        // a fail-stopped WAL cannot durably record cursor advances, so
        // idle heartbeats (whose only product is an advance) stop;
        // already-staged mass still delivers — see the module docs
        let healthy = store.wal_healthy();
        let mut need = false;
        for p in peers.iter_mut() {
            if now < p.backoff_until {
                continue;
            }
            if p.pending.is_some()
                || p.acked_version != stamp
                || p.tensor_synced != tstamp
                || !p.synced_once
            {
                need = true;
            } else if healthy {
                p.idle_ticks += 1;
                if p.idle_ticks >= HEARTBEAT_TICKS {
                    need = true;
                }
            }
        }
        if need {
            let ctx = SyncCtx {
                store: &store,
                cfg: &cfg,
                counters: &counters,
                origin_id,
                allow_heartbeat: healthy,
            };
            let (version, snap) = store.origin_snapshot();
            for peer in peers.iter_mut() {
                sync_peer(peer, &snap, version, &ctx);
                sync_tensors(peer, tstamp, &ctx);
            }
        }
        let cursor = peers.iter().map(|p| p.acked_version).min().unwrap_or(0);
        // the sync age only advances when every channel is settled:
        // contacted at least once, nothing staged, cursor at least at
        // the probed stamp — a partitioned or never-reached peer makes
        // the age grow (or stay "never") instead of masking the outage
        // behind a liveness tick
        let now_ms = crate::obs::now_ms();
        let mut settled = true;
        for p in peers.iter() {
            let peer_settled = p.synced_once
                && p.pending.is_none()
                && p.acked_version >= stamp
                && p.tensor_synced >= tstamp;
            if peer_settled {
                // per-peer lag gauge: now − last settled tick
                p.obs.note_settled(now_ms);
            } else {
                settled = false;
            }
        }
        crate::obs::global().repl_ticks.inc();
        if settled {
            crate::obs::global().repl_settled_ticks.inc();
        }
        counters.note_tick(cursor, settled);
    }
    crate::log_info!("replicator: stopping");
}

/// Per-tick context shared by every peer's [`sync_peer`] call.
struct SyncCtx<'a> {
    store: &'a DurableStore,
    cfg: &'a ReplicaConfig,
    counters: &'a ReplicationCounters,
    origin_id: u64,
    /// heartbeats allowed this tick (off while the WAL is fail-stopped
    /// — their only product is a cursor advance it could not record)
    allow_heartbeat: bool,
}

/// One peer's share of a sync tick: stage a frame if there is unshipped
/// mass, then try to deliver whatever is staged (possibly a retry from
/// an earlier tick). At most two delivery attempts per tick (the second
/// only for the gap → full-ship fallback).
fn sync_peer(p: &mut Peer, snap: &StreamSketch, version: u64, ctx: &SyncCtx<'_>) {
    if Instant::now() < p.backoff_until {
        return;
    }
    if p.client.is_none() {
        match StoreClient::connect_with(&p.addr, ctx.cfg.client_options()) {
            Ok(c) => {
                p.client = Some(c);
                p.backoff_ms = 0;
            }
            Err(e) => {
                crate::log_debug!("replicator: cannot reach {} ({e})", p.addr);
                p.bump_backoff();
                return;
            }
        }
    }
    if p.pending.is_none() {
        // nothing staged: establish a never-contacted channel (an
        // eager first-contact full ship, so "synced" always means
        // "actually acked"), ship new mass, or probe an idle channel
        // with a tiny empty-delta heartbeat (a receiver that restarted
        // and lost un-snapshotted replica mass answers it with a
        // sequence gap, which triggers the healing full ship)
        let heartbeat = p.synced_once && p.idle_ticks >= HEARTBEAT_TICKS && ctx.allow_heartbeat;
        if version == p.acked_version && p.synced_once && !heartbeat {
            return; // unchanged cursor — zero bytes on idle channels
        }
        p.idle_ticks = 0;
        let force_full = !p.synced_once
            || (ctx.cfg.full_ship_every > 0 && p.syncs_since_full + 1 >= ctx.cfg.full_ship_every);
        if force_full {
            // a dense 2-D full ship means the channel may be starting
            // from nothing (first contact / healing cadence) — forget
            // the tensor acks so every tensor re-ships too; duplicates
            // dedup on the receiver's (origin, tensor) sequence
            p.tensor_acked.clear();
            p.tensor_synced = 0;
        }
        p.pending = Some(stage(p.next_seq, ctx.origin_id, snap, &p.acked, version, force_full));
    }
    for attempt in 0..2 {
        let Some(pending) = p.pending.as_ref() else { return };
        // connected above (or the function already returned); if that
        // invariant ever breaks, skip the tick instead of killing the
        // replicator thread
        let Some(client) = p.client.as_mut() else { return };
        let sent = faults::fire("repl.send")
            .map_err(anyhow::Error::from)
            .and_then(|()| client.raw_call(&pending.frame));
        match sent {
            Ok(_) => {
                // applied or deduped — both mean the peer now holds
                // everything up to this frame's snapshot. Record the
                // advance durably BEFORE moving the channel forward: if
                // the cursor log fails, the frame stays staged and the
                // next tick re-sends identical bytes (the receiver
                // dedups them into an acknowledged no-op), so the
                // durable cursor never trails the receiver's horizon by
                // more than one frame — the restart-resume invariant.
                let Some(done) = p.pending.take() else { return };
                if let Err(e) = ctx.store.advance_replica_cursor(&p.addr, p.next_seq, done.version)
                {
                    crate::log_warn!(
                        "replicator: {} acked seq {} but the cursor advance did not \
                         persist ({e}); keeping the frame staged for a dedup-safe retry",
                        p.addr,
                        p.next_seq
                    );
                    p.pending = Some(done);
                    p.bump_backoff();
                    return;
                }
                ctx.counters.note_ship(done.frame.len() as u64, done.full);
                p.obs.note_ship(done.frame.len() as u64, done.full);
                p.acked = done.snap;
                p.acked_version = done.version;
                p.next_seq += 1;
                p.synced_once = true;
                p.syncs_since_full = if done.full { 0 } else { p.syncs_since_full + 1 };
                p.backoff_ms = 0; // healthy channel: next failure starts backoff fresh
                return;
            }
            Err(e) => {
                let msg = e.to_string();
                if msg.contains(wire::SEQ_GAP_MARKER) && attempt == 0 {
                    // the peer lost this channel's state (receiver
                    // restart): rebuild the staged frame as a dense
                    // full-state ship under the same sequence and try
                    // once more this tick
                    crate::log_info!(
                        "replicator: {} reports a sequence gap; falling back to a \
                         full-state ship",
                        p.addr
                    );
                    p.pending =
                        Some(stage(p.next_seq, ctx.origin_id, snap, &p.acked, version, true));
                    // the gap means the receiver restarted and lost its
                    // un-logged replica-plane mass — tensor mass
                    // included, so those channels reset alongside
                    p.tensor_acked.clear();
                    p.tensor_synced = 0;
                    continue;
                }
                if msg.contains(SERVER_ERR_PREFIX) {
                    // server-side rejection that is not a gap (e.g. a
                    // family mismatch): the connection is healthy and
                    // the frame stays staged, but a persistent
                    // rejection must not retry a possibly-large frame
                    // at full tick rate — back off like a transport
                    // failure while keeping the connection
                    crate::log_warn!("replicator: {} rejected frame: {msg}", p.addr);
                    p.bump_backoff();
                } else {
                    // transport failure — ambiguous delivery; keep the
                    // staged bytes for an identical (dedup-safe) retry
                    crate::log_debug!("replicator: {} transport error: {msg}", p.addr);
                    p.client = None;
                    p.bump_backoff();
                }
                return;
            }
        }
    }
}

/// One peer's tensor-plane share of a sync tick: ship every tensor
/// whose registry version is above this peer's ack as an idempotent
/// dense full-state `TMERGE_ORIGIN` frame (sequence = that version, so
/// the receiver's per-`(origin, tensor)` horizon dedups re-delivery).
/// Deliberately no staged-retry state: a failed or ambiguous send just
/// re-ships the then-current full sketch next tick, which subsumes the
/// lost frame by linearity. Runs only on a channel [`sync_peer`] has
/// already established this incarnation (`synced_once`), so tensor
/// frames never race ahead of the first-contact 2-D full ship.
fn sync_tensors(p: &mut Peer, tstamp: u64, ctx: &SyncCtx<'_>) {
    if !p.synced_once || p.client.is_none() || Instant::now() < p.backoff_until {
        return;
    }
    let dirty = ctx.store.tensor_dirty_origins(&p.tensor_acked);
    if dirty.is_empty() {
        p.tensor_synced = tstamp;
        return;
    }
    for (name, version, full) in dirty {
        // re-borrow each iteration: the error arm below may drop the
        // connection, and `tensor_acked` needs `p` back in the Ok arm
        let Some(client) = p.client.as_mut() else { return };
        let frame = wire::build_tensor_merge(ctx.origin_id, version, &name, &full);
        let sent = faults::fire("repl.send")
            .map_err(anyhow::Error::from)
            .and_then(|()| client.raw_call(&frame));
        match sent {
            Ok(_) => {
                // applied or deduped — either way the peer holds this
                // tensor's mass through `version`
                ctx.counters.note_ship(frame.len() as u64, true);
                p.obs.note_ship(frame.len() as u64, true);
                p.tensor_acked.insert(name, version);
            }
            Err(e) => {
                let msg = e.to_string();
                if msg.contains(SERVER_ERR_PREFIX) {
                    // server-side rejection (e.g. a family mismatch at
                    // the receiver): back off rather than re-send a
                    // doomed frame at full tick rate
                    crate::log_warn!(
                        "replicator: {} rejected tensor {name:?} frame: {msg}",
                        p.addr
                    );
                } else {
                    crate::log_debug!(
                        "replicator: {} transport error on tensor ship: {msg}",
                        p.addr
                    );
                    p.client = None;
                }
                p.bump_backoff();
                return;
            }
        }
    }
    p.tensor_synced = tstamp;
}

/// Build the staged frame for `seq`: a dense full-state ship of the
/// whole origin snapshot, or the sparse-encoded exact delta since the
/// peer's cursor.
fn stage(
    seq: u64,
    origin_id: u64,
    snap: &StreamSketch,
    acked: &StreamSketch,
    version: u64,
    full: bool,
) -> Pending {
    let frame = if full {
        wire::build_merge_origin(origin_id, seq, wire::MODE_FULL, false, snap)
    } else {
        // exact by linearity: snapshot − cursor is precisely the mass
        // accumulated since the last acknowledged ship
        let mut delta = snap.clone();
        delta.merge_scaled(acked, -1.0);
        wire::build_merge_origin(origin_id, seq, wire::MODE_DELTA, false, &delta)
    };
    Pending { frame, snap: snap.clone(), version, full }
}
