//! Debug-build lock-order checker — the machine-checked lock-ordering
//! contract for the store.
//!
//! Every guarantee about deadlock freedom in this subsystem used to be
//! folklore: the PR-2 sharding work fixed a scan/write ordering bug and
//! the PR-3/PR-4 durability work fixed a gate/queue one, and both fixes
//! survive only as comments. This module turns the convention into a
//! checker with the same shape as [`super::faults`]: fully armed under
//! `#[cfg(debug_assertions)]` (so `cargo test` and the crash matrix's
//! debug children run every suite under it) and compiled to inlineable
//! no-ops in release builds (verified by the `is_armed` cfg test).
//!
//! ## The lock hierarchy
//!
//! Acquisitions must respect this class order, top to bottom:
//!
//! ```text
//! DDL              tensor DDL mutex (serializes create/replicate-create)
//!   COMMIT_GATE    RwLock: shared for append→apply, exclusive for
//!                  snapshot / advance_epoch / truncation
//!     SCAN_CACHE   version-stamped merged-scan cache mutex
//!       WAL_QUEUE  group-commit leader/follower queue mutex
//!         SHARD    per-shard mutexes, ascending shard index only
//!           TENSOR_REGISTRY  the one tensor-catalog mutex
//! ```
//!
//! Skipping levels is fine (a point query takes only `SHARD`); taking a
//! *higher* class while holding a lower one, or two shards out of index
//! order, is a bug even if it does not deadlock on this run — some
//! interleaving will. Each [`acquire`] records the edge
//! `held-class → acquiring-class` in a global acquisition-order graph
//! and panics (with the current held stack and the recorded stack of
//! the conflicting edge) as soon as any cycle appears, on the *first*
//! run that exhibits both orders — no unlucky timing needed.
//!
//! **Registration order matters**: call [`acquire`] *before* blocking
//! on the real lock, so an ordering violation panics loudly instead of
//! deadlocking the test suite.
//!
//! ## Deliberate exclusions
//!
//! The origin-snapshot table and replica-cursor mutexes are *not*
//! classes: `apply_origin_merge` takes origins → WAL queue while
//! `snapshot` takes WAL queue → origins, which a naive order graph
//! would call a cycle. Both paths hold the commit gate (shared vs
//! exclusive), which serializes them — the "cycle" is unreachable.
//! Gate-serialized leaf mutexes stay out of the graph; everything that
//! can actually interleave is in it. The replicator's stop-signal
//! mutex/condvar pair is its own single-lock domain and is likewise
//! not a class.
//!
//! ## Adding a lock
//!
//! Give it a class here (or reuse one), place it in the hierarchy
//! comment above, and wrap each acquisition site:
//!
//! ```ignore
//! let _ld = lockdep::acquire(lockdep::SHARD, shard_index as u32);
//! let guard = shard.lock().expect("shard lock");
//! ```
//!
//! The returned [`Held`] token unregisters on drop (by identity, not
//! LIFO — guard vectors from `lock_all` drop front-to-back and that is
//! fine).

/// A lock class — one level of the store's lock hierarchy. The `u16`
/// is an arbitrary id; ids ≥ 100 are reserved for tests.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct Class(pub u16);

/// Tensor DDL mutex (`DurableStore::ddl`).
pub const DDL: Class = Class(0);
/// Commit gate RwLock (`DurableStore::commit`).
pub const COMMIT_GATE: Class = Class(1);
/// Merged-scan cache mutex (`ShardedStore::scan`).
pub const SCAN_CACHE: Class = Class(2);
/// Group-commit queue mutex (`GroupCommitLog::state`).
pub const WAL_QUEUE: Class = Class(3);
/// Per-shard mutexes — ascending shard index order enforced.
pub const SHARD: Class = Class(4);
/// Tensor registry mutex (`ShardedStore::tensors`).
pub const TENSOR_REGISTRY: Class = Class(5);

impl Class {
    fn label(self, index: u32) -> String {
        match self {
            DDL => "ddl".into(),
            COMMIT_GATE => "commit-gate".into(),
            SCAN_CACHE => "scan-cache".into(),
            WAL_QUEUE => "wal-queue".into(),
            SHARD => format!("shard[{index}]"),
            TENSOR_REGISTRY => "tensor-registry".into(),
            Class(n) => format!("class-{n}"),
        }
    }
}

#[cfg(debug_assertions)]
mod armed {
    use super::Class;
    use std::cell::RefCell;
    use std::collections::HashMap;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::{Mutex, OnceLock};

    #[derive(Clone, Copy)]
    struct Entry {
        id: u64,
        class: u16,
        index: u32,
    }

    thread_local! {
        /// Locks this thread currently holds, in acquisition order.
        static HELD: RefCell<Vec<Entry>> = const { RefCell::new(Vec::new()) };
    }

    static NEXT_ID: AtomicU64 = AtomicU64::new(1);

    /// Where an order edge was first observed — enough to print "the
    /// other side" of a cycle without capturing OS backtraces.
    struct EdgeInfo {
        thread: String,
        stack: Vec<(u16, u32)>,
    }

    /// `edges[(a, b)]` = some thread acquired class `b` while holding
    /// class `a`. A cycle in this graph is an ordering bug.
    struct Graph {
        edges: HashMap<(u16, u16), EdgeInfo>,
    }

    impl Graph {
        /// Is `to` reachable from `from` over recorded edges?
        fn reaches(&self, from: u16, to: u16) -> bool {
            let mut stack = vec![from];
            let mut seen = std::collections::HashSet::new();
            while let Some(c) = stack.pop() {
                if c == to {
                    return true;
                }
                if seen.insert(c) {
                    stack.extend(self.edges.keys().filter(|(a, _)| *a == c).map(|(_, b)| *b));
                }
            }
            false
        }
    }

    fn graph() -> &'static Mutex<Graph> {
        static GRAPH: OnceLock<Mutex<Graph>> = OnceLock::new();
        GRAPH.get_or_init(|| Mutex::new(Graph { edges: HashMap::new() }))
    }

    fn render(stack: &[(u16, u32)]) -> String {
        if stack.is_empty() {
            return "(none)".into();
        }
        stack
            .iter()
            .map(|&(c, i)| Class(c).label(i))
            .collect::<Vec<_>>()
            .join(" -> ")
    }

    /// An acquisition registered on this thread's held stack; dropping
    /// it (by identity) unregisters.
    #[must_use = "dropping the token immediately unregisters the acquisition"]
    pub struct Held {
        id: u64,
    }

    impl Drop for Held {
        fn drop(&mut self) {
            // try_with: thread-local teardown during process exit must
            // not turn into a second panic
            let _ = HELD.try_with(|h| {
                let mut v = h.borrow_mut();
                if let Some(pos) = v.iter().rposition(|e| e.id == self.id) {
                    v.remove(pos);
                }
            });
        }
    }

    /// `true` when the checker is compiled in (debug builds).
    pub fn is_armed() -> bool {
        true
    }

    /// Register acquiring `class` (shard `index` for [`super::SHARD`],
    /// 0 otherwise). Call *before* blocking on the real lock. Panics on
    /// any ordering violation.
    pub fn acquire(class: Class, index: u32) -> Held {
        let snapshot: Vec<(u16, u32)> =
            HELD.with(|h| h.borrow().iter().map(|e| (e.class, e.index)).collect());

        // intra-thread rules: shards ascend strictly; no other class is
        // re-entrant
        for &(c, i) in &snapshot {
            if c != class.0 {
                continue;
            }
            if class == super::SHARD && i < index {
                continue;
            }
            let what = if class == super::SHARD {
                "out-of-index-order shard acquisition"
            } else {
                "re-entrant acquisition"
            };
            panic!(
                "lockdep: {what}: thread {:?} acquiring {} while holding [{}]",
                std::thread::current().name().unwrap_or("?"),
                class.label(index),
                render(&snapshot),
            );
        }

        // cross-thread rule: record held -> acquiring edges; any cycle
        // means two threads disagree on the order
        let mut cycle: Option<String> = None;
        {
            let mut g = graph().lock().unwrap_or_else(|p| p.into_inner());
            for &(c, _) in &snapshot {
                if c == class.0 || g.edges.contains_key(&(c, class.0)) {
                    continue;
                }
                if g.reaches(class.0, c) {
                    // don't insert the bad edge — later tests must not
                    // inherit a poisoned graph
                    let reverse = g
                        .edges
                        .iter()
                        .filter(|((a, b), _)| (g.reaches(class.0, *a) && *b == c) || *a == class.0)
                        .map(|((a, b), info)| {
                            format!(
                                "  edge {} -> {} first seen on thread {:?} holding [{}]",
                                Class(*a).label(0),
                                Class(*b).label(0),
                                info.thread,
                                render(&info.stack),
                            )
                        })
                        .collect::<Vec<_>>()
                        .join("\n");
                    cycle = Some(format!(
                        "lockdep: ordering cycle: thread {:?} acquiring {} while holding [{}], \
                         but the reverse order is already on record:\n{reverse}",
                        std::thread::current().name().unwrap_or("?"),
                        class.label(index),
                        render(&snapshot),
                    ));
                    break;
                }
                g.edges.insert(
                    (c, class.0),
                    EdgeInfo {
                        thread: std::thread::current().name().unwrap_or("?").to_string(),
                        stack: snapshot.clone(),
                    },
                );
            }
        }
        if let Some(msg) = cycle {
            panic!("{msg}");
        }

        let id = NEXT_ID.fetch_add(1, Ordering::Relaxed);
        HELD.with(|h| h.borrow_mut().push(Entry { id, class: class.0, index }));
        Held { id }
    }
}

#[cfg(not(debug_assertions))]
mod disarmed {
    use super::Class;

    /// Release-build token: a ZST with no `Drop` — the whole checker
    /// inlines away.
    #[must_use = "dropping the token immediately unregisters the acquisition"]
    pub struct Held;

    /// `false` in release builds: [`acquire`] is a no-op.
    #[inline(always)]
    pub fn is_armed() -> bool {
        false
    }

    #[inline(always)]
    pub fn acquire(_class: Class, _index: u32) -> Held {
        Held
    }
}

#[cfg(debug_assertions)]
pub use armed::{acquire, is_armed, Held};
#[cfg(not(debug_assertions))]
pub use disarmed::{acquire, is_armed, Held};

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::catch_unwind;

    /// Acceptance gate: armed exactly in debug builds, no-op in release
    /// (`cargo test --release` runs this same assertion).
    #[test]
    fn armed_matches_debug_assertions() {
        assert_eq!(is_armed(), cfg!(debug_assertions));
    }

    #[cfg(debug_assertions)]
    mod debug_only {
        use super::*;

        #[test]
        fn ascending_shards_and_identity_release_are_clean() {
            let a = acquire(SHARD, 0);
            let b = acquire(SHARD, 3);
            let c = acquire(TENSOR_REGISTRY, 0);
            // guard vectors drop front-to-back; identity-based release
            // must not care
            drop(a);
            drop(b);
            drop(c);
            let _again = acquire(SHARD, 0);
        }

        #[test]
        fn reversed_shard_acquisition_panics() {
            let err = catch_unwind(|| {
                let _hi = acquire(SHARD, 3);
                let _lo = acquire(SHARD, 1);
            })
            .expect_err("reversed shard order must panic");
            let msg = err.downcast_ref::<String>().expect("string panic payload");
            assert!(msg.contains("out-of-index-order"), "got: {msg}");
            assert!(msg.contains("shard[3]"), "held stack missing: {msg}");
        }

        #[test]
        fn same_shard_twice_panics() {
            let err = catch_unwind(|| {
                let _a = acquire(SHARD, 2);
                let _b = acquire(SHARD, 2);
            })
            .expect_err("re-acquiring the same shard must panic");
            let msg = err.downcast_ref::<String>().expect("string panic payload");
            assert!(msg.contains("shard[2]"), "got: {msg}");
        }

        #[test]
        fn non_shard_reentrancy_panics() {
            let err = catch_unwind(|| {
                let _a = acquire(Class(100), 0);
                let _b = acquire(Class(100), 0);
            })
            .expect_err("re-entrant class must panic");
            let msg = err.downcast_ref::<String>().expect("string panic payload");
            assert!(msg.contains("re-entrant"), "got: {msg}");
        }

        #[test]
        fn order_cycle_panics_with_both_stacks() {
            // establish A -> B, then attempt B -> A; classes unique to
            // this test so the global graph stays clean for others
            let (a, b) = (Class(110), Class(111));
            {
                let _a = acquire(a, 0);
                let _b = acquire(b, 0);
            }
            let err = catch_unwind(|| {
                let _b = acquire(b, 0);
                let _a = acquire(a, 0);
            })
            .expect_err("reverse order after a recorded edge must panic");
            let msg = err.downcast_ref::<String>().expect("string panic payload");
            assert!(msg.contains("cycle"), "got: {msg}");
            assert!(msg.contains("class-111"), "current stack missing: {msg}");
            assert!(msg.contains("class-110 -> class-111"), "recorded edge missing: {msg}");
        }

        #[test]
        fn transitive_cycle_is_caught() {
            // A -> B and B -> C on record; C -> A must panic even though
            // the direct reverse edge was never seen
            let (a, b, c) = (Class(120), Class(121), Class(122));
            {
                let _a = acquire(a, 0);
                let _b = acquire(b, 0);
            }
            {
                let _b = acquire(b, 0);
                let _c = acquire(c, 0);
            }
            let err = catch_unwind(|| {
                let _c = acquire(c, 0);
                let _a = acquire(a, 0);
            })
            .expect_err("transitive reverse order must panic");
            let msg = err.downcast_ref::<String>().expect("string panic payload");
            assert!(msg.contains("cycle"), "got: {msg}");
        }

        #[test]
        fn skipping_levels_is_clean() {
            // the documented DAG, acquired with gaps, in order
            let _g = acquire(COMMIT_GATE, 0);
            let _q = acquire(WAL_QUEUE, 0);
            let _s = acquire(SHARD, 1);
            let _r = acquire(TENSOR_REGISTRY, 0);
        }
    }
}
