//! Reproduction drivers for every table and figure in the paper's
//! evaluation (see DESIGN.md §Experiment index). Each function prints
//! the same rows/series the paper reports and returns the rendered
//! table so EXPERIMENTS.md can be assembled from a single run.
//!
//! Shared conventions:
//! - wall-clock via [`crate::util::bench`] (median of adaptive samples);
//! - recovery error = relative Frobenius error, median of `d` repeats
//!   (the paper uses 5 for Fig. 8, 300 for Fig. 9);
//! - CTS and MTS compared at **equal compression ratio** (the paper's
//!   protocol: `O(m²) = O(c)` keeps recovery error at the same level).

pub mod ablation;
pub mod fig10;
pub mod fig8;
pub mod fig9;
pub mod service;
pub mod tables;
pub mod variance;

pub use ablation::{
    run_ablation_batching, run_ablation_fft_packing, run_ablation_median_d,
    run_ablation_sketch_path,
};
pub use fig10::{run_fig10, run_fig12};
pub use fig8::run_fig8;
pub use fig9::run_fig9;
pub use service::{run_combine_bench, run_service_bench};
pub use tables::{run_table1, run_table3, run_table45, run_table6};
pub use variance::run_variance;

/// Quick-mode flag shared by the benches (CI uses quick).
#[derive(Clone, Copy, Debug)]
pub struct ExpConfig {
    pub quick: bool,
    pub seed: u64,
}

impl Default for ExpConfig {
    fn default() -> Self {
        Self { quick: false, seed: 20190711 }
    }
}

impl ExpConfig {
    pub fn bench_cfg(&self) -> crate::util::bench::BenchConfig {
        if self.quick {
            crate::util::bench::BenchConfig::quick()
        } else {
            crate::util::bench::BenchConfig::default()
        }
    }
}
