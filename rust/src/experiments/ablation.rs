//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. MTS sketch path: fused scatter vs literal Eq. 3 one-hot
//!    contractions (the structure the Pallas kernel uses — on CPU the
//!    scatter wins; on TPU the matmul formulation is the point).
//! 2. Kron combine: packed single complex FFT2 vs unpacked 3-FFT
//!    reference (the §Perf optimization).
//! 3. Coordinator batching: throughput vs `max_batch`.
//! 4. Median-of-d: recovery error vs d (the robust-estimator knob every
//!    theorem in the paper uses).

use super::ExpConfig;
use crate::coordinator::{BackendKind, Coordinator, CoordinatorConfig, Job};
use crate::fft::{circular_convolve2, circular_convolve2_real, circular_convolve2_unpacked};
use crate::rng::Pcg64;
use crate::sketch::estimate::median_decompress;
use crate::sketch::mts::MtsSketcher;
use crate::tensor::{rel_error, Tensor};
use crate::util::bench::{bench, fmt_duration, Table};
use anyhow::Result;
use std::sync::atomic::Ordering;
use std::sync::Arc;

pub fn run_ablation_sketch_path(cfg: &ExpConfig) -> Table {
    let bcfg = cfg.bench_cfg();
    let mut t = Table::new(
        "Ablation 1 — MTS sketch: fused scatter vs Eq. 3 contractions",
        &["input", "sketch", "scatter", "contraction", "ratio"],
    );
    for &(n, m) in &[(64usize, 16usize), (128, 32), (256, 64)] {
        let mut rng = Pcg64::new(cfg.seed);
        let x = Tensor::randn(&[n, n], &mut rng);
        let sk = MtsSketcher::new(&[n, n], &[m, m], 1);
        let scatter = bench("scatter", &bcfg, || sk.sketch(&x)).median;
        let contract = bench("contract", &bcfg, || sk.sketch_contract(&x)).median;
        t.row(vec![
            format!("{n}×{n}"),
            format!("{m}×{m}"),
            fmt_duration(scatter),
            fmt_duration(contract),
            format!("{:.1}x", contract.as_secs_f64() / scatter.as_secs_f64()),
        ]);
    }
    t
}

pub fn run_ablation_fft_packing(cfg: &ExpConfig) -> Table {
    let bcfg = cfg.bench_cfg();
    let mut t = Table::new(
        "Ablation 2 — Kron combine: real RFFT2 vs packed (2 FFT2) vs unpacked (3 FFT2)",
        &["m", "real", "packed", "unpacked", "real speedup"],
    );
    for &m in &[16usize, 40, 71, 128] {
        let mut rng = Pcg64::new(cfg.seed);
        let a = rng.normal_vec(m * m);
        let b = rng.normal_vec(m * m);
        let real = bench("real", &bcfg, || circular_convolve2_real(&a, &b, m, m)).median;
        let packed = bench("packed", &bcfg, || circular_convolve2(&a, &b, m, m)).median;
        let unpacked =
            bench("unpacked", &bcfg, || circular_convolve2_unpacked(&a, &b, m, m)).median;
        t.row(vec![
            m.to_string(),
            fmt_duration(real),
            fmt_duration(packed),
            fmt_duration(unpacked),
            format!("{:.2}x", packed.as_secs_f64() / real.as_secs_f64()),
        ]);
    }
    t
}

pub fn run_ablation_batching(cfg: &ExpConfig, artifacts_dir: &str) -> Result<Table> {
    let per_client = if cfg.quick { 200 } else { 500 };
    let mut t = Table::new(
        "Ablation 3 — coordinator throughput vs max_batch (xla backend)",
        &["max_batch", "req/s", "mean batch", "mean latency"],
    );
    for &max_batch in &[1usize, 8, 64] {
        let co = Arc::new(Coordinator::start(CoordinatorConfig {
            backend: BackendKind::Xla,
            artifacts_dir: artifacts_dir.to_string(),
            max_batch,
            ..Default::default()
        })?);
        let man = crate::runtime::Manifest::load(artifacts_dir)?;
        let n = man.ops["cs_sketch"].input_dims[0];
        let t0 = std::time::Instant::now();
        let mut handles = Vec::new();
        for c in 0..4u64 {
            let co = co.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Pcg64::new(c + 1);
                let mut inflight = std::collections::VecDeque::new();
                for _ in 0..per_client {
                    let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
                    loop {
                        match co.try_submit(Job::CsSketch(x.clone())) {
                            Ok(rx) => {
                                inflight.push_back(rx);
                                break;
                            }
                            Err(_) => std::thread::yield_now(),
                        }
                    }
                    if inflight.len() >= 32 {
                        inflight.pop_front().unwrap().recv().unwrap().unwrap();
                    }
                }
                for rx in inflight {
                    rx.recv().unwrap().unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let m = co.metrics();
        t.row(vec![
            max_batch.to_string(),
            format!("{:.0}", m.completed.load(Ordering::Relaxed) as f64 / wall),
            format!("{:.1}", m.mean_batch_size()),
            format!("{:.0}µs", m.mean_latency_us()),
        ]);
    }
    Ok(t)
}

pub fn run_ablation_median_d(cfg: &ExpConfig) -> Table {
    let mut rng = Pcg64::new(cfg.seed);
    let t_in = Tensor::randn(&[12, 12], &mut rng);
    let mut t = Table::new(
        "Ablation 4 — recovery error vs median-of-d (12×12 → 6×6)",
        &["d", "rel error"],
    );
    for &d in &[1usize, 3, 5, 9, 21] {
        let rec = median_decompress(d, |rep| {
            let sk = MtsSketcher::with_repeat(&[12, 12], &[6, 6], cfg.seed, rep);
            sk.decompress(&sk.sketch(&t_in))
        });
        t.row(vec![d.to_string(), format!("{:.4}", rel_error(&t_in, &rec))]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_d_ablation_monotone_tail() {
        let t = run_ablation_median_d(&ExpConfig { quick: true, seed: 3 });
        let s = t.render();
        // parse the d=1 and d=21 error rows
        let errs: Vec<f64> = s
            .lines()
            .skip(3)
            .filter_map(|l| l.split_whitespace().nth(1).and_then(|v| v.parse().ok()))
            .collect();
        assert_eq!(errs.len(), 5);
        assert!(errs[4] < errs[0], "d=21 must beat d=1: {errs:?}");
    }

    #[test]
    fn fft_packing_ablation_runs() {
        let cfg = ExpConfig { quick: true, seed: 1 };
        let t = run_ablation_fft_packing(&cfg);
        assert!(t.render().contains("packed"));
    }

    #[test]
    fn sketch_path_ablation_runs() {
        let cfg = ExpConfig { quick: true, seed: 1 };
        let t = run_ablation_sketch_path(&cfg);
        assert!(t.render().contains("scatter"));
    }
}
