//! Tables 1 / 3 / 4–5 / 6: computation and memory of sketched tensor
//! operations, CTS vs MTS, at equal recovery error (`c ≈ m²` coupling).
//!
//! Wall-clock is measured on this machine; *memory* is counted in
//! f64 scalars (sketch output + method-specific intermediates), which is
//! testbed-independent and matches the units of the paper's asymptotic
//! rows. The claim under test is the *shape*: who wins, by what factor,
//! and where the crossovers sit as (n, r) vary.

use super::ExpConfig;
use crate::decomp::{CpTensor, TtTensor, TuckerTensor};
use crate::rng::Pcg64;
use crate::sketch::cp::{CtsCp, MtsCp};
use crate::sketch::cs::{sketch_outer_product, CsSketcher};
use crate::sketch::kron::{CtsKron, MtsKron};
use crate::sketch::tt::{CtsTtCombined, MtsTt};
use crate::sketch::tucker::{CtsTucker, MtsTucker};
use crate::tensor::{kron, Tensor};
use crate::util::bench::{bench, fmt_duration, Table};

// ---------------------------------------------------------------------
// Table 3 (+ Figs 4–6): sketched Kronecker product computation
// ---------------------------------------------------------------------

pub struct KronCost {
    pub n: usize,
    pub cs_outer: std::time::Duration,
    pub cts: std::time::Duration,
    pub mts: std::time::Duration,
    pub dense: std::time::Duration,
    pub cts_mem: usize,
    pub mts_mem: usize,
    pub dense_mem: usize,
}

pub fn run_table3(cfg: &ExpConfig, ns: &[usize]) -> (Table, Vec<KronCost>) {
    let bcfg = cfg.bench_cfg();
    let mut t = Table::new(
        "Table 3 — Kronecker sketch computation (c = m², equal error)",
        &["n", "dense", "CS(u⊗v)", "CTS(A⊗B)", "MTS(A⊗B)", "cts/mts", "mem dense", "mem cts", "mem mts"],
    );
    let mut out = Vec::new();
    for &n in ns {
        let mut rng = Pcg64::new(cfg.seed + n as u64);
        let a = Tensor::randn(&[n, n], &mut rng);
        let b = Tensor::randn(&[n, n], &mut rng);
        let u = rng.normal_vec(n);
        let v = rng.normal_vec(n);
        // equal-error coupling: take m = n (ratio n²), c = m² = n²
        let m = n;
        let c = m * m;
        let su = CsSketcher::new(n, c, cfg.seed);
        let sv = CsSketcher::new(n, c, cfg.seed + 1);
        let ck = CtsKron::new(&[n, n], &[n, n], c, cfg.seed);
        let mk = MtsKron::new(&[n, n], &[n, n], m, m, cfg.seed);

        let dense = bench("dense", &bcfg, || kron(&a, &b)).median;
        let cs_outer = bench("cs", &bcfg, || sketch_outer_product(&su, &sv, &u, &v)).median;
        let cts = bench("cts", &bcfg, || ck.compress(&a, &b)).median;
        let mts = bench("mts", &bcfg, || mk.compress(&a, &b)).median;

        let cost = KronCost {
            n,
            cs_outer,
            cts,
            mts,
            dense,
            cts_mem: n * n * c,  // (n1·n3) × c sketch
            mts_mem: m * m,      // m1 × m2 sketch
            dense_mem: n * n * n * n,
        };
        t.row(vec![
            n.to_string(),
            fmt_duration(dense),
            fmt_duration(cs_outer),
            fmt_duration(cts),
            fmt_duration(mts),
            format!("{:.1}x", cts.as_secs_f64() / mts.as_secs_f64()),
            cost.dense_mem.to_string(),
            cost.cts_mem.to_string(),
            cost.mts_mem.to_string(),
        ]);
        out.push(cost);
    }
    (t, out)
}

// ---------------------------------------------------------------------
// Tables 4–5: Tucker / CP sketching
// ---------------------------------------------------------------------

pub struct DecompCost {
    pub form: &'static str,
    pub n: usize,
    pub r: usize,
    pub exact: std::time::Duration,
    pub cts: std::time::Duration,
    pub mts: std::time::Duration,
    pub cts_mem: usize,
    pub mts_mem: usize,
}

/// Equal-error coupling per §3.1: `c = O(r³)` and `m1·m2 = O(r³)` for
/// Tucker; `c = O(r²)`… we use c = m1·m2 directly so both methods carry
/// identical sketch information.
pub fn run_table45(cfg: &ExpConfig, configs: &[(usize, usize)]) -> (Table, Vec<DecompCost>) {
    let bcfg = cfg.bench_cfg();
    let mut t = Table::new(
        "Tables 4–5 — Tucker/CP-form sketching (c = m1·m2, equal error)",
        &["form", "n", "r", "exact", "CTS", "MTS", "cts/mts", "mem cts", "mem mts"],
    );
    let mut out = Vec::new();
    for &(n, r) in configs {
        let mut rng = Pcg64::new(cfg.seed + (n * 131 + r) as u64);

        // ---- Tucker ----
        {
            let tk = TuckerTensor::random(&[n, n, n], &[r, r, r], &mut rng);
            // sketch sizes: m1·m2 = c; pick m2 ≈ r (core axis), m1 = c/m2
            let c = (r * r * r * 4).max(16);
            let m2 = r.max(2);
            let m1 = (c / m2).max(2);
            let cts = CtsTucker::new(&[n, n, n], c, cfg.seed);
            let mts = MtsTucker::new(&[n, n, n], &[r, r, r], m1, m2, cfg.seed);
            let exact = bench("exact", &bcfg, || tk.reconstruct()).median;
            let tc = bench("cts", &bcfg, || cts.sketch(&tk)).median;
            let tm = bench("mts", &bcfg, || mts.sketch(&tk)).median;
            let cost = DecompCost {
                form: "Tucker",
                n,
                r,
                exact,
                cts: tc,
                mts: tm,
                // CTS intermediates: c·r per-mode CS tables + c output
                cts_mem: c * r * 3 + c,
                // MTS intermediates: m1·m2 kron sketch + m2 core CS + m1 out
                mts_mem: m1 * m2 + m2 + m1,
            };
            t.row(vec![
                "Tucker".into(),
                n.to_string(),
                r.to_string(),
                fmt_duration(exact),
                fmt_duration(tc),
                fmt_duration(tm),
                format!("{:.1}x", tc.as_secs_f64() / tm.as_secs_f64()),
                cost.cts_mem.to_string(),
                cost.mts_mem.to_string(),
            ]);
            out.push(cost);
        }

        // ---- CP (same n, r; includes overcomplete r > n configs) ----
        {
            let cp = CpTensor::random(&[n, n, n], r, &mut rng);
            let c = (r * r * 4).max(16);
            let m2 = r.max(2);
            let m1 = (c / m2).max(2);
            let cts = CtsCp::new(&[n, n, n], c, cfg.seed);
            let mts = MtsCp::new(&[n, n, n], r, m1, m2, cfg.seed);
            let exact = bench("exact", &bcfg, || cp.reconstruct()).median;
            let tc = bench("cts", &bcfg, || cts.sketch(&cp)).median;
            let tm = bench("mts", &bcfg, || mts.sketch(&cp)).median;
            let cost = DecompCost {
                form: "CP",
                n,
                r,
                exact,
                cts: tc,
                mts: tm,
                cts_mem: c * r * 3 + c,
                mts_mem: m1 * m2 + m2 + m1,
            };
            t.row(vec![
                "CP".into(),
                n.to_string(),
                r.to_string(),
                fmt_duration(exact),
                fmt_duration(tc),
                fmt_duration(tm),
                format!("{:.1}x", tc.as_secs_f64() / tm.as_secs_f64()),
                cost.cts_mem.to_string(),
                cost.mts_mem.to_string(),
            ]);
            out.push(cost);
        }
    }
    (t, out)
}

// ---------------------------------------------------------------------
// Table 6: tensor-train sketching
// ---------------------------------------------------------------------

pub fn run_table6(cfg: &ExpConfig, configs: &[(usize, usize)]) -> (Table, Vec<DecompCost>) {
    let bcfg = cfg.bench_cfg();
    let mut t = Table::new(
        "Table 6 — TT-form sketching (c coupled to m1·m2)",
        &["form", "n", "r", "exact", "CTS", "MTS", "cts/mts", "mem cts", "mem mts"],
    );
    let mut out = Vec::new();
    for &(n, r) in configs {
        let mut rng = Pcg64::new(cfg.seed + (n * 17 + r) as u64);
        let tt = TtTensor::random(&[n, n, n], &[r, r], &mut rng);
        // equal-information coupling: combined CTS sketch of length c vs
        // MTS final sketch m1·m3 ≈ c, with a narrow inner axis m2 = O(r)
        let c = (r * r * 4).max(8);
        let (m1, m2, m3) = ((r * r).max(4), (2 * r).max(4), 4);
        let cts = CtsTtCombined::new(&[n, n, n], &[r, r], c, cfg.seed);
        let mts = MtsTt::new(&[n, n, n], &[r, r], m1, m2, m3, cfg.seed);
        let exact = bench("exact", &bcfg, || tt.reconstruct()).median;
        let tc = bench("cts", &bcfg, || cts.sketch(&tt)).median;
        let tm = bench("mts", &bcfg, || mts.sketch(&tt)).median;
        let cost = DecompCost {
            form: "TT",
            n,
            r,
            exact,
            cts: tc,
            mts: tm,
            // CTS working set: cached G1/G3 column spectra (complex) +
            // the length-c accumulator/output
            cts_mem: 4 * r * c + 2 * c,
            // MTS working set: m1×m2 Kron sketch + m2×m3 core sketch +
            // the m1×m3 output
            mts_mem: m1 * m2 + m2 * m3 + mts.sketch_len(),
        };
        t.row(vec![
            "TT".into(),
            n.to_string(),
            r.to_string(),
            fmt_duration(exact),
            fmt_duration(tc),
            fmt_duration(tm),
            format!("{:.1}x", tc.as_secs_f64() / tm.as_secs_f64()),
            cost.cts_mem.to_string(),
            cost.mts_mem.to_string(),
        ]);
        out.push(cost);
    }
    (t, out)
}

// ---------------------------------------------------------------------
// Table 1: improvement ratios (derived from measured 3/4/5/6)
// ---------------------------------------------------------------------

pub fn run_table1(cfg: &ExpConfig) -> Table {
    let mut t = Table::new(
        "Table 1 — measured MTS-over-CTS improvement ratios",
        &["operator", "computation (cts/mts)", "memory (cts/mts)", "paper says"],
    );
    // Kronecker at n = 24 (paper: computation O(n), memory O(n²))
    let (_, kron_rows) = run_table3(cfg, &[24]);
    let k = &kron_rows[0];
    t.row(vec![
        "Kronecker (n=24)".into(),
        format!("{:.1}x", k.cts.as_secs_f64() / k.mts.as_secs_f64()),
        format!("{:.0}x", k.cts_mem as f64 / k.mts_mem as f64),
        "O(n), O(n²)".into(),
    ]);
    // Tucker/CP at (n, r) = (16, 6): paper O(r²)/O(r³), memory O(r)
    let (_, dec_rows) = run_table45(cfg, &[(16, 6)]);
    for row in &dec_rows {
        t.row(vec![
            format!("{} (n=16, r=6)", row.form),
            format!("{:.1}x", row.cts.as_secs_f64() / row.mts.as_secs_f64()),
            format!("{:.1}x", row.cts_mem as f64 / row.mts_mem as f64),
            if row.form == "Tucker" { "O(r²)/O(r³), O(r)" } else { "O(r) if r>n, O(r)" }
                .into(),
        ]);
    }
    // TT at (n, r) = (16, 4)
    let (_, tt_rows) = run_table6(cfg, &[(16, 4)]);
    let r = &tt_rows[0];
    t.row(vec![
        "Tensor-train (n=16, r=4)".into(),
        format!("{:.1}x", r.cts.as_secs_f64() / r.mts.as_secs_f64()),
        format!("{:.1}x", r.cts_mem as f64 / r.mts_mem as f64),
        "O(r²) if log r>n, O(n)".into(),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> ExpConfig {
        ExpConfig { quick: true, seed: 2 }
    }

    #[test]
    fn table3_mts_dominates_cts_and_dense() {
        let (_t, rows) = run_table3(&quick(), &[12, 20]);
        for r in &rows {
            assert!(r.mts < r.cts, "n={}: mts should beat cts", r.n);
            assert!(r.mts_mem < r.cts_mem);
            assert!(r.mts_mem < r.dense_mem);
        }
        // the gap should widen with n (paper: O(n) computation ratio)
        let g0 = rows[0].cts.as_secs_f64() / rows[0].mts.as_secs_f64();
        let g1 = rows[1].cts.as_secs_f64() / rows[1].mts.as_secs_f64();
        assert!(g1 > g0 * 0.8, "ratio should not collapse: {g0} -> {g1}");
    }

    #[test]
    fn table45_runs_both_regimes() {
        // undercomplete r<n and overcomplete r>n
        let (_t, rows) = run_table45(&quick(), &[(10, 3), (6, 8)]);
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(r.mts_mem < r.cts_mem, "{} (n={}, r={})", r.form, r.n, r.r);
        }
    }

    #[test]
    fn table6_runs() {
        let (_t, rows) = run_table6(&quick(), &[(10, 3)]);
        assert_eq!(rows.len(), 1);
        assert!(rows[0].cts > std::time::Duration::ZERO);
    }

    #[test]
    fn table1_renders_all_rows() {
        let t = run_table1(&quick());
        let s = t.render();
        assert!(s.contains("Kronecker"));
        assert!(s.contains("Tucker"));
        assert!(s.contains("CP"));
        assert!(s.contains("Tensor-train"));
    }
}
