//! Figures 10–12: the tensor-regression-network experiment, end to end
//! through the Rust trainer over the AOT artifacts.
//!
//! - Fig 10: training loss + test accuracy curves for the network with
//!   FC head, exact TRL head, CTS-sketched and MTS-sketched TRL heads.
//! - Fig 12: test accuracy of the MTS-tensorized network vs compression
//!   ratio (ratio 1 = the exact tensorized network).
//!
//! Histories are also dumped as JSON (one file per model) under
//! `results/` so the curves can be replotted.

use super::ExpConfig;
use crate::runtime::Runtime;
use crate::train::{TrainHistory, Trainer};
use crate::util::bench::Table;
use anyhow::Result;

pub struct TrainSettings {
    pub steps: usize,
    pub lr: f32,
    pub eval_every: usize,
}

impl TrainSettings {
    pub fn for_cfg(cfg: &ExpConfig) -> Self {
        if cfg.quick {
            Self { steps: 40, lr: 0.02, eval_every: 20 }
        } else {
            Self { steps: 400, lr: 0.02, eval_every: 50 }
        }
    }
}

/// Per-head learning-rate adjustment: the exact TRL's multiplicative
/// parametrization (logits go through a product of four factors) has
/// much sharper curvature than the linear sketched heads — at the
/// shared lr it oscillates around chance. Empirically lr/4 converges
/// cleanly (see EXPERIMENTS.md §Fig10 notes).
pub fn lr_for(model: &str, base: f32) -> f32 {
    if model == "trl" {
        base * 0.25
    } else {
        base
    }
}

pub fn train_model(
    rt: &Runtime,
    model: &str,
    s: &TrainSettings,
    seed: u64,
    quiet: bool,
) -> Result<TrainHistory> {
    let mut tr = Trainer::new(rt, model)?;
    tr.train(s.steps, lr_for(model, s.lr), s.eval_every, seed, quiet)
}

fn dump_history(hist: &TrainHistory) {
    let _ = std::fs::create_dir_all("results");
    let path = format!("results/train_{}.json", hist.model);
    let _ = std::fs::write(&path, hist.to_json().to_string_pretty());
}

/// Fig 10: compare head variants at (roughly) matched training budget.
pub fn run_fig10(cfg: &ExpConfig, rt: &Runtime) -> Result<(Table, Vec<TrainHistory>)> {
    let s = TrainSettings::for_cfg(cfg);
    let models = ["fc", "trl", "trl_cts_8", "trl_mts_4x4x8"];
    let mut t = Table::new(
        &format!("Figure 10 — training on synthetic corpus ({} steps)", s.steps),
        &["model", "head params", "final train loss", "final test acc", "wall (s)"],
    );
    let mut hists = Vec::new();
    for model in models {
        let hist = train_model(rt, model, &s, cfg.seed, cfg.quick)?;
        dump_history(&hist);
        t.row(vec![
            model.into(),
            hist.head_param_count.to_string(),
            format!("{:.4}", hist.train_loss.last().copied().unwrap_or(f64::NAN)),
            format!("{:.3}", hist.final_test_acc()),
            format!("{:.1}", hist.wall_secs),
        ]);
        hists.push(hist);
    }
    Ok((t, hists))
}

/// Fig 12: MTS-head accuracy vs compression ratio (w.r.t. exact trl).
pub fn run_fig12(cfg: &ExpConfig, rt: &Runtime) -> Result<(Table, Vec<(f64, f64)>)> {
    let s = TrainSettings::for_cfg(cfg);
    // baseline: exact tensorized network
    let base = train_model(rt, "trl", &s, cfg.seed, cfg.quick)?;
    dump_history(&base);
    let base_params = base.head_param_count as f64;
    let sweep = ["trl_mts_8x8x16", "trl_mts_4x4x8", "trl_mts_3x3x6", "trl_mts_2x2x4"];
    let mut t = Table::new(
        &format!("Figure 12 — test accuracy vs compression ratio ({} steps)", s.steps),
        &["model", "head params", "compression ratio", "test acc", "acc drop vs trl"],
    );
    t.row(vec![
        "trl (ratio 1)".into(),
        base.head_param_count.to_string(),
        "1.0".into(),
        format!("{:.3}", base.final_test_acc()),
        "0.000".into(),
    ]);
    let mut pts = vec![(1.0, base.final_test_acc())];
    for model in sweep {
        let hist = train_model(rt, model, &s, cfg.seed, cfg.quick)?;
        dump_history(&hist);
        let ratio = base_params / hist.head_param_count as f64;
        t.row(vec![
            model.into(),
            hist.head_param_count.to_string(),
            format!("{ratio:.1}"),
            format!("{:.3}", hist.final_test_acc()),
            format!("{:.3}", base.final_test_acc() - hist.final_test_acc()),
        ]);
        pts.push((ratio, hist.final_test_acc()));
    }
    Ok((t, pts))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig10_quick_all_heads_learn() {
        if !crate::runtime::artifacts_available(crate::runtime::DEFAULT_ARTIFACTS_DIR) {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::new(crate::runtime::DEFAULT_ARTIFACTS_DIR).unwrap();
        let cfg = ExpConfig { quick: true, seed: 1 };
        let s = TrainSettings { steps: 16, lr: 0.02, eval_every: 8 };
        // one head is enough for CI; full sweep runs in `hocs bench fig10`
        let hist = train_model(&rt, "trl_mts_4x4x8", &s, cfg.seed, true).unwrap();
        assert!(hist.train_loss.last().unwrap() < hist.train_loss.first().unwrap());
    }
}
