//! Coordinator throughput/latency benchmark — the §Perf L3 measurement:
//! flood the service with sketch requests from several client threads
//! and report throughput, mean/max latency and mean batch size, for
//! both backends.

use super::ExpConfig;
use crate::coordinator::{BackendKind, Coordinator, CoordinatorConfig, Job};
use crate::rng::Pcg64;
use crate::util::bench::Table;
use anyhow::Result;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

pub struct ServiceStats {
    pub backend: &'static str,
    pub requests: u64,
    pub wall_secs: f64,
    pub throughput: f64,
    pub mean_latency_us: f64,
    pub mean_batch: f64,
}

pub fn run_service_bench(cfg: &ExpConfig, artifacts_dir: &str) -> Result<(Table, Vec<ServiceStats>)> {
    let n_clients = 4usize;
    let per_client = if cfg.quick { 200 } else { 1000 };
    let mut t = Table::new(
        &format!("Coordinator service bench — {n_clients} clients × {per_client} cs_sketch requests"),
        &["backend", "requests", "wall (s)", "req/s", "mean latency", "mean batch"],
    );
    let mut out = Vec::new();
    for kind in [BackendKind::PureRust, BackendKind::Xla] {
        let co = Arc::new(Coordinator::start(CoordinatorConfig {
            backend: kind,
            artifacts_dir: artifacts_dir.to_string(),
            ..Default::default()
        })?);
        let man = crate::runtime::Manifest::load(artifacts_dir)?;
        let n = man.ops["cs_sketch"].input_dims[0];
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for c in 0..n_clients {
            let co = co.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Pcg64::new(c as u64 + 1);
                // pipelined client: keep a window of requests in flight
                // so the batcher actually gets to coalesce
                const WINDOW: usize = 32;
                let mut inflight = std::collections::VecDeque::new();
                for _ in 0..per_client {
                    let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
                    loop {
                        match co.try_submit(Job::CsSketch(x.clone())) {
                            Ok(rx) => {
                                inflight.push_back(rx);
                                break;
                            }
                            Err(_) => std::thread::yield_now(), // backpressure
                        }
                    }
                    if inflight.len() >= WINDOW {
                        inflight.pop_front().unwrap().recv().unwrap().unwrap();
                    }
                }
                for rx in inflight {
                    rx.recv().unwrap().unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let m = co.metrics();
        let requests = m.completed.load(Ordering::Relaxed);
        let stats = ServiceStats {
            backend: match kind {
                BackendKind::PureRust => "pure-rust",
                BackendKind::Xla => "xla-pjrt",
            },
            requests,
            wall_secs: wall,
            throughput: requests as f64 / wall,
            mean_latency_us: m.mean_latency_us(),
            mean_batch: m.mean_batch_size(),
        };
        t.row(vec![
            stats.backend.into(),
            stats.requests.to_string(),
            format!("{wall:.2}"),
            format!("{:.0}", stats.throughput),
            format!("{:.0}µs", stats.mean_latency_us),
            format!("{:.1}", stats.mean_batch),
        ]);
        out.push(stats);
    }
    Ok((t, out))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn service_bench_quick() {
        if !crate::runtime::artifacts_available(crate::runtime::DEFAULT_ARTIFACTS_DIR) {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let cfg = ExpConfig { quick: true, seed: 1 };
        let (_t, stats) = run_service_bench(&cfg, "artifacts").unwrap();
        assert_eq!(stats.len(), 2);
        for s in &stats {
            assert_eq!(s.requests, 800);
            assert!(s.throughput > 10.0, "{} too slow: {}", s.backend, s.throughput);
        }
    }
}
