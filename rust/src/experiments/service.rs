//! Coordinator throughput/latency benchmark — the §Perf L3 measurement:
//! flood the service with sketch requests from several client threads
//! and report throughput, mean/p50/p99 latency and mean batch size
//! across a sweep of worker counts × batch limits, so the scaling of
//! the worker pool is *measured*, not asserted.
//!
//! Also hosts the L1 combine microbench (complex packed FFT2 vs the
//! real-input RFFT2 path) — the two sets of numbers land together in
//! `BENCH_service.json` (written by `benches/bench_service.rs`).

use super::ExpConfig;
use crate::coordinator::{BackendKind, Coordinator, CoordinatorConfig, Job};
use crate::fft::{circular_convolve2, circular_convolve2_real};
use crate::rng::Pcg64;
use crate::util::bench::{bench, fmt_duration, Table};
use anyhow::Result;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

pub struct ServiceStats {
    pub backend: &'static str,
    pub workers: usize,
    pub max_batch: usize,
    pub requests: u64,
    pub wall_secs: f64,
    pub throughput: f64,
    pub mean_latency_us: f64,
    pub p50_latency_us: u64,
    pub p99_latency_us: u64,
    pub mean_batch: f64,
}

/// One row of the combine microbench: `MtsKron::combine`'s kernel at
/// sketch size m×m through both FFT paths.
pub struct CombineStats {
    pub m: usize,
    pub complex_us: f64,
    pub real_us: f64,
    pub speedup: f64,
}

/// Complex packed FFT2 vs real-input RFFT2 path for the Kronecker
/// combine kernel, swept over the acceptance sizes m = 64..512.
pub fn run_combine_bench(cfg: &ExpConfig) -> (Table, Vec<CombineStats>) {
    let bcfg = cfg.bench_cfg();
    let ms: &[usize] = if cfg.quick { &[64, 128] } else { &[64, 128, 256, 512] };
    let mut t = Table::new(
        "Kron combine kernel — complex packed FFT2 vs real-input RFFT2",
        &["m", "complex", "real", "speedup"],
    );
    let mut out = Vec::new();
    for &m in ms {
        let mut rng = Pcg64::new(cfg.seed);
        let a = rng.normal_vec(m * m);
        let b = rng.normal_vec(m * m);
        let cx = bench("complex", &bcfg, || circular_convolve2(&a, &b, m, m)).median;
        let re = bench("real", &bcfg, || circular_convolve2_real(&a, &b, m, m)).median;
        let speedup = cx.as_secs_f64() / re.as_secs_f64();
        t.row(vec![
            m.to_string(),
            fmt_duration(cx),
            fmt_duration(re),
            format!("{speedup:.2}x"),
        ]);
        out.push(CombineStats {
            m,
            complex_us: cx.as_secs_f64() * 1e6,
            real_us: re.as_secs_f64() * 1e6,
            speedup,
        });
    }
    (t, out)
}

fn run_one_config(
    kind: BackendKind,
    backend_name: &'static str,
    workers: usize,
    max_batch: usize,
    per_client: usize,
    artifacts_dir: &str,
) -> Result<ServiceStats> {
    let n_clients = 4usize;
    let co = Arc::new(Coordinator::start(CoordinatorConfig {
        backend: kind,
        artifacts_dir: artifacts_dir.to_string(),
        workers: Some(workers),
        max_batch,
        ..Default::default()
    })?);
    let man = crate::runtime::Manifest::load(artifacts_dir)?;
    let n = man.ops["cs_sketch"].input_dims[0];
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let co = co.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Pcg64::new(c as u64 + 1);
            // pipelined client: keep a window of requests in flight
            // so the batcher actually gets to coalesce
            const WINDOW: usize = 32;
            let mut inflight = std::collections::VecDeque::new();
            for _ in 0..per_client {
                let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
                loop {
                    match co.try_submit(Job::CsSketch(x.clone())) {
                        Ok(rx) => {
                            inflight.push_back(rx);
                            break;
                        }
                        Err(_) => std::thread::yield_now(), // backpressure
                    }
                }
                if inflight.len() >= WINDOW {
                    inflight.pop_front().unwrap().recv().unwrap().unwrap();
                }
            }
            for rx in inflight {
                rx.recv().unwrap().unwrap();
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = co.metrics();
    let requests = m.completed.load(Ordering::Relaxed);
    Ok(ServiceStats {
        backend: backend_name,
        workers,
        max_batch,
        requests,
        wall_secs: wall,
        throughput: requests as f64 / wall,
        mean_latency_us: m.mean_latency_us(),
        p50_latency_us: m.latency_percentile_us(0.5),
        p99_latency_us: m.latency_percentile_us(0.99),
        mean_batch: m.mean_batch_size(),
    })
}

/// Sweep worker counts × batch limits on the pure-Rust backend (plus
/// one XLA row when that backend is available) and report the scaling.
pub fn run_service_bench(
    cfg: &ExpConfig,
    artifacts_dir: &str,
) -> Result<(Table, Vec<ServiceStats>)> {
    let per_client = if cfg.quick { 200 } else { 1000 };
    let mut t = Table::new(
        &format!("Coordinator service bench — 4 clients × {per_client} cs_sketch requests"),
        &[
            "backend", "workers", "max_batch", "req/s", "mean lat", "p50", "p99", "mean batch",
        ],
    );
    let mut out = Vec::new();
    let worker_sweep: &[usize] = if cfg.quick { &[1, 4] } else { &[1, 2, 4] };
    let batch_sweep: &[usize] = if cfg.quick { &[64] } else { &[1, 16, 64] };
    for &workers in worker_sweep {
        for &max_batch in batch_sweep {
            let s = run_one_config(
                BackendKind::PureRust,
                "pure-rust",
                workers,
                max_batch,
                per_client,
                artifacts_dir,
            )?;
            push_row(&mut t, &s);
            out.push(s);
        }
    }
    // the XLA backend needs the real PJRT bindings; skip gracefully when
    // running against the stubbed build
    match run_one_config(BackendKind::Xla, "xla-pjrt", 1, 64, per_client, artifacts_dir) {
        Ok(s) => {
            push_row(&mut t, &s);
            out.push(s);
        }
        Err(e) => eprintln!("service bench: xla backend skipped ({e})"),
    }
    Ok((t, out))
}

fn push_row(t: &mut Table, s: &ServiceStats) {
    t.row(vec![
        s.backend.into(),
        s.workers.to_string(),
        s.max_batch.to_string(),
        format!("{:.0}", s.throughput),
        format!("{:.0}µs", s.mean_latency_us),
        format!("{}µs", s.p50_latency_us),
        format!("{}µs", s.p99_latency_us),
        format!("{:.1}", s.mean_batch),
    ]);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn combine_bench_runs_and_reports_speedup() {
        let cfg = ExpConfig { quick: true, seed: 1 };
        let (t, stats) = run_combine_bench(&cfg);
        assert_eq!(stats.len(), 2);
        assert!(t.render().contains("complex"));
        for s in &stats {
            assert!(s.complex_us > 0.0 && s.real_us > 0.0);
            // NOTE: the ≥1.5× claim is asserted on release-mode numbers
            // (cargo bench → BENCH_service.json), not in debug tests.
            assert!(s.speedup.is_finite());
        }
    }

    #[test]
    fn service_bench_quick() {
        if !crate::runtime::artifacts_available(crate::runtime::DEFAULT_ARTIFACTS_DIR) {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let cfg = ExpConfig { quick: true, seed: 1 };
        let (_t, stats) = run_service_bench(&cfg, "artifacts").unwrap();
        // quick sweep: workers {1, 4} × batch {64} on pure-rust (the
        // xla row appears only with the real PJRT bindings)
        assert!(stats.len() >= 2);
        for s in &stats {
            assert_eq!(s.requests, 800);
            assert!(s.throughput > 10.0, "{} too slow: {}", s.backend, s.throughput);
            assert!(s.p50_latency_us <= s.p99_latency_us);
        }
    }
}
