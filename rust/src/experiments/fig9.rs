//! Figure 9: covariance matrix estimation. `A ∈ ℝ^{10×10}`, entries
//! uniform on [-1,1] except rows 2 and 9 (1-based) positively
//! correlated. Baseline: Pagh compressed matrix multiplication at
//! compression ratio 2.5 (c = 40). MTS route: sketch `A ⊗ Aᵀ` at
//! compression ratio 6.25 (m1·m2 = 1600) and read the covariance out of
//! the Kronecker sketch. 300 repeats, median.
//!
//! Paper's reading: MTS estimate is *better* despite the *higher*
//! compression ratio.

use super::ExpConfig;
use crate::rng::Pcg64;
use crate::sketch::covariance::{
    covariance_median_mts, covariance_median_pagh, figure9_matrix,
};
use crate::tensor::rel_error;
use crate::util::bench::Table;

pub struct Fig9Result {
    pub pagh_ratio: f64,
    pub mts_ratio: f64,
    pub pagh_err: f64,
    pub mts_err: f64,
}

pub fn run_fig9(cfg: &ExpConfig) -> (Table, Fig9Result) {
    let mut rng = Pcg64::new(cfg.seed);
    let a = figure9_matrix(&mut rng);
    let truth = a.matmul(&a.transpose());
    let d = if cfg.quick { 31 } else { 301 }; // paper: 300 repeats
    let c = 40; // ratio 100²/…  → n²/c = 2.5
    let (m1, m2) = (40, 40); // (nr)²/(m1·m2) = 10000/1600 = 6.25

    let pagh = covariance_median_pagh(&a, c, d, cfg.seed);
    let mts = covariance_median_mts(&a, m1, m2, d, cfg.seed);
    let r = Fig9Result {
        pagh_ratio: 100.0 / c as f64,
        mts_ratio: 10_000.0 / (m1 * m2) as f64,
        pagh_err: rel_error(&truth, &pagh),
        mts_err: rel_error(&truth, &mts),
    };

    let mut t = Table::new(
        &format!("Figure 9 — covariance estimation (median of {d})"),
        &["method", "compression_ratio", "rel_error"],
    );
    t.row(vec![
        "Pagh CS (AAᵀ)".into(),
        format!("{:.2}", r.pagh_ratio),
        format!("{:.4}", r.pagh_err),
    ]);
    t.row(vec![
        "MTS (A⊗Aᵀ)".into(),
        format!("{:.2}", r.mts_ratio),
        format!("{:.4}", r.mts_err),
    ]);
    (t, r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9_both_methods_recover_covariance() {
        // Reproduction note (recorded in EXPERIMENTS.md): under the
        // matched median-of-d protocol both estimators land in the same
        // error regime; the paper's claim that MTS is *strictly* better
        // at the higher compression ratio did not reproduce point-for-
        // point, but the structural claim (correlated rows visible in
        // the reconstruction) does — see the structure test below.
        let cfg = ExpConfig { quick: true, seed: 3 };
        let (_t, r) = run_fig9(&cfg);
        assert!(r.mts_ratio > r.pagh_ratio, "MTS runs at the higher ratio");
        assert!(r.pagh_err < 1.0, "pagh err {}", r.pagh_err);
        assert!(r.mts_err < 1.0, "mts err {}", r.mts_err);
        assert!(
            r.mts_err < 3.0 * r.pagh_err,
            "errors should be the same order: {} vs {}",
            r.mts_err,
            r.pagh_err
        );
    }

    #[test]
    fn fig9_mts_preserves_correlated_row_structure() {
        // Fig 9's visual claim: the strong (row2, row9) covariance block
        // survives sketching. Check that cov[1,8] is the largest
        // off-diagonal entry of the MTS reconstruction.
        use crate::rng::Pcg64;
        let mut rng = Pcg64::new(11);
        let a = figure9_matrix(&mut rng);
        let rec = covariance_median_mts(&a, 40, 40, 101, 11);
        let target = rec.at2(1, 8).abs();
        let mut larger = 0;
        for i in 0..10 {
            for j in 0..10 {
                if i != j && !(i == 1 && j == 8) && !(i == 8 && j == 1)
                    && rec.at2(i, j).abs() > target
                {
                    larger += 1;
                }
            }
        }
        // 90 off-diagonal entries; the correlated pair should rank near
        // the top (sketching noise allows a few swaps)
        assert!(larger <= 8, "cov(2,9) should stand out; {larger} entries larger");
    }
}
