//! Theorem 2.1 empirical check: MTS point estimates are unbiased, and
//! their variance tracks the collision structure. Also documents the
//! paper-bound discrepancy (the stated ‖T‖²_F/(m1·m2) bound drops the
//! same-row/column collision terms — see EXPERIMENTS.md).

use super::ExpConfig;
use crate::rng::Pcg64;
use crate::sketch::mts::MtsSketcher;
use crate::tensor::Tensor;
use crate::util::bench::Table;
use crate::util::stats::{mean, variance};

pub struct VarianceRow {
    pub m: usize,
    pub bias: f64,
    pub emp_var: f64,
    pub corrected_bound: f64,
    pub paper_bound: f64,
}

pub fn run_variance(cfg: &ExpConfig) -> (Table, Vec<VarianceRow>) {
    let n = 8usize;
    let dims = [n, n];
    let target = [1usize, 6];
    let mut rng = Pcg64::new(cfg.seed);
    let t = Tensor::randn(&dims, &mut rng);
    let truth = t.get(&target);
    let reps = if cfg.quick { 2000 } else { 8000 };

    let mut table = Table::new(
        &format!("Theorem 2.1 — empirical estimator stats ({reps} sketches, 8×8 input)"),
        &["m×m", "bias", "emp var", "corrected bound", "paper bound", "var ≤ corrected?"],
    );
    let mut rows = Vec::new();
    for &m in &[2usize, 4, 6] {
        let est: Vec<f64> = (0..reps)
            .map(|rep| {
                let sk = MtsSketcher::new(&dims, &[m, m], cfg.seed + 1000 + rep as u64);
                sk.estimate(&sk.sketch(&t), &target)
            })
            .collect();
        let bias = mean(&est) - truth;
        let emp_var = variance(&est);
        let mf = m as f64;
        let mut corrected = 0.0;
        for i in 0..n {
            for j in 0..n {
                let v = t.get(&[i, j]).powi(2);
                corrected += match (i == target[0], j == target[1]) {
                    (true, true) => 0.0,
                    (true, false) => v / mf,
                    (false, true) => v / mf,
                    (false, false) => v / (mf * mf),
                };
            }
        }
        let paper = t.fro_norm().powi(2) / (mf * mf);
        table.row(vec![
            format!("{m}×{m}"),
            format!("{bias:+.4}"),
            format!("{emp_var:.4}"),
            format!("{corrected:.4}"),
            format!("{paper:.4}"),
            (emp_var <= corrected * 1.25).to_string(),
        ]);
        rows.push(VarianceRow { m, bias, emp_var, corrected_bound: corrected, paper_bound: paper });
    }
    (table, rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variance_rows_satisfy_corrected_bound() {
        let (_t, rows) = run_variance(&ExpConfig { quick: true, seed: 4 });
        for r in &rows {
            assert!(r.bias.abs() < 0.25, "m={}: bias {}", r.m, r.bias);
            assert!(
                r.emp_var <= r.corrected_bound * 1.3,
                "m={}: {} vs {}",
                r.m,
                r.emp_var,
                r.corrected_bound
            );
        }
    }

    #[test]
    fn variance_decreases_with_m() {
        let (_t, rows) = run_variance(&ExpConfig { quick: true, seed: 6 });
        assert!(rows[0].emp_var > rows[2].emp_var);
    }
}
