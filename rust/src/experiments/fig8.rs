//! Figure 8: Kronecker-product estimation for two 10×10 matrices —
//! recovery relative error and compression time vs compression ratio,
//! CTS vs MTS, median of 5 independent sketches.
//!
//! The paper's reading: at equal compression ratio MTS has lower error
//! AND lower compression time (≈10× claimed in the intro).

use super::ExpConfig;
use crate::rng::Pcg64;
use crate::sketch::estimate::median_decompress;
use crate::sketch::kron::{CtsKron, MtsKron};
use crate::tensor::{kron, rel_error, Tensor};
use crate::util::bench::{bench, fmt_duration, Table};
use crate::util::stats::median;

pub struct Fig8Row {
    pub ratio: f64,
    pub cts_err: f64,
    pub mts_err: f64,
    pub cts_time: std::time::Duration,
    pub mts_time: std::time::Duration,
}

pub fn run_fig8(cfg: &ExpConfig, n: usize) -> (Table, Vec<Fig8Row>) {
    let mut rng = Pcg64::new(cfg.seed);
    let a = Tensor::randn(&[n, n], &mut rng);
    let b = Tensor::randn(&[n, n], &mut rng);
    let truth = kron(&a, &b);
    let d = 5; // paper: 5 independent runs, median
    let bcfg = cfg.bench_cfg();

    let ratios: &[f64] = if cfg.quick {
        &[2.0, 10.0, 50.0]
    } else {
        &[2.0, 2.5, 5.0, 10.0, 20.0, 50.0]
    };

    let mut table = Table::new(
        &format!("Figure 8 — Kron estimation, {n}×{n} inputs (median of {d})"),
        &[
            "ratio", "cts_err", "mts_err", "cts_time", "mts_time", "time_speedup",
        ],
    );
    let mut rows = Vec::new();
    for &ratio in ratios {
        // CTS: ratio = n²/c ⇒ c = n²/ratio
        let c = ((n * n) as f64 / ratio).round().max(1.0) as usize;
        // MTS: ratio = n⁴/m² ⇒ m = n²/√ratio
        let m = ((n * n) as f64 / ratio.sqrt()).round().max(1.0) as usize;

        let cts_errs: Vec<f64> = (0..d)
            .map(|rep| {
                let ck = CtsKron::with_repeat(&[n, n], &[n, n], c, cfg.seed, rep);
                rel_error(&truth, &ck.decompress(&ck.compress(&a, &b)))
            })
            .collect();
        // median-of-d entrywise (robust estimator, same d)
        let mts_rec = median_decompress(d, |rep| {
            let mk = MtsKron::with_repeat(&[n, n], &[n, n], m, m, cfg.seed, rep);
            mk.decompress(&mk.compress(&a, &b))
        });
        let cts_rec = median_decompress(d, |rep| {
            let ck = CtsKron::with_repeat(&[n, n], &[n, n], c, cfg.seed, rep);
            ck.decompress(&ck.compress(&a, &b))
        });
        let _ = cts_errs;
        let cts_err = rel_error(&truth, &cts_rec);
        let mts_err = rel_error(&truth, &mts_rec);

        // compression time (sketch only, the paper's "running time")
        let ck = CtsKron::new(&[n, n], &[n, n], c, cfg.seed);
        let cts_time = bench("cts", &bcfg, || ck.compress(&a, &b)).median;
        let mk = MtsKron::new(&[n, n], &[n, n], m, m, cfg.seed);
        let mts_time = bench("mts", &bcfg, || mk.compress(&a, &b)).median;

        table.row(vec![
            format!("{ratio:.1}"),
            format!("{cts_err:.4}"),
            format!("{mts_err:.4}"),
            fmt_duration(cts_time),
            fmt_duration(mts_time),
            format!("{:.1}x", cts_time.as_secs_f64() / mts_time.as_secs_f64()),
        ]);
        rows.push(Fig8Row { ratio, cts_err, mts_err, cts_time, mts_time });
    }
    (table, rows)
}

/// Sanity helper used by tests: error should grow with ratio for both
/// methods (the paper's qualitative claim).
pub fn errors_monotone(rows: &[Fig8Row]) -> bool {
    let cts: Vec<f64> = rows.iter().map(|r| r.cts_err).collect();
    let mts: Vec<f64> = rows.iter().map(|r| r.mts_err).collect();
    // allow small non-monotonic noise: compare first vs last
    cts.last() >= cts.first() && mts.last() >= mts.first()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig8_quick_runs_and_errors_grow_with_ratio() {
        let cfg = ExpConfig { quick: true, seed: 7 };
        let (_t, rows) = run_fig8(&cfg, 10);
        assert_eq!(rows.len(), 3);
        assert!(errors_monotone(&rows), "error should grow with compression");
        // NOTE: the MTS-faster-than-CTS timing claim is asserted by the
        // release-mode bench (`cargo bench` / `hocs bench fig8`), not
        // here — debug-mode FFT timings are meaningless.
    }

    #[test]
    fn fig8_median_error_tracks_sqrt_ratio() {
        // Theory: rel error ≈ √ratio for single sketches; median-of-5
        // brings it below that. At ratio 2 expect ≲ 1.4, at ratio 50
        // clearly larger than at ratio 2.
        let cfg = ExpConfig { quick: true, seed: 9 };
        let (_t, rows) = run_fig8(&cfg, 10);
        assert!(rows[0].mts_err < 1.45, "mts err {}", rows[0].mts_err);
        assert!(
            rows.last().unwrap().mts_err > rows[0].mts_err,
            "error must grow with ratio"
        );
    }

    #[test]
    fn fig8_table_renders() {
        let cfg = ExpConfig { quick: true, seed: 11 };
        let (t, _) = run_fig8(&cfg, 8);
        let s = t.render();
        assert!(s.contains("Figure 8"));
        assert!(s.lines().count() >= 5);
    }
}
