//! Row-major dense tensor of f64.

use crate::rng::Pcg64;

/// Dense N-th-order tensor, row-major (last mode fastest).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    dims: Vec<usize>,
    data: Vec<f64>,
}

impl Tensor {
    // ---------- constructors ----------

    pub fn zeros(dims: &[usize]) -> Self {
        let n: usize = dims.iter().product();
        Self { dims: dims.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(data: Vec<f64>, dims: &[usize]) -> Self {
        let n: usize = dims.iter().product();
        assert_eq!(data.len(), n, "data length {} != product of dims {:?}", data.len(), dims);
        Self { dims: dims.to_vec(), data }
    }

    /// Scalar tensor (order 0).
    pub fn scalar(v: f64) -> Self {
        Self { dims: vec![], data: vec![v] }
    }

    /// iid standard-normal entries.
    pub fn randn(dims: &[usize], rng: &mut Pcg64) -> Self {
        let n: usize = dims.iter().product();
        Self { dims: dims.to_vec(), data: rng.normal_vec(n) }
    }

    /// iid uniform entries in `[lo, hi)`.
    pub fn rand_uniform(dims: &[usize], lo: f64, hi: f64, rng: &mut Pcg64) -> Self {
        let n: usize = dims.iter().product();
        Self { dims: dims.to_vec(), data: rng.uniform_vec(n, lo, hi) }
    }

    /// Identity matrix n×n.
    pub fn eye(n: usize) -> Self {
        let mut t = Self::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    // ---------- accessors ----------

    #[inline]
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    #[inline]
    pub fn order(&self) -> usize {
        self.dims.len()
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1; self.dims.len()];
        for k in (0..self.dims.len().saturating_sub(1)).rev() {
            s[k] = s[k + 1] * self.dims[k + 1];
        }
        s
    }

    /// Flatten a multi-index to the linear offset.
    #[inline]
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.dims.len());
        let mut off = 0;
        for (k, (&i, &d)) in idx.iter().zip(self.dims.iter()).enumerate() {
            debug_assert!(i < d, "index {i} out of bounds for mode {k} (dim {d})");
            off = off * d + i;
        }
        off
    }

    #[inline]
    pub fn get(&self, idx: &[usize]) -> f64 {
        self.data[self.offset(idx)]
    }

    #[inline]
    pub fn set(&mut self, idx: &[usize], v: f64) {
        let o = self.offset(idx);
        self.data[o] = v;
    }

    /// 2-D accessor (matrices).
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f64 {
        debug_assert_eq!(self.order(), 2);
        self.data[i * self.dims[1] + j]
    }

    // ---------- shape manipulation ----------

    /// Reinterpret with new dims (same number of elements, no copy).
    pub fn reshape(mut self, dims: &[usize]) -> Self {
        let n: usize = dims.iter().product();
        assert_eq!(n, self.data.len(), "reshape {:?} -> {:?} changes size", self.dims, dims);
        self.dims = dims.to_vec();
        self
    }

    /// Permute modes: `perm[k]` is the source mode that becomes mode k.
    pub fn permute(&self, perm: &[usize]) -> Self {
        assert_eq!(perm.len(), self.dims.len());
        let mut seen = vec![false; perm.len()];
        for &p in perm {
            assert!(!seen[p], "permute: duplicate mode {p}");
            seen[p] = true;
        }
        let new_dims: Vec<usize> = perm.iter().map(|&p| self.dims[p]).collect();
        let src_strides = self.strides();
        let mut out = Tensor::zeros(&new_dims);
        let mut idx = vec![0usize; new_dims.len()];
        for o in out.data.iter_mut() {
            let mut src = 0;
            for (k, &i) in idx.iter().enumerate() {
                src += i * src_strides[perm[k]];
            }
            *o = self.data[src];
            // increment row-major multi-index
            for k in (0..idx.len()).rev() {
                idx[k] += 1;
                if idx[k] < new_dims[k] {
                    break;
                }
                idx[k] = 0;
            }
        }
        out
    }

    /// Mode-k unfolding as an `n_k × (∏_{j≠k} n_j)` matrix (Kolda
    /// convention: remaining modes in original order, row-major).
    pub fn unfold(&self, mode: usize) -> Tensor {
        assert!(mode < self.dims.len());
        let nk = self.dims[mode];
        let rest: usize = self.len() / nk;
        let mut perm: Vec<usize> = vec![mode];
        perm.extend((0..self.dims.len()).filter(|&k| k != mode));
        self.permute(&perm).reshape(&[nk, rest])
    }

    /// Inverse of [`Tensor::unfold`]: fold an `n_mode × rest` matrix back
    /// into `dims`.
    pub fn fold(mat: &Tensor, mode: usize, dims: &[usize]) -> Tensor {
        assert_eq!(mat.order(), 2);
        let mut permuted_dims: Vec<usize> = vec![dims[mode]];
        permuted_dims.extend(dims.iter().enumerate().filter(|&(k, _)| k != mode).map(|(_, &d)| d));
        let t = mat.clone().reshape(&permuted_dims);
        // inverse permutation of [mode, 0, 1, .., mode-1, mode+1, ..]
        let mut perm = vec![0usize; dims.len()];
        perm[mode] = 0;
        let mut src = 1;
        for (k, p) in perm.iter_mut().enumerate() {
            if k != mode {
                *p = src;
                src += 1;
            }
        }
        t.permute(&perm)
    }

    // ---------- arithmetic ----------

    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |a, &x| a.max(x.abs()))
    }

    pub fn scale(&self, s: f64) -> Self {
        Self { dims: self.dims.clone(), data: self.data.iter().map(|x| x * s).collect() }
    }

    // `add`/`sub` allocate a fresh tensor from borrowed operands, which
    // does not fit the by-value `std::ops` signatures.
    #[allow(clippy::should_implement_trait)]
    pub fn add(&self, o: &Tensor) -> Self {
        assert_eq!(self.dims, o.dims);
        let data = self.data.iter().zip(o.data.iter()).map(|(a, b)| a + b).collect();
        Self { dims: self.dims.clone(), data }
    }

    #[allow(clippy::should_implement_trait)]
    pub fn sub(&self, o: &Tensor) -> Self {
        assert_eq!(self.dims, o.dims);
        let data = self.data.iter().zip(o.data.iter()).map(|(a, b)| a - b).collect();
        Self { dims: self.dims.clone(), data }
    }

    /// Hadamard (element-wise) product — `∘` in the paper.
    pub fn hadamard(&self, o: &Tensor) -> Self {
        assert_eq!(self.dims, o.dims);
        let data = self.data.iter().zip(o.data.iter()).map(|(a, b)| a * b).collect();
        Self { dims: self.dims.clone(), data }
    }

    pub fn add_assign(&mut self, o: &Tensor) {
        assert_eq!(self.dims, o.dims);
        for (a, b) in self.data.iter_mut().zip(o.data.iter()) {
            *a += b;
        }
    }

    /// Matrix multiply (both order-2).
    pub fn matmul(&self, o: &Tensor) -> Tensor {
        assert_eq!(self.order(), 2, "matmul lhs must be a matrix");
        assert_eq!(o.order(), 2, "matmul rhs must be a matrix");
        let (m, k) = (self.dims[0], self.dims[1]);
        let (k2, n) = (o.dims[0], o.dims[1]);
        assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
        let mut out = vec![0.0; m * n];
        // ikj loop order: streams rhs rows, writes each out row repeatedly
        for i in 0..m {
            let orow = &mut out[i * n..(i + 1) * n];
            for kk in 0..k {
                let a = self.data[i * k + kk];
                if a == 0.0 {
                    continue;
                }
                let brow = &o.data[kk * n..(kk + 1) * n];
                for (ov, &bv) in orow.iter_mut().zip(brow.iter()) {
                    *ov += a * bv;
                }
            }
        }
        Tensor::from_vec(out, &[m, n])
    }

    /// Matrix transpose (order-2).
    pub fn transpose(&self) -> Tensor {
        assert_eq!(self.order(), 2);
        self.permute(&[1, 0])
    }

    /// Extract column `j` of a matrix.
    pub fn col(&self, j: usize) -> Vec<f64> {
        assert_eq!(self.order(), 2);
        let (m, n) = (self.dims[0], self.dims[1]);
        assert!(j < n);
        (0..m).map(|i| self.data[i * n + j]).collect()
    }

    /// Extract row `i` of a matrix.
    pub fn row(&self, i: usize) -> &[f64] {
        assert_eq!(self.order(), 2);
        let n = self.dims[1];
        &self.data[i * n..(i + 1) * n]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn offsets_are_row_major() {
        let t = Tensor::from_vec((0..24).map(|x| x as f64).collect(), &[2, 3, 4]);
        assert_eq!(t.get(&[0, 0, 0]), 0.0);
        assert_eq!(t.get(&[0, 0, 3]), 3.0);
        assert_eq!(t.get(&[0, 1, 0]), 4.0);
        assert_eq!(t.get(&[1, 0, 0]), 12.0);
        assert_eq!(t.get(&[1, 2, 3]), 23.0);
        assert_eq!(t.strides(), vec![12, 4, 1]);
    }

    #[test]
    fn permute_transpose_matrix() {
        let t = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        let tt = t.permute(&[1, 0]);
        assert_eq!(tt.dims(), &[3, 2]);
        assert_eq!(tt.data(), &[1.0, 4.0, 2.0, 5.0, 3.0, 6.0]);
    }

    #[test]
    fn permute_roundtrip_3d() {
        let mut rng = Pcg64::new(1);
        let t = Tensor::randn(&[3, 4, 5], &mut rng);
        let p = t.permute(&[2, 0, 1]);
        assert_eq!(p.dims(), &[5, 3, 4]);
        let back = p.permute(&[1, 2, 0]);
        assert_eq!(back, t);
    }

    #[test]
    fn unfold_fold_roundtrip() {
        let mut rng = Pcg64::new(2);
        let t = Tensor::randn(&[3, 4, 5], &mut rng);
        for mode in 0..3 {
            let u = t.unfold(mode);
            assert_eq!(u.dims()[0], t.dims()[mode]);
            let back = Tensor::fold(&u, mode, t.dims());
            assert_eq!(back, t, "mode {mode}");
        }
    }

    #[test]
    fn unfold_values_mode1() {
        // T[i,j] laid out [2,3]; unfold(1) is the transpose
        let t = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        let u = t.unfold(1);
        assert_eq!(u.dims(), &[3, 2]);
        assert_eq!(u.data(), &[1.0, 4.0, 2.0, 5.0, 3.0, 6.0]);
    }

    #[test]
    fn matmul_known_values() {
        let a = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        let b = Tensor::from_vec(vec![5.0, 6.0, 7.0, 8.0], &[2, 2]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Pcg64::new(3);
        let a = Tensor::randn(&[4, 4], &mut rng);
        let i = Tensor::eye(4);
        assert_eq!(a.matmul(&i), a);
        assert_eq!(i.matmul(&a), a);
    }

    #[test]
    fn fro_norm_345() {
        let t = Tensor::from_vec(vec![3.0, 4.0], &[2]);
        assert!((t.fro_norm() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn hadamard_and_add() {
        let a = Tensor::from_vec(vec![1.0, 2.0], &[2]);
        let b = Tensor::from_vec(vec![3.0, 4.0], &[2]);
        assert_eq!(a.hadamard(&b).data(), &[3.0, 8.0]);
        assert_eq!(a.add(&b).data(), &[4.0, 6.0]);
        assert_eq!(b.sub(&a).data(), &[2.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "reshape")]
    fn reshape_size_mismatch_panics() {
        Tensor::zeros(&[2, 3]).reshape(&[4, 2]);
    }

    #[test]
    fn col_row_access() {
        let a = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        assert_eq!(a.col(1), vec![2.0, 5.0]);
        assert_eq!(a.row(1), &[4.0, 5.0, 6.0]);
    }
}
