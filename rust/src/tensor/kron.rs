//! Kronecker, outer, and tensor products — the expensive operations the
//! sketch layer avoids materializing (Figs. 4–6).

use super::dense::Tensor;

/// Kronecker product of two matrices:
/// `(A ⊗ B)[n3(p-1)+h, n4(q-1)+g] = A[p,q]·B[h,g]`
/// (paper Appendix B.1; 0-based here).
pub fn kron(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.order(), 2, "kron lhs must be a matrix");
    assert_eq!(b.order(), 2, "kron rhs must be a matrix");
    let (n1, n2) = (a.dims()[0], a.dims()[1]);
    let (n3, n4) = (b.dims()[0], b.dims()[1]);
    let mut out = Tensor::zeros(&[n1 * n3, n2 * n4]);
    let cols = n2 * n4;
    {
        let od = out.data_mut();
        for p in 0..n1 {
            for q in 0..n2 {
                let av = a.at2(p, q);
                if av == 0.0 {
                    continue;
                }
                for h in 0..n3 {
                    let orow = (p * n3 + h) * cols;
                    let brow = b.row(h);
                    for (g, &bv) in brow.iter().enumerate() {
                        od[orow + q * n4 + g] = av * bv;
                    }
                }
            }
        }
    }
    out
}

/// Kronecker product of two vectors (= flattened outer product).
pub fn kron_vec(a: &[f64], b: &[f64]) -> Vec<f64> {
    let mut out = Vec::with_capacity(a.len() * b.len());
    for &x in a {
        for &y in b {
            out.push(x * y);
        }
    }
    out
}

/// Outer (tensor) product of N vectors: order-N tensor with
/// `T[i₁,…,i_N] = v₁[i₁]⋯v_N[i_N]`.
pub fn outer(vs: &[&[f64]]) -> Tensor {
    assert!(!vs.is_empty());
    let dims: Vec<usize> = vs.iter().map(|v| v.len()).collect();
    let mut data = vec![1.0];
    for v in vs {
        let mut next = Vec::with_capacity(data.len() * v.len());
        for &d in &data {
            for &x in v.iter() {
                next.push(d * x);
            }
        }
        data = next;
    }
    Tensor::from_vec(data, &dims)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::tensor::rel_error;

    #[test]
    fn kron_2x2_known() {
        let a = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        let b = Tensor::from_vec(vec![0.0, 1.0, 1.0, 0.0], &[2, 2]);
        let k = kron(&a, &b);
        assert_eq!(k.dims(), &[4, 4]);
        #[rustfmt::skip]
        let want = vec![
            0.0, 1.0, 0.0, 2.0,
            1.0, 0.0, 2.0, 0.0,
            0.0, 3.0, 0.0, 4.0,
            3.0, 0.0, 4.0, 0.0,
        ];
        assert_eq!(k.data(), want.as_slice());
    }

    #[test]
    fn kron_rect_shapes() {
        let mut rng = Pcg64::new(1);
        let a = Tensor::randn(&[2, 3], &mut rng);
        let b = Tensor::randn(&[4, 5], &mut rng);
        let k = kron(&a, &b);
        assert_eq!(k.dims(), &[8, 15]);
        for p in 0..2 {
            for q in 0..3 {
                for h in 0..4 {
                    for g in 0..5 {
                        let want = a.at2(p, q) * b.at2(h, g);
                        assert!((k.at2(p * 4 + h, q * 5 + g) - want).abs() < 1e-12);
                    }
                }
            }
        }
    }

    #[test]
    fn kron_mixed_product_property() {
        // (A⊗B)(C⊗D) = (AC)⊗(BD)
        let mut rng = Pcg64::new(2);
        let a = Tensor::randn(&[2, 3], &mut rng);
        let b = Tensor::randn(&[2, 2], &mut rng);
        let c = Tensor::randn(&[3, 2], &mut rng);
        let d = Tensor::randn(&[2, 3], &mut rng);
        let lhs = kron(&a, &b).matmul(&kron(&c, &d));
        let rhs = kron(&a.matmul(&c), &b.matmul(&d));
        assert!(rel_error(&rhs, &lhs) < 1e-12);
    }

    #[test]
    fn kron_vec_matches_outer() {
        let a = [1.0, 2.0];
        let b = [3.0, 4.0, 5.0];
        let kv = kron_vec(&a, &b);
        let o = outer(&[&a, &b]);
        assert_eq!(kv, o.data());
        assert_eq!(kv, vec![3.0, 4.0, 5.0, 6.0, 8.0, 10.0]);
    }

    #[test]
    fn outer_three_vectors() {
        let u = [1.0, 2.0];
        let v = [1.0, -1.0];
        let w = [2.0, 0.0, 1.0];
        let t = outer(&[&u, &v, &w]);
        assert_eq!(t.dims(), &[2, 2, 3]);
        for i in 0..2 {
            for j in 0..2 {
                for k in 0..3 {
                    assert_eq!(t.get(&[i, j, k]), u[i] * v[j] * w[k]);
                }
            }
        }
    }

    #[test]
    fn vec_of_kron_matrix_equals_kron_of_unfoldings() {
        // sanity: T = u⊗v⊗w reshaped matches kron structure
        let u = [1.0, 2.0, 3.0];
        let v = [4.0, 5.0];
        let t = outer(&[&u, &v]);
        let k = kron_vec(&u, &v);
        assert_eq!(t.data(), k.as_slice());
    }
}
