//! Dense tensor substrate: strided row-major tensors of `f64` with the
//! operations the paper's algorithms need — reshape/permute, mode-k
//! unfoldings, tensor (outer) products, Kronecker products, full
//! multilinear contraction `T(V₁,…,V_N)` (Eq. 3), and norms.
//!
//! This is deliberately a from-scratch substrate (no ndarray offline);
//! the contraction kernel follows the "extended BLAS" observation of
//! Shi et al. (2016) that the paper cites: a single-mode contraction is
//! a batch of GEMMs over the untouched trailing modes and needs no
//! transposition/copy.

pub mod contract;
pub mod dense;
pub mod kron;

pub use contract::{mode_k_product, multilinear, ModeKTiming};
pub use dense::Tensor;
pub use kron::{kron, kron_vec, outer};

/// Relative Frobenius error ‖a − b‖_F / ‖a‖_F — the paper's Fig. 8/9
/// error metric.
pub fn rel_error(truth: &Tensor, approx: &Tensor) -> f64 {
    assert_eq!(truth.dims(), approx.dims(), "rel_error shape mismatch");
    let denom = truth.fro_norm();
    let mut num = 0.0;
    for (x, y) in truth.data().iter().zip(approx.data().iter()) {
        let d = x - y;
        num += d * d;
    }
    if denom == 0.0 {
        num.sqrt()
    } else {
        num.sqrt() / denom
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rel_error_zero_for_identical() {
        let t = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        assert_eq!(rel_error(&t, &t.clone()), 0.0);
    }

    #[test]
    fn rel_error_scales() {
        let a = Tensor::from_vec(vec![3.0, 4.0], &[2]);
        let b = Tensor::from_vec(vec![0.0, 0.0], &[2]);
        assert!((rel_error(&a, &b) - 1.0).abs() < 1e-12);
    }
}
