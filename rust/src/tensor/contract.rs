//! Tensor contraction: mode-k products and full multilinear maps.
//!
//! `mode_k_product(T, M, k)` computes `T ×_k Mᵀ` in the paper's notation
//! `T(I, …, M, …, I)` — contract mode k of `T` (size n_k) against the
//! first index of `M ∈ ℝ^{n_k × m}`, producing a tensor whose mode k has
//! size m. This is the primitive behind both the sketch itself (Eq. 3,
//! contraction with the hash matrices H_i) and Tucker reconstruction.
//!
//! Implementation follows the Shi et al. (2016) extended-BLAS scheme the
//! paper cites: split the modes into (left, k, right); for each left
//! slice the contraction is a single `right × n_k` by `n_k × m` GEMM —
//! no transposition or copy of `T` is ever made.

use super::dense::Tensor;

/// Counters for the operation-count instrumentation used by the
/// Table 4/5/6 benches (multiply-adds, elements moved).
#[derive(Clone, Copy, Debug, Default)]
pub struct ModeKTiming {
    pub fma: u64,
    pub moved: u64,
}

/// Contract mode `k` of `t` with matrix `m` (`m.dims() == [n_k, mk]`),
/// i.e. out[..., j, ...] = Σ_i t[..., i, ...] · m[i, j].
pub fn mode_k_product(t: &Tensor, m: &Tensor, k: usize) -> Tensor {
    let (out, _) = mode_k_product_counted(t, m, k);
    out
}

/// Same as [`mode_k_product`] but also returns op counters.
pub fn mode_k_product_counted(t: &Tensor, m: &Tensor, k: usize) -> (Tensor, ModeKTiming) {
    assert!(k < t.order(), "mode {k} out of range for order {}", t.order());
    assert_eq!(m.order(), 2, "contraction matrix must be 2-D");
    let nk = t.dims()[k];
    assert_eq!(m.dims()[0], nk, "mode-{k} size {nk} != matrix rows {}", m.dims()[0]);
    let mk = m.dims()[1];

    let left: usize = t.dims()[..k].iter().product();
    let right: usize = t.dims()[k + 1..].iter().product();

    let mut out_dims = t.dims().to_vec();
    out_dims[k] = mk;
    let mut out = Tensor::zeros(&out_dims);

    let td = t.data();
    let md = m.data();
    let od = out.data_mut();

    // For each left index L: T[L, i, R] is laid out as a (nk × right)
    // block at offset L·nk·right. out[L, j, R] = Σ_i T[L,i,R] · M[i,j]
    // — i.e. block_outᵀ = M ᵀ · block, done here as: for each i, axpy
    // M[i,j]·row_i into out row j.
    for l in 0..left {
        let tb = &td[l * nk * right..(l + 1) * nk * right];
        let ob = &mut od[l * mk * right..(l + 1) * mk * right];
        for i in 0..nk {
            let trow = &tb[i * right..(i + 1) * right];
            let mrow = &md[i * mk..(i + 1) * mk];
            for (j, &w) in mrow.iter().enumerate() {
                if w == 0.0 {
                    continue;
                }
                let orow = &mut ob[j * right..(j + 1) * right];
                for (o, &tv) in orow.iter_mut().zip(trow.iter()) {
                    *o += w * tv;
                }
            }
        }
    }

    let timing = ModeKTiming {
        fma: (left * nk * mk * right) as u64,
        moved: (t.len() + out.len() + m.len()) as u64,
    };
    (out, timing)
}

/// Full multilinear contraction `T(M₁, …, M_N)`: each `ms[k]` is either
/// `Some(M)` with `M ∈ ℝ^{n_k × m_k}` or `None` (identity / skip).
///
/// Applies smallest-output-first to minimize intermediate size.
pub fn multilinear(t: &Tensor, ms: &[Option<&Tensor>]) -> Tensor {
    assert_eq!(ms.len(), t.order(), "need one (optional) matrix per mode");
    // order modes by shrink factor (descending shrink first)
    let mut order: Vec<usize> = (0..ms.len()).filter(|&k| ms[k].is_some()).collect();
    order.sort_by(|&a, &b| {
        let ra = ms[a].unwrap().dims()[1] as f64 / t.dims()[a] as f64;
        let rb = ms[b].unwrap().dims()[1] as f64 / t.dims()[b] as f64;
        ra.partial_cmp(&rb).unwrap()
    });
    let mut cur = t.clone();
    for k in order {
        cur = mode_k_product(&cur, ms[k].unwrap(), k);
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    /// Naive reference: contract via explicit loops.
    fn naive_mode_k(t: &Tensor, m: &Tensor, k: usize) -> Tensor {
        let nk = t.dims()[k];
        let mk = m.dims()[1];
        let mut out_dims = t.dims().to_vec();
        out_dims[k] = mk;
        let mut out = Tensor::zeros(&out_dims);
        let mut idx = vec![0usize; t.order()];
        loop {
            let mut oidx = idx.clone();
            for j in 0..mk {
                oidx[k] = j;
                let mut acc = out.get(&oidx);
                // contribution for this source element happens below;
                // easier: recompute sum fully
                acc = 0.0;
                let mut sidx = idx.clone();
                for i in 0..nk {
                    sidx[k] = i;
                    acc += t.get(&sidx) * m.at2(i, j);
                }
                out.set(&oidx, acc);
            }
            // advance idx skipping mode k (we fixed it)
            let mut done = true;
            for d in (0..idx.len()).rev() {
                if d == k {
                    continue;
                }
                idx[d] += 1;
                if idx[d] < t.dims()[d] {
                    done = false;
                    break;
                }
                idx[d] = 0;
            }
            if done {
                break;
            }
        }
        let _ = nk;
        out
    }

    #[test]
    fn matches_naive_all_modes() {
        let mut rng = Pcg64::new(4);
        let t = Tensor::randn(&[3, 4, 5], &mut rng);
        for k in 0..3 {
            let m = Tensor::randn(&[t.dims()[k], 2 + k], &mut rng);
            let got = mode_k_product(&t, &m, k);
            let want = naive_mode_k(&t, &m, k);
            assert_eq!(got.dims(), want.dims());
            for (a, b) in got.data().iter().zip(want.data().iter()) {
                assert!((a - b).abs() < 1e-10, "mode {k}");
            }
        }
    }

    #[test]
    fn identity_contraction_is_noop() {
        let mut rng = Pcg64::new(5);
        let t = Tensor::randn(&[4, 3, 2], &mut rng);
        for k in 0..3 {
            let i = Tensor::eye(t.dims()[k]);
            let got = mode_k_product(&t, &i, k);
            assert_eq!(got, t, "mode {k}");
        }
    }

    #[test]
    fn mode_product_on_matrix_is_matmul() {
        let mut rng = Pcg64::new(6);
        let a = Tensor::randn(&[4, 5], &mut rng);
        let m = Tensor::randn(&[5, 3], &mut rng);
        // contracting mode 1 of A with M = A · M
        let got = mode_k_product(&a, &m, 1);
        let want = a.matmul(&m);
        for (x, y) in got.data().iter().zip(want.data().iter()) {
            assert!((x - y).abs() < 1e-10);
        }
    }

    #[test]
    fn multilinear_matches_sequential() {
        let mut rng = Pcg64::new(7);
        let t = Tensor::randn(&[3, 4, 5], &mut rng);
        let m0 = Tensor::randn(&[3, 2], &mut rng);
        let m2 = Tensor::randn(&[5, 6], &mut rng);
        let got = multilinear(&t, &[Some(&m0), None, Some(&m2)]);
        let want = mode_k_product(&mode_k_product(&t, &m0, 0), &m2, 2);
        assert_eq!(got.dims(), want.dims());
        for (x, y) in got.data().iter().zip(want.data().iter()) {
            assert!((x - y).abs() < 1e-10);
        }
    }

    #[test]
    fn fma_counter_counts() {
        let mut rng = Pcg64::new(8);
        let t = Tensor::randn(&[2, 3, 4], &mut rng);
        let m = Tensor::randn(&[3, 5], &mut rng);
        let (_, timing) = mode_k_product_counted(&t, &m, 1);
        assert_eq!(timing.fma, (2 * 3 * 5 * 4) as u64);
    }

    #[test]
    fn figure2_example_contraction() {
        // Paper Fig. 2: A ∈ ℝ^{2×2×3}, u, v ∈ ℝ^{2×1} → A(u, v, I) ∈ ℝ^{1×1×3}
        let mut rng = Pcg64::new(9);
        let a = Tensor::randn(&[2, 2, 3], &mut rng);
        let u = Tensor::randn(&[2, 1], &mut rng);
        let v = Tensor::randn(&[2, 1], &mut rng);
        let got = multilinear(&a, &[Some(&u), Some(&v), None]);
        assert_eq!(got.dims(), &[1, 1, 3]);
        for t3 in 0..3 {
            let mut want = 0.0;
            for i in 0..2 {
                for j in 0..2 {
                    want += a.get(&[i, j, t3]) * u.at2(i, 0) * v.at2(j, 0);
                }
            }
            assert!((got.get(&[0, 0, t3]) - want).abs() < 1e-10);
        }
    }
}
