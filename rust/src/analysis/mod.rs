//! Self-checking invariant plane: `hocs lint`.
//!
//! The store's correctness arguments lean on cross-cutting invariants
//! that no single `#[test]` can see: every durable write must be
//! fault-injectable, every wire opcode must exist at every protocol
//! layer, served paths must not panic, and the on-disk format must not
//! drift without a version bump. This module is a purpose-built static
//! analyzer for exactly those four contracts — a few hundred lines of
//! comment/string-aware scanning (see [`lex`]), no parser dependency,
//! run as `hocs lint` and as a unit test over the shipped tree.
//!
//! # Pass catalog
//!
//! | pass | scope | contract |
//! |------|-------|----------|
//! | `fault-coverage` ([`fault_coverage`]) | `store/**` | raw `File::create` / `.write_all` / `.sync_data` / `.sync_all` / `fs::rename` only inside fns that touch `store::faults` |
//! | `opcode-symmetry` ([`opcode_symmetry`]) | wire_ops / server / client / main | every `ALL`-table row has a dispatch arm, a client method, and (if named) a CLI verb in `USAGE` plus a match arm; no orphan consts or dangling `op::` refs |
//! | `no-panic-paths` ([`no_panic`]) | scoped fns (see `no_panic::SCOPES`) | no `unwrap` / `expect` / panicking macros / indexing on request-serving and durability paths |
//! | `version-gate` ([`version_gate`]) | `store/wal.rs` | WAL record shapes, tags, header consts, and snapshot sections match the manifest pinned for the current `FORMAT_VERSION` |
//!
//! # Annotation grammar
//!
//! A violation that is *provably fine* is silenced in place:
//!
//! ```text
//! // lint: allow(<pass>) <reason>
//! ```
//!
//! A **trailing** comment covers its own line. An **own-line** comment
//! covers the next code line (attribute lines are skipped) — or, if
//! that line starts a `fn`, the whole fn. The reason is mandatory and
//! the pass name must exist: an empty reason or an unknown pass is
//! itself a violation (`lint-annotation`), so the escape hatch cannot
//! rot into a blanket mute.
//!
//! # Adding a pass
//!
//! 1. Create `analysis/<pass>.rs` with `pub const PASS: &str` and a
//!    `check(&SourceFile) -> Vec<Violation>` (take extra inputs via an
//!    `Inputs` struct if the pass is cross-file, keeping it callable
//!    on fixtures).
//! 2. Wire it into [`run_lint`] and add `PASS` to [`PASS_NAMES`] so
//!    annotations can reference it.
//! 3. Seed a known-bad fixture under `analysis/fixtures/` and assert
//!    in this module's tests that the pass flags it — a pass without a
//!    failing fixture is a pass that may silently match nothing.
//!
//! The `fixtures/` directory is not compiled (no `mod` declarations)
//! and the source walker skips it, so the deliberately-bad code never
//! reaches rustc, clippy, or the lint's own self-run.

pub mod fault_coverage;
pub mod lex;
pub mod no_panic;
pub mod opcode_symmetry;
pub mod version_gate;

use std::fmt;
use std::fs;
use std::path::Path;

use anyhow::{Context, Result};

use self::lex::SourceFile;

/// Pass names an annotation may reference.
pub const PASS_NAMES: &[&str] =
    &[fault_coverage::PASS, opcode_symmetry::PASS, no_panic::PASS, version_gate::PASS];

/// Malformed annotations are violations of this pseudo-pass (and are
/// themselves not annotatable away).
pub const ANNOTATION_PASS: &str = "lint-annotation";

/// One finding. `line` 0 means the finding is about the file (or a
/// cross-file relationship) rather than a specific line.
#[derive(Debug, Clone)]
pub struct Violation {
    pub pass: &'static str,
    pub file: String,
    pub line: usize,
    pub message: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.line == 0 {
            write!(f, "{}: [{}] {}", self.file, self.pass, self.message)
        } else {
            write!(f, "{}:{}: [{}] {}", self.file, self.line, self.pass, self.message)
        }
    }
}

pub fn render(violations: &[Violation]) -> String {
    violations.iter().map(|v| format!("{v}\n")).collect()
}

/// Lint every `.rs` file under `root` (paths in findings are
/// `/`-separated and root-relative). The cross-file `opcode-symmetry`
/// pass runs when all four of its surfaces are present under `root`;
/// `version-gate` runs on `store/wal.rs`.
pub fn run_lint(root: &Path) -> Result<Vec<Violation>> {
    let mut files = Vec::new();
    collect(root, root, &mut files)?;
    files.sort_by(|a, b| a.0.cmp(&b.0));
    let sources: Vec<SourceFile> =
        files.iter().map(|(rel, text)| SourceFile::parse(rel, text)).collect();

    let mut violations = Vec::new();
    let mut allows = Vec::new();
    for sf in &sources {
        violations.extend(fault_coverage::check(sf));
        violations.extend(no_panic::check(sf));
        if sf.path == "store/wal.rs" {
            violations.extend(version_gate::check(sf));
        }
        let (file_allows, bad) = parse_allows(sf);
        allows.extend(file_allows);
        violations.extend(bad);
    }
    let find = |p: &str| sources.iter().find(|sf| sf.path == p);
    if let (Some(wire_ops), Some(server), Some(client), Some(main)) = (
        find("store/wire_ops.rs"),
        find("store/server.rs"),
        find("store/client.rs"),
        find("main.rs"),
    ) {
        let inputs = opcode_symmetry::Inputs { wire_ops, server, client, main };
        violations.extend(opcode_symmetry::check(&inputs));
    }

    violations.retain(|v| {
        v.pass == ANNOTATION_PASS
            || !allows.iter().any(|a| {
                a.file == v.file && a.pass == v.pass && v.line >= a.first && v.line <= a.last
            })
    });
    violations.sort_by(|a, b| (&a.file, a.line, a.pass).cmp(&(&b.file, b.line, b.pass)));
    Ok(violations)
}

fn collect(root: &Path, dir: &Path, out: &mut Vec<(String, String)>) -> Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)
        .with_context(|| format!("reading {dir:?}"))?
        .collect::<std::io::Result<_>>()?;
    entries.sort_by_key(|e| e.file_name());
    for entry in entries {
        let path = entry.path();
        if path.is_dir() {
            if entry.file_name() != "fixtures" {
                collect(root, &path, out)?;
            }
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .to_string_lossy()
                .replace('\\', "/");
            let text = fs::read_to_string(&path).with_context(|| format!("reading {path:?}"))?;
            out.push((rel, text));
        }
    }
    Ok(())
}

/// A resolved `// lint: allow(<pass>) <reason>` annotation: silences
/// `pass` findings on lines `first..=last` of `file`.
struct Allow {
    file: String,
    pass: &'static str,
    first: usize,
    last: usize,
}

fn parse_allows(sf: &SourceFile) -> (Vec<Allow>, Vec<Violation>) {
    let mut allows = Vec::new();
    let mut bad = Vec::new();
    let spans = sf.fn_spans();
    for c in &sf.comments {
        // doc comments (`///`, `//!`) never carry directives — a
        // literal example in module docs must not become an allow
        if c.text.starts_with("///") || c.text.starts_with("//!") {
            continue;
        }
        let body = c.text.trim_start_matches('/').trim();
        let Some(rest) = body.strip_prefix("lint:") else { continue };
        let mut flag = |message: String| {
            bad.push(Violation {
                pass: ANNOTATION_PASS,
                file: sf.path.clone(),
                line: c.line,
                message,
            });
        };
        let rest = rest.trim();
        let Some(inner) = rest.strip_prefix("allow(") else {
            flag(format!(
                "unrecognized lint directive `{rest}`; expected `allow(<pass>) <reason>`"
            ));
            continue;
        };
        let Some(close) = inner.find(')') else {
            flag("unterminated `allow(` in lint annotation".to_string());
            continue;
        };
        let pass_name = &inner[..close];
        let Some(pass) = PASS_NAMES.iter().copied().find(|p| *p == pass_name) else {
            flag(format!(
                "unknown pass `{}` in lint annotation (known: {})",
                &inner[..close],
                PASS_NAMES.join(", ")
            ));
            continue;
        };
        if inner[close + 1..].trim().is_empty() {
            flag(format!("`allow({pass})` needs a reason — say why this site is safe"));
            continue;
        }
        let (first, last) = if c.trailing {
            (c.line, c.line)
        } else {
            let mut t = c.line + 1;
            while t <= sf.line_count() {
                let l = sf.line(t).trim();
                if !l.is_empty() && !l.starts_with("#[") {
                    break;
                }
                t += 1;
            }
            if t > sf.line_count() {
                flag(format!("`allow({pass})` covers no code (end of file)"));
                continue;
            }
            match spans.iter().find(|s| s.start_line == t) {
                Some(s) => (t, s.end_line),
                None => (t, t),
            }
        };
        allows.push(Allow { file: sf.path.clone(), pass, first, last });
    }
    (allows, bad)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn src_root() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust").join("src")
    }

    /// The shipped tree holds its own invariants — the same check CI
    /// runs as `hocs lint --deny`.
    #[test]
    fn shipped_tree_is_lint_clean() {
        let violations = run_lint(&src_root()).expect("lint run");
        assert!(
            violations.is_empty(),
            "lint violations on the shipped tree:\n{}",
            render(&violations)
        );
    }

    #[test]
    fn fault_coverage_flags_unrouted_durable_writes() {
        let sf = SourceFile::parse(
            "store/fixture.rs",
            include_str!("fixtures/bad_fault_coverage.rs"),
        );
        let vs = fault_coverage::check(&sf);
        assert_eq!(vs.len(), 4, "create/write_all/sync_data/rename all flagged:\n{}", render(&vs));
        assert!(vs.iter().all(|v| v.pass == fault_coverage::PASS));
        // the shimmed sibling fn in the same fixture is covered
        assert!(!render(&vs).contains("install_shimmed"));
    }

    #[test]
    fn no_panic_flags_every_token_class() {
        let sf = SourceFile::parse("store/fixture.rs", include_str!("fixtures/bad_no_panic.rs"));
        let vs = no_panic::check_fns(&sf, &["dispatch"]);
        let text = render(&vs);
        for needle in ["`.unwrap()`", "`.expect`", "`panic!`", "indexing"] {
            assert!(text.contains(needle), "missing {needle} in:\n{text}");
        }
        // and a missing scoped fn is itself a finding
        let missing = no_panic::check_fns(&sf, &["dispatch", "gone"]);
        assert!(render(&missing).contains("scoped fn `gone` not found"));
    }

    #[test]
    fn opcode_symmetry_flags_every_missing_layer() {
        let wire_ops = SourceFile::parse(
            "store/wire_ops.rs",
            include_str!("fixtures/bad_opcode_symmetry.rs"),
        );
        let server = SourceFile::parse(
            "store/server.rs",
            "fn dispatch(opcode: u8) {\n    match opcode {\n        op::PING => {}\n        op::GHOST => {}\n        _ => {}\n    }\n}\n",
        );
        let client = SourceFile::parse(
            "store/client.rs",
            "impl Client {\n    pub fn ping(&self) {}\n}\n",
        );
        let main = SourceFile::parse(
            "main.rs",
            "const USAGE: &str = \"usage: hocs <status>\";\nfn main() {\n    match verb {\n        \"status\" => {}\n        _ => {}\n    }\n}\n",
        );
        let vs = opcode_symmetry::check(&opcode_symmetry::Inputs {
            wire_ops: &wire_ops,
            server: &server,
            client: &client,
            main: &main,
        });
        let text = render(&vs);
        for needle in [
            "`ORPHAN` is missing from the ALL table",
            "undeclared opcode const `GONE`",
            "no dispatch arm `op::PING2 =>`",
            "no client method `fn orphan(`",
            "CLI verb `ping` (wire op PING) is not listed in USAGE",
            "CLI verb `ping` (wire op PING) has no match arm",
            "no unknown-opcode rejection",
            "`op::GHOST` does not name a declared wire-op const",
        ] {
            assert!(text.contains(needle), "missing `{needle}` in:\n{text}");
        }
    }

    #[test]
    fn version_gate_flags_drift_and_missing_pins() {
        let sf = SourceFile::parse("store/wal.rs", include_str!("fixtures/bad_version_gate.rs"));
        let (manifest, version) = version_gate::extract_manifest(&sf.raw).expect("extracts");
        assert_eq!(version, 7);
        // matching pin: clean
        assert!(version_gate::check_against(&sf, &[(7, &manifest)]).is_empty());
        // no pin for the declared version
        let vs = version_gate::check_against(&sf, &[(6, &manifest)]);
        assert!(render(&vs).contains("no pinned manifest"), "{}", render(&vs));
        // pinned but drifted (one tag renamed)
        let drifted = manifest.replace("TAG_PING", "TAG_RENAMED");
        let vs = version_gate::check_against(&sf, &[(7, &drifted)]);
        assert!(render(&vs).contains("drifted without a FORMAT_VERSION bump"), "{}", render(&vs));
    }

    #[test]
    fn annotations_require_reasons_and_known_passes() {
        let sf = SourceFile::parse("store/fixture.rs", include_str!("fixtures/bad_annotation.rs"));
        let (allows, bad) = parse_allows(&sf);
        let text = render(&bad);
        assert!(text.contains("needs a reason"), "{text}");
        assert!(text.contains("unknown pass `no-such-pass`"), "{text}");
        // the one well-formed annotation resolved to a fn-level allow
        assert_eq!(allows.len(), 1);
        assert!(allows[0].last > allows[0].first, "fn-level span covers the body");
    }

    #[test]
    fn annotations_suppress_only_their_pass_and_span() {
        let sf = SourceFile::parse("store/fixture.rs", include_str!("fixtures/bad_annotation.rs"));
        let (allows, _) = parse_allows(&sf);
        let vs = no_panic::check_fns(&sf, &["annotated", "unannotated"]);
        let survivors: Vec<_> = vs
            .iter()
            .filter(|v| {
                !allows.iter().any(|a| {
                    a.file == v.file && a.pass == v.pass && v.line >= a.first && v.line <= a.last
                })
            })
            .collect();
        assert!(!vs.is_empty(), "fixture produces raw findings");
        assert!(
            survivors.iter().all(|v| render(&[(*v).clone()]).contains("unannotated")),
            "only the unannotated fn's findings survive:\n{}",
            render(&vs)
        );
        assert!(!survivors.is_empty(), "the unannotated fn is still flagged");
    }
}
