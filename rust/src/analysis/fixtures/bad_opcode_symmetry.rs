// Seeded-bad fixture for the `opcode-symmetry` pass: a mini wire-op
// table where every layer is out of sync with some row.
// Never compiled — fed to the pass as text by analysis/mod.rs tests.

/// Served by the fixture server, client has `fn ping`, but the fixture
/// `main.rs` lists no `ping` verb — two CLI findings.
pub const PING: u8 = 1;
/// Declared but absent from ALL — an orphan const finding.
pub const ORPHAN: u8 = 2;
/// In ALL but with no dispatch arm and no client method.
pub const PING2: u8 = 3;

pub const ALL: &[WireOp] = &[
    WireOp { code: PING, name: "PING", client_method: "ping", cli: Some("ping") },
    WireOp { code: PING2, name: "PING2", client_method: "orphan", cli: None },
    // `GONE` is never declared — an undeclared-const finding
    WireOp { code: GONE, name: "GONE", client_method: "gone", cli: None },
];
