// Seeded-bad fixture for the `version-gate` pass: a wal.rs-shaped
// source declaring FORMAT_VERSION 7, used to exercise the
// missing-pin and manifest-drift findings against synthetic pins.
// Never compiled — fed to the pass as text by analysis/mod.rs tests.

const SNAP_MAGIC: &[u8; 8] = b"FIXSNAP0";
const WAL_MAGIC: &[u8; 8] = b"FIXWAL00";
const FORMAT_VERSION: u32 = 7;
const HEADER_LEN: usize = 20;

pub enum WalRecord {
    /// a doc comment between variants must not enter the manifest
    Ping { nonce: u64 },
    Pong,
}

const TAG_PING: u8 = 1;
const TAG_PONG: u8 = 2; // trailing comments are cut before pinning

impl Fixture {
    fn write_snapshot_file(&self) -> std::io::Result<()> {
        let mut out = Vec::new();
        out.extend_from_slice(SNAP_MAGIC);
        put_u32(&mut out, FORMAT_VERSION);
        self.body.encode_into(&mut out);
        install(&out)
    }
}
