// Seeded-bad fixture for the `no-panic-paths` pass: every banned
// token class in one served-path fn.
// Never compiled — fed to the pass as text by analysis/mod.rs tests.

pub fn dispatch(req: &[u8]) -> u8 {
    let first = req[0];
    let parsed: u8 = std::str::from_utf8(&req[1..]).unwrap().parse().expect("digits");
    if first == 0 {
        panic!("zero opcode");
    }
    parsed
}
