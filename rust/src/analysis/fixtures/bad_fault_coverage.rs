// Seeded-bad fixture for the `fault-coverage` pass: durable-path
// filesystem mutations that never route through `store::faults`.
// Never compiled — fed to the pass as text by analysis/mod.rs tests.
use std::fs::{self, File};
use std::io::Write as _;
use std::path::Path;

/// Four raw durable ops, zero `faults::` reach — four findings.
pub fn install_unchecked(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    let tmp = path.with_extension("tmp");
    let mut f = File::create(&tmp)?;
    f.write_all(bytes)?;
    f.sync_data()?;
    fs::rename(&tmp, path)?;
    Ok(())
}

/// The same shape with a fault checkpoint — covered, no findings.
pub fn install_shimmed(path: &Path) -> std::io::Result<()> {
    faults::fire("fixture.create")?;
    let _f = File::create(path)?;
    Ok(())
}
