// Seeded-bad fixture for annotation handling: malformed annotations
// are `lint-annotation` findings; the one well-formed annotation
// suppresses exactly its own fn, and only for its own pass.
// Never compiled — fed to the pass as text by analysis/mod.rs tests.

// lint: allow(fault-coverage)
pub fn reasonless(req: &[u8]) -> u8 {
    req.len() as u8
}

// lint: allow(no-such-pass) the pass name is wrong, so this is flagged
pub fn unknown_pass(req: &[u8]) -> u8 {
    req.len() as u8
}

// lint: allow(no-panic-paths) fixture: poison here is unreachable by construction
pub fn annotated(req: &[u8]) -> u8 {
    req[0]
}

pub fn unannotated(req: &[u8]) -> u8 {
    req[0]
}
