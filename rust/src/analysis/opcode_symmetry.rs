//! `opcode-symmetry`: the wire-protocol table is the single source of
//! truth, and every surface that speaks the protocol must cover it.
//!
//! `store/wire_ops.rs` declares each opcode once, in the `ALL` table,
//! with its canonical name, client method, and optional CLI verb. This
//! pass re-parses that table from source and checks, for every row:
//!
//! * the `code` identifier is a declared `u8` const (and every
//!   non-`STATUS_*` const appears in some row — no orphan opcodes);
//! * `store/server.rs` has a dispatch arm `op::<NAME> =>` plus an
//!   unknown-opcode rejection path (`op::unknown(`);
//! * `store/client.rs` defines `fn <client_method>(` (the method may
//!   build its frame via helpers, so no `op::` reference is required);
//! * if the row names a CLI verb, `main.rs` lists it in `USAGE` and
//!   matches it (`"<verb>" =>`);
//! * every `op::<UPPERCASE>` reference in server/client resolves to a
//!   declared const (catches dispatch arms for deleted opcodes).
//!
//! Adding an opcode and forgetting any one of those layers is exactly
//! the drift this pass exists to stop.

use super::lex::{is_ident, match_brace, SourceFile};
use super::Violation;

pub const PASS: &str = "opcode-symmetry";

/// The four files the pass correlates. Split out so tests can feed
/// seeded-bad fixtures for any single surface.
pub struct Inputs<'a> {
    pub wire_ops: &'a SourceFile,
    pub server: &'a SourceFile,
    pub client: &'a SourceFile,
    pub main: &'a SourceFile,
}

struct OpRow {
    const_name: String,
    client_method: String,
    cli: Option<String>,
    line: usize,
}

pub fn check(inp: &Inputs) -> Vec<Violation> {
    let mut out = Vec::new();
    let rows = parse_table(inp.wire_ops, &mut out);
    let consts = parse_consts(inp.wire_ops);
    let usage = usage_text(inp.main);

    for (name, line) in &consts {
        if !name.starts_with("STATUS_") && !rows.iter().any(|r| &r.const_name == name) {
            out.push(Violation {
                pass: PASS,
                file: inp.wire_ops.path.clone(),
                line: *line,
                message: format!("opcode const `{name}` is missing from the ALL table"),
            });
        }
    }

    for row in &rows {
        let name = &row.const_name;
        if !consts.iter().any(|(n, _)| n == name) {
            out.push(Violation {
                pass: PASS,
                file: inp.wire_ops.path.clone(),
                line: row.line,
                message: format!("ALL table references undeclared opcode const `{name}`"),
            });
            continue;
        }
        if !inp.server.cleaned.contains(&format!("op::{name} =>")) {
            out.push(Violation {
                pass: PASS,
                file: inp.server.path.clone(),
                line: 0,
                message: format!("no dispatch arm `op::{name} =>` for wire op {name}"),
            });
        }
        let method = &row.client_method;
        if !inp.client.cleaned.contains(&format!("fn {method}(")) {
            out.push(Violation {
                pass: PASS,
                file: inp.client.path.clone(),
                line: 0,
                message: format!("no client method `fn {method}(` for wire op {name}"),
            });
        }
        if let Some(verb) = &row.cli {
            if !contains_verb(&usage, verb) {
                out.push(Violation {
                    pass: PASS,
                    file: inp.main.path.clone(),
                    line: 0,
                    message: format!("CLI verb `{verb}` (wire op {name}) is not listed in USAGE"),
                });
            }
            if !inp.main.raw.contains(&format!("\"{verb}\" =>")) {
                out.push(Violation {
                    pass: PASS,
                    file: inp.main.path.clone(),
                    line: 0,
                    message: format!("CLI verb `{verb}` (wire op {name}) has no match arm"),
                });
            }
        }
    }

    if !inp.server.cleaned.contains("op::unknown(") {
        out.push(Violation {
            pass: PASS,
            file: inp.server.path.clone(),
            line: 0,
            message: "server dispatch has no unknown-opcode rejection (`op::unknown(`)".to_string(),
        });
    }

    for sf in [inp.server, inp.client] {
        for (ident, line) in op_refs(sf) {
            if !consts.iter().any(|(n, _)| *n == ident) {
                out.push(Violation {
                    pass: PASS,
                    file: sf.path.clone(),
                    line,
                    message: format!("`op::{ident}` does not name a declared wire-op const"),
                });
            }
        }
    }

    out
}

/// Re-parse the `ALL` table rows from raw source (the string fields
/// live inside literals, which cleaning blanks). Each row is a
/// `WireOp { … }` struct expression; the `struct WireOp {` declaration
/// itself is skipped.
fn parse_table(wire: &SourceFile, out: &mut Vec<Violation>) -> Vec<OpRow> {
    let mut rows = Vec::new();
    let raw = &wire.raw;
    let mut at = 0;
    while let Some(rel) = raw[at..].find("WireOp {") {
        let start = at + rel;
        at = start + "WireOp ".len();
        if raw[..start].trim_end().ends_with("struct") {
            continue;
        }
        let open = start + "WireOp ".len();
        let Some(end) = match_brace(raw.as_bytes(), open) else { break };
        let body = &raw[open + 1..end];
        let line = wire.line_of(start);
        at = end + 1;
        let Some(const_name) = field_ident(body, "code:") else {
            out.push(Violation {
                pass: PASS,
                file: wire.path.clone(),
                line,
                message: "WireOp row has no parsable `code:` field".to_string(),
            });
            continue;
        };
        let Some(client_method) = field_str(body, "client_method:") else {
            out.push(Violation {
                pass: PASS,
                file: wire.path.clone(),
                line,
                message: format!("WireOp row {const_name} has no parsable `client_method:` field"),
            });
            continue;
        };
        let cli = match field_cli(body) {
            Ok(cli) => cli,
            Err(()) => {
                out.push(Violation {
                    pass: PASS,
                    file: wire.path.clone(),
                    line,
                    message: format!("WireOp row {const_name} has no parsable `cli:` field"),
                });
                continue;
            }
        };
        rows.push(OpRow { const_name, client_method, cli, line });
    }
    if rows.is_empty() {
        out.push(Violation {
            pass: PASS,
            file: wire.path.clone(),
            line: 0,
            message: "no WireOp rows found — ALL table missing or unparsable".to_string(),
        });
    }
    rows
}

/// `pub const NAME: u8 = …;` declarations, with their lines.
fn parse_consts(wire: &SourceFile) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    for (idx, line) in wire.raw.lines().enumerate() {
        let t = line.trim();
        if let Some(rest) = t.strip_prefix("pub const ") {
            if let Some(name) = rest.split(':').next() {
                if rest[name.len()..].starts_with(": u8 = ") {
                    out.push((name.to_string(), idx + 1));
                }
            }
        }
    }
    out
}

fn field_ident(body: &str, key: &str) -> Option<String> {
    let rest = body[body.find(key)? + key.len()..].trim_start();
    let end = rest.bytes().position(|b| !is_ident(b)).unwrap_or(rest.len());
    (end > 0).then(|| rest[..end].to_string())
}

fn field_str(body: &str, key: &str) -> Option<String> {
    let rest = body[body.find(key)? + key.len()..].trim_start();
    let rest = rest.strip_prefix('"')?;
    Some(rest[..rest.find('"')?].to_string())
}

fn field_cli(body: &str) -> Result<Option<String>, ()> {
    let rest = body[body.find("cli:").ok_or(())? + "cli:".len()..].trim_start();
    if rest.starts_with("None") {
        return Ok(None);
    }
    let rest = rest.strip_prefix("Some(").ok_or(())?;
    field_str(rest, "").map(Some).ok_or(())
}

/// Extract the `USAGE` string contents from `main.rs` raw text so the
/// verb check looks at the help screen, not at incidental mentions.
fn usage_text(main: &SourceFile) -> String {
    let Some(p) = main.raw.find("const USAGE:") else { return String::new() };
    let Some(q) = main.raw[p..].find('"') else { return String::new() };
    let bytes = main.raw.as_bytes();
    let mut i = p + q + 1;
    let start = i;
    while i < bytes.len() {
        match bytes[i] {
            b'\\' => i += 2,
            b'"' => break,
            _ => i += 1,
        }
    }
    main.raw[start..i.min(bytes.len())].to_string()
}

/// `verb` appears in `text` delimited by non-verb characters, so
/// `update` inside `update-batch` does not count.
fn contains_verb(text: &str, verb: &str) -> bool {
    let is_verb_char = |b: u8| b.is_ascii_lowercase() || b.is_ascii_digit() || b == b'-';
    let bytes = text.as_bytes();
    let mut at = 0;
    while let Some(rel) = text[at..].find(verb) {
        let off = at + rel;
        at = off + 1;
        let before_ok = off == 0 || !is_verb_char(bytes[off - 1]);
        let after_ok = off + verb.len() >= bytes.len() || !is_verb_char(bytes[off + verb.len()]);
        if before_ok && after_ok {
            return true;
        }
    }
    false
}

/// Every `op::IDENT` reference with an uppercase identifier in cleaned
/// text (lowercase refs like `op::unknown` / `op::name` are helper
/// calls, not opcode consts).
fn op_refs(sf: &SourceFile) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    let needle = "op::";
    let mut at = 0;
    while let Some(rel) = sf.cleaned[at..].find(needle) {
        let off = at + rel;
        at = off + needle.len();
        if off > 0 && is_ident(sf.cleaned.as_bytes()[off - 1]) {
            continue; // wire_ops:: or some_op:: — not the `op` alias
        }
        let rest = &sf.cleaned[off + needle.len()..];
        let end = rest.bytes().position(|b| !is_ident(b)).unwrap_or(rest.len());
        let ident = &rest[..end];
        if ident.starts_with(|c: char| c.is_ascii_uppercase()) {
            out.push((ident.to_string(), sf.line_of(off)));
        }
    }
    out
}
