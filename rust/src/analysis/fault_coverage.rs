//! `fault-coverage`: every durable-path filesystem mutation under
//! `store/` must be reachable by the deterministic fault plane.
//!
//! The store's crash-safety story rests on `store::faults` wrapping
//! (or guarding) each write/sync/rename so the fault harness can fail
//! it on demand. A raw `File::create` / `.write_all` / `.sync_data` /
//! `.sync_all` / `fs::rename` that the harness cannot reach is a
//! durability claim the crash tests silently stop exercising. The rule
//! is function-granular: the enclosing `fn` must touch `faults::`
//! somewhere (a shim call, or a `faults::fire` checkpoint before the
//! raw op). `store/faults.rs` itself and `#[cfg(test)]` modules are
//! exempt; anything else needs a `// lint: allow(fault-coverage)`
//! annotation with a reason.

use super::lex::SourceFile;
use super::Violation;

pub const PASS: &str = "fault-coverage";

/// Tokens that mutate durable state. Matched against cleaned text, so
/// string literals and comments cannot trip them; `faults::write_all(`
/// does not match `.write_all(` (the leading dot is part of the
/// token).
const TOKENS: &[&str] =
    &["File::create(", ".write_all(", ".sync_data(", ".sync_all(", "fs::rename("];

pub fn check(sf: &SourceFile) -> Vec<Violation> {
    if !sf.path.starts_with("store/") || sf.path == "store/faults.rs" {
        return Vec::new();
    }
    let mut out = Vec::new();
    let spans = sf.fn_spans();
    let tests = sf.test_spans();
    for token in TOKENS {
        let mut at = 0;
        while let Some(rel) = sf.cleaned[at..].find(token) {
            let off = at + rel;
            at = off + token.len();
            let line = sf.line_of(off);
            if tests.iter().any(|t| t.contains(&line)) {
                continue;
            }
            // innermost enclosing fn: the last span (file order ~
            // nesting order) whose body contains the offset
            let encl = spans.iter().rev().find(|s| s.body.contains(&off));
            let covered = encl
                .map(|s| sf.cleaned[s.body.clone()].contains("faults::"))
                .unwrap_or(false);
            if !covered {
                let what = token.trim_start_matches('.').trim_end_matches('(');
                let fn_name = encl.map_or("<no enclosing fn>", |s| s.name.as_str());
                out.push(Violation {
                    pass: PASS,
                    file: sf.path.clone(),
                    line,
                    message: format!(
                        "raw `{what}` in `{fn_name}` is invisible to the fault plane; \
                         route it through a `store::faults` shim or add a `faults::fire` \
                         checkpoint in this fn"
                    ),
                });
            }
        }
    }
    out
}
