//! `no-panic-paths`: request-serving and durability code must return
//! errors, not panic.
//!
//! A panic in the server loop kills the connection task; a panic while
//! holding the WAL commit gate can poison it for every other writer; a
//! panic in the replicator silently stops anti-entropy. The scoped
//! functions below are the paths where an attacker-supplied frame or a
//! torn file on disk must surface as `Err`, so `.unwrap()`,
//! `.expect(…)`, the panicking macros, and slice/array indexing are
//! all flagged inside them.
//!
//! The scope list is intentionally explicit (file + fn names): renames
//! fail the lint until the list is updated, which is the point — the
//! panic-freedom contract should not silently evaporate in a refactor.
//! Indexing is detected heuristically: a `[` immediately preceded by
//! an identifier, `)`, or `]`. Attributes (`#[…]`), array types
//! (`[u8; 4]`), and `vec![…]` do not match. Sites that are provably
//! fine (e.g. a lock poisoned only by a panic elsewhere, where
//! propagating would double-fail) carry
//! `// lint: allow(no-panic-paths) <reason>` annotations.

use super::lex::SourceFile;
use super::Violation;

pub const PASS: &str = "no-panic-paths";

/// (file path, scoped fn names). Every name must resolve to at least
/// one non-test `fn` in that file.
pub const SCOPES: &[(&str, &[&str])] = &[
    (
        "store/server.rs",
        &[
            "accept_loop",
            "connection_loop",
            "handle_request",
            "dispatch",
            "write_frame",
            "read_frame_into",
            "put_entries",
            "tensor_family",
        ],
    ),
    (
        "store/wal.rs",
        &[
            "commit_frame",
            "append_frames",
            "write_and_sync",
            "append_record",
            "append_payload",
            "gate_shared",
            "gate_excl",
        ],
    ),
    ("store/replica/mod.rs", &["run", "sync_peer", "sync_tensors", "stage"]),
    // kernel dispatch sits under every batched write: resolving the
    // path (env probe + CPU feature detection) must never panic, or a
    // misspelt HOCS_KERNEL could take down the serve loop
    ("sketch/kernel.rs", &["configured", "best_vector_path"]),
    // observability runs inside every instrumented hot path: a panic
    // while counting or rendering would turn telemetry into an outage
    ("obs/registry.rs", &["rpc_observe", "render_into"]),
    ("obs/trace.rs", &["span"]),
    ("obs/mod.rs", &["render_text"]),
];

const TOKENS: &[&str] =
    &[".unwrap()", ".expect(", "panic!", "unreachable!", "todo!", "unimplemented!"];

pub fn check(sf: &SourceFile) -> Vec<Violation> {
    let Some((_, fns)) = SCOPES.iter().find(|(path, _)| *path == sf.path) else {
        return Vec::new();
    };
    check_fns(sf, fns)
}

/// Split from [`check`] so fixtures can be scanned under an arbitrary
/// fn list without masquerading as a scoped file.
pub fn check_fns(sf: &SourceFile, fns: &[&str]) -> Vec<Violation> {
    let mut out = Vec::new();
    let spans = sf.fn_spans();
    let tests = sf.test_spans();
    for name in fns {
        let mut found = false;
        for span in spans.iter().filter(|s| s.name == *name) {
            if tests.iter().any(|t| t.contains(&span.start_line)) {
                continue;
            }
            found = true;
            scan_body(sf, span.body.clone(), name, &mut out);
        }
        if !found {
            out.push(Violation {
                pass: PASS,
                file: sf.path.clone(),
                line: 0,
                message: format!(
                    "scoped fn `{name}` not found — update the no-panic-paths scope list \
                     in analysis/no_panic.rs to match the refactor"
                ),
            });
        }
    }
    out
}

fn scan_body(
    sf: &SourceFile,
    body: std::ops::Range<usize>,
    fn_name: &str,
    out: &mut Vec<Violation>,
) {
    let text = &sf.cleaned[body.clone()];
    for token in TOKENS {
        let mut at = 0;
        while let Some(rel) = text[at..].find(token) {
            let off = at + rel;
            at = off + token.len();
            out.push(Violation {
                pass: PASS,
                file: sf.path.clone(),
                line: sf.line_of(body.start + off),
                message: format!(
                    "`{}` in `{fn_name}` can panic on a served path; return an error instead",
                    token.trim_end_matches('(')
                ),
            });
        }
    }
    // indexing heuristic: `[` directly after an ident / `)` / `]`
    let bytes = text.as_bytes();
    for (i, &b) in bytes.iter().enumerate() {
        if b == b'[' && i > 0 {
            let p = bytes[i - 1];
            if super::lex::is_ident(p) || p == b')' || p == b']' {
                out.push(Violation {
                    pass: PASS,
                    file: sf.path.clone(),
                    line: sf.line_of(body.start + i),
                    message: format!(
                        "indexing in `{fn_name}` can panic on a served path; \
                         use `.get(…)` and return an error"
                    ),
                });
            }
        }
    }
}
