//! Comment- and string-aware source model for the lint passes.
//!
//! Deliberately **not** a Rust parser. [`SourceFile`] blanks comment
//! text and string/char-literal contents with spaces — preserving byte
//! offsets and line structure exactly — records every line comment,
//! and recovers `fn` spans and `#[cfg(test)]` module spans by brace
//! matching over the cleaned text. That is enough for the token-level
//! passes to scan without being fooled by `panic!` in a doc comment or
//! `File::create` inside an error-message string, while staying a few
//! hundred lines with zero dependencies.
//!
//! Handled literal forms: `//` and nested `/* */` comments, plain and
//! byte strings (`"…"`, `b"…"`), raw and raw-byte strings
//! (`r"…"`, `r#"…"#`, `br#"…"#`), char and byte-char literals
//! (`'x'`, `b'x'`, `'\n'`, `'\u{…}'`), and lifetimes/labels (`'a`,
//! `'outer:`). Accepted limitation, absent from this codebase:
//! a multibyte char literal (`'é'`) is treated as a lifetime. The
//! self-run lint test is the backstop if a blind spot ever matters.

use std::ops::Range;

/// One source file, cleaned for token scanning.
pub struct SourceFile {
    /// display path, `/`-separated, relative to the lint root
    pub path: String,
    /// original text (string literals visible — table parsing)
    pub raw: String,
    /// same byte length as `raw`: comment text and literal contents
    /// replaced by spaces (delimiters kept), newlines preserved
    pub cleaned: String,
    /// byte offset of each line start (index 0 = line 1)
    line_starts: Vec<usize>,
    /// every `//`-style comment, in file order
    pub comments: Vec<Comment>,
}

/// One `//` comment (doc comments included — callers filter).
pub struct Comment {
    /// 1-based line
    pub line: usize,
    /// full text including the leading slashes
    pub text: String,
    /// code precedes it on the same line (a trailing comment)
    pub trailing: bool,
}

/// A `fn` item: where it starts, where its body ends, and the body's
/// byte range in `cleaned`/`raw`. Bodyless trait methods are skipped.
pub struct FnSpan {
    pub name: String,
    /// 1-based line of the `fn` keyword
    pub start_line: usize,
    /// 1-based line of the closing brace
    pub end_line: usize,
    /// byte range strictly inside the braces
    pub body: Range<usize>,
}

impl SourceFile {
    pub fn parse(path: &str, raw: &str) -> Self {
        let (cleaned, comments) = clean(raw);
        debug_assert_eq!(cleaned.len(), raw.len(), "cleaning must preserve offsets");
        let mut line_starts = vec![0];
        for (i, b) in raw.bytes().enumerate() {
            if b == b'\n' {
                line_starts.push(i + 1);
            }
        }
        Self { path: path.to_string(), raw: raw.to_string(), cleaned, line_starts, comments }
    }

    /// 1-based line containing byte `offset`.
    pub fn line_of(&self, offset: usize) -> usize {
        self.line_starts.partition_point(|&s| s <= offset)
    }

    pub fn line_count(&self) -> usize {
        self.line_starts.len()
    }

    /// Cleaned text of a 1-based line (without the newline).
    pub fn line(&self, line: usize) -> &str {
        let start = self.line_starts[line - 1];
        let end = self.line_starts.get(line).map_or(self.cleaned.len(), |&e| e - 1);
        &self.cleaned[start..end.max(start)]
    }

    /// Every `fn` item with a body, nested ones included, in order of
    /// the `fn` keyword. `fn(u32) -> u32` pointer *types* never match:
    /// the keyword must be followed by an identifier.
    pub fn fn_spans(&self) -> Vec<FnSpan> {
        let c = self.cleaned.as_bytes();
        let mut spans = Vec::new();
        let mut i = 0;
        while i + 2 < c.len() {
            let at_kw = c[i] == b'f'
                && c[i + 1] == b'n'
                && (i == 0 || !is_ident(c[i - 1]))
                && c[i + 2].is_ascii_whitespace();
            if !at_kw {
                i += 1;
                continue;
            }
            let mut j = i + 2;
            while j < c.len() && c[j].is_ascii_whitespace() {
                j += 1;
            }
            let name_start = j;
            while j < c.len() && is_ident(c[j]) {
                j += 1;
            }
            if j == name_start {
                i = j.max(i + 1);
                continue;
            }
            let name = self.cleaned[name_start..j].to_string();
            // body opens at the first `{` before any `;` (a `;` first
            // means a bodyless trait-method declaration)
            let mut k = j;
            while k < c.len() && c[k] != b'{' && c[k] != b';' {
                k += 1;
            }
            if k < c.len() && c[k] == b'{' {
                if let Some(end) = match_brace(c, k) {
                    spans.push(FnSpan {
                        name,
                        start_line: self.line_of(i),
                        end_line: self.line_of(end),
                        body: k + 1..end,
                    });
                }
            }
            // resume right after the name so nested fns are still seen
            i = j;
        }
        spans
    }

    /// Line ranges (1-based, inclusive) of `#[cfg(test)]` modules —
    /// test code is exempt from the production-path passes.
    pub fn test_spans(&self) -> Vec<Range<usize>> {
        let mut out = Vec::new();
        let mut line = 1;
        while line <= self.line_count() {
            if self.line(line).trim() == "#[cfg(test)]" {
                let attr_end = self.line_starts[line - 1] + self.line(line).len();
                if let Some(rel) = self.cleaned[attr_end..].find('{') {
                    let open = attr_end + rel;
                    if let Some(end) = match_brace(self.cleaned.as_bytes(), open) {
                        let end_line = self.line_of(end);
                        out.push(line..end_line + 1);
                        line = end_line + 1;
                        continue;
                    }
                }
            }
            line += 1;
        }
        out
    }
}

pub fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Byte offset of the `}` matching the `{` at `open`.
pub fn match_brace(bytes: &[u8], open: usize) -> Option<usize> {
    debug_assert_eq!(bytes[open], b'{');
    let mut depth = 0usize;
    for (i, &b) in bytes.iter().enumerate().skip(open) {
        match b {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    return Some(i);
                }
            }
            _ => {}
        }
    }
    None
}

/// The state machine: blank comments and literal contents, keep
/// delimiters and newlines, collect line comments.
fn clean(raw: &str) -> (String, Vec<Comment>) {
    let b = raw.as_bytes();
    let mut out: Vec<u8> = Vec::with_capacity(b.len());
    let mut comments = Vec::new();
    let mut line = 1usize;
    let mut line_has_code = false;
    let mut i = 0;
    while i < b.len() {
        match b[i] {
            b'\n' => {
                out.push(b'\n');
                line += 1;
                line_has_code = false;
                i += 1;
            }
            b'/' if b.get(i + 1) == Some(&b'/') => {
                let start = i;
                while i < b.len() && b[i] != b'\n' {
                    i += 1;
                }
                comments.push(Comment {
                    line,
                    text: raw[start..i].to_string(),
                    trailing: line_has_code,
                });
                out.resize(out.len() + (i - start), b' ');
            }
            b'/' if b.get(i + 1) == Some(&b'*') => {
                let mut depth = 1usize;
                out.extend_from_slice(b"  ");
                i += 2;
                while i < b.len() && depth > 0 {
                    if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
                        depth += 1;
                        out.extend_from_slice(b"  ");
                        i += 2;
                    } else if b[i] == b'*' && b.get(i + 1) == Some(&b'/') {
                        depth -= 1;
                        out.extend_from_slice(b"  ");
                        i += 2;
                    } else if b[i] == b'\n' {
                        out.push(b'\n');
                        line += 1;
                        line_has_code = false;
                        i += 1;
                    } else {
                        out.push(b' ');
                        i += 1;
                    }
                }
            }
            b'"' => {
                line_has_code = true;
                i = blank_quoted(b, i, &mut out, &mut line);
            }
            b'b' if !prev_is_ident(b, i) => {
                line_has_code = true;
                match b.get(i + 1) {
                    Some(&b'"') => {
                        out.push(b'b');
                        i = blank_quoted(b, i + 1, &mut out, &mut line);
                    }
                    Some(&b'\'') => {
                        out.push(b'b');
                        i = char_or_lifetime(b, i + 1, &mut out);
                    }
                    Some(&b'r') if raw_str_quote(b, i + 2).is_some() => {
                        out.extend_from_slice(b"br");
                        i = blank_raw(b, i + 2, &mut out, &mut line);
                    }
                    _ => {
                        out.push(b'b');
                        i += 1;
                    }
                }
            }
            b'r' if !prev_is_ident(b, i) && raw_str_quote(b, i + 1).is_some() => {
                line_has_code = true;
                out.push(b'r');
                i = blank_raw(b, i + 1, &mut out, &mut line);
            }
            b'\'' => {
                line_has_code = true;
                i = char_or_lifetime(b, i, &mut out);
            }
            c => {
                if c != b' ' && c != b'\t' {
                    line_has_code = true;
                }
                out.push(c);
                i += 1;
            }
        }
    }
    let cleaned = String::from_utf8(out).unwrap_or_else(|_| raw.to_string());
    (cleaned, comments)
}

fn prev_is_ident(b: &[u8], i: usize) -> bool {
    i > 0 && is_ident(b[i - 1])
}

/// `#`-count + quote check for a raw-string start at `i` (the byte
/// after `r` / `br`). Returns the offset of the opening `"`.
fn raw_str_quote(b: &[u8], i: usize) -> Option<usize> {
    let mut j = i;
    while b.get(j) == Some(&b'#') {
        j += 1;
    }
    (b.get(j) == Some(&b'"')).then_some(j)
}

/// Blank a plain/byte string starting at the `"` at `i`; returns the
/// index past the closing quote. Escapes are blanked pairwise so `\"`
/// cannot terminate early; newlines inside survive for line tracking —
/// including one consumed by a `\`-newline continuation escape.
fn blank_quoted(b: &[u8], i: usize, out: &mut Vec<u8>, line: &mut usize) -> usize {
    out.push(b'"');
    let mut j = i + 1;
    while j < b.len() {
        match b[j] {
            b'\\' if j + 1 < b.len() => {
                if b[j + 1] == b'\n' {
                    out.extend_from_slice(b" \n");
                    *line += 1;
                } else {
                    out.extend_from_slice(b"  ");
                }
                j += 2;
            }
            b'"' => {
                out.push(b'"');
                return j + 1;
            }
            b'\n' => {
                out.push(b'\n');
                *line += 1;
                j += 1;
            }
            _ => {
                out.push(b' ');
                j += 1;
            }
        }
    }
    j
}

/// Blank a raw (byte) string: `i` points at the first `#` or the `"`;
/// contents end at `"` followed by the same number of `#`s.
fn blank_raw(b: &[u8], i: usize, out: &mut Vec<u8>, line: &mut usize) -> usize {
    let quote = match raw_str_quote(b, i) {
        Some(q) => q,
        None => return i,
    };
    let hashes = quote - i;
    out.resize(out.len() + hashes, b'#');
    out.push(b'"');
    let mut j = quote + 1;
    while j < b.len() {
        let closes = b[j] == b'"'
            && b.get(j + 1..j + 1 + hashes).is_some_and(|tail| tail.iter().all(|&h| h == b'#'));
        if closes {
            out.push(b'"');
            out.resize(out.len() + hashes, b'#');
            return j + 1 + hashes;
        }
        if b[j] == b'\n' {
            out.push(b'\n');
            *line += 1;
        } else {
            out.push(b' ');
        }
        j += 1;
    }
    j
}

/// Disambiguate `'` at `i`: a char literal (`'x'`, `'\n'`, `'\u{…}'`)
/// is blanked; a lifetime or loop label passes through untouched.
fn char_or_lifetime(b: &[u8], i: usize, out: &mut Vec<u8>) -> usize {
    if b.get(i + 1) == Some(&b'\\') {
        // escaped char literal: blank through the closing quote
        out.push(b'\'');
        out.extend_from_slice(b"  ");
        let mut j = i + 3;
        while j < b.len() && b[j] != b'\'' && b[j] != b'\n' {
            out.push(b' ');
            j += 1;
        }
        if b.get(j) == Some(&b'\'') {
            out.push(b'\'');
            j += 1;
        }
        return j;
    }
    if b.get(i + 2) == Some(&b'\'') && b.get(i + 1).is_some_and(|&c| c != b'\'' && c != b'\\') {
        out.extend_from_slice(b"' '");
        return i + 3;
    }
    out.push(b'\'');
    i + 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cleaning_blanks_comments_and_literals_but_keeps_offsets() {
        let src = r#"fn f() -> u8 { // panic! here is prose
    let s = "a panic! inside a string";
    let c = 'x';
    let lt: &'static str = s; /* and panic!
       across lines */
    0
}
"#;
        let sf = SourceFile::parse("t.rs", src);
        assert_eq!(sf.cleaned.len(), src.len());
        assert!(!sf.cleaned.contains("panic!"), "no panic token may survive cleaning");
        assert!(sf.cleaned.contains("'static"), "lifetimes survive");
        assert_eq!(sf.comments.len(), 1);
        assert!(sf.comments[0].trailing);
        // every newline is preserved, so line math holds
        assert_eq!(
            sf.cleaned.bytes().filter(|&b| b == b'\n').count(),
            src.bytes().filter(|&b| b == b'\n').count()
        );
    }

    #[test]
    fn raw_and_byte_strings_are_blanked() {
        let src = "let a = r#\"panic! \"quoted\"\"#; let b = b\"panic!\"; let c = br#\"x\"#;";
        let sf = SourceFile::parse("t.rs", src);
        assert_eq!(sf.cleaned.len(), src.len());
        assert!(!sf.cleaned.contains("panic!"));
        assert!(!sf.cleaned.contains("quoted"));
    }

    #[test]
    fn fn_spans_cover_bodies_and_skip_trait_decls() {
        let src = concat!(
            "trait T { fn decl(&self); }\n",
            "fn outer() {\n    fn inner() { let _ = 1; }\n    inner();\n}\n"
        );
        let sf = SourceFile::parse("t.rs", src);
        let spans = sf.fn_spans();
        let names: Vec<_> = spans.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, ["outer", "inner"], "decl has no body; nested fns are seen");
        let outer = &spans[0];
        assert_eq!((outer.start_line, outer.end_line), (2, 5));
        assert!(sf.cleaned[outer.body.clone()].contains("inner()"));
    }

    #[test]
    fn test_spans_find_cfg_test_modules() {
        let src = concat!(
            "fn live() {}\n#[cfg(test)]\nmod tests {\n",
            "    #[test]\n    fn t() { assert!(true); }\n}\n"
        );
        let sf = SourceFile::parse("t.rs", src);
        let spans = sf.test_spans();
        assert_eq!(spans.len(), 1);
        assert!(spans[0].contains(&5), "test fn line is inside the span");
        assert!(!spans[0].contains(&1), "live code is outside");
    }

    #[test]
    fn char_literal_quote_does_not_unbalance_strings() {
        let src = "let q = '\"'; let s = \"after\"; let esc = '\\''; let done = 1;";
        let sf = SourceFile::parse("t.rs", src);
        assert_eq!(sf.cleaned.len(), src.len());
        assert!(!sf.cleaned.contains("after"), "string after a quote char literal is blanked");
        assert!(sf.cleaned.contains("done"), "code after an escaped-quote literal survives");
    }
}
