//! `version-gate`: the on-disk format cannot drift without a
//! `FORMAT_VERSION` bump.
//!
//! The durable format is defined entirely in `store/wal.rs`: the
//! header consts, the `WalRecord` enum (what the log can contain), the
//! `TAG_*` record tags (how each variant is framed), and the snapshot
//! section list in `write_snapshot_file` (what the image contains, in
//! order). This pass extracts all four into a canonical text manifest
//! and compares it against the pinned manifest for the current
//! version, shipped as `analysis/format_manifest_v<N>.txt`.
//!
//! A deliberate format change is a three-line ritual: bump
//! `FORMAT_VERSION`, run `hocs lint --print-manifest
//! > rust/src/analysis/format_manifest_v<N>.txt`, add the
//! `include_str!` pin below. An *accidental* change — a new enum
//! variant, a reordered snapshot section, a retagged record — fails
//! the lint with the first drifted line. The manifest is plain
//! diffable text rather than a hash precisely so the failure shows
//! *what* moved.
//!
//! Extraction is line-based over raw source: each candidate line is
//! cut at its first `//` and trimmed, so comments move freely without
//! touching the manifest. (A `//` inside a string literal on a
//! format-defining line would cut early; none of the extracted line
//! shapes carry URLs or comment-like strings.)

use super::lex::SourceFile;
use super::Violation;

pub const PASS: &str = "version-gate";

/// Pinned manifests, one per shipped `FORMAT_VERSION`.
const PINS: &[(u32, &str)] = &[(5, include_str!("format_manifest_v5.txt"))];

pub fn check(sf: &SourceFile) -> Vec<Violation> {
    check_against(sf, PINS)
}

/// Split from [`check`] so fixtures can be validated against synthetic
/// pin sets.
pub fn check_against(sf: &SourceFile, pins: &[(u32, &str)]) -> Vec<Violation> {
    let (manifest, version) = match extract_manifest(&sf.raw) {
        Ok(m) => m,
        Err(msg) => {
            return vec![Violation { pass: PASS, file: sf.path.clone(), line: 0, message: msg }]
        }
    };
    let Some((_, pinned)) = pins.iter().find(|(v, _)| *v == version) else {
        return vec![Violation {
            pass: PASS,
            file: sf.path.clone(),
            line: 0,
            message: format!(
                "FORMAT_VERSION {version} has no pinned manifest; generate one with \
                 `hocs lint --print-manifest > rust/src/analysis/format_manifest_v{version}.txt` \
                 and pin it in analysis/version_gate.rs"
            ),
        }];
    };
    if manifest == *pinned {
        return Vec::new();
    }
    let drift = first_diff(&manifest, pinned);
    vec![Violation {
        pass: PASS,
        file: sf.path.clone(),
        line: 0,
        message: format!(
            "on-disk format drifted without a FORMAT_VERSION bump ({drift}); if the \
             change is intentional, bump FORMAT_VERSION and re-pin the manifest"
        ),
    }]
}

/// Canonical format manifest for a `wal.rs`-shaped source, plus the
/// `FORMAT_VERSION` it declares.
pub fn extract_manifest(raw: &str) -> Result<(String, u32), String> {
    let lines: Vec<&str> = raw.lines().collect();
    let mut out = Vec::new();

    out.push("[format]".to_string());
    let mut version = None;
    for prefix in
        ["const FORMAT_VERSION:", "const SNAP_MAGIC:", "const WAL_MAGIC:", "const HEADER_LEN:"]
    {
        let Some(line) = lines.iter().map(|l| cut(l)).find(|l| l.starts_with(prefix)) else {
            return Err(format!("format const `{prefix}` not found"));
        };
        if prefix == "const FORMAT_VERSION:" {
            version = line
                .split('=')
                .nth(1)
                .and_then(|v| v.trim().trim_end_matches(';').parse::<u32>().ok());
        }
        out.push(line.to_string());
    }
    let Some(version) = version else {
        return Err("FORMAT_VERSION value is not a literal integer".to_string());
    };

    out.push("[wal-record-tags]".to_string());
    let mut tags = 0;
    for line in lines.iter().map(|l| cut(l)) {
        if line.starts_with("const TAG_") {
            out.push(line.to_string());
            tags += 1;
        }
    }
    if tags == 0 {
        return Err("no `const TAG_` record tags found".to_string());
    }

    out.push("[wal-record-shapes]".to_string());
    let Some(open) = lines.iter().position(|l| l.trim() == "pub enum WalRecord {") else {
        return Err("`pub enum WalRecord {` not found".to_string());
    };
    let Some(close) = lines[open + 1..].iter().position(|l| l.starts_with('}')) else {
        return Err("WalRecord enum is unterminated".to_string());
    };
    for line in &lines[open + 1..open + 1 + close] {
        let line = cut(line);
        if !line.is_empty() {
            out.push(line.to_string());
        }
    }

    out.push("[snapshot-sections]".to_string());
    let Some(snap) = lines.iter().position(|l| l.contains("fn write_snapshot_file")) else {
        return Err("`fn write_snapshot_file` not found".to_string());
    };
    let Some(end) = lines[snap..].iter().position(|l| *l == "    }") else {
        return Err("write_snapshot_file is unterminated".to_string());
    };
    let mut sections = 0;
    for line in &lines[snap..snap + end] {
        let line = cut(line);
        if line.starts_with("out.") || line.contains("&mut out") {
            out.push(line.to_string());
            sections += 1;
        }
    }
    if sections == 0 {
        return Err("no snapshot section lines found in write_snapshot_file".to_string());
    }

    Ok((out.join("\n") + "\n", version))
}

/// Cut a raw line at its first `//` and trim both ends.
fn cut(line: &str) -> &str {
    line.find("//").map_or(line, |p| &line[..p]).trim()
}

fn first_diff(got: &str, pinned: &str) -> String {
    for (i, (g, p)) in got.lines().zip(pinned.lines()).enumerate() {
        if g != p {
            return format!("manifest line {}: pinned `{p}` vs source `{g}`", i + 1);
        }
    }
    let (g, p) = (got.lines().count(), pinned.lines().count());
    format!("manifest length changed: pinned {p} lines vs source {g}")
}
